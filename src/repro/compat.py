"""Version-compat shims over the jax API surface this codebase targets.

The code is written against the current jax API (``jax.shard_map``,
``jax.sharding.AxisType``, ``pltpu.CompilerParams``); older releases (the
0.4.x line this container ships) spell those differently or not at all.
Everything version-sensitive goes through this module so call sites stay on
the forward-looking spelling.
"""

from __future__ import annotations

from typing import Any, Sequence

import jax


def tpu_compiler_params(**kwargs) -> Any:
    """``pltpu.CompilerParams(...)`` (new) / ``pltpu.TPUCompilerParams`` (0.4.x)."""
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(**kwargs)


def make_mesh(shape: Sequence[int], axes: Sequence[str]):
    """``jax.make_mesh`` with all axes Auto; drops ``axis_types`` on old jax."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def get_abstract_mesh():
    """``jax.sharding.get_abstract_mesh`` or None when the concept is absent."""
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    return fn() if fn is not None else None


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None, check_vma=False):
    """``jax.shard_map`` with partial-manual axes.

    On 0.4.x this maps to ``jax.experimental.shard_map.shard_map`` where the
    manual/auto split is expressed inversely (``auto`` = mesh axes *not* in
    ``axis_names``) and ``check_vma`` is called ``check_rep`` (which must be
    off for partial-auto regions).
    """
    if hasattr(jax, "shard_map"):
        kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=check_vma)
        if axis_names is not None:
            kwargs["axis_names"] = set(axis_names)
        return jax.shard_map(f, **kwargs)

    from jax.experimental.shard_map import shard_map as _shard_map

    # an empty axis_names means "all axes manual" (the new-jax default), so
    # only a non-empty set carves out auto axes here
    auto: frozenset = frozenset()
    if axis_names:
        auto = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=bool(check_vma) and not auto, auto=auto,
    )

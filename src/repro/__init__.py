"""repro — NTX near-memory DNN training, rebuilt as a multi-pod JAX/TPU framework.

The paper's contributions are exposed as composable subsystems:

- :mod:`repro.core`      — wide accumulation, NTX offload descriptors, tiling,
                            strided-conv decomposition, systolic mesh collectives.
- :mod:`repro.lower`     — the unified lowering pipeline: layer specs ->
                            NtxProgram IR -> {reference, timing, Pallas} executors.
- :mod:`repro.kernels`   — Pallas TPU kernels (ntx_matmul, flash_attention, ssd_scan,
                            conv2d) with jnp oracles.
- :mod:`repro.models`    — the model zoo (dense/MoE/hybrid/SSM decoders) and
                            train/serve steps.
- :mod:`repro.parallel`  — sharding rules and collective helpers (DP/TP/EP/SP).
- :mod:`repro.data`      — in-memory sharded dataset (the paper's "large in-memory
                            dataset" tier).
- :mod:`repro.optim`     — optimizers + gradient compression.
- :mod:`repro.checkpoint`— sharded, atomic, elastic checkpoints.
- :mod:`repro.runtime`   — fault-tolerant supervisor (restart, elastic re-mesh,
                            straggler policy).
- :mod:`repro.configs`   — assigned architecture configs (+ paper workloads).
- :mod:`repro.launch`    — production mesh, dry-run, train/serve drivers.
"""

__version__ = "0.1.0"

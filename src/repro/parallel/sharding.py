"""Sharding rules: parameter/optimizer/cache PartitionSpecs for the zoo.

Parallelism map (production mesh (pod, data, model)):

  * DP  — batch over ("pod", "data"); the pod axis is the paper's
          mesh-of-HMCs tier (C6), "data" the intra-pod tier.
  * TP  — "model": attention heads, FFN hidden, vocab, experts, rnn width.
          Head counts not divisible by the axis are GSPMD-padded (overhead
          reported per arch in EXPERIMENTS.md §Roofline).
  * EP  — experts live on "model" (see models/moe.py).
  * SP  — long-context cells shard the *sequence* over "data"
          (ParallelCtx.seq_axis) instead of the batch.
  * ZeRO-1 — optimizer state additionally sharded over the DP axes on the
          first divisible unsharded dim (:func:`zero1_spec`).

Specs are derived from tree *paths* (module name + leaf name), so they work
for any pattern mix and for unit-stacked (leading-axis) parameter trees.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

TP = "model"

# (module, leaf) -> layer-local spec (without the unit-stacking dim).
_RULES: dict[tuple[str, str], tuple] = {
    # attention
    ("attn", "wq"): (None, TP),
    ("attn", "wk"): (None, TP),
    ("attn", "wv"): (None, TP),
    ("attn", "wo"): (TP, None),
    ("attn", "bq"): (TP,),
    ("attn", "bk"): (TP,),
    ("attn", "bv"): (TP,),
    # mlp
    ("mlp", "w_gate"): (None, TP),
    ("mlp", "w_up"): (None, TP),
    ("mlp", "w_down"): (TP, None),
    ("shared", "w_gate"): (None, TP),
    ("shared", "w_up"): (None, TP),
    ("shared", "w_down"): (TP, None),
    # moe (experts on the model axis = EP; expert FFN dim FSDP-sharded over
    # "data" — gathered per layer inside the EP body — so 400B-param expert
    # banks fit per-chip: see models/moe.py and DESIGN.md §Distribution)
    ("moe", "router"): (None, None),
    ("moe", "w_gate"): (TP, None, "data"),
    ("moe", "w_up"): (TP, None, "data"),
    ("moe", "w_down"): (TP, "data", None),
    # rg-lru
    ("rec", "w_gelu"): (None, TP),
    ("rec", "w_rnn"): (None, TP),
    ("rec", "w_out"): (TP, None),
    ("rec", "conv_w"): (None, TP),
    ("rec", "conv_b"): (TP,),
    ("rec", "w_a"): (TP, None, None),  # block-diagonal gates: blocks on TP
    ("rec", "w_x"): (TP, None, None),
    ("rec", "lambda"): (TP,),
    # mamba2
    ("ssm", "w_z"): (None, TP),
    ("ssm", "w_x"): (None, TP),
    ("ssm", "w_b"): (None, None),  # tiny (d, g*n): replicated
    ("ssm", "w_c"): (None, None),
    ("ssm", "w_dt"): (None, TP),
    ("ssm", "conv_wx"): (None, TP),
    ("ssm", "conv_bx"): (TP,),
    ("ssm", "conv_wb"): (None, None),
    ("ssm", "conv_bb"): (None,),
    ("ssm", "conv_wc"): (None, None),
    ("ssm", "conv_bc"): (None,),
    ("ssm", "a_log"): (TP,),
    ("ssm", "dt_bias"): (TP,),
    ("ssm", "d_skip"): (TP,),
    ("ssm", "w_out"): (TP, None),
    # top level
    ("", "embed"): (TP, None),  # vocab-sharded
    ("", "lm_head"): (None, TP),
}

_MODULES = ("attn", "moe", "shared", "mlp", "rec", "ssm")

# CNN layer specs (the repro.lower graph compiler), keyed by spec class
# name so lower/ stays import-light. Same column-parallel convention as
# the attention/mlp rows above: the *output-feature* axis goes on the
# model axis — conv weights are HWIO so cout is last, matmul weights are
# [k, n] so n is last, bias is (c,). The 2D mesh splitter
# (repro.lower.mesh, shard="2d") consumes this to decide which layers
# tensor-shard their output-channel rep level across a mesh row; layers
# without a rule (pool/relu/flatten and anything future) stay data-split.
CNN_RULES: dict[str, tuple] = {
    "Conv2dSpec": (None, None, None, TP),
    "MatmulSpec": (None, TP),
    "BiasSpec": (TP,),
}


def cnn_param_spec(spec: Any) -> tuple | None:
    """Layer-local partition tuple for a CNN layer spec, or None.

    Returns the ``CNN_RULES`` row for the spec's class (None when the
    layer has no tensor-sharding rule). A row containing :data:`TP`
    means the layer's output features are split across the model axis.
    """
    return CNN_RULES.get(type(spec).__name__)


def _path_names(path) -> list[str]:
    names = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            names.append(str(k.key))
        elif isinstance(k, jax.tree_util.SequenceKey):
            names.append(f"[{k.idx}]")
        else:
            names.append(str(k))
    return names


def spec_for_path(path, shape) -> P:
    """PartitionSpec for one parameter leaf, inferring unit-stacking."""
    names = _path_names(path)
    leaf = names[-1]
    module = ""
    for n in names[:-1]:
        if n in _MODULES:
            module = n
    # norms (any *norm* module or scale/bias leaves) are replicated, except
    # the ssm gated-norm scale which lives on the sharded d_inner.
    if leaf in ("scale", "bias"):
        if module == "ssm" and "norm" in names:
            base = (TP,)
        else:
            base = (None,) * _infer_rank_tail(shape, 1)
            return _pad_spec(base, shape)
        return _pad_spec(base, shape)
    key = (module, leaf)
    if key not in _RULES and ("", leaf) in _RULES:
        key = ("", leaf)
    if key not in _RULES:
        return P(*((None,) * len(shape)))  # replicate unknowns
    base = _RULES[key]
    return _pad_spec(base, shape)


def _infer_rank_tail(shape, tail: int) -> int:
    return tail


def _pad_spec(base: tuple, shape) -> P:
    """Left-pad the layer-local spec with None for unit-stacking dims."""
    pad = len(shape) - len(base)
    assert pad >= 0, (base, shape)
    return P(*(((None,) * pad) + tuple(base)))


def sanitize_spec(spec: P, shape, mesh) -> P:
    """Drop (replicate) any spec axis whose dim isn't divisible by the axis.

    Explicit pjit in_shardings require exact divisibility; e.g. mamba2's
    vocab 50280 cannot shard 16-way, so its embedding stays replicated.
    """
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for e, d in zip(entries, shape):
        if e is None:
            out.append(None)
            continue
        axes = e if isinstance(e, tuple) else (e,)
        n = math.prod(mesh.shape[a] for a in axes)
        out.append(e if (d % n == 0 and d >= n) else None)
    return P(*out)


def param_shardings(params_shape_tree, mesh) -> Any:
    """NamedSharding tree for a parameter (shape-)tree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, sanitize_spec(spec_for_path(path, leaf.shape), leaf.shape, mesh)
        ),
        params_shape_tree,
    )


def zero1_spec(spec: P, shape, mesh, dp_axes: tuple[str, ...]) -> P:
    """ZeRO-1: additionally shard one unsharded dim over the *free* DP axes."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    used = set()
    for e in entries:
        for a in (e if isinstance(e, tuple) else (e,)):
            if a is not None:
                used.add(a)
    free = tuple(a for a in dp_axes if a not in used)
    if not free:
        return spec
    dp = math.prod(mesh.shape[a] for a in free)
    for i, (e, d) in enumerate(zip(entries, shape)):
        if e is None and d % dp == 0 and d >= dp:
            entries[i] = free
            return P(*entries)
    return spec  # nothing divisible: keep replicated over DP


def opt_state_shardings(params_shape_tree, mesh, dp_axes: tuple[str, ...]) -> Any:
    def one(path, leaf):
        spec = sanitize_spec(spec_for_path(path, leaf.shape), leaf.shape, mesh)
        return NamedSharding(mesh, zero1_spec(spec, leaf.shape, mesh, dp_axes))

    return jax.tree_util.tree_map_with_path(one, params_shape_tree)


# ---------------------------------------------------------------------------
# Batch / cache specs
# ---------------------------------------------------------------------------


def batch_specs(cfg, kind: str, batch: int, mesh, dp_axes, seq_axis=None):
    """PartitionSpecs for a train/prefill batch dict."""
    dp = math.prod(mesh.shape[a] for a in dp_axes) if dp_axes else 1
    bspec = tuple(dp_axes) if (dp_axes and batch % dp == 0) else None
    if cfg.input_mode == "embeddings":
        inputs = P(bspec, seq_axis, None)
    else:
        inputs = P(bspec, seq_axis) if cfg.n_codebooks == 1 else P(bspec, seq_axis, None)
    labels = P(bspec, seq_axis) if cfg.n_codebooks == 1 else P(bspec, seq_axis, None)
    return {"inputs": inputs, "labels": labels}


def _div(size: int, mesh, axis) -> bool:
    n = mesh.shape[axis] if isinstance(axis, str) else math.prod(mesh.shape[a] for a in axis)
    return size % n == 0 and size >= n


def cache_specs(cache_shape_tree, mesh, dp_axes, batch: int):
    """Decode-cache NamedShardings: batch over DP, then TP placement per leaf.

    Cache layouts (with optional unit-stacking dim U in front):
      attn k/v:  (U, B, Hkv, L, Dh) -> heads on TP when Hkv % tp == 0, else the
                 cache *sequence* on TP (flash-decoding; see
                 models/attention.py::_dense_decode_attention), else replicate.
      rec h:     (U, B, Dr)           -> width on TP
      rec conv:  (U, B, W, Dr)        -> width on TP
      ssm conv:  (U, B, W, conv_dim)  -> replicated (tiny, mixed-part concat)
      ssm state: (U, B, H, P, N)      -> heads on TP

    Every TP placement falls back to replication when not divisible — explicit
    pjit in_shardings require exact divisibility.
    """
    dp = math.prod(mesh.shape[a] for a in dp_axes) if dp_axes else 1
    bspec = tuple(dp_axes) if (dp_axes and batch % dp == 0) else None

    def one(path, leaf):
        names = _path_names(path)
        leaf_name = names[-1]
        rank = len(leaf.shape)
        shape = leaf.shape

        def tp_if(dim_idx):
            return TP if _div(shape[dim_idx], mesh, TP) else None

        if leaf_name in ("k", "v"):
            # (..., B, Hkv, L, Dh)
            h_tp = tp_if(rank - 3)
            l_tp = tp_if(rank - 2) if h_tp is None else None
            base = (bspec, h_tp, l_tp, None)
        elif leaf_name == "h":
            base = (bspec, tp_if(rank - 1))
        elif leaf_name == "conv":
            base = (bspec, None, tp_if(rank - 1))  # rec/ssm conv window: width on TP
        elif leaf_name == "ssm":
            base = (bspec, tp_if(rank - 3), None, None)
        else:
            base = (None,) * rank
        pad = rank - len(base)
        spec = P(*(((None,) * pad) + tuple(base)))
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, cache_shape_tree)

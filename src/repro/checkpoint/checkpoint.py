"""Sharded, atomic, elastic checkpoints.

Layout:  <dir>/step_<N>/
             manifest.json       tree structure + shapes/dtypes + extras
             leaf_<i>.npy        one file per tree leaf

Guarantees required at 1000-node scale:
  * **atomicity** — written to ``.tmp-step_<N>`` and renamed only when every
    leaf + manifest is on disk, so a killed writer never leaves a torn
    checkpoint; restore always picks the newest *complete* step.
  * **async** — ``save_async`` snapshots to host memory synchronously (cheap)
    and writes in a background thread, so the train loop is blocked only by
    the device->host copy, not the filesystem.
  * **elastic restore** — leaves are stored as full (unsharded) arrays and
    re-placed with whatever shardings the *restoring* mesh provides, so a job
    can come back on a different device count (runtime/supervisor.py).
  * retention of the last ``keep`` checkpoints.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
from pathlib import Path

import jax
import ml_dtypes
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)$")


def _decode_dtype(arr: np.ndarray, dtype_str: str) -> np.ndarray:
    """np.load returns void dtypes for ml_dtypes (bf16 etc.); view them back."""
    if arr.dtype.kind == "V":
        return arr.view(np.dtype(getattr(ml_dtypes, dtype_str)))
    return arr


def _flatten_with_paths(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(ckpt_dir, step: int, state, extras: dict | None = None, keep: int = 3):
    """Synchronous atomic save of a pytree ``state``."""
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    tmp = ckpt_dir / f".tmp-step_{step:08d}"
    final = ckpt_dir / f"step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()

    leaves, treedef = _flatten_with_paths(state)
    host_leaves = [np.asarray(jax.device_get(leaf)) for leaf in leaves]
    for i, leaf in enumerate(host_leaves):
        np.save(tmp / f"leaf_{i}.npy", leaf)
    manifest = {
        "step": int(step),
        "treedef": str(treedef),
        "n_leaves": len(host_leaves),
        "shapes": [list(l.shape) for l in host_leaves],
        "dtypes": [str(l.dtype) for l in host_leaves],
        "extras": extras or {},
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    os.replace(tmp, final)  # atomic publish
    _retain(ckpt_dir, keep)
    return final


class AsyncCheckpointer:
    """Snapshot synchronously, write in the background; at most one in flight."""

    def __init__(self, ckpt_dir, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: threading.Thread | None = None

    def save(self, step: int, state, extras: dict | None = None):
        self.wait()
        # Device->host snapshot happens here (synchronously, consistent view).
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
        self._thread = threading.Thread(
            target=save, args=(self.ckpt_dir, step, host_state, extras, self.keep), daemon=True
        )
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(ckpt_dir) -> int | None:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = [
        int(m.group(1))
        for p in ckpt_dir.iterdir()
        if (m := _STEP_RE.match(p.name)) and (p / "manifest.json").exists()
    ]
    return max(steps) if steps else None


def restore(ckpt_dir, template, step: int | None = None, shardings=None):
    """Restore into the structure of ``template``; optionally re-shard.

    ``shardings``: optional tree (matching template) of NamedShardings — the
    elastic-restore path: the restoring mesh may differ from the saving mesh.
    Returns (state, extras).
    """
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = ckpt_dir / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    leaves, treedef = jax.tree_util.tree_flatten(template)
    assert manifest["n_leaves"] == len(leaves), (
        f"checkpoint has {manifest['n_leaves']} leaves, template {len(leaves)}"
    )
    loaded = [
        _decode_dtype(np.load(d / f"leaf_{i}.npy"), manifest["dtypes"][i])
        for i in range(len(leaves))
    ]
    for got, want in zip(loaded, leaves):
        assert tuple(got.shape) == tuple(want.shape), (got.shape, want.shape)
    if shardings is not None:
        sh_leaves = jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding)
        )
        arrs = [
            jax.device_put(l.astype(w.dtype), s)
            for l, w, s in zip(loaded, leaves, sh_leaves)
        ]
    else:
        arrs = [jax.numpy.asarray(l.astype(w.dtype)) for l, w in zip(loaded, leaves)]
    return jax.tree_util.tree_unflatten(treedef, arrs), manifest["extras"]


def _retain(ckpt_dir: Path, keep: int):
    steps = sorted(
        int(m.group(1))
        for p in ckpt_dir.iterdir()
        if (m := _STEP_RE.match(p.name))
    )
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(ckpt_dir / f"step_{s:08d}", ignore_errors=True)

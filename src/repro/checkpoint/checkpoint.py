"""Sharded, atomic, elastic checkpoints.

Layout:  <dir>/step_<N>/
             manifest.json       tree structure + shapes/dtypes + extras
             leaf_<i>.npy        one file per tree leaf

Guarantees required at 1000-node scale:
  * **atomicity** — written to ``.tmp-step_<N>`` and renamed only when every
    leaf + manifest is on disk (manifest last, fsynced, directory entry
    fsynced after the publish rename), so a killed writer never leaves a
    torn checkpoint that ``restore``/``latest_step`` will pick up.
  * **validation on read** — a ``step_<N>`` directory only counts as a
    checkpoint when its manifest parses and every leaf file it names is
    present with a real ``.npy`` header; anything else (a crash that raced
    the rename, a truncated disk, manual vandalism) is skipped with a
    warning and recovery falls back to the next-newest complete step
    instead of raising mid-recovery.
  * **async** — ``AsyncCheckpointer`` snapshots to host memory synchronously
    (cheap) and writes in a background thread; a background failure is
    re-raised as :class:`CheckpointError` on the next ``save()``/``wait()``
    (never swallowed), and ``wait(timeout=...)`` bounds shutdown so a hung
    filesystem cannot deadlock the supervisor.
  * **elastic restore** — leaves are stored as full (unsharded) arrays and
    re-placed with whatever shardings the *restoring* mesh provides, so a job
    can come back on a different device count (runtime/supervisor.py).
  * retention of the last ``keep`` checkpoints.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
import warnings
from pathlib import Path

import jax
import ml_dtypes
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)$")
_NPY_MAGIC = b"\x93NUMPY"


class CheckpointError(RuntimeError):
    """A checkpoint could not be written or read back."""


def _decode_dtype(arr: np.ndarray, dtype_str: str) -> np.ndarray:
    """np.load returns void dtypes for ml_dtypes (bf16 etc.); view them back."""
    if arr.dtype.kind == "V":
        return arr.view(np.dtype(getattr(ml_dtypes, dtype_str)))
    return arr


def _flatten_with_paths(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _fsync(path: Path) -> None:
    """Flush one file (or directory entry) to stable storage; best-effort."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def save(ckpt_dir, step: int, state, extras: dict | None = None, keep: int = 3):
    """Synchronous crash-atomic save of a pytree ``state``.

    Everything lands in ``.tmp-step_<N>`` first — leaves, then the manifest
    (written last and fsynced, so a manifest's presence implies every leaf
    preceded it) — and one ``os.replace`` publishes the directory. A kill at
    any instant leaves either the previous checkpoint set untouched plus an
    ignorable ``.tmp-*`` orphan, or the complete new step; never a torn
    ``step_<N>`` that :func:`latest_step`/:func:`restore` would pick up.
    """
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    tmp = ckpt_dir / f".tmp-step_{step:08d}"
    final = ckpt_dir / f"step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()

    leaves, treedef = _flatten_with_paths(state)
    host_leaves = [np.asarray(jax.device_get(leaf)) for leaf in leaves]
    for i, leaf in enumerate(host_leaves):
        np.save(tmp / f"leaf_{i}.npy", leaf)
    manifest = {
        "step": int(step),
        "treedef": str(treedef),
        "n_leaves": len(host_leaves),
        "shapes": [list(l.shape) for l in host_leaves],
        "dtypes": [str(l.dtype) for l in host_leaves],
        "extras": extras or {},
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    _fsync(tmp / "manifest.json")
    if final.exists():  # re-saving a step: replace the whole directory
        shutil.rmtree(final)
    os.replace(tmp, final)  # atomic publish
    _fsync(ckpt_dir)  # the rename itself reaches stable storage
    _retain(ckpt_dir, keep)
    return final


def validate_step_dir(d: Path) -> str | None:
    """Why ``d`` is NOT a complete checkpoint, or None when it is.

    Checks the manifest parses with the expected keys and that every leaf
    file it names exists with a genuine ``.npy`` header — cheap (no array
    data is read), so recovery can scan a whole checkpoint directory.
    """
    mf = Path(d) / "manifest.json"
    if not mf.exists():
        return "missing manifest.json"
    try:
        manifest = json.loads(mf.read_text())
    except (OSError, json.JSONDecodeError) as e:
        return f"unreadable manifest.json ({e})"
    for key in ("step", "n_leaves", "shapes", "dtypes"):
        if key not in manifest:
            return f"manifest missing {key!r}"
    try:
        n = int(manifest["n_leaves"])
    except (TypeError, ValueError):
        return "manifest n_leaves is not an integer"
    for i in range(n):
        leaf = Path(d) / f"leaf_{i}.npy"
        try:
            with open(leaf, "rb") as f:
                if f.read(len(_NPY_MAGIC)) != _NPY_MAGIC:
                    return f"leaf_{i}.npy is not a numpy file"
        except OSError:
            return f"missing leaf_{i}.npy"
    return None


def _step_dirs(ckpt_dir: Path) -> list[tuple[int, Path]]:
    return sorted(
        (int(m.group(1)), p)
        for p in ckpt_dir.iterdir()
        if (m := _STEP_RE.match(p.name))
    )


def complete_steps(ckpt_dir) -> list[int]:
    """Validated checkpoint steps, ascending; warns on torn directories."""
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return []
    out = []
    for step, p in _step_dirs(ckpt_dir):
        defect = validate_step_dir(p)
        if defect is None:
            out.append(step)
        else:
            warnings.warn(
                f"skipping torn checkpoint {p}: {defect}", stacklevel=2
            )
    return out


def latest_step(ckpt_dir) -> int | None:
    steps = complete_steps(ckpt_dir)
    return steps[-1] if steps else None


def _load_step(d: Path, template, shardings):
    manifest = json.loads((d / "manifest.json").read_text())
    leaves, treedef = jax.tree_util.tree_flatten(template)
    assert manifest["n_leaves"] == len(leaves), (
        f"checkpoint has {manifest['n_leaves']} leaves, template {len(leaves)}"
    )
    loaded = [
        _decode_dtype(np.load(d / f"leaf_{i}.npy"), manifest["dtypes"][i])
        for i in range(len(leaves))
    ]
    for got, want in zip(loaded, leaves):
        assert tuple(got.shape) == tuple(want.shape), (got.shape, want.shape)
    if shardings is not None:
        sh_leaves = jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding)
        )
        arrs = [
            jax.device_put(l.astype(w.dtype), s)
            for l, w, s in zip(loaded, leaves, sh_leaves)
        ]
    else:
        arrs = [jax.numpy.asarray(l.astype(w.dtype)) for l, w in zip(loaded, leaves)]
    return jax.tree_util.tree_unflatten(treedef, arrs), manifest["extras"]


def restore(ckpt_dir, template, step: int | None = None, shardings=None):
    """Restore into the structure of ``template``; optionally re-shard.

    ``shardings``: optional tree (matching template) of NamedShardings — the
    elastic-restore path: the restoring mesh may differ from the saving mesh.
    With ``step=None`` the newest *complete* checkpoint wins; steps whose
    manifest fails validation — or whose leaves fail to load — are skipped
    with a warning and recovery falls back to the next-newest, so one torn
    directory never aborts a restart. An explicit ``step`` that is torn
    raises :class:`CheckpointError`. Returns (state, extras).
    """
    ckpt_dir = Path(ckpt_dir)
    if step is not None:
        d = ckpt_dir / f"step_{step:08d}"
        defect = validate_step_dir(d)
        if defect is not None:
            raise CheckpointError(f"checkpoint {d} is torn: {defect}")
        return _load_step(d, template, shardings)
    for s in reversed(complete_steps(ckpt_dir)):
        d = ckpt_dir / f"step_{s:08d}"
        try:
            return _load_step(d, template, shardings)
        # Template mismatches (AssertionError) are caller bugs and propagate;
        # only data-level corruption past the header check falls back.
        except (OSError, ValueError, KeyError, json.JSONDecodeError) as e:
            warnings.warn(
                f"checkpoint {d} failed to load ({e!r}); "
                "falling back to the previous step", stacklevel=2,
            )
    raise FileNotFoundError(f"no complete checkpoint in {ckpt_dir}")


class AsyncCheckpointer:
    """Snapshot synchronously, write in the background; at most one in flight.

    A failed background save is never swallowed: the exception is captured
    and re-raised (wrapped in :class:`CheckpointError`) from the NEXT
    ``save()`` or ``wait()`` call, so the train loop learns its checkpoint
    cadence is broken instead of crashing later with only stale steps on
    disk. ``wait(timeout=...)`` returns False if the writer is still running
    when the timeout expires — supervisor shutdown stays bounded even when
    the filesystem hangs.
    """

    def __init__(self, ckpt_dir, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._exc: BaseException | None = None

    def _write(self, step, state, extras):
        try:
            save(self.ckpt_dir, step, state, extras, self.keep)
        except BaseException as e:  # noqa: BLE001 - must cross the thread
            self._exc = e

    def save(self, step: int, state, extras: dict | None = None):
        self.wait()
        # Device->host snapshot happens here (synchronously, consistent view).
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
        self._thread = threading.Thread(
            target=self._write, args=(step, host_state, extras), daemon=True
        )
        self._thread.start()

    def wait(self, timeout: float | None = None) -> bool:
        """Join the in-flight save; re-raise its failure if it had one.

        Returns True when no save is left in flight; False when ``timeout``
        expired with the writer still running (the thread is left alone — a
        later ``wait()`` can still collect it).
        """
        if self._thread is not None:
            self._thread.join(timeout)
            if self._thread.is_alive():
                return False
            self._thread = None
        if self._exc is not None:
            exc, self._exc = self._exc, None
            raise CheckpointError(
                f"background checkpoint save failed: {exc!r}"
            ) from exc
        return True


def _retain(ckpt_dir: Path, keep: int):
    steps = sorted(
        int(m.group(1))
        for p in ckpt_dir.iterdir()
        if (m := _STEP_RE.match(p.name))
    )
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(ckpt_dir / f"step_{s:08d}", ignore_errors=True)

"""Hierarchical performance counters for the NTX stack.

A :class:`CounterRegistry` is a flat dict of ``scope/leaf -> number`` with a
stack of scope prefixes, so recording under ``with reg.scope("step0", "c1",
"fwd")`` lands on ``step0/c1/fwd/offloads``. The scheme deliberately mirrors
the lowering tags (``{node}:{pass}:{inner}``): :func:`record_program` walks a
program's blocks once and books each block's *closed-form* counts — the same
``n_commands`` / ``busy_cycles`` / ``dma_bytes`` arithmetic
:class:`repro.lower.ir.NtxProgram` exposes — under the block's node/pass
scope. Registry totals therefore match the program's own properties exactly
(:func:`program_totals` is the cross-check; ``tests/test_obs.py`` asserts
equality).

Leaf names recorded by the stock instrumentation:

  ``offloads, staging_offloads, commands, busy_cycles, macs, dma_bytes,
  spill_bytes, fill_bytes`` (per program, via :func:`record_program`);
  ``timing/*_cycles`` (via :func:`record_schedule`); ``mesh/link_bytes,
  mesh/link_hops, mesh/link_transfers, mesh/link_congestion_s`` (via
  :func:`record_link_schedule`); ``plan_cache/hits|misses|retraces|calls``
  (the Pallas executor); ``supervisor/steps|restarts|stragglers`` (the
  training supervisor).

Zero overhead when disabled: instrument sites call :func:`get_active` (one
module-global read, returns ``None``) and skip everything else. Snapshots
are plain JSON dicts, so counters ride checkpoints and survive
crash/restore cycles together with the model state.
"""

from __future__ import annotations

from contextlib import contextmanager

_SEP = "/"

#: Process-wide active registry (None = instrumentation disabled).
_ACTIVE: "CounterRegistry | None" = None


def get_active() -> "CounterRegistry | None":
    """The currently installed registry, or None when telemetry is off."""
    return _ACTIVE


@contextmanager
def use_registry(reg: "CounterRegistry | None"):
    """Install ``reg`` as the process-wide active registry for the block."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = reg
    try:
        yield reg
    finally:
        _ACTIVE = prev


class CounterRegistry:
    """Hierarchical monotone counters with a pushdown scope prefix."""

    __slots__ = ("enabled", "_counters", "_prefix")

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._counters: dict[str, float] = {}
        self._prefix = ""

    # -- recording ----------------------------------------------------------

    @contextmanager
    def scope(self, *parts: str):
        """Push ``parts`` onto the scope prefix for the ``with`` block."""
        prev = self._prefix
        tail = _SEP.join(p for p in parts if p)
        self._prefix = f"{prev}{_SEP}{tail}" if prev and tail else (prev or tail)
        try:
            yield self
        finally:
            self._prefix = prev

    def inc(self, name: str, value: float = 1) -> None:
        if not self.enabled:
            return
        key = f"{self._prefix}{_SEP}{name}" if self._prefix else name
        self._counters[key] = self._counters.get(key, 0) + value

    # -- reading ------------------------------------------------------------

    def counters(self) -> dict[str, float]:
        """A copy of the flat ``scope/leaf -> value`` map."""
        return dict(self._counters)

    def get(self, key: str, default: float = 0) -> float:
        return self._counters.get(key, default)

    def total(self, leaf: str, prefix: str = "") -> float:
        """Sum of ``leaf`` across every scope under ``prefix``."""
        want = f"{_SEP}{leaf}"
        tot = 0
        for key, v in self._counters.items():
            if prefix and not key.startswith(prefix):
                continue
            if key == leaf or key.endswith(want):
                tot += v
        return tot

    def totals(self, prefix: str = "") -> dict[str, float]:
        """Aggregate every leaf name across scopes under ``prefix``."""
        out: dict[str, float] = {}
        for key, v in self._counters.items():
            if prefix and not key.startswith(prefix):
                continue
            leaf = key.rsplit(_SEP, 1)[-1]
            out[leaf] = out.get(leaf, 0) + v
        return out

    def tree(self) -> dict:
        """The counters as a nested dict (for pretty-printing)."""
        root: dict = {}
        for key, v in sorted(self._counters.items()):
            node = root
            parts = key.split(_SEP)
            for p in parts[:-1]:
                node = node.setdefault(p, {})
            node[parts[-1]] = v
        return root

    # -- lifecycle ----------------------------------------------------------

    def snapshot(self) -> dict[str, float]:
        """JSON-safe copy of the counters (checkpoint ``extras`` friendly)."""
        return {k: float(v) for k, v in self._counters.items()}

    def restore(self, snap: dict[str, float]) -> None:
        """Roll the counters back to a :meth:`snapshot` (crash recovery)."""
        self._counters = {k: float(v) for k, v in (snap or {}).items()}

    def merge(self, other: "CounterRegistry | dict") -> None:
        """Add another registry's (or snapshot's) counters into this one."""
        src = other._counters if isinstance(other, CounterRegistry) else other
        for k, v in src.items():
            self._counters[k] = self._counters.get(k, 0) + v

    def clear(self) -> None:
        self._counters.clear()

    def __len__(self) -> int:
        return len(self._counters)

    def __bool__(self) -> bool:
        # A fresh registry is empty but NOT falsy — ``if reg:`` guards at
        # instrument sites must mean "is telemetry on", not "has counted".
        return True

    def __repr__(self) -> str:
        return f"CounterRegistry({len(self._counters)} counters, enabled={self.enabled})"


# ---------------------------------------------------------------------------
# Scope derivation from lowering tags
# ---------------------------------------------------------------------------


def block_scope(tag: str) -> tuple[str, ...]:
    """Map a block tag to its counter scope.

    ``"c1:fwd:..."`` -> ``("c1", "fwd")`` (the graph compiler's
    ``{node}:{pass}`` step keys), ``"spill:act1"``/``"fill:act1"`` ->
    ``("tcdm", "spill"|"fill")``, ``"allreduce:update:fc:upd[0]"`` ->
    ``("mesh", "allreduce")``, ``"allgather:w_c1[1]"`` ->
    ``("mesh", "allgather")``. Anything else books under its first tag
    component (single-layer programs) or ``("untagged",)``.
    """
    if not tag:
        return ("untagged",)
    parts = tag.split(":")
    if parts[0] in ("spill", "fill"):
        return ("tcdm", parts[0])
    if parts[0] in ("allreduce", "allgather"):
        return ("mesh", parts[0])
    if len(parts) >= 2 and parts[1] in ("fwd", "dx", "dw", "upd"):
        return (parts[0], parts[1])
    return (parts[0],)


def _program_digest(program) -> dict[str, float]:
    """``scope/leaf -> value`` for one program, memoized on the program.

    A training loop records the SAME compiled program every step, so the
    per-block walk (properties, tag parsing) runs once; repeat recordings
    are a flat dict merge — that keeps the counters-on step wall within the
    instrumentation-overhead budget ``check_regression.py`` gates.
    """
    digest = getattr(program, "_obs_digest", None)
    if digest is not None:
        return digest
    digest = {}

    def add(scope: tuple[str, ...], leaf: str, v: float) -> None:
        key = _SEP.join((*scope, leaf))
        digest[key] = digest.get(key, 0) + v

    for b in program.blocks:
        n = b.n_commands
        cycles = b.busy_cycles
        dma = (b.dma_bytes_in + b.dma_bytes_out) * n
        scope = block_scope(b.tag)
        add(scope, "staging_offloads" if b.is_staging else "offloads", n)
        add(scope, "commands", n)
        add(scope, "busy_cycles", cycles)
        add(scope, "dma_bytes", dma)
        if b.template.opcode == "mac":
            add(scope, "macs", cycles)
        if b.tag.startswith("spill:"):
            add(scope, "spill_bytes", b.dma_bytes_out * n)
        elif b.tag.startswith("fill:"):
            add(scope, "fill_bytes", b.dma_bytes_in * n)
    try:
        object.__setattr__(program, "_obs_digest", digest)
    except (AttributeError, TypeError):
        pass  # slotted/uncachable program: recompute per call
    return digest


def record_program(reg: CounterRegistry, program) -> None:
    """Book ``program``'s closed-form per-block counts into ``reg``.

    O(blocks) once per program, O(tags) after (:func:`_program_digest`).
    Totals across scopes equal the program's own properties:
    ``offloads == program.n_offloads``, ``commands == program.n_commands``,
    ``busy_cycles == program.busy_cycles``, ``dma_bytes ==
    program.dma_bytes``. MACs count one multiply-accumulate per active
    datapath cycle of ``mac``-opcode blocks (the NTX FPU issues one FMA per
    cycle), spill/fill bytes are the DMA traffic of the liveness
    allocator's spill blocks.
    """
    if reg is None or not reg.enabled:
        return
    for key, v in _program_digest(program).items():
        reg.inc(key, v)


def program_totals(program) -> dict[str, float]:
    """The closed-form totals :func:`record_program` must reproduce."""
    return {
        "offloads": program.n_offloads,
        "staging_offloads": program.n_staging_offloads,
        "commands": program.n_commands,
        "busy_cycles": program.busy_cycles,
        "dma_bytes": program.dma_bytes,
    }


def record_schedule(reg: CounterRegistry, result) -> None:
    """Book a :class:`ScheduleResult`'s cycle accounting under ``timing/``."""
    if reg is None or not reg.enabled:
        return
    s = result.summary()
    with reg.scope("timing"):
        reg.inc("scheduled_programs", 1)
        reg.inc("total_cycles", s["total_cycles"])
        reg.inc("exec_cycles", result.exec_cycles)
        reg.inc("dma_stall_cycles", s["dma_stall_cycles"])
        reg.inc("queue_stall_cycles", s["queue_stall_cycles"])
        reg.inc("overhead_cycles", s["overhead_cycles"])


def record_link_schedule(reg: CounterRegistry, schedule) -> None:
    """Book a :class:`LinkSchedule`'s traffic under ``mesh/<pass>/``.

    One scheduled transfer = one hop on one directed link, so
    ``link_hops`` counts transfers and ``link_bytes`` sums their payloads;
    scoping by the transfer tag's head (``reduce_v``, ``bcast_h``,
    ``ring``, ...) makes per-pass link traffic rankable in the hotspot
    table while totals stay the whole schedule's.
    """
    if reg is None or not reg.enabled:
        return
    with reg.scope("mesh"):
        for st in schedule.transfers:
            head = (st.transfer.tag or "link").split(":")[0]
            with reg.scope(head):
                reg.inc("link_transfers", 1)
                reg.inc("link_hops", 1)
                reg.inc("link_bytes", st.transfer.num_bytes)
        reg.inc("link_congestion_s", schedule.congestion_time)

"""Metrics reporting: per-step JSONL, hotspot tables, BENCH json writer.

Three consumers share this module:

  * ``launch/train.py --metrics out.jsonl`` and the training
    :class:`~repro.runtime.supervisor.Supervisor` stream one JSON object
    per training step through :class:`MetricsWriter` — schema:
    ``{"schema_version": 1, "step": int, "wall_s": float, "loss": float?,
    "metrics": {...}?, "counters": {leaf: total}}`` where ``counters`` are
    the step's :class:`~repro.obs.counters.CounterRegistry` leaf totals
    (offloads, commands, dma_bytes, busy_cycles, macs, ...).
  * :func:`format_hotspots` renders the registry's top-k scopes by cycles,
    DMA bytes and link bytes — the CLI prints it after a run.
  * :func:`write_bench_json` is the ONE writer every ``BENCH_*.json``
    artifact goes through (``benchmarks/run.py``, ``offload_bench.py``,
    ``mesh_bench.py``, ``trainstep_bench.py``), stamping the shared
    ``schema_version`` that ``check_regression.py`` validates.
"""

from __future__ import annotations

import json
import os

#: Version stamp shared by every BENCH_*.json and metrics JSONL record.
SCHEMA_VERSION = 1


# ---------------------------------------------------------------------------
# Per-step JSONL metrics
# ---------------------------------------------------------------------------


def _jsonable(v):
    """Best-effort scalar coercion (jax/numpy arrays -> float)."""
    if isinstance(v, (int, float, str, bool)) or v is None:
        return v
    try:
        return float(v)
    except (TypeError, ValueError):
        return str(v)


class MetricsWriter:
    """Append-only JSONL emitter; one flushed line per record."""

    def __init__(self, path, append: bool = False):
        self.path = str(path)
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        self._f = open(self.path, "a" if append else "w")

    def write(self, record: dict) -> None:
        rec = {"schema_version": SCHEMA_VERSION}
        for k, v in record.items():
            if isinstance(v, dict):
                rec[k] = {kk: _jsonable(vv) for kk, vv in v.items()}
            else:
                rec[k] = _jsonable(v)
        self._f.write(json.dumps(rec) + "\n")
        self._f.flush()

    def close(self) -> None:
        self._f.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def read_jsonl(path) -> list[dict]:
    """Load a metrics JSONL back into a list of records."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


# ---------------------------------------------------------------------------
# Hotspot tables
# ---------------------------------------------------------------------------


def hotspots(reg, leaf: str, k: int = 5, prefix: str = "") -> list[tuple[str, float]]:
    """Top-``k`` (scope, value) pairs for one counter leaf, descending."""
    want = f"/{leaf}"
    rows = []
    for key, v in reg.counters().items():
        if prefix and not key.startswith(prefix):
            continue
        if key == leaf:
            rows.append(("<root>", v))
        elif key.endswith(want):
            rows.append((key[: -len(want)], v))
    rows.sort(key=lambda r: -r[1])
    return rows[:k]


def _fmt(v: float) -> str:
    for unit, div in (("G", 1e9), ("M", 1e6), ("K", 1e3)):
        if abs(v) >= div:
            return f"{v / div:.2f}{unit}"
    return f"{v:.0f}" if v == int(v) else f"{v:.3f}"


def format_hotspots(reg, k: int = 5) -> str:
    """Human-readable top-k table by cycles, DMA bytes and link bytes."""
    sections = (
        ("busy_cycles", "by cycles"),
        ("dma_bytes", "by DMA bytes"),
        ("link_bytes", "by link bytes"),
    )
    lines = [f"top-{k} hotspots"]
    for leaf, title in sections:
        rows = hotspots(reg, leaf, k)
        if not rows:
            continue
        lines.append(f"  {title}:")
        width = max(len(s) for s, _ in rows)
        for scope, v in rows:
            lines.append(f"    {scope:<{width}}  {_fmt(v):>10}")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# The one BENCH_*.json writer
# ---------------------------------------------------------------------------


def write_bench_json(payload: dict, path) -> str:
    """Write a BENCH artifact with the shared ``schema_version`` stamp.

    Every benchmark JSON goes through here so ``check_regression.py`` can
    rely on one envelope; ``payload`` is written as-is apart from the
    version field (an existing ``schema_version`` is overwritten).
    """
    doc = {"schema_version": SCHEMA_VERSION, **payload}
    doc["schema_version"] = SCHEMA_VERSION
    d = os.path.dirname(str(path))
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, default=_jsonable)
    return str(path)


def write_offload_bench(results: dict, path="artifacts/BENCH_offload.json") -> str:
    """The BENCH_offload envelope: benchmarks + the one wall-time summary.

    Both ``benchmarks/run.py`` and ``benchmarks/offload_bench.py`` route
    through this — ``total_wall_s`` is computed here, in exactly one place.
    """
    total = sum(r.get("wall_s", 0.0) for r in results.values())
    return write_bench_json(
        {"benchmarks": results, "total_wall_s": total}, path
    )

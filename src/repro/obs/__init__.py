"""Unified NTX telemetry: hierarchical counters, merged traces, reports.

Three small modules, one activation idiom:

  * :mod:`repro.obs.counters` — a hierarchical :class:`CounterRegistry`
    (scoped like ``step0/c1/fwd``) that the executors, the mesh timer, the
    plan cache and the supervisor all record into when one is active.
    Totals are cross-checked against the closed-form
    :class:`repro.lower.ir.NtxProgram` counts — the counters *are* the
    program's arithmetic, not a parallel estimate.
  * :mod:`repro.obs.trace` — merges cluster exec/DMA lanes, mesh-link
    occupancy lanes and host-side lowering/dispatch spans into one
    Perfetto-loadable chrome trace with flow events tying a command block's
    lowering to its shard execution and its link transfers.
  * :mod:`repro.obs.report` — per-step JSONL metrics emitter, top-k hotspot
    tables, and the one shared BENCH_*.json writer (``schema_version``).

Instrumentation is zero-overhead when disabled: every record site starts
with a module-global ``get_active()`` read that returns ``None`` unless a
registry/collector was installed via ``use_registry``/``use_collector``.
"""

from repro.obs.counters import (
    CounterRegistry,
    get_active,
    record_link_schedule,
    record_program,
    record_schedule,
    program_totals,
    use_registry,
)
from repro.obs.report import (
    SCHEMA_VERSION,
    MetricsWriter,
    format_hotspots,
    hotspots,
    read_jsonl,
    write_bench_json,
    write_offload_bench,
)
from repro.obs.trace import TraceCollector, get_active_trace, use_collector

__all__ = [
    "CounterRegistry",
    "get_active",
    "record_link_schedule",
    "record_program",
    "record_schedule",
    "program_totals",
    "use_registry",
    "SCHEMA_VERSION",
    "MetricsWriter",
    "format_hotspots",
    "hotspots",
    "read_jsonl",
    "write_bench_json",
    "write_offload_bench",
    "TraceCollector",
    "get_active_trace",
    "use_collector",
]

"""Merged Perfetto traces: cluster lanes + mesh links + host spans.

:class:`repro.runtime.scheduler.Timeline` already exports per-command
cluster lanes; this module widens the picture to the whole stack in ONE
chrome-trace JSON that Perfetto (https://ui.perfetto.dev) loads directly:

  * **cluster lanes** (``pid hmc0``) — per-cluster exec and DMA spans at
    *block* granularity, reconstructed from the timing engine's per-command
    records by replaying the scheduler's round-robin deal
    (:func:`block_spans`), so every span carries its lowering tag
    (``c1:fwd``, ``spill:act1``, ``allreduce:update:fc:upd[0]``, ...).
  * **mesh lanes** (``pid mesh``) — one track per directed link, spans from
    the :class:`repro.runtime.mesh.LinkSchedule` (the systolic update's
    reduce/broadcast passes, ring steps, ...).
  * **host lanes** (``pid host``) — wall-clock spans for graph lowering
    (``lower:{node}:{pass}``) and Pallas plan dispatch, recorded live via
    :meth:`TraceCollector.host_span`.
  * **flow events** (``ph s/t/f``) — arrows tying a command block's host
    lowering span to its shard execution span and on to the link transfer
    that carries its result across the mesh.

Simulated lanes are in microseconds of modeled time (cycles / f_ntx); host
lanes are microseconds of wall time rebased to zero. The groups share the
trace, not a clock — Perfetto renders them as separate process tracks.

Activation mirrors :mod:`repro.obs.counters`: instrument sites check
:func:`get_active_trace` (one global read) and do nothing when no collector
is installed.
"""

from __future__ import annotations

import json
import time
from contextlib import contextmanager

#: Process-wide active collector (None = trace capture off).
_ACTIVE: "TraceCollector | None" = None


def get_active_trace() -> "TraceCollector | None":
    """The currently installed collector, or None when capture is off."""
    return _ACTIVE


@contextmanager
def use_collector(col: "TraceCollector | None"):
    """Install ``col`` as the process-wide trace collector for the block."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = col
    try:
        yield col
    finally:
        _ACTIVE = prev


def block_spans(program, result, n_clusters: int):
    """Per-cluster block-granularity spans from a ScheduleResult's records.

    Replays the scheduler's round-robin deal — global command ``i`` lands on
    cluster ``i % n_clusters`` — which holds for both the event engine (flat
    deal in ``MultiClusterScheduler.schedule``) and the block engine
    (``program_segments`` reproduces the same shares, and
    ``simulate_offload_blocks`` materializes records in segment order). Each
    block's span on a cluster runs from its first record's issue to its last
    record's retire. Yields ``(cluster, tag, exec_t0, exec_t1, dma_t0,
    dma_t1, n_cmds)`` in cycles; blocks whose records were elided past the
    block engine's record cap are skipped (their cycles still count — only
    the per-span rendering is lost).
    """
    blocks = list(program.blocks)
    for c, trace in enumerate(result.cluster_traces):
        records = trace.records
        ri = 0
        g = 0
        for b in blocks:
            count = b.n_commands
            first = g + ((c - g) % n_clusters)
            share = (
                (g + count - 1 - first) // n_clusters + 1
                if first < g + count
                else 0
            )
            g += count
            if share == 0:
                continue
            take = records[ri : ri + share]
            ri += share
            if not take:
                continue  # elided tail
            exec_t0 = min(r.program_start for r in take)
            exec_t1 = max(r.retire_t for r in take)
            dma_t0 = min(r.dma_start for r in take)
            dma_t1 = max(r.dma_end for r in take)
            yield (c, b.tag, exec_t0, exec_t1, dma_t0, dma_t1, len(take))


class TraceCollector:
    """Accumulates chrome-trace events from every layer of the stack."""

    def __init__(self, f_ntx: float = 1.5e9):
        self.f_ntx = f_ntx
        self.events: list[dict] = []
        self._host_origin: float | None = None
        self._flow_id = 0

    # -- host (wall-clock) spans --------------------------------------------

    def _now_us(self) -> float:
        t = time.perf_counter()
        if self._host_origin is None:
            self._host_origin = t
        return (t - self._host_origin) * 1e6

    @contextmanager
    def host_span(self, name: str, *, tid: str = "dispatch",
                  cat: str = "host", args: dict | None = None):
        """Record a wall-clock span on the ``host`` process track."""
        t0 = self._now_us()
        try:
            yield
        finally:
            t1 = self._now_us()
            self.events.append({
                "name": name, "cat": cat, "ph": "X",
                "pid": "host", "tid": tid,
                "ts": t0, "dur": max(t1 - t0, 0.01),
                "args": dict(args or {}),
            })

    # -- simulated lanes ----------------------------------------------------

    def _cycles_us(self, cycles: float) -> float:
        return cycles / self.f_ntx * 1e6

    def add_cluster_lanes(self, program, result, n_clusters: int,
                          *, pid: str = "hmc0") -> list[dict]:
        """Block-granularity exec + DMA lanes for one timed program.

        Returns the exec events added (flow-linking anchors).
        """
        exec_events = []
        for c, tag, e0, e1, d0, d1, n in block_spans(program, result, n_clusters):
            name = tag or "untagged"
            ev = {
                "name": name, "cat": "exec", "ph": "X",
                "pid": pid, "tid": f"cluster{c}",
                "ts": self._cycles_us(e0),
                "dur": max(self._cycles_us(e1 - e0), 0.001),
                "args": {"tag": tag, "cycles": e1 - e0, "commands": n},
            }
            self.events.append(ev)
            exec_events.append(ev)
            if d1 > d0:
                self.events.append({
                    "name": name, "cat": "dma", "ph": "X",
                    "pid": pid, "tid": f"cluster{c}:dma",
                    "ts": self._cycles_us(d0),
                    "dur": max(self._cycles_us(d1 - d0), 0.001),
                    "args": {"tag": tag, "cycles": d1 - d0},
                })
        return exec_events

    def add_link_lanes(self, schedule, *, pid: str = "mesh") -> list[dict]:
        """One track per directed mesh link; spans from a LinkSchedule."""
        out = []
        for st in schedule.transfers:
            (a, b) = st.transfer.link
            ev = {
                "name": st.transfer.tag or "transfer", "cat": "link", "ph": "X",
                "pid": pid, "tid": f"{a}->{b}",
                "ts": st.t0 * 1e6,
                "dur": max((st.t1 - st.t0) * 1e6, 0.001),
                "args": {
                    "bytes": st.transfer.num_bytes,
                    "queued_us": st.queued * 1e6,
                },
            }
            self.events.append(ev)
            out.append(ev)
        return out

    # -- flow events --------------------------------------------------------

    def add_flow(self, chain: list[dict], *, name: str = "flow") -> None:
        """Tie already-added "X" events together with s/t/f flow arrows."""
        chain = [ev for ev in chain if ev is not None]
        if len(chain) < 2:
            return
        self._flow_id += 1
        for i, ev in enumerate(chain):
            ph = "s" if i == 0 else ("f" if i == len(chain) - 1 else "t")
            flow = {
                "name": name, "cat": "flow", "ph": ph, "id": self._flow_id,
                "pid": ev["pid"], "tid": ev["tid"],
                "ts": ev["ts"] + ev.get("dur", 0) / 2,
            }
            if ph == "f":
                flow["bp"] = "e"
            self.events.append(flow)

    def link_flows(self, exec_events: list[dict],
                   link_events: list[dict]) -> int:
        """Flow arrows: lowering span -> shard exec span -> link transfer.

        Host lowering spans are matched to compute blocks by their
        ``{node}:{pass}`` step key; allreduce/allgather epilogue blocks are
        matched on to the first link transfer of the systolic pass that
        carries them (reduce passes for gradient reduction, broadcast
        passes for the updated weights). Returns the number of flows added.
        """
        host_by_key = {}
        for ev in self.events:
            if ev.get("pid") == "host" and ev["name"].startswith("lower:"):
                host_by_key.setdefault(ev["name"][len("lower:"):], ev)
        first_link: dict[str, dict] = {}
        for ev in link_events:
            first_link.setdefault(ev["name"].split(":")[0], ev)

        def pass_link(*tags):
            for t in tags:
                if t in first_link:
                    return first_link[t]
            return next(iter(link_events), None) if link_events else None

        def step_key(inner: str) -> str:
            # "fc:dw:matmul[0]" -> the lowering span's "fc:dw" step key
            return ":".join(inner.split("[")[0].split(":")[:2])

        seen_keys: set[str] = set()
        n_flows = 0
        for ev in exec_events:
            tag = ev["args"].get("tag", "")
            if tag.startswith("allreduce:reduce:"):
                chain = [host_by_key.get(step_key(tag.split(":", 2)[2])), ev,
                         pass_link("reduce_v", "reduce_h")]
            elif tag.startswith("allreduce:update:"):
                chain = [host_by_key.get(step_key(tag.split(":", 2)[2])), ev,
                         pass_link("bcast_h", "bcast_v")]
            elif tag.startswith("allgather:"):
                chain = [ev, pass_link("bcast_v", "bcast_h")]
            else:
                key = ":".join(tag.split(":")[:2])
                if key in seen_keys or key not in host_by_key:
                    continue
                seen_keys.add(key)
                chain = [host_by_key[key], ev]
            before = self._flow_id
            self.add_flow(chain, name=tag.split("[")[0] or "flow")
            n_flows += self._flow_id - before
        return n_flows

    # -- one-call mesh-step merge -------------------------------------------

    def add_mesh_step(self, sharded, *, n_clusters: int = 16,
                      engine: str | None = None):
        """Time HMC 0's shard + the link exchange; add all lanes + flows.

        ``sharded`` is a :class:`repro.lower.mesh.ShardedTrainStep`. Uses
        the event engine when the shard fits under the block-engine
        threshold (complete per-command records -> complete block spans);
        above it the block engine's record cap trims the rendered tail.
        Returns ``(ScheduleResult, LinkSchedule)``.
        """
        from repro.runtime import scheduler as rt_sched
        from repro.runtime.mesh import LinkSchedule, MeshInterconnect

        lead = sharded.alive_hmcs[0]
        shard = sharded.shard_program(lead)
        if engine is None:
            engine = (
                "event"
                if shard.n_commands <= rt_sched.BLOCK_ENGINE_THRESHOLD
                else "block"
            )
        sched = rt_sched.MultiClusterScheduler(
            n_clusters=n_clusters, f_ntx=self.f_ntx
        )
        result = sched.schedule_program(shard, engine=engine)
        rows, cols = sharded.mesh_shape
        exec_events = self.add_cluster_lanes(
            shard, result, n_clusters, pid=f"hmc{lead}"
        )
        if sharded.n_alive > 1:
            # degraded meshes exchange over the hole-routing survivor ring
            net = MeshInterconnect(rows, cols, failed=sharded.failed_hmcs)
            upd = (net.ring_allreduce(sharded.allreduce_bytes)
                   if sharded.failed_hmcs
                   else net.systolic_update(sharded.allreduce_bytes))
        else:
            upd = LinkSchedule()
        link_events = self.add_link_lanes(upd)
        self.link_flows(exec_events, link_events)
        return result, upd

    def add_recovery(self, step, event, rec, degraded) -> None:
        """Detect -> restore -> replay spans for one survived fault.

        ``event`` is the :class:`repro.runtime.faults.FaultEvent`, ``rec``
        its :class:`~repro.runtime.faults.RecoveryTiming`, ``degraded`` the
        re-sharded step. Rendered on a dedicated ``recovery`` process so
        the cost sits next to the steady-state lanes in the same trace.
        """
        t0 = 0.0
        spans = (
            (f"detect:{event.describe()}", rec.t_detect),
            ("restore:params", rec.t_restore),
            (f"replay:step{step}", rec.t_replay),
        )
        for name, dt in spans:
            self.events.append({
                "name": name, "cat": "recovery", "ph": "X",
                "pid": "recovery", "tid": f"step{step}",
                "ts": t0 * 1e6, "dur": max(dt * 1e6, 0.001),
                "args": {
                    "alive": degraded.n_alive,
                    "failed": list(degraded.failed_hmcs),
                    "recovery_cycles": rec.cycles(self.f_ntx),
                },
            })
            t0 += dt

    # -- export -------------------------------------------------------------

    def to_chrome_trace(self) -> dict:
        return {"traceEvents": list(self.events), "displayTimeUnit": "ns"}

    def save(self, path) -> str:
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f)
        return str(path)

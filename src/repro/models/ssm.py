"""Mamba-2 (SSD) block — attention-free sequence mixing.

Follows the Mamba-2 architecture (arXiv:2405.21060): a fused input projection
producing (z, x, B, C, dt); a short depthwise causal conv over (x, B, C); the
SSD scan with scalar-per-head decay A; a D skip; gated RMSNorm; out projection.

The scan runs through :mod:`repro.kernels.ops.ssd` — the Pallas chunked kernel
on TPU, the portable chunked scan elsewhere; both were property-tested against
the sequential recurrence. Decode carries (conv_state, ssm_state) and costs
O(1) per token — this is why mamba2 runs the ``long_500k`` shape.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models.blocks import _dot, init_rmsnorm, rms_norm

_CONV_W = 4


def _dims(cfg):
    d_inner = cfg.ssm_headdim * cfg.n_heads  # == 2 * d_model for mamba2
    g, n = cfg.ssm_groups, cfg.ssm_state
    return d_inner, g, n


def init_ssm_block(rng, cfg, dtype=jnp.bfloat16):
    d = cfg.d_model
    d_inner, g, n = _dims(cfg)
    h = cfg.n_heads
    conv_dim = d_inner + 2 * g * n
    ks = jax.random.split(rng, 5)
    std = d**-0.5
    # dt bias: softplus^-1 of dt in [1e-3, 1e-1] (mamba init)
    dt = jnp.exp(
        jax.random.uniform(ks[3], (h,), jnp.float32)
        * (jnp.log(0.1) - jnp.log(1e-3))
        + jnp.log(1e-3)
    )
    kz, kx, kb, kc, kd = jax.random.split(ks[0], 5)
    # Input projections kept separate (not fused as in the reference CUDA impl)
    # so each is cleanly column-shardable under TP; see DESIGN.md §Hardware.
    return {
        "w_z": (jax.random.normal(kz, (d, d_inner)) * std).astype(dtype),
        "w_x": (jax.random.normal(kx, (d, d_inner)) * std).astype(dtype),
        "w_b": (jax.random.normal(kb, (d, g * n)) * std).astype(dtype),
        "w_c": (jax.random.normal(kc, (d, g * n)) * std).astype(dtype),
        "w_dt": (jax.random.normal(kd, (d, h)) * std).astype(dtype),
        # Separate depthwise convs per component keep the sharded x-part TP-local
        # while b/c stay replicated (they are tiny: g*n wide).
        "conv_wx": (jax.random.normal(ks[1], (_CONV_W, d_inner)) * 0.1).astype(dtype),
        "conv_bx": jnp.zeros((d_inner,), dtype),
        "conv_wb": (jax.random.normal(ks[4], (_CONV_W, g * n)) * 0.1).astype(dtype),
        "conv_bb": jnp.zeros((g * n,), dtype),
        "conv_wc": (jax.random.normal(ks[4], (_CONV_W, g * n)) * 0.1).astype(dtype),
        "conv_bc": jnp.zeros((g * n,), dtype),
        "a_log": jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32)),  # A = -exp(a_log)
        "dt_bias": dt + jnp.log(-jnp.expm1(-dt)),  # softplus^-1(dt)
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm": init_rmsnorm(d_inner),
        "w_out": (jax.random.normal(ks[2], (d_inner, d)) * d_inner**-0.5).astype(dtype),
    }


def _project(x, params):
    z = _dot(x, params["w_z"])
    xs = _dot(x, params["w_x"])
    b = _dot(x, params["w_b"])
    c = _dot(x, params["w_c"])
    dt = _dot(x, params["w_dt"])
    return z, xs, b, c, dt


def _causal_conv1d(x, w, b):
    out = jnp.zeros(x.shape, jnp.float32)
    for k in range(w.shape[0]):
        shifted = jnp.pad(x, ((0, 0), (k, 0), (0, 0)))[:, : x.shape[1]]
        out = out + shifted.astype(jnp.float32) * w[k].astype(jnp.float32)
    return jax.nn.silu(out + b.astype(jnp.float32)).astype(x.dtype)


def ssm_block(x: jnp.ndarray, params, cfg, *, backend: str = "auto", chunk: int = 128):
    """Full-sequence Mamba-2 block. x: (B,S,D) -> (B,S,D)."""
    bsz, s, _ = x.shape
    d_inner, g, n = _dims(cfg)
    h, p = cfg.n_heads, cfg.ssm_headdim

    z, xs, b, c, dt = _project(x, params)
    xs = _causal_conv1d(xs, params["conv_wx"], params["conv_bx"])
    b = _causal_conv1d(b, params["conv_wb"], params["conv_bb"])
    c = _causal_conv1d(c, params["conv_wc"], params["conv_bc"])

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,S,H)
    a = -jnp.exp(params["a_log"])  # (H,)
    la = (dt * a).transpose(0, 2, 1)  # (B,H,S) log-decay <= 0

    xh = xs.reshape(bsz, s, h, p).transpose(0, 2, 1, 3)  # (B,H,S,P)
    xh = xh * dt.transpose(0, 2, 1)[..., None].astype(xh.dtype)  # dt-scaled input
    bg = b.reshape(bsz, s, g, n).transpose(0, 2, 1, 3)  # (B,G,S,N)
    cg = c.reshape(bsz, s, g, n).transpose(0, 2, 1, 3)

    y = ops.ssd(xh, la, bg, cg, chunk=min(chunk, s), backend=backend)  # (B,H,S,P)
    y = y + params["d_skip"][None, :, None, None].astype(xh.dtype) * xh
    y = y.transpose(0, 2, 1, 3).reshape(bsz, s, d_inner)

    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)  # gated
    y = rms_norm(y, params["norm"], cfg.norm_eps)
    return _dot(y, params["w_out"])


def init_ssm_cache(cfg, batch: int, dtype=jnp.bfloat16):
    d_inner, g, n = _dims(cfg)
    conv_dim = d_inner + 2 * g * n
    return {
        "conv": jnp.zeros((batch, _CONV_W - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, cfg.n_heads, cfg.ssm_headdim, n), jnp.float32),
    }


def ssm_block_step(x1: jnp.ndarray, params, cfg, cache):
    """One decode step (O(1)). x1: (B,1,D). Returns (y (B,1,D), new cache)."""
    bsz = x1.shape[0]
    d_inner, g, n = _dims(cfg)
    h, p = cfg.n_heads, cfg.ssm_headdim

    z, xs, b, c, dt = _project(x1, params)
    xbc = jnp.concatenate([xs, b, c], axis=-1)  # (B,1,conv_dim)
    window = jnp.concatenate([cache["conv"], xbc], axis=1)  # (B,W,conv_dim)

    def _conv_step(win, w, bias):
        out = (win.astype(jnp.float32) * w[::-1].astype(jnp.float32)[None]).sum(1)
        return jax.nn.silu(out + bias.astype(jnp.float32)).astype(x1.dtype)

    wx, wb, wc = jnp.split(window, [d_inner, d_inner + g * n], axis=-1)
    xs = _conv_step(wx, params["conv_wx"], params["conv_bx"])
    b = _conv_step(wb, params["conv_wb"], params["conv_bb"])
    c = _conv_step(wc, params["conv_wc"], params["conv_bc"])

    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])  # (B,H)
    a = jnp.exp(dt * -jnp.exp(params["a_log"]))  # (B,H) decay
    xh = xs.reshape(bsz, h, p) * dt[..., None].astype(xs.dtype)  # (B,H,P)
    bg = b.reshape(bsz, g, n)
    cg = c.reshape(bsz, g, n)
    grp = h // g
    bh = jnp.repeat(bg, grp, axis=1)  # (B,H,N)
    ch = jnp.repeat(cg, grp, axis=1)

    state = cache["ssm"] * a[..., None, None] + (
        xh[..., :, None].astype(jnp.float32) * bh[..., None, :].astype(jnp.float32)
    )  # (B,H,P,N)
    y = jnp.einsum("bhpn,bhn->bhp", state, ch.astype(jnp.float32)).astype(x1.dtype)
    y = y + params["d_skip"][None, :, None].astype(x1.dtype) * xh
    y = y.reshape(bsz, 1, d_inner)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    y = rms_norm(y, params["norm"], cfg.norm_eps)
    return _dot(y, params["w_out"]), {"conv": window[:, 1:], "ssm": state}

"""Model and parallelism configuration dataclasses."""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any

import jax.numpy as jnp

# A layer is (mixer, ffn):
#   mixer: "attn" (full), "swa" (sliding window), "rec" (RG-LRU), "ssm" (Mamba-2)
#   ffn:   "mlp", "moe", or None (mamba2 blocks have no separate FFN)
LayerKind = tuple[str, Any]


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    pattern: tuple[LayerKind, ...] = (("attn", "mlp"),)
    # attention
    rope_theta: float = 1e4
    qkv_bias: bool = False
    qk_norm: bool = False
    window: int | None = None  # sliding-window size for "swa" mixers
    # moe
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    shared_expert_d_ff: int = 0
    capacity_factor: float = 1.25
    # ssm (mamba2)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_groups: int = 1
    # rg-lru
    lru_width: int = 0
    # frontend / io
    input_mode: str = "tokens"  # "tokens" | "embeddings" (vlm/audio stubs)
    n_codebooks: int = 1  # musicgen: parallel codebook heads
    tie_embeddings: bool = False
    embed_scale: bool = False  # gemma-style sqrt(d) embedding scale
    # misc
    mlp_act: str = "swiglu"  # swiglu | geglu | gelu
    norm_type: str = "rms"  # rms | layer
    norm_eps: float = 1e-6
    dtype: Any = jnp.bfloat16
    # which input shapes this arch supports (dry-run cells)
    shapes: tuple[str, ...] = ("train_4k", "prefill_32k", "decode_32k")

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch can run long_500k (no full-attention layer)."""
        return all(m != "attn" for m, _ in self.pattern)

    def with_(self, **kw) -> "ModelConfig":
        return replace(self, **kw)


@dataclass(frozen=True)
class ParallelCtx:
    """How a forward/backward pass is parallelized & executed.

    ``mesh=None`` means single-device (smoke tests / CPU examples); then all
    sharding constraints are no-ops and MoE uses the dense oracle path.
    """

    mesh: Any = None
    dp_axes: tuple[str, ...] = ()  # axes the batch dim is sharded over
    tp_axis: str | None = None  # "model" on the production mesh
    seq_axis: str | None = None  # sequence-parallel axis (long-context cells)
    moe_impl: str = "dense"  # dense | ep
    attn_backend: str = "auto"  # kernels.ops backend
    remat: str = "none"  # none | full
    block_kv: int = 512
    ssd_chunk: int = 128
    grad_sync: str = "auto"  # auto(pjit psum) | systolic | compressed
    # §Perf knobs (EXPERIMENTS.md):
    sp_model: bool = False  # H2: sequence-parallel residual stream over "model"
    collective_dtype: str = "f32"  # H1: "bf16" rounds partials pre-collective
    windowed_attn: bool = False  # H5: window-limited KV scan for swa prefill
    shard_heads: bool = False  # H3: pin q/k/v to head-sharding (GSPMD pads)
    shard_scan_params: bool = False  # H6: pin per-layer param slices in the scan

    def act_spec(self):
        """PartitionSpec for (B, S, D) activations."""
        from jax.sharding import PartitionSpec as P

        if self.mesh is None:
            return None
        seq = self.seq_axis
        if self.sp_model and seq is None:
            seq = self.tp_axis  # Megatron-SP: residuals sharded on S over TP
        return P(self.dp_axes if self.dp_axes else None, seq, None)


def constrain(x, ctx: ParallelCtx, spec=None):
    """with_sharding_constraint if a mesh is present, else identity."""
    if ctx.mesh is None:
        return x
    import jax
    from jax.sharding import NamedSharding

    spec = spec if spec is not None else ctx.act_spec()
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))

"""Language-model wrapper: embeddings, decoder stack, heads, losses, serving.

Inputs are either token ids (B, S) or — for the [vlm]/[audio] stub frontends —
precomputed embeddings (B, S, D) (`cfg.input_mode == "embeddings"`); musicgen
additionally predicts ``n_codebooks`` parallel vocabularies.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import transformer as tfm
from repro.models.blocks import init_norm, apply_norm
from repro.models.config import ModelConfig, ParallelCtx, constrain


def init_lm(rng, cfg: ModelConfig) -> dict:
    k_embed, k_dec, k_head = jax.random.split(rng, 3)
    p = {
        "embed": (jax.random.normal(k_embed, (cfg.vocab_size, cfg.d_model)) * 0.02).astype(
            cfg.dtype
        ),
        "decoder": tfm.init_decoder(k_dec, cfg),
        "final_norm": init_norm(cfg.d_model, cfg.norm_type),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = (
            jax.random.normal(k_head, (cfg.d_model, cfg.n_codebooks * cfg.vocab_size))
            * cfg.d_model**-0.5
        ).astype(cfg.dtype)
    return p


def embed_inputs(params, inputs, cfg: ModelConfig):
    """Token ids (B,S) or (B,S,n_codebooks) -> embeddings; passthrough for stubs."""
    if inputs.dtype in (jnp.int32, jnp.int64):
        if cfg.n_codebooks > 1 and inputs.ndim == 3:
            x = jnp.take(params["embed"], inputs, axis=0).sum(axis=2)  # codebook sum
        else:
            x = jnp.take(params["embed"], inputs, axis=0)
    else:
        x = inputs.astype(cfg.dtype)  # stub frontend: precomputed embeddings
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
    return x


def logits_from_hidden(params, x, cfg: ModelConfig):
    if cfg.tie_embeddings:
        w = params["embed"].T  # (D, V)
    else:
        w = params["lm_head"]  # (D, CB*V)
    logits = jnp.dot(x, w, preferred_element_type=jnp.float32)
    if cfg.n_codebooks > 1:
        logits = logits.reshape(x.shape[:-1] + (cfg.n_codebooks, cfg.vocab_size))
    return logits


def forward(params, inputs, cfg: ModelConfig, ctx: ParallelCtx):
    """-> (logits fp32, aux dict)."""
    from repro.models import blocks as _blocks

    _blocks.set_matmul_partial_dtype(ctx.collective_dtype)
    x = embed_inputs(params, inputs, cfg)
    x = constrain(x, ctx)
    x, aux = tfm.decoder(x, params["decoder"], cfg, ctx)
    x = apply_norm(x, params["final_norm"], cfg.norm_type, cfg.norm_eps)
    logits = logits_from_hidden(params, x, cfg)
    if ctx.mesh is not None:
        vspec = (
            P(ctx.dp_axes or None, ctx.seq_axis, ctx.tp_axis)
            if cfg.n_codebooks == 1
            else P(ctx.dp_axes or None, ctx.seq_axis, None, ctx.tp_axis)
        )
        logits = constrain(logits, ctx, vspec)
    return logits, aux


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray, z_loss: float = 0.0):
    """Mean CE over all positions (and codebooks when present), fp32.

    logits: (..., V) fp32; labels: (...) int32.
    """
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ce = jnp.mean(lse - ll)
    if z_loss:
        ce = ce + z_loss * jnp.mean(lse**2)
    return ce


def lm_loss(params, batch, cfg: ModelConfig, ctx: ParallelCtx, aux_weight: float = 0.01):
    """batch: {"inputs": ids/embeddings, "labels": ids}. Returns (loss, metrics)."""
    logits, aux = forward(params, batch["inputs"], cfg, ctx)
    ce = cross_entropy(logits, batch["labels"])
    loss = ce + aux_weight * aux["load_balance"] + 1e-3 * aux["router_z"]
    metrics = {"loss": loss, "ce": ce, **aux}
    return loss, metrics


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    return tfm.init_decoder_cache(cfg, batch, max_len, dtype)


def serve_step(params, cache, token, pos, cfg: ModelConfig, ctx: ParallelCtx):
    """One decode step: token (B,) int32 (or (B,D) stub embedding), pos scalar.

    Returns (logits (B, V) fp32 [or (B, CB, V)], new_cache).
    """
    if token.dtype in (jnp.int32, jnp.int64):
        inp = token[:, None] if cfg.n_codebooks == 1 else token[:, None, :]
    else:
        inp = token[:, None, :]
    x = embed_inputs(params, inp, cfg)
    x, cache = tfm.decoder_step(x, params["decoder"], cfg, cache, pos, ctx)
    x = apply_norm(x, params["final_norm"], cfg.norm_type, cfg.norm_eps)
    logits = logits_from_hidden(params, x, cfg)
    return logits[:, 0], cache


def prefill(params, inputs, cfg: ModelConfig, ctx: ParallelCtx):
    """Prefill forward (logits for all positions; cache fill is decode-side).

    The prefill benchmark cell lowers this function: it is the compute shape
    that matters (attention + MLP over the full prompt).
    """
    logits, _ = forward(params, inputs, cfg, ctx)
    return logits

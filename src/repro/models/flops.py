"""Analytic FLOP / byte / parameter counts per architecture and shape.

Used by (a) the roofline tables (MODEL_FLOPS = 6·N·D for training, 2·N·D for
inference, + attention terms) and (b) the paper's energy model in benchmarks/.
Counts follow the standard convention: a MAC = 2 flops; backward = 2x forward
matmul flops (dL/dx and dL/dw).
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.models.config import ModelConfig


@dataclass(frozen=True)
class Counts:
    params_total: int
    params_active: int  # per-token active (MoE: top_k experts only)
    flops_fwd_per_token: int  # matmul flops, excl. attention quadratic term
    attn_flops_fwd_per_token_per_ctx: float  # multiply by context length
    params_expert: int = 0  # routed-expert params (FSDP-sharded over DP)


def _layer_counts(cfg: ModelConfig, kind) -> tuple[int, int, float]:
    """(params, active_params, attn_per_ctx) for one layer of ``kind``."""
    mixer, ffn = kind
    d = cfg.d_model
    p_mix = 0
    attn_ctx = 0.0
    if mixer in ("attn", "swa"):
        qdim = cfg.n_heads * cfg.head_dim
        kvdim = cfg.n_kv_heads * cfg.head_dim
        p_mix = d * (qdim + 2 * kvdim) + qdim * d
        if cfg.qkv_bias:
            p_mix += qdim + 2 * kvdim
        # score+value flops per token per context position: 2*2*qdim
        attn_ctx = 4.0 * qdim
        if mixer == "swa" and cfg.window:
            attn_ctx = 0.0  # accounted as fixed window cost in flops_fwd
    elif mixer == "rec":
        dr = cfg.lru_width
        nb = 16
        p_mix = 2 * d * dr + dr * d + 4 * dr + 2 * nb * (dr // nb) ** 2 + dr
    elif mixer == "ssm":
        di = cfg.n_heads * cfg.ssm_headdim
        gn = cfg.ssm_groups * cfg.ssm_state
        p_mix = d * (2 * di + 2 * gn + cfg.n_heads) + di * d + 4 * (di + 2 * gn)
    p_ffn = a_ffn = 0
    if ffn == "mlp":
        mats = 3 if cfg.mlp_act in ("swiglu", "geglu") else 2
        p_ffn = a_ffn = mats * d * cfg.d_ff
    elif ffn == "moe":
        mats = 3 if cfg.mlp_act in ("swiglu", "geglu") else 2
        per_expert = mats * d * cfg.moe_d_ff
        p_ffn = cfg.n_experts * per_expert + d * cfg.n_experts
        a_ffn = cfg.top_k * per_expert + d * cfg.n_experts
        if cfg.shared_expert_d_ff:
            shared = mats * d * cfg.shared_expert_d_ff
            p_ffn += shared
            a_ffn += shared
    return p_mix + p_ffn, p_mix + a_ffn, attn_ctx


def fixed_mixer_flops_per_token(cfg: ModelConfig, kind) -> int:
    """Non-projection per-token flops (SWA window, SSM scan, RG-LRU scan)."""
    mixer, _ = kind
    if mixer == "swa" and cfg.window:
        return 4 * cfg.n_heads * cfg.head_dim * cfg.window
    if mixer == "ssm":
        # SSD: per token, per head: chunk-quadratic ~ 2*Q*(P+N) + state 4*P*N
        q = 128
        return cfg.n_heads * (2 * q * (cfg.ssm_headdim + cfg.ssm_state)
                              + 4 * cfg.ssm_headdim * cfg.ssm_state)
    if mixer == "rec":
        return 12 * cfg.lru_width
    return 0


def count(cfg: ModelConfig) -> Counts:
    plen = len(cfg.pattern)
    n_units, rem = divmod(cfg.n_layers, plen)
    layer_list = list(cfg.pattern) * n_units + list(cfg.pattern[:rem])

    p_total = p_active = p_expert = 0
    attn_ctx = 0.0
    fwd_fixed = 0
    mats = 3 if cfg.mlp_act in ("swiglu", "geglu") else 2
    for kind in layer_list:
        p, a, c = _layer_counts(cfg, kind)
        p_total += p
        p_active += a
        attn_ctx += c
        fwd_fixed += fixed_mixer_flops_per_token(cfg, kind)
        if kind[1] == "moe":
            p_expert += cfg.n_experts * mats * cfg.d_model * cfg.moe_d_ff

    embed = cfg.vocab_size * cfg.d_model
    head = 0 if cfg.tie_embeddings else cfg.d_model * cfg.n_codebooks * cfg.vocab_size
    p_total += embed + head
    p_active += embed + head

    # 2 flops per active param per token (embedding lookup ~free, head matmul
    # counted via its params).
    head_active = cfg.d_model * cfg.n_codebooks * cfg.vocab_size  # tied or not, the matmul runs
    fwd = 2 * (p_active - embed - head) + 2 * head_active + fwd_fixed
    return Counts(
        params_total=p_total,
        params_active=p_active,
        flops_fwd_per_token=fwd,
        attn_flops_fwd_per_token_per_ctx=attn_ctx,
        params_expert=p_expert,
    )


def train_step_flops(cfg: ModelConfig, seq: int, batch: int) -> float:
    """Total model flops for one training step (fwd + bwd = 3x fwd)."""
    c = count(cfg)
    tokens = seq * batch
    # mean attention context for causal = seq/2
    attn = c.attn_flops_fwd_per_token_per_ctx * (seq / 2.0)
    return 3.0 * tokens * (c.flops_fwd_per_token + attn)


def prefill_flops(cfg: ModelConfig, seq: int, batch: int) -> float:
    c = count(cfg)
    attn = c.attn_flops_fwd_per_token_per_ctx * (seq / 2.0)
    return float(seq * batch) * (c.flops_fwd_per_token + attn)


def decode_step_flops(cfg: ModelConfig, ctx_len: int, batch: int) -> float:
    """One token for every sequence in the batch, against a ctx_len cache."""
    c = count(cfg)
    attn = c.attn_flops_fwd_per_token_per_ctx * float(ctx_len)
    return float(batch) * (c.flops_fwd_per_token + attn)


def decode_hbm_bytes(cfg: ModelConfig, ctx_len: int, batch: int, dtype_bytes: int = 2) -> float:
    """Decode is memory-bound: params + KV/state reads dominate."""
    c = count(cfg)
    kv = 0.0
    plen = len(cfg.pattern)
    n_units, rem = divmod(cfg.n_layers, plen)
    layer_list = list(cfg.pattern) * n_units + list(cfg.pattern[:rem])
    for mixer, _ in layer_list:
        if mixer == "attn":
            kv += 2 * cfg.n_kv_heads * cfg.head_dim * ctx_len
        elif mixer == "swa":
            kv += 2 * cfg.n_kv_heads * cfg.head_dim * min(ctx_len, cfg.window or ctx_len)
        elif mixer == "ssm":
            kv += cfg.n_heads * cfg.ssm_headdim * cfg.ssm_state * 2  # fp32 state r/w
        elif mixer == "rec":
            kv += cfg.lru_width * 2
    return c.params_active * dtype_bytes + batch * kv * dtype_bytes


# ---------------------------------------------------------------------------
# Analytic HBM traffic (per chip) for the *kernelized TPU path*.
#
# The dry-run lowers portable XLA code whose CPU-compiled HLO grossly
# over-states HBM traffic (little fusion; blockwise attention materializes
# scores). On TPU the Pallas kernels keep score/state tiles in VMEM, so the
# roofline memory term uses this first-principles model instead (assumptions
# inline); the HLO bytes proxy is reported as a diagnostic upper bound.
# ---------------------------------------------------------------------------


def _attn_kv_traffic(cfg: ModelConfig, tokens_loc: float, seq: int,
                     block_q: int = 512, dtype_bytes: int = 2) -> float:
    """Flash-attention HBM traffic: K/V re-streamed once per q-block."""
    total = 0.0
    plen = len(cfg.pattern)
    n_units, rem = divmod(cfg.n_layers, plen)
    layer_list = list(cfg.pattern) * n_units + list(cfg.pattern[:rem])
    for mixer, _ in layer_list:
        if mixer not in ("attn", "swa"):
            continue
        ctx = seq if mixer == "attn" else min(seq, cfg.window or seq)
        kv_bytes = tokens_loc * (ctx / seq) * 2 * cfg.n_kv_heads * cfg.head_dim * dtype_bytes
        n_q_blocks = max(1, seq // block_q)
        # causal: on average half the KV range is visited per q block
        total += kv_bytes * n_q_blocks * (0.5 if mixer == "attn" else 1.0)
    return total


def _layer_act_traffic(cfg: ModelConfig, tokens_loc: float, tp: int,
                       dtype_bytes: int = 2) -> float:
    """Per-pass matmul-output writes within one decoder pass (all layers).

    ~6 tensor-sized intermediates hit HBM per layer on TPU after fusion
    (qkv out, attn out, 2 ffn hidden (sharded /tp), ffn out, residual).
    """
    d = cfg.d_model
    widest_ff = max(cfg.d_ff, cfg.moe_d_ff * cfg.top_k)
    per_layer = tokens_loc * dtype_bytes * (4 * d + 2 * widest_ff / tp)
    return cfg.n_layers * per_layer


def train_hbm_bytes_per_chip(
    cfg: ModelConfig, seq: int, batch: int, tp: int = 16, dp: int = 16,
    dtype_bytes: int = 2,
) -> float:
    """One train step, full remat, SGD-momentum (fp32 mu), bf16 params."""
    c = count(cfg)
    tokens_loc = seq * batch / dp
    p_loc = c.params_total / tp  # traffic view: each chip touches its TP shard
    # weights: fwd read + remat read + bwd read (bf16) ; grad write+read (fp32),
    # momentum read+write (fp32), param read+write (bf16)
    w = p_loc * (3 * dtype_bytes + 8 + 8 + 2 * dtype_bytes)
    # activation carries saved across the unit scan (write fwd, read bwd)
    acts = 2 * cfg.n_layers * tokens_loc * cfg.d_model * dtype_bytes
    # within-layer intermediates: fwd + remat-fwd + bwd ~ 3 passes
    inner = 3 * _layer_act_traffic(cfg, tokens_loc, tp, dtype_bytes)
    attn = 2 * _attn_kv_traffic(cfg, tokens_loc, seq, dtype_bytes=dtype_bytes)
    logits = 2 * tokens_loc * (cfg.n_codebooks * cfg.vocab_size / tp) * 4
    return w + acts + inner + attn + logits


def prefill_hbm_bytes_per_chip(
    cfg: ModelConfig, seq: int, batch: int, tp: int = 16, dp: int = 16,
    dtype_bytes: int = 2,
) -> float:
    c = count(cfg)
    tokens_loc = seq * batch / dp
    w = (c.params_total / tp) * dtype_bytes
    inner = _layer_act_traffic(cfg, tokens_loc, tp, dtype_bytes)
    attn = _attn_kv_traffic(cfg, tokens_loc, seq, dtype_bytes=dtype_bytes)
    logits = tokens_loc * (cfg.n_codebooks * cfg.vocab_size / tp) * 4
    return w + inner + attn + logits


def decode_hbm_bytes_per_chip(
    cfg: ModelConfig, ctx_len: int, batch: int, tp: int = 16, dp: int = 16,
    dtype_bytes: int = 2,
) -> float:
    """One decode step: TP-sharded weight read + this chip's KV/state slice.

    The cache is batch-sharded over DP (when batch divides) and head/width- or
    sequence-sharded over TP, so each chip reads cache_total/(dp_eff * tp).
    """
    total = decode_hbm_bytes(cfg, ctx_len, batch, dtype_bytes)
    params_part = count(cfg).params_active * dtype_bytes
    cache_part = total - params_part
    dp_eff = dp if batch % dp == 0 else 1
    return params_part / tp + cache_part / (dp_eff * tp)

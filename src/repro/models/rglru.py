"""RG-LRU recurrent block (RecurrentGemma / Griffin).

The RG-LRU recurrence is a *diagonal* linear RNN:

    r_t = sigmoid(x_t W_a)                       (recurrence gate)
    i_t = sigmoid(x_t W_x)                       (input gate)
    log a_t = -c * softplus(Lambda) * r_t        (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Being diagonal+associative it runs as ``lax.associative_scan`` (O(log S)
depth — TPU-friendly without a custom kernel; the NTX mapping is the L0
hardware loop with a carried accumulator). Decode is a single fused step on a
carried state. The full recurrent block is Griffin's: GeLU branch x (conv1d ->
RG-LRU) branch, merged multiplicatively.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.blocks import _dot

_C = 8.0
_CONV_W = 4  # temporal conv width


N_GATE_BLOCKS = 16  # block-diagonal gates (official impl); also TP-local


def init_rglru_block(rng, cfg, dtype=jnp.bfloat16):
    d, dr = cfg.d_model, cfg.lru_width
    nb = N_GATE_BLOCKS
    assert dr % nb == 0, (dr, nb)
    ks = jax.random.split(rng, 7)
    std = d**-0.5
    # Lambda init so a^c in (0.9, 0.999) (Griffin appendix).
    u = jax.random.uniform(ks[0], (dr,), jnp.float32, 0.9**2, 0.999**2)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / (2 * _C)))  # softplus^-1
    bstd = (dr // nb) ** -0.5
    return {
        "w_gelu": (jax.random.normal(ks[1], (d, dr)) * std).astype(dtype),
        "w_rnn": (jax.random.normal(ks[2], (d, dr)) * std).astype(dtype),
        "w_out": (jax.random.normal(ks[3], (dr, d)) * dr**-0.5).astype(dtype),
        "conv_w": (jax.random.normal(ks[4], (_CONV_W, dr)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((dr,), dtype),
        # Block-diagonal gate projections (Griffin's BlockDiagonalLinear):
        # TP-local when the rnn width is sharded, since each block stays whole.
        "w_a": (jax.random.normal(ks[5], (nb, dr // nb, dr // nb)) * bstd).astype(dtype),
        "w_x": (jax.random.normal(ks[6], (nb, dr // nb, dr // nb)) * bstd).astype(dtype),
        "lambda": lam,  # fp32
    }


def _block_diag_dot(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """x: (..., Dr), w: (nb, Dr/nb, Dr/nb) block-diagonal projection."""
    nb, blk, _ = w.shape
    xb = x.reshape(x.shape[:-1] + (nb, blk))
    y = jnp.einsum("...nb,nbc->...nc", xb, w, preferred_element_type=jnp.float32)
    return y.reshape(x.shape).astype(jnp.float32)


def _causal_conv1d(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv over the sequence dim. x: (B,S,C), w: (W,C)."""
    out = jnp.zeros_like(x, shape=x.shape).astype(jnp.float32)
    for k in range(w.shape[0]):
        shifted = jnp.pad(x, ((0, 0), (k, 0), (0, 0)))[:, : x.shape[1]]
        out = out + shifted.astype(jnp.float32) * w[k].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def _rglru_gates(x: jnp.ndarray, params):
    """Returns (log_a, beta*ix): the per-step decay and input of the recurrence."""
    r = jax.nn.sigmoid(_block_diag_dot(x, params["w_a"]))
    i = jax.nn.sigmoid(_block_diag_dot(x, params["w_x"]))
    log_a = -_C * jax.nn.softplus(params["lambda"]) * r  # (B,S,Dr) fp32, <= 0
    a2 = jnp.exp(2.0 * log_a)
    beta = jnp.sqrt(jnp.clip(1.0 - a2, 1e-12))
    return log_a, beta * i * x.astype(jnp.float32)


def rglru_scan(x: jnp.ndarray, params) -> jnp.ndarray:
    """Full-sequence RG-LRU via associative scan. x: (B,S,Dr)."""
    log_a, bx = _rglru_gates(x, params)

    def combine(e1, e2):  # e2 applied after e1
        a1, b1 = e1
        a2, b2 = e2
        return a1 + a2, jnp.exp(a2) * b1 + b2

    log_acum, h = jax.lax.associative_scan(combine, (log_a, bx), axis=1)
    del log_acum
    return h.astype(x.dtype)


def rglru_step(x1: jnp.ndarray, h: jnp.ndarray, params):
    """One decode step. x1: (B,1,Dr); h: (B,Dr) fp32. Returns (y, new_h)."""
    log_a, bx = _rglru_gates(x1, params)
    h = jnp.exp(log_a[:, 0]) * h + bx[:, 0]
    return h[:, None].astype(x1.dtype), h


def rglru_block(x: jnp.ndarray, params, cfg) -> jnp.ndarray:
    """Griffin recurrent block, full sequence. x: (B,S,D) -> (B,S,D)."""
    g = jax.nn.gelu(_dot(x, params["w_gelu"]).astype(jnp.float32)).astype(x.dtype)
    r = _dot(x, params["w_rnn"])
    r = _causal_conv1d(r, params["conv_w"], params["conv_b"])
    r = rglru_scan(r, params)
    return _dot(g * r, params["w_out"])


def init_rglru_cache(cfg, batch: int, dtype=jnp.bfloat16):
    dr = cfg.lru_width
    return {
        "h": jnp.zeros((batch, dr), jnp.float32),
        "conv": jnp.zeros((batch, _CONV_W - 1, dr), dtype),
    }


def rglru_block_step(x1: jnp.ndarray, params, cfg, cache):
    """One decode step of the full recurrent block. x1: (B,1,D)."""
    g = jax.nn.gelu(_dot(x1, params["w_gelu"]).astype(jnp.float32)).astype(x1.dtype)
    r = _dot(x1, params["w_rnn"])  # (B,1,Dr)
    # conv over [cache, r]
    window = jnp.concatenate([cache["conv"], r], axis=1)  # (B, W, Dr)
    w = params["conv_w"]
    rc = (window.astype(jnp.float32) * w[::-1].astype(jnp.float32)[None]).sum(1)
    rc = (rc + params["conv_b"].astype(jnp.float32)).astype(x1.dtype)[:, None]
    y, h = rglru_step(rc, cache["h"], params)
    out = _dot(g * y, params["w_out"])
    return out, {"h": h, "conv": window[:, 1:]}

"""GQA attention with full / sliding-window masking and a functional KV cache.

The score computation goes through :mod:`repro.kernels.ops.attention` (Pallas
flash kernel on TPU, blockwise-jnp elsewhere) so all archs share the NTX-style
fp32-accumulated datapath. GQA is native — KV is never repeated in memory.

TP sharding note: head dims carry the "heads"/"kv_heads" logical axes; the
sharding rules map both onto the mesh "model" axis (GSPMD pads when the head
count is not divisible — the per-arch padding overhead is reported in the
roofline tables).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models.blocks import _dot, apply_rope, init_rmsnorm, rms_norm


def init_attention(rng, cfg, dtype=jnp.bfloat16):
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(rng, 4)
    std = d**-0.5
    p = {
        "wq": (jax.random.normal(ks[0], (d, hq * dh)) * std).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d, hkv * dh)) * std).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d, hkv * dh)) * std).astype(dtype),
        "wo": (jax.random.normal(ks[3], (hq * dh, d)) * (hq * dh) ** -0.5).astype(dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * dh,), dtype)
        p["bk"] = jnp.zeros((hkv * dh,), dtype)
        p["bv"] = jnp.zeros((hkv * dh,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(dh)
        p["k_norm"] = init_rmsnorm(dh)
    return p


def _project_qkv(x, params, cfg, positions):
    b, s, _ = x.shape
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = _dot(x, params["wq"])
    k = _dot(x, params["wk"])
    v = _dot(x, params["wv"])
    if cfg.qkv_bias:
        q = q + params["bq"].astype(q.dtype)
        k = k + params["bk"].astype(k.dtype)
        v = v + params["bv"].astype(v.dtype)
    q = q.reshape(b, s, hq, dh).transpose(0, 2, 1, 3)  # (B, Hq, S, Dh)
    k = k.reshape(b, s, hkv, dh).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, hkv, dh).transpose(0, 2, 1, 3)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention_block(
    x: jnp.ndarray,  # (B, S, D)
    params,
    cfg,
    *,
    window: int | None = None,
    backend: str = "auto",
    block_kv: int = 512,
    windowed: bool = False,
    ctx=None,
) -> jnp.ndarray:
    """Training/prefill self-attention (causal)."""
    b, s, _ = x.shape
    positions = jnp.arange(s)
    q, k, v = _project_qkv(x, params, cfg, positions)
    if ctx is not None and ctx.shard_heads and ctx.mesh is not None:
        # H3 (§Perf): pin the (B, H, S, Dh) tensors to head-sharding so the
        # score einsums are head-local (GSPMD pads non-divisible head counts);
        # otherwise GSPMD may shard the contraction dim and partial-sum the
        # fp32 score tensors — the dominant collective in the baseline.
        from jax.sharding import NamedSharding, PartitionSpec as P

        hspec = NamedSharding(ctx.mesh, P(ctx.dp_axes or None, ctx.tp_axis, None, None))
        q = jax.lax.with_sharding_constraint(q, hspec)
        k = jax.lax.with_sharding_constraint(k, hspec)
        v = jax.lax.with_sharding_constraint(v, hspec)
    o = ops.attention(
        q, k, v, causal=True, window=window, backend=backend,
        block_kv=min(block_kv, s), windowed=windowed,
    )
    o = o.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * cfg.head_dim)
    return _dot(o, params["wo"])


def init_kv_cache(cfg, batch: int, max_len: int, window: int | None, dtype=jnp.bfloat16):
    """Cache for one attention layer. Sliding-window layers only keep the window."""
    length = min(max_len, window) if window is not None else max_len
    shape = (batch, cfg.n_kv_heads, length, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def decode_attention_block(
    x: jnp.ndarray,  # (B, 1, D)
    params,
    cfg,
    cache,
    pos: jnp.ndarray,  # scalar int32: index of the token being generated
    *,
    window: int | None = None,
    block_kv: int = 512,
):
    """One decode step: update the cache at ``pos`` and attend to the prefix.

    Sliding-window layers store the cache as a ring buffer of size ``window``
    (slot = pos % window) — the RG-LRU/local-attention memory model.
    Returns (out (B,1,D), new_cache).
    """
    b = x.shape[0]
    q, k, v = _project_qkv(x, params, cfg, positions=pos[None])
    cache_len = cache["k"].shape[2]
    slot = pos % cache_len if window is not None else pos
    new_k = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k.astype(cache["k"].dtype), slot, axis=2
    )
    new_v = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v.astype(cache["v"].dtype), slot, axis=2
    )

    if window is not None:
        # Ring buffer: positions of slot j = pos - ((pos - j) mod cache_len).
        slots = jnp.arange(cache_len)
        kv_pos = pos - ((pos - slots) % cache_len)  # (cache_len,) absolute positions
        valid = kv_pos >= jnp.maximum(0, pos - window + 1)
    else:
        valid = jnp.arange(cache_len) <= pos
    o = _dense_decode_attention(q, new_k, new_v, valid)
    o = o.transpose(0, 2, 1, 3).reshape(b, 1, cfg.n_heads * cfg.head_dim)
    return _dot(o, params["wo"]), {"k": new_k, "v": new_v}


def _dense_decode_attention(q, k, v, valid):
    """Single-token attention over the full cache, flash-decoding friendly.

    Written as dense einsums over the cache length so that when the cache is
    sharded on its sequence dim (kv_heads < TP degree), GSPMD partitions the
    score/value contractions S-parallel and inserts only tiny collectives
    (softmax max/sum and the (B,H,D) output psum) — the flash-decoding
    pattern, with no KV gather.
    """
    b, hq, _, dh = q.shape
    hkv = k.shape[1]
    grp = hq // hkv
    qf = q.astype(jnp.float32).reshape(b, hkv, grp, dh)
    s = jnp.einsum("bkgd,bkjd->bkgj", qf, k.astype(jnp.float32)) * (dh**-0.5)
    s = jnp.where(valid[None, None, None, :], s, -1e30)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = jnp.where(valid[None, None, None, :], p, 0.0)
    o = jnp.einsum("bkgj,bkjd->bkgd", p, v.astype(jnp.float32))
    o = o / jnp.sum(p, axis=-1, keepdims=True)
    return o.reshape(b, hq, 1, dh).astype(q.dtype)

"""The unified decoder: dense / MoE / hybrid (RG-LRU) / SSM block mixes.

Layers are grouped into *pattern units* (e.g. RecurrentGemma's
(rec, rec, swa)); parameters of equal-kind layers are stacked along a leading
unit axis and the forward pass is a ``lax.scan`` over units — keeping the HLO
size O(pattern) instead of O(n_layers), which matters both for multi-pod
compile times and for the NTX view of the world: one offloaded "command"
(scan body) sweeps all layers (C2).

Remat ("full") wraps the scan body, so the memory-vs-recompute trade is made
per unit — the activation-storage discipline the paper's Figure 1 discusses.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.blocks import apply_norm, init_mlp, init_norm, mlp
from repro.models.config import ModelConfig, ParallelCtx, constrain

AUX_KEYS = ("load_balance", "router_z")


def _zero_aux():
    return {k: jnp.float32(0.0) for k in AUX_KEYS}


# ---------------------------------------------------------------------------
# Single layer
# ---------------------------------------------------------------------------


def init_layer(rng, cfg: ModelConfig, kind) -> dict:
    mixer, ffn = kind
    k1, k2 = jax.random.split(rng)
    p: dict[str, Any] = {"norm1": init_norm(cfg.d_model, cfg.norm_type)}
    if mixer in ("attn", "swa"):
        p["attn"] = attn_mod.init_attention(k1, cfg, cfg.dtype)
    elif mixer == "rec":
        p["rec"] = rglru_mod.init_rglru_block(k1, cfg, cfg.dtype)
    elif mixer == "ssm":
        p["ssm"] = ssm_mod.init_ssm_block(k1, cfg, cfg.dtype)
    else:
        raise ValueError(f"unknown mixer {mixer!r}")
    if ffn is not None:
        p["norm2"] = init_norm(cfg.d_model, cfg.norm_type)
        if ffn == "mlp":
            p["mlp"] = init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.mlp_act, cfg.dtype)
        elif ffn == "moe":
            p["moe"] = moe_mod.init_moe(k2, cfg, cfg.dtype)
        else:
            raise ValueError(f"unknown ffn {ffn!r}")
    return p


def apply_layer(x, p, cfg: ModelConfig, kind, ctx: ParallelCtx):
    mixer, ffn = kind
    aux = _zero_aux()
    h = apply_norm(x, p["norm1"], cfg.norm_type, cfg.norm_eps)
    if mixer in ("attn", "swa"):
        window = cfg.window if mixer == "swa" else None
        h = attn_mod.attention_block(
            h, p["attn"], cfg, window=window, backend=ctx.attn_backend,
            block_kv=ctx.block_kv, windowed=ctx.windowed_attn, ctx=ctx,
        )
    elif mixer == "rec":
        h = rglru_mod.rglru_block(h, p["rec"], cfg)
    elif mixer == "ssm":
        h = ssm_mod.ssm_block(h, p["ssm"], cfg, backend=ctx.attn_backend, chunk=ctx.ssd_chunk)
    x = constrain(x + h, ctx)
    if ffn is not None:
        h = apply_norm(x, p["norm2"], cfg.norm_type, cfg.norm_eps)
        if ffn == "mlp":
            h = mlp(h, p["mlp"], cfg.mlp_act)
        else:
            if ctx.moe_impl == "ep" and ctx.mesh is not None:
                h, aux = moe_mod.moe_ep(h, p["moe"], cfg, ctx.mesh, dp_axes=ctx.dp_axes)
            else:
                h, aux = moe_mod.moe_dense(h, p["moe"], cfg)
        x = constrain(x + h, ctx)
    return x, aux


# ---------------------------------------------------------------------------
# Decode (single token) layer
# ---------------------------------------------------------------------------


def init_layer_cache(cfg: ModelConfig, kind, batch: int, max_len: int, dtype=None):
    mixer, _ = kind
    dtype = dtype or cfg.dtype
    if mixer in ("attn", "swa"):
        window = cfg.window if mixer == "swa" else None
        return attn_mod.init_kv_cache(cfg, batch, max_len, window, dtype)
    if mixer == "rec":
        return rglru_mod.init_rglru_cache(cfg, batch, dtype)
    if mixer == "ssm":
        return ssm_mod.init_ssm_cache(cfg, batch, dtype)
    raise ValueError(mixer)


def apply_layer_step(x, p, cfg, kind, cache, pos, ctx: ParallelCtx):
    mixer, ffn = kind
    h = apply_norm(x, p["norm1"], cfg.norm_type, cfg.norm_eps)
    if mixer in ("attn", "swa"):
        window = cfg.window if mixer == "swa" else None
        h, cache = attn_mod.decode_attention_block(
            h, p["attn"], cfg, cache, pos, window=window, block_kv=ctx.block_kv
        )
    elif mixer == "rec":
        h, cache = rglru_mod.rglru_block_step(h, p["rec"], cfg, cache)
    elif mixer == "ssm":
        h, cache = ssm_mod.ssm_block_step(h, p["ssm"], cfg, cache)
    x = x + h
    if ffn is not None:
        h = apply_norm(x, p["norm2"], cfg.norm_type, cfg.norm_eps)
        if ffn == "mlp":
            h = mlp(h, p["mlp"], cfg.mlp_act)
        elif ctx.moe_impl == "ep" and ctx.mesh is not None:
            h, _ = moe_mod.moe_ep(h, p["moe"], cfg, ctx.mesh, dp_axes=ctx.dp_axes)
        else:
            h, _ = moe_mod.moe_dense(h, p["moe"], cfg)
        x = x + h
    return x, cache


# ---------------------------------------------------------------------------
# Full decoder stack (scan over pattern units)
# ---------------------------------------------------------------------------


def _unit_counts(cfg: ModelConfig) -> tuple[int, int]:
    plen = len(cfg.pattern)
    return cfg.n_layers // plen, cfg.n_layers % plen


def init_decoder(rng, cfg: ModelConfig) -> dict:
    n_units, rem = _unit_counts(cfg)
    keys = jax.random.split(rng, n_units * len(cfg.pattern) + rem)

    units = []
    for pos, kind in enumerate(cfg.pattern):
        stacked = [
            init_layer(keys[u * len(cfg.pattern) + pos], cfg, kind) for u in range(n_units)
        ]
        units.append(jax.tree.map(lambda *xs: jnp.stack(xs), *stacked))
    rem_layers = [
        init_layer(keys[n_units * len(cfg.pattern) + i], cfg, cfg.pattern[i])
        for i in range(rem)
    ]
    return {"units": tuple(units), "rem": tuple(rem_layers)}


def decoder(x, params, cfg: ModelConfig, ctx: ParallelCtx):
    """x: (B, S, D) -> (B, S, D), plus accumulated aux losses."""
    n_units, rem = _unit_counts(cfg)

    def unit_body(carry, unit_params):
        x, aux = carry
        for pos, kind in enumerate(cfg.pattern):
            x, a = apply_layer(x, unit_params[pos], cfg, kind, ctx)
            aux = {k: aux[k] + a[k] for k in AUX_KEYS}
        return (x, aux), None

    body = unit_body
    if ctx.remat == "full":
        body = jax.checkpoint(unit_body, prevent_cse=False)

    carry = (x, _zero_aux())
    if n_units > 0:
        carry, _ = jax.lax.scan(body, carry, params["units"])
    x, aux = carry
    for i, p in enumerate(params["rem"]):
        x, a = apply_layer(x, p, cfg, cfg.pattern[i], ctx)
        aux = {k: aux[k] + a[k] for k in AUX_KEYS}
    return x, aux


def init_decoder_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    n_units, rem = _unit_counts(cfg)
    units = []
    for pos, kind in enumerate(cfg.pattern):
        one = init_layer_cache(cfg, kind, batch, max_len, dtype)
        units.append(jax.tree.map(lambda l: jnp.broadcast_to(l, (n_units,) + l.shape).copy(), one))
    rem_caches = tuple(
        init_layer_cache(cfg, cfg.pattern[i], batch, max_len, dtype) for i in range(rem)
    )
    return {"units": tuple(units), "rem": rem_caches}


def decoder_step(x, params, cfg: ModelConfig, cache, pos, ctx: ParallelCtx):
    """One decode step through the whole stack. x: (B,1,D)."""
    n_units, rem = _unit_counts(cfg)

    def unit_body(x, scanned):
        unit_params, unit_cache = scanned
        new_caches = []
        for p_idx, kind in enumerate(cfg.pattern):
            x, c = apply_layer_step(
                x, unit_params[p_idx], cfg, kind, unit_cache[p_idx], pos, ctx
            )
            new_caches.append(c)
        return x, tuple(new_caches)

    new_cache = {"units": cache["units"], "rem": cache["rem"]}
    if n_units > 0:
        x, new_units = jax.lax.scan(unit_body, x, (params["units"], cache["units"]))
        new_cache["units"] = new_units
    rem_caches = []
    for i, p in enumerate(params["rem"]):
        x, c = apply_layer_step(x, p, cfg, cfg.pattern[i], cache["rem"][i], pos, ctx)
        rem_caches.append(c)
    new_cache["rem"] = tuple(rem_caches)
    return x, new_cache

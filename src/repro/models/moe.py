"""Mixture-of-Experts FFN with expert parallelism (EP) on the "model" axis.

Two interchangeable implementations (property-tested against each other):

* ``moe_dense``  — exact: every expert computed for every token, combined with
  router weights. O(E) compute; used at smoke-test scale and as the oracle.
* ``moe_ep``     — production: experts sharded over the mesh "model" axis via a
  partial-manual ``shard_map``. Because activations are replicated across the
  TP axis between blocks (Megatron-style), each model-rank *already holds every
  token* — dispatch needs **zero communication**: a rank gathers the
  (token, k) pairs routed to its local experts into capacity-bounded buffers,
  runs its expert FFNs, scatters weighted outputs back, and a single
  ``psum`` over "model" combines ranks (the same collective a dense TP MLP
  needs). This is the NTX lesson (C3) applied to MoE: move compute to where
  the data already is instead of re-tiling/re-sharding it.

Capacity: each rank processes at most ``C = ceil(T*K/n_ranks * cap_factor)``
pairs, padded/dropped GShard-style; dropped tokens keep only their other-k
contributions. Router: softmax -> top-k, renormalized; load-balance and
router-z auxiliary losses are returned for the trainer.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.models.blocks import _dot


def init_moe(rng, cfg, dtype=jnp.bfloat16):
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(rng, 5)
    std = d**-0.5
    p = {
        "router": (jax.random.normal(ks[0], (d, e)) * std).astype(jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (e, d, f)) * std).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (e, d, f)) * std).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (e, f, d)) * f**-0.5).astype(dtype),
    }
    if cfg.shared_expert_d_ff:
        from repro.models.blocks import init_mlp

        p["shared"] = init_mlp(ks[4], d, cfg.shared_expert_d_ff, cfg.mlp_act, dtype)
    return p


def route(x2d: jnp.ndarray, router_w: jnp.ndarray, top_k: int):
    """Softmax-then-top-k routing. Returns (weights (T,K) fp32, ids (T,K), aux).

    Logits accumulate in fp32 but x2d is consumed in its own dtype — creating
    an fp32 copy of the activations here makes GSPMD gather fp32 activations
    for the EP body too (2x the wire bytes; §Perf B-H3).
    """
    logits = jnp.dot(
        x2d, router_w.astype(x2d.dtype), preferred_element_type=jnp.float32
    )  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    w, ids = jax.lax.top_k(probs, top_k)  # (T, K)
    w = w / jnp.clip(w.sum(-1, keepdims=True), 1e-9)
    # GShard load-balance loss + router z-loss.
    e = router_w.shape[1]
    me = probs.mean(0)  # (E,) mean prob
    one_hot = jax.nn.one_hot(ids[:, 0], e, dtype=jnp.float32)  # top-1 assignment share
    ce = one_hot.mean(0)
    aux = {
        "load_balance": e * jnp.sum(me * ce),
        "router_z": jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2),
    }
    return w, ids, aux


def _expert_ffn(xe: jnp.ndarray, wg, wu, wd, act: str) -> jnp.ndarray:
    """xe: (E_local, C, D); expert weights (E_local, D, F) / (E_local, F, D)."""
    h = jnp.einsum("ecd,edf->ecf", xe, wg, preferred_element_type=jnp.float32)
    if act in ("swiglu", "geglu"):
        gate = jax.nn.silu(h) if act == "swiglu" else jax.nn.gelu(h)
        up = jnp.einsum("ecd,edf->ecf", xe, wu, preferred_element_type=jnp.float32)
        h = gate * up
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("ecf,efd->ecd", h.astype(xe.dtype), wd, preferred_element_type=jnp.float32)


def moe_dense(x: jnp.ndarray, params, cfg):
    """Exact O(E) reference: all experts on all tokens (smoke scale / oracle)."""
    b, s, d = x.shape
    x2 = x.reshape(b * s, d)
    w, ids, aux = route(x2, params["router"], cfg.top_k)
    e = cfg.n_experts
    # combine(T, E) from top-k
    comb = jnp.zeros((b * s, e), jnp.float32)
    comb = jax.vmap(lambda c, i, v: c.at[i].add(v))(comb, ids, w)
    y_all = _expert_ffn(
        jnp.broadcast_to(x2, (e,) + x2.shape).astype(x.dtype),
        params["w_gate"],
        params["w_up"],
        params["w_down"],
        cfg.mlp_act,
    )  # (E, T, D)
    y = jnp.einsum("etd,te->td", y_all, comb).astype(x.dtype)
    if "shared" in params:
        from repro.models.blocks import mlp

        y = y + mlp(x2, params["shared"], cfg.mlp_act)
    return y.reshape(b, s, d), aux


def _moe_rank_body(x2, comb, wg, wu, wd, *, e_local, cap, act, gather_axis):
    """Per-(dp, model)-rank EP body (runs inside a manual shard_map region).

    ``x2`` is dp-local; ``comb`` is the (T, E_local) slice of the combine
    matrix — sharded over "model", so its cotangent stays rank-local (passing
    the replicated (T,K) routing tensors instead makes their backward a psum
    storm over "model": the dominant collective of the first MoE baseline,
    see EXPERIMENTS.md §Perf B-H2). Expert weights are model-rank-local with
    the FFN dim FSDP-sharded over ``gather_axis`` — gathered transiently, so
    the resident footprint of a 400B expert bank is params/(model*data)/chip.
    """
    if gather_axis:
        wg = jax.lax.all_gather(wg, gather_axis, axis=2, tiled=True)
        wu = jax.lax.all_gather(wu, gather_axis, axis=2, tiled=True)
        wd = jax.lax.all_gather(wd, gather_axis, axis=1, tiled=True)
    t, d = x2.shape

    y = jnp.zeros((t, d), jnp.float32)
    for le in range(e_local):
        # (T,) routing weight of this expert for each token (0 if not routed).
        w_e = comb[:, le]
        m = w_e > 0.0
        # Capacity slots (first-come order, GShard-style dropping).
        slot = jnp.cumsum(m.astype(jnp.int32)) - 1
        slot = jnp.where(m & (slot < cap), slot, cap)  # overflow -> slot `cap`
        buf = jnp.zeros((cap + 1, d), x2.dtype).at[slot].add(
            jnp.where(m[:, None], x2, 0).astype(x2.dtype)
        )
        ye = _expert_ffn(buf[None, :cap], wg[le : le + 1], wu[le : le + 1], wd[le : le + 1], act)[0]
        ye = jnp.concatenate([ye, jnp.zeros((1, d), ye.dtype)], axis=0)  # overflow row
        y = y + ye[slot].astype(jnp.float32) * w_e[:, None]
    # Combine happens *outside* the manual region (stacked over "model" and
    # summed in the auto region): an in-body psum of bf16 partials gets
    # re-upcast to f32 by the psum_invariant lowering (§Perf B-H1/B-H4), while
    # the auto-region reduction keeps bf16 and lets GSPMD pick AR vs RS+AG.
    return y.astype(x2.dtype)[None]


def moe_ep(x: jnp.ndarray, params, cfg, mesh, dp_axes: tuple[str, ...] = ()):
    """Expert-parallel MoE over the mesh "model" axis (production path).

    ``dp_axes``: mesh axes the token/batch dim is sharded over — they join the
    manual set so capacity bookkeeping (cumsum, slots) stays shard-local and
    never couples dp shards.
    """
    b, s, d = x.shape
    x2 = x.reshape(b * s, d)
    w, ids, aux = route(x2, params["router"], cfg.top_k)
    # Dense (T, E) combine matrix, sharded over experts ("model") on entry.
    t = b * s
    comb = jnp.zeros((t, cfg.n_experts), jnp.float32)
    comb = comb.at[jnp.arange(t)[:, None], ids].add(w)

    n_ranks = mesh.shape["model"]
    e_local = cfg.n_experts // n_ranks
    assert cfg.n_experts % n_ranks == 0, (cfg.n_experts, n_ranks)
    dp_degree = 1
    for a in dp_axes:
        dp_degree *= mesh.shape[a]
    # Per-rank capacity: expected T_local*K/n_ranks pairs, padded by the factor.
    t_local = b * s // dp_degree  # tokens per dp shard (replicated across model)
    cap_rank = int((t_local * cfg.top_k / n_ranks) * cfg.capacity_factor + 0.999)
    cap = max(8, -(-cap_rank // e_local))  # per local expert

    gather_axis = "data" if ("data" in dp_axes and cfg.moe_d_ff % mesh.shape["data"] == 0) else None
    body = functools.partial(
        _moe_rank_body, e_local=e_local, cap=cap, act=cfg.mlp_act, gather_axis=gather_axis
    )
    tok = P(dp_axes) if dp_axes else P()
    comb_spec = P(dp_axes if dp_axes else None, "model")
    wgu_spec = P("model", None, gather_axis)
    wd_spec = P("model", gather_axis, None)
    # When nested inside a manual region (the systolic train step), shard_map
    # must be given the surrounding *abstract* mesh, not the concrete one.
    ctx_mesh = compat.get_abstract_mesh()
    sm_mesh = ctx_mesh if (ctx_mesh is not None and ctx_mesh.shape) else mesh
    out_spec = P(("model",) ,*( [dp_axes] if dp_axes else [None]), None)
    y = compat.shard_map(
        body,
        mesh=sm_mesh,
        in_specs=(tok, comb_spec, wgu_spec, wgu_spec, wd_spec),
        out_specs=out_spec,
        axis_names=set(dp_axes) | {"model"},
        check_vma=True,
    )(x2, comb, params["w_gate"], params["w_up"], params["w_down"])
    y = y.sum(axis=0).astype(x.dtype)  # combine ranks in the auto region
    if "shared" in params:
        from repro.models.blocks import mlp

        y = y + mlp(x2, params["shared"], cfg.mlp_act)
    return y.reshape(b, s, d), aux

"""Shared NN building blocks: norms, RoPE, MLPs.

Parameters are plain pytrees (nested dicts of jnp arrays); every init function
has a matching apply function. Compute follows the NTX discipline: matmuls
accumulate in fp32 (``preferred_element_type``) and are rounded once at the
cast back to the activation dtype.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


# Rounding point for matmul partial sums. "f32" (default, NTX-faithful):
# per-chip partials stay fp32, so the TP all-reduce runs in fp32. "bf16"
# (beyond-paper perf option, EXPERIMENTS.md §Perf H1): partials are rounded to
# bf16 *before* the collective, halving TP wire bytes; the MXU still
# accumulates each partial in fp32 internally.
MATMUL_PARTIAL_DTYPE = "f32"


def set_matmul_partial_dtype(mode: str):
    global MATMUL_PARTIAL_DTYPE
    assert mode in ("f32", "bf16")
    MATMUL_PARTIAL_DTYPE = mode


def _dot(x, w):
    """Activation @ weight with fp32 accumulation, output in activation dtype."""
    if MATMUL_PARTIAL_DTYPE == "bf16":
        return jnp.dot(x, w, preferred_element_type=x.dtype)
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_rmsnorm(d: int, dtype=jnp.float32):
    return {"scale": jnp.zeros((d,), dtype)}  # gemma-style (1 + scale)


def rms_norm(x: jnp.ndarray, params, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + params["scale"].astype(jnp.float32))).astype(x.dtype)


def init_layernorm(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layer_norm(x: jnp.ndarray, params, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"] + params["bias"]).astype(x.dtype)


def apply_norm(x, params, kind: str, eps: float):
    return rms_norm(x, params, eps) if kind == "rms" else layer_norm(x, params, eps)


def init_norm(d: int, kind: str, dtype=jnp.float32):
    return init_rmsnorm(d, dtype) if kind == "rms" else init_layernorm(d, dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, D_head); positions: (S,) or (..., S) token positions."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)  # (d/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, d/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def init_mlp(rng, d: int, d_ff: int, act: str, dtype=jnp.bfloat16):
    k1, k2, k3 = jax.random.split(rng, 3)
    std = d**-0.5
    p = {"w_down": (jax.random.normal(k3, (d_ff, d)) * d_ff**-0.5).astype(dtype)}
    if act in ("swiglu", "geglu"):
        p["w_gate"] = (jax.random.normal(k1, (d, d_ff)) * std).astype(dtype)
        p["w_up"] = (jax.random.normal(k2, (d, d_ff)) * std).astype(dtype)
    else:  # plain gelu
        p["w_up"] = (jax.random.normal(k2, (d, d_ff)) * std).astype(dtype)
    return p


def mlp(x: jnp.ndarray, params, act: str) -> jnp.ndarray:
    if act == "swiglu":
        h = jax.nn.silu(_dot(x, params["w_gate"]).astype(jnp.float32)).astype(x.dtype)
        h = h * _dot(x, params["w_up"])
    elif act == "geglu":
        h = jax.nn.gelu(_dot(x, params["w_gate"]).astype(jnp.float32)).astype(x.dtype)
        h = h * _dot(x, params["w_up"])
    else:
        h = jax.nn.gelu(_dot(x, params["w_up"]).astype(jnp.float32)).astype(x.dtype)
    return _dot(h, params["w_down"])

"""Multi-cluster work scheduler over the command-queue + DMA runtime.

The top of the offload stack: take an :class:`~repro.core.ntx.NtxCommand`
loop nest (or a whole layer's worth of them), split it across the HMC's
clusters (§3.1's tiling over vaults), feed every cluster's driver its share,
and simulate the queues + DMA to a per-engine timeline.

  * :func:`partition_command` — split a command's outermost free loop into
    independent sub-commands with rebased AGUs (the driver-side loop of
    Table 2 made explicit). Executing the parts sequentially through
    ``ntx_execute`` is bit-identical to the original command.
  * :class:`MultiClusterScheduler` — round-robins commands over clusters,
    runs :func:`~repro.runtime.cmdqueue.simulate_offload` per cluster with
    the vault-capped DMA config, and collects a :class:`Timeline`.
  * :func:`simulate_workload` — the event-driven counterpart of the paper's
    analytical model (benchmarks/ntx_model.py eqs. 4-11): same calibration
    constants, but the overlap emerges from the simulated double-buffered
    pipeline instead of a ``max()``. The two must agree within ~10% —
    ``benchmarks/offload_bench.py`` checks this on the paper's workloads.

Timelines export as Chrome ``chrome://tracing`` / Perfetto JSON.
"""

from __future__ import annotations

import json
import math
from dataclasses import dataclass, field, replace
from typing import Sequence

from repro.core.ntx import Agu, NtxCommand
from repro.runtime import dma as dma_mod
from repro.runtime.cmdqueue import (
    BlockSegment,
    OffloadTrace,
    simulate_offload,
    simulate_offload_blocks,
)

# Compute-side calibration, identical to benchmarks/ntx_model.py (pinned by a
# test there): per-kernel NTX utilization and full-network derating.
ETA_COMPUTE = 0.84
ETA_NET = 0.855
ENGINES_PER_CLUSTER = 8  # NTX co-processors per RISC-V driver (§2.1)

# schedule_program(engine="auto"): programs above this command count take the
# block-replicated steady-state path (identical cycle counts, O(blocks) time);
# below it the full event-driven run keeps complete per-command traces.
BLOCK_ENGINE_THRESHOLD = 50_000


# ---------------------------------------------------------------------------
# Loop-nest partitioning
# ---------------------------------------------------------------------------


def _rebase(agu: Agu | None, level: int, start: int) -> Agu | None:
    if agu is None:
        return None
    return Agu(agu.base + start * agu.strides[level], agu.strides)


def partition_command(cmd: NtxCommand, parts: int) -> list[NtxCommand]:
    """Split ``cmd`` along its outermost non-unit loop into ≤ ``parts`` pieces.

    The split loop must sit at or above the accumulator's init/store levels so
    no accumulation region crosses a part boundary — each piece is then an
    independent command (what the driver's software loop iterates in Table 2).
    """
    level = None
    for l in range(len(cmd.loops) - 1, -1, -1):
        if cmd.loops[l] > 1:
            level = l
            break
    if level is None or parts <= 1:
        return [cmd]
    if cmd.init_level > level or cmd.store_level > level:
        raise ValueError(
            f"cannot split loop L{level}: accumulator spans it "
            f"(init_level={cmd.init_level}, store_level={cmd.store_level})"
        )
    n = cmd.loops[level]
    parts = min(parts, n)
    base_sz, rem = divmod(n, parts)
    out = []
    start = 0
    for p in range(parts):
        sz = base_sz + (1 if p < rem else 0)
        loops = list(cmd.loops)
        loops[level] = sz
        out.append(
            NtxCommand(
                loops=tuple(loops),
                opcode=cmd.opcode,
                agu_rd0=_rebase(cmd.agu_rd0, level, start),
                agu_rd1=_rebase(cmd.agu_rd1, level, start),
                agu_wr=_rebase(cmd.agu_wr, level, start),
                init_level=cmd.init_level,
                store_level=cmd.store_level,
                init_value=cmd.init_value,
            )
        )
        start += sz
    return out


def partition_program(program, parts: int):
    """Refine a lowered program's blocks into up to ``parts`` template pieces.

    Each block's command *template* is split along its outermost splittable
    free loop (:func:`partition_command`, which refuses to tear accumulation
    regions — such blocks stay whole); every piece keeps the block's driver
    replication loops, so a block with ``n`` commands becomes up to
    ``parts`` blocks of ``n`` commands each. Executing the refined program
    is bit-identical to the original (the pieces partition each command's
    iteration space), but the finer offload granularity is what lets one
    layer fill many clusters x engines — §3.1's tiling applied at the
    program level. Per-command DMA descriptors are scaled so total traffic
    is preserved.
    """
    from repro.lower.ir import NtxProgram

    new_blocks = []
    for b in program.blocks:
        try:
            pieces = partition_command(b.template, parts)
        except ValueError:
            pieces = [b.template]
        for p in pieces:
            new_blocks.append(
                replace(
                    b,
                    template=p,
                    dma_bytes_in=b.dma_bytes_in / len(pieces),
                    dma_bytes_out=b.dma_bytes_out / len(pieces),
                )
            )
    return NtxProgram(
        name=f"{program.name}:part{parts}",
        blocks=new_blocks,
        regions=program.regions,
        design=program.design,
        meta={**program.meta, "partitioned": parts},
    )


# ---------------------------------------------------------------------------
# Timeline / trace export
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TraceEvent:
    name: str
    cat: str  # "program" | "dma" | "exec"
    cluster: int
    engine: int  # -1 == the driver core
    t0: int
    t1: int


@dataclass
class Timeline:
    events: list[TraceEvent] = field(default_factory=list)

    def add_trace(self, cluster: int, trace: OffloadTrace) -> None:
        for i, r in enumerate(trace.records):
            name = f"cmd{i}:{r.cmd.opcode}"
            self.events.append(TraceEvent(name, "program", cluster, -1,
                                          r.program_start, r.issue_t))
            if r.dma_end > r.dma_start:
                self.events.append(TraceEvent(name, "dma", cluster, r.engine,
                                              r.dma_start, r.dma_end))
            self.events.append(TraceEvent(name, "exec", cluster, r.engine,
                                          r.exec_start, r.retire_t))

    def to_chrome_trace(self) -> dict:
        """chrome://tracing "X" (complete) events; pid=cluster, tid=engine."""
        out = []
        for e in self.events:
            tid = "driver" if e.engine < 0 else f"ntx{e.engine}"
            out.append({
                "name": e.name, "cat": e.cat, "ph": "X",
                "pid": f"cluster{e.cluster}", "tid": tid,
                "ts": e.t0, "dur": max(e.t1 - e.t0, 0),
                "args": {"cycles": e.t1 - e.t0},
            })
        return {"traceEvents": out, "displayTimeUnit": "ns"}

    def save(self, path) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f)


# ---------------------------------------------------------------------------
# Multi-cluster scheduling
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ClusterConfig:
    n_engines: int = ENGINES_PER_CLUSTER
    queue_depth: int = 4
    sync: bool = False
    dma: dma_mod.DmaConfig = field(default_factory=dma_mod.DmaConfig)
    dma_overlap: bool = True


@dataclass
class ScheduleResult:
    cluster_traces: list[OffloadTrace]
    timeline: Timeline

    @property
    def total_cycles(self) -> int:
        return max((t.stats.total_cycles for t in self.cluster_traces), default=0)

    @property
    def exec_cycles(self) -> int:
        return sum(t.stats.exec_cycles for t in self.cluster_traces)

    @property
    def utilization(self) -> float:
        engines = sum(t.stats.n_engines for t in self.cluster_traces)
        return self.exec_cycles / max(engines * self.total_cycles, 1)

    def summary(self) -> dict:
        s0 = self.cluster_traces[0].stats if self.cluster_traces else None
        return {
            "clusters": len(self.cluster_traces),
            "total_cycles": self.total_cycles,
            "utilization": self.utilization,
            "queue_depth": s0.queue_depth if s0 else 0,
            "n_commands": sum(t.stats.n_commands for t in self.cluster_traces),
            "dma_stall_cycles": sum(t.stats.dma_stall_cycles
                                    for t in self.cluster_traces),
            "queue_stall_cycles": sum(t.stats.queue_stall_cycles
                                      for t in self.cluster_traces),
            "overhead_cycles": sum(t.stats.overhead_cycles
                                   for t in self.cluster_traces),
            "elided_commands": sum(t.elided_commands
                                   for t in self.cluster_traces),
        }


class MultiClusterScheduler:
    """Partition command streams across clusters and simulate each one."""

    def __init__(self, n_clusters: int = 1,
                 cluster: ClusterConfig | None = None,
                 f_ntx: float = 1.5e9):
        self.n_clusters = n_clusters
        self.cluster = cluster or ClusterConfig()
        self.f_ntx = f_ntx
        # every cluster sees its share of the vault crossbar
        self._dma = self.cluster.dma.capped(n_clusters, f_ntx)

    def distribute(self, cmd: NtxCommand) -> list[list[NtxCommand]]:
        """Split one big command into per-cluster work lists."""
        parts = partition_command(cmd, self.n_clusters)
        buckets: list[list[NtxCommand]] = [[] for _ in range(self.n_clusters)]
        for i, p in enumerate(parts):
            buckets[i % self.n_clusters].append(p)
        return buckets

    def schedule(
        self,
        commands: Sequence[NtxCommand] | Sequence[Sequence[NtxCommand]],
        *,
        bytes_per_command: Sequence[float] | None = None,
        exec_cycles=None,
    ) -> ScheduleResult:
        """Simulate ``commands`` over the clusters.

        A flat sequence is dealt round robin; a pre-bucketed list of lists
        (e.g. from :meth:`distribute`) is used as-is. ``bytes_per_command``
        (flat, same order) attaches an input DMA transfer to each command.
        """
        if commands and isinstance(commands[0], NtxCommand):
            buckets = [list(commands[i::self.n_clusters])
                       for i in range(self.n_clusters)]
            byte_buckets = (
                [list(bytes_per_command[i::self.n_clusters])
                 for i in range(self.n_clusters)]
                if bytes_per_command is not None else None
            )
        else:
            buckets = [list(b) for b in commands]
            if bytes_per_command is not None:
                byte_buckets, it = [], iter(bytes_per_command)
                for b in buckets:
                    byte_buckets.append([next(it) for _ in b])
            else:
                byte_buckets = None

        timeline = Timeline()
        traces = []
        for c, bucket in enumerate(buckets):
            dma_cycles = None
            if byte_buckets is not None:
                dma_cycles = [
                    self._dma.transfer_cycles(dma_mod.Transfer(nb))
                    for nb in byte_buckets[c]
                ]
            trace = simulate_offload(
                bucket,
                n_engines=self.cluster.n_engines,
                queue_depth=self.cluster.queue_depth,
                sync=self.cluster.sync,
                exec_cycles=exec_cycles,
                dma_cycles=dma_cycles,
                dma_overlap=self.cluster.dma_overlap,
                dma_buffers=self._dma.n_buffers,
            )
            timeline.add_trace(c, trace)
            traces.append(trace)
        return ScheduleResult(cluster_traces=traces, timeline=timeline)

    def program_segments(self, program) -> list[list[BlockSegment]]:
        """Per-cluster :class:`BlockSegment` lists for ``program``.

        Reproduces exactly the round-robin deal of :meth:`schedule` — global
        command ``i`` lands on cluster ``i % n_clusters`` at bucket position
        ``i // n_clusters`` — without materializing a single command: each
        block contributes one segment per cluster, sized by how many of the
        block's replicas fall on that cluster.
        """
        segs: list[list[BlockSegment]] = [[] for _ in range(self.n_clusters)]
        g = 0  # global index of the block's first command
        for template, count, dma_bytes_in in program.block_segments():
            dc = (
                self._dma.transfer_cycles(dma_mod.Transfer(dma_bytes_in))
                if dma_bytes_in
                else 0
            )
            for c in range(self.n_clusters):
                first = g + ((c - g) % self.n_clusters)
                if first < g + count:
                    share = (g + count - 1 - first) // self.n_clusters + 1
                    segs[c].append(BlockSegment(template, share, dc))
            g += count
        return segs

    def schedule_program(self, program, *, engine: str = "auto",
                         exec_cycles=None) -> ScheduleResult:
        """Simulate a lowered :class:`repro.lower.NtxProgram`.

        The command stream and the per-command DMA byte counts both come
        from the program — this is the timing-executor entry point
        (:func:`repro.lower.executors.run_timing` wraps it).

        ``engine`` selects the simulation strategy:

          * ``"event"`` — materialize every command and run the full
            event-driven simulation (complete per-command traces).
          * ``"block"`` — the block-replicated steady-state fast path
            (:func:`repro.runtime.cmdqueue.simulate_offload_blocks`):
            identical cycle counts, O(blocks) instead of O(commands).
          * ``"auto"`` — ``"block"`` above ``BLOCK_ENGINE_THRESHOLD``
            commands, ``"event"`` below.

        ``exec_cycles`` overrides per-command datapath cycles (e.g. an
        eta-derated ``busy_cycles``); on the block path it must not depend
        on AGU bases.
        """
        if engine == "auto":
            engine = (
                "block" if program.n_commands > BLOCK_ENGINE_THRESHOLD
                else "event"
            )
        if engine == "event":
            return self.schedule(
                list(program.commands()),
                bytes_per_command=list(program.command_dma_bytes()),
                exec_cycles=exec_cycles,
            )
        if engine != "block":
            raise ValueError(f"unknown timing engine {engine!r}")
        timeline = Timeline()
        traces = []
        for c, segs in enumerate(self.program_segments(program)):
            trace = simulate_offload_blocks(
                segs,
                n_engines=self.cluster.n_engines,
                queue_depth=self.cluster.queue_depth,
                sync=self.cluster.sync,
                exec_cycles=exec_cycles,
                dma_overlap=self.cluster.dma_overlap,
                dma_buffers=self._dma.n_buffers,
            )
            timeline.add_trace(c, trace)
            traces.append(trace)
        return ScheduleResult(cluster_traces=traces, timeline=timeline)


# ---------------------------------------------------------------------------
# Event-driven counterpart of the analytical model (eqs. 4-11)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class WorkloadEstimate:
    cycles: int  # NTX-clock makespan per cluster (all clusters balanced)
    time: float  # seconds at f_ntx
    compute_stall_cycles: int
    buffer_stall_cycles: int
    overlap_efficiency: float


def simulate_workload(
    macs: float,
    bytes_total: float,
    *,
    n_clusters: int = 16,
    f_ntx: float = 1.5e9,
    tiles_per_cluster: int = 64,
    bytes_seq_frac: float = 0.02,
    overlap: bool = True,
) -> WorkloadEstimate:
    """Tile a (macs, bytes) kernel over the cube and simulate the streaming.

    Mirrors :func:`benchmarks.ntx_model.cluster_time`: compute derated by
    eta_c * eta_net, DMA by eta_d at the vault-capped rate, a
    ``bytes_seq_frac`` head+tail that cannot overlap — but the par-phase
    overlap comes out of the double-buffered pipeline simulation rather than
    an analytic ``max()``.
    """
    macs_c = macs / n_clusters
    bytes_c = bytes_total / n_clusters
    seq_bytes = bytes_c * bytes_seq_frac
    par_bytes = bytes_c - seq_bytes

    cfg = dma_mod.DmaConfig().capped(n_clusters, f_ntx)
    # one balanced tile stream per cluster; compute wall-cycles spread over
    # the 8 engines at 1 MAC/cycle each (R_c = 8 MACs/cycle/cluster)
    compute_per_tile = macs_c / tiles_per_cluster / ENGINES_PER_CLUSTER
    compute_per_tile /= ETA_COMPUTE * ETA_NET
    tiles = [
        (dma_mod.Transfer(par_bytes / tiles_per_cluster), compute_per_tile)
        for _ in range(tiles_per_cluster)
    ]
    stats = dma_mod.DmaEngine(cfg).pipeline(tiles, overlap=overlap)
    seq_cycles = int(math.ceil(seq_bytes / (cfg.bytes_per_cycle * cfg.eta)))
    cycles = stats.total_cycles + seq_cycles
    return WorkloadEstimate(
        cycles=cycles,
        time=cycles / f_ntx,
        compute_stall_cycles=stats.compute_stall_cycles,
        buffer_stall_cycles=stats.buffer_stall_cycles,
        overlap_efficiency=stats.overlap_efficiency,
    )

"""Double-buffered cluster DMA model: TCDM banking + HMC vault bandwidth.

The cluster DMA engine streams tiles between the HMC vaults (through the
vault controllers) and the TCDM scratchpad while the NTX engines compute
(paper §2.1/§3.1). This module models the three effects that decide whether
the transfer hides behind compute:

  * **sustained bandwidth** — ``R_D_BYTES_PER_CYCLE`` bytes per NTX cycle per
    cluster at efficiency ``ETA_DMA`` (the paper's eta_d), the same
    calibration constants as :mod:`benchmarks.ntx_model` (a test pins them).
  * **TCDM bank conflicts** — the scratchpad is word-interleaved over
    ``TCDM_BANKS`` banks; a strided burst that hits only a subset of banks
    serializes by ``gcd(stride, banks)``.
  * **HMC internal bandwidth cap** — all clusters share the 320 GB/s vault
    crossbar; past ~16 clusters the per-cluster share, not the DMA engine,
    is the limit (the Fig. 8 "dent").

``DmaEngine.pipeline`` plays a tile stream through ``n_buffers`` TCDM tile
buffers and reports where the cycles went — compute stall (compute waited on
a transfer) vs buffer stall (transfer waited on a free buffer).
"""

from __future__ import annotations

import functools
import math
from dataclasses import dataclass
from typing import Sequence

# Calibration constants — numerically identical to benchmarks/ntx_model.py
# (tests cross-check); duplicated here so src/ never imports benchmarks/.
R_D_BYTES_PER_CYCLE = 4.8  # DMA bytes per NTX cycle per cluster (Table 4)
ETA_DMA = 0.87  # eta_d: achievable fraction of the DMA wire rate
HMC_INTERNAL_BW = 320e9  # B/s through the vault crossbar (§4.9)
TCDM_BANKS = 32  # word-interleaved SRAM banks per cluster


def bank_conflict_factor(word_stride: int, banks: int = TCDM_BANKS) -> int:
    """Serialization factor of a constant-stride burst over ``banks`` banks.

    A stride-s burst touches ``banks / gcd(s, banks)`` distinct banks, so the
    per-cycle parallelism drops by ``gcd(s, banks)``. Stride 0 (broadcast
    reads of one address) pins a single bank.
    """
    if word_stride == 0:
        return banks
    return math.gcd(abs(word_stride), banks)


def vault_bytes_per_cycle(n_clusters: int, f_ntx: float,
                          wire_rate: float = R_D_BYTES_PER_CYCLE) -> float:
    """Per-cluster DMA bytes/cycle after the shared HMC crossbar cap."""
    cap = HMC_INTERNAL_BW / (n_clusters * f_ntx)
    return min(wire_rate, cap)


@dataclass(frozen=True)
class Transfer:
    """One DMA job: ``num_bytes`` moved with TCDM word stride ``word_stride``."""

    num_bytes: float
    word_stride: int = 1


@functools.lru_cache(maxsize=4096)
def _transfer_cycles(num_bytes: float, word_stride: int,
                     bytes_per_cycle: float, eta: float, banks: int) -> int:
    eff = bytes_per_cycle * eta / bank_conflict_factor(word_stride, banks)
    return int(math.ceil(num_bytes / eff))


@dataclass(frozen=True)
class DmaConfig:
    bytes_per_cycle: float = R_D_BYTES_PER_CYCLE
    eta: float = ETA_DMA
    n_buffers: int = 2  # double buffering by default
    banks: int = TCDM_BANKS

    def transfer_cycles(self, t: Transfer) -> int:
        # memoized: the event-driven scheduler evaluates this once per
        # command, and block-replicated programs repeat a handful of
        # (bytes, stride) pairs across hundreds of thousands of commands
        return _transfer_cycles(t.num_bytes, t.word_stride,
                                self.bytes_per_cycle, self.eta, self.banks)

    def capped(self, n_clusters: int, f_ntx: float) -> "DmaConfig":
        """This config with the per-cluster share of the vault crossbar."""
        return DmaConfig(
            bytes_per_cycle=vault_bytes_per_cycle(
                n_clusters, f_ntx, self.bytes_per_cycle
            ),
            eta=self.eta, n_buffers=self.n_buffers, banks=self.banks,
        )


@dataclass(frozen=True)
class PipelineStats:
    total_cycles: int
    compute_cycles: int  # sum of tile compute
    dma_cycles: int  # sum of transfer times
    compute_stall_cycles: int  # compute unit idle, waiting on a transfer
    buffer_stall_cycles: int  # DMA idle, waiting on a free tile buffer

    @property
    def overlap_efficiency(self) -> float:
        """1.0 == transfers fully hidden behind compute."""
        ideal = max(self.compute_cycles, self.dma_cycles)
        return ideal / max(self.total_cycles, 1)


class DmaEngine:
    """Plays a tile stream through ``cfg.n_buffers`` TCDM tile buffers."""

    def __init__(self, cfg: DmaConfig | None = None):
        self.cfg = cfg or DmaConfig()

    def pipeline(
        self,
        tiles: Sequence[tuple[Transfer, float]],
        *,
        overlap: bool = True,
    ) -> PipelineStats:
        """``tiles`` = [(input transfer, compute cycles)] per tile, in order.

        With ``overlap`` the engine prefetches tile i+1 while tile i computes
        (classic double buffering); without it every transfer serializes with
        compute — the §2.5 strawman used to measure what overlap buys.
        """
        nbuf = self.cfg.n_buffers
        d_end: list[int] = []
        c_end: list[int] = []
        compute_stall = 0
        buffer_stall = 0
        dma_sum = 0
        comp_sum = 0
        for i, (tr, cc) in enumerate(tiles):
            dc = self.cfg.transfer_cycles(tr)
            cc = int(math.ceil(cc))
            prev_d = d_end[i - 1] if i else 0
            prev_c = c_end[i - 1] if i else 0
            if overlap:
                slot_free = c_end[i - nbuf] if i >= nbuf else 0
                d_start = max(prev_d, slot_free)
                buffer_stall += d_start - prev_d
                d_i = d_start + dc
                c_start = max(prev_c, d_i)
                compute_stall += c_start - prev_c
            else:
                d_start = max(prev_d, prev_c)
                d_i = d_start + dc
                c_start = d_i
                compute_stall += c_start - prev_c
            d_end.append(d_i)
            c_end.append(c_start + cc)
            dma_sum += dc
            comp_sum += cc
        total = max(c_end[-1] if c_end else 0, d_end[-1] if d_end else 0)
        return PipelineStats(
            total_cycles=total,
            compute_cycles=comp_sum,
            dma_cycles=dma_sum,
            compute_stall_cycles=compute_stall,
            buffer_stall_cycles=buffer_stall,
        )

"""Per-co-processor command queues: the loosely-coupled offload path (§2.2).

The paper's headline mechanism is that the RISC-V driver core and the NTX
co-processors are *loosely coupled*: the driver writes the next command into a
staging area while the co-processor is still streaming the previous one, so
the per-offload programming cost disappears behind execution and one scalar
core keeps 8 NTX engines busy. This module is a cycle-level discrete-event
model of exactly that flow:

  * :func:`program_cycles` — how long the driver needs to fill one staging
    area (one 32-bit store per register: loop bounds, AGU bases + strides,
    opcode/config — ~26 cycles for a 3-AGU command).
  * :class:`CommandQueue` — a bounded FIFO of staged commands per engine with
    back-pressure: a full queue stalls the driver until a slot retires.
  * :func:`simulate_offload` — one driver feeding ``n_engines`` queues round
    robin, either ``sync`` (tightly coupled: program, issue, spin until
    retire — the NS baseline) or queued (the NTX path). Every command gets
    issue/retire timestamps; DMA prefetch for a staged command may overlap
    the execution of earlier commands (double buffering at the engine).

All times are NTX-clock cycles. The model is exact for FIFO queues because
commands are issued in program order per engine.
"""

from __future__ import annotations

import bisect
import math
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Iterable, Sequence

from repro.core.ntx import MAX_LOOPS, NtxCommand

# One 32-bit store per staging-area register (§2.2); the issue itself is one
# more store to the command register. A blocking (NS-style) offload
# additionally pays a completion round trip: raise-event + driver wake/poll.
STAGING_WRITE_CYCLES = 1
CMD_ISSUE_CYCLES = 1
SYNC_ROUNDTRIP_CYCLES = 10


def program_cycles(cmd: NtxCommand) -> int:
    """Driver cycles to fill one staging area for ``cmd``.

    Registers written: 5 loop bounds, per present AGU 1 base + 5 strides,
    opcode/levels config word, and the accumulator init value.
    """
    regs = MAX_LOOPS  # loop bounds
    for agu in (cmd.agu_rd0, cmd.agu_rd1, cmd.agu_wr):
        if agu is not None:
            regs += 1 + MAX_LOOPS
    regs += 2  # opcode + init/store levels word, init value
    return regs * STAGING_WRITE_CYCLES + CMD_ISSUE_CYCLES


class QueueFull(RuntimeError):
    """Raised by :meth:`CommandQueue.push` when the FIFO is at depth."""


@dataclass
class QueueRecord:
    """Lifecycle timestamps of one offloaded command (all in NTX cycles)."""

    cmd: NtxCommand
    engine: int
    program_start: int  # driver begins writing the staging area
    issue_t: int  # command enters the queue
    dma_start: int  # input prefetch begins (== issue_t when no DMA)
    dma_end: int
    exec_start: int  # FMAC datapath starts
    retire_t: int  # last store completes; queue slot frees

    @property
    def queue_wait(self) -> int:
        return self.exec_start - self.issue_t

    @property
    def exec_cycles(self) -> int:
        return self.retire_t - self.exec_start


class CommandQueue:
    """Bounded FIFO of in-flight commands for one engine.

    A command occupies its slot from issue until retire (the staging area
    holds it while it executes). ``free_at`` tells the driver when the next
    push can be issued — this is the back-pressure the driver spins on.
    """

    def __init__(self, depth: int):
        if depth < 1:
            raise ValueError("queue depth must be >= 1")
        self.depth = depth
        self.records: list[QueueRecord] = []
        self._issues: list[int] = []  # sorted copies of the record timestamps,
        self._retires: list[int] = []  # so occupancy/free_at are O(log n)

    def occupancy(self, t: int) -> int:
        return bisect.bisect_right(self._issues, t) - bisect.bisect_right(
            self._retires, t
        )

    def free_at(self, t: int) -> int:
        """Earliest time >= t at which a new command may be issued."""
        live = len(self._retires) - bisect.bisect_right(self._retires, t)
        if live < self.depth:
            return t
        # the oldest of the newest `depth` in-flight retires first
        return self._retires[len(self._retires) - self.depth]

    def push(self, record: QueueRecord) -> None:
        if self.occupancy(record.issue_t) >= self.depth:
            raise QueueFull(
                f"engine {record.engine}: queue depth {self.depth} exceeded at "
                f"t={record.issue_t}"
            )
        self.records.append(record)
        bisect.insort(self._issues, record.issue_t)
        bisect.insort(self._retires, record.retire_t)


@dataclass(frozen=True)
class OffloadStats:
    """Aggregate of one :func:`simulate_offload` run."""

    n_commands: int
    n_engines: int
    queue_depth: int
    sync: bool
    total_cycles: int  # makespan: last retire
    exec_cycles: int  # sum of datapath-busy cycles over all commands
    dma_cycles: int  # sum of transfer cycles
    driver_cycles: int  # cycles the driver spent programming/spinning
    dma_stall_cycles: int  # engine ready but waiting on its prefetch
    queue_stall_cycles: int  # driver blocked on a full queue (back-pressure)
    overhead_cycles: int  # makespan minus the busiest engine's pure exec time

    @property
    def overhead_per_offload(self) -> float:
        return self.overhead_cycles / max(self.n_commands, 1)

    @property
    def utilization(self) -> float:
        """Fraction of engine-cycles spent executing."""
        return self.exec_cycles / max(self.n_engines * self.total_cycles, 1)


@dataclass
class OffloadTrace:
    records: list[QueueRecord]
    queues: list[CommandQueue]
    stats: OffloadStats
    # commands whose records were not materialized (block-replicated fast
    # path, or the record cap): the stats still account for every command.
    elided_commands: int = 0


def simulate_offload(
    commands: Sequence[NtxCommand],
    *,
    n_engines: int = 8,
    queue_depth: int = 4,
    sync: bool = False,
    exec_cycles: Callable[[NtxCommand], float] | None = None,
    dma_cycles: Sequence[float] | None = None,
    dma_overlap: bool = True,
    dma_buffers: int = 2,
) -> OffloadTrace:
    """One driver core feeding ``n_engines`` command queues.

    ``sync=True`` models the tightly-coupled NS baseline: the driver programs
    a command, issues it, and spins until it retires (plus a completion round
    trip) before touching the next one — queue depth is irrelevant.

    ``dma_cycles[i]`` is the input-transfer time of command ``i``. With
    ``dma_overlap`` the prefetch may start as soon as the command is staged
    (so it hides behind earlier executions, bounded by ``dma_buffers`` TCDM
    tile buffers per engine); without it the transfer runs back-to-back with
    execution — the no-double-buffering strawman.
    """
    exec_fn = exec_cycles or (lambda c: c.busy_cycles)
    queues = [CommandQueue(1 if sync else queue_depth) for _ in range(n_engines)]
    # per-engine state
    busy_until = [0] * n_engines
    dma_busy_until = [0] * n_engines
    done_exec_ends: list[list[int]] = [[] for _ in range(n_engines)]  # per slot reuse
    records: list[QueueRecord] = []

    t_driver = 0
    driver_busy = 0
    queue_stall = 0
    dma_stall = 0
    exec_total = 0
    dma_total = 0

    for i, cmd in enumerate(commands):
        e = i % n_engines
        q = queues[e]
        # back-pressure: wait for a free slot before writing the staging area
        t_free = q.free_at(t_driver)
        queue_stall += t_free - t_driver
        prog_start = t_free
        prog = program_cycles(cmd)
        issue_t = prog_start + prog
        driver_busy += prog

        dc = int(math.ceil(dma_cycles[i])) if dma_cycles is not None else 0
        if dc:
            if dma_overlap:
                # prefetch may start once staged; the target tile buffer must
                # have been drained by the (j - dma_buffers)-th command.
                j = len(done_exec_ends[e])
                slot_free = (
                    done_exec_ends[e][j - dma_buffers] if j >= dma_buffers else 0
                )
                dma_start = max(issue_t, dma_busy_until[e], slot_free)
            else:
                dma_start = max(issue_t, busy_until[e])
            dma_end = dma_start + dc
            dma_busy_until[e] = dma_end
        else:
            dma_start = dma_end = issue_t

        ready = max(busy_until[e], issue_t)
        exec_start = max(ready, dma_end)
        dma_stall += exec_start - ready
        ec = int(math.ceil(exec_fn(cmd)))
        retire_t = exec_start + ec
        busy_until[e] = retire_t
        done_exec_ends[e].append(retire_t)
        exec_total += ec
        dma_total += dc

        rec = QueueRecord(cmd, e, prog_start, issue_t, dma_start, dma_end,
                          exec_start, retire_t)
        q.push(rec)
        records.append(rec)

        if sync:
            # spin until completion + round trip before the next command
            t_driver = retire_t + SYNC_ROUNDTRIP_CYCLES
            driver_busy += SYNC_ROUNDTRIP_CYCLES
        else:
            t_driver = issue_t

    total = max((r.retire_t for r in records), default=0)
    per_engine_exec = [0] * n_engines
    for r in records:
        per_engine_exec[r.engine] += r.exec_cycles
    overhead = total - max(per_engine_exec, default=0)
    stats = OffloadStats(
        n_commands=len(records),
        n_engines=n_engines,
        queue_depth=1 if sync else queue_depth,
        sync=sync,
        total_cycles=total,
        exec_cycles=exec_total,
        dma_cycles=dma_total,
        driver_cycles=driver_busy,
        dma_stall_cycles=dma_stall,
        queue_stall_cycles=queue_stall,
        overhead_cycles=overhead,
    )
    return OffloadTrace(records=records, queues=queues, stats=stats)


# ---------------------------------------------------------------------------
# Block-replicated steady-state simulation
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class BlockSegment:
    """A run of ``count`` timing-identical commands (one CommandBlock's share).

    Every command materialized from a :class:`repro.lower.ir.CommandBlock`
    has the same loop bounds, the same AGU population, and the same
    per-command input-DMA bytes — only the AGU *bases* differ between
    replicas, and no timing quantity (:func:`program_cycles`,
    ``busy_cycles``, transfer cycles) depends on a base. A segment therefore
    describes a block's whole command stream to the timing model without
    materializing it.
    """

    template: NtxCommand
    count: int
    dma_cycles: int = 0


def simulate_offload_blocks(
    segments: Iterable[BlockSegment],
    *,
    n_engines: int = 8,
    queue_depth: int = 4,
    sync: bool = False,
    exec_cycles: Callable[[NtxCommand], float] | None = None,
    dma_overlap: bool = True,
    dma_buffers: int = 2,
    max_records: int = 50_000,
) -> OffloadTrace:
    """Bit-exact :func:`simulate_offload` over block-replicated command runs.

    Each segment is simulated event-by-event only until the queue/DMA
    pipeline reaches **steady state** — one full engine round (``n_engines``
    consecutive commands) advancing every live timestamp by the same delta —
    after which the remaining rounds are replicated analytically. The update
    rules are max-plus (``max()`` and ``+`` of per-segment constants), so a
    uniformly shifted state reproduces a uniformly shifted round exactly:
    the analytic tail is cycle-identical to what event-by-event simulation
    would produce, and segment boundaries stitch on the exact carried state
    (per-engine busy/DMA horizons, tile-buffer and queue-slot history).

    ``exec_cycles`` must not depend on AGU bases (the default —
    ``busy_cycles`` — never does). Stats match :func:`simulate_offload` on
    the expanded stream bit for bit; records are materialized only up to
    ``max_records``, ``elided_commands`` counts the rest, and fast-path
    records carry the segment template rather than rebased AGU bases.

    The per-command update rules below deliberately *duplicate* (rather
    than share) :func:`simulate_offload`'s pipeline step: the two engines
    are kept as independent implementations of the same contract so the
    randomized exact-equality tests in ``tests/test_timing_fast.py`` check
    one against the other instead of one implementation against itself.
    Any behavioural change must be made in both and survives those tests.
    """
    exec_fn = exec_cycles or (lambda c: c.busy_cycles)
    depth = 1 if sync else queue_depth
    n_eng = n_engines
    busy = [0] * n_eng
    dma_busy = [0] * n_eng
    exec_hist = [deque(maxlen=dma_buffers) for _ in range(n_eng)]
    retire_hist = [deque(maxlen=depth) for _ in range(n_eng)]
    queues = [CommandQueue(depth) for _ in range(n_eng)]
    records: list[QueueRecord] = []

    state = {
        "t_driver": 0, "driver_busy": 0, "queue_stall": 0, "dma_stall": 0,
        "exec_total": 0, "dma_total": 0, "n_commands": 0, "elided": 0,
        "max_retire": 0, "i": 0,
    }
    per_engine_exec = [0] * n_eng

    for seg in segments:
        if seg.count <= 0:
            continue
        cmd = seg.template
        prog = program_cycles(cmd)
        ec = int(math.ceil(exec_fn(cmd)))
        dc = int(math.ceil(seg.dma_cycles))
        include_dma = dc > 0

        def step():
            s = state
            e = s["i"] % n_eng
            h = retire_hist[e]
            t_driver = s["t_driver"]
            # queue back-pressure (free_at over the last `depth` retires)
            if len(h) == depth and h[0] > t_driver:
                t_free = h[0]
                s["queue_stall"] += t_free - t_driver
            else:
                t_free = t_driver
            prog_start = t_free
            issue_t = prog_start + prog
            s["driver_busy"] += prog
            if dc:
                if dma_overlap:
                    eh = exec_hist[e]
                    slot_free = eh[0] if len(eh) == dma_buffers else 0
                    dma_start = max(issue_t, dma_busy[e], slot_free)
                else:
                    dma_start = max(issue_t, busy[e])
                dma_end = dma_start + dc
                dma_busy[e] = dma_end
            else:
                dma_start = dma_end = issue_t
            ready = busy[e] if busy[e] > issue_t else issue_t
            exec_start = dma_end if dma_end > ready else ready
            s["dma_stall"] += exec_start - ready
            retire_t = exec_start + ec
            busy[e] = retire_t
            exec_hist[e].append(retire_t)
            h.append(retire_t)
            s["exec_total"] += ec
            s["dma_total"] += dc
            per_engine_exec[e] += ec
            s["n_commands"] += 1
            s["i"] += 1
            if retire_t > s["max_retire"]:
                s["max_retire"] = retire_t
            if len(records) < max_records:
                rec = QueueRecord(cmd, e, prog_start, issue_t, dma_start,
                                  dma_end, exec_start, retire_t)
                queues[e].push(rec)
                records.append(rec)
            else:
                s["elided"] += 1
            if sync:
                s["t_driver"] = retire_t + SYNC_ROUNDTRIP_CYCLES
                s["driver_busy"] += SYNC_ROUNDTRIP_CYCLES
            else:
                s["t_driver"] = issue_t

        def signature():
            sig = [state["t_driver"]]
            sig += busy
            if include_dma:
                sig += dma_busy
            for h in exec_hist:
                sig.extend(h)
            for h in retire_hist:
                sig.extend(h)
            return sig

        remaining = seg.count
        prev_sig = None
        qs_mark, ds_mark = state["queue_stall"], state["dma_stall"]
        qs_round = ds_round = 0
        steady = False
        delta = 0
        while remaining >= n_eng:
            for _ in range(n_eng):
                step()
            remaining -= n_eng
            qs_round = state["queue_stall"] - qs_mark
            ds_round = state["dma_stall"] - ds_mark
            qs_mark, ds_mark = state["queue_stall"], state["dma_stall"]
            sig = signature()
            if prev_sig is not None and len(sig) == len(prev_sig):
                delta = sig[0] - prev_sig[0]
                if delta > 0 and all(
                    a - b == delta for a, b in zip(sig, prev_sig)
                ):
                    steady = True
                    break
            prev_sig = sig

        if steady and remaining >= n_eng:
            rounds = remaining // n_eng
            remaining -= rounds * n_eng
            shift = rounds * delta
            state["t_driver"] += shift
            state["max_retire"] = max(
                state["max_retire"], max(busy) + shift
            )
            state["queue_stall"] += rounds * qs_round
            state["dma_stall"] += rounds * ds_round
            state["driver_busy"] += rounds * n_eng * (
                prog + (SYNC_ROUNDTRIP_CYCLES if sync else 0)
            )
            state["exec_total"] += rounds * n_eng * ec
            state["dma_total"] += rounds * n_eng * dc
            state["n_commands"] += rounds * n_eng
            state["elided"] += rounds * n_eng
            state["i"] += rounds * n_eng
            for e in range(n_eng):
                busy[e] += shift
                per_engine_exec[e] += rounds * ec
                if include_dma:
                    dma_busy[e] += shift
                exec_hist[e] = deque(
                    (x + shift for x in exec_hist[e]), maxlen=dma_buffers
                )
                retire_hist[e] = deque(
                    (x + shift for x in retire_hist[e]), maxlen=depth
                )
        while remaining > 0:
            step()
            remaining -= 1

    total = state["max_retire"]
    stats = OffloadStats(
        n_commands=state["n_commands"],
        n_engines=n_eng,
        queue_depth=depth,
        sync=sync,
        total_cycles=total,
        exec_cycles=state["exec_total"],
        dma_cycles=state["dma_total"],
        driver_cycles=state["driver_busy"],
        dma_stall_cycles=state["dma_stall"],
        queue_stall_cycles=state["queue_stall"],
        overhead_cycles=total - max(per_engine_exec, default=0),
    )
    return OffloadTrace(records=records, queues=queues, stats=stats,
                        elided_commands=state["elided"])


def overhead_reduction(
    commands: Sequence[NtxCommand],
    *,
    n_engines: int = 8,
    queue_depth: int = 4,
    **kw,
) -> tuple[OffloadTrace, OffloadTrace, float]:
    """(sync_trace, queued_trace, offload-overhead reduction factor).

    The paper's §2.2 claim: loose coupling cuts the offload overhead — the
    cycles the engines are *not* executing while work remains — by ~7x.
    """
    s = simulate_offload(commands, n_engines=n_engines, sync=True, **kw)
    a = simulate_offload(commands, n_engines=n_engines, queue_depth=queue_depth, **kw)
    red = s.stats.overhead_cycles / max(a.stats.overhead_cycles, 1)
    return s, a, red

"""Fault-tolerant training supervisor: restart, elastic re-mesh, stragglers.

What a 1000-node deployment needs and how this maps onto the single-process
container (mechanisms are real; failures are injected):

  * **checkpoint/restart** — AsyncCheckpointer every ``ckpt_every`` steps;
    on failure the supervisor restores the latest complete checkpoint and
    resumes the data iterator at the restored step (bit-identical stream —
    data/pipeline.py's (seed, step) contract).
  * **elastic re-mesh** — on permanent node loss the job continues on the
    surviving device set: a new (smaller DP) mesh is built, parameters are
    re-placed with the new shardings (checkpoint.restore(shardings=...)),
    and the global batch is either kept (more per-device work) or rescaled.
    Exercised in tests by re-meshing 8 -> 4 fake devices.
  * **straggler mitigation** — per-step deadline derived from the paper's
    mesh-update model (core/systolic.mesh_update_time_model) plus an EWMA of
    compute time. In production the policy is drop-and-rescale: the gradient
    average proceeds over responsive workers and is rescaled by
    alive/total — statistically unbiased because shard assignment is random.
    The container simulates the detection path and logs the decision.
  * **failure detection** — heartbeats are the step returns themselves; an
    injected ``FailureInjector`` raises at configured steps to exercise the
    recovery path deterministically.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax

from repro.checkpoint import checkpoint as ckpt
from repro.core.systolic import mesh_update_time_model
from repro.runtime.faults import RetryPolicy


class SimulatedFailure(RuntimeError):
    pass


class SimulatedStraggler(RuntimeError):
    pass


@dataclass
class FailureInjector:
    """Deterministic fault schedule: {step: kind}; kind in {"crash","straggler"}."""

    schedule: dict = field(default_factory=dict)

    def check(self, step: int):
        kind = self.schedule.get(step)
        if kind == "crash":
            # fire once
            del self.schedule[step]
            raise SimulatedFailure(f"injected crash at step {step}")
        if kind == "straggler":
            del self.schedule[step]
            raise SimulatedStraggler(f"injected straggler at step {step}")


@dataclass
class StragglerPolicy:
    """Deadline = ewma(compute) * slack + mesh update bound (paper eq. 14/15)."""

    slack: float = 3.0
    weight_bytes: float = 300e6  # paper's 300 MB update
    mesh_side: int = 16
    ewma: float | None = None

    def deadline(self) -> float:
        base = self.ewma if self.ewma is not None else 60.0
        return base * self.slack + mesh_update_time_model(self.weight_bytes, self.mesh_side)

    def observe(self, dt: float):
        self.ewma = dt if self.ewma is None else 0.9 * self.ewma + 0.1 * dt


@dataclass
class SupervisorReport:
    steps_run: int = 0
    restarts: int = 0
    straggler_events: int = 0
    redispatches: int = 0
    remesh_events: int = 0
    backoffs: list = field(default_factory=list)  # seconds slept per retry
    log: list = field(default_factory=list)


class Supervisor:
    """Drives (train_step, iterator) to ``total_steps`` surviving failures."""

    def __init__(
        self,
        make_step,  # (mesh) -> train_step callable
        init_state,  # (mesh) -> fresh state (used only on cold start)
        iterator,
        ckpt_dir,
        *,
        ckpt_every: int = 10,
        injector: FailureInjector | None = None,
        straggler_policy: StragglerPolicy | None = None,
        meshes=None,  # fallback meshes for elastic re-mesh (largest first)
        state_shardings_fn=None,  # (state_template, mesh) -> shardings tree
        registry=None,  # repro.obs.CounterRegistry (checkpointed with state)
        metrics_path=None,  # per-step metrics JSONL (repro.obs.report schema)
        retry: RetryPolicy | None = None,  # bounded restart backoff schedule
        sleep_fn=time.sleep,  # injectable for tests (no real sleeping)
        redispatch: bool = True,  # re-dispatch straggler steps to a backup
    ):
        self.make_step = make_step
        self.init_state = init_state
        self.iterator = iterator
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.injector = injector or FailureInjector()
        self.straggler = straggler_policy or StragglerPolicy()
        self.meshes = list(meshes) if meshes else [None]
        self.state_shardings_fn = state_shardings_fn
        self.checkpointer = ckpt.AsyncCheckpointer(ckpt_dir)
        self.report = SupervisorReport()
        self.registry = registry
        self.metrics_path = metrics_path
        self.retry = retry or RetryPolicy()
        self.sleep_fn = sleep_fn
        self.redispatch = redispatch

    def _restore_or_init(self, mesh):
        state = self.init_state(mesh)
        latest = ckpt.latest_step(self.ckpt_dir)
        if latest is None:
            return state, 0
        shardings = (
            self.state_shardings_fn(state, mesh) if self.state_shardings_fn else None
        )
        state, extras = ckpt.restore(self.ckpt_dir, state, shardings=shardings)
        self.iterator.load_state_dict(extras["iterator"])
        if self.registry is not None:
            # Counters ride the checkpoint like the model state: a crash
            # rolls them back to the restored step, so totals stay exact
            # over any number of failure/restore cycles (no double counts
            # from replayed steps). Lifecycle events (restarts, stragglers)
            # are not replayed — their live values survive the rollback.
            reg = self.registry
            live = reg.counters()
            reg.restore(extras.get("counters", {}))
            for k in ("supervisor/restarts", "supervisor/stragglers"):
                if live.get(k, 0) > reg.get(k):
                    reg.inc(k, live.get(k, 0) - reg.get(k))
        return state, int(extras["step"])

    def run(self, total_steps: int, metrics_cb=None) -> SupervisorReport:
        from contextlib import nullcontext

        from repro.obs import counters as obs
        from repro.obs import report as obs_report

        reg = self.registry
        writer = (
            obs_report.MetricsWriter(self.metrics_path)
            if self.metrics_path
            else None
        )
        install = obs.use_registry(reg) if reg is not None else nullcontext()
        with install:
            try:
                return self._run(total_steps, metrics_cb, reg, writer)
            finally:
                if writer is not None:
                    writer.close()

    def _redispatch(self, step, reg, why: str):
        """Deadline re-dispatch: hand the straggler's step to a backup.

        The backup's (deterministic) execution is the step run the loop
        performs next — same batch, same state, so numerics are unchanged;
        what the policy adds is the *accounting*: the event, its counter,
        and the log line a fleet scheduler would act on.
        """
        self.report.redispatches += 1
        if reg is not None:
            reg.inc("supervisor/redispatches")
        self.report.log.append(
            f"step {step}: {why} — re-dispatched to backup worker"
        )

    def _run(self, total_steps, metrics_cb, reg, writer) -> SupervisorReport:
        mesh_idx = 0
        consecutive_failures = 0
        while True:
            mesh = self.meshes[mesh_idx]
            step_fn = self.make_step(mesh)
            state, step = self._restore_or_init(mesh)
            try:
                while step < total_steps:
                    t0 = time.time()
                    try:
                        self.injector.check(step)
                    except SimulatedStraggler as e:
                        # Straggler != failure: the drop-and-rescale policy
                        # proceeds with the step (over responsive workers).
                        self.report.straggler_events += 1
                        if reg is not None:
                            reg.inc("supervisor/stragglers")
                        self.report.log.append(
                            f"straggler: {e} — continuing (drop-and-rescale)"
                        )
                        if self.redispatch:
                            self._redispatch(step, reg, "straggler detected")
                    batch = next(self.iterator)
                    state, metrics = step_fn(state, batch)
                    dt = time.time() - t0
                    self.straggler.observe(dt)
                    if dt > self.straggler.deadline():
                        self.report.straggler_events += 1
                        if reg is not None:
                            reg.inc("supervisor/stragglers")
                        self.report.log.append(
                            f"step {step}: exceeded deadline ({dt:.2f}s) — "
                            "drop-and-rescale policy would engage"
                        )
                        if self.redispatch:
                            self._redispatch(step, reg, "deadline exceeded")
                    step += 1
                    self.report.steps_run += 1
                    consecutive_failures = 0  # progress resets the backoff
                    if reg is not None:
                        reg.inc("supervisor/steps")
                    if writer is not None:
                        writer.write({
                            "step": step,
                            "wall_s": dt,
                            "metrics": dict(metrics),
                            "counters": reg.totals() if reg is not None else {},
                        })
                    if metrics_cb:
                        metrics_cb(step, metrics)
                    if step % self.ckpt_every == 0 or step == total_steps:
                        extras = {
                            "step": step,
                            "iterator": self.iterator.state_dict(),
                        }
                        if reg is not None:
                            extras["counters"] = reg.snapshot()
                        self.checkpointer.save(step, state, extras=extras)
                self.checkpointer.wait()
                return self.report
            except SimulatedStraggler as e:
                self.report.straggler_events += 1
                self.report.log.append(f"straggler: {e} — continuing (drop-and-rescale)")
                continue
            except (SimulatedFailure, ckpt.CheckpointError) as e:
                self.report.restarts += 1
                if reg is not None:
                    reg.inc("supervisor/restarts")
                consecutive_failures += 1
                if consecutive_failures > self.retry.max_retries:
                    self.report.log.append(
                        f"crash: {e} — giving up after "
                        f"{consecutive_failures - 1} retries"
                    )
                    raise
                # bounded retry: exponential backoff before the restore
                delay = self.retry.delay(consecutive_failures - 1)
                self.report.backoffs.append(delay)
                self.report.log.append(
                    f"crash: {e} — retry {consecutive_failures}/"
                    f"{self.retry.max_retries} after {delay:.2f}s backoff, "
                    "restoring latest checkpoint"
                )
                self.sleep_fn(delay)
                try:
                    self.checkpointer.wait()
                except ckpt.CheckpointError as ce:
                    # the in-flight save is also broken: recovery proceeds
                    # from the last checkpoint that DID land
                    self.report.log.append(f"pending checkpoint failed: {ce}")
                # Elastic policy: after a crash, optionally fail over to the
                # next (smaller) mesh if one is configured.
                if mesh_idx + 1 < len(self.meshes):
                    mesh_idx += 1
                    self.report.remesh_events += 1
                    self.report.log.append(
                        f"re-mesh: continuing on fallback mesh #{mesh_idx}"
                    )
                continue

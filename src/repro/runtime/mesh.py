"""Inter-HMC interconnect model: the mesh's serial links (paper §4.9).

One HMC talks to its four neighbours over 60 GB/s serial links; a weight
update crosses the mesh as four directional systolic passes (reduce then
broadcast along each axis), eqs. (14)-(15):

    t_pass   = W / LINK_BW + n_side * HOP_LATENCY                   (14)
    t_update = 4 * t_pass                                           (15)

This module keeps the link layer explicit instead of closed-form:

  * :class:`MeshInterconnect` — the RxC mesh of directed links with an
    event-level :meth:`schedule`: transfers on the same link serialize
    (ring-step congestion), disjoint links run concurrently, every hop
    pays the cube-traversal latency. The systolic update and the chunked
    ring allreduce are both built on it; on a congestion-free embedding
    the systolic pass lands exactly on eq. (14), which is what keeps the
    executed mesh efficiencies within a hair of ``ntx_model.mesh``.
  * :func:`time_mesh_step` — one executed+timed mesh training step: the
    per-HMC shard program (from
    :func:`repro.lower.mesh.shard_training_step`) goes through the
    block-replicated timing engine
    (:meth:`~repro.runtime.scheduler.MultiClusterScheduler.schedule_program`
    -> ``simulate_offload_blocks``), the gradient/weight exchange through
    the link schedule.

Calibration constants are numerically identical to
``benchmarks/ntx_model.py`` (a test pins them); duplicated here because
``src/`` never imports ``benchmarks/``.
"""

from __future__ import annotations

from dataclasses import dataclass, field

# §4.9 link calibration — pinned against benchmarks/ntx_model.py by a test.
LINK_BW = 60e9  # B/s per serial link
HOP_LATENCY = 20e-6  # s per cube traversal (conservative)
CUBE_POWER_MESH = 21.0  # W assumed during mesh compute
P_LINKS = 8.0  # W, all four serial links

#: One HMC's DRAM capacity (§2: 4 GB cube) — the budget a workload's
#: whole-step footprint is checked against to decide whether it *needs*
#: model sharding (the 2D bench gates that its big case exceeds this).
HMC_DRAM_BYTES = 4 * 2**30


@dataclass(frozen=True)
class LinkTransfer:
    """One point-to-point transfer over a single mesh link."""

    link: tuple[tuple[int, int], tuple[int, int]]  # ((r, c) -> (r, c))
    num_bytes: float
    start: float = 0.0
    tag: str = ""


@dataclass(frozen=True)
class ScheduledTransfer:
    transfer: LinkTransfer
    t0: float
    t1: float

    @property
    def queued(self) -> float:
        """Time spent waiting for the link (congestion)."""
        return self.t0 - self.transfer.start


@dataclass
class LinkSchedule:
    transfers: list[ScheduledTransfer] = field(default_factory=list)

    @property
    def makespan(self) -> float:
        return max((t.t1 for t in self.transfers), default=0.0)

    @property
    def congestion_time(self) -> float:
        return sum(t.queued for t in self.transfers)


class MeshInterconnect:
    """An RxC mesh of HMCs joined by directed nearest-neighbour links.

    ``failed`` marks dead cubes (flat row-major ids or (r, c) coords): a
    dead cube's serial links die with it, so transfers touching it are
    rejected, the systolic update is unavailable, and the degraded mesh
    falls back to a survivor ring that routes *around* the holes
    (:meth:`ring_allreduce`).
    """

    def __init__(self, rows: int, cols: int, *,
                 link_bw: float = LINK_BW, hop_latency: float = HOP_LATENCY,
                 failed=()):
        if rows < 1 or cols < 1:
            raise ValueError(f"degenerate mesh {rows}x{cols}")
        self.rows = rows
        self.cols = cols
        self.link_bw = link_bw
        self.hop_latency = hop_latency
        self.failed: set[tuple[int, int]] = set()
        for node in failed:
            self.fail(node)

    @property
    def n_hmcs(self) -> int:
        return self.rows * self.cols

    def _coord(self, node) -> tuple[int, int]:
        """Flat row-major cube id -> (r, c); coords pass through."""
        if isinstance(node, tuple):
            return node
        return divmod(int(node), self.cols)

    def fail(self, node) -> None:
        """Mark a cube dead (flat id or (r, c)); its four links die too."""
        r, c = self._coord(node)
        if not (0 <= r < self.rows and 0 <= c < self.cols):
            raise ValueError(f"node {(r, c)} outside {self.rows}x{self.cols}")
        self.failed.add((r, c))

    @property
    def alive_nodes(self) -> list[tuple[int, int]]:
        return [(r, c) for r in range(self.rows) for c in range(self.cols)
                if (r, c) not in self.failed]

    def _check_link(self, link) -> None:
        (r0, c0), (r1, c1) = link
        for r, c in ((r0, c0), (r1, c1)):
            if not (0 <= r < self.rows and 0 <= c < self.cols):
                raise ValueError(f"node {(r, c)} outside {self.rows}x{self.cols}")
            if (r, c) in self.failed:
                raise ValueError(f"link {link} touches failed cube {(r, c)}")
        if abs(r0 - r1) + abs(c0 - c1) != 1:
            raise ValueError(f"{link} is not a nearest-neighbour link")

    def transfer_time(self, num_bytes: float) -> float:
        """Wire time of one transfer on one link, excluding the hop."""
        return num_bytes / self.link_bw

    # -- the event-level link scheduler -------------------------------------

    def schedule(self, transfers: list[LinkTransfer]) -> LinkSchedule:
        """Serialize per link, run links concurrently, charge one hop each.

        Transfers are served per link in submission order once their
        ``start`` time arrives — a transfer finding its link busy queues
        behind the one in flight (ring-step congestion). Completion is
        ``begin + hop_latency + bytes / link_bw`` (cut-through: the hop is
        the first-word latency, the stream follows at the wire rate).
        """
        busy: dict[tuple, float] = {}
        out = LinkSchedule()
        for tr in transfers:
            self._check_link(tr.link)
            t0 = max(tr.start, busy.get(tr.link, 0.0))
            t1 = t0 + self.hop_latency + self.transfer_time(tr.num_bytes)
            busy[tr.link] = t1
            out.transfers.append(ScheduledTransfer(tr, t0, t1))
        return out

    # -- the paper's systolic weight update (eqs. 14-15) ---------------------

    def _pass_transfers(self, num_bytes: float, axis: int, reverse: bool,
                        t0: float, tag: str) -> list[LinkTransfer]:
        """One directional pass: every line of the mesh pipelines the full
        array across its links, cut-through (link ``i`` starts one hop
        after link ``i-1``, streaming concurrently). The last link of a
        length-L line completes at ``t0 + L * hop + bytes / bw`` — eq. (14)
        with that axis's extent as n_side.
        """
        out = []
        n_lines = self.cols if axis == 0 else self.rows
        length = self.rows if axis == 0 else self.cols
        hops = range(length - 1)
        for line in range(n_lines):
            for i, h in enumerate(reversed(hops) if reverse else hops):
                if axis == 0:
                    a, b = (h, line), (h + 1, line)
                else:
                    a, b = (line, h), (line, h + 1)
                if reverse:
                    a, b = b, a
                out.append(LinkTransfer(
                    link=(a, b), num_bytes=num_bytes,
                    start=t0 + (i + 1) * self.hop_latency,
                    tag=f"{tag}:line{line}",
                ))
        return out

    def systolic_update(self, weight_bytes: float) -> LinkSchedule:
        """The 4-pass weight exchange: reduce then broadcast along each
        axis, each pass streaming the full W bytes down every line.

        On the congestion-free line embedding each pass takes
        ``W / link_bw + L * hop_latency`` — eq. (14) with the axis extent
        as n_side — and the passes serialize, so a square mesh lands
        exactly on eq. (15); degenerate axes (extent 1) contribute no
        pass. The schedule is built from individual
        :class:`LinkTransfer`s, so a different embedding (or a busy mesh)
        shows up as congestion, not as a changed formula.
        """
        if self.failed:
            raise ValueError(
                "systolic update needs every line intact; a degraded mesh "
                "allreduces over the survivor ring (ring_allreduce)"
            )
        transfers: list[LinkTransfer] = []
        t0 = 0.0
        for axis, reverse, tag in ((0, False, "reduce_v"), (1, False, "reduce_h"),
                                   (1, True, "bcast_h"), (0, True, "bcast_v")):
            length = self.rows if axis == 0 else self.cols
            if length < 2:
                continue
            transfers += self._pass_transfers(weight_bytes, axis, reverse, t0, tag)
            t0 += self.transfer_time(weight_bytes) + length * self.hop_latency
        return self.schedule(transfers)

    def update_time(self, weight_bytes: float) -> float:
        """The weight-exchange time: eq. (15) systolic on a healthy mesh,
        the survivor-ring allreduce once any cube has failed."""
        if len(self.alive_nodes) <= 1:
            return 0.0
        if self.failed:
            return self.ring_allreduce(weight_bytes).makespan
        return self.systolic_update(weight_bytes).makespan

    # -- the chunked ring alternative ----------------------------------------

    def ring_allreduce(self, num_bytes: float) -> LinkSchedule:
        """Reduce-scatter + allgather over a boustrophedon ring embedding.

        2(n-1) steps, each moving ``num_bytes / n`` per node; the snake
        embedding uses every mesh link at most once per direction, so the
        steps themselves are congestion-free and the schedule time is
        ``2 (n-1) (num_bytes / (n * link_bw) + hop)``.

        On a degraded mesh the ring is the *survivor* snake: dead cubes
        drop out, and ring edges whose snake neighbours are no longer
        adjacent route store-and-forward around the holes (BFS over alive
        cubes) — recovery cost appears as extra hops and congestion, not a
        changed formula.
        """
        nodes = self._snake_nodes()
        n = len(nodes)
        if n <= 1:
            return LinkSchedule()
        chunk = num_bytes / n
        transfers = []
        t0 = 0.0
        step_t = self.transfer_time(chunk) + self.hop_latency
        for step in range(2 * (n - 1)):
            phase = "reduce" if step < n - 1 else "gather"
            for i in range(n):
                a, b = nodes[i], nodes[(i + 1) % n]
                if abs(a[0] - b[0]) + abs(a[1] - b[1]) != 1:
                    # the ring's wrap edge (or a hole the snake skips) is
                    # not a mesh link: route it store-and-forward through
                    # intermediate cubes (hop j starts once hop j-1
                    # delivered). The detour's latency stretches the ring
                    # past the single-hop floor, and on a busy mesh its
                    # links queue like any other transfer.
                    path = self._route_around(a, b)
                    for hop_i, (u, v) in enumerate(zip(path, path[1:])):
                        transfers.append(LinkTransfer(
                            (u, v), chunk,
                            t0 + hop_i * (self.transfer_time(chunk)
                                          + self.hop_latency),
                            f"ring:{phase}{step}",
                        ))
                else:
                    transfers.append(LinkTransfer((a, b), chunk, t0,
                                                  f"ring:{phase}{step}"))
            t0 += step_t
        return self.schedule(transfers)

    def ring_allreduce_time(self, num_bytes: float) -> float:
        return self.ring_allreduce(num_bytes).makespan

    def _snake_nodes(self) -> list[tuple[int, int]]:
        """The boustrophedon ring order, dead cubes skipped."""
        nodes = []
        for r in range(self.rows):
            cs = range(self.cols) if r % 2 == 0 else range(self.cols - 1, -1, -1)
            nodes += [(r, c) for c in cs if (r, c) not in self.failed]
        return nodes

    def _route_around(self, a: tuple[int, int], b: tuple[int, int]
                      ) -> list[tuple[int, int]]:
        """A multi-hop path from ``a`` to ``b`` avoiding failed cubes.

        Dimension-ordered (row-first) when that path is clear — identical
        to the healthy wrap route — else shortest path by BFS over the
        survivors. Raises when the failures partition the mesh.
        """
        path = _route(a, b)
        if not self.failed or all(p not in self.failed for p in path):
            return path
        from collections import deque

        prev: dict[tuple[int, int], tuple[int, int] | None] = {a: None}
        q = deque([a])
        while q:
            u = q.popleft()
            if u == b:
                break
            r, c = u
            for v in ((r + 1, c), (r - 1, c), (r, c + 1), (r, c - 1)):
                if (0 <= v[0] < self.rows and 0 <= v[1] < self.cols
                        and v not in self.failed and v not in prev):
                    prev[v] = u
                    q.append(v)
        if b not in prev:
            raise ValueError(
                f"mesh partitioned: no route {a}->{b} around failed cubes "
                f"{sorted(self.failed)}"
            )
        out = [b]
        while out[-1] != a:
            out.append(prev[out[-1]])
        return out[::-1]


def _route(a: tuple[int, int], b: tuple[int, int]) -> list[tuple[int, int]]:
    """Dimension-ordered (row-first) path between two mesh nodes."""
    path = [a]
    r, c = a
    while r != b[0]:
        r += 1 if b[0] > r else -1
        path.append((r, c))
    while c != b[1]:
        c += 1 if b[1] > c else -1
        path.append((r, c))
    return path


def _partition_coarse(program, parts: int):
    """§3.1 refinement of only the *coarse* blocks of ``program``.

    Blocks with fewer than ``parts`` commands (single-command whole-batch
    relus, spill/fill blits, the reduce-scatter chunks) cannot spread over
    all clusters x engines and would pin one cluster with a multi-second
    command; blocks already streaming thousands of replicas balance on
    their own and are left untouched — full :func:`partition_program`
    would multiply the block count by ``parts`` for no balance gain.
    """
    from repro.lower.ir import NtxProgram
    from repro.lower.mesh import split_block_template

    new_blocks = []
    for b in program.blocks:
        if b.n_commands >= parts:
            new_blocks.append(b)
            continue
        want = -(-parts // b.n_commands)  # ceil: pieces x replicas >= parts
        new_blocks.extend(split_block_template(b, want))
    return NtxProgram(
        name=f"{program.name}:coarse{parts}",
        blocks=new_blocks,
        regions=program.regions,
        design=program.design,
        meta={**program.meta, "partitioned_coarse": parts},
    )


# ---------------------------------------------------------------------------
# One executed + timed mesh training step
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MeshStepTiming:
    """Timing of one data-parallel training step on a mesh of HMCs."""

    mesh_shape: tuple[int, int]
    n_hmcs: int
    batch: int
    t_shard: float  # s: one cube's shard program (compute + spill DMA)
    t_update: float  # s: the link exchange (eq. 15, or survivor ring)
    t_single: float  # s: the unsharded step on one cube
    shard_cycles: int
    single_cycles: int
    link_congestion: float  # s queued on busy links during the update
    alive_hmcs: int = 0  # surviving cubes; 0 = every cube healthy

    @property
    def n_alive(self) -> int:
        return self.alive_hmcs or self.n_hmcs

    @property
    def t_step(self) -> float:
        return self.t_shard + self.t_update

    @property
    def speedup(self) -> float:
        return self.t_single / self.t_step

    @property
    def parallel_eff(self) -> float:
        """Speedup per *surviving* cube — how well the survivors are used."""
        return self.speedup / self.n_alive

    @property
    def t_image(self) -> float:
        """Per-image time of the single-cube baseline (eq. 16's t_image)."""
        return self.t_single / self.batch

    def summary(self) -> dict:
        return {
            "mesh": f"{self.mesh_shape[0]}x{self.mesh_shape[1]}",
            "n_hmcs": self.n_hmcs,
            "n_alive": self.n_alive,
            "batch": self.batch,
            "t_shard_ms": self.t_shard * 1e3,
            "t_update_ms": self.t_update * 1e3,
            "t_step_ms": self.t_step * 1e3,
            "t_single_ms": self.t_single * 1e3,
            "speedup": self.speedup,
            "parallel_eff": self.parallel_eff,
            "link_congestion_ms": self.link_congestion * 1e3,
        }


def time_mesh_step(
    sharded,
    *,
    n_clusters: int = 16,
    f_ntx: float = 1.5e9,
    derate: bool = True,
    engine: str = "block",
    partition: bool = True,
    single_result=None,
) -> MeshStepTiming:
    """Time one mesh step: shard program on the block engine + link exchange.

    ``sharded`` is a :class:`repro.lower.mesh.ShardedTrainStep`. Every cube
    runs a structurally identical shard, so HMC 0's program stands for all;
    the weight exchange is the eq.-(15) systolic update over the program's
    actual parameter bytes. ``derate=True`` applies the calibrated
    eta_c * eta_net compute derating exactly like ``benchmarks.ntx_model``
    (and the ``mesh_sweep`` benchmark); ``partition=True`` first refines
    both programs with :func:`~repro.runtime.scheduler.partition_program`
    (§3.1 tiling) so single-command blocks — whole-batch relus, spill
    blits — spread over all clusters x engines instead of pinning one
    cluster. ``single_result`` optionally reuses an already-timed unsharded
    ScheduleResult (callers sweeping mesh sizes at a fixed batch share it).

    2D-sharded programs delegate to :func:`time_mesh_step_2d` (GPipe
    fill/drain + per-row exchange), so callers can hand either layout to
    this one entry point.
    """
    if sharded.program.meta.get("mesh", {}).get("shard") == "2d":
        return time_mesh_step_2d(
            sharded, n_clusters=n_clusters, f_ntx=f_ntx, derate=derate,
            engine=engine, partition=partition, single_result=single_result,
        )
    from repro.runtime import scheduler as rt_sched

    eta = rt_sched.ETA_COMPUTE * rt_sched.ETA_NET
    exec_cycles = (lambda c: c.busy_cycles / eta) if derate else None
    parts = n_clusters * rt_sched.ENGINES_PER_CLUSTER

    def timed(program):
        if partition:
            program = _partition_coarse(program, parts)
        sched = rt_sched.MultiClusterScheduler(
            n_clusters=n_clusters, f_ntx=f_ntx
        )
        return sched.schedule_program(program, engine=engine,
                                      exec_cycles=exec_cycles)

    shard_res = timed(sharded.shard_program(sharded.alive_hmcs[0]))
    if single_result is None:
        single_result = timed(sharded.base_program)
    rows, cols = sharded.mesh_shape
    net = MeshInterconnect(rows, cols, failed=sharded.failed_hmcs)
    if sharded.n_alive > 1:
        # a degraded mesh can't run the systolic lines through a dead
        # cube: the survivors fall back to the hole-routing ring
        upd = (net.ring_allreduce(sharded.allreduce_bytes)
               if sharded.failed_hmcs
               else net.systolic_update(sharded.allreduce_bytes))
        t_update, congestion = upd.makespan, upd.congestion_time
        from repro.obs import counters as obs

        obs.record_link_schedule(obs.get_active(), upd)
    else:
        t_update, congestion = 0.0, 0.0
    return MeshStepTiming(
        mesh_shape=sharded.mesh_shape,
        n_hmcs=sharded.n_hmcs,
        batch=sharded.graph.batch,
        t_shard=shard_res.total_cycles / f_ntx,
        t_update=t_update,
        t_single=single_result.total_cycles / f_ntx,
        shard_cycles=shard_res.total_cycles,
        single_cycles=single_result.total_cycles,
        link_congestion=congestion,
        alive_hmcs=sharded.n_alive,
    )


@dataclass(frozen=True)
class MeshStepTiming2D:
    """Timing of one 2D-sharded (pipeline x tensor/data) mesh step.

    Duck-types :class:`MeshStepTiming`'s derived metrics (``t_step`` /
    ``speedup`` / ``parallel_eff`` / ``t_image`` / ``summary``) so the
    training CLI and the benches consume either. ``parallel_eff`` is
    measured against perfect scaling of the interconnect-model baseline:
    ``t_single / (t_step * n_alive)``.
    """

    mesh_shape: tuple[int, int]
    n_hmcs: int
    batch: int
    n_micro: int  # GPipe microbatches in the fill/drain schedule
    row_times: tuple[float, ...]  # s: full-batch shard per pipeline row
    t_compute: float  # s: pipeline makespan (fill + steady + drain)
    t_boundary: float  # s: vertical-link send/recv schedule makespan
    t_update: float  # s: per-row weight exchange (2 passes over row links)
    t_single: float  # s: the unsharded step on one cube
    bubble_frac: float  # idle fraction of total stage-time
    shard_cycles: int  # sum of the per-row representative shard cycles
    single_cycles: int
    link_congestion: float  # s queued on busy links (boundary + update)
    alive_hmcs: int = 0

    @property
    def n_alive(self) -> int:
        return self.alive_hmcs or self.n_hmcs

    @property
    def t_shard(self) -> float:
        """The slowest row's full-batch shard time (bottleneck stage)."""
        return max(self.row_times)

    @property
    def t_step(self) -> float:
        # boundary transfers overlap the fill/drain compute; the weight
        # exchange serializes after the drain, exactly like the 1D model
        return max(self.t_compute, self.t_boundary) + self.t_update

    @property
    def speedup(self) -> float:
        return self.t_single / self.t_step

    @property
    def parallel_eff(self) -> float:
        return self.speedup / self.n_alive

    @property
    def t_image(self) -> float:
        return self.t_single / self.batch

    def summary(self) -> dict:
        return {
            "mesh": f"{self.mesh_shape[0]}x{self.mesh_shape[1]}",
            "n_hmcs": self.n_hmcs,
            "n_alive": self.n_alive,
            "batch": self.batch,
            "n_micro": self.n_micro,
            "row_times_ms": [t * 1e3 for t in self.row_times],
            "t_compute_ms": self.t_compute * 1e3,
            "t_boundary_ms": self.t_boundary * 1e3,
            "t_update_ms": self.t_update * 1e3,
            "t_step_ms": self.t_step * 1e3,
            "t_single_ms": self.t_single * 1e3,
            "bubble_frac": self.bubble_frac,
            "speedup": self.speedup,
            "parallel_eff": self.parallel_eff,
            "link_congestion_ms": self.link_congestion * 1e3,
        }


def _row_update_transfers(
    net: MeshInterconnect, row: int, columns: tuple[int, ...], weight_bytes: float
) -> list[LinkTransfer]:
    """The 2-pass (reduce + broadcast) weight exchange of one pipeline row.

    The row's stage parameters never leave the row, so the exchange is
    eq. (14) along the row's horizontal links only — cut-through down the
    line of *surviving* columns, then back. Consecutive survivors that
    are no longer adjacent (a dead cube inside the tensor group) route
    store-and-forward around the hole, exactly like the degraded ring.
    Different rows use disjoint links, so one schedule over all rows
    overlaps them.
    """
    if len(columns) < 2 or weight_bytes <= 0:
        return []
    coords = [(row, c) for c in columns]
    transfers: list[LinkTransfer] = []
    t0 = 0.0
    for reverse, tag in ((False, "rowreduce"), (True, "rowbcast")):
        hops = list(zip(coords, coords[1:]))
        if reverse:
            hops = [(b, a) for a, b in reversed(hops)]
        i = 0
        for a, b in hops:
            path = net._route_around(a, b)
            for u, v in zip(path, path[1:]):
                transfers.append(LinkTransfer(
                    link=(u, v), num_bytes=weight_bytes,
                    start=t0 + (i + 1) * net.hop_latency,
                    tag=f"{tag}:row{row}",
                ))
                i += 1
        t0 += net.transfer_time(weight_bytes) + (i + 1) * net.hop_latency
    return transfers


def time_mesh_step_2d(
    sharded,
    *,
    n_clusters: int = 16,
    f_ntx: float = 1.5e9,
    derate: bool = True,
    engine: str = "block",
    partition: bool = True,
    single_result=None,
) -> MeshStepTiming2D:
    """Time one 2D-sharded mesh step: GPipe rows + event-level link traffic.

    Per pipeline row the representative surviving cube's shard program is
    timed on the block engine (full batch — every column of a row is
    structurally symmetric, like the 1D model). With per-row full-batch
    times ``t_r`` and ``M`` microbatches, the non-interleaved GPipe
    fill/drain makespan is::

        t_compute = sum_r t_r / M  +  (M - 1) * max_r t_r / M

    (each microbatch visits every stage once — the merged fwd+bwd visit —
    and the steady state is paced by the slowest stage; at R = 1 this
    reduces to the 1D shard time, and for balanced stages the overhead is
    the textbook ``(R - 1) / (M + R - 1)`` bubble). Stage-boundary
    activations/gradients become per-microbatch vertical-link transfers
    (one chunk per column pair, timed by :meth:`MeshInterconnect.schedule`
    — congestion shows up, fwd and bwd use opposite link directions); the
    per-row weight exchange runs 2 passes over each row's horizontal
    links with that *row's* parameter bytes, all rows concurrent.
    """
    from repro.runtime import scheduler as rt_sched

    meta = sharded.program.meta["mesh"]
    pmeta = meta["pipeline"]
    rows, cols = sharded.mesh_shape
    n_micro = int(pmeta["n_micro"])
    row_owners = [tuple(ro) for ro in meta["row_owners"]]

    eta = rt_sched.ETA_COMPUTE * rt_sched.ETA_NET
    exec_cycles = (lambda c: c.busy_cycles / eta) if derate else None
    parts = n_clusters * rt_sched.ENGINES_PER_CLUSTER

    def timed(program):
        if partition:
            program = _partition_coarse(program, parts)
        sched = rt_sched.MultiClusterScheduler(n_clusters=n_clusters, f_ntx=f_ntx)
        return sched.schedule_program(program, engine=engine, exec_cycles=exec_cycles)

    row_results = [timed(sharded.shard_program(ro[0])) for ro in row_owners]
    if single_result is None:
        single_result = timed(sharded.base_program)
    row_times = tuple(res.total_cycles / f_ntx for res in row_results)
    tau = [t / n_micro for t in row_times]
    tau_max = max(tau)
    t_compute = sum(tau) + (n_micro - 1) * tau_max
    bubble_frac = 1.0 - sum(row_times) / (rows * t_compute) if t_compute else 0.0

    net = MeshInterconnect(rows, cols, failed=sharded.failed_hmcs)
    alive = set(sharded.alive_hmcs)

    # stage-boundary traffic: one chunk per (microbatch, column pair) on
    # the vertical links, paced by the steady-state microbatch cadence
    boundary: list[LinkTransfer] = []
    for x in pmeta["xfers"]:
        src, dst = int(x["src"]), int(x["dst"])
        pair_cols = [
            c for c in range(cols)
            if src * cols + c in alive and dst * cols + c in alive
        ]
        if pair_cols:
            chunk = float(x["bytes"]) / (len(pair_cols) * n_micro)
            for m in range(n_micro):
                for c in pair_cols:
                    boundary.append(LinkTransfer(
                        link=((src, c), (dst, c)), num_bytes=chunk,
                        start=m * tau_max, tag=f"pipe:{x['region']}",
                    ))
        else:
            # pathological degradation: no straight column pair survives;
            # route the whole tensor between the rows' first survivors
            a = net._coord(row_owners[src][0])
            b = net._coord(row_owners[dst][0])
            path = net._route_around(a, b)
            chunk = float(x["bytes"]) / n_micro
            for m in range(n_micro):
                for u, v in zip(path, path[1:]):
                    boundary.append(LinkTransfer(
                        link=(u, v), num_bytes=chunk,
                        start=m * tau_max, tag=f"pipe:{x['region']}",
                    ))
    bsched = net.schedule(boundary)

    upd_transfers: list[LinkTransfer] = []
    for r, ro in enumerate(row_owners):
        columns = tuple(net._coord(h)[1] for h in ro)
        upd_transfers += _row_update_transfers(
            net, r, columns, float(pmeta["stage_param_bytes"][r])
        )
    usched = net.schedule(upd_transfers)

    from repro.obs import counters as obs

    reg = obs.get_active()
    obs.record_link_schedule(reg, bsched)
    obs.record_link_schedule(reg, usched)

    return MeshStepTiming2D(
        mesh_shape=sharded.mesh_shape,
        n_hmcs=sharded.n_hmcs,
        batch=sharded.graph.batch,
        n_micro=n_micro,
        row_times=row_times,
        t_compute=t_compute,
        t_boundary=bsched.makespan,
        t_update=usched.makespan,
        t_single=single_result.total_cycles / f_ntx,
        bubble_frac=bubble_frac,
        shard_cycles=sum(res.total_cycles for res in row_results),
        single_cycles=single_result.total_cycles,
        link_congestion=bsched.congestion_time + usched.congestion_time,
        alive_hmcs=sharded.n_alive,
    )


def expected_update_time(weight_bytes: float, rows: int, cols: int) -> float:
    """The closed-form value the link schedule must reproduce.

    Two passes (reduce + broadcast) per non-degenerate axis, each eq. (14)
    with that axis's extent as n_side — on a square mesh exactly eq. (15),
    ``4 (W / LINK_BW + n_side * HOP)``; on a rectangle the shorter axis
    pays its own (smaller) hop count.
    """
    total = 0.0
    for length in (rows, cols):
        if length > 1:
            total += 2.0 * (weight_bytes / LINK_BW + length * HOP_LATENCY)
    return total

"""Offload runtime: command queues, DMA streaming, multi-cluster scheduling.

The asynchronous near-memory offload subsystem (paper §2.2/§3.1):

- :mod:`repro.runtime.cmdqueue`  — per-engine command FIFOs with depth,
  back-pressure and issue/retire timestamps; one driver feeding 8 NTX.
- :mod:`repro.runtime.dma`       — double-buffered cluster DMA with TCDM bank
  conflicts and the shared HMC vault bandwidth cap.
- :mod:`repro.runtime.scheduler` — loop-nest partitioning across clusters,
  queue feeding, chrome-trace timelines, and the event-driven counterpart of
  the analytical model in ``benchmarks/ntx_model.py``.
- :mod:`repro.runtime.mesh`      — the inter-HMC serial-link layer (§4.9):
  per-link transfer scheduling with congestion, the 4-pass systolic weight
  update (eqs. 14-15), failed-cube degradation (survivor-ring allreduce
  routing around dead cubes), and :func:`~repro.runtime.mesh.time_mesh_step`
  over sharded train-step programs.
- :mod:`repro.runtime.faults`    — deterministic fault injection: scripted
  and seeded chaos schedules, bounded-retry backoff, modeled recovery cost
  (:func:`~repro.runtime.faults.time_recovery`) and the train-loop
  :class:`~repro.runtime.faults.ChaosController`.
- :mod:`repro.runtime.supervisor` — fault-tolerant training supervisor
  (imported lazily: it pulls in jax).
"""

from repro.runtime import cmdqueue, dma, faults, mesh, scheduler  # noqa: F401

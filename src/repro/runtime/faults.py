"""Deterministic, seedable fault injection for the mesh of HMCs.

A production mesh loses cubes and suffers stragglers; the paper's scaling
story (§4.9) assumes neither. This module supplies the missing failure
model, kept strictly deterministic so every chaos run is replayable:

  * :class:`FaultEvent` / :class:`ChaosSchedule` — *what* fails and
    *when*. Scripted specs name exact events
    (``"kill:hmc=1@step=2"``); seeded specs
    (``"random:seed=7,p_kill=0.02"``) draw per-(seed, step, cube)
    Bernoulli faults from a counter-keyed RNG, so the same seed replays
    the same fault history regardless of how the mesh is swept.
  * :class:`RetryPolicy` — bounded retry with exponential backoff, the
    schedule the supervisor sleeps between restore attempts.
  * :class:`RecoveryTiming` / :func:`time_recovery` — the *modeled* cost
    of surviving a kill: detection (the weight exchange that never
    completes), parameter re-load, and the replayed step on the degraded
    mesh, in the same cycle currency as
    :func:`repro.runtime.mesh.time_mesh_step`.
  * :class:`ChaosController` — the train-loop hook
    (:func:`repro.lower.graph.train_graph`'s ``chaos=``): it intercepts
    each executed step BEFORE its outputs commit, so a killed cube's
    step is discarded, the program re-shards onto the survivors
    (:func:`repro.lower.mesh.reshard_training_step`), and the same step
    replays — gradients stay bit-identical to the healthy run under the
    reference executor because no partial results ever commit.

The model layer here is numpy/jax-free; the controller imports the
checkpoint store lazily.
"""

from __future__ import annotations

import re
from dataclasses import dataclass

KINDS = ("kill", "straggle", "preempt")

_EVENT_RE = re.compile(
    r"^(?P<kind>kill|straggle|preempt)"
    r"(?::(?P<params>[a-z0-9_=.,]+))?"
    r"@step=(?P<step>\d+)$"
)


@dataclass(frozen=True)
class FaultEvent:
    """One injected fault: ``kind`` at ``step``, targeting cube ``hmc``.

    ``hmc`` is a flat row-major cube id for kill/straggle and ``None``
    for a whole-job preemption; ``slow`` is the straggler's slowdown
    factor (its step takes ``slow`` times longer than its peers').
    """

    step: int
    kind: str
    hmc: int | None = None
    slow: float = 4.0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} (want {KINDS})")

    def describe(self) -> str:
        target = "job" if self.hmc is None else f"hmc{self.hmc}"
        extra = f" x{self.slow:g}" if self.kind == "straggle" else ""
        return f"{self.kind}:{target}@step{self.step}{extra}"


class ChaosSchedule:
    """A replayable fault schedule, scripted or seeded-random.

    Scripted grammar (events joined by ``;``)::

        kill:hmc=1@step=2
        straggle:hmc=0,slow=4@step=3
        preempt@step=5

    Seeded grammar::

        random:seed=7,p_kill=0.02,p_straggle=0.05,slow=4,max_kills=1

    draws one Bernoulli per (cube, step) from an RNG keyed on
    ``(seed, step, hmc)`` — the same seed yields the same fault history
    for any query order, and ``max_kills`` caps total cube deaths so a
    long run cannot chew through the whole mesh.
    """

    def __init__(self, events: list[FaultEvent] | None = None, *,
                 seed: int | None = None, p_kill: float = 0.0,
                 p_straggle: float = 0.0, slow: float = 4.0,
                 max_kills: int = 1):
        self.events = tuple(events or ())
        self.seed = seed
        self.p_kill = p_kill
        self.p_straggle = p_straggle
        self.slow = slow
        self.max_kills = max_kills
        self._kills_emitted = 0
        self._fired: set[tuple[int, str, int | None]] = set()

    @classmethod
    def parse(cls, spec: str) -> "ChaosSchedule":
        """Parse a ``--chaos`` spec (see class docstring for the grammar)."""
        spec = spec.strip().lower()
        if not spec or spec == "none":
            return cls()
        if spec.startswith("random:"):
            kw: dict = {}
            for tok in spec[len("random:"):].split(","):
                k, _, v = tok.partition("=")
                if k in ("seed", "max_kills"):
                    kw[k] = int(v)
                elif k in ("p_kill", "p_straggle", "slow"):
                    kw[k] = float(v)
                else:
                    raise ValueError(f"unknown random-chaos key {k!r} in {spec!r}")
            if kw.get("seed") is None:
                raise ValueError(f"random chaos spec needs seed=: {spec!r}")
            return cls(**kw)
        events = []
        for part in spec.split(";"):
            part = part.strip()
            if not part:
                continue
            m = _EVENT_RE.match(part)
            if m is None:
                raise ValueError(
                    f"bad chaos event {part!r} "
                    "(want e.g. 'kill:hmc=1@step=2' or 'preempt@step=5')"
                )
            hmc, slow = None, 4.0
            for tok in filter(None, (m.group("params") or "").split(",")):
                k, _, v = tok.partition("=")
                if k == "hmc":
                    hmc = int(v)
                elif k == "slow":
                    slow = float(v)
                else:
                    raise ValueError(f"unknown chaos param {k!r} in {part!r}")
            kind = m.group("kind")
            if kind != "preempt" and hmc is None:
                raise ValueError(f"{kind!r} event needs hmc=: {part!r}")
            events.append(FaultEvent(int(m.group("step")), kind, hmc, slow))
        return cls(sorted(events, key=lambda e: e.step))

    def events_at(self, step: int, n_hmcs: int) -> list[FaultEvent]:
        """The faults firing at ``step``; each scripted event fires once."""
        out = []
        for e in self.events:
            key = (e.step, e.kind, e.hmc)
            if e.step == step and key not in self._fired:
                self._fired.add(key)
                out.append(e)
        if self.seed is not None and (self.p_kill or self.p_straggle):
            import numpy as np

            for h in range(n_hmcs):
                u = np.random.default_rng((self.seed, step, h)).random()
                if u < self.p_kill:
                    if self._kills_emitted < self.max_kills:
                        self._kills_emitted += 1
                        out.append(FaultEvent(step, "kill", h))
                elif u < self.p_kill + self.p_straggle:
                    out.append(FaultEvent(step, "straggle", h, self.slow))
        return out

    def __bool__(self) -> bool:
        return bool(self.events) or (
            self.seed is not None and bool(self.p_kill or self.p_straggle)
        )


@dataclass(frozen=True)
class RetryPolicy:
    """Bounded retry with exponential backoff (deterministic, no jitter).

    ``delay(attempt)`` for attempt = 0, 1, 2, ... is
    ``min(base_delay * factor**attempt, max_delay)``; after
    ``max_retries`` consecutive failures the caller gives up and
    re-raises. Deterministic so tests can pin the whole schedule.
    """

    max_retries: int = 3
    base_delay: float = 0.5
    factor: float = 2.0
    max_delay: float = 30.0

    def delay(self, attempt: int) -> float:
        if attempt < 0:
            raise ValueError(f"attempt {attempt} < 0")
        return min(self.base_delay * self.factor ** attempt, self.max_delay)

    def delays(self) -> list[float]:
        """The full backoff schedule, one delay per permitted retry."""
        return [self.delay(a) for a in range(self.max_retries)]


# ---------------------------------------------------------------------------
# Modeled recovery cost
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RecoveryTiming:
    """The modeled cost of surviving one cube kill, in seconds.

    ``t_detect``: the weight exchange that never completes — survivors
    notice the dead cube after one healthy update-time deadline.
    ``t_restore``: streaming the full parameter set back out to the
    survivors (one broadcast over the degraded ring).
    ``t_replay``: the discarded step re-executed on the degraded mesh.
    """

    t_detect: float
    t_restore: float
    t_replay: float
    healthy_step: float  # s, the steady-state healthy step (overhead basis)
    degraded_step: float  # s, the steady-state degraded step

    @property
    def t_total(self) -> float:
        return self.t_detect + self.t_restore + self.t_replay

    def cycles(self, f_ntx: float = 1.5e9) -> int:
        return int(round(self.t_total * f_ntx))

    @property
    def overhead_steps(self) -> float:
        """Recovery cost in units of healthy steps (the bench gate)."""
        return self.t_total / self.healthy_step

    def summary(self) -> dict:
        return {
            "t_detect_ms": self.t_detect * 1e3,
            "t_restore_ms": self.t_restore * 1e3,
            "t_replay_ms": self.t_replay * 1e3,
            "t_total_ms": self.t_total * 1e3,
            "recovery_cycles": self.cycles(),
            "overhead_steps": self.overhead_steps,
        }


def time_recovery(healthy, degraded, *, n_clusters: int = 16,
                  f_ntx: float = 1.5e9, single_result=None):
    """Model the recovery cost of going from ``healthy`` to ``degraded``.

    Both arguments are :class:`repro.lower.mesh.ShardedTrainStep`s over
    the same graph (``degraded`` from
    :func:`~repro.lower.mesh.reshard_training_step`). Detection is one
    healthy update-time (the exchange the dead cube never answers),
    restore streams the parameter bytes over the survivor ring, and the
    replay is the degraded step itself — all through the same
    event-level link scheduler that times normal steps, so recovery
    cycles and steady-state cycles are one currency.
    """
    from repro.runtime.mesh import MeshInterconnect, time_mesh_step

    t_healthy = time_mesh_step(healthy, n_clusters=n_clusters, f_ntx=f_ntx,
                               single_result=single_result)
    t_degraded = time_mesh_step(degraded, n_clusters=n_clusters, f_ntx=f_ntx,
                                single_result=single_result)
    rows, cols = healthy.mesh_shape
    net = MeshInterconnect(rows, cols, failed=degraded.failed_hmcs)
    w = healthy.allreduce_bytes
    t_detect = max(t_healthy.t_update, net.hop_latency)
    # one broadcast pass of the full parameters over the survivor ring
    t_restore = (w / net.link_bw + len(net.alive_nodes) * net.hop_latency
                 if degraded.n_alive > 1 else w / net.link_bw)
    return RecoveryTiming(
        t_detect=t_detect,
        t_restore=t_restore,
        t_replay=t_degraded.t_step,
        healthy_step=t_healthy.t_step,
        degraded_step=t_degraded.t_step,
    )


# ---------------------------------------------------------------------------
# The train-loop chaos hook
# ---------------------------------------------------------------------------


@dataclass
class ChaosAction:
    """What the controller wants the train loop to do instead of commit:
    discard the just-executed step and resume at ``resume_step``, with an
    optionally re-sharded ``program`` and/or rewound ``params``."""

    resume_step: int
    program: object | None = None
    params: dict | None = None


class ChaosController:
    """Drives :func:`repro.lower.graph.train_graph` through injected faults.

    The loop calls three hooks:

      * ``start(program, params)`` — before step 0; writes the initial
        checkpoint (a preemption at step 0 must have something to rewind
        to) and returns the program to run.
      * ``intercept(step, outs, params)`` — after the step executed but
        BEFORE its outputs commit. Returns ``None`` (commit normally) or
        a :class:`ChaosAction` discarding the step: a **kill** re-shards
        onto the survivors and replays the same step; a **preempt**
        restores the latest checkpoint and rewinds. A **straggle** only
        records the event (deadline re-dispatch is modeled, the step's
        numerics are unaffected).
      * ``committed(step, params)`` — after the commit; checkpoints every
        ``ckpt_every`` steps.

    Because nothing commits until the step survives, the reference-path
    gradients of a chaos run are bit-identical to the healthy run's.
    """

    def __init__(self, schedule: ChaosSchedule | str, *, sharded=None,
                 ckpt_dir=None, ckpt_every: int = 1,
                 retry: RetryPolicy | None = None, n_clusters: int = 16,
                 sleep_fn=None):
        if isinstance(schedule, str):
            schedule = ChaosSchedule.parse(schedule)
        self.schedule = schedule
        self.sharded = sharded  # ShardedTrainStep (None = single cube)
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.retry = retry or RetryPolicy()
        self.n_clusters = n_clusters
        self.sleep_fn = sleep_fn if sleep_fn is not None else (lambda s: None)
        self.events: list[str] = []
        self.recoveries: list[RecoveryTiming] = []
        self.remesh_events = 0
        self.preemptions = 0
        self.straggler_events = 0
        self.backoffs: list[float] = []
        self._failures_in_a_row = 0
        self._last_ckpt_step = None

    # -- hooks ---------------------------------------------------------------

    def start(self, program, params):
        if self.ckpt_dir is not None:
            # The controller OWNS this directory: wipe leftovers from a
            # previous run so a preemption can never rewind into stale state.
            import shutil
            from pathlib import Path

            p = Path(self.ckpt_dir)
            if p.exists():
                shutil.rmtree(p)
            self._save(0, params)
            self._last_ckpt_step = 0
        return program

    def intercept(self, step: int, outs, params) -> ChaosAction | None:
        n = self.sharded.n_hmcs if self.sharded is not None else 1
        events = self.schedule.events_at(step, n)
        if not events:
            self._failures_in_a_row = 0
            return None
        action: ChaosAction | None = None
        for e in events:
            self.events.append(e.describe())
            if e.kind == "straggle":
                self.straggler_events += 1
                self._record("stragglers")
                continue
            self._backoff()
            if e.kind == "kill" and self.sharded is not None:
                if e.hmc in self.sharded.failed_hmcs:
                    continue  # already dead
                action = self._handle_kill(step, e)
            else:
                # a kill without a mesh takes the whole job down, like preempt
                action = self._handle_preempt(step, params)
        if action is None:
            self._failures_in_a_row = 0
        return action

    def committed(self, step: int, params):
        self._failures_in_a_row = 0
        if self.ckpt_dir is not None and (step + 1) % self.ckpt_every == 0:
            self._save(step + 1, params)
            self._last_ckpt_step = step + 1

    # -- fault handlers ------------------------------------------------------

    def _handle_kill(self, step: int, e: FaultEvent) -> ChaosAction:
        from repro.lower.mesh import reshard_training_step

        healthy = self.sharded
        degraded = reshard_training_step(healthy, e.hmc)
        rec = time_recovery(healthy, degraded, n_clusters=self.n_clusters)
        self.recoveries.append(rec)
        self.remesh_events += 1
        self.sharded = degraded
        self._record("remesh_events")
        self._record("recovery_cycles", rec.cycles())
        self._trace_recovery(step, e, rec, degraded)
        self.events.append(
            f"reshard@step{step}: {degraded.n_alive}/{degraded.n_hmcs} alive, "
            f"recovery {rec.t_total * 1e3:.2f} ms"
        )
        return ChaosAction(resume_step=step, program=degraded.program)

    def _handle_preempt(self, step: int, params) -> ChaosAction:
        self.preemptions += 1
        self._record("preemptions")
        if self.ckpt_dir is None:
            # nothing on disk: replay from the current (uncommitted) params
            self.events.append(f"preempt@step{step}: no ckpt dir, replaying step")
            return ChaosAction(resume_step=step)
        from repro.checkpoint import checkpoint as ckpt

        state, extras = ckpt.restore(self.ckpt_dir, params)
        resume = int(extras["step"])
        self.events.append(f"preempt@step{step}: restored step {resume}")
        return ChaosAction(resume_step=resume, params=state)

    # -- plumbing ------------------------------------------------------------

    def _backoff(self):
        if self._failures_in_a_row >= self.retry.max_retries:
            raise RuntimeError(
                f"gave up after {self._failures_in_a_row} consecutive "
                f"failures (RetryPolicy.max_retries={self.retry.max_retries})"
            )
        delay = self.retry.delay(self._failures_in_a_row)
        self._failures_in_a_row += 1
        self.backoffs.append(delay)
        self.sleep_fn(delay)

    def _save(self, step: int, params):
        from repro.checkpoint import checkpoint as ckpt

        ckpt.save(self.ckpt_dir, step, params, extras={"step": step})

    def _record(self, name: str, value: float = 1):
        from repro.obs import counters as obs

        reg = obs.get_active()
        if reg is not None:
            with reg.scope("chaos"):
                reg.inc(name, value)

    def _trace_recovery(self, step, e, rec, degraded):
        from repro.obs import trace as obs_trace

        tc = obs_trace.get_active_trace()
        if tc is None:
            return
        add = getattr(tc, "add_recovery", None)
        if add is not None:
            add(step, e, rec, degraded)

    def report(self) -> dict:
        return {
            "events": list(self.events),
            "remesh_events": self.remesh_events,
            "preemptions": self.preemptions,
            "straggler_events": self.straggler_events,
            "backoffs": list(self.backoffs),
            "recovery_cycles": sum(r.cycles() for r in self.recoveries),
            "alive_hmcs": (self.sharded.n_alive
                           if self.sharded is not None else 1),
        }

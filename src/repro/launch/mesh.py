"""Production mesh construction.

Kept as functions (never module-level constants) so importing this module
never touches jax device state — required for the dry-run's forced 512-device
initialization to happen first.
"""

from __future__ import annotations

import jax

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    """The target deployment mesh.

    Single pod:  (16, 16) over ("data", "model")  — 256 chips (v5e pod).
    Multi-pod:   (2, 16, 16) over ("pod", "data", "model") — 512 chips.

    The "pod" axis carries the paper's mesh-of-HMCs data-parallel tier (C6);
    scaling beyond 2 pods is the same code with a larger leading axis.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_debug_mesh(shape=(1, 1), axes=("data", "model")):
    """Tiny mesh for tests (works with a single CPU device when prod(shape)==1)."""
    return compat.make_mesh(shape, axes)


def dp_axes_of(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def n_chips(mesh) -> int:
    import math

    return math.prod(mesh.shape.values())

"""Serve-step factory: batched single-token decode against a KV/state cache.

``decode_*`` / ``long_*`` dry-run cells lower exactly this function. The cache
is donated (in-place update on device), batch is sharded over DP, heads/state
width over TP (parallel/sharding.py::cache_specs).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models import lm
from repro.models.config import ModelConfig, ParallelCtx
from repro.parallel import sharding as shd


def make_serve_step(cfg: ModelConfig, ctx: ParallelCtx):
    def serve_step(params, cache, token, pos):
        logits, cache = lm.serve_step(params, cache, token, pos, cfg, ctx)
        return logits, cache

    return serve_step


def serve_shardings(cfg, param_struct, cache_struct, token_struct, mesh, dp_axes, batch):
    params_sh = shd.param_shardings(param_struct, mesh)
    cache_sh = shd.cache_specs(cache_struct, mesh, dp_axes, batch)
    import math

    dp = math.prod(mesh.shape[a] for a in dp_axes) if dp_axes else 1
    bspec = dp_axes if (dp_axes and batch % dp == 0) else None
    token_sh = NamedSharding(mesh, P(bspec, *([None] * (len(token_struct.shape) - 1))))
    pos_sh = NamedSharding(mesh, P())
    return params_sh, cache_sh, token_sh, pos_sh


def greedy_decode(params, cfg, ctx, prompt_tokens, max_new: int):
    """Simple greedy decoding loop for the serving example (CPU-scale)."""
    b, s0 = prompt_tokens.shape
    max_len = s0 + max_new
    cache = lm.init_cache(cfg, b, max_len, dtype=cfg.dtype)
    step = jax.jit(make_serve_step(cfg, ctx))

    tokens = prompt_tokens
    logits = None
    for t in range(s0):
        logits, cache = step(params, cache, tokens[:, t], jnp.int32(t))
    out = []
    cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    for t in range(s0, max_len):
        out.append(cur)
        logits, cache = step(params, cache, cur, jnp.int32(t))
        cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jnp.stack(out, axis=1)

"""Assigned input shapes and ShapeDtypeStruct stand-ins for the dry-run.

``input_specs`` returns weak-type-correct, shardable ShapeDtypeStructs for
every model input — no device allocation (the shannon/kernels pattern).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.models.config import ModelConfig


@dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str  # "train" | "prefill" | "decode"
    seq: int
    batch: int


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524_288, 1),
}


def batch_structs(cfg: ModelConfig, shape: ShapeCell):
    """{"inputs", "labels"} ShapeDtypeStructs for a train/prefill step."""
    b, s = shape.batch, shape.seq
    if cfg.input_mode == "embeddings":
        inputs = jax.ShapeDtypeStruct((b, s, cfg.d_model), cfg.dtype)
    elif cfg.n_codebooks > 1:
        inputs = jax.ShapeDtypeStruct((b, s, cfg.n_codebooks), jnp.int32)
    else:
        inputs = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if cfg.n_codebooks > 1:
        labels = jax.ShapeDtypeStruct((b, s, cfg.n_codebooks), jnp.int32)
    else:
        labels = jax.ShapeDtypeStruct((b, s), jnp.int32)
    return {"inputs": inputs, "labels": labels}


def decode_structs(cfg: ModelConfig, shape: ShapeCell):
    """(cache, token, pos) ShapeDtypeStructs for one serve_step."""
    b, s = shape.batch, shape.seq
    cache = jax.eval_shape(lambda: lm.init_cache(cfg, b, s))
    if cfg.input_mode == "embeddings":
        token = jax.ShapeDtypeStruct((b, cfg.d_model), cfg.dtype)
    elif cfg.n_codebooks > 1:
        token = jax.ShapeDtypeStruct((b, cfg.n_codebooks), jnp.int32)
    else:
        token = jax.ShapeDtypeStruct((b,), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return cache, token, pos


def param_structs(cfg: ModelConfig):
    return jax.eval_shape(lambda: lm.init_lm(jax.random.PRNGKey(0), cfg))


def input_specs(cfg: ModelConfig, shape_name: str):
    """All ShapeDtypeStructs a given (arch x shape) cell needs."""
    shape = SHAPES[shape_name]
    if shape.kind in ("train", "prefill"):
        return {"params": param_structs(cfg), "batch": batch_structs(cfg, shape)}
    cache, token, pos = decode_structs(cfg, shape)
    return {"params": param_structs(cfg), "cache": cache, "token": token, "pos": pos}

"""Trip-count-aware HLO accounting.

``compiled.cost_analysis()`` visits each computation **once** — a
``lax.scan`` over N layer-units contributes its body flops/bytes/collectives
a single time, undercounting by ~N. This walker parses the optimized HLO
text, builds the computation call graph, multiplies through
``known_trip_count`` of every while loop, and accounts per-computation:

  * dot/convolution flops (operand shapes resolved via per-computation
    symbol tables),
  * collective wire-bytes (ring-algorithm factors, as in hlo_stats),
  * an HBM-traffic proxy: 2 x sum of instruction result bytes (writes +
    first-reads), excluding parameter/constant/tuple plumbing.

The result is the per-device roofline input. Validated against analytic
6*N*D counts in tests (ratio reported per cell in EXPERIMENTS.md).
"""

from __future__ import annotations

import math
import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\((.*)\)\s+->")
_INSTR_RE = re.compile(r"^\s+(?:ROOT\s+)?%?([\w.\-]+)\s+=\s+(.+?)\s+([\w\-]+)\(")
_CALLEE_RE = re.compile(r"(?:calls|body|condition|to_apply)=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r"known_trip_count[^0-9]*(\d+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERANDS_RE = re.compile(r"\(((?:%[\w.\-]+(?:,\s*)?)+)\)")

_COLL_FACTOR = {
    "all-reduce": lambda n: 2.0 * (n - 1) / n,
    "all-gather": lambda n: (n - 1) / n,
    "reduce-scatter": lambda n: (n - 1) / n,
    "all-to-all": lambda n: (n - 1) / n,
    "collective-permute": lambda n: 1.0,
}
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _parse_shapes(type_str: str):
    """All (dtype, dims) in a type string (handles tuples)."""
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        shape = tuple(int(d) for d in dims.split(",") if d) if dims else ()
        out.append((dt, shape))
    return out


def _type_bytes(type_str: str) -> int:
    return sum(
        _DTYPE_BYTES[dt] * (math.prod(shape) if shape else 1)
        for dt, shape in _parse_shapes(type_str)
    )


@dataclass
class _Instr:
    name: str
    type_str: str
    op: str
    line: str


@dataclass
class _Computation:
    name: str
    params: dict = field(default_factory=dict)  # name -> type_str
    instrs: list = field(default_factory=list)


def _split_computations(text: str) -> tuple[dict, str]:
    comps: dict[str, _Computation] = {}
    entry = None
    cur = None
    for line in text.splitlines():
        if not line.strip():
            continue
        if not line.startswith(" "):
            m = _HEADER_RE.match(line)
            if m:
                is_entry, name, args = m.group(1), m.group(2), m.group(3)
                cur = _Computation(name)
                # header params: "a: f32[64,64], b: (s32[], f32[2])"
                for pm in re.finditer(r"%?([\w.\-]+):\s*(\([^)]*\)|[\w\[\],]+)", args):
                    cur.params[pm.group(1)] = pm.group(2)
                comps[name] = cur
                if is_entry:
                    entry = name
                continue
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if m:
            cur.instrs.append(_Instr(m.group(1), m.group(2), m.group(3), line))
    return comps, entry


def _symbol_table(comp: _Computation) -> dict:
    table = dict(comp.params)
    for ins in comp.instrs:
        table[ins.name] = ins.type_str
    return table


def _operand_names(line: str, op: str) -> list[str]:
    # operands are inside the first (...) after "op("
    idx = line.find(op + "(")
    if idx < 0:
        return []
    depth = 0
    buf = ""
    for ch in line[idx + len(op):]:
        if ch == "(":
            depth += 1
            if depth == 1:
                continue
        if ch == ")":
            depth -= 1
            if depth == 0:
                break
        if depth >= 1:
            buf += ch
    return re.findall(r"%([\w.\-]+)", buf)


def _dot_flops(ins: _Instr, table: dict) -> float:
    ops = _operand_names(ins.line, ins.op)
    if not ops:
        return 0.0
    lhs_t = table.get(ops[0])
    res = _parse_shapes(ins.type_str)
    if not res or lhs_t is None:
        return 0.0
    res_elems = math.prod(res[0][1]) if res[0][1] else 1
    lhs_shapes = _parse_shapes(lhs_t)
    if not lhs_shapes:
        return 0.0
    lhs_shape = lhs_shapes[0][1]
    m = _CONTRACT_RE.search(ins.line)
    if not m:
        return 2.0 * res_elems  # unknown contraction: lower bound
    cdims = [int(d) for d in m.group(1).split(",") if d]
    csize = math.prod(lhs_shape[d] for d in cdims if d < len(lhs_shape)) or 1
    return 2.0 * res_elems * csize


def _conv_flops(ins: _Instr, table: dict) -> float:
    ops = _operand_names(ins.line, ins.op)
    res = _parse_shapes(ins.type_str)
    if len(ops) < 2 or not res:
        return 0.0
    rhs_t = table.get(ops[1])
    if rhs_t is None:
        return 0.0
    rhs = _parse_shapes(rhs_t)
    if not rhs:
        return 0.0
    # kernel elems x 2 per output element (grouping ignored: upper bound)
    kernel_elems = math.prod(rhs[0][1][:-1]) if rhs[0][1] else 1
    res_elems = math.prod(res[0][1]) if res[0][1] else 1
    return 2.0 * res_elems * kernel_elems


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2


@dataclass
class WalkStats:
    flops: float = 0.0
    bytes_proxy: float = 0.0
    coll_wire_bytes: float = 0.0
    coll_by_op: dict = field(default_factory=lambda: defaultdict(float))
    coll_counts: dict = field(default_factory=lambda: defaultdict(float))

    def as_dict(self):
        return {
            "flops": self.flops,
            "bytes_proxy": self.bytes_proxy,
            "coll_wire_bytes": self.coll_wire_bytes,
            "coll_by_op": dict(self.coll_by_op),
            "coll_counts": dict(self.coll_counts),
        }


_SKIP_BYTES_OPS = {"tuple", "parameter", "constant", "get-tuple-element",
                   "bitcast", "copy", "after-all", "iota"}


def walk(hlo_text: str) -> WalkStats:
    comps, entry = _split_computations(hlo_text)
    if entry is None:
        return WalkStats()

    # per-computation raw stats + call edges
    raw = {}
    edges = defaultdict(list)  # comp -> [(callee, multiplier)]
    for name, comp in comps.items():
        table = _symbol_table(comp)
        flops = bytes_proxy = wire = 0.0
        by_op = defaultdict(float)
        counts = defaultdict(float)
        for ins in comp.instrs:
            base_op = ins.op.replace("-start", "") if ins.op.endswith("-start") else ins.op
            if base_op in ("dot", "dot-general"):
                flops += _dot_flops(ins, table)
            elif base_op == "convolution":
                flops += _conv_flops(ins, table)
            if base_op in _COLL_FACTOR:
                n = _group_size(ins.line)
                if n > 1 or base_op == "collective-permute":
                    b = _type_bytes(ins.type_str) * _COLL_FACTOR[base_op](max(n, 2))
                    wire += b
                    by_op[base_op] += b
                    counts[base_op] += 1
            if base_op not in _SKIP_BYTES_OPS and not base_op.endswith("-done"):
                bytes_proxy += 2.0 * _type_bytes(ins.type_str)
            # call edges
            trip = 1
            tm = _TRIP_RE.search(ins.line)
            if tm and base_op == "while":
                trip = int(tm.group(1))
            for callee in _CALLEE_RE.findall(ins.line):
                edges[name].append((callee, trip))
            bm = _BRANCHES_RE.search(ins.line)
            if bm:
                for callee in re.findall(r"%?([\w.\-]+)", bm.group(1)):
                    edges[name].append((callee, 1))
        raw[name] = (flops, bytes_proxy, wire, by_op, counts)

    # effective multiplier per computation (sum over call paths)
    mult = defaultdict(float)

    def visit(name, m):
        if name not in raw:
            return
        mult[name] += m
        for callee, trip in edges.get(name, []):
            visit(callee, m * trip)

    visit(entry, 1.0)

    out = WalkStats()
    for name, (flops, bp, wire, by_op, counts) in raw.items():
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        out.flops += m * flops
        out.bytes_proxy += m * bp
        out.coll_wire_bytes += m * wire
        for k, v in by_op.items():
            out.coll_by_op[k] += m * v
        for k, v in counts.items():
            out.coll_counts[k] += m * v
    return out

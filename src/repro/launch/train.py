"""Train-step factory + CLI driver.

Three gradient-sync modes (EXPERIMENTS.md §Perf compares them):

  * ``auto``       — pure pjit: GSPMD inserts the DP all-reduce and XLA's
                     latency-hiding scheduler overlaps it with the backward
                     pass. This is the beyond-paper optimized path.
  * ``systolic``   — the paper-faithful C6 path: loss+grad run inside a
                     partial-manual shard_map over the DP axes ("pod","data")
                     and gradients are averaged by the explicit 4-wave
                     systolic ring (core/systolic.py), exactly like the
                     mesh-of-HMCs weight update in Fig. 14.
  * ``compressed`` — systolic + int8 error-feedback compression of the
                     gradient stream (optim/compression.py): 4x fewer bytes
                     on the slowest (inter-pod) hop.

Microbatch gradient accumulation (``num_microbatches``) bounds activation
memory — the paper's batch-loop with constant memory footprint (§4.5 note 1).
"""

from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import systolic
from repro.models import lm
from repro.models.config import ModelConfig, ParallelCtx
from repro.optim import compression
from repro.optim.optimizers import Optimizer, apply_updates, clip_by_global_norm
from repro.parallel import sharding as shd


def _dp_degree(mesh, dp_axes) -> int:
    return math.prod(mesh.shape[a] for a in dp_axes) if dp_axes else 1


def init_train_state(rng, cfg: ModelConfig, optimizer: Optimizer, grad_sync: str = "auto",
                     mesh=None, dp_axes: tuple[str, ...] = ()):
    params = lm.init_lm(rng, cfg)
    state = {"params": params, "opt": optimizer.init(params), "step": jnp.zeros((), jnp.int32)}
    if grad_sync == "compressed":
        dp = _dp_degree(mesh, dp_axes) if mesh is not None else 1
        state["err"] = jax.tree.map(
            lambda p: jnp.zeros((dp,) + p.shape, jnp.float32), params
        )
    return state


def _grads_and_metrics(params, batch, cfg, ctx, num_microbatches):
    """Local (per-dp-shard under systolic; logical under pjit) grads."""

    def loss_fn(p, mb):
        return lm.lm_loss(p, mb, cfg, ctx)

    if num_microbatches <= 1:
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        return grads, metrics

    def mb_slice(x, i):
        mb = x.shape[0] // num_microbatches
        return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0)

    def body(carry, i):
        acc, _ = carry
        mb = jax.tree.map(lambda x: mb_slice(x, i), batch)
        (loss, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
        acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), acc, g)
        return (acc, metrics), None

    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (gsum, metrics), _ = jax.lax.scan(
        body, (zeros, {"loss": 0.0, "ce": 0.0, "load_balance": 0.0, "router_z": 0.0}),
        jnp.arange(num_microbatches),
    )
    grads = jax.tree.map(lambda g, p: (g / num_microbatches).astype(p.dtype), gsum, params)
    return grads, metrics


def make_train_step(
    cfg: ModelConfig,
    ctx: ParallelCtx,
    optimizer: Optimizer,
    *,
    grad_sync: str = "auto",
    num_microbatches: int = 1,
    clip_norm: float | None = 1.0,
):
    mesh, dp_axes = ctx.mesh, ctx.dp_axes

    def finish(state, grads, metrics):
        if mesh is not None:
            # H4 (§Perf): pin gradient shardings to the parameter shardings.
            # Without this GSPMD may materialize full (TP-unsharded) weight
            # gradients inside the backward scan and all-reduce them at full
            # size every layer iteration.
            g_sh = shd.param_shardings(
                jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), grads),
                mesh,
            )
            grads = jax.tree.map(
                lambda g, s: jax.lax.with_sharding_constraint(g, s), grads, g_sh
            )
        if clip_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
            metrics = dict(metrics, grad_norm=gnorm)
        updates, opt = optimizer.update(grads, state["opt"], state["params"])
        params = apply_updates(state["params"], updates)
        new_state = dict(state, params=params, opt=opt, step=state["step"] + 1)
        return new_state, metrics

    if grad_sync == "auto" or mesh is None or not dp_axes:

        def train_step(state, batch):
            grads, metrics = _grads_and_metrics(state["params"], batch, cfg, ctx,
                                                num_microbatches)
            return finish(state, grads, metrics)

        return train_step

    # --- paper-faithful systolic modes -------------------------------------
    dp_sizes = tuple(mesh.shape[a] for a in dp_axes)
    # The systolic wave order is horizontal ("data") then vertical ("pod"),
    # matching Fig. 14 — reverse of the mesh axis order.
    wave_axes = tuple(reversed(dp_axes))
    wave_sizes = tuple(mesh.shape[a] for a in wave_axes)
    inner_ctx = ParallelCtx(
        mesh=mesh, dp_axes=(), tp_axis=ctx.tp_axis, seq_axis=None,
        moe_impl=ctx.moe_impl, attn_backend=ctx.attn_backend, remat=ctx.remat,
        block_kv=ctx.block_kv, ssd_chunk=ctx.ssd_chunk,
    )
    compressed = grad_sync == "compressed"

    def per_shard(params, batch, err):
        grads, metrics = _grads_and_metrics(params, batch, cfg, inner_ctx, num_microbatches)
        new_err = err
        if compressed:
            err0 = jax.tree.map(lambda e: e[0], err)  # drop local leading dim
            grads, _payload, ne = compression.compress_with_feedback(grads, err0)
            new_err = jax.tree.map(lambda e: e[None], ne)
            # int8 wire payload per ring hop (4x fewer bytes on every wave)
            grads = systolic.systolic_mean_tree_q8(grads, wave_axes, wave_sizes)
        else:
            grads = systolic.systolic_mean_tree(grads, wave_axes, wave_sizes)
        metrics = jax.tree.map(lambda m: jax.lax.pmean(m, dp_axes), metrics)
        return grads, metrics, new_err

    batch_spec_fn = lambda leaf: P(dp_axes, *([None] * (len(leaf.shape) - 1)))

    def train_step(state, batch):
        err = state.get("err", {"_": jnp.zeros((_dp_degree(mesh, dp_axes), 1), jnp.float32)})
        batch_specs = jax.tree.map(lambda x: batch_spec_fn(x), batch)
        err_specs = jax.tree.map(lambda _: P(dp_axes), err)
        grads, metrics, new_err = compat.shard_map(
            per_shard,
            mesh=mesh,
            in_specs=(P(), batch_specs, err_specs),
            out_specs=(P(), P(), err_specs),
            axis_names=set(dp_axes),
            check_vma=False,
        )(state["params"], batch, err)
        new_state, metrics = finish(state, grads, metrics)
        if "err" in state:
            new_state["err"] = new_err
        return new_state, metrics

    return train_step


# ---------------------------------------------------------------------------
# Offload-aware step accounting (the paper's runtime view of one train step)
# ---------------------------------------------------------------------------


def offload_step_report(cfg: ModelConfig, seq: int, batch: int, *,
                        n_clusters: int = 16, queue_depth: int = 4,
                        f_ntx: float = 1.5e9) -> dict:
    """Map one training step onto the NTX offload runtime (modeled).

    MACs come from the analytic flop counts, DMA bytes from the HBM-traffic
    model at fp32 stream width (the near-memory tier streams wide); the
    cycle estimate runs the double-buffered runtime of
    :mod:`repro.runtime.scheduler`. The per-layer block lowers the step's
    GEMMs through :func:`repro.lower.lower` — forward plus both training
    passes (dW, dX), the paper's whole-training-layer offload story — and
    the queue-level block maps the dominant forward GEMM onto per-cluster
    command streams to compare queued vs synchronous offload (§2.2).
    """
    from repro.lower import MatmulSpec, NS_DESIGN, lower_layer, run_timing
    from repro.models import flops
    from repro.runtime import scheduler as rt_sched

    macs = flops.train_step_flops(cfg, seq, batch) / 2.0
    dma_bytes = flops.train_hbm_bytes_per_chip(cfg, seq, batch, tp=1, dp=1,
                                               dtype_bytes=4)
    est = rt_sched.simulate_workload(macs, dma_bytes, n_clusters=n_clusters,
                                     f_ntx=f_ntx)

    # per-layer fwd+bwd command accounting from the unified lowering
    tokens = seq * batch
    d_ff = cfg.d_ff or getattr(cfg, "moe_d_ff", 0) or 4 * cfg.d_model
    layer_specs = {
        "attn_qkvo": MatmulSpec(tokens, 4 * cfg.d_model, cfg.d_model),
        "ffn_in": MatmulSpec(tokens, d_ff, cfg.d_model),
        "ffn_out": MatmulSpec(tokens, cfg.d_model, d_ff),
    }
    layers = {}
    layer_progs = {}
    for lname, spec in layer_specs.items():
        progs = layer_progs[lname] = lower_layer(spec)
        # NS-vs-NTX cycle comparison from the timing executor: the NS design
        # re-issues one command per output element (tokens x d_out commands —
        # millions per layer), which only the block-replicated fast path can
        # simulate; split each program over the clusters first (§3.1).
        timed = {}
        for design, prs in (("ntx", progs),
                            ("ns", lower_layer(spec, design=NS_DESIGN))):
            total = 0
            for pr in prs.values():
                # refine only coarse programs (the NTX single-command GEMMs);
                # NS streams are already millions of fine-grained commands
                want = n_clusters * rt_sched.ENGINES_PER_CLUSTER * queue_depth
                if pr.n_commands < want:
                    pr = rt_sched.partition_program(
                        pr, -(-want // pr.n_commands)
                    )
                total += run_timing(pr, n_clusters=n_clusters, f_ntx=f_ntx,
                                    engine="block").total_cycles
            timed[design] = total
        layers[lname] = {
            "offloads": {p: pr.n_offloads for p, pr in progs.items()},
            "busy_cycles": {p: pr.busy_cycles for p, pr in progs.items()},
            "fwd_bwd_offloads": sum(pr.n_offloads for pr in progs.values()),
            "fwd_bwd_cycles_timed": timed["ntx"],
            "fwd_bwd_cycles_timed_ns": timed["ns"],
            "ns_over_ntx_cycles": timed["ns"] / max(timed["ntx"], 1),
        }

    # queue-level view of the dominant GEMM: (tokens x d_ff x d_model)
    gemm = layer_progs["ffn_in"]["fwd"].blocks[0].template
    # enough tiles that every engine's queue can actually fill to queue_depth
    parts = rt_sched.partition_command(
        gemm, n_clusters * rt_sched.ENGINES_PER_CLUSTER * queue_depth
    )
    tile_bytes = [
        (p.loops[2] * p.loops[0] + p.loops[0] * p.loops[1]) * 4 for p in parts
    ]
    sched = rt_sched.MultiClusterScheduler(
        n_clusters=n_clusters,
        cluster=rt_sched.ClusterConfig(queue_depth=queue_depth),
        f_ntx=f_ntx,
    )
    queued = sched.schedule(parts, bytes_per_command=tile_bytes)
    sync_sched = rt_sched.MultiClusterScheduler(
        n_clusters=n_clusters,
        cluster=rt_sched.ClusterConfig(sync=True),
        f_ntx=f_ntx,
    )
    synced = sync_sched.schedule(parts, bytes_per_command=tile_bytes)
    return {
        "macs_per_step": macs,
        "dma_bytes_per_step": dma_bytes,
        "cycles_per_step": est.cycles,
        "step_time_s": est.time,
        "overlap_efficiency": est.overlap_efficiency,
        "layers": layers,
        "gemm_offloads": queued.summary()["n_commands"],
        "gemm_cycles_queued": queued.total_cycles,
        "gemm_cycles_sync": synced.total_cycles,
        "gemm_queued_speedup": synced.total_cycles / max(queued.total_cycles, 1),
        "gemm_utilization": queued.utilization,
    }


# ---------------------------------------------------------------------------
# Sharding helpers for jit/lower
# ---------------------------------------------------------------------------


def state_shardings(state_struct, mesh, dp_axes):
    """NamedSharding tree for a train state (params TP, opt ZeRO-1, err DP)."""
    param_sh = shd.param_shardings(state_struct["params"], mesh)
    opt_sh = shd.opt_state_shardings(state_struct["opt"], mesh, dp_axes)
    out = {
        "params": param_sh,
        "opt": opt_sh,
        "step": NamedSharding(mesh, P()),
    }
    if "err" in state_struct:
        out["err"] = jax.tree.map(
            lambda leaf: NamedSharding(
                mesh, P(dp_axes, *([None] * (len(leaf.shape) - 1)))
            ),
            state_struct["err"],
        )
    return out


def batch_shardings(batch_struct, cfg, mesh, dp_axes, seq_axis=None, batch_size=None):
    def one(leaf):
        b = leaf.shape[0]
        dp = _dp_degree(mesh, dp_axes)
        bspec = dp_axes if (dp_axes and b % dp == 0) else None
        rest = [None] * (len(leaf.shape) - 1)
        if seq_axis is not None and len(leaf.shape) >= 2:
            rest[0] = seq_axis
        return NamedSharding(mesh, P(bspec, *rest))

    return jax.tree.map(one, batch_struct)


# ---------------------------------------------------------------------------
# CLI driver: the production training entrypoint.
#
#   PYTHONPATH=src python -m repro.launch.train --arch qwen1_5_0_5b \
#       --reduced --steps 50 --batch 8 --seq 64 --grad-sync systolic
#
# On a real multi-host deployment jax.distributed.initialize() runs first and
# the same code drives every host; in this container it runs single-process
# (optionally with fake devices via XLA_FLAGS for mesh exercises).
# ---------------------------------------------------------------------------


def validate_mesh_args(mesh: str | None, shard: str, batch: int) -> tuple[int, int] | None:
    """Upfront --mesh / --shard validation with actionable errors.

    Checks everything that would otherwise surface as a deep shard_map or
    splitter failure: mesh spec parses as RxC, the mesh is not degenerate,
    the batch divides over the cubes, and ``--shard 2d`` actually has a
    mesh to shard over. Device-count shortfall is only a warning — the
    executor falls back to the bit-identical single-device walk.

    Returns (rows, cols), or None when no mesh was requested.
    """
    from repro.lower.mesh import parse_mesh

    if shard not in ("1d", "2d"):
        raise SystemExit(f"--shard must be '1d' or '2d', got {shard!r}")
    if mesh is None:
        if shard == "2d":
            raise SystemExit(
                "--shard 2d needs a mesh: pass --mesh RxC (rows = pipeline "
                "stages, columns = tensor/data shards), e.g. --mesh 2x2"
            )
        return None
    try:
        rows, cols = parse_mesh(mesh)
    except ValueError as e:
        raise SystemExit(
            f"bad --mesh {mesh!r}: {e} (expected RxC, e.g. --mesh 2x4)"
        ) from None
    if rows < 1 or cols < 1:
        raise SystemExit(
            f"--mesh {mesh!r} is degenerate: both dimensions must be >= 1"
        )
    n = rows * cols
    if batch % n != 0:
        raise SystemExit(
            f"--batch {batch} does not divide over the {rows}x{cols} mesh "
            f"({n} cubes); pick a batch that is a multiple of {n}, e.g. "
            f"--batch {max(n, (batch // n + 1) * n)}"
        )
    n_dev = jax.device_count()
    if n_dev < n:
        print(f"note: {n_dev} jax device(s) < {n} cubes — run_pallas will "
              f"use the (bit-identical) single-device walk; set "
              f"XLA_FLAGS=--xla_force_host_platform_device_count={n} "
              f"for real shard_map execution")
    return rows, cols


def run_ntx_cnn(steps: int, batch: int, img: int, *, n_clusters: int = 16,
                lr: float = 0.05, momentum: float = 0.9,
                interpret: bool | None = None,
                mesh: str | None = None,
                shard: str = "1d",
                metrics: str | None = None,
                trace: str | None = None,
                fuse: bool = True,
                chaos: str | None = None,
                ckpt_dir: str | None = None) -> dict:
    """The ``--backend ntx`` mode: train the paper's small CNN end-to-end
    with every step one compiled :class:`repro.lower.NtxProgram` executed
    through ``run_pallas`` graph execution (cached per-node plans).

    With ``mesh="RxC"`` the step program is sharded across a mesh of HMCs
    (:func:`repro.lower.shard_training_step`): ``run_pallas`` executes it
    data-parallel via ``shard_map`` when enough jax devices exist (e.g.
    ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` for a 2x2 mesh
    on CPU), and the modeled mesh timing (per-HMC shard program + eq. 14-15
    link exchange) is printed alongside. ``shard="2d"`` turns the mesh 2D:
    rows become GPipe-style pipeline stages (explicit send/recv blocks on
    the vertical links), columns tensor/data-shard each stage, and the
    modeled timing reports microbatch count and pipeline-bubble fraction.

    ``metrics`` streams one JSON object per step (loss, wall seconds, the
    step's counter totals — :mod:`repro.obs.report` schema); ``trace``
    writes the merged Perfetto trace (cluster exec/DMA lanes, mesh link
    lanes, host lowering/dispatch spans, flow events). Either also prints
    the top-k hotspot table at the end.

    ``fuse`` (default) executes whole-step programs through the region
    fuser — chains of compatible layers as single double-buffered Pallas
    kernels, one cached step-level plan per program. ``fuse=False``
    (``--no-fuse``) is the escape hatch back to per-node plan dispatch.

    ``chaos`` injects faults (:class:`repro.runtime.faults.ChaosSchedule`
    grammar, e.g. ``"kill:hmc=1@step=2"``): a killed cube's step is
    discarded, the program elastically re-shards onto the survivors and
    the step replays, so the run converges to the same gradients as the
    healthy run. Any chaos run (including ``"none"``) switches to
    step-keyed batches — ``batch_fn(i)`` depends only on ``i`` — so a
    replayed step sees bit-identical data; ``ckpt_dir`` enables the
    preemption-rewind path (defaults to ``artifacts/ntx_chaos_ckpt``
    when chaos is on).

    Returns the :func:`repro.lower.train_graph` result dict (program,
    params, losses, per-step walls) plus ``"chaos"`` (the controller's
    report) when chaos was requested.
    """
    from contextlib import nullcontext

    import numpy as np

    from repro import obs
    from repro.lower import (
        PlanCache,
        frequency_band_batches,
        lower_training_step,
        paper_cnn_graph,
        shard_training_step,
        train_graph,
    )
    from repro.lower.executors import _cache_stats

    registry = obs.CounterRegistry() if (metrics or trace) else None
    collector = obs.TraceCollector() if trace else None
    reg_ctx = obs.use_registry(registry) if registry is not None else nullcontext()
    col_ctx = obs.use_collector(collector) if collector is not None else nullcontext()
    with reg_ctx, col_ctx:
        graph = paper_cnn_graph(batch=batch, img=img, lr=lr, momentum=momentum)
        program = lower_training_step(graph, n_clusters=n_clusters)
        print(f"ntx train-step program: {len(program.blocks)} blocks, "
              f"{program.n_commands} commands, "
              f"peak TCDM {program.meta['peak_tcdm_bytes']} / "
              f"{program.meta['tcdm_budget_bytes']} B "
              f"({len(program.meta['spilled'])} spilled)")
        sharded = None
        if mesh is not None:
            from repro.runtime.mesh import time_mesh_step

            sharded = shard_training_step(graph, mesh_shape=mesh,
                                          n_clusters=n_clusters,
                                          program=program, shard=shard)
            program = sharded.program
            n_dev = jax.device_count()
            how = ("shard_map data-parallel" if n_dev >= sharded.n_hmcs
                   else f"single-device walk ({n_dev} jax device(s) "
                        f"< {sharded.n_hmcs} HMCs)")
            print(f"mesh {sharded.mesh_shape[0]}x{sharded.mesh_shape[1]}: "
                  f"{sharded.n_hmcs} HMCs x {sharded.shard_batch} images, "
                  f"{len(program.blocks)} blocks incl. allreduce epilogue; "
                  f"executing via {how}")
            if sharded.shard == "2d":
                pmeta = program.meta["mesh"]["pipeline"]
                stages = [">".join(s) for s in pmeta["stages"]]
                print(f"2d pipeline: {pmeta['n_stages']} stage(s) "
                      f"[{' | '.join(stages)}], "
                      f"{pmeta['n_micro']} microbatch(es), "
                      f"{len(pmeta['xfers'])} boundary transfer(s)")
            tm = time_mesh_step(sharded, n_clusters=n_clusters)
            print(f"modeled mesh step: shard {tm.t_shard*1e3:.3f} ms + "
                  f"update {tm.t_update*1e3:.3f} ms "
                  f"-> speedup {tm.speedup:.2f}, "
                  f"parallel eff {tm.parallel_eff:.1%}")
            if sharded.shard == "2d":
                print(f"2d timing: compute {tm.t_compute*1e3:.3f} ms "
                      f"(bubble {tm.bubble_frac:.1%}), boundary "
                      f"{tm.t_boundary*1e3:.3f} ms (overlapped)")
        chaos_ctl = None
        if chaos is not None:
            from repro.runtime.faults import ChaosController

            # chaos runs need replayable data: key every batch on the step
            # alone so a replayed step sees bit-identical images
            def batch_fn(i):
                rng = np.random.RandomState(10_000 + i)
                return frequency_band_batches(rng, batch, img,
                                              graph.loss.classes)(i)

            chaos_ctl = ChaosController(
                chaos, sharded=sharded,
                ckpt_dir=ckpt_dir or "artifacts/ntx_chaos_ckpt",
                n_clusters=n_clusters,
            )
            print(f"chaos: {chaos!r} (ckpt dir "
                  f"{chaos_ctl.ckpt_dir}, retries "
                  f"{chaos_ctl.retry.max_retries} @ backoff "
                  f"{chaos_ctl.retry.delays()})")
        else:
            batch_fn = frequency_band_batches(np.random.RandomState(0), batch,
                                              img, graph.loss.classes)
        cache = PlanCache()
        res = train_graph(graph, steps, batch_fn, program=program,
                          backend="pallas", interpret=interpret,
                          params=graph.init_params(seed=0),
                          metrics_path=metrics, cache=cache, fuse=fuse,
                          chaos=chaos_ctl)
        if chaos_ctl is not None:
            rep = res["chaos"] = chaos_ctl.report()
            if chaos_ctl.sharded is not None:
                sharded = chaos_ctl.sharded  # trace the surviving mesh
            for line in rep["events"]:
                print(f"chaos event: {line}")
            print(f"chaos report: {rep['remesh_events']} re-shard(s), "
                  f"{rep['preemptions']} preemption(s), "
                  f"{rep['straggler_events']} straggler(s), "
                  f"{rep['recovery_cycles']} modeled recovery cycles, "
                  f"{rep['alive_hmcs']} cube(s) alive at exit")
        if collector is not None:
            if sharded is not None:
                collector.add_mesh_step(sharded, n_clusters=n_clusters)
            else:
                from repro.lower.executors import run_timing

                # The lane-rendering timing run must not double-book the
                # training run's counters.
                with obs.use_registry(None):
                    result = run_timing(program, n_clusters=n_clusters)
                collector.add_cluster_lanes(
                    program, result, n_clusters, pid="hmc0"
                )
                exec_evs = [e for e in collector.events
                            if e.get("cat") == "exec"]
                collector.link_flows(exec_evs, [])
            print(f"merged Perfetto trace: {collector.save(trace)} "
                  f"({len(collector.events)} events) — open in "
                  "https://ui.perfetto.dev")
    losses = res["losses"]
    for i, (loss, w) in enumerate(zip(losses, res["walls"])):
        print(f"step {i:5d} loss={loss:.4f} ({w*1e3:.0f} ms)", flush=True)
    hits, misses, traces, calls = _cache_stats(cache)
    print(f"plan cache: {len(cache)} plans, {traces} traces "
          f"({hits} hits / {misses} misses over {calls} calls)")
    fusion = next(
        iter(program.meta.get("_fusion_plans", {}).values()), None
    )
    if fusion is not None:
        print(f"fusion: {fusion.n_regions} regions + "
              f"{len(fusion.fallback_steps)} fallback steps per step, "
              f"coverage {fusion.coverage:.1%} "
              f"({fusion.fused_commands}/{fusion.total_commands} commands)")
    else:
        print("fusion: disabled (--no-fuse) — per-node plan dispatch")
    if metrics:
        print(f"per-step metrics JSONL: {metrics}")
    if registry is not None:
        print(obs.format_hotspots(registry))
    print(f"done: {steps} ntx steps, loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    return res


def _dag_oracle_loss(graph, p, x, onehot):
    """Any DAG NetworkGraph in plain jax — the ``--check-grads`` oracle."""
    from repro.lower import (
        AttentionSpec,
        EmbeddingSpec,
        LayerNormSpec,
        MatmulSpec,
        PosEmbedSpec,
        ReluSpec,
        ResidualAddSpec,
    )

    acts = {graph.input_edge: x}
    for node in graph.nodes:
        s = node.spec
        a = acts[node.in_edge]
        if isinstance(s, (MatmulSpec, EmbeddingSpec)):
            y = a @ p[node.param]
        elif isinstance(s, ReluSpec):
            y = jax.nn.relu(a)
        elif isinstance(s, LayerNormSpec):
            mu = jnp.mean(a, axis=-1, keepdims=True)
            var = jnp.mean((a - mu) ** 2, axis=-1, keepdims=True)
            w = p[node.param]
            y = (a - mu) * jax.lax.rsqrt(var + s.eps) * w[0] + w[1]
        elif isinstance(s, ResidualAddSpec):
            y = a + acts[node.aux_edges[0]]
        elif isinstance(s, PosEmbedSpec):
            y = (a.reshape(s.batch, s.seq, s.d) + p[node.param][None])
            y = y.reshape(-1, s.d)
        elif isinstance(s, AttentionSpec):
            D = s.d

            def one(qkv, s=s, D=D):
                def heads(m):
                    return m.reshape(s.seq, s.n_heads, s.head_dim).transpose(1, 0, 2)

                q, k, v = (heads(qkv[:, i * D:(i + 1) * D]) for i in range(3))
                sc = jnp.einsum("hid,hjd->hij", q, k) * s.scale
                mask = jnp.where(
                    jnp.tril(jnp.ones((s.seq, s.seq), qkv.dtype)) > 0, 0.0, -1e9
                )
                pr = jax.nn.softmax(sc + mask[None], axis=-1)
                ctx = jnp.einsum("hij,hjd->hid", pr, v)
                return ctx.transpose(1, 0, 2).reshape(s.seq, D)

            y = jax.vmap(one)(a.reshape(-1, s.seq, 3 * D)).reshape(-1, D)
        else:  # pragma: no cover - new node types need an oracle rule
            raise TypeError(type(s).__name__)
        acts[node.out_edge] = y
    z = acts[graph.logits_edge]
    return -jnp.mean(jnp.sum(jax.nn.log_softmax(z) * onehot, axis=1))


def run_ntx_lm(model: str, steps: int, batch: int, seq: int, *,
               n_clusters: int = 16, lr: float = 0.05,
               reduced: bool = True,
               interpret: bool | None = None,
               mesh: str | None = None,
               shard: str = "1d",
               metrics: str | None = None,
               trace: str | None = None,
               fuse: bool = True,
               check_grads: bool = False) -> dict:
    """The ``--backend ntx --model <config>`` mode: train a small
    decoder-only transformer end-to-end, every step one compiled
    :class:`repro.lower.NtxProgram`.

    The named :class:`~repro.models.config.ModelConfig` (``repro.configs``
    registry) is shrunk to smoke scale (``reduced``, the default) and built
    into a DAG training graph by :meth:`NetworkGraph.from_model_config` —
    embedding, learned positions, pre-LN attention + FFN blocks with
    residual fan-out, final norm, tied-free head — then trained on the
    synthetic next-token task of :func:`repro.lower.lm_token_batches`
    through the same ``run_pallas`` plan-cache execution as the CNN path.
    The block-engine timing run prints Table-2-style offload/command/cycle
    counts for the LM step; with ``mesh="RxC"`` the step program shards
    across the HMC mesh and :func:`repro.runtime.mesh.time_mesh_step`
    reports the modeled mesh step alongside.

    ``check_grads`` re-runs one step and verifies every ``d_<param>``
    against ``jax.grad`` of the plain-jax graph oracle at fp32 tolerance —
    the CI lm-train-smoke gate.
    """
    from contextlib import nullcontext

    import numpy as np

    from repro import obs
    from repro.configs import get_config, reduce_config
    from repro.lower import (
        NetworkGraph,
        PlanCache,
        lm_token_batches,
        lower_training_step,
        run_pallas,
        run_timing,
        shard_training_step,
        train_graph,
    )
    from repro.lower.executors import _cache_stats

    cfg = get_config(model)
    if reduced:
        cfg = reduce_config(cfg)
    else:
        print(f"note: lowering the FULL {cfg.name} config — expect a very "
              f"large program; --reduced is the smoke-scale path")
    registry = obs.CounterRegistry() if (metrics or trace) else None
    collector = obs.TraceCollector() if trace else None
    reg_ctx = obs.use_registry(registry) if registry is not None else nullcontext()
    col_ctx = obs.use_collector(collector) if collector is not None else nullcontext()
    with reg_ctx, col_ctx:
        graph = NetworkGraph.from_model_config(cfg, batch=batch, seq=seq, lr=lr)
        program = lower_training_step(graph, n_clusters=n_clusters)
        print(f"ntx LM train-step program ({graph.name}): "
              f"{len(graph.nodes)} nodes -> {len(program.blocks)} blocks, "
              f"{program.n_commands} commands, "
              f"peak TCDM {program.meta['peak_tcdm_bytes']} / "
              f"{program.meta['tcdm_budget_bytes']} B "
              f"({len(program.meta['spilled'])} spilled)")
        # Table-2-style step accounting from the timing engine
        with obs.use_registry(None):
            timed = run_timing(program, n_clusters=n_clusters, engine="block")
        print(f"timing engine: {program.n_offloads} offloads, "
              f"{program.n_commands} commands, "
              f"{timed.total_cycles} cycles/step on {n_clusters} clusters")
        sharded = None
        if mesh is not None:
            from repro.runtime.mesh import time_mesh_step

            sharded = shard_training_step(graph, mesh_shape=mesh,
                                          n_clusters=n_clusters,
                                          program=program, shard=shard)
            program = sharded.program
            n_dev = jax.device_count()
            how = ("shard_map data-parallel" if n_dev >= sharded.n_hmcs
                   else f"single-device walk ({n_dev} jax device(s) "
                        f"< {sharded.n_hmcs} HMCs)")
            print(f"mesh {sharded.mesh_shape[0]}x{sharded.mesh_shape[1]}: "
                  f"{sharded.n_hmcs} HMCs x {sharded.shard_batch} sequences, "
                  f"{len(program.blocks)} blocks incl. allreduce epilogue; "
                  f"executing via {how}")
            tm = time_mesh_step(sharded, n_clusters=n_clusters)
            print(f"modeled mesh step: shard {tm.t_shard*1e3:.3f} ms + "
                  f"update {tm.t_update*1e3:.3f} ms "
                  f"-> speedup {tm.speedup:.2f}, "
                  f"parallel eff {tm.parallel_eff:.1%}")
        batch_fn = lm_token_batches(np.random.RandomState(0), batch, seq,
                                    cfg.vocab_size)
        cache = PlanCache()
        res = train_graph(graph, steps, batch_fn, program=program,
                          backend="pallas", interpret=interpret,
                          params=graph.init_params(seed=0),
                          metrics_path=metrics, cache=cache, fuse=fuse)
        if collector is not None:
            if sharded is not None:
                collector.add_mesh_step(sharded, n_clusters=n_clusters)
            else:
                with obs.use_registry(None):
                    result = run_timing(program, n_clusters=n_clusters)
                collector.add_cluster_lanes(
                    program, result, n_clusters, pid="hmc0"
                )
                exec_evs = [e for e in collector.events
                            if e.get("cat") == "exec"]
                collector.link_flows(exec_evs, [])
            print(f"merged Perfetto trace: {collector.save(trace)} "
                  f"({len(collector.events)} events) — open in "
                  "https://ui.perfetto.dev")
    losses = res["losses"]
    for i, (loss, w) in enumerate(zip(losses, res["walls"])):
        print(f"step {i:5d} loss={loss:.4f} ({w*1e3:.0f} ms)", flush=True)
    hits, misses, traces, calls = _cache_stats(cache)
    print(f"plan cache: {len(cache)} plans, {traces} traces "
          f"({hits} hits / {misses} misses over {calls} calls)")
    fusion = next(
        iter(program.meta.get("_fusion_plans", {}).values()), None
    )
    if fusion is not None:
        print(f"fusion: {fusion.n_regions} regions + "
              f"{len(fusion.fallback_steps)} fallback steps per step, "
              f"coverage {fusion.coverage:.1%} "
              f"({fusion.fused_commands}/{fusion.total_commands} commands) — "
              f"token-row graphs fuse update epilogues only")
    else:
        print("fusion: disabled (--no-fuse) — per-node plan dispatch")
    if check_grads:
        x, labels = batch_fn(0)
        eye = np.eye(cfg.vocab_size, dtype=np.float32)
        onehot = eye[np.asarray(labels)]
        params = graph.init_params(seed=0)
        inputs = {graph.input_edge: x, graph.label_edge: onehot, **params}
        outs = run_pallas(res["program"], inputs, cache=cache, fuse=fuse)
        jp = {k: jnp.asarray(v) for k, v in params.items()}
        grads = jax.grad(
            lambda p: _dag_oracle_loss(graph, p, jnp.asarray(x),
                                       jnp.asarray(onehot))
        )(jp)
        import numpy as _np

        worst = 0.0
        for p in graph.param_shapes():
            got = _np.asarray(outs[f"d_{p}"])
            want = _np.asarray(grads[p])
            rel = float(_np.max(_np.abs(got - want))
                        / (_np.max(_np.abs(want)) + 1e-12))
            worst = max(worst, rel)
            if not _np.allclose(got, want, rtol=1e-4, atol=1e-5):
                raise SystemExit(
                    f"gradient check FAILED for {p}: rel err {rel:.2e}"
                )
        print(f"gradient check vs jax.grad: {len(graph.param_shapes())} "
              f"params OK (worst rel err {worst:.2e})")
    if metrics:
        print(f"per-step metrics JSONL: {metrics}")
    if registry is not None:
        print(obs.format_hotspots(registry))
    print(f"done: {steps} LM ntx steps, loss "
          f"{losses[0]:.4f} -> {losses[-1]:.4f}")
    return res


def _cli():
    import argparse
    import time

    from repro.configs import get_config, reduce_config
    from repro.data.pipeline import DataIterator, InMemoryDataset
    from repro.models.config import ParallelCtx
    from repro.optim.optimizers import get_optimizer
    from repro.runtime.supervisor import FailureInjector, Supervisor

    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="xla", choices=["xla", "ntx"],
                    help="xla: the LM training CLI below; ntx: train the "
                         "paper's small CNN — or, with --model, a small "
                         "decoder-only transformer — with one compiled "
                         "NtxProgram per step (run_pallas graph execution)")
    ap.add_argument("--img", type=int, default=16,
                    help="ntx backend: CNN input image size")
    ap.add_argument("--model", default=None, metavar="ARCH",
                    help="ntx backend: instead of the CNN, train a small "
                         "decoder-only transformer built from this "
                         "repro.configs ModelConfig name (e.g. "
                         "qwen1_5_0_5b) via "
                         "NetworkGraph.from_model_config; combine with "
                         "--reduced for the smoke-scale config")
    ap.add_argument("--check-grads", action="store_true",
                    help="ntx --model: after training, re-run one step and "
                         "verify every parameter gradient against jax.grad "
                         "of the plain-jax graph oracle at fp32 tolerance")
    ap.add_argument("--mesh", default=None, metavar="RxC",
                    help="ntx backend: shard the train step across an RxC "
                         "mesh of HMCs (batch must divide evenly); executes "
                         "data-parallel via shard_map when enough jax "
                         "devices exist and prints the modeled mesh timing")
    ap.add_argument("--shard", default="1d", choices=["1d", "2d"],
                    help="ntx backend: mesh sharding layout. 1d: pure data "
                         "parallelism (every cube runs the whole model on a "
                         "batch slice). 2d: mesh rows are GPipe-style "
                         "pipeline stages with explicit send/recv link "
                         "traffic, columns tensor/data-shard each stage — "
                         "for models that don't fit one HMC")
    ap.add_argument("--chaos", default=None, metavar="SPEC",
                    help="ntx backend: inject faults — 'kill:hmc=1@step=2', "
                         "'straggle:hmc=0,slow=4@step=3', 'preempt@step=5' "
                         "(join with ';'), or "
                         "'random:seed=7,p_kill=0.02'. A killed cube's step "
                         "is discarded, the program re-shards onto the "
                         "survivors and the step replays; a preemption "
                         "rewinds to the latest checkpoint. 'none' enables "
                         "the (step-keyed) chaos data path without faults — "
                         "the healthy baseline for chaos diffs")
    ap.add_argument("--chaos-ckpt", default=None, metavar="DIR",
                    help="ntx backend: checkpoint dir the chaos controller "
                         "owns (wiped at start; default "
                         "artifacts/ntx_chaos_ckpt)")
    ap.add_argument("--arch", default="qwen1_5_0_5b")
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--optimizer", default="adamw", choices=["sgd", "adamw"])
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--grad-sync", default="auto",
                    choices=["auto", "systolic", "compressed"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="artifacts/train_cli_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--crash-at", type=int, default=None)
    ap.add_argument("--offload-report", action="store_true",
                    help="print the modeled NTX offload accounting for one "
                         "train step (queue/DMA runtime) and compare it with "
                         "the measured step time at the end")
    ap.add_argument("--offload-clusters", type=int, default=16)
    ap.add_argument("--queue-depth", type=int, default=4)
    ap.add_argument("--metrics", default=None, metavar="OUT.jsonl",
                    help="stream per-step metrics (loss/wall/counter totals) "
                         "as JSON lines to this path (both backends)")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="ntx backend: write the merged Perfetto trace "
                         "(cluster exec/DMA + mesh link + host lanes) here")
    ap.add_argument("--no-fuse", action="store_true",
                    help="ntx backend: disable the region fuser and run "
                         "per-node plan dispatch (the pre-fusion walk); "
                         "numerics are identical, steps are slower")
    args = ap.parse_args()

    if args.backend == "ntx":
        validate_mesh_args(args.mesh, args.shard, args.batch)
        if args.model is not None:
            if args.chaos is not None:
                raise SystemExit("--chaos is CNN-path only for now; "
                                 "drop it or drop --model")
            res = run_ntx_lm(args.model, args.steps, args.batch, args.seq,
                             n_clusters=args.offload_clusters,
                             lr=args.lr, reduced=args.reduced,
                             mesh=args.mesh, shard=args.shard,
                             metrics=args.metrics, trace=args.trace,
                             fuse=not args.no_fuse,
                             check_grads=args.check_grads)
            if (len(res["losses"]) >= 3
                    and not res["losses"][-1] < res["losses"][0]):
                raise SystemExit("ntx LM training did not decrease the loss")
            return
        res = run_ntx_cnn(args.steps, args.batch, args.img,
                          n_clusters=args.offload_clusters, mesh=args.mesh,
                          shard=args.shard,
                          metrics=args.metrics, trace=args.trace,
                          fuse=not args.no_fuse, chaos=args.chaos,
                          ckpt_dir=args.chaos_ckpt)
        if len(res["losses"]) >= 3 and not res["losses"][-1] < res["losses"][0]:
            raise SystemExit("ntx CNN training did not decrease the loss")
        return

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    if cfg.input_mode == "embeddings":
        raise SystemExit("CLI driver trains token-input archs; use examples/ for stubs")

    n_dev = jax.device_count()
    if n_dev > 1:
        model = math.gcd(n_dev, 4)
        mesh = compat.make_mesh((n_dev // model, model), ("data", "model"))
        dp_axes = ("data",)
    else:
        mesh, dp_axes = None, ()
    ctx = ParallelCtx(mesh=mesh, dp_axes=dp_axes,
                      tp_axis="model" if mesh else None, attn_backend="xla",
                      grad_sync=args.grad_sync)

    opt = get_optimizer(args.optimizer, args.lr)
    ds = InMemoryDataset.synthetic(2_000_000, cfg.vocab_size, args.seq, seed=0)
    iterator = DataIterator(ds, batch_size=args.batch, seed=0)

    def init_state(_mesh):
        return init_train_state(jax.random.PRNGKey(0), cfg, opt, args.grad_sync,
                                mesh, dp_axes)

    def make_step(_mesh):
        return jax.jit(
            make_train_step(cfg, ctx, opt, grad_sync=args.grad_sync,
                            num_microbatches=args.microbatches),
            donate_argnums=(0,),
        )

    offload = None
    if args.offload_report:
        offload = offload_step_report(cfg, args.seq, args.batch,
                                      n_clusters=args.offload_clusters,
                                      queue_depth=args.queue_depth)
        print("offload step accounting (modeled NTX runtime):")
        for key, v in offload.items():
            if key == "layers":
                print("  per-layer fwd+bwd offloads (lowered programs):")
                for lname, info in v.items():
                    offs = info["offloads"]
                    print(f"    {lname}: fwd={offs['fwd']} dw={offs['dw']} "
                          f"dx={offs['dx']} total={info['fwd_bwd_offloads']} "
                          f"timed_cycles={info['fwd_bwd_cycles_timed']} "
                          f"ns/ntx={info['ns_over_ntx_cycles']:.2f}x")
            else:
                print(f"  {key}: {v:.4g}" if isinstance(v, float)
                      else f"  {key}: {v}")

    injector = FailureInjector({args.crash_at: "crash"} if args.crash_at else {})
    t0 = time.time()

    def cb(step, metrics):
        if step % 10 == 0:
            print(f"step {step:5d} ce={float(metrics['ce']):.4f} "
                  f"({time.time() - t0:.0f}s)", flush=True)

    registry = None
    if args.metrics:
        from repro.obs import CounterRegistry

        registry = CounterRegistry()
    sup = Supervisor(make_step, init_state, iterator, args.ckpt_dir,
                     ckpt_every=args.ckpt_every, injector=injector,
                     registry=registry, metrics_path=args.metrics)
    report = sup.run(args.steps, metrics_cb=cb)
    print(f"done: {report.steps_run} steps, {report.restarts} restarts")
    if args.metrics:
        print(f"per-step metrics JSONL: {args.metrics}")
    if offload is not None and report.steps_run:
        measured = (time.time() - t0) / report.steps_run
        print(f"offload model: {offload['step_time_s']*1e3:.2f} ms/step modeled "
              f"on {args.offload_clusters} clusters vs {measured*1e3:.2f} ms/step "
              f"measured on {jax.default_backend()}")


if __name__ == "__main__":
    _cli()

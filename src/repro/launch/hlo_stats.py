"""Parse compiled (post-SPMD) HLO for roofline inputs.

``cost_analysis()`` gives FLOPs and bytes-accessed of the *per-device* module,
but not collective traffic — we recover that by walking the optimized HLO text
and summing operand bytes of every collective op, scaled to per-chip
wire-bytes by the standard ring algorithm factors:

    all-reduce        2 (n-1)/n * bytes
    all-gather          (n-1)/n * bytes   (bytes = full output)
    reduce-scatter      (n-1)/n * bytes   (bytes = full input)
    all-to-all          (n-1)/n * bytes
    collective-permute        1 * bytes

n = participant-group size parsed from replica_groups.
"""

from __future__ import annotations

import math
import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(\(?[\w\[\],\s{}:#*]+?\)?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_SRC_TGT_RE = re.compile(r"source_target_pairs=\{")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    counts: dict = field(default_factory=lambda: defaultdict(int))
    raw_bytes: dict = field(default_factory=lambda: defaultdict(float))
    wire_bytes: dict = field(default_factory=lambda: defaultdict(float))

    @property
    def total_wire_bytes(self) -> float:
        return sum(self.wire_bytes.values())

    @property
    def total_raw_bytes(self) -> float:
        return sum(self.raw_bytes.values())

    def as_dict(self) -> dict:
        return {
            "counts": dict(self.counts),
            "raw_bytes": {k: float(v) for k, v in self.raw_bytes.items()},
            "wire_bytes": {k: float(v) for k, v in self.wire_bytes.items()},
            "total_wire_bytes": float(self.total_wire_bytes),
        }


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        # iota format [num_groups, group_size]
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2  # conservative default


def _ring_factor(op: str, n: int) -> float:
    if op == "all-reduce":
        return 2.0 * (n - 1) / n
    if op in ("all-gather", "reduce-scatter", "all-to-all"):
        return (n - 1) / n
    return 1.0  # collective-permute


def collect_collectives(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        type_str, op = m.group(1), m.group(2)
        n = _group_size(line)
        if n <= 1 and op != "collective-permute":
            continue
        b = _shape_bytes(type_str)
        stats.counts[op] += 1
        stats.raw_bytes[op] += b
        stats.wire_bytes[op] += b * _ring_factor(op, max(n, 2))
    return stats


def memory_stats(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        ma = None
    out = {}
    if ma is None:
        return out
    for k in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    ):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def cost_stats(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    keys = ("flops", "bytes accessed", "transcendentals", "optimal_seconds")
    return {k: float(ca[k]) for k in keys if k in ca}

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
).strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: for the
single-pod (16,16) and multi-pod (2,16,16) meshes, every supported cell must
``.lower().compile()`` cleanly; the compiled artifact's memory/cost analysis
and collective schedule feed EXPERIMENTS.md §Dry-run / §Roofline.

Usage:
    python -m repro.launch.dryrun --arch qwen3_8b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all --mesh both --out artifacts/dryrun
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, get_config
from repro.launch import hlo_stats, hlo_walk, shapes as shp
from repro.launch.mesh import dp_axes_of, make_production_mesh, n_chips
from repro.launch.serve import make_serve_step, serve_shardings
from repro.launch.train import (
    batch_shardings,
    init_train_state,
    make_train_step,
    state_shardings,
)
from repro.models import flops as flops_mod
from repro.models import lm
from repro.models.config import ParallelCtx
from repro.optim.optimizers import get_optimizer
from repro.parallel import sharding as shd


OPT_FLAGS = {
    "bf16_coll": dict(collective_dtype="bf16"),
    "sp_model": dict(sp_model=True),
    "windowed": dict(windowed_attn=True),
    "shard_heads": dict(shard_heads=True),
    "scan_params": dict(shard_scan_params=True),
    "bigblk": dict(block_kv=2048),
}


def build_ctx(cfg, mesh, cell: shp.ShapeCell, grad_sync="auto", moe_impl=None, opts=()):
    dp_axes = dp_axes_of(mesh)
    import math

    dp = math.prod(mesh.shape[a] for a in dp_axes)
    seq_axis = None
    dp_for_batch = dp_axes
    if cell.kind in ("train", "prefill") and cell.batch % dp != 0:
        # batch not divisible by DP -> shard the sequence instead (SP)
        seq_axis = "data"
        dp_for_batch = ()
    if moe_impl is None:
        moe_impl = "ep" if cfg.n_experts else "dense"
    kw = dict(block_kv=512)
    for o in opts:
        kw.update(OPT_FLAGS[o])
    return ParallelCtx(
        mesh=mesh,
        dp_axes=dp_for_batch,
        tp_axis="model",
        seq_axis=seq_axis,
        moe_impl=moe_impl,
        attn_backend="xla",
        remat="full" if cell.kind == "train" else "none",
        ssd_chunk=128,
        grad_sync=grad_sync,
        **kw,
    )


def lower_cell(arch: str, shape_name: str, mesh, grad_sync: str = "auto", opts=()):
    """Returns (lowered, compiled, meta) for one cell."""
    cfg = get_config(arch)
    cell = shp.SHAPES[shape_name]
    ctx = build_ctx(cfg, mesh, cell, grad_sync, opts=opts)
    dp_axes = dp_axes_of(mesh)

    if cell.kind == "train":
        optimizer = get_optimizer("sgd", 1e-2)
        state_struct = jax.eval_shape(
            lambda: init_train_state(
                jax.random.PRNGKey(0), cfg, optimizer, grad_sync, mesh, dp_axes
            )
        )
        batch_struct = shp.batch_structs(cfg, cell)
        step = make_train_step(cfg, ctx, optimizer, grad_sync=grad_sync)
        st_sh = state_shardings(state_struct, mesh, dp_axes)
        b_sh = batch_shardings(batch_struct, cfg, mesh, ctx.dp_axes, ctx.seq_axis)
        with mesh:
            lowered = jax.jit(
                step,
                in_shardings=(st_sh, b_sh),
                donate_argnums=(0,),
            ).lower(state_struct, batch_struct)
        model_flops = flops_mod.train_step_flops(cfg, cell.seq, cell.batch)
    elif cell.kind == "prefill":
        param_struct = shp.param_structs(cfg)
        batch_struct = shp.batch_structs(cfg, cell)

        def prefill_fn(params, batch):
            return lm.prefill(params, batch["inputs"], cfg, ctx)

        p_sh = shd.param_shardings(param_struct, mesh)
        b_sh = batch_shardings(batch_struct, cfg, mesh, ctx.dp_axes, ctx.seq_axis)
        with mesh:
            lowered = jax.jit(prefill_fn, in_shardings=(p_sh, b_sh)).lower(
                param_struct, batch_struct
            )
        model_flops = flops_mod.prefill_flops(cfg, cell.seq, cell.batch)
    else:  # decode
        param_struct = shp.param_structs(cfg)
        cache_struct, token_struct, pos_struct = shp.decode_structs(cfg, cell)
        step = make_serve_step(cfg, ctx)
        p_sh, c_sh, t_sh, pos_sh = serve_shardings(
            cfg, param_struct, cache_struct, token_struct, mesh, dp_axes, cell.batch
        )
        with mesh:
            lowered = jax.jit(
                step, in_shardings=(p_sh, c_sh, t_sh, pos_sh), donate_argnums=(1,)
            ).lower(param_struct, cache_struct, token_struct, pos_struct)
        model_flops = flops_mod.decode_step_flops(cfg, cell.seq, cell.batch)

    compiled = lowered.compile()
    meta = {
        "arch": arch,
        "shape": shape_name,
        "kind": cell.kind,
        "mesh": dict(mesh.shape),
        "chips": n_chips(mesh),
        "grad_sync": grad_sync,
        "model_flops": float(model_flops),
        "params_total": flops_mod.count(cfg).params_total,
        "params_active": flops_mod.count(cfg).params_active,
    }
    return lowered, compiled, meta


def run_cell(arch, shape_name, mesh, grad_sync="auto", out_dir=None, tag="", opts=()):
    t0 = time.time()
    lowered, compiled, meta = lower_cell(arch, shape_name, mesh, grad_sync, opts=opts)
    meta["opts"] = list(opts)
    hlo = compiled.as_text()
    stats = {
        **meta,
        "compile_seconds": time.time() - t0,
        "memory": hlo_stats.memory_stats(compiled),
        "cost": hlo_stats.cost_stats(compiled),
        "collectives": hlo_stats.collect_collectives(hlo).as_dict(),
        # trip-count-aware accounting (cost_analysis counts scan bodies once)
        "walk": hlo_walk.walk(hlo).as_dict(),
        "hlo_bytes": len(hlo),
    }
    if out_dir:
        out_dir = Path(out_dir)
        out_dir.mkdir(parents=True, exist_ok=True)
        mesh_tag = "multi" if "pod" in meta["mesh"] else "single"
        name = f"{arch}--{shape_name}--{mesh_tag}{('--' + tag) if tag else ''}.json"
        (out_dir / name).write_text(json.dumps(stats, indent=1))
    return stats


def supported_cells():
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape_name in cfg.shapes:
            yield arch, shape_name


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--grad-sync", default="auto",
                    choices=["auto", "systolic", "compressed"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--opt", default="", help="comma list: bf16_coll,sp_model,windowed")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    if args.list:
        for arch, shape in supported_cells():
            print(f"{arch} {shape}")
        return

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(make_production_mesh(multi_pod=False))
    if args.mesh in ("multi", "both"):
        meshes.append(make_production_mesh(multi_pod=True))

    cells = list(supported_cells()) if args.all else [(args.arch, args.shape)]
    failures = 0
    for mesh in meshes:
        mesh_tag = "multi" if "pod" in mesh.axis_names else "single"
        for arch, shape_name in cells:
            label = f"{arch} x {shape_name} x {mesh_tag} [{args.grad_sync}]"
            try:
                opts = tuple(o for o in args.opt.split(",") if o)
                stats = run_cell(arch, shape_name, mesh, args.grad_sync, args.out,
                                 args.tag, opts=opts)
                mem = stats["memory"].get("argument_size_in_bytes", 0) / stats["chips"]
                print(
                    f"OK   {label}: compile={stats['compile_seconds']:.1f}s "
                    f"flops/dev={stats['cost'].get('flops', 0):.3e} "
                    f"coll_wire={stats['collectives']['total_wire_bytes']:.3e}B"
                )
            except Exception as e:  # noqa: BLE001 - report and continue
                failures += 1
                print(f"FAIL {label}: {type(e).__name__}: {e}")
                traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} cell(s) failed")


if __name__ == "__main__":
    main()

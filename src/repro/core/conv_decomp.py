"""Strided-conv backward decomposition (paper §3.2, Fig. 6).

The input-gradient of a stride-s convolution is a *sparse* convolution: each
input pixel receives contributions from a varying number of output pixels.
NTX's FMAC cannot vary the summand count within one command, so the paper
decomposes the sparse convolution into s*s *dense* convolutions — one per
input-pixel phase class — each using the subset of filter taps congruent to
that phase, and interleaves the partial results.

The same decomposition is TPU-idiomatic (dense regular matmuls instead of
input-dilated scatter), so we implement it exactly and validate it against
``jax.vjp`` of ``lax.conv_general_dilated`` in the test-suite.

Layout conventions: NHWC activations, HWIO weights (the framework's defaults).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def conv2d(x: jnp.ndarray, w: jnp.ndarray, stride: int = 1, padding: int = 0) -> jnp.ndarray:
    """Reference forward: stride-s 2-D convolution, NHWC x HWIO -> NHWC."""
    return lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def _phase_slices(k: int, stride: int, phase: int) -> jnp.ndarray:
    """Indices of filter taps congruent to ``phase`` (may be empty)."""
    return jnp.arange(phase, k, stride)


def _dense_corr(dy: jnp.ndarray, w_ab: jnp.ndarray, pads: tuple[int, int]):
    """Default dense stride-1 "full" correlation for one phase (lax conv)."""
    ph, pw = pads
    return lax.conv_general_dilated(
        dy,
        w_ab,
        window_strides=(1, 1),
        padding=[(ph, ph), (pw, pw)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def conv2d_input_grad_decomposed(
    dy: jnp.ndarray,
    w: jnp.ndarray,
    stride: int,
    x_hw: tuple[int, int],
    padding: int = 0,
    conv_fn=_dense_corr,
) -> jnp.ndarray:
    """d(loss)/d(x) of :func:`conv2d`, as s*s interleaved *dense* convolutions.

    For input pixel (i, j), only the filter taps u === (i + pad) (mod s) (resp.
    v for j) ever touch it. Grouping pixels by phase (a, b) = ((i+pad)%s,
    (j+pad)%s) gives, per phase, a dense stride-1 correlation of ``dy`` with
    the *flipped* tap subset w[a::s, b::s] — a constant number of MACs per
    pixel, which is the property NTX needs (one command per phase).

    ``conv_fn(dy, w_ab, (pad_h, pad_w))`` performs the per-phase dense
    correlation; the default uses ``lax``, and the Pallas program executor
    injects the streaming kernel here so the backward pass runs on the same
    datapath as the forward (see :func:`repro.lower.executors.run_pallas`).
    """
    n, yh, yw, cout = dy.shape
    kh, kw, cin, _ = w.shape
    xh, xw = x_hw
    s = stride
    dx = jnp.zeros((n, xh, xw, cin), dy.dtype)

    for a in range(s):
        ta = len(range(a, kh, s))  # taps in this row-phase
        if ta == 0:
            continue
        for b in range(s):
            tb = len(range(b, kw, s))
            if tb == 0:
                continue
            # Tap subset for this phase, spatially flipped, channels swapped
            # (cout becomes the contraction dim of the backward conv).
            w_ab = w[a::s, b::s]  # (ta, tb, cin, cout)
            w_ab = jnp.flip(w_ab, axis=(0, 1)).transpose(0, 1, 3, 2)  # (ta,tb,cout,cin)

            # Dense stride-1 "full" correlation: out[m] = sum_t dy[m-t]*w_sub[t].
            out_full = conv_fn(dy, w_ab, (ta - 1, tb - 1))  # (n, yh+ta-1, yw+tb-1, cin)

            # Input pixels of this phase: i = i0_a + s*q, q = 0..na-1.
            i0 = (a - padding) % s
            j0 = (b - padding) % s
            na = len(range(i0, xh, s))
            nb = len(range(j0, xw, s))
            if na == 0 or nb == 0:
                continue
            # Phase-local coordinates map to out_full at offset ii0 = (i0+pad-a)/s.
            ii0 = (i0 + padding - a) // s
            jj0 = (j0 + padding - b) // s

            # Clip against the valid range of out_full; contributions outside
            # are zero (dy index out of range).
            fh, fw = out_full.shape[1], out_full.shape[2]
            lo_i, lo_j = max(ii0, 0), max(jj0, 0)
            hi_i, hi_j = min(ii0 + na, fh), min(jj0 + nb, fw)
            if hi_i <= lo_i or hi_j <= lo_j:
                continue
            piece = out_full[:, lo_i:hi_i, lo_j:hi_j, :]

            # Destination rows/cols for the clipped piece.
            qi0 = lo_i - ii0  # first phase-q row actually produced
            qj0 = lo_j - jj0
            di0 = i0 + s * qi0
            dj0 = j0 + s * qj0
            dx = dx.at[
                :,
                di0 : di0 + s * piece.shape[1] : s,
                dj0 : dj0 + s * piece.shape[2] : s,
                :,
            ].add(piece)
    return dx


def conv2d_weight_grad(
    x: jnp.ndarray,
    dy: jnp.ndarray,
    stride: int,
    k_hw: tuple[int, int],
    padding: int = 0,
) -> jnp.ndarray:
    """d(loss)/d(w): a dense correlation of x with dy (regular on NTX).

    The weight gradient of a strided conv is itself a *dilated* correlation but
    with a constant summand count per tap, so it maps onto a plain command: we
    express it via ``lax`` with dy as an (yh, yw)-shaped rhs dilated by s.
    """
    kh, kw = k_hw
    # conv(x^T, dy^T) trick: batch becomes contraction.
    dw = lax.conv_general_dilated(
        x.transpose(3, 1, 2, 0),  # C,H,W,N : feature dim is batch now
        dy.transpose(1, 2, 0, 3),  # yh,yw,N,cout
        window_strides=(1, 1),
        padding=[(padding, padding), (padding, padding)],
        rhs_dilation=(stride, stride),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )  # (cin, kh', kw', cout)
    return dw[:, :kh, :kw, :].transpose(1, 2, 0, 3)


def conv2d_with_decomposed_vjp(x, w, stride: int = 1, padding: int = 0):
    """conv2d whose custom VJP uses the paper's decomposition (used by the CNN
    example so the backward pass exercises C4 end-to-end)."""

    @jax.custom_vjp
    def f(x, w):
        return conv2d(x, w, stride, padding)

    def fwd(x, w):
        return f(x, w), (x, w)

    def bwd(res, dy):
        x, w = res
        dx = conv2d_input_grad_decomposed(dy, w, stride, (x.shape[1], x.shape[2]), padding)
        dw = conv2d_weight_grad(x, dy, stride, (w.shape[0], w.shape[1]), padding)
        return dx, dw

    f.defvjp(fwd, bwd)
    return f(x, w)

"""Systolic mesh gradient exchange (paper §3.4, §4.9, Fig. 14).

The paper scales data-parallel training across a 2-D mesh of HMCs: each cube
computes a local weight update, then the global average is formed by **four
streaming waves** — a horizontal pass followed by a vertical pass over the
mesh, each implemented as a systolic pipeline over the serial links.

TPU ICI *is* a 2-D(+) torus with ~the same per-link bandwidth the paper
assumes (50-60 GB/s), so the algorithm transplants almost verbatim:

  * per mesh axis, wave 1 = ring **reduce-scatter** (each chip ends up with a
    fully-reduced 1/n-th shard), wave 2 = ring **all-gather** — built from
    ``lax.ppermute`` neighbour hops exactly like the paper's neighbour links;
  * the horizontal ("data") pass runs first, then the vertical ("pod") pass,
    i.e. 4 waves for the production mesh — matching Fig. 14(b).

``psum_mean`` is the let-XLA-do-it baseline (XLA lowers it to the same ring
on a torus, but fuses/overlaps it with backward compute); the explicit
systolic path is the paper-faithful artifact and the unit of account for the
collective roofline term. Both are exposed so EXPERIMENTS.md §Perf can compare
them.

All functions run **inside shard_map** over the relevant axes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax


def _ring_perm(n: int, reverse: bool = False):
    if reverse:
        return [((d + 1) % n, d) for d in range(n)]
    return [(d, (d + 1) % n) for d in range(n)]


def ring_reduce_scatter(chunks: jnp.ndarray, axis_name: str, axis_size: int) -> jnp.ndarray:
    """Wave 1: ring reduce-scatter.

    ``chunks``: (n, c) local array, n == axis_size. Returns the (c,)-shaped
    fully-reduced chunk this device owns, which is chunk ``(i + 2) % n`` —
    callers should pair this with :func:`ring_all_gather` which restores order.
    n-1 neighbour hops, each moving c elements: the per-wave traffic the paper
    counts in eq. (14).
    """
    n = axis_size
    i = lax.axis_index(axis_name)
    if n == 1:
        return chunks[0]
    acc = lax.dynamic_index_in_dim(chunks, (i + 1) % n, axis=0, keepdims=False)
    perm = _ring_perm(n)

    def body(t, acc):
        acc = lax.ppermute(acc, axis_name, perm)
        c = (i - t) % n
        return acc + lax.dynamic_index_in_dim(chunks, c, axis=0, keepdims=False)

    return lax.fori_loop(0, n - 1, body, acc)


def ring_all_gather(chunk: jnp.ndarray, axis_name: str, axis_size: int) -> jnp.ndarray:
    """Wave 2: ring all-gather of per-device chunks back into (n, c).

    Chunk ownership follows :func:`ring_reduce_scatter`'s final placement
    (device i holds chunk (i+2) % n), so after this wave every device holds
    the identical, correctly-ordered (n, c) array.
    """
    n = axis_size
    if n == 1:
        return chunk[None]
    i = lax.axis_index(axis_name)
    out = jnp.zeros((n,) + chunk.shape, chunk.dtype)
    ci = (i + 2) % n
    out = lax.dynamic_update_slice_in_dim(out, chunk[None], ci, axis=0)
    perm = _ring_perm(n)

    def body(t, carry):
        out, buf, ci = carry
        buf = lax.ppermute(buf, axis_name, perm)
        ci = (ci - 1) % n
        out = lax.dynamic_update_slice_in_dim(out, buf[None], ci, axis=0)
        return out, buf, ci

    out, _, _ = lax.fori_loop(0, n - 1, body, (out, chunk, ci))
    return out


def systolic_all_reduce(x: jnp.ndarray, axis_name: str, axis_size: int) -> jnp.ndarray:
    """All-reduce(sum) along one mesh axis as two systolic ring waves."""
    if axis_size == 1:
        return x
    flat = x.reshape(-1)
    pad = (-flat.size) % axis_size
    if pad:
        flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(axis_size, -1)
    reduced = ring_reduce_scatter(chunks, axis_name, axis_size)
    gathered = ring_all_gather(reduced, axis_name, axis_size)
    out = gathered.reshape(-1)
    if pad:
        out = out[: flat.size - pad]
    return out.reshape(x.shape)


def systolic_mean(
    x: jnp.ndarray, axis_names: tuple[str, ...], axis_sizes: tuple[int, ...]
) -> jnp.ndarray:
    """Paper Fig. 14: horizontal wave pair, then vertical wave pair, then scale.

    ``axis_names``/``axis_sizes``: the mesh axes to average over, e.g.
    (("data", "pod"), (16, 2)) — 4 waves total on the production mesh.
    """
    total = 1
    for name, size in zip(axis_names, axis_sizes):
        x = systolic_all_reduce(x, name, size)
        total *= size
    return x / total


def systolic_mean_tree(tree, axis_names: tuple[str, ...], axis_sizes: tuple[int, ...]):
    """Gradient-pytree version: flatten once, stream as a single dense buffer.

    The paper streams the full 300 MB weight update as one systolic transfer;
    flattening the gradient pytree into one fp32 buffer reproduces that (and
    maximizes per-hop message size). Used by the paper-faithful train step.
    """
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    sizes = [leaf.size for leaf in leaves]
    shapes = [leaf.shape for leaf in leaves]
    dtypes = [leaf.dtype for leaf in leaves]
    flat = jnp.concatenate([leaf.reshape(-1).astype(jnp.float32) for leaf in leaves])
    flat = systolic_mean(flat, axis_names, axis_sizes)
    out, off = [], 0
    for size, shape, dtype in zip(sizes, shapes, dtypes):
        out.append(flat[off : off + size].reshape(shape).astype(dtype))
        off += size
    return jax.tree_util.tree_unflatten(treedef, out)


def psum_mean_tree(tree, axis_names: tuple[str, ...]):
    """Baseline/beyond-paper path: let XLA schedule (and overlap) the reduction."""
    n = 1
    for name in axis_names:
        n *= lax.psum(1, name)
    return jax.tree_util.tree_map(lambda g: lax.psum(g, axis_names) / n, tree)


def mesh_update_time_model(
    weight_bytes: float,
    mesh_side: int,
    link_bw: float = 60e9,
    hop_latency: float = 20e-6,
) -> float:
    """Paper eqs. (14)-(15): T_update = 4 * (T_tx + N * T_lat).

    Kept here (not in benchmarks/) because launch/train uses it for straggler
    deadlines and benchmarks/fig14 reproduces the paper's numbers with it.
    """
    t_tx = weight_bytes / link_bw
    t_pass = t_tx + mesh_side * hop_latency
    return 4.0 * t_pass


# ---------------------------------------------------------------------------
# Quantized systolic waves (beyond-paper, §Perf): every ring hop ships an int8
# payload + fp32 scale instead of fp32 values — 4x fewer wire bytes, visible
# in the compiled HLO (s8 collective-permutes). Per-hop quantization error is
# zero-mean and bounded by scale/2; the train step's error-feedback state
# (optim/compression.py) absorbs the step-level residual.
# ---------------------------------------------------------------------------


def _q8(x):
    amax = jnp.max(jnp.abs(x))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def ring_reduce_scatter_q8(chunks: jnp.ndarray, axis_name: str, axis_size: int):
    """Reduce-scatter wave with int8 hop payloads. chunks: (n, c) fp32."""
    n = axis_size
    i = lax.axis_index(axis_name)
    if n == 1:
        return chunks[0]
    acc = lax.dynamic_index_in_dim(chunks, (i + 1) % n, axis=0, keepdims=False)
    perm = _ring_perm(n)

    def body(t, acc):
        q, scale = _q8(acc)
        q = lax.ppermute(q, axis_name, perm)  # 1-byte wire payload
        scale = lax.ppermute(scale, axis_name, perm)
        acc = q.astype(jnp.float32) * scale
        c = (i - t) % n
        return acc + lax.dynamic_index_in_dim(chunks, c, axis=0, keepdims=False)

    return lax.fori_loop(0, n - 1, body, acc)


def ring_all_gather_q8(chunk: jnp.ndarray, axis_name: str, axis_size: int):
    """All-gather wave with int8 hop payloads; mirrors ring_all_gather."""
    n = axis_size
    if n == 1:
        return chunk[None]
    i = lax.axis_index(axis_name)
    out = jnp.zeros((n,) + chunk.shape, jnp.float32)
    ci = (i + 2) % n
    out = lax.dynamic_update_slice_in_dim(out, chunk[None], ci, axis=0)
    perm = _ring_perm(n)
    q, scale = _q8(chunk)

    def body(t, carry):
        out, q, scale, ci = carry
        q = lax.ppermute(q, axis_name, perm)
        scale = lax.ppermute(scale, axis_name, perm)
        ci = (ci - 1) % n
        val = (q.astype(jnp.float32) * scale)[None]
        out = lax.dynamic_update_slice_in_dim(out, val, ci, axis=0)
        return out, q, scale, ci

    out, _, _, _ = lax.fori_loop(0, n - 1, body, (out, q, scale, ci))
    return out


def systolic_all_reduce_q8(x: jnp.ndarray, axis_name: str, axis_size: int):
    if axis_size == 1:
        return x
    flat = x.reshape(-1)
    pad = (-flat.size) % axis_size
    if pad:
        flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(axis_size, -1)
    reduced = ring_reduce_scatter_q8(chunks, axis_name, axis_size)
    gathered = ring_all_gather_q8(reduced, axis_name, axis_size)
    out = gathered.reshape(-1)
    if pad:
        out = out[: flat.size - pad]
    return out.reshape(x.shape)


def systolic_mean_tree_q8(tree, axis_names, axis_sizes):
    """Quantized-wire version of :func:`systolic_mean_tree` (compressed mode)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    sizes = [leaf.size for leaf in leaves]
    shapes = [leaf.shape for leaf in leaves]
    dtypes = [leaf.dtype for leaf in leaves]
    flat = jnp.concatenate([leaf.reshape(-1).astype(jnp.float32) for leaf in leaves])
    total = 1
    for name, size in zip(axis_names, axis_sizes):
        flat = systolic_all_reduce_q8(flat, name, size)
        total *= size
    flat = flat / total
    out, off = [], 0
    for size, shape, dtype in zip(sizes, shapes, dtypes):
        out.append(flat[off : off + size].reshape(shape).astype(dtype))
        off += size
    return jax.tree_util.tree_unflatten(treedef, out)

"""Wide-accumulation numerics — the NTX FMAC datapath (paper §2.3, Table 1).

NTX's FMAC aggregates 48-bit products into a ~300-bit partial-carry-save
accumulator and defers rounding to the final store, so *reductions* (convolution
inner products in particular) come out more accurate than a conventional fp32
FPU that rounds after every FMA.

There is no 300-bit accumulator on a TPU. The MXU gives us one step of the same
ladder for free — bf16 x bf16 products accumulate in fp32, and the product of two
bf16 values is *exact* in fp32 (8+8 significand bits < 24). For fp32 inputs we
emulate the wide accumulator with branch-free two-float (double-float) arithmetic:

  * ``two_sum``      — Knuth's error-free addition (6 flops, no branches)
  * ``two_prod``     — Dekker/Veltkamp error-free product (no FMA required,
                       which matters because neither XLA:CPU nor the VPU expose
                       a guaranteed fused FMA to jnp)
  * ``wide_sum`` / ``wide_dot`` — compensated reductions whose error is
                       O(eps) instead of O(n*eps), i.e. fp64-quality results
                       carried in two fp32 words, rounded once at the end.

These functions are pure jnp, differentiable-free utilities used by
``kernels/ntx_matmul`` (fp32 path), the Table 1 benchmark, and the kernel ref
oracles.
"""

from __future__ import annotations

import jax.numpy as jnp

# Veltkamp split constant for fp32: 2**ceil(24/2) + 1.
_SPLIT_F32 = jnp.float32(4097.0)


def two_sum(a: jnp.ndarray, b: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Error-free transformation: a + b = s + e exactly (Knuth 2Sum).

    Branch-free, so it vectorizes on the VPU and in interpret mode.
    """
    s = a + b
    bp = s - a
    ap = s - bp
    e = (a - ap) + (b - bp)
    return s, e


def fast_two_sum(a: jnp.ndarray, b: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """2Sum specialization valid when |a| >= |b| (Dekker). 3 flops."""
    s = a + b
    e = b - (s - a)
    return s, e


def _split(a: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Veltkamp split of an fp32 value into high/low halves (12+12 bits)."""
    c = _SPLIT_F32 * a
    hi = c - (c - a)
    lo = a - hi
    return hi, lo


def two_prod(a: jnp.ndarray, b: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Error-free transformation: a * b = p + e exactly (Dekker two-product).

    Uses Veltkamp splitting so it does not require a hardware FMA. Classical
    precondition: exactness requires the error term not to underflow, i.e.
    |a*b| comfortably above the fp32 subnormal range — always true for the
    activation/weight magnitudes these reductions see.
    """
    p = a * b
    ah, al = _split(a)
    bh, bl = _split(b)
    e = ((ah * bh - p) + ah * bl + al * bh) + al * bl
    return p, e


def wide_sum(x: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    """Compensated (Kahan-Babuska/Neumaier) sum along ``axis``.

    The NTX analogue of summing into the PCS accumulator and rounding once at
    the end: the relative error is O(eps) + O(n * eps^2) instead of the naive
    O(n * eps).
    """
    x = jnp.moveaxis(x, axis, 0)

    def body(carry, xi):
        s, c = carry
        t, e = two_sum(s, xi)
        return (t, c + e), None

    import jax

    (s, c), _ = jax.lax.scan(body, (jnp.zeros_like(x[0]), jnp.zeros_like(x[0])), x)
    return s + c


def wide_dot(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Compensated inner product over the last axis: error ~ eps, not n*eps.

    Every product is split error-free (two_prod) and both the product stream
    and its error stream are accumulated with compensation — the two-float
    rendering of "accumulate at full precision, round at the store".
    """
    import jax

    a2 = jnp.moveaxis(a, -1, 0)
    b2 = jnp.moveaxis(b, -1, 0)

    def body(carry, ab):
        s, c = carry
        ai, bi = ab
        p, ep = two_prod(ai, bi)
        t, es = two_sum(s, p)
        return (t, c + (ep + es)), None

    zero = jnp.zeros(jnp.broadcast_shapes(a2.shape[1:], b2.shape[1:]), a.dtype)
    (s, c), _ = jax.lax.scan(body, (zero, zero), (a2, b2))
    return s + c


def kahan_step(s: jnp.ndarray, c: jnp.ndarray, x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One Neumaier update step — used inside Pallas kernel K-loops."""
    t, e = two_sum(s, x)
    return t, c + e

"""The NTX offload programming model (paper §2.2–2.5, Fig. 5, Table 2).

An NTX command is five nested hardware loops (L0 innermost … L4 outermost),
three address-generator units (AGUs) evaluating the affine address equation

    A = A_base + i0*s0 + i1*s1 + i2*s2 + i3*s3 + i4*s4            (eq. 1)

with one add per cycle, plus an opcode executed in the innermost loop body.
The accumulator is (re-)initialized when loops at ``init_level`` and above
wrap, and stored through the write AGU at ``store_level``.

This module keeps that descriptor as a first-class object:

  * :class:`Agu`, :class:`NtxCommand` — the paper's staging-area contents.
  * :func:`ntx_execute` — a cycle-faithful *reference interpreter* over a flat
    memory (numpy). This is the behavioural model the Pallas kernels are tested
    against, and it uses the wide accumulator from :mod:`repro.core.precision`.
  * :func:`strides_to_steps` — eq. (2)/(3): the stride→step conversion the
    RISC-V driver performs when programming a command.
  * :func:`offload_count` / :func:`conv_offloads` — the Table 2 arithmetic:
    how many commands a driver core must issue given the number of hardware
    loops available (NS has 3 loops + 2 AGUs, NTX has 5 loops + 3 AGUs).

On TPU, a command's loop nest maps onto a ``pallas_call`` grid + BlockSpec
index maps (the AGUs), so "one offload" == "one pallas_call over many output
pixels" — that is exactly the paper's C2 contribution transplanted.
"""

from __future__ import annotations

import functools
import math
from dataclasses import dataclass
from typing import Sequence

import numpy as np

MAX_LOOPS = 5
_OPS = (
    "mac", "vadd", "vmul", "vmax", "vmin", "relu", "copy", "memset", "argmax",
    # comparison / transcendental helpers of the PCS FPU (§2.3): the step
    # function and >= mask feed the ReLU / max-pool backward mask patterns,
    # exp and reciprocal feed the softmax-cross-entropy gradient lowering,
    # reciprocal-sqrt feeds the layernorm rstd lowering.
    "sign", "cmpge", "vexp", "vrecip", "vrsqrt",
)


@dataclass(frozen=True)
class Agu:
    """One address-generator unit: base address + per-loop element strides."""

    base: int
    strides: tuple[int, ...]  # length MAX_LOOPS, strides[i] applies to loop i

    def __post_init__(self):
        if len(self.strides) != MAX_LOOPS:
            raise ValueError(f"AGU needs {MAX_LOOPS} strides, got {len(self.strides)}")

    def address(self, idx: Sequence[int]) -> int:
        return self.base + sum(i * s for i, s in zip(idx, self.strides))


@dataclass(frozen=True)
class NtxCommand:
    """A complete NTX staging-area image (one offload)."""

    loops: tuple[int, ...]  # N0..N4, innermost first; unused loops = 1
    opcode: str
    agu_rd0: Agu
    agu_rd1: Agu | None = None
    agu_wr: Agu | None = None
    init_level: int = MAX_LOOPS  # accumulator init when loops >= level wrap
    store_level: int = 1  # write-back once loops < level complete
    init_value: float = 0.0

    def __post_init__(self):
        if len(self.loops) != MAX_LOOPS:
            raise ValueError(f"need {MAX_LOOPS} loop bounds, got {len(self.loops)}")
        if self.opcode not in _OPS:
            raise ValueError(f"unknown opcode {self.opcode!r}; supported: {_OPS}")
        if any(n < 1 for n in self.loops):
            raise ValueError("loop bounds must be >= 1")

    @property
    def total_iterations(self) -> int:
        return math.prod(self.loops)

    @property
    def busy_cycles(self) -> int:
        """Single-cycle-throughput FMAC => one iteration per cycle (paper §2.3)."""
        return self.total_iterations


def strides_to_steps(strides: Sequence[int], loops: Sequence[int]) -> list[int]:
    """Paper eq. (2)/(3): convert absolute strides s_i to incremental steps p_i.

    The AGU adds exactly one step per cycle; the step for loop i must undo the
    accumulated steps of the inner loops that just wrapped.
    """
    steps = [0] * len(strides)
    steps[0] = strides[0]
    for i in range(1, len(strides)):
        steps[i] = strides[i] - (loops[i - 1] - 1) * steps[i - 1]
    return steps


def steps_to_strides(steps: Sequence[int], loops: Sequence[int]) -> list[int]:
    """Inverse of :func:`strides_to_steps` (used in tests)."""
    strides = [0] * len(steps)
    strides[0] = steps[0]
    for i in range(1, len(steps)):
        strides[i] = steps[i] + (loops[i - 1] - 1) * steps[i - 1]
    return strides


def ntx_execute(
    cmd: NtxCommand,
    memory: np.ndarray,
    wide: bool = True,
    *,
    vectorize: bool = True,
    inplace: bool = False,
) -> np.ndarray:
    """Reference interpreter: execute one offloaded command against ``memory``.

    ``memory`` is the TCDM: a flat fp32 numpy array; a copy with results written
    through the write AGU is returned. ``wide=True`` models the PCS accumulator
    (fp64 carried internally, rounded at store — bit-accurate to two-float for
    the sizes we test); ``wide=False`` models a conventional fp32 FPU that
    rounds after every FMA.

    ``vectorize=True`` routes affine-dense ``mac``/``copy``/``memset``
    commands through a numpy fast path that is bit-identical to the loop
    interpreter (same accumulation order, same rounding points) but orders of
    magnitude faster; anything it cannot prove safe falls back to the loops.
    ``inplace=True`` mutates ``memory`` (must be a flat fp32 array) instead of
    copying — the program executors use this to avoid O(TCDM) per command.
    """
    if inplace:
        mem = memory
        if mem.dtype != np.float32 or mem.ndim != 1:
            raise ValueError("inplace execution needs a flat float32 memory")
    else:
        mem = np.array(memory, dtype=np.float32, copy=True)
    if vectorize and _execute_vectorized(cmd, mem, wide):
        return mem
    _execute_loops(cmd, mem, wide)
    return mem


def _execute_loops(cmd: NtxCommand, mem: np.ndarray, wide: bool) -> None:
    """The cycle-faithful 5-deep loop nest (mutates ``mem``)."""
    acc_dtype = np.float64 if wide else np.float32
    acc = acc_dtype(cmd.init_value)
    arg_idx = 0
    counter = 0

    n0, n1, n2, n3, n4 = cmd.loops
    for i4 in range(n4):
        for i3 in range(n3):
            for i2 in range(n2):
                for i1 in range(n1):
                    for i0 in range(n0):
                        idx = (i0, i1, i2, i3, i4)
                        # Accumulator init: when all loops below init_level are
                        # at zero, a fresh accumulation region starts.
                        if all(idx[j] == 0 for j in range(min(cmd.init_level, MAX_LOOPS))):
                            acc = acc_dtype(cmd.init_value)
                            counter = 0
                            arg_idx = 0

                        rd0 = np.float32(mem[cmd.agu_rd0.address(idx)])
                        rd1 = (
                            np.float32(mem[cmd.agu_rd1.address(idx)])
                            if cmd.agu_rd1 is not None
                            else np.float32(0.0)
                        )

                        if cmd.opcode == "mac":
                            if wide:
                                acc = acc + np.float64(rd0) * np.float64(rd1)
                            else:
                                acc = np.float32(acc + rd0 * rd1)
                        elif cmd.opcode == "vadd":
                            acc = acc_dtype(np.float32(rd0 + rd1))
                        elif cmd.opcode == "vmul":
                            acc = acc_dtype(np.float32(rd0 * rd1))
                        elif cmd.opcode == "vmax":
                            acc = max(acc, acc_dtype(rd0)) if counter else acc_dtype(rd0)
                        elif cmd.opcode == "vmin":
                            acc = min(acc, acc_dtype(rd0)) if counter else acc_dtype(rd0)
                        elif cmd.opcode == "relu":
                            acc = acc_dtype(max(np.float32(0.0), rd0))
                        elif cmd.opcode == "sign":
                            acc = acc_dtype(1.0 if rd0 > 0 else 0.0)
                        elif cmd.opcode == "cmpge":
                            acc = acc_dtype(1.0 if rd0 >= rd1 else 0.0)
                        elif cmd.opcode == "vexp":
                            acc = acc_dtype(np.exp(rd0))
                        elif cmd.opcode == "vrecip":
                            acc = acc_dtype(np.float32(1.0) / rd0)
                        elif cmd.opcode == "vrsqrt":
                            acc = acc_dtype(np.float32(1.0) / np.sqrt(rd0))
                        elif cmd.opcode == "copy":
                            acc = acc_dtype(rd0)
                        elif cmd.opcode == "memset":
                            acc = acc_dtype(cmd.init_value)
                        elif cmd.opcode == "argmax":
                            if counter == 0 or acc_dtype(rd0) > acc:
                                acc = acc_dtype(rd0)
                                arg_idx = counter
                        counter += 1

                        # Store: when all loops below store_level wrap, the
                        # accumulator is rounded once and written back.
                        wraps = all(
                            idx[j] == cmd.loops[j] - 1
                            for j in range(min(cmd.store_level, MAX_LOOPS))
                        )
                        if wraps and cmd.agu_wr is not None:
                            out = np.float32(arg_idx) if cmd.opcode == "argmax" else np.float32(acc)
                            mem[cmd.agu_wr.address(idx)] = out


# ---------------------------------------------------------------------------
# Vectorized fast path (bit-identical to the loop interpreter)
# ---------------------------------------------------------------------------


def _agu_span(agu: Agu, loops: Sequence[int]) -> tuple[int, int]:
    """(min, max) address the AGU can emit over the loop nest."""
    lo = hi = agu.base
    for n, s in zip(loops, agu.strides):
        d = (n - 1) * s
        if d < 0:
            lo += d
        else:
            hi += d
    return lo, hi


@functools.lru_cache(maxsize=256)
def _offset_grid(strides: tuple[int, ...], loops: tuple[int, ...]) -> np.ndarray:
    """Base-relative AGU offsets, shaped (n4..n0) so C-order == issue order.

    Cached on (strides, loops): a :class:`repro.lower.ir.CommandBlock`
    re-issues one template thousands of times with only the AGU *bases*
    rebased, so the offset lattice — the expensive part of the address grid
    — is shared across every replica. The cached array is read-only; callers
    get fresh arrays from :func:`_agu_grid`'s base addition.
    """
    addr = np.int64(0)
    for j, (n, s) in enumerate(zip(loops, strides)):
        shape = [1] * MAX_LOOPS
        shape[MAX_LOOPS - 1 - j] = n
        addr = addr + (np.arange(n, dtype=np.int64) * s).reshape(shape)
    grid = np.ascontiguousarray(np.broadcast_to(addr, tuple(reversed(loops))))
    grid.setflags(write=False)
    return grid


def _agu_grid(agu: Agu, loops: Sequence[int]) -> np.ndarray:
    """All addresses, shaped (n4, n3, n2, n1, n0) so C-order == issue order."""
    return agu.base + _offset_grid(agu.strides, tuple(loops))


def _spans_ok(cmd: NtxCommand, size: int, check_alias: bool = True) -> bool:
    """All addresses in range and (for value-reading opcodes) the write span
    disjoint from every read span — the loop interpreter interleaves reads
    and writes, so gathering all reads up front is only safe without
    aliasing. Out-of-range also covers negative addresses, where numpy's
    wrap-around semantics require the sequential interpreter."""
    agus = [a for a in (cmd.agu_rd0, cmd.agu_rd1, cmd.agu_wr) if a is not None]
    spans = [_agu_span(a, cmd.loops) for a in agus]
    if any(lo < 0 or hi >= size for lo, hi in spans):
        return False
    if check_alias and cmd.agu_wr is not None:
        wlo, whi = _agu_span(cmd.agu_wr, cmd.loops)
        for agu, (lo, hi) in zip(agus, spans):
            if agu is cmd.agu_wr:
                continue
            if not (hi < wlo or whi < lo):
                return False
    return True


# Streaming elementwise opcodes and their fp32 numpy forms. ``vexp`` may
# differ from the scalar loop path by one ulp (numpy SIMD vs scalar libm);
# every other entry is bit-identical.
_ELEMENTWISE = {
    "vadd": lambda a, b: a + b,
    "vmul": lambda a, b: a * b,
    "relu": lambda a, _: np.maximum(a, np.float32(0.0)),
    "sign": lambda a, _: (a > 0).astype(np.float32),
    "cmpge": lambda a, b: (a >= b).astype(np.float32),
    "vexp": lambda a, _: np.exp(a),
    "vrecip": lambda a, _: np.float32(1.0) / a,
    "vrsqrt": lambda a, _: np.float32(1.0) / np.sqrt(a),
}


def _execute_vectorized(cmd: NtxCommand, mem: np.ndarray, wide: bool) -> bool:
    """Try the affine-dense fast path; return False to fall back to loops."""
    if cmd.agu_wr is None:
        return False
    # memset ignores the read values, so read/write aliasing is harmless; an
    # identity copy (read AGU == write AGU — the graph compiler's spill/fill
    # DMA model) writes back exactly what it reads, so aliasing is fine too.
    identity_copy = cmd.opcode == "copy" and cmd.agu_rd0 == cmd.agu_wr
    if not _spans_ok(
        cmd, mem.size, check_alias=cmd.opcode != "memset" and not identity_copy
    ):
        return False

    if cmd.opcode == "memset" and cmd.store_level == 0:
        wa = _agu_grid(cmd.agu_wr, cmd.loops).ravel()
        if np.unique(wa).size != wa.size:
            return False  # colliding writes: sequential order matters
        mem[wa] = np.float32(cmd.init_value)
        return True

    if cmd.opcode == "copy" and cmd.store_level == 0:
        wa = _agu_grid(cmd.agu_wr, cmd.loops).ravel()
        if np.unique(wa).size != wa.size:
            return False
        ra = _agu_grid(cmd.agu_rd0, cmd.loops).ravel()
        mem[wa] = mem[ra]
        return True

    if cmd.opcode in _ELEMENTWISE and cmd.store_level == 0:
        # Streaming elementwise ops overwrite the accumulator every iteration
        # and store every iteration, so with unique write addresses the loop
        # order is irrelevant and one gathered numpy expression is
        # bit-identical (all ops round in fp32, same as the loop body).
        wa = _agu_grid(cmd.agu_wr, cmd.loops).ravel()
        if np.unique(wa).size != wa.size:
            return False
        v0 = mem[_agu_grid(cmd.agu_rd0, cmd.loops).ravel()]
        v1 = None
        if cmd.opcode in ("vadd", "vmul", "cmpge"):
            if cmd.agu_rd1 is None:
                return False
            v1 = mem[_agu_grid(cmd.agu_rd1, cmd.loops).ravel()]
        out = _ELEMENTWISE[cmd.opcode](v0, v1)
        mem[wa] = out.astype(np.float32, copy=False)
        return True

    if cmd.opcode in ("vmax", "vmin"):
        # Region reduce: like mac, requires init/store boundaries to
        # coincide. min/max preserve fp32 values exactly, so the vectorized
        # reduce is bit-identical to the sequential one.
        lvl = cmd.init_level
        if cmd.store_level != lvl or not 1 <= lvl <= MAX_LOOPS:
            return False
        red = math.prod(cmd.loops[:lvl])
        outer = math.prod(cmd.loops[lvl:])
        v0 = mem[_agu_grid(cmd.agu_rd0, cmd.loops).ravel()].reshape(outer, red)
        wr = cmd.agu_wr
        base = wr.base + sum((cmd.loops[j] - 1) * wr.strides[j] for j in range(lvl))
        wa = _agu_grid(Agu(base, wr.strides), (1,) * lvl + cmd.loops[lvl:]).ravel()
        if np.unique(wa).size != wa.size:
            return False
        mem[wa] = v0.max(axis=1) if cmd.opcode == "vmax" else v0.min(axis=1)
        return True

    if cmd.opcode == "mac":
        # One accumulation region per outer-loop combo: requires the init and
        # store boundaries to coincide so regions are contiguous runs.
        lvl = cmd.init_level
        if cmd.store_level != lvl or not 1 <= lvl <= MAX_LOOPS:
            return False
        if cmd.agu_rd1 is None:
            return False
        red = math.prod(cmd.loops[:lvl])  # reduction length per region
        outer = math.prod(cmd.loops[lvl:])  # number of regions
        # Gathered reads, C-order == issue order; regions are the rows.
        v0 = mem[_agu_grid(cmd.agu_rd0, cmd.loops).ravel()].reshape(outer, red)
        v1 = mem[_agu_grid(cmd.agu_rd1, cmd.loops).ravel()].reshape(outer, red)
        # Store address per region: inner loops at their final index. Pinning
        # the inner loop bounds to 1 (their stride contribution is folded
        # into the base) keeps the grid's ravel order == region issue order.
        wr = cmd.agu_wr
        base = wr.base + sum((cmd.loops[j] - 1) * wr.strides[j] for j in range(lvl))
        wa = _agu_grid(Agu(base, wr.strides), (1,) * lvl + cmd.loops[lvl:]).ravel()
        if np.unique(wa).size != wa.size:
            return False
        # Sequential accumulation per region, vectorized across regions —
        # the same fp ops in the same order as the loop interpreter, so the
        # result is bit-identical.
        if wide:
            acc = np.full(outer, cmd.init_value, np.float64)
            v0 = v0.astype(np.float64)
            v1 = v1.astype(np.float64)
        else:
            acc = np.full(outer, cmd.init_value, np.float32)
        for r in range(red):
            acc = acc + v0[:, r] * v1[:, r]
        mem[wa] = acc.astype(np.float32)
        return True

    return False


# ---------------------------------------------------------------------------
# Offload-count analytics (paper Table 2).
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ConvShape:
    """A convolution as the paper counts it: 4D weights, 3D input/output."""

    kw: int
    kh: int
    cin: int
    out_w: int
    out_h: int
    cout: int

    @property
    def reduction_dims(self) -> tuple[int, int, int]:
        return (self.kw, self.kh, self.cin)

    @property
    def output_dims(self) -> tuple[int, int, int]:
        return (self.out_w, self.out_h, self.cout)


def offload_count(conv: ConvShape, hw_loops: int, autonomous_writeback: bool) -> int:
    """Number of commands a driver core must issue for one conv layer.

    A convolution is a 6-deep nest (3 output dims x 3 reduction dims). With
    ``hw_loops`` loops available, the innermost ``hw_loops`` dims run inside
    one command; the rest are issued by the driver. Without an autonomous
    write-back AGU (NS), at most the 3 reduction dims can be offloaded —
    every output pixel is its own command (paper §2.5(iii)).
    """
    dims = list(conv.reduction_dims) + list(conv.output_dims)  # innermost first
    usable = min(hw_loops, len(dims))
    if not autonomous_writeback:
        usable = min(usable, len(conv.reduction_dims))
    host_dims = dims[usable:]
    return math.prod(host_dims) if host_dims else 1


def busy_cycles_per_offload(conv: ConvShape, hw_loops: int, autonomous_writeback: bool) -> int:
    dims = list(conv.reduction_dims) + list(conv.output_dims)
    usable = min(hw_loops, len(dims))
    if not autonomous_writeback:
        usable = min(usable, len(conv.reduction_dims))
    return math.prod(dims[:usable])


# The two design points the paper compares (Table 2).
NS_LOOPS = dict(hw_loops=3, autonomous_writeback=False)
NTX_LOOPS = dict(hw_loops=5, autonomous_writeback=True)

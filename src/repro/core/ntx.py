"""The NTX offload programming model (paper §2.2–2.5, Fig. 5, Table 2).

An NTX command is five nested hardware loops (L0 innermost … L4 outermost),
three address-generator units (AGUs) evaluating the affine address equation

    A = A_base + i0*s0 + i1*s1 + i2*s2 + i3*s3 + i4*s4            (eq. 1)

with one add per cycle, plus an opcode executed in the innermost loop body.
The accumulator is (re-)initialized when loops at ``init_level`` and above
wrap, and stored through the write AGU at ``store_level``.

This module keeps that descriptor as a first-class object:

  * :class:`Agu`, :class:`NtxCommand` — the paper's staging-area contents.
  * :func:`ntx_execute` — a cycle-faithful *reference interpreter* over a flat
    memory (numpy). This is the behavioural model the Pallas kernels are tested
    against, and it uses the wide accumulator from :mod:`repro.core.precision`.
  * :func:`strides_to_steps` — eq. (2)/(3): the stride→step conversion the
    RISC-V driver performs when programming a command.
  * :func:`offload_count` / :func:`conv_offloads` — the Table 2 arithmetic:
    how many commands a driver core must issue given the number of hardware
    loops available (NS has 3 loops + 2 AGUs, NTX has 5 loops + 3 AGUs).

On TPU, a command's loop nest maps onto a ``pallas_call`` grid + BlockSpec
index maps (the AGUs), so "one offload" == "one pallas_call over many output
pixels" — that is exactly the paper's C2 contribution transplanted.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Sequence

import numpy as np

MAX_LOOPS = 5
_OPS = ("mac", "vadd", "vmul", "vmax", "vmin", "relu", "copy", "memset", "argmax")


@dataclass(frozen=True)
class Agu:
    """One address-generator unit: base address + per-loop element strides."""

    base: int
    strides: tuple[int, ...]  # length MAX_LOOPS, strides[i] applies to loop i

    def __post_init__(self):
        if len(self.strides) != MAX_LOOPS:
            raise ValueError(f"AGU needs {MAX_LOOPS} strides, got {len(self.strides)}")

    def address(self, idx: Sequence[int]) -> int:
        return self.base + sum(i * s for i, s in zip(idx, self.strides))


@dataclass(frozen=True)
class NtxCommand:
    """A complete NTX staging-area image (one offload)."""

    loops: tuple[int, ...]  # N0..N4, innermost first; unused loops = 1
    opcode: str
    agu_rd0: Agu
    agu_rd1: Agu | None = None
    agu_wr: Agu | None = None
    init_level: int = MAX_LOOPS  # accumulator init when loops >= level wrap
    store_level: int = 1  # write-back once loops < level complete
    init_value: float = 0.0

    def __post_init__(self):
        if len(self.loops) != MAX_LOOPS:
            raise ValueError(f"need {MAX_LOOPS} loop bounds, got {len(self.loops)}")
        if self.opcode not in _OPS:
            raise ValueError(f"unknown opcode {self.opcode!r}; supported: {_OPS}")
        if any(n < 1 for n in self.loops):
            raise ValueError("loop bounds must be >= 1")

    @property
    def total_iterations(self) -> int:
        return math.prod(self.loops)

    @property
    def busy_cycles(self) -> int:
        """Single-cycle-throughput FMAC => one iteration per cycle (paper §2.3)."""
        return self.total_iterations


def strides_to_steps(strides: Sequence[int], loops: Sequence[int]) -> list[int]:
    """Paper eq. (2)/(3): convert absolute strides s_i to incremental steps p_i.

    The AGU adds exactly one step per cycle; the step for loop i must undo the
    accumulated steps of the inner loops that just wrapped.
    """
    steps = [0] * len(strides)
    steps[0] = strides[0]
    for i in range(1, len(strides)):
        steps[i] = strides[i] - (loops[i - 1] - 1) * steps[i - 1]
    return steps


def steps_to_strides(steps: Sequence[int], loops: Sequence[int]) -> list[int]:
    """Inverse of :func:`strides_to_steps` (used in tests)."""
    strides = [0] * len(steps)
    strides[0] = steps[0]
    for i in range(1, len(steps)):
        strides[i] = steps[i] + (loops[i - 1] - 1) * steps[i - 1]
    return strides


def ntx_execute(cmd: NtxCommand, memory: np.ndarray, wide: bool = True) -> np.ndarray:
    """Reference interpreter: execute one offloaded command against ``memory``.

    ``memory`` is the TCDM: a flat fp32 numpy array; a copy with results written
    through the write AGU is returned. ``wide=True`` models the PCS accumulator
    (fp64 carried internally, rounded at store — bit-accurate to two-float for
    the sizes we test); ``wide=False`` models a conventional fp32 FPU that
    rounds after every FMA.
    """
    mem = np.array(memory, dtype=np.float32, copy=True)
    acc_dtype = np.float64 if wide else np.float32
    acc = acc_dtype(cmd.init_value)
    arg_idx = 0
    counter = 0

    n0, n1, n2, n3, n4 = cmd.loops
    for i4 in range(n4):
        for i3 in range(n3):
            for i2 in range(n2):
                for i1 in range(n1):
                    for i0 in range(n0):
                        idx = (i0, i1, i2, i3, i4)
                        # Accumulator init: when all loops below init_level are
                        # at zero, a fresh accumulation region starts.
                        if all(idx[j] == 0 for j in range(min(cmd.init_level, MAX_LOOPS))):
                            acc = acc_dtype(cmd.init_value)
                            counter = 0
                            arg_idx = 0

                        rd0 = np.float32(mem[cmd.agu_rd0.address(idx)])
                        rd1 = (
                            np.float32(mem[cmd.agu_rd1.address(idx)])
                            if cmd.agu_rd1 is not None
                            else np.float32(0.0)
                        )

                        if cmd.opcode == "mac":
                            if wide:
                                acc = acc + np.float64(rd0) * np.float64(rd1)
                            else:
                                acc = np.float32(acc + rd0 * rd1)
                        elif cmd.opcode == "vadd":
                            acc = acc_dtype(np.float32(rd0 + rd1))
                        elif cmd.opcode == "vmul":
                            acc = acc_dtype(np.float32(rd0 * rd1))
                        elif cmd.opcode == "vmax":
                            acc = max(acc, acc_dtype(rd0)) if counter else acc_dtype(rd0)
                        elif cmd.opcode == "vmin":
                            acc = min(acc, acc_dtype(rd0)) if counter else acc_dtype(rd0)
                        elif cmd.opcode == "relu":
                            acc = acc_dtype(max(np.float32(0.0), rd0))
                        elif cmd.opcode == "copy":
                            acc = acc_dtype(rd0)
                        elif cmd.opcode == "memset":
                            acc = acc_dtype(cmd.init_value)
                        elif cmd.opcode == "argmax":
                            if counter == 0 or acc_dtype(rd0) > acc:
                                acc = acc_dtype(rd0)
                                arg_idx = counter
                        counter += 1

                        # Store: when all loops below store_level wrap, the
                        # accumulator is rounded once and written back.
                        wraps = all(
                            idx[j] == cmd.loops[j] - 1
                            for j in range(min(cmd.store_level, MAX_LOOPS))
                        )
                        if wraps and cmd.agu_wr is not None:
                            out = np.float32(arg_idx) if cmd.opcode == "argmax" else np.float32(acc)
                            mem[cmd.agu_wr.address(idx)] = out
    return mem


# ---------------------------------------------------------------------------
# Offload-count analytics (paper Table 2).
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ConvShape:
    """A convolution as the paper counts it: 4D weights, 3D input/output."""

    kw: int
    kh: int
    cin: int
    out_w: int
    out_h: int
    cout: int

    @property
    def reduction_dims(self) -> tuple[int, int, int]:
        return (self.kw, self.kh, self.cin)

    @property
    def output_dims(self) -> tuple[int, int, int]:
        return (self.out_w, self.out_h, self.cout)


def offload_count(conv: ConvShape, hw_loops: int, autonomous_writeback: bool) -> int:
    """Number of commands a driver core must issue for one conv layer.

    A convolution is a 6-deep nest (3 output dims x 3 reduction dims). With
    ``hw_loops`` loops available, the innermost ``hw_loops`` dims run inside
    one command; the rest are issued by the driver. Without an autonomous
    write-back AGU (NS), at most the 3 reduction dims can be offloaded —
    every output pixel is its own command (paper §2.5(iii)).
    """
    dims = list(conv.reduction_dims) + list(conv.output_dims)  # innermost first
    usable = min(hw_loops, len(dims))
    if not autonomous_writeback:
        usable = min(usable, len(conv.reduction_dims))
    host_dims = dims[usable:]
    return math.prod(host_dims) if host_dims else 1


def busy_cycles_per_offload(conv: ConvShape, hw_loops: int, autonomous_writeback: bool) -> int:
    dims = list(conv.reduction_dims) + list(conv.output_dims)
    usable = min(hw_loops, len(dims))
    if not autonomous_writeback:
        usable = min(usable, len(conv.reduction_dims))
    return math.prod(dims[:usable])


# The two design points the paper compares (Table 2).
NS_LOOPS = dict(hw_loops=3, autonomous_writeback=False)
NTX_LOOPS = dict(hw_loops=5, autonomous_writeback=True)


def matmul_command(
    m: int,
    n: int,
    k: int,
    a_base: int,
    b_base: int,
    c_base: int,
) -> NtxCommand:
    """Build the NtxCommand for a row-major (m,k)x(k,n)->(m,n) matmul.

    Loop mapping (innermost first): L0=k (reduction), L1=n, L2=m.
    AGU strides follow eq. (1) with element units.
    """
    return NtxCommand(
        loops=(k, n, m, 1, 1),
        opcode="mac",
        agu_rd0=Agu(a_base, (1, 0, k, 0, 0)),  # A[i2, i0]
        agu_rd1=Agu(b_base, (n, 1, 0, 0, 0)),  # B[i0, i1]
        agu_wr=Agu(c_base, (0, 1, n, 0, 0)),  # C[i2, i1]
        init_level=1,  # new accumulation per (i1, i2) pixel
        store_level=1,  # store once L0 completes
    )


def conv2d_command(
    in_h: int,
    in_w: int,
    cin: int,
    kh: int,
    kw: int,
    cout_tile: int,
    x_base: int,
    w_base: int,
    y_base: int,
) -> NtxCommand:
    """NtxCommand for a VALID 2-D convolution tile, NHWC x HWIO -> NHWC.

    Loop mapping (innermost first): L0=cin, L1=kw, L2=kh (reduction);
    L3=out_w, L4=out_h. One command covers a full output plane for one
    output channel — the paper's "many output pixels per offload".
    """
    out_h, out_w = in_h - kh + 1, in_w - kw + 1
    return NtxCommand(
        loops=(cin, kw, kh, out_w, out_h),
        opcode="mac",
        # x[i4 + i2, i3 + i1, i0] with row stride in_w*cin
        agu_rd0=Agu(x_base, (1, cin, in_w * cin, cin, in_w * cin)),
        # w[i2, i1, i0] for a fixed cout (HWI contiguous)
        agu_rd1=Agu(w_base, (1, cin, kw * cin, 0, 0)),
        # y[i4, i3] with row stride out_w (single channel plane)
        agu_wr=Agu(y_base, (0, 0, 0, 1, out_w)),
        init_level=3,  # fresh accumulator per output pixel (loops 0..2 reduce)
        store_level=3,  # store when the 3 reduction loops complete
        init_value=0.0,
    )

"""On-the-fly tile planning (paper §3.1, §4.5 + TPU adaptation).

The paper's clusters stream tiles of dense, canonically-laid-out tensors from
DRAM into a 128 KiB TCDM through a DMA that double-buffers transfers behind
compute, and it constrains tiles so the innermost dimension yields DRAM bursts
of >= 32 B (>= 8 fp32 elements).

On TPU the same discipline applies one level up the hierarchy: HBM -> VMEM
copies are emitted by the Pallas pipeline (double-buffered by construction),
and efficiency wants (a) the *last* tile dimension a multiple of 128 lanes,
(b) the second-to-last a multiple of the dtype's sublane pack, and (c) matmul
tiles aligned to the 128x128 MXU. This module picks block shapes under a VMEM
budget; kernels consume the plan, and the roofline napkin math reads the
arithmetic-intensity numbers off it.
"""

from __future__ import annotations

from dataclasses import dataclass

# Conservative usable VMEM per TensorCore. v5e has ~128 MiB of on-chip vector
# memory headline, but the compiler owns a share; kernels plan against 16 MiB
# unless told otherwise (the paper plans against its 128 KiB TCDM the same way).
DEFAULT_VMEM_BUDGET = 16 * 1024 * 1024
LANE = 128  # lane count: last-dim alignment for the VPU/MXU
MIN_BURST_ELEMS = 8  # paper §4.1.3: innermost dim >= 8 elems => bursts >= 32 B


def sublane(dtype_bytes: int) -> int:
    """Second-to-last dim packing for a dtype (8 for fp32, 16 for bf16...)."""
    return max(8, 32 // dtype_bytes)


@dataclass(frozen=True)
class MatmulTilePlan:
    """Block shapes for C[M,N] += A[M,K] @ B[K,N] with an fp32 accumulator."""

    bm: int
    bn: int
    bk: int
    vmem_bytes: int
    grid: tuple[int, int, int]  # (m_tiles, n_tiles, k_tiles)

    @property
    def arithmetic_intensity(self) -> float:
        """flops per HBM byte moved for one (bm,bn) output tile."""
        flops = 2 * self.bm * self.bn * self.bk * self.grid[2]
        k = self.bk * self.grid[2]
        bytes_moved = (self.bm * k + k * self.bn) * 2 + self.bm * self.bn * 4
        return flops / bytes_moved


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _round_down_pow2_mult(x: int, m: int) -> int:
    """Largest multiple of m that is <= x (at least m)."""
    return max(m, (x // m) * m)


def plan_matmul_tiles(
    m: int,
    n: int,
    k: int,
    in_dtype_bytes: int = 2,
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
    acc_bytes: int = 4,
) -> MatmulTilePlan:
    """Choose MXU-aligned (bm, bn, bk) fitting double-buffered VMEM.

    Footprint (Pallas pipeline double-buffers inputs, accumulator is single):
        2*(bm*bk + bk*bn)*in_bytes + bm*bn*acc_bytes  <=  budget

    Strategy mirrors the paper's tiling goals: maximize reuse (big bm x bn
    output tile => each A/B byte used bn/bm times) while keeping bursts long
    (bk spans the full K when it fits, so the innermost stream is contiguous).
    """
    bm = _round_down_pow2_mult(min(m, 512), LANE)
    bn = _round_down_pow2_mult(min(n, 512), LANE)
    bk = _round_down_pow2_mult(min(k, 2048), LANE)

    def fits(bm, bn, bk):
        return 2 * (bm * bk + bk * bn) * in_dtype_bytes + bm * bn * acc_bytes <= vmem_budget

    # Shrink greedily: K first (reuse is insensitive to bk), then the larger
    # of bm/bn, never below one MXU tile.
    while not fits(bm, bn, bk):
        if bk > LANE:
            bk //= 2
        elif bm >= bn and bm > LANE:
            bm //= 2
        elif bn > LANE:
            bn //= 2
        else:
            break
    grid = (_round_up(m, bm) // bm, _round_up(n, bn) // bn, _round_up(k, bk) // bk)
    vmem = 2 * (bm * bk + bk * bn) * in_dtype_bytes + bm * bn * acc_bytes
    return MatmulTilePlan(bm=bm, bn=bn, bk=bk, vmem_bytes=vmem, grid=grid)


@dataclass(frozen=True)
class StencilTilePlan:
    """Tile for a stencil (conv/pool) op over an NHWC tensor (paper §3.1)."""

    th: int  # tile height (output rows)
    tw: int  # tile width (output cols)
    halo: int  # overlap rows/cols needed from neighbours (kernel-1)
    vmem_bytes: int
    burst_elems: int  # innermost contiguous run (>= MIN_BURST_ELEMS)


def plan_stencil_tiles(
    h: int,
    w: int,
    cin: int,
    cout: int,
    kh: int,
    kw: int,
    dtype_bytes: int = 4,
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
) -> StencilTilePlan:
    """Pick an output tile (th, tw) so in+out+weights double-buffer in VMEM.

    The channel dim stays whole (it is the innermost, contiguous one — this is
    what keeps DMA bursts long, paper Fig. 11) and we shrink spatial dims.
    """
    halo = max(kh, kw) - 1
    th, tw = min(h, 64), min(w, 64)

    def fits(th, tw):
        inp = (th + halo) * (tw + halo) * cin
        out = th * tw * cout
        wgt = kh * kw * cin * cout
        return (2 * inp + 2 * out + wgt) * dtype_bytes <= vmem_budget

    while not fits(th, tw) and (th > 1 or tw > 1):
        if tw >= th and tw > 1:
            tw = max(1, tw // 2)
        else:
            th = max(1, th // 2)
    inp = (th + halo) * (tw + halo) * cin
    out = th * tw * cout
    wgt = kh * kw * cin * cout
    return StencilTilePlan(
        th=th,
        tw=tw,
        halo=halo,
        vmem_bytes=(2 * inp + 2 * out + wgt) * dtype_bytes,
        burst_elems=max(cin, MIN_BURST_ELEMS),
    )

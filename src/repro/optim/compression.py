"""Gradient compression with error feedback (beyond-paper extension).

The paper streams the full 300 MB fp32 weight update over the mesh (§4.9).
A modern large-scale trick the paper explicitly leaves to future work
("compression techniques offer other interesting angles", §6): quantize the
cross-pod gradient stream to int8 with *error feedback*, cutting the slowest
(inter-pod) hop's bytes 4x while keeping SGD convergence (the residual is
re-injected next step, so the compression error is zero-mean over time).

Used by the "compressed" grad_sync mode of the train step; the collective
roofline term of the pod axis drops accordingly (see EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray):
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_with_feedback(grads, err_state):
    """EF-SGD compression: g_hat = Q(g + e); e' = g + e - g_hat.

    Returns (compressed fp32 grads — exactly representable in int8*scale —
    plus the payload tree (q, scale) a transport layer would ship, and the
    new error state).
    """

    def one(g, e):
        x = g.astype(jnp.float32) + e
        q, scale = quantize_int8(x)
        ghat = dequantize_int8(q, scale)
        return ghat, (q, scale), x - ghat

    flat, treedef = jax.tree_util.tree_flatten(grads)
    eflat = jax.tree_util.tree_leaves(err_state)
    outs = [one(g, e) for g, e in zip(flat, eflat)]
    ghat = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
    payload = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
    new_err = jax.tree_util.tree_unflatten(treedef, [o[2] for o in outs])
    return ghat, payload, new_err

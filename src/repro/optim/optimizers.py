"""Optimizers: SGD(+momentum) — the paper's algorithm — and AdamW.

Functional optax-style interface kept dependency-free:

    opt = sgd(lr=..., momentum=...)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)

Optimizer state is the ZeRO-1-shardable tree (see parallel/sharding.py):
momenta/second moments are kept in fp32 regardless of param dtype (the paper's
full-precision-where-it-matters discipline, C1 at the optimizer level).
"""

from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable  # (grads, state, params) -> (updates, new_state)


def _f32(tree):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), tree)


def sgd(lr: float = 1e-2, momentum: float = 0.9, nesterov: bool = False) -> Optimizer:
    def init(params):
        return {"mu": _f32(params), "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params=None):
        del params
        mu = jax.tree.map(
            lambda m, g: momentum * m + g.astype(jnp.float32), state["mu"], grads
        )
        if nesterov:
            upd = jax.tree.map(lambda m, g: momentum * m + g.astype(jnp.float32), mu, grads)
        else:
            upd = mu
        updates = jax.tree.map(lambda u: -lr * u, upd)
        return updates, {"mu": mu, "count": state["count"] + 1}

    return Optimizer(init, update)


def adamw(
    lr: float = 3e-4,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
) -> Optimizer:
    def init(params):
        return {"m": _f32(params), "v": _f32(params), "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        c = state["count"] + 1
        m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state["m"], grads)
        v = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)), state["v"], grads
        )
        bc1 = 1 - b1**c.astype(jnp.float32)
        bc2 = 1 - b2**c.astype(jnp.float32)

        def upd(m, v, p):
            step = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            return -lr * (step + weight_decay * p.astype(jnp.float32))

        updates = jax.tree.map(upd, m, v, params)
        return updates, {"m": m, "v": v, "count": c}

    return Optimizer(init, update)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype), params, updates)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def get_optimizer(name: str, lr: float) -> Optimizer:
    if name == "sgd":
        return sgd(lr=lr)
    if name == "adamw":
        return adamw(lr=lr)
    raise ValueError(f"unknown optimizer {name!r}")

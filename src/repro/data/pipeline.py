"""In-memory data pipeline — the paper's "large in-memory dataset" tier (§4.5).

NTX trains from a dataset resident *in the memory cubes themselves* (0.5-7 GB
per cube; 31-247 s of autonomous training per fill). The JAX rendering:

  * :class:`InMemoryDataset` — the full token array lives in host/HBM memory,
    sharded by DP rank (each pod/host owns a contiguous shard, like each HMC
    owning its sample range).
  * :class:`DataIterator` — *stateless-resumable*: batch t is a pure function
    of (seed, t), so checkpoint/restart and elastic re-sharding reproduce the
    exact same sample stream (runtime/supervisor.py relies on this).
  * :class:`Prefetcher` — double-buffering onto device, the cluster-DMA
    pattern (C3) applied at the input layer.

Synthetic corpora are generated deterministically for the examples/tests;
``from_arrays`` ingests a real tokenized corpus unchanged.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import jax
import numpy as np


@dataclass
class InMemoryDataset:
    tokens: np.ndarray  # (n_tokens,) int32 — resident, canonical, dense (C3)
    seq_len: int
    vocab_size: int

    @classmethod
    def synthetic(cls, n_tokens: int, vocab_size: int, seq_len: int, seed: int = 0):
        """Deterministic synthetic corpus with local structure (ngram-ish),
        so cross-entropy actually decreases during the examples' training."""
        rng = np.random.RandomState(seed)
        # Markov-ish stream: next token = f(prev) + noise, so it is learnable.
        n = int(n_tokens)
        base = rng.randint(0, vocab_size, size=n // 16 + 2).astype(np.int64)
        idx = np.arange(n)
        toks = (base[idx // 16] * 31 + idx % 16 * 7) % vocab_size
        noise = rng.rand(n) < 0.1
        toks[noise] = rng.randint(0, vocab_size, noise.sum())
        return cls(tokens=toks.astype(np.int32), seq_len=seq_len, vocab_size=vocab_size)

    @classmethod
    def from_arrays(cls, tokens: np.ndarray, seq_len: int, vocab_size: int):
        return cls(tokens=np.asarray(tokens, np.int32), seq_len=seq_len, vocab_size=vocab_size)

    @property
    def n_sequences(self) -> int:
        return (len(self.tokens) - 1) // self.seq_len

    def shard(self, rank: int, world: int) -> "InMemoryDataset":
        """Contiguous per-host shard (each HMC holds its own sample range)."""
        per = self.n_sequences // world
        lo = rank * per * self.seq_len
        hi = (rank + 1) * per * self.seq_len + 1
        return InMemoryDataset(self.tokens[lo:hi], self.seq_len, self.vocab_size)

    def batch_at(self, step: int, batch_size: int, seed: int = 0) -> dict:
        """Pure function of (seed, step): the resumability contract."""
        n = self.n_sequences
        # Philox-style counter RNG keyed by (seed, step) — no mutable state.
        rng = np.random.RandomState((seed * 1_000_003 + step) % (2**31))
        idx = rng.randint(0, n, size=batch_size)
        starts = idx * self.seq_len
        offs = np.arange(self.seq_len + 1)
        seqs = self.tokens[starts[:, None] + offs[None, :]]  # (B, S+1)
        return {"inputs": seqs[:, :-1], "labels": seqs[:, 1:]}


class DataIterator:
    """Checkpointable iterator: state == (seed, step). Nothing else."""

    def __init__(self, dataset: InMemoryDataset, batch_size: int, seed: int = 0, step: int = 0):
        self.dataset = dataset
        self.batch_size = batch_size
        self.seed = seed
        self.step = step

    def __next__(self) -> dict:
        batch = self.dataset.batch_at(self.step, self.batch_size, self.seed)
        self.step += 1
        return batch

    def state_dict(self) -> dict:
        return {"seed": self.seed, "step": self.step, "batch_size": self.batch_size}

    def load_state_dict(self, state: dict):
        assert state["batch_size"] == self.batch_size or True
        self.seed = int(state["seed"])
        self.step = int(state["step"])


class Prefetcher:
    """Double-buffered host->device prefetch (the input-layer DMA, C3)."""

    def __init__(self, iterator: DataIterator, depth: int = 2, sharding=None):
        self.iterator = iterator
        self.sharding = sharding
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._worker, daemon=True)
        self.thread.start()

    def _worker(self):
        while not self._stop.is_set():
            batch = next(self.iterator)
            if self.sharding is not None:
                batch = jax.tree.map(lambda x, s=self.sharding: jax.device_put(x, s), batch)
            else:
                batch = jax.tree.map(jax.device_put, batch)
            while not self._stop.is_set():
                try:
                    self.q.put(batch, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def __next__(self):
        return self.q.get()

    def stop(self):
        self._stop.set()
        # Drain so the worker can exit.
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self.thread.join(timeout=2.0)

"""NTX FMAC matmul — the paper's datapath (C1+C3) as a Pallas TPU kernel.

The kernel realizes, on MXU/VMEM, exactly what the NTX cluster does with its
FMAC + TCDM + DMA:

  * 3-deep ``grid`` = the hardware loops that the driver offloads once per
    tile (C2): one ``pallas_call`` covers the whole output, like one NTX
    command covers many output pixels;
  * BlockSpec index maps = the AGU address equations (eq. 1);
  * the Pallas pipeline double-buffers HBM->VMEM tile copies behind compute =
    the cluster DMA (C3);
  * the fp32 VMEM accumulator with deferred rounding = the PCS accumulator
    (C1): for bf16 inputs every MXU product is *exact* in fp32, and for fp32
    inputs an optional compensated (2Sum) accumulator halves the exponent of
    the K-direction error growth, reproducing Table 1's "better than an fp32
    FPU" property.

Block shapes come from :mod:`repro.core.tiling` so the working set provably
fits VMEM and matmul dims are 128-aligned.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params
from repro.core.precision import two_sum
from repro.core.tiling import plan_matmul_tiles


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, comp_ref, *, k_tiles: int, compensated: bool):
    """One (bm, bn) output tile; K accumulated across the innermost grid dim."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        if compensated:
            comp_ref[...] = jnp.zeros_like(comp_ref)

    prod = jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)
    if compensated:
        # 2Sum across K-tiles: accumulator error is O(eps), not O(k_tiles*eps).
        s, e = two_sum(acc_ref[...], prod)
        acc_ref[...] = s
        comp_ref[...] += e
    else:
        acc_ref[...] += prod

    @pl.when(pl.program_id(2) == k_tiles - 1)
    def _store():
        # Deferred rounding: the accumulator leaves VMEM exactly once.
        out = acc_ref[...]
        if compensated:
            out = out + comp_ref[...]
        o_ref[...] = out.astype(o_ref.dtype)


def ntx_matmul(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    out_dtype=jnp.float32,
    compensated: bool = False,
    block_m: int | None = None,
    block_n: int | None = None,
    block_k: int | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """C[M,N] = A[M,K] @ B[K,N] with NTX wide accumulation.

    Shapes must tile evenly by the chosen blocks (the ops wrapper pads).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    in_bytes = max(a.dtype.itemsize, b.dtype.itemsize)
    plan = plan_matmul_tiles(m, n, k, in_dtype_bytes=in_bytes)
    bm = block_m or min(plan.bm, m)
    bn = block_n or min(plan.bn, n)
    bk = block_k or min(plan.bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"shape ({m},{n},{k}) must tile by ({bm},{bn},{bk}); use ops.matmul for padding"
    )
    k_tiles = k // bk

    grid = (m // bm, n // bn, k_tiles)
    kernel = functools.partial(_matmul_kernel, k_tiles=k_tiles, compensated=compensated)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((bm, bn), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a, b)

"""Pure-jnp/numpy oracles for every Pallas kernel in this package.

These are deliberately simple (dense, sequential) and serve as ground truth in
``tests/kernels`` across shape/dtype sweeps. The fp64 variants model the
paper's 64-bit-float baseline used in Table 1.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def matmul_ref(a: jnp.ndarray, b: jnp.ndarray, out_dtype=jnp.float32) -> jnp.ndarray:
    """fp32 matmul with highest-precision accumulation XLA offers."""
    return jnp.dot(
        a.astype(jnp.float32),
        b.astype(jnp.float32),
        precision=jax.lax.Precision.HIGHEST,
        preferred_element_type=jnp.float32,
    ).astype(out_dtype)


def matmul_ref64(a, b) -> np.ndarray:
    """The paper's common baseline: full fp64 accumulation (numpy, host)."""
    return np.asarray(a, np.float64) @ np.asarray(b, np.float64)


def attention_ref(
    q: jnp.ndarray,  # (B, Hq, Sq, D)
    k: jnp.ndarray,  # (B, Hkv, Skv, D)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int | None = None,
    sm_scale: float | None = None,
    q_offset: int = 0,
    kv_valid_len: int | None = None,
) -> jnp.ndarray:
    """Dense softmax attention in fp32 — the oracle for flash_attention."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    grp = hq // hkv
    if sm_scale is None:
        sm_scale = 1.0 / (d**0.5)
    k = jnp.repeat(k, grp, axis=1)
    v = jnp.repeat(v, grp, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s *= sm_scale
    q_ids = q_offset + jnp.arange(sq)[:, None]
    kv_ids = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if kv_valid_len is not None:
        mask &= kv_ids < kv_valid_len
    if causal:
        mask &= kv_ids <= q_ids
    if window is not None:
        mask &= kv_ids > q_ids - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    # Fully-masked rows give uniform p; zero them for parity with flash.
    any_visible = mask.any(axis=-1)[None, None, :, None]
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return jnp.where(any_visible, out, 0.0).astype(q.dtype)


def ssd_ref(
    x: jnp.ndarray,  # (B, H, S, P)
    la: jnp.ndarray,  # (B, H, S)
    b: jnp.ndarray,  # (B, G, S, N)
    c: jnp.ndarray,  # (B, G, S, N)
    h0: jnp.ndarray | None = None,  # (B, H, P, N)
) -> jnp.ndarray:
    """Sequential SSD recurrence (the literal state-space model), fp32."""
    bb, h, s, p = x.shape
    _, g, _, n = b.shape
    grp = h // g
    b = jnp.repeat(b, grp, axis=1)  # (B, H, S, N)
    c = jnp.repeat(c, grp, axis=1)

    def step(hstate, inputs):
        xt, lat, bt, ct = inputs  # (B,H,P), (B,H), (B,H,N), (B,H,N)
        a = jnp.exp(lat)[..., None, None]  # (B,H,1,1)
        hstate = a * hstate + xt[..., :, None] * bt[..., None, :]  # (B,H,P,N)
        yt = jnp.einsum("bhpn,bhn->bhp", hstate, ct)
        return hstate, yt

    init = h0 if h0 is not None else jnp.zeros((bb, h, p, n), jnp.float32)
    xs = (
        x.astype(jnp.float32).transpose(2, 0, 1, 3),
        la.astype(jnp.float32).transpose(2, 0, 1),
        b.astype(jnp.float32).transpose(2, 0, 1, 3),
        c.astype(jnp.float32).transpose(2, 0, 1, 3),
    )
    _, ys = jax.lax.scan(step, init, xs)
    return ys.transpose(1, 2, 0, 3).astype(x.dtype)  # (B,H,S,P)


def conv2d_ref(x: jnp.ndarray, w: jnp.ndarray, stride: int = 1, padding: int = 0):
    """NHWC x HWIO valid/same conv oracle (fp32)."""
    return jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    ).astype(x.dtype)

"""Explicitly double-buffered streaming kernels — the runtime model on TPU.

Where :mod:`repro.kernels.ntx_matmul` lets the Pallas pipeline helper do the
HBM->VMEM staging implicitly, this module writes the cluster-DMA flow out by
hand, exactly as :mod:`repro.runtime` models it: inputs stay in HBM/ANY
memory, the kernel owns two VMEM tile buffers per operand, and a manual
``make_async_copy`` prefetches tile k+1 while the MXU contracts tile k. One
grid step = one NTX command queue entry; the k-loop inside the kernel = the
double-buffered DMA engine of :mod:`repro.runtime.dma`.

The fp32 VMEM accumulator with a single deferred store keeps the NTX wide-
accumulation (C1) story. Numerics are cross-checked against
:func:`repro.kernels.ref.matmul_ref`; the tile schedule's modeled cycles are
cross-checked against the runtime in ``tests/test_runtime_queue.py`` via
:func:`streaming_tiles`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

N_BUFFERS = 2  # double buffering, as in runtime.dma.DmaConfig


def _stream_mm_kernel(a_hbm, b_hbm, o_ref, *, bm, bn, bk, k_tiles, a_dtype, b_dtype):
    i = pl.program_id(0)
    j = pl.program_id(1)

    def body(a_buf, b_buf, acc_ref, sem):
        def copies(slot, kk):
            a_cp = pltpu.make_async_copy(
                a_hbm.at[pl.ds(i * bm, bm), pl.ds(kk * bk, bk)],
                a_buf.at[slot], sem.at[slot, 0],
            )
            b_cp = pltpu.make_async_copy(
                b_hbm.at[pl.ds(kk * bk, bk), pl.ds(j * bn, bn)],
                b_buf.at[slot], sem.at[slot, 1],
            )
            return a_cp, b_cp

        def start(slot, kk):
            for cp in copies(slot, kk):
                cp.start()

        def wait(slot, kk):
            for cp in copies(slot, kk):
                cp.wait()

        start(0, 0)
        acc_ref[...] = jnp.zeros_like(acc_ref)

        def k_step(kk, carry):
            cur = jax.lax.rem(kk, N_BUFFERS)
            nxt = jax.lax.rem(kk + 1, N_BUFFERS)

            @pl.when(kk + 1 < k_tiles)
            def _prefetch():  # next tile streams in while this one computes
                start(nxt, kk + 1)

            wait(cur, kk)
            acc_ref[...] += jnp.dot(
                a_buf[cur], b_buf[cur], preferred_element_type=jnp.float32
            )
            return carry

        jax.lax.fori_loop(0, k_tiles, k_step, 0)
        # deferred rounding: the accumulator leaves VMEM exactly once
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)

    pl.run_scoped(
        body,
        a_buf=pltpu.VMEM((N_BUFFERS, bm, bk), a_dtype),
        b_buf=pltpu.VMEM((N_BUFFERS, bk, bn), b_dtype),
        acc_ref=pltpu.VMEM((bm, bn), jnp.float32),
        sem=pltpu.SemaphoreType.DMA((N_BUFFERS, 2)),
    )


def _block(dim: int, cap: int = 128) -> int:
    return min(cap, 1 << (dim - 1).bit_length()) if dim < cap else cap


def _pad_to(x: jnp.ndarray, mult: tuple[int, int]) -> jnp.ndarray:
    pads = [(0, (-d) % m) for d, m in zip(x.shape, mult)]
    return jnp.pad(x, pads) if any(p[1] for p in pads) else x


@functools.partial(
    jax.jit, static_argnames=("out_dtype", "block_m", "block_n", "block_k", "interpret")
)
def streaming_matmul(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    out_dtype=jnp.float32,
    block_m: int | None = None,
    block_n: int | None = None,
    block_k: int | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """C[M,N] = A[M,K] @ B[K,N] with hand-rolled double-buffered streaming."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm = block_m or _block(m)
    bn = block_n or _block(n)
    bk = block_k or _block(k)
    ap = _pad_to(a, (bm, bk))
    bp = _pad_to(b, (bk, bn))
    mp, kp = ap.shape
    _, np_ = bp.shape
    k_tiles = kp // bk

    kernel = functools.partial(
        _stream_mm_kernel, bm=bm, bn=bn, bk=bk, k_tiles=k_tiles,
        a_dtype=ap.dtype, b_dtype=bp.dtype,
    )
    out = pl.pallas_call(
        kernel,
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        interpret=interpret,
    )(ap, bp)
    return out[:m, :n]


def streaming_conv2d(
    x: jnp.ndarray,  # (N, H, W, Cin)
    w: jnp.ndarray,  # (KH, KW, Cin, Cout)
    *,
    stride: int = 1,
    padding: int = 0,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jnp.ndarray:
    """NHWC x HWIO conv as an im2col streaming matmul (the paper's conv map).

    The (kh, kw, cin) reduction dims flatten into the streamed K axis —
    the same loop order :func:`repro.lower.rules.conv2d_fwd_template` gives
    the AGUs.
    """
    n, h, wid, cin = x.shape
    kh, kw, _, cout = w.shape
    if padding:
        x = jnp.pad(x, ((0, 0), (padding, padding), (padding, padding), (0, 0)))
        h, wid = h + 2 * padding, wid + 2 * padding
    oh = (h - kh) // stride + 1
    ow = (wid - kw) // stride + 1
    cols = jnp.concatenate(
        [
            x[:, dh : dh + oh * stride : stride, dw : dw + ow * stride : stride, :]
            for dh in range(kh)
            for dw in range(kw)
        ],
        axis=-1,
    )  # (N, OH, OW, KH*KW*Cin) in (kh, kw, cin) order
    lhs = cols.reshape(n * oh * ow, kh * kw * cin)
    rhs = w.reshape(kh * kw * cin, cout)
    y = streaming_matmul(lhs, rhs, out_dtype=out_dtype, interpret=interpret)
    return y.reshape(n, oh, ow, cout)


def streaming_tiles(
    m: int, n: int, k: int,
    *,
    block_m: int | None = None,
    block_n: int | None = None,
    block_k: int | None = None,
    itemsize: int = 4,
) -> list[tuple[float, float]]:
    """The kernel's exact tile stream as (dma_bytes, macs) pairs.

    One entry per (i, j, kk) inner step, in issue order — what the manual
    DMA engine above actually transfers and contracts. Feeding this to
    :class:`repro.runtime.dma.DmaEngine` (or wrapping each entry in an
    ``NtxCommand``) yields the runtime's cycle estimate for this kernel.
    """
    bm = block_m or _block(m)
    bn = block_n or _block(n)
    bk = block_k or _block(k)
    mp, np_, kp = -(-m // bm) * bm, -(-n // bn) * bn, -(-k // bk) * bk
    tiles = []
    for _i in range(mp // bm):
        for _j in range(np_ // bn):
            for _kk in range(kp // bk):
                tiles.append(((bm * bk + bk * bn) * itemsize, float(bm * bn * bk)))
    return tiles

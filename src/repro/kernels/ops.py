"""Public jit'd wrappers around the Pallas kernels.

Backend policy (``backend=`` on every op):
  * ``"tpu"``       — the Pallas kernel (the production path).
  * ``"interpret"`` — the Pallas kernel body executed in Python on CPU
                      (correctness validation; what the tests sweep).
  * ``"xla"``       — a portable, *blockwise* jnp implementation with the same
                      memory behaviour (never materializes the full score
                      matrix / state history). This is what the CPU container
                      runs for training, and what the multi-pod dry-run lowers
                      (Pallas TPU kernels cannot lower on the CPU backend).
  * ``"auto"``      — "tpu" on TPU devices, else "xla".

The blockwise xla paths are differentiable (each KV/chunk step is rematerialized
in the backward pass — flash-attention-style recompute via ``jax.checkpoint``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import ntx_matmul as _mm
from repro.kernels import ssd_scan as _ssd


def _auto_backend() -> str:
    return "tpu" if jax.default_backend() == "tpu" else "xla"


def _resolve(backend: str) -> str:
    return _auto_backend() if backend == "auto" else backend


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------


def _pad_to(x: jnp.ndarray, mult: tuple[int, int]) -> jnp.ndarray:
    pads = [(0, (-d) % m) for d, m in zip(x.shape, mult)]
    if any(p[1] for p in pads):
        return jnp.pad(x, pads)
    return x


@functools.partial(jax.jit, static_argnames=("compensated", "out_dtype", "backend"))
def matmul(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    compensated: bool = False,
    out_dtype=jnp.float32,
    backend: str = "auto",
) -> jnp.ndarray:
    """NTX wide-accumulation matmul. Pads to MXU tiles as needed."""
    be = _resolve(backend)
    m, k = a.shape
    _, n = b.shape
    if be == "xla":
        return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(out_dtype)
    bm = min(128, 1 << (m - 1).bit_length()) if m < 128 else 128
    bn = min(128, 1 << (n - 1).bit_length()) if n < 128 else 128
    bk = min(128, 1 << (k - 1).bit_length()) if k < 128 else 128
    ap = _pad_to(a, (bm, bk))
    bp = _pad_to(b, (bk, bn))
    out = _mm.ntx_matmul(
        ap,
        bp,
        out_dtype=out_dtype,
        compensated=compensated,
        block_m=bm,
        block_n=bn,
        block_k=bk,
        interpret=(be == "interpret"),
    )
    return out[:m, :n]


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def _blockwise_attention_xla(
    q: jnp.ndarray,  # (B, Hq, Sq, D)
    k: jnp.ndarray,  # (B, Hkv, Skv, D)
    v: jnp.ndarray,
    *,
    causal: bool,
    window: int | None,
    sm_scale: float,
    q_offset,
    kv_valid_len,
    block_kv: int,
) -> jnp.ndarray:
    """Online-softmax attention scanning KV blocks; GQA grouped (no KV repeat)."""
    bsz, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    grp = hq // hkv
    # Tensors stay in the input dtype (bf16 in production) — exactly like the
    # Pallas kernel: only score/normalizer statistics are carried in fp32.
    # This keeps every resharding collective on 2-byte payloads (§Perf).
    qf = q.reshape(bsz, hkv, grp, sq, d)

    block_kv = min(block_kv, skv)
    pad = (-skv) % block_kv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nblk = k.shape[2] // block_kv
    kb = k.reshape(bsz, hkv, nblk, block_kv, d).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(bsz, hkv, nblk, block_kv, d).transpose(2, 0, 1, 3, 4)

    q_ids = q_offset + jnp.arange(sq)  # (Sq,) — q_offset may be traced
    valid = skv if kv_valid_len is None else kv_valid_len

    @jax.checkpoint
    def step(carry, inputs):
        m_p, l_p, acc = carry
        kblk, vblk, kv0 = inputs  # (B,Hkv,bkv,D) x2, scalar block start
        s = (
            jnp.einsum(
                "bkgqd,bkjd->bkgqj", qf, kblk, preferred_element_type=jnp.float32
            )
            * sm_scale
        )
        kv_ids = kv0 + jnp.arange(block_kv)  # (bkv,)
        mask = (kv_ids[None, :] < valid) | jnp.zeros((sq, 1), bool)
        if causal:
            mask &= kv_ids[None, :] <= q_ids[:, None]
        if window is not None:
            mask &= kv_ids[None, :] > q_ids[:, None] - window
        s = jnp.where(mask[None, None, None], s, -1e30)
        m_c = jnp.max(s, axis=-1)
        m_n = jnp.maximum(m_p, m_c)
        # Avoid NaN from (-inf) - (-inf) on fully-masked prefixes.
        safe_m = jnp.where(m_n <= -1e29, 0.0, m_n)
        p = jnp.exp(s - safe_m[..., None])
        p = jnp.where(mask[None, None, None], p, 0.0)
        alpha = jnp.exp(jnp.where(m_p <= -1e29, -jnp.inf, m_p - safe_m))
        l_n = l_p * alpha + p.sum(-1)
        # p rounded to the value dtype before the MXU matmul (as on TPU);
        # the accumulator stays fp32.
        acc = acc * alpha[..., None] + jnp.einsum(
            "bkgqj,bkjd->bkgqd", p.astype(vblk.dtype), vblk,
            preferred_element_type=jnp.float32,
        )
        return (m_n, l_n, acc), None

    m0 = jnp.full((bsz, hkv, grp, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((bsz, hkv, grp, sq), jnp.float32)
    a0 = jnp.zeros((bsz, hkv, grp, sq, d), jnp.float32)
    kv_starts = jnp.arange(nblk) * block_kv
    (m_f, l_f, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kb, vb, kv_starts))
    l_f = jnp.where(l_f == 0.0, 1.0, l_f)
    out = (acc / l_f[..., None]).reshape(bsz, hq, sq, d)
    return out.astype(q.dtype)


def _windowed_attention_xla(q, k, v, *, window: int, sm_scale: float, block_q: int):
    """Sliding-window attention that only visits in-window KV (H5, §Perf).

    The generic blockwise path scans *all* KV blocks and masks, wasting
    S/window-fold compute for local-attention layers at long S. Here each
    q-block dynamic-slices just its (window + block_q)-sized KV span, making
    prefill cost O(S * window) — matching what the Pallas kernel's block
    skipping achieves on TPU.
    """
    b, hq, s, d = q.shape
    _, hkv, skv, _ = k.shape
    grp = hq // hkv
    block_q = min(block_q, s)
    assert s % block_q == 0, (s, block_q)
    span = min(window + block_q, skv)
    nq = s // block_q
    qf = q.astype(jnp.float32).reshape(b, hkv, grp, s, d)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    @jax.checkpoint
    def one(_, qi):
        qstart = qi * block_q
        s0 = jnp.clip(qstart + block_q - span, 0, skv - span)
        qb = jax.lax.dynamic_slice_in_dim(qf, qstart, block_q, axis=3)
        kb = jax.lax.dynamic_slice_in_dim(kf, s0, span, axis=2)
        vb = jax.lax.dynamic_slice_in_dim(vf, s0, span, axis=2)
        sc = jnp.einsum("bkgqd,bkjd->bkgqj", qb, kb) * sm_scale
        q_ids = qstart + jnp.arange(block_q)
        kv_ids = s0 + jnp.arange(span)
        mask = (kv_ids[None, :] <= q_ids[:, None]) & (
            kv_ids[None, :] > q_ids[:, None] - window
        )
        sc = jnp.where(mask[None, None, None], sc, -1e30)
        p = jax.nn.softmax(sc, axis=-1)
        ob = jnp.einsum("bkgqj,bkjd->bkgqd", p, vb)
        return None, ob

    _, blocks = jax.lax.scan(one, None, jnp.arange(nq))  # (nq,B,Hkv,G,bq,D)
    out = blocks.transpose(1, 2, 3, 0, 4, 5).reshape(b, hq, s, d)
    return out.astype(q.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "sm_scale", "block_q", "block_kv", "backend",
                     "windowed"),
)
def attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int | None = None,
    sm_scale: float | None = None,
    q_offset=0,
    kv_valid_len=None,
    block_q: int = 128,
    block_kv: int = 128,
    backend: str = "auto",
    windowed: bool = False,
) -> jnp.ndarray:
    """Flash attention with GQA + causal/sliding-window masking.

    ``q_offset``/``kv_valid_len`` may be traced scalars (decode path).
    ``windowed=True`` uses the window-limited KV scan (H5) on the xla path.
    """
    d = q.shape[-1]
    if sm_scale is None:
        sm_scale = 1.0 / (d**0.5)
    be = _resolve(backend)
    if (
        windowed and window is not None and be == "xla"
        and isinstance(q_offset, int) and q_offset == 0 and kv_valid_len is None
    ):
        return _windowed_attention_xla(
            q, k, v, window=window, sm_scale=sm_scale, block_q=max(block_q, 256)
        )
    if be in ("tpu", "interpret"):
        assert isinstance(q_offset, int) and q_offset == 0 and kv_valid_len is None, (
            "the Pallas kernel currently serves the q_offset=0 full-cache case; "
            "decode uses the blockwise path"
        )
        return _fa.flash_attention(
            q,
            k,
            v,
            causal=causal,
            window=window,
            sm_scale=sm_scale,
            block_q=block_q,
            block_kv=block_kv,
            interpret=(be == "interpret"),
        )
    return _blockwise_attention_xla(
        q,
        k,
        v,
        causal=causal,
        window=window,
        sm_scale=sm_scale,
        q_offset=q_offset,
        kv_valid_len=kv_valid_len,
        block_kv=block_kv,
    )


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------


def _ssd_chunked_xla(x, la, b, c, *, chunk: int, h0=None):
    """Chunked dual-form SSD in portable jnp (scan over chunks)."""
    bb, h, s, p = x.shape
    _, g, _, n = b.shape
    grp = h // g
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    xf = x.astype(jnp.float32).reshape(bb, h, nc, chunk, p).transpose(2, 0, 1, 3, 4)
    laf = la.astype(jnp.float32).reshape(bb, h, nc, chunk).transpose(2, 0, 1, 3)
    bf = b.astype(jnp.float32).reshape(bb, g, nc, chunk, n).transpose(2, 0, 1, 3, 4)
    cf = c.astype(jnp.float32).reshape(bb, g, nc, chunk, n).transpose(2, 0, 1, 3, 4)

    causal = jnp.tril(jnp.ones((chunk, chunk), bool))

    @jax.checkpoint
    def step(hstate, inputs):
        xc, lac, bc, cc = inputs  # (B,H,Q,P) (B,H,Q) (B,G,Q,N) (B,G,Q,N)
        cum = jnp.cumsum(lac, axis=-1)  # (B,H,Q) inclusive
        total = cum[..., -1]  # (B,H)
        # intra (grouped to avoid repeating b/c across the head group)
        cumg = cum.reshape(bb, g, grp, chunk)
        scores = jnp.einsum("bgin,bgjn->bgij", cc, bc)  # (B,G,Q,Q)
        decay = jnp.exp(cumg[..., :, None] - cumg[..., None, :])  # (B,G,grp,Q,Q)
        decay = jnp.where(causal, decay, 0.0)
        xg = xc.reshape(bb, g, grp, chunk, p)
        y = jnp.einsum("bgij,bgkij,bgkjp->bgkip", scores, decay, xg)
        # inter
        hg = hstate.reshape(bb, g, grp, p, n)
        y += jnp.exp(cumg)[..., None] * jnp.einsum("bgin,bgkpn->bgkip", cc, hg)
        # state update
        w = jnp.exp(total.reshape(bb, g, grp)[..., None] - cumg)[..., None] * bc[:, :, None]
        hstate = jnp.exp(total)[..., None, None] * hstate + jnp.einsum(
            "bgkip,bgkin->bgkpn", xg, w
        ).reshape(bb, h, p, n)
        return hstate, y.reshape(bb, h, chunk, p)

    init = h0 if h0 is not None else jnp.zeros((bb, h, p, n), jnp.float32)
    hfinal, ys = jax.lax.scan(step, init, (xf, laf, bf, cf))
    y = ys.transpose(1, 2, 0, 3, 4).reshape(bb, h, s, p)
    return y.astype(x.dtype), hfinal


@functools.partial(jax.jit, static_argnames=("chunk", "backend", "return_state"))
def ssd(
    x: jnp.ndarray,
    la: jnp.ndarray,
    b: jnp.ndarray,
    c: jnp.ndarray,
    *,
    chunk: int = 128,
    backend: str = "auto",
    return_state: bool = False,
):
    """Mamba-2 SSD scan. Returns y (and the final state if requested)."""
    be = _resolve(backend)
    if be in ("tpu", "interpret") and not return_state:
        y = _ssd.ssd_scan(x, la, b, c, chunk=chunk, interpret=(be == "interpret"))
        return y
    y, h = _ssd_chunked_xla(x, la, b, c, chunk=chunk)
    return (y, h) if return_state else y

"""Public kernel entry points.

Callers import ops from here (``from repro.kernels import matmul``) instead
of reaching into the implementation modules: :mod:`repro.kernels.ops` owns
the backend policy ("tpu" / "interpret" / "xla" / "auto") and
:mod:`repro.kernels.streaming` the hand-rolled double-buffered variants that
mirror the runtime's DMA model. :mod:`repro.kernels.ref` stays importable as
a module — it is the oracle package for the test-suite, not a serving path.
"""

from repro.kernels.ops import attention, matmul, ssd
from repro.kernels.streaming import (
    streaming_conv2d,
    streaming_matmul,
    streaming_tiles,
)

__all__ = [
    "attention",
    "matmul",
    "ssd",
    "streaming_conv2d",
    "streaming_matmul",
    "streaming_tiles",
]

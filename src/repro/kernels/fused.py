"""Fused-region Pallas kernels: one kernel per contiguous train-step chain.

:mod:`repro.lower.fuse` groups contiguous compatible node passes of a
train-step :class:`~repro.lower.ir.NtxProgram` into
:class:`~repro.lower.fuse.RegionSpec` regions; this module compiles each
region into ONE ``pallas_call`` — the software analogue of the NTX datapath
streaming a whole loop nest through the FMAC pipeline instead of taking a
per-op offload round trip.

The kernel shape follows :mod:`repro.kernels.streaming`'s hand-rolled DMA
idiom, lifted from the k-loop to the batch-tile grid:

  * the grid walks batch tiles; every *streamed* (batched) region input
    owns two VMEM tile buffers and a ``make_async_copy`` prefetches tile
    k+1 out of ANY/HBM while tile k computes;
  * params and momentum state ride in as resident VMEM blocks;
  * every intermediate edge of the region lives in kernel scratch values —
    conv pre-activations, relu masks, im2col columns never touch HBM;
  * cross-batch dW reductions accumulate in VMEM scratch across grid steps
    and the SGD/momentum update runs as the last grid step's epilogue, so
    a fwd or bwd chain plus its update is one dispatch.

Convolutions are expressed per tile as statically-unrolled im2col plus an
MXU ``jnp.dot`` with an fp32 accumulator (the NTX wide-accumulation story);
the input gradient is the transposed conv — dilate dy by the stride, pad by
``k-1-p``, correlate with the rotated kernel — all inside the same tile.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.lower.rules import (
    BiasSpec,
    Conv2dSpec,
    FlattenSpec,
    MatmulSpec,
    MaxPool2dSpec,
    ReluSpec,
    SoftmaxXentSpec,
)

N_BUFFERS = 2  # double buffering, as in kernels.streaming / runtime.dma


def _batch_block(batch: int) -> int:
    """Batch-tile size: two grid steps when the batch splits evenly.

    Small tiles keep the per-tile im2col slices cheap (the measured cost
    center) while two grid steps give the prefetch something to overlap.
    """
    if batch >= 4 and batch % 2 == 0:
        return batch // 2
    return batch


def _pad_hw(x, ph: int, pw: int):
    if ph or pw:
        return jnp.pad(x, ((0, 0), (ph, ph), (pw, pw), (0, 0)))
    return x


def _conv_cols(xp, spec: Conv2dSpec):
    """Unrolled im2col on a padded tile -> (bn*oh*ow, kh*kw*cin)."""
    bn = xp.shape[0]
    s = spec.stride
    oh, ow = spec.out_h, spec.out_w
    cols = jnp.concatenate(
        [
            xp[:, dh : dh + oh * s : s, dw : dw + ow * s : s, :]
            for dh in range(spec.kh)
            for dw in range(spec.kw)
        ],
        axis=-1,
    )
    return cols.reshape(bn * oh * ow, spec.kh * spec.kw * spec.cin)


def _conv_fwd_tile(x, w, spec: Conv2dSpec):
    p = spec.padding
    cols = _conv_cols(_pad_hw(x, p, p), spec)
    wf = w.reshape(spec.kh * spec.kw * spec.cin, spec.cout)
    y = jnp.dot(cols, wf, preferred_element_type=jnp.float32)
    return y.reshape(x.shape[0], spec.out_h, spec.out_w, spec.cout)


def _conv_dw_tile(x, dy, spec: Conv2dSpec):
    """This tile's dW contribution: cols(x)^T @ dy, batch in the contraction."""
    p = spec.padding
    cols = _conv_cols(_pad_hw(x, p, p), spec)
    dyf = dy.reshape(-1, spec.cout)
    dwf = jnp.dot(cols.T, dyf, preferred_element_type=jnp.float32)
    return dwf.reshape(spec.kh, spec.kw, spec.cin, spec.cout)


def _conv_dx_tile(dy, w, spec: Conv2dSpec):
    """Transposed conv per tile: dilate dy, pad by k-1-p, correlate rot180(w)."""
    bn = dy.shape[0]
    s, p = spec.stride, spec.padding
    oh, ow = spec.out_h, spec.out_w
    if s > 1:
        z = jnp.zeros(
            (bn, (oh - 1) * s + 1, (ow - 1) * s + 1, spec.cout), dy.dtype
        )
        z = z.at[:, ::s, ::s, :].set(dy)
    else:
        z = dy
    qh, qw = spec.kh - 1 - p, spec.kw - 1 - p
    rh = (spec.in_h + 2 * p - spec.kh) % s
    rw = (spec.in_w + 2 * p - spec.kw) % s
    z = jnp.pad(z, ((0, 0), (qh, qh + rh), (qw, qw + rw), (0, 0)))
    w_hat = w[::-1, ::-1, :, :].transpose(0, 1, 3, 2)  # (kh, kw, cout, cin)
    cols = jnp.concatenate(
        [
            z[:, dh : dh + spec.in_h, dw : dw + spec.in_w, :]
            for dh in range(spec.kh)
            for dw in range(spec.kw)
        ],
        axis=-1,
    ).reshape(bn * spec.in_h * spec.in_w, spec.kh * spec.kw * spec.cout)
    dxf = jnp.dot(
        cols, w_hat.reshape(-1, spec.cin), preferred_element_type=jnp.float32
    )
    return dxf.reshape(bn, spec.in_h, spec.in_w, spec.cin)


def _pool_fwd_tile(x, spec: MaxPool2dSpec):
    """window == stride max pool as a reshape-max (checked by the fuser)."""
    bn, h, w, c = x.shape
    k = spec.window
    return x.reshape(bn, h // k, k, w // k, k, c).max(axis=(2, 4))


def _pool_dx_tile(x, g, spec: MaxPool2dSpec):
    """Max-pool input gradient: first-match winner mask, row-major taps.

    Ties route the gradient to the first maximal tap in window order —
    the same tie-breaking as XLA's select-and-scatter, so the fused chain
    matches ``jax.vjp`` of ``reduce_window`` bit for bit.
    """
    bn, h, w, c = x.shape
    k = spec.window
    oh, ow = h // k, w // k
    xw = x.reshape(bn, oh, k, ow, k, c)
    y = xw.max(axis=(2, 4))
    eq = xw == y[:, :, None, :, None, :]
    taps = eq.transpose(0, 1, 3, 5, 2, 4).reshape(bn, oh, ow, c, k * k)
    first = taps & (jnp.cumsum(taps.astype(jnp.int32), axis=-1) == 1)
    dx = first.astype(g.dtype) * g[:, :, :, :, None]
    return (
        dx.reshape(bn, oh, ow, c, k, k)
        .transpose(0, 1, 4, 2, 5, 3)
        .reshape(bn, h, w, c)
    )


def _stage_flow(region, env):
    """Run the region's dataflow stages on one batch tile.

    ``env`` maps edge names to tile values (leading ``bn`` axis on batched
    edges); gains every intermediate and stage output. Returns ``(env,
    partials)`` where ``partials`` holds this tile's contribution to each
    cross-batch ``d_<param>`` reduction. ``upd`` stages run later, in
    :func:`_stage_updates`, once the reduction is complete.
    """
    partials = {}
    for st in region.stages:
        s = st.spec
        if st.pass_ == "fwd":
            x = env[st.in_edge]
            if isinstance(s, Conv2dSpec):
                y = _conv_fwd_tile(x, env[st.param], s)
            elif isinstance(s, MatmulSpec):
                y = jnp.dot(
                    x, env[st.param], preferred_element_type=jnp.float32
                )
            elif isinstance(s, BiasSpec):
                y = x + env[st.param]
            elif isinstance(s, ReluSpec):
                y = jnp.maximum(x, 0.0)
            elif isinstance(s, MaxPool2dSpec):
                y = _pool_fwd_tile(x, s)
            elif isinstance(s, FlattenSpec):
                y = x.reshape(x.shape[0], -1)
            else:
                raise TypeError(f"no fused fwd rule for {type(s).__name__}")
            env[st.out_edge] = y
        elif st.pass_ == "dw":
            g = env[f"d_{st.out_edge}"]
            if isinstance(s, Conv2dSpec):
                d = _conv_dw_tile(env[st.in_edge], g, s)
            elif isinstance(s, MatmulSpec):
                d = jnp.dot(
                    env[st.in_edge].T, g, preferred_element_type=jnp.float32
                )
            elif isinstance(s, BiasSpec):
                d = g.reshape(-1, s.c).sum(axis=0)
            else:
                raise TypeError(f"no fused dW rule for {type(s).__name__}")
            partials[f"d_{st.param}"] = d
        elif st.pass_ == "dx":
            if isinstance(s, SoftmaxXentSpec):
                # softmax-CE loss gradient (softmax(z) - onehot) / B: rows
                # are independent, so the batch-tile split is exact; the
                # 1/B scale uses the spec's global batch, and the onehot
                # labels arrive via the stage's param slot
                z = env[st.in_edge]
                env[f"d_{st.in_edge}"] = (
                    jax.nn.softmax(z, axis=-1) - env[st.param]
                ) / s.batch
                continue
            g = env[f"d_{st.out_edge}"]
            if isinstance(s, Conv2dSpec):
                dx = _conv_dx_tile(g, env[st.param], s)
            elif isinstance(s, MatmulSpec):
                dx = jnp.dot(
                    g, env[st.param].T, preferred_element_type=jnp.float32
                )
            elif isinstance(s, ReluSpec):
                # mask from the relu *output*: y > 0 iff x > 0, so the
                # pre-activation never has to escape its forward region
                dx = jnp.where(env[st.out_edge] > 0.0, g, 0.0)
            elif isinstance(s, MaxPool2dSpec):
                dx = _pool_dx_tile(env[st.in_edge], g, s)
            elif isinstance(s, FlattenSpec):
                dx = g.reshape((g.shape[0],) + tuple(s.in_shape))
            elif isinstance(s, BiasSpec):
                dx = g
            else:
                raise TypeError(f"no fused dX rule for {type(s).__name__}")
            env[f"d_{st.in_edge}"] = dx
        elif st.pass_ != "upd":
            raise TypeError(f"unknown pass {st.pass_!r} in fused region")
    return env, partials


def _stage_updates(region, totals, env):
    """SGD/momentum epilogue on the fully reduced dW totals."""
    outs = {}
    for st in region.stages:
        if st.pass_ != "upd":
            continue
        p = st.param
        # the gradient total normally accumulates in-region; when a spill
        # or chain barrier split the dw stage into an earlier region, the
        # already-reduced total arrives as a resident input instead
        dw = totals.get(f"d_{p}")
        if dw is None:
            dw = env[f"d_{p}"]
        if region.momentum:
            v_new = region.momentum * env[f"v_{p}"] + dw
            outs[f"v_{p}_new"] = v_new
        else:
            v_new = dw
        outs[f"{p}_new"] = env[p] - region.lr * v_new
    return outs


def build_region_callable(region, *, interpret: bool):
    """Compile one RegionSpec into a dict -> dict jax callable.

    The callable takes the region's input edges (batched activations /
    gradients plus resident params) and returns its escaping edges; it is
    what the :class:`~repro.lower.executors.PlanCache` jits under the
    region key, so the whole chain is one cached dispatch.
    """
    streamed = [n for n, b in region.inputs if b]
    resident = [n for n, b in region.inputs if not b]
    batched_outs = [n for n, k in region.outputs if k == "batched"]
    reduced_outs = [n for n, k in region.outputs if k == "reduced"]
    out_names = batched_outs + reduced_outs
    acc_names = [f"d_{st.param}" for st in region.stages if st.pass_ == "dw"]
    has_epilogue = bool(acc_names) or any(
        st.pass_ == "upd" for st in region.stages
    )
    n_s, n_r, n_o = len(streamed), len(resident), len(out_names)

    def fn(j):
        B = region.batch
        bn = _batch_block(B)
        grid = B // bn

        def probe(vals):
            env, partials = _stage_flow(region, dict(vals))
            return env, partials

        env_sh, part_sh = jax.eval_shape(probe, j)

        def out_struct(name):
            if name in part_sh:
                return part_sh[name]
            if name.endswith("_new"):
                base = name[:-4]
                return jax.ShapeDtypeStruct(j[base].shape, jnp.float32)
            return env_sh[name]

        in_specs = [pl.BlockSpec(memory_space=pltpu.ANY) for _ in streamed]
        for name in resident:
            shp = tuple(j[name].shape)
            in_specs.append(
                pl.BlockSpec(shp, _const_map(len(shp)))
            )
        out_specs, out_shape = [], []
        for name in batched_outs:
            shp = tuple(out_struct(name).shape)
            out_specs.append(
                pl.BlockSpec((bn,) + shp[1:], _lead_map(len(shp)))
            )
            out_shape.append(jax.ShapeDtypeStruct(shp, jnp.float32))
        for name in reduced_outs:
            shp = tuple(out_struct(name).shape)
            out_specs.append(pl.BlockSpec(shp, _const_map(len(shp))))
            out_shape.append(jax.ShapeDtypeStruct(shp, jnp.float32))

        scratch = [
            pltpu.VMEM((N_BUFFERS, bn) + tuple(j[name].shape[1:]), jnp.float32)
            for name in streamed
        ]
        if n_s:
            scratch.append(pltpu.SemaphoreType.DMA((N_BUFFERS, n_s)))
        scratch += [
            pltpu.VMEM(tuple(part_sh[name].shape), jnp.float32)
            for name in acc_names
        ]

        def kernel(*refs):
            srefs = refs[:n_s]
            rrefs = refs[n_s : n_s + n_r]
            orefs = refs[n_s + n_r : n_s + n_r + n_o]
            rest = refs[n_s + n_r + n_o :]
            bufs = rest[:n_s]
            sem = rest[n_s] if n_s else None
            accs = rest[n_s + (1 if n_s else 0) :]

            gi = pl.program_id(0)
            slot = jax.lax.rem(gi, N_BUFFERS)

            def copy_in(k, sl, idx):
                return pltpu.make_async_copy(
                    srefs[k].at[pl.ds(idx * bn, bn)],
                    bufs[k].at[sl],
                    sem.at[sl, k],
                )

            @pl.when(gi == 0)
            def _():
                for k in range(n_s):
                    copy_in(k, 0, 0).start()

            for k in range(n_s):
                copy_in(k, slot, gi).wait()

            @pl.when(gi + 1 < grid)
            def _():
                nxt = jax.lax.rem(gi + 1, N_BUFFERS)
                for k in range(n_s):
                    copy_in(k, nxt, gi + 1).start()

            env = {name: rrefs[i][...] for i, name in enumerate(resident)}
            for k, name in enumerate(streamed):
                env[name] = bufs[k][slot]
            env, partials = _stage_flow(region, env)

            if acc_names:

                @pl.when(gi == 0)
                def _():
                    for i in range(len(acc_names)):
                        accs[i][...] = jnp.zeros(accs[i].shape, jnp.float32)

                for i, name in enumerate(acc_names):
                    accs[i][...] += partials[name]

            for i, name in enumerate(batched_outs):
                orefs[i][...] = env[name]

            if has_epilogue:

                @pl.when(gi == grid - 1)
                def _():
                    totals = {
                        name: accs[i][...] for i, name in enumerate(acc_names)
                    }
                    upd = _stage_updates(region, totals, env)
                    for i, name in enumerate(reduced_outs):
                        oref = orefs[len(batched_outs) + i]
                        oref[...] = upd[name] if name in upd else totals[name]

        res = pl.pallas_call(
            kernel,
            grid=(grid,),
            in_specs=in_specs,
            out_specs=out_specs,
            out_shape=out_shape,
            scratch_shapes=scratch,
            interpret=interpret,
        )(*[j[n] for n in streamed], *[j[n] for n in resident])
        if not isinstance(res, (list, tuple)):
            res = (res,)
        return dict(zip(out_names, res))

    return fn


def _const_map(rank: int):
    return lambda i: (0,) * rank


def _lead_map(rank: int):
    return lambda i: (i,) + (0,) * (rank - 1)

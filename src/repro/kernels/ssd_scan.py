"""Mamba-2 SSD (state-space duality) chunked scan as a Pallas TPU kernel.

The SSD layer is itself a five-deep loop nest (batch, head, chunk, position,
state) — precisely the shape of an NtxCommand (C2) — and its chunked "dual
form" is the NTX streaming pattern: a quadratic-in-chunk dense block handled
by the MXU plus a small recurrent state carried across chunks in fp32 VMEM
scratch (C1's wide accumulator again: the state never leaves VMEM and is
rounded only when written).

Recurrence (per batch, head; x_t in R^P, b_t, c_t in R^N, a_t = exp(la_t)):

    h_t = a_t * h_{t-1} + x_t b_t^T          (P, N)
    y_t = h_t c_t                             (P,)

Chunked dual form over chunks of length Q with inclusive cumsum cum_i of la:

    y_intra[i] = sum_{j<=i} exp(cum_i - cum_j) (c_i . b_j) x_j     — MXU block
    y_inter[i] = exp(cum_i) * (h_prev c_i)                          — state read
    h_next     = exp(cum_{Q-1}) h_prev
                 + sum_j exp(cum_{Q-1} - cum_j) x_j b_j^T           — state write

The chunk grid dimension is sequential ("arbitrary"), the state persists in
scratch across grid steps — Pallas's analogue of the NTX accumulator
surviving loop iterations.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params


def _ssd_kernel(x_ref, la_ref, b_ref, c_ref, y_ref, h_scr, *, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, 0].astype(jnp.float32)  # (Q, P)
    la = la_ref[0, 0].astype(jnp.float32)  # (Q,) log-decay, <= 0
    b = b_ref[0, 0].astype(jnp.float32)  # (Q, N)
    c = c_ref[0, 0].astype(jnp.float32)  # (Q, N)
    q = x.shape[0]

    cum = jnp.cumsum(la)  # inclusive; (Q,)
    total = cum[-1]

    # Intra-chunk: causal decay-weighted score block on the MXU.
    scores = jax.lax.dot_general(
        c, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (Q, Q): scores[i, j] = c_i . b_j
    li = cum[:, None] - cum[None, :]  # log decay i<-j
    causal = (
        jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
        >= jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    )
    decay = jnp.where(causal, jnp.exp(li), 0.0)
    y = jnp.dot(scores * decay, x, preferred_element_type=jnp.float32)  # (Q, P)

    # Inter-chunk: contribution of the carried state.
    h = h_scr[...]  # (P, N) fp32
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        c, h, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (Q, P)

    # State update (wide accumulator never leaves VMEM between chunks).
    w = jnp.exp(total - cum)[:, None] * b  # (Q, N)
    h_scr[...] = jnp.exp(total) * h + jax.lax.dot_general(
        x, w, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (P, N)

    y_ref[0, 0] = y.astype(y_ref.dtype)


def ssd_scan(
    x: jnp.ndarray,  # (B, H, S, P)
    la: jnp.ndarray,  # (B, H, S) log decay (<= 0)
    b: jnp.ndarray,  # (B, G, S, N)
    c: jnp.ndarray,  # (B, G, S, N)
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Chunked SSD scan; returns y with shape (B, H, S, P)."""
    bb, h, s, p = x.shape
    _, g, _, n = b.shape
    assert h % g == 0, (h, g)
    grp = h // g
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    n_chunks = s // chunk
    grid = (bb, h, n_chunks)

    kernel = functools.partial(_ssd_kernel, n_chunks=n_chunks)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, chunk), lambda bi, hi, ci: (bi, hi, ci)),
            pl.BlockSpec((1, 1, chunk, n), lambda bi, hi, ci, g=grp: (bi, hi // g, ci, 0)),
            pl.BlockSpec((1, 1, chunk, n), lambda bi, hi, ci, g=grp: (bi, hi // g, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, p), lambda bi, hi, ci: (bi, hi, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((bb, h, s, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, la, b, c)

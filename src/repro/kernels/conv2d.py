"""NTX conv2d — the paper's primary workload kernel, on Pallas/TPU.

A direct convolution written exactly as the NtxCommand of §2.4 executes it:
the grid iterates output tiles (the driver's offload loop), the kernel body
runs the (kh, kw) reduction loops with the channel contraction on the MXU,
and the fp32 accumulator lives in VMEM until the single deferred store (C1).
Output tiles overlap on their input halo, so the input plane is kept whole
per batch element and the kernel slices its slab with a dynamic row offset
(the AGU address calculation, eq. 1); `core/tiling.plan_stencil_tiles`
guarantees the slab fits VMEM at the sizes the framework uses.

Layout: NHWC x HWIO -> NHWC, stride >= 1, VALID padding (callers pad).
Strided output is computed by strided VMEM slicing — the forward counterpart
of the paper's §3.2 backward decomposition (constant MACs per output pixel).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params


def _conv_kernel(x_ref, w_ref, o_ref, acc_ref, *, kh, kw, stride, th, ow, slab_h):
    """One (1, th, ow, Cout) output tile; x_ref holds the full (padded) plane."""
    t = pl.program_id(1)
    row0 = t * th * stride
    cin = x_ref.shape[-1]
    cout = o_ref.shape[-1]
    slab = x_ref[0, pl.dslice(row0, slab_h)]  # (slab_h, W, Cin)

    acc_ref[...] = jnp.zeros_like(acc_ref)
    for u in range(kh):
        for v in range(kw):
            xs = jax.lax.slice(
                slab,
                (u, v, 0),
                (u + (th - 1) * stride + 1, v + (ow - 1) * stride + 1, cin),
                (stride, stride, 1),
            )  # (th, ow, cin)
            acc_ref[...] += jnp.dot(
                xs.reshape(th * ow, cin), w_ref[u, v],
                preferred_element_type=jnp.float32,
            ).reshape(th, ow, cout)
    o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def conv2d_ntx(
    x: jnp.ndarray,  # (N, H, W, Cin) — pre-padded
    w: jnp.ndarray,  # (kh, kw, Cin, Cout)
    *,
    stride: int = 1,
    tile_h: int = 8,
    interpret: bool = False,
) -> jnp.ndarray:
    n, h, wid, cin = x.shape
    kh, kw, _, cout = w.shape
    oh = (h - kh) // stride + 1
    ow = (wid - kw) // stride + 1
    th = min(tile_h, oh)
    n_tiles = -(-oh // th)
    pad_rows = (n_tiles * th - oh) * stride
    if pad_rows:
        x = jnp.pad(x, ((0, 0), (0, pad_rows), (0, 0), (0, 0)))
    slab_h = (th - 1) * stride + kh

    kernel = functools.partial(
        _conv_kernel, kh=kh, kw=kw, stride=stride, th=th, ow=ow, slab_h=slab_h
    )
    out = pl.pallas_call(
        kernel,
        grid=(n, n_tiles),
        in_specs=[
            pl.BlockSpec((1, x.shape[1], wid, cin), lambda b, t: (b, 0, 0, 0)),
            pl.BlockSpec((kh, kw, cin, cout), lambda b, t: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, th, ow, cout), lambda b, t: (b, t, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n_tiles * th, ow, cout), x.dtype),
        scratch_shapes=[pltpu.VMEM((th, ow, cout), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, w)
    return out[:, :oh]

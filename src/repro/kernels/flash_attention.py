"""Blockwise-softmax attention (flash attention) as a Pallas TPU kernel.

Attention is the compute hot-spot of every assigned LM architecture, and it is
built here in full NTX style (C1+C2+C3): the score/renormalization statistics
and the output accumulator live in fp32 VMEM scratch for the whole KV sweep and
are rounded exactly once at the store — the PCS-accumulator discipline applied
to the online-softmax recurrence. The (q_block, kv_block) grid is the offloaded
loop nest; BlockSpec index maps implement GQA by pointing a group of q-heads at
their shared kv-head without replicating KV in HBM.

Supports causal masking and sliding-window (Mistral/local-attention) masking.
Fully-masked kv blocks are skipped with ``pl.when`` (compute saved; the DMA
still streams them — see EXPERIMENTS.md §Perf for the measured effect).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

NEG_INF = -1e30
_LANES = 128


def _attn_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    m_scr,
    l_scr,
    acc_scr,
    *,
    kv_blocks: int,
    block_q: int,
    block_kv: int,
    causal: bool,
    window: int | None,
    sm_scale: float,
    kv_len: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    kv_start = ki * block_kv

    # Static-shape block skip decision must be dynamic (traced), so use when().
    def visible():
        v = jnp.bool_(True)
        if causal:
            v = jnp.logical_and(v, kv_start <= q_start + block_q - 1)
        if window is not None:
            v = jnp.logical_and(v, kv_start + block_kv - 1 >= q_start - window)
        return v

    @pl.when(visible())
    def _block():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)  # (bkv, d)
        v = v_ref[0, 0].astype(jnp.float32)  # (bkv, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (bq, bkv)
        s *= sm_scale

        q_ids = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
        kv_ids = kv_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)
        mask = kv_ids < kv_len  # tail padding
        if causal:
            mask = jnp.logical_and(mask, kv_ids <= q_ids)
        if window is not None:
            mask = jnp.logical_and(mask, kv_ids > q_ids - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]  # (bq, LANES) broadcast stats
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=1, keepdims=True)  # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.broadcast_to(m_cur, m_prev.shape))
        alpha = jnp.exp(m_prev - m_new)  # rescale of old stats
        p = jnp.exp(s - m_new[:, :1])  # (bq, bkv)
        # Rows with no visible key yet: m_new == NEG_INF -> p must be 0.
        p = jnp.where(jnp.broadcast_to(m_new[:, :1] <= NEG_INF / 2, p.shape), 0.0, p)
        alpha = jnp.where(m_new <= NEG_INF / 2, 0.0, alpha)

        l_scr[...] = l_prev * alpha + jnp.broadcast_to(
            jnp.sum(p, axis=1, keepdims=True), l_prev.shape
        )
        m_scr[...] = m_new
        acc_scr[...] = acc_scr[...] * alpha[:, :1] + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )

    @pl.when(ki == kv_blocks - 1)
    def _store():
        l = l_scr[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows -> zero output
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention(
    q: jnp.ndarray,  # (B, Hq, Sq, D)
    k: jnp.ndarray,  # (B, Hkv, Skv, D)
    v: jnp.ndarray,  # (B, Hkv, Skv, D)
    *,
    causal: bool = True,
    window: int | None = None,
    sm_scale: float | None = None,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Multi-head attention with GQA via index maps (no KV replication)."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    if sm_scale is None:
        sm_scale = 1.0 / (d**0.5)

    block_q = min(block_q, sq)
    block_kv = min(block_kv, skv)
    assert sq % block_q == 0, (sq, block_q)
    pad_kv = (-skv) % block_kv
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_kv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_kv), (0, 0)))
    kv_blocks = k.shape[2] // block_kv
    grid = (b, hq, sq // block_q, kv_blocks)

    kernel = functools.partial(
        _attn_kernel,
        kv_blocks=kv_blocks,
        block_q=block_q,
        block_kv=block_kv,
        causal=causal,
        window=window,
        sm_scale=sm_scale,
        kv_len=skv,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda bi, h, qi, ki: (bi, h, qi, 0)),
            pl.BlockSpec(
                (1, 1, block_kv, d), lambda bi, h, qi, ki, g=group: (bi, h // g, ki, 0)
            ),
            pl.BlockSpec(
                (1, 1, block_kv, d), lambda bi, h, qi, ki, g=group: (bi, h // g, ki, 0)
            ),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), lambda bi, h, qi, ki: (bi, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, _LANES), jnp.float32),
            pltpu.VMEM((block_q, _LANES), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)

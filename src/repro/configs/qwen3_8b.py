"""qwen3-8b [dense] — GQA with qk_norm, no QKV bias.

[hf:Qwen/Qwen3-8B; hf] 36L d_model=4096 32H (GQA kv=8, head_dim 128)
d_ff=12288 vocab=151936, qk_norm. Pure full attention -> long_500k skipped.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=12288,
    vocab_size=151_936,
    qk_norm=True,
    rope_theta=1e6,
    shapes=("train_4k", "prefill_32k", "decode_32k"),
)

"""Assigned architecture configs (public-literature exact numbers) + registry.

Every module exports ``CONFIG`` (the exact assigned configuration) and the
registry offers :func:`reduce_config` — a family-preserving shrink used by the
per-arch smoke tests (tiny widths/depths, same block structure, same code
paths). The FULL configs are exercised only through the dry-run
(ShapeDtypeStructs — no allocation).
"""

from __future__ import annotations

import importlib

import jax.numpy as jnp

from repro.models.config import ModelConfig

ARCHS = (
    "recurrentgemma_2b",
    "llava_next_mistral_7b",
    "llama3_2_3b",
    "qwen2_5_32b",
    "qwen1_5_0_5b",
    "qwen3_8b",
    "musicgen_medium",
    "llama4_maverick_400b_a17b",
    "qwen3_moe_235b_a22b",
    "mamba2_780m",
)

# Paper workloads (the CNNs/LSTM NTX was evaluated on) are modelled
# analytically in benchmarks/ntx_model.py and exercised by examples/, not here.
PAPER_WORKLOADS: tuple[str, ...] = ()


def get_config(name: str) -> ModelConfig:
    name = name.replace("-", "_").replace(".", "_")
    if name not in ARCHS + PAPER_WORKLOADS:
        raise KeyError(f"unknown arch {name!r}; available: {ARCHS + PAPER_WORKLOADS}")
    return importlib.import_module(f"repro.configs.{name}").CONFIG


def reduce_config(cfg: ModelConfig) -> ModelConfig:
    """Family-preserving smoke-scale shrink (same pattern, tiny dims)."""
    plen = len(cfg.pattern)
    n_layers = plen * 2 + (1 if cfg.n_layers % plen else 0)
    kv_ratio = max(1, cfg.n_heads // cfg.n_kv_heads)
    n_heads = 4
    n_kv = max(1, n_heads // kv_ratio)
    kw = dict(
        n_layers=n_layers,
        d_model=64,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=16,
        d_ff=128,
        vocab_size=128,
        window=min(cfg.window, 8) if cfg.window else None,
        dtype=jnp.float32,
    )
    if cfg.n_experts:
        kw.update(n_experts=8, top_k=min(cfg.top_k, 4), moe_d_ff=32)
        if cfg.shared_expert_d_ff:
            kw.update(shared_expert_d_ff=32)
    if cfg.lru_width:
        kw.update(lru_width=64)
    if cfg.ssm_state:
        kw.update(n_heads=8, ssm_headdim=16, ssm_state=16, ssm_groups=min(2, cfg.ssm_groups))
    return cfg.with_(**kw)

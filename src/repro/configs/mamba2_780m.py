"""mamba2-780m [ssm] — SSD (state-space duality), attention-free.

[arXiv:2405.21060; unverified] 48L d_model=1536, d_inner=3072 (48 heads x
headdim 64), ssm_state=128, n_groups=1, vocab=50280 padded to 50288 (the
official impl's pad_vocab_size_multiple=16 — required here for 16-way vocab
TP), tied embeddings. Attention-free -> long_500k runs (O(1)/token decode).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=48,  # d_inner / ssm_headdim
    n_kv_heads=48,
    head_dim=64,
    d_ff=0,
    vocab_size=50_288,  # 50280 + pad_vocab_size_multiple=16 (official impl)
    pattern=(("ssm", None),),
    ssm_state=128,
    ssm_headdim=64,
    ssm_groups=1,
    tie_embeddings=True,
    shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
)

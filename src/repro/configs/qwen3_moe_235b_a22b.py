"""qwen3-moe-235b-a22b [moe] — 128 experts, top-8.

[hf:Qwen/Qwen3 MoE family; hf] 94L d_model=4096 64H (GQA kv=4, head_dim 128)
vocab=151936, every layer MoE: 128 experts top-8, expert d_ff=1536, qk_norm.
Full attention -> long_500k skipped.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab_size=151_936,
    pattern=(("attn", "moe"),),
    n_experts=128,
    top_k=8,
    moe_d_ff=1536,
    qk_norm=True,
    rope_theta=1e6,
    shapes=("train_4k", "prefill_32k", "decode_32k"),
)

"""llama3.2-3b [dense] — small llama3.

[hf:meta-llama/Llama-3.2-1B pattern; unverified] 28L d_model=3072 24H
(GQA kv=8, head_dim 128) d_ff=8192 vocab=128256, rope_theta=500000, tied
embeddings. Pure full attention -> long_500k skipped (DESIGN.md §Arch).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=128_256,
    rope_theta=5e5,
    tie_embeddings=True,
    shapes=("train_4k", "prefill_32k", "decode_32k"),
)

"""llama4-maverick-400b-a17b [moe] — 128-expert top-1 MoE, early fusion.

[hf:meta-llama/Llama-4 family; unverified] 48L d_model=5120 40H (GQA kv=8,
head_dim 128) vocab=202048, MoE 128 experts top-1 with a shared expert
(d_ff=8192 per the assignment), MoE interleaved 1:1 with dense layers
(pattern (attn,mlp),(attn,moe)) as in the released Maverick config — this is
what makes total params ~= 400B with ~17B active. Early-fusion multimodality
is a stub (text path only). Full attention -> long_500k skipped.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202_048,
    pattern=(("attn", "mlp"), ("attn", "moe")),
    n_experts=128,
    top_k=1,
    moe_d_ff=8192,
    shared_expert_d_ff=8192,
    rope_theta=5e5,
    shapes=("train_4k", "prefill_32k", "decode_32k"),
)

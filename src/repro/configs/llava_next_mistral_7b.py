"""llava-next-mistral-7b [vlm] — Mistral-7B backbone, anyres vision frontend.

[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified] 32L d_model=4096 32H
(GQA kv=8, head_dim 128) d_ff=14336 vocab=32000. The anyres patch frontend is
a STUB per the task spec: input_specs() provides precomputed patch+text
embeddings (B, S, D). Mistral's sliding-window attention (4096) keeps the
backbone sub-quadratic -> long_500k runs.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32_000,
    pattern=(("swa", "mlp"),),
    window=4096,
    input_mode="embeddings",
    rope_theta=1e4,
    shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
)

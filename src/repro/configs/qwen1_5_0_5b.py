"""qwen1.5-0.5b [dense] — MHA (kv == heads) with QKV bias.

[hf:Qwen/Qwen1.5-0.5B; hf] 24L d_model=1024 16H (kv=16, head_dim 64)
d_ff=2816 vocab=151936, QKV bias, tied embeddings. Pure full attention ->
long_500k skipped.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    family="dense",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=2816,
    vocab_size=151_936,
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1e6,
    shapes=("train_4k", "prefill_32k", "decode_32k"),
)

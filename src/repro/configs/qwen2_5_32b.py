"""qwen2.5-32b [dense] — GQA with QKV bias.

[hf:Qwen/Qwen2.5 family; hf] 64L d_model=5120 40H (GQA kv=8, head_dim 128)
d_ff=27648 vocab=152064, QKV bias, rope_theta=1e6. Pure full attention ->
long_500k skipped.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=27648,
    vocab_size=152_064,
    qkv_bias=True,
    rope_theta=1e6,
    shapes=("train_4k", "prefill_32k", "decode_32k"),
)

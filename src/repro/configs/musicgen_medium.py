"""musicgen-medium [audio] — decoder-only over EnCodec tokens.

[arXiv:2306.05284; hf] 48L d_model=1536 24H (MHA kv=24, head_dim 64)
d_ff=6144 (GELU, LayerNorm) vocab=2048, 4 parallel codebooks (delay pattern
handled by the data side). The EnCodec frontend is a STUB per the task spec:
input_specs() provides precomputed frame embeddings. Full attention ->
long_500k skipped.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    n_codebooks=4,
    input_mode="embeddings",
    mlp_act="gelu",
    norm_type="layer",
    shapes=("train_4k", "prefill_32k", "decode_32k"),
)

"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 1:2 ratio.

[arXiv:2402.19427; hf] 26L d_model=2560 10H (MQA kv=1, head_dim 256)
d_ff=7680 (GeGLU) vocab=256000, local-attention window 2048, pattern
(rec, rec, swa). Sub-quadratic -> runs long_500k.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256_000,
    pattern=(("rec", "mlp"), ("rec", "mlp"), ("swa", "mlp")),
    window=2048,
    lru_width=2560,
    mlp_act="geglu",
    embed_scale=True,
    tie_embeddings=True,
    rope_theta=1e4,
    shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
)

"""The unified NTX lowering pipeline (layer spec -> NtxProgram -> backends).

    spec = Conv2dSpec(in_h=16, in_w=16, cin=8, kh=3, kw=3, cout=4)
    prog = lower(spec, "dx")                   # command-level §3.2 backward
    outs = run_reference(prog, {"dy": dy, "w": w})   # numpy ground truth
    res  = run_timing(prog, n_clusters=4)      # event-driven cycle estimate
    outs = run_pallas(prog, {"dy": dy, "w": w})      # Pallas kernels

One lowering rule per layer type serves the interpreter, the timing model,
and the TPU backend. Above the per-layer rules sits the network-graph
compiler (:mod:`repro.lower.graph`): a whole training step — forward, loss
gradient, backward, SGD update — compiles to ONE NtxProgram with
liveness-allocated TCDM, consumed unchanged by all three executors:

    graph = paper_cnn_graph(batch=8)
    prog  = lower_training_step(graph)         # one program per train step
    outs  = run_pallas(prog, {"x": x, "onehot": y1h, **params})

Above that again sits mesh data parallelism (:mod:`repro.lower.mesh`): the
compiled step shards across a mesh of HMCs with an explicit
gradient-allreduce epilogue, bit-identical under ``run_reference`` and
``shard_map``-parallel under ``run_pallas``:

    sharded = shard_training_step(graph, mesh_shape=(2, 2))
    outs    = run_pallas(sharded.program, inputs)   # psum allreduce

On the Pallas path, whole-step programs route through the region fuser
(:mod:`repro.lower.fuse`): contiguous compatible chains execute as single
double-buffered fused kernels and the step compiles to ONE cached
callable; ``run_pallas(..., fuse=False)`` is the per-node escape hatch.

See docs/architecture.md ("The lowering pipeline", "The graph compiler",
"Mesh execution", "The region fuser").
"""

from repro.lower.executors import (
    BatchedSpec,
    PLAN_CACHE,
    PlanCache,
    run_pallas,
    run_reference,
    run_timing,
)
from repro.lower.fuse import (
    FusionPlan,
    RegionSpec,
    plan_fusion,
)
from repro.lower.graph import (
    GraphNode,
    NetworkGraph,
    edge_consumers,
    frequency_band_batches,
    lm_token_batches,
    lower_training_step,
    paper_cnn_graph,
    softmax_xent_loss,
    train_graph,
)
from repro.lower.mesh import (
    ShardedTrainStep,
    parse_mesh,
    reshard_training_step,
    shard_training_step,
)
from repro.lower.ir import (
    ELEM_BYTES,
    CommandBlock,
    DesignPoint,
    LivenessAllocator,
    NS_DESIGN,
    NTX_DESIGN,
    NtxProgram,
    RegionAllocator,
    TensorRegion,
)
from repro.lower.rules import (
    AttentionSpec,
    BiasSpec,
    Conv2dSpec,
    EmbeddingSpec,
    FlattenSpec,
    LayerNormSpec,
    MatmulSpec,
    MaxPool2dSpec,
    PASSES,
    PosEmbedSpec,
    ReluSpec,
    ResidualAddSpec,
    SgdUpdateSpec,
    SoftmaxXentSpec,
    lower,
    lower_layer,
    register_lowering,
    supported_matrix,
)

__all__ = [
    "ELEM_BYTES",
    "AttentionSpec",
    "BatchedSpec",
    "BiasSpec",
    "CommandBlock",
    "Conv2dSpec",
    "DesignPoint",
    "EmbeddingSpec",
    "FlattenSpec",
    "FusionPlan",
    "GraphNode",
    "LayerNormSpec",
    "LivenessAllocator",
    "MatmulSpec",
    "MaxPool2dSpec",
    "NS_DESIGN",
    "NTX_DESIGN",
    "NetworkGraph",
    "NtxProgram",
    "PASSES",
    "PLAN_CACHE",
    "PlanCache",
    "PosEmbedSpec",
    "RegionAllocator",
    "RegionSpec",
    "ReluSpec",
    "ResidualAddSpec",
    "SgdUpdateSpec",
    "ShardedTrainStep",
    "SoftmaxXentSpec",
    "TensorRegion",
    "edge_consumers",
    "frequency_band_batches",
    "lm_token_batches",
    "parse_mesh",
    "plan_fusion",
    "reshard_training_step",
    "shard_training_step",
    "lower",
    "lower_layer",
    "lower_training_step",
    "paper_cnn_graph",
    "register_lowering",
    "softmax_xent_loss",
    "supported_matrix",
    "train_graph",
]

"""The unified NTX lowering pipeline (layer spec -> NtxProgram -> backends).

    spec = Conv2dSpec(in_h=16, in_w=16, cin=8, kh=3, kw=3, cout=4)
    prog = lower(spec, "dx")                   # command-level §3.2 backward
    outs = run_reference(prog, {"dy": dy, "w": w})   # numpy ground truth
    res  = run_timing(prog, n_clusters=4)      # event-driven cycle estimate
    outs = run_pallas(prog, {"dy": dy, "w": w})      # Pallas kernels

One lowering rule per layer type serves the interpreter, the timing model,
and the TPU backend — see docs/architecture.md ("The lowering pipeline").
"""

from repro.lower.executors import (
    PLAN_CACHE,
    PlanCache,
    run_pallas,
    run_pallas_network,
    run_reference,
    run_timing,
)
from repro.lower.ir import (
    ELEM_BYTES,
    CommandBlock,
    DesignPoint,
    NS_DESIGN,
    NTX_DESIGN,
    NtxProgram,
    TensorRegion,
)
from repro.lower.rules import (
    Conv2dSpec,
    MatmulSpec,
    MaxPool2dSpec,
    PASSES,
    ReluSpec,
    lower,
    lower_layer,
)

__all__ = [
    "ELEM_BYTES",
    "CommandBlock",
    "Conv2dSpec",
    "DesignPoint",
    "MatmulSpec",
    "MaxPool2dSpec",
    "NS_DESIGN",
    "NTX_DESIGN",
    "NtxProgram",
    "PASSES",
    "PLAN_CACHE",
    "PlanCache",
    "ReluSpec",
    "TensorRegion",
    "lower",
    "lower_layer",
    "run_pallas",
    "run_pallas_network",
    "run_reference",
    "run_timing",
]

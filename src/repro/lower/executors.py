"""Three interchangeable executors over one :class:`NtxProgram`.

  * :func:`run_reference` — the behavioural model: place the inputs in a flat
    numpy TCDM, run every command through
    :func:`repro.core.ntx.ntx_execute` (vectorized fast path by default),
    read the outputs back. Ground truth for the other two.
  * :func:`run_timing` — the performance model: feed the block structure (or
    the exact command stream) + per-command DMA descriptors to
    :class:`repro.runtime.scheduler.MultiClusterScheduler` and return its
    :class:`ScheduleResult`. Programs above ~50k commands take the
    block-replicated steady-state path automatically — identical cycle
    counts, O(blocks) wall time — so million-command NS-design convs are
    cheap to time.
  * :func:`run_pallas` — the production backend: route the lowered layer to
    the Pallas kernels (:mod:`repro.kernels.streaming`,
    :mod:`repro.kernels.ops`) through a process-wide :class:`PlanCache` of
    jitted whole-pass executables, so "one offload" becomes "one cached
    pallas_call" — zero retraces after warmup.

All three consume the same lowered program — a new layer type needs one
lowering rule, not three backend implementations.
"""

from __future__ import annotations

import numpy as np

from repro.core.ntx import ntx_execute
from repro.lower.ir import NtxProgram
from repro.lower.rules import Conv2dSpec, MatmulSpec, MaxPool2dSpec, ReluSpec

# ---------------------------------------------------------------------------
# 1. Reference executor (numpy TCDM + the ntx_execute interpreter)
# ---------------------------------------------------------------------------


def run_reference(
    program: NtxProgram,
    inputs: dict[str, np.ndarray],
    *,
    wide: bool = True,
    vectorize: bool = True,
) -> dict[str, np.ndarray]:
    """Execute ``program`` against a flat TCDM; return its output regions.

    ``inputs`` maps region names (kind "input"/"param") to arrays of the
    region's shape. Scratch regions are staged by the program's own
    memset/copy commands — no out-of-band padding happens here.
    """
    mem = np.zeros(program.memory_words, np.float32)
    needed = {r.name for r in program.regions.values() if r.kind in ("input", "param")}
    missing = needed - set(inputs)
    if missing:
        raise ValueError(f"missing input regions: {sorted(missing)}")
    for name, arr in inputs.items():
        r = program.region(name)
        a = np.asarray(arr, np.float32)
        if a.shape != r.shape:
            raise ValueError(f"region {name!r} expects shape {r.shape}, got {a.shape}")
        mem[r.base : r.end] = a.ravel()
    for cmd in program.commands():
        ntx_execute(cmd, mem, wide=wide, vectorize=vectorize, inplace=True)
    return {
        r.name: mem[r.base : r.end].reshape(r.shape).copy()
        for r in program.regions_of_kind("output")
    }


# ---------------------------------------------------------------------------
# 2. Timing executor (event-driven queue/DMA runtime, block fast path)
# ---------------------------------------------------------------------------


def run_timing(
    program: NtxProgram,
    *,
    n_clusters: int = 1,
    cluster=None,
    f_ntx: float = 1.5e9,
    engine: str = "auto",
    exec_cycles=None,
):
    """Simulate ``program`` on the offload runtime; returns a ScheduleResult.

    The command stream and the per-command input-DMA byte counts both come
    straight from the lowered program, so the timing model sees exactly what
    the reference interpreter executes. ``engine`` picks the simulation
    strategy (``"auto"`` | ``"event"`` | ``"block"``): the block-replicated
    steady-state path gives cycle counts identical to the event-driven
    engine in O(blocks) time, so there is no program-size cap — NS-design
    convs with millions of commands simulate in milliseconds.
    ``exec_cycles`` optionally overrides per-command datapath cycles (must
    not depend on AGU bases on the block path).
    """
    from repro.runtime import scheduler as rt_sched

    sched = rt_sched.MultiClusterScheduler(
        n_clusters=n_clusters, cluster=cluster, f_ntx=f_ntx
    )
    return sched.schedule_program(program, engine=engine, exec_cycles=exec_cycles)


# ---------------------------------------------------------------------------
# 3. Pallas executor (kernels/streaming.py + kernels/ops.py, plan cache)
# ---------------------------------------------------------------------------


def _plan_callable(spec, pass_: str, interpret: bool):
    """Pure jax function dict[str, Array] -> dict[str, Array] for one plan.

    Shapes/strides are baked in from ``spec`` (hashable frozen dataclasses),
    so one callable serves every invocation of that (spec, pass) — this is
    what :class:`PlanCache` jits and keeps.
    """
    import jax
    import jax.numpy as jnp

    from repro.core import conv_decomp
    from repro.kernels import streaming

    if isinstance(spec, MatmulSpec):
        if pass_ == "fwd":
            return lambda j: {
                "c": streaming.streaming_matmul(j["a"], j["b"], interpret=interpret)
            }
        if pass_ == "dw":
            return lambda j: {
                "dw": streaming.streaming_matmul(j["a"].T, j["dy"], interpret=interpret)
            }
        if pass_ == "dx":
            return lambda j: {
                "dx": streaming.streaming_matmul(j["dy"], j["b"].T, interpret=interpret)
            }

    if isinstance(spec, Conv2dSpec):
        s, p = spec.stride, spec.padding
        if pass_ == "fwd":

            def fwd(j):
                y = streaming.streaming_conv2d(
                    j["x"][None], j["w"], stride=s, padding=p, interpret=interpret
                )
                return {"y": y[0]}

            return fwd
        if pass_ == "dw":
            # dW = cols(x)^T @ dy: the same im2col the forward kernel streams,
            # with the (oh*ow) output pixels as the contraction dim.
            def dw(j):
                x = j["x"][None]
                if p:
                    xp = jnp.pad(x, ((0, 0), (p, p), (p, p), (0, 0)))
                else:
                    xp = x
                oh, ow = spec.out_h, spec.out_w
                cols = jnp.concatenate(
                    [
                        xp[:, dh : dh + oh * s : s, dw_ : dw_ + ow * s : s, :]
                        for dh in range(spec.kh)
                        for dw_ in range(spec.kw)
                    ],
                    axis=-1,
                ).reshape(oh * ow, spec.kh * spec.kw * spec.cin)
                dyf = j["dy"].reshape(oh * ow, spec.cout)
                dw_flat = streaming.streaming_matmul(
                    cols.T, dyf, interpret=interpret
                )
                return {
                    "dw": dw_flat.reshape(spec.kh, spec.kw, spec.cin, spec.cout)
                }

            return dw
        if pass_ == "dx":
            # The §3.2 phase decomposition with the dense per-phase conv
            # routed through the streaming Pallas kernel.
            def dx(j):
                def conv_fn(dy, w_ab, pads):
                    ph, pw = pads
                    dyp = jnp.pad(dy, ((0, 0), (ph, ph), (pw, pw), (0, 0)))
                    return streaming.streaming_conv2d(
                        dyp, w_ab, stride=1, padding=0, interpret=interpret
                    )

                out = conv_decomp.conv2d_input_grad_decomposed(
                    j["dy"][None], j["w"], s, (spec.in_h, spec.in_w), p,
                    conv_fn=conv_fn,
                )
                return {"dx": out[0]}

            return dx

    if isinstance(spec, MaxPool2dSpec):
        if pass_ == "fwd":
            w, s = spec.window, spec.stride

            def pool(j):
                y = jax.lax.reduce_window(
                    j["x"], -jnp.inf, jax.lax.max, (w, w, 1), (s, s, 1), "VALID"
                )
                return {"y": y}

            return pool

    if isinstance(spec, ReluSpec):
        if pass_ == "fwd":
            return lambda j: {"y": jnp.maximum(j["x"], 0.0)}
        if pass_ == "dx":
            # ReLU backward has no lowering rule (pure mask), but routing it
            # through a cached plan keeps run_pallas_network retrace-free.
            return lambda j: {"dx": jnp.where(j["x"] > 0.0, j["dy"], 0.0)}

    raise TypeError(
        f"no Pallas route for spec {type(spec).__name__} pass {pass_!r}"
    )


class CompiledPlan:
    """One jitted whole-pass executable plus its jax trace counter.

    ``traces`` increments each time jax (re-)traces the underlying function
    — after warmup on fixed shapes it must stay at 1, which the tests and
    the ``pallas_plan_cache`` benchmark assert.
    """

    __slots__ = ("key", "fn", "traces", "calls")

    def __init__(self, key):
        self.key = key
        self.fn = None
        self.traces = 0
        self.calls = 0

    def __call__(self, inputs):
        self.calls += 1
        return self.fn(inputs)


class PlanCache:
    """Compiled-program cache for the Pallas executor.

    Keyed by ``(spec, pass, design, interpret)`` — specs are frozen
    dataclasses carrying every static shape/stride, so two programs lowered
    from equal specs share one jitted executable. The cache is unbounded
    (one entry per distinct layer shape in the process); :meth:`clear`
    drops everything.
    """

    def __init__(self):
        self._plans: dict = {}
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._plans)

    def clear(self) -> None:
        self._plans.clear()
        self.hits = 0
        self.misses = 0

    def get(self, spec, pass_: str, design: str, interpret: bool) -> CompiledPlan:
        key = (spec, pass_, design, bool(interpret))
        plan = self._plans.get(key)
        if plan is not None:
            self.hits += 1
            return plan
        self.misses += 1
        import jax

        plan = CompiledPlan(key)
        raw = _plan_callable(spec, pass_, bool(interpret))

        def counted(j):
            plan.traces += 1
            return raw(j)

        plan.fn = jax.jit(counted)
        self._plans[key] = plan
        return plan


#: Process-wide default cache; pass ``cache=`` to isolate (tests, benchmarks).
PLAN_CACHE = PlanCache()


def _resolve_interpret(interpret):
    if interpret is not None:
        return bool(interpret)
    import jax

    return jax.default_backend() != "tpu"


def run_pallas(
    program: NtxProgram,
    inputs: dict,
    *,
    interpret: bool | None = None,
    cache: PlanCache | None = None,
):
    """Execute the lowered layer through the cached Pallas plans.

    ``interpret=None`` picks the Pallas interpreter off-TPU (CPU tests) and
    the compiled kernel on TPU. Inputs may be numpy or ``jax.Array`` —
    device arrays pass straight through (no host round trip) — and outputs
    are ``jax.Array``s keyed like :func:`run_reference`'s output dict.
    Repeated calls on equal specs reuse one jitted executable from
    ``cache`` (default: the process-wide :data:`PLAN_CACHE`).
    """
    import jax.numpy as jnp

    interpret = _resolve_interpret(interpret)
    spec = program.meta.get("spec")
    pass_ = program.meta.get("pass", "fwd")
    if cache is None:
        cache = PLAN_CACHE
    plan = cache.get(spec, pass_, program.design.name, interpret)
    j = {k: jnp.asarray(v, jnp.float32) for k, v in inputs.items()}
    return plan(j)


def run_pallas_network(
    specs,
    x,
    params,
    dy=None,
    *,
    interpret: bool | None = None,
    cache: PlanCache | None = None,
    design: str = "ntx",
):
    """One whole fwd + dW + dX chain through cached plans — no per-layer
    retrace.

    ``specs`` is a shape-chained layer sequence (``Conv2dSpec`` /
    ``MatmulSpec`` / ``ReluSpec`` / ``MaxPool2dSpec``); ``params`` is
    aligned with it (weight array for conv/matmul, ``None`` otherwise).
    The forward pass threads ``x`` through every layer; the backward pass
    threads ``dy`` (default: ones over the final output) back, producing
    the input gradient and one weight gradient per parameterized layer.
    Every layer-pass executes through ``cache`` — after one warmup call,
    repeated invocations with the same shapes trigger zero retraces.

    Pooling layers are forward-only (no dX lowering yet): a chain that
    contains one raises ``NotImplementedError`` when the backward pass is
    requested, i.e. always — keep pools out of training chains for now.

    Returns ``{"y": ..., "dx": ..., "dw": [per-layer grads or None]}``.
    """
    import jax.numpy as jnp

    interpret = _resolve_interpret(interpret)
    if cache is None:
        cache = PLAN_CACHE
    if len(specs) != len(params):
        raise ValueError(f"{len(specs)} specs but {len(params)} param entries")

    def plan(spec, pass_):
        return cache.get(spec, pass_, design, interpret)

    # forward: keep each layer's input for the backward pass
    a = jnp.asarray(x, jnp.float32)
    acts = []
    for spec, w in zip(specs, params):
        acts.append(a)
        if isinstance(spec, MatmulSpec):
            a = plan(spec, "fwd")({"a": a, "b": jnp.asarray(w, jnp.float32)})["c"]
        elif isinstance(spec, Conv2dSpec):
            a = plan(spec, "fwd")({"x": a, "w": jnp.asarray(w, jnp.float32)})["y"]
        elif isinstance(spec, (ReluSpec, MaxPool2dSpec)):
            a = plan(spec, "fwd")({"x": a})["y"]
        else:
            raise TypeError(f"no network route for {type(spec).__name__}")
    y = a

    # backward: dX chains in reverse, dW drops out per parameterized layer
    g = jnp.ones_like(y) if dy is None else jnp.asarray(dy, jnp.float32)
    dws: list = [None] * len(specs)
    for idx in range(len(specs) - 1, -1, -1):
        spec, w, a_in = specs[idx], params[idx], acts[idx]
        if isinstance(spec, MatmulSpec):
            wj = jnp.asarray(w, jnp.float32)
            dws[idx] = plan(spec, "dw")({"a": a_in, "dy": g})["dw"]
            g = plan(spec, "dx")({"dy": g, "b": wj})["dx"]
        elif isinstance(spec, Conv2dSpec):
            wj = jnp.asarray(w, jnp.float32)
            dws[idx] = plan(spec, "dw")({"x": a_in, "dy": g})["dw"]
            g = plan(spec, "dx")({"dy": g, "w": wj})["dx"]
        elif isinstance(spec, ReluSpec):
            g = plan(spec, "dx")({"x": a_in, "dy": g})["dx"]
        else:
            raise NotImplementedError(
                f"{type(spec).__name__} has no backward lowering — "
                "training chains must avoid pooling for now"
            )
    return {"y": y, "dx": g, "dw": dws}

"""Three interchangeable executors over one :class:`NtxProgram`.

  * :func:`run_reference` — the behavioural model: place the inputs in a flat
    numpy TCDM, run every command through
    :func:`repro.core.ntx.ntx_execute` (vectorized fast path by default),
    read the outputs back. Ground truth for the other two.
  * :func:`run_timing` — the performance model: feed the exact command
    stream + per-command DMA descriptors to
    :class:`repro.runtime.scheduler.MultiClusterScheduler` and return its
    event-driven :class:`ScheduleResult` (queues, back-pressure,
    double-buffered DMA, chrome-trace timeline).
  * :func:`run_pallas` — the production backend: route the lowered layer to
    the Pallas kernels (:mod:`repro.kernels.streaming`,
    :mod:`repro.kernels.ops`), so "one offload" becomes "one pallas_call".

All three consume the same lowered program — a new layer type needs one
lowering rule, not three backend implementations.
"""

from __future__ import annotations

import numpy as np

from repro.core.ntx import ntx_execute
from repro.lower.ir import NtxProgram
from repro.lower.rules import Conv2dSpec, MatmulSpec, MaxPool2dSpec, ReluSpec

# Keep timing runs bounded: materializing an NS-design program for a big conv
# would enqueue ~1e6 commands; refuse rather than hang.
MAX_TIMED_COMMANDS = 250_000


# ---------------------------------------------------------------------------
# 1. Reference executor (numpy TCDM + the ntx_execute interpreter)
# ---------------------------------------------------------------------------


def run_reference(
    program: NtxProgram,
    inputs: dict[str, np.ndarray],
    *,
    wide: bool = True,
    vectorize: bool = True,
) -> dict[str, np.ndarray]:
    """Execute ``program`` against a flat TCDM; return its output regions.

    ``inputs`` maps region names (kind "input"/"param") to arrays of the
    region's shape. Scratch regions are staged by the program's own
    memset/copy commands — no out-of-band padding happens here.
    """
    mem = np.zeros(program.memory_words, np.float32)
    needed = {r.name for r in program.regions.values() if r.kind in ("input", "param")}
    missing = needed - set(inputs)
    if missing:
        raise ValueError(f"missing input regions: {sorted(missing)}")
    for name, arr in inputs.items():
        r = program.region(name)
        a = np.asarray(arr, np.float32)
        if a.shape != r.shape:
            raise ValueError(f"region {name!r} expects shape {r.shape}, got {a.shape}")
        mem[r.base : r.end] = a.ravel()
    for cmd in program.commands():
        ntx_execute(cmd, mem, wide=wide, vectorize=vectorize, inplace=True)
    return {
        r.name: mem[r.base : r.end].reshape(r.shape).copy()
        for r in program.regions_of_kind("output")
    }


# ---------------------------------------------------------------------------
# 2. Timing executor (event-driven queue/DMA runtime)
# ---------------------------------------------------------------------------


def run_timing(
    program: NtxProgram,
    *,
    n_clusters: int = 1,
    cluster=None,
    f_ntx: float = 1.5e9,
    max_commands: int = MAX_TIMED_COMMANDS,
):
    """Simulate ``program`` on the offload runtime; returns a ScheduleResult.

    The command stream and the per-command input-DMA byte counts both come
    straight from the lowered program, so the timing model sees exactly what
    the reference interpreter executes.
    """
    from repro.runtime import scheduler as rt_sched

    n = program.n_commands
    if n > max_commands:
        raise ValueError(
            f"program has {n} commands (> {max_commands}); partition or raise "
            "max_commands explicitly"
        )
    sched = rt_sched.MultiClusterScheduler(
        n_clusters=n_clusters, cluster=cluster, f_ntx=f_ntx
    )
    return sched.schedule_program(program)


# ---------------------------------------------------------------------------
# 3. Pallas executor (kernels/streaming.py + kernels/ops.py)
# ---------------------------------------------------------------------------


def run_pallas(
    program: NtxProgram,
    inputs: dict[str, np.ndarray],
    *,
    interpret: bool | None = None,
) -> dict[str, np.ndarray]:
    """Execute the lowered layer through the Pallas kernels.

    ``interpret=None`` picks the Pallas interpreter off-TPU (CPU tests) and
    the compiled kernel on TPU. Output dict mirrors :func:`run_reference`.
    """
    import jax
    import jax.numpy as jnp

    from repro.core import conv_decomp
    from repro.kernels import streaming

    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    spec = program.meta.get("spec")
    pass_ = program.meta.get("pass", "fwd")
    j = {k: jnp.asarray(np.asarray(v, np.float32)) for k, v in inputs.items()}

    if isinstance(spec, MatmulSpec):
        if pass_ == "fwd":
            out = streaming.streaming_matmul(j["a"], j["b"], interpret=interpret)
            return {"c": np.asarray(out)}
        if pass_ == "dw":
            out = streaming.streaming_matmul(j["a"].T, j["dy"], interpret=interpret)
            return {"dw": np.asarray(out)}
        if pass_ == "dx":
            out = streaming.streaming_matmul(j["dy"], j["b"].T, interpret=interpret)
            return {"dx": np.asarray(out)}

    if isinstance(spec, Conv2dSpec):
        s, p = spec.stride, spec.padding
        if pass_ == "fwd":
            y = streaming.streaming_conv2d(
                j["x"][None], j["w"], stride=s, padding=p, interpret=interpret
            )
            return {"y": np.asarray(y[0])}
        if pass_ == "dw":
            # dW = cols(x)^T @ dy: the same im2col the forward kernel streams,
            # with the (oh*ow) output pixels as the contraction dim.
            x = j["x"][None]
            if p:
                x = jnp.pad(x, ((0, 0), (p, p), (p, p), (0, 0)))
            oh, ow = spec.out_h, spec.out_w
            cols = jnp.concatenate(
                [
                    x[:, dh : dh + oh * s : s, dw : dw + ow * s : s, :]
                    for dh in range(spec.kh)
                    for dw in range(spec.kw)
                ],
                axis=-1,
            ).reshape(oh * ow, spec.kh * spec.kw * spec.cin)
            dyf = j["dy"].reshape(oh * ow, spec.cout)
            dw_flat = streaming.streaming_matmul(cols.T, dyf, interpret=interpret)
            return {
                "dw": np.asarray(
                    dw_flat.reshape(spec.kh, spec.kw, spec.cin, spec.cout)
                )
            }
        if pass_ == "dx":
            # The §3.2 phase decomposition with the dense per-phase conv
            # routed through the streaming Pallas kernel.
            def conv_fn(dy, w_ab, pads):
                ph, pw = pads
                dyp = jnp.pad(dy, ((0, 0), (ph, ph), (pw, pw), (0, 0)))
                return streaming.streaming_conv2d(
                    dyp, w_ab, stride=1, padding=0, interpret=interpret
                )

            dx = conv_decomp.conv2d_input_grad_decomposed(
                j["dy"][None], j["w"], s, (spec.in_h, spec.in_w), p,
                conv_fn=conv_fn,
            )
            return {"dx": np.asarray(dx[0])}

    if isinstance(spec, MaxPool2dSpec):
        x = j["x"]
        w, s = spec.window, spec.stride
        y = jax.lax.reduce_window(
            x, -jnp.inf, jax.lax.max, (w, w, 1), (s, s, 1), "VALID"
        )
        return {"y": np.asarray(y)}

    if isinstance(spec, ReluSpec):
        return {"y": np.asarray(jnp.maximum(j["x"], 0.0))}

    raise TypeError(
        f"no Pallas route for spec {type(spec).__name__} pass {pass_!r}"
    )

"""Three interchangeable executors over one :class:`NtxProgram`.

  * :func:`run_reference` — the behavioural model: place the inputs in a flat
    numpy TCDM, run every command through
    :func:`repro.core.ntx.ntx_execute` (vectorized fast path by default),
    read the outputs back. Ground truth for the other two.
  * :func:`run_timing` — the performance model: feed the block structure (or
    the exact command stream) + per-command DMA descriptors to
    :class:`repro.runtime.scheduler.MultiClusterScheduler` and return its
    :class:`ScheduleResult`. Programs above ~50k commands take the
    block-replicated steady-state path automatically — identical cycle
    counts, O(blocks) wall time — so million-command NS-design convs are
    cheap to time.
  * :func:`run_pallas` — the production backend: route the lowered layer to
    the Pallas kernels (:mod:`repro.kernels.streaming`,
    :mod:`repro.kernels.ops`) through a process-wide :class:`PlanCache` of
    jitted whole-pass executables, so "one offload" becomes "one cached
    pallas_call" — zero retraces after warmup.

All three consume the same lowered program — a new layer type needs one
lowering rule, not three backend implementations.
"""

from __future__ import annotations

import numpy as np

from dataclasses import dataclass

from repro.core.ntx import ntx_execute
from repro.lower.ir import NtxProgram
from repro.obs import counters as obs
from repro.obs import trace as obs_trace
from repro.lower.rules import (
    AttentionSpec,
    BiasSpec,
    Conv2dSpec,
    EmbeddingSpec,
    FlattenSpec,
    LayerNormSpec,
    MatmulSpec,
    MaxPool2dSpec,
    PosEmbedSpec,
    ReluSpec,
    ResidualAddSpec,
    SgdUpdateSpec,
    SoftmaxXentSpec,
)


@dataclass(frozen=True)
class BatchedSpec:
    """A per-image spec vmapped over the leading batch axis.

    The graph executor uses this as the plan-cache key for per-image layer
    nodes (conv/pool) executing over a whole batch: parameters broadcast,
    everything else maps over axis 0.
    """

    spec: object
    batch: int

# ---------------------------------------------------------------------------
# 1. Reference executor (numpy TCDM + the ntx_execute interpreter)
# ---------------------------------------------------------------------------


def run_reference(
    program: NtxProgram,
    inputs: dict[str, np.ndarray],
    *,
    wide: bool = True,
    vectorize: bool = True,
) -> dict[str, np.ndarray]:
    """Execute ``program`` against a flat TCDM; return its output regions.

    ``inputs`` maps region names (kind "input"/"param") to arrays of the
    region's shape. Scratch regions are staged by the program's own
    memset/copy commands — no out-of-band padding happens here.
    """
    mem = np.zeros(program.memory_words, np.float32)
    needed = {r.name for r in program.regions.values() if r.kind in ("input", "param")}
    missing = needed - set(inputs)
    if missing:
        raise ValueError(f"missing input regions: {sorted(missing)}")
    for name, arr in inputs.items():
        r = program.region(name)
        a = np.asarray(arr, np.float32)
        if a.shape != r.shape:
            raise ValueError(f"region {name!r} expects shape {r.shape}, got {a.shape}")
        mem[r.base : r.end] = a.ravel()
    for cmd in program.commands():
        ntx_execute(cmd, mem, wide=wide, vectorize=vectorize, inplace=True)
    obs.record_program(obs.get_active(), program)
    return {
        r.name: mem[r.base : r.end].reshape(r.shape).copy()
        for r in program.regions_of_kind("output")
    }


# ---------------------------------------------------------------------------
# 2. Timing executor (event-driven queue/DMA runtime, block fast path)
# ---------------------------------------------------------------------------


def run_timing(
    program: NtxProgram,
    *,
    n_clusters: int = 1,
    cluster=None,
    f_ntx: float = 1.5e9,
    engine: str = "auto",
    exec_cycles=None,
):
    """Simulate ``program`` on the offload runtime; returns a ScheduleResult.

    The command stream and the per-command input-DMA byte counts both come
    straight from the lowered program, so the timing model sees exactly what
    the reference interpreter executes. ``engine`` picks the simulation
    strategy (``"auto"`` | ``"event"`` | ``"block"``): the block-replicated
    steady-state path gives cycle counts identical to the event-driven
    engine in O(blocks) time, so there is no program-size cap — NS-design
    convs with millions of commands simulate in milliseconds.
    ``exec_cycles`` optionally overrides per-command datapath cycles (must
    not depend on AGU bases on the block path).
    """
    from repro.runtime import scheduler as rt_sched

    sched = rt_sched.MultiClusterScheduler(
        n_clusters=n_clusters, cluster=cluster, f_ntx=f_ntx
    )
    result = sched.schedule_program(program, engine=engine, exec_cycles=exec_cycles)
    reg = obs.get_active()
    if reg is not None:
        obs.record_program(reg, program)
        obs.record_schedule(reg, result)
    return result


# ---------------------------------------------------------------------------
# 3. Pallas executor (kernels/streaming.py + kernels/ops.py, plan cache)
# ---------------------------------------------------------------------------


def _plan_callable(spec, pass_: str, interpret: bool):
    """Pure jax function dict[str, Array] -> dict[str, Array] for one plan.

    Shapes/strides are baked in from ``spec`` (hashable frozen dataclasses),
    so one callable serves every invocation of that (spec, pass) — this is
    what :class:`PlanCache` jits and keeps.
    """
    import jax
    import jax.numpy as jnp

    from repro.core import conv_decomp
    from repro.kernels import streaming
    from repro.lower.fuse import RegionSpec

    if isinstance(spec, RegionSpec):
        # one fused kernel for a whole region chain; ``pass_`` is "region"
        from repro.kernels import fused

        return fused.build_region_callable(spec, interpret=interpret)

    if isinstance(spec, MatmulSpec):
        if pass_ == "fwd":
            return lambda j: {
                "c": streaming.streaming_matmul(j["a"], j["b"], interpret=interpret)
            }
        if pass_ == "dw":
            return lambda j: {
                "dw": streaming.streaming_matmul(j["a"].T, j["dy"], interpret=interpret)
            }
        if pass_ == "dx":
            return lambda j: {
                "dx": streaming.streaming_matmul(j["dy"], j["b"].T, interpret=interpret)
            }

    if isinstance(spec, Conv2dSpec):
        s, p = spec.stride, spec.padding
        if pass_ == "fwd":

            def fwd(j):
                y = streaming.streaming_conv2d(
                    j["x"][None], j["w"], stride=s, padding=p, interpret=interpret
                )
                return {"y": y[0]}

            return fwd
        if pass_ == "dw":
            # dW = cols(x)^T @ dy: the same im2col the forward kernel streams,
            # with the (oh*ow) output pixels as the contraction dim.
            def dw(j):
                x = j["x"][None]
                if p:
                    xp = jnp.pad(x, ((0, 0), (p, p), (p, p), (0, 0)))
                else:
                    xp = x
                oh, ow = spec.out_h, spec.out_w
                cols = jnp.concatenate(
                    [
                        xp[:, dh : dh + oh * s : s, dw_ : dw_ + ow * s : s, :]
                        for dh in range(spec.kh)
                        for dw_ in range(spec.kw)
                    ],
                    axis=-1,
                ).reshape(oh * ow, spec.kh * spec.kw * spec.cin)
                dyf = j["dy"].reshape(oh * ow, spec.cout)
                dw_flat = streaming.streaming_matmul(
                    cols.T, dyf, interpret=interpret
                )
                return {
                    "dw": dw_flat.reshape(spec.kh, spec.kw, spec.cin, spec.cout)
                }

            return dw
        if pass_ == "dx":
            # The §3.2 phase decomposition with the dense per-phase conv
            # routed through the streaming Pallas kernel.
            def dx(j):
                def conv_fn(dy, w_ab, pads):
                    ph, pw = pads
                    dyp = jnp.pad(dy, ((0, 0), (ph, ph), (pw, pw), (0, 0)))
                    return streaming.streaming_conv2d(
                        dyp, w_ab, stride=1, padding=0, interpret=interpret
                    )

                out = conv_decomp.conv2d_input_grad_decomposed(
                    j["dy"][None], j["w"], s, (spec.in_h, spec.in_w), p,
                    conv_fn=conv_fn,
                )
                return {"dx": out[0]}

            return dx

    if isinstance(spec, MaxPool2dSpec):
        w, s = spec.window, spec.stride

        def pool_fwd(x):
            return jax.lax.reduce_window(
                x, -jnp.inf, jax.lax.max, (w, w, 1), (s, s, 1), "VALID"
            )

        if pass_ == "fwd":
            return lambda j: {"y": pool_fwd(j["x"])}
        if pass_ == "dx":

            def pool_dx(j):
                _, vjp = jax.vjp(pool_fwd, j["x"])
                return {"dx": vjp(j["dy"])[0]}

            return pool_dx

    if isinstance(spec, ReluSpec):
        if pass_ == "fwd":
            return lambda j: {"y": jnp.maximum(j["x"], 0.0)}
        if pass_ == "dx":
            # the sign/select mask pattern of the lowering rule, in jnp
            return lambda j: {"dx": jnp.where(j["x"] > 0.0, j["dy"], 0.0)}

    if isinstance(spec, BiasSpec):
        if pass_ == "fwd":
            return lambda j: {"y": j["x"] + j["b"][None, :]}
        if pass_ == "dw":
            return lambda j: {"db": j["dy"].sum(axis=0)}
        if pass_ == "dx":
            return lambda j: {"dx": j["dy"]}

    if isinstance(spec, SoftmaxXentSpec):
        if pass_ == "dx":
            B = spec.batch

            def xent_dx(j):
                p = jax.nn.softmax(j["z"], axis=-1)
                return {"dz": (p - j["onehot"]) / B}

            return xent_dx

    if isinstance(spec, SgdUpdateSpec):
        if pass_ == "upd":
            lr, mu = spec.lr, spec.momentum
            if mu:

                def upd_mom(j):
                    v_new = mu * j["v"] + j["dw"]
                    return {"v_new": v_new, "w_new": j["w"] - lr * v_new}

                return upd_mom
            return lambda j: {"w_new": j["w"] - lr * j["dw"]}

    if isinstance(spec, AttentionSpec):
        S, H, Dh = spec.seq, spec.n_heads, spec.head_dim
        D = H * Dh

        def attn_one(x):  # (S, 3D) qkv -> (S, D) context, causal
            q = x[:, :D].reshape(S, H, Dh).transpose(1, 0, 2)
            k = x[:, D:2 * D].reshape(S, H, Dh).transpose(1, 0, 2)
            v = x[:, 2 * D:].reshape(S, H, Dh).transpose(1, 0, 2)
            sc = jnp.einsum("hid,hjd->hij", q, k) * spec.scale
            mask = jnp.where(
                jnp.tril(jnp.ones((S, S), x.dtype)) > 0, 0.0, -1e9
            )
            p = jax.nn.softmax(sc + mask[None], axis=-1)
            ctx = jnp.einsum("hij,hjd->hid", p, v)
            return ctx.transpose(1, 0, 2).reshape(S, D)

        if pass_ == "fwd":
            return lambda j: {"y": attn_one(j["x"])}
        if pass_ == "dx":

            def attn_dx(j):
                _, vjp = jax.vjp(attn_one, j["x"])
                return {"dx": vjp(j["dy"])[0]}

            return attn_dx

    if isinstance(spec, LayerNormSpec):
        eps = spec.eps

        def ln_xhat(j):
            mu = jnp.mean(j["x"], axis=-1, keepdims=True)
            var = jnp.mean((j["x"] - mu) ** 2, axis=-1, keepdims=True)
            return (j["x"] - mu) * jax.lax.rsqrt(var + eps)

        if pass_ == "fwd":
            return lambda j: {"y": ln_xhat(j) * j["w"][0] + j["w"][1]}
        if pass_ == "dw":
            return lambda j: {
                "dw": jnp.stack(
                    [(j["dy"] * ln_xhat(j)).sum(axis=0), j["dy"].sum(axis=0)]
                )
            }
        if pass_ == "dx":

            def ln_dx(j):
                xhat = ln_xhat(j)
                mu = jnp.mean(j["x"], axis=-1, keepdims=True)
                var = jnp.mean((j["x"] - mu) ** 2, axis=-1, keepdims=True)
                dyg = j["dy"] * j["w"][0]
                m1 = jnp.mean(dyg, axis=-1, keepdims=True)
                m2 = jnp.mean(dyg * xhat, axis=-1, keepdims=True)
                return {
                    "dx": (dyg - m1 - xhat * m2) * jax.lax.rsqrt(var + eps)
                }

            return ln_dx

    if isinstance(spec, ResidualAddSpec):
        if pass_ == "fwd":
            return lambda j: {"y": j["x"] + j["x2"]}
        if pass_ == "dx":
            return lambda j: {"dx": j["dy"]}

    if isinstance(spec, EmbeddingSpec):
        if pass_ == "fwd":
            return lambda j: {
                "y": streaming.streaming_matmul(j["x"], j["w"],
                                                interpret=interpret)
            }
        if pass_ == "dw":
            return lambda j: {
                "dw": streaming.streaming_matmul(j["x"].T, j["dy"],
                                                 interpret=interpret)
            }

    if isinstance(spec, PosEmbedSpec):
        if pass_ == "fwd":
            return lambda j: {"y": j["x"] + j["w"][None]}
        if pass_ == "dw":
            return lambda j: {"dw": j["dy"].sum(axis=0)}
        if pass_ == "dx":
            return lambda j: {"dx": j["dy"]}

    if isinstance(spec, BatchedSpec):
        inner = _plan_callable(spec.spec, pass_, interpret)

        def batched(j):
            axes = {k: (None if k in ("w", "b") else 0) for k in j}
            return jax.vmap(inner, in_axes=(axes,))(j)

        return batched

    raise TypeError(
        f"no Pallas route for spec {type(spec).__name__} pass {pass_!r}"
    )


class CompiledPlan:
    """One jitted whole-pass executable plus its jax trace counter.

    ``traces`` increments each time jax (re-)traces the underlying function
    — after warmup on fixed shapes it must stay at 1, which the tests and
    the ``pallas_plan_cache`` benchmark assert.
    """

    __slots__ = ("key", "fn", "traces", "calls")

    def __init__(self, key):
        self.key = key
        self.fn = None
        self.traces = 0
        self.calls = 0

    def __call__(self, inputs):
        self.calls += 1
        return self.fn(inputs)


class PlanCache:
    """Compiled-program cache for the Pallas executor.

    Keyed by ``(spec, pass, design, interpret)`` — specs are frozen
    dataclasses carrying every static shape/stride, so two programs lowered
    from equal specs share one jitted executable. The cache is unbounded
    (one entry per distinct layer shape in the process); :meth:`clear`
    drops everything.
    """

    def __init__(self):
        self._plans: dict = {}
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._plans)

    def clear(self) -> None:
        self._plans.clear()
        self.hits = 0
        self.misses = 0

    def get(self, spec, pass_: str, design: str, interpret: bool) -> CompiledPlan:
        interpret = bool(interpret)
        return self.get_fn(
            (spec, pass_, design, interpret),
            lambda: _plan_callable(spec, pass_, interpret),
        )

    def get_fn(self, key, build) -> CompiledPlan:
        """A cached jitted plan for an arbitrary hashable key.

        ``build`` runs once per key to produce the raw jax callable.
        :meth:`get` routes per-node plans through here with
        ``(spec, pass, design, interpret)`` keys; the fused graph executor
        caches whole-train-step callables under step-level keys the same
        way, so the retrace/hit accounting covers both granularities.
        """
        plan = self._plans.get(key)
        if plan is not None:
            self.hits += 1
            return plan
        self.misses += 1
        import jax

        plan = CompiledPlan(key)
        raw = build()

        def counted(j):
            plan.traces += 1
            return raw(j)

        plan.fn = jax.jit(counted)
        self._plans[key] = plan
        return plan


#: Process-wide default cache; pass ``cache=`` to isolate (tests, benchmarks).
PLAN_CACHE = PlanCache()


def _cache_stats(cache: PlanCache) -> tuple[int, int, int, int]:
    """(hits, misses, traces, calls) — the plan-cache counter snapshot."""
    traces = sum(p.traces for p in cache._plans.values())
    calls = sum(p.calls for p in cache._plans.values())
    return cache.hits, cache.misses, traces, calls


def _record_cache_delta(reg, cache: PlanCache, before) -> None:
    """Book what the cache did during one executor call under plan_cache/."""
    if reg is None or not reg.enabled:
        return
    h, m, t, c = _cache_stats(cache)
    h0, m0, t0, c0 = before
    with reg.scope("plan_cache"):
        reg.inc("hits", h - h0)
        reg.inc("misses", m - m0)
        reg.inc("retraces", t - t0)
        reg.inc("calls", c - c0)


def _dispatch_plan(cache: PlanCache, design: str, interpret: bool):
    """The graph walkers' (spec, pass) -> plan closure, trace-span aware.

    With a :class:`repro.obs.trace.TraceCollector` active, every plan
    invocation is wrapped in a host-side dispatch span (the wall time jax
    spends entering the jitted executable — Pallas dispatch overhead).
    """
    col = obs_trace.get_active_trace()

    def plan(spec, pass_):
        p = cache.get(spec, pass_, design, interpret)
        if col is None:
            return p

        label = getattr(spec, "label", None)  # RegionSpec names its chain
        name = label or f"{type(spec).__name__}:{pass_}"
        cat = "fused" if label else "dispatch"

        def timed(j):
            with col.host_span(name, tid="dispatch", cat=cat):
                return p(j)

        return timed

    return plan


def _as_jax_f32(inputs: dict) -> dict:
    """Inputs as float32 jax arrays; device arrays pass through untouched.

    The identity check matters for step-level dispatch: ``jnp.asarray``
    with a dtype is not free even on an already-f32 device array, and a
    dozen per-step no-op conversions cost as much as a fused kernel.
    """
    import jax
    import jax.numpy as jnp

    out = {}
    for k, v in inputs.items():
        if not (isinstance(v, jax.Array) and v.dtype == jnp.float32):
            v = jnp.asarray(v, jnp.float32)
        out[k] = v
    return out


def _resolve_interpret(interpret):
    if interpret is not None:
        return bool(interpret)
    import jax

    return jax.default_backend() != "tpu"


def _fusion_for(program, *, fuse_updates: bool):
    """The program's memoized FusionPlan (region formation is per-program)."""
    plans = program.meta.setdefault("_fusion_plans", {})
    plan = plans.get(fuse_updates)
    if plan is None:
        from repro.lower import fuse as fuse_mod

        plan = fuse_mod.plan_fusion(program, fuse_updates=fuse_updates)
        plans[fuse_updates] = plan
    return plan


def _graph_fingerprint(graph):
    """Hashable identity of everything a step callable bakes in."""
    return (
        tuple(
            (n.name, n.spec, n.param, n.in_edge, n.out_edge, n.aux_edges)
            for n in graph.nodes
        ),
        graph.loss,
        graph.batch,
        graph.lr,
        graph.momentum,
        graph.input_edge,
        graph.label_edge,
        graph.logits_edge,
    )


def _step_plan(cache, graph, fusion, design, interpret, *, keep_grads):
    """One jitted callable for the WHOLE fused train step.

    The fused walk still dispatches 5-ish plans per step; at millisecond
    step times that per-plan jit entry overhead dominates the kernels
    themselves. Caching the entire segment walk as a single plan — keyed by
    the graph fingerprint plus the fusion plan's segment tuple (RegionSpecs
    are frozen) — collapses a step to one dispatch, with the region
    pallas_calls inlined into the step executable at trace time. Only used
    when no TraceCollector is active: traces want the per-plan host spans.
    """
    segs = tuple(
        s.step if s.region is None else s.region for s in fusion.segments
    )
    key = (
        "train_step",
        _graph_fingerprint(graph),
        segs,
        keep_grads,
        design,
        bool(interpret),
    )

    def build():
        plan = _dispatch_plan(cache, design, interpret)

        def raw(j):
            return _graph_step_local(
                graph, j, plan, graph.batch,
                keep_grads=keep_grads, fusion=fusion,
            )

        return raw

    return cache.get_fn(key, build)


def _record_fusion(reg, fusion) -> None:
    """Book what the fuser covered this step under fusion/."""
    if reg is None or not reg.enabled or fusion is None:
        return
    with reg.scope("fusion"):
        reg.inc("regions", fusion.n_regions)
        reg.inc("fallback_dispatches", len(fusion.fallback_steps))
        reg.inc("fused_commands", fusion.fused_commands)
        reg.inc(
            "unfused_commands",
            fusion.total_commands - fusion.fused_commands,
        )


def run_pallas(
    program: NtxProgram,
    inputs: dict,
    *,
    interpret: bool | None = None,
    cache: PlanCache | None = None,
    fuse: bool = True,
):
    """Execute the lowered layer through the cached Pallas plans.

    ``interpret=None`` picks the Pallas interpreter off-TPU (CPU tests) and
    the compiled kernel on TPU. Inputs may be numpy or ``jax.Array`` —
    device arrays pass straight through (no host round trip) — and outputs
    are ``jax.Array``s keyed like :func:`run_reference`'s output dict.
    Repeated calls on equal specs reuse one jitted executable from
    ``cache`` (default: the process-wide :data:`PLAN_CACHE`).

    Fused train-step programs execute as ONE cached jitted callable per
    step (the region kernels inline into it at trace time), so the warm
    path is a single dispatch — the executor analogue of the paper's
    "one offload per training step" goal.

    ``fuse`` (train-step programs only) routes the graph walk through the
    :mod:`repro.lower.fuse` region plan — whole fwd/bwd chains as single
    fused kernels — with per-node dispatch as the fallback for steps
    without a fusion rule. ``fuse=False`` is the escape hatch: the original
    one-plan-per-node walk, bit-for-bit the PR-4 behaviour.
    """
    interpret = _resolve_interpret(interpret)
    if cache is None:
        cache = PLAN_CACHE
    reg = obs.get_active()
    before = _cache_stats(cache) if reg is not None else None
    if program.meta.get("pass") == "train_step":
        if "mesh" in program.meta:
            out = _run_pallas_graph_mesh(
                program, inputs, interpret, cache, fuse=fuse
            )
        else:
            out = _run_pallas_graph(
                program, inputs, interpret, cache, fuse=fuse
            )
    else:
        spec = program.meta.get("spec")
        pass_ = program.meta.get("pass", "fwd")
        plan = _dispatch_plan(cache, program.design.name, interpret)(spec, pass_)
        out = plan(_as_jax_f32(inputs))
    if reg is not None:
        # The counters are the *program's* closed-form offload/DMA
        # arithmetic — what the NTX cube would execute for this step — not
        # a measurement of the jax backend that computed the numerics.
        obs.record_program(reg, program)
        _record_cache_delta(reg, cache, before)
    return out


def _run_pallas_graph(program, inputs, interpret: bool, cache, fuse=True):
    """Graph-driven Pallas execution of one whole-train-step program.

    Walks the :class:`repro.lower.graph.NetworkGraph` behind ``program`` in
    the same fwd → loss grad → dW/update/dX schedule the command stream
    encodes. With ``fuse`` (the default) the walk follows the program's
    :class:`repro.lower.fuse.FusionPlan`: contiguous fusable chains run as
    single region kernels, everything else through the cached per-node
    plans (per-image nodes key as :class:`BatchedSpec`). Outputs carry the
    program's output-region names — logits, ``d_<param>`` (when kept),
    ``<param>_new`` and ``v_<param>_new`` — so callers are
    executor-agnostic.
    """
    graph = program.meta["graph"]
    keep_grads = program.meta.get("keep_grads", True)
    j = _as_jax_f32(inputs)
    fusion = _fusion_for(program, fuse_updates=True) if fuse else None
    _record_fusion(obs.get_active(), fusion)
    if fusion is not None and obs_trace.get_active_trace() is None:
        step = _step_plan(cache, graph, fusion, program.design.name,
                          interpret, keep_grads=keep_grads)
        return step(j)
    plan = _dispatch_plan(cache, program.design.name, interpret)
    return _graph_step_local(graph, j, plan, graph.batch,
                             keep_grads=keep_grads, fusion=fusion)


def _graph_step_local(graph, j, plan, B, *, keep_grads=True,
                      grad_reduce=None, batched=None, fusion=None):
    """One train step over ``B``-image arrays through cached per-node plans.

    ``B`` is the batch the arrays actually carry — the graph's full batch
    on the single-device path, the per-shard slice inside the mesh route's
    ``shard_map`` body (where ``grad_reduce`` is the cross-shard psum that
    realizes the gradient allreduce; the loss plan's 1/B_global scale makes
    the psum a batch mean). ``batched`` forces a leading batch axis on the
    activations even at ``B == 1`` — a mesh shard of one image still
    carries its axis so the out-spec concatenation works. The walk mirrors
    the command stream's fwd → loss grad → dW/update/dX schedule exactly;
    with ``fusion`` set it follows the fusion plan's segments instead —
    the same schedule, chains collapsed into region dispatches.
    """
    reduce = grad_reduce or (lambda g: g)
    batched = (B > 1) if batched is None else batched
    if fusion is not None:
        return _walk_fused(graph, j, plan, B, fusion,
                           keep_grads=keep_grads, reduce=reduce,
                           batched=batched)

    def bspec(spec):
        return BatchedSpec(spec, B) if batched else spec

    # forward
    acts = {graph.input_edge: j[graph.input_edge]}
    for node in graph.nodes:
        s, a = node.spec, acts[node.in_edge]
        if isinstance(s, Conv2dSpec):
            y = plan(bspec(s), "fwd")({"x": a, "w": j[node.param]})["y"]
        elif isinstance(s, MatmulSpec):
            y = plan(s, "fwd")({"a": a, "b": j[node.param]})["c"]
        elif isinstance(s, BiasSpec):
            y = plan(s, "fwd")({"x": a.reshape(-1, s.c), "b": j[node.param]})
            y = y["y"].reshape(a.shape)
        elif isinstance(s, ReluSpec):
            whole = ReluSpec((B,) + tuple(s.shape)) if batched else s
            y = plan(whole, "fwd")({"x": a})["y"]
        elif isinstance(s, MaxPool2dSpec):
            y = plan(bspec(s), "fwd")({"x": a})["y"]
        elif isinstance(s, FlattenSpec):
            y = a.reshape((B, s.size) if batched else (s.size,))
        elif isinstance(s, AttentionSpec):
            # per-sequence node over token-row activations (rows = B*S)
            xb = a.reshape(-1, s.seq, 3 * s.d)
            y = plan(BatchedSpec(s, xb.shape[0]), "fwd")({"x": xb})["y"]
            y = y.reshape(-1, s.d)
        elif isinstance(s, LayerNormSpec):
            y = plan(s, "fwd")({"x": a, "w": j[node.param]})["y"]
        elif isinstance(s, ResidualAddSpec):
            y = plan(s, "fwd")(
                {"x": a, "x2": acts[node.aux_edges[0]]}
            )["y"]
        elif isinstance(s, EmbeddingSpec):
            y = plan(s, "fwd")({"x": a, "w": j[node.param]})["y"]
        elif isinstance(s, PosEmbedSpec):
            # -1, not s.batch: mesh shards walk with a local batch
            xb = a.reshape(-1, s.seq, s.d)
            y = plan(s, "fwd")({"x": xb, "w": j[node.param]})["y"]
            y = y.reshape(-1, s.d)
        else:
            raise TypeError(f"no graph route for {type(s).__name__}")
        acts[node.out_edge] = y

    logits = acts[graph.logits_edge]
    outs = {graph.logits_edge: logits}

    # loss gradient seeds the per-edge gradient map; DAG fan-out edges
    # accumulate one contribution per consumer (matching the compiled
    # program's partial + accumulate-step schedule)
    grads = {graph.logits_edge: plan(graph.loss, "dx")(
        {"z": logits, "onehot": j[graph.label_edge]}
    )["dz"]}

    def add_grad(edge, v):
        grads[edge] = grads[edge] + v if edge in grads else v

    # backward: dW -> update -> dX per node, in reverse
    for node in reversed(graph.nodes):
        s, a_in = node.spec, acts[node.in_edge]
        g = grads[node.out_edge]
        if node.param is not None:
            p = node.param
            if isinstance(s, Conv2dSpec):
                dwv = plan(bspec(s), "dw")({"x": a_in, "dy": g})["dw"]
                dw = dwv.sum(axis=0) if batched else dwv
            elif isinstance(s, MatmulSpec):
                dw = plan(s, "dw")({"a": a_in, "dy": g})["dw"]
            elif isinstance(s, BiasSpec):
                dw = plan(s, "dw")({"dy": g.reshape(-1, s.c)})["db"]
            elif isinstance(s, (LayerNormSpec, EmbeddingSpec)):
                dw = plan(s, "dw")({"x": a_in, "dy": g})["dw"]
            elif isinstance(s, PosEmbedSpec):
                dw = plan(s, "dw")(
                    {"dy": g.reshape(-1, s.seq, s.d)}
                )["dw"]
            else:
                raise TypeError(f"no dW route for {type(s).__name__}")
            dw = reduce(dw)
            if keep_grads:
                outs[f"d_{p}"] = dw
            u_spec = SgdUpdateSpec(
                n=dw.size, lr=graph.lr, momentum=graph.momentum
            )
            u_in = {"w": j[p].reshape(-1), "dw": dw.reshape(-1)}
            if graph.momentum:
                u_in["v"] = j[f"v_{p}"].reshape(-1)
            u = plan(u_spec, "upd")(u_in)
            outs[f"{p}_new"] = u["w_new"].reshape(j[p].shape)
            if graph.momentum:
                outs[f"v_{p}_new"] = u["v_new"].reshape(j[p].shape)
        if node.in_edge == graph.input_edge:
            continue
        if isinstance(s, Conv2dSpec):
            gx = plan(bspec(s), "dx")({"dy": g, "w": j[node.param]})["dx"]
        elif isinstance(s, MatmulSpec):
            gx = plan(s, "dx")({"dy": g, "b": j[node.param]})["dx"]
        elif isinstance(s, ReluSpec):
            whole = ReluSpec((B,) + tuple(s.shape)) if batched else s
            gx = plan(whole, "dx")({"x": a_in, "dy": g})["dx"]
        elif isinstance(s, MaxPool2dSpec):
            gx = plan(bspec(s), "dx")({"x": a_in, "dy": g})["dx"]
        elif isinstance(s, AttentionSpec):
            xb = a_in.reshape(-1, s.seq, 3 * s.d)
            gx = plan(BatchedSpec(s, xb.shape[0]), "dx")(
                {"x": xb, "dy": g.reshape(-1, s.seq, s.d)}
            )["dx"].reshape(a_in.shape)
        elif isinstance(s, LayerNormSpec):
            gx = plan(s, "dx")(
                {"x": a_in, "w": j[node.param], "dy": g}
            )["dx"]
        elif isinstance(s, ResidualAddSpec):
            gx = plan(s, "dx")({"dy": g})["dx"]
            add_grad(node.aux_edges[0], gx)
        elif isinstance(s, PosEmbedSpec):
            gx = plan(s, "dx")(
                {"dy": g.reshape(-1, s.seq, s.d)}
            )["dx"].reshape(-1, s.d)
        elif isinstance(s, (FlattenSpec, BiasSpec)):
            gx = g.reshape(a_in.shape)
        else:
            raise TypeError(f"no dX route for {type(s).__name__}")
        add_grad(node.in_edge, gx)
    return outs


def _walk_fused(graph, j, plan, B, fusion, *, keep_grads, reduce, batched):
    """The fused segment walk: region kernels + per-node fallback steps.

    Activations and activation gradients live in ``env`` keyed by edge
    name (gradient of edge ``e`` is ``d_<e>``) so region dispatches and
    fallback steps compose in any interleaving the fusion plan produced.
    Regions containing fused SGD updates require ``reduce`` to be the
    identity — the fuser only emits them on the single-device path.
    """
    import dataclasses

    nodes = {n.name: n for n in graph.nodes}
    env = {graph.input_edge: j[graph.input_edge]}
    outs: dict = {}

    def bspec(spec):
        return BatchedSpec(spec, B) if batched else spec

    def add_grad(edge, v):
        key = f"d_{edge}"
        env[key] = env[key] + v if key in env else v

    def exec_step(key):
        name, pass_ = key.split(":")
        if pass_ == "acc":
            # fan-out accumulate: the jax walk sums contributions into
            # d_<edge> as each consumer's dx lands, so by the time the
            # compiled schedule reaches the acc step there is nothing
            # left to do
            return
        if name == "loss":
            env[f"d_{graph.logits_edge}"] = plan(graph.loss, "dx")(
                {"z": env[graph.logits_edge], "onehot": j[graph.label_edge]}
            )["dz"]
            return
        node = nodes[name]
        s = node.spec
        if pass_ == "fwd":
            a = env[node.in_edge]
            if isinstance(s, Conv2dSpec):
                y = plan(bspec(s), "fwd")({"x": a, "w": j[node.param]})["y"]
            elif isinstance(s, MatmulSpec):
                y = plan(s, "fwd")({"a": a, "b": j[node.param]})["c"]
            elif isinstance(s, BiasSpec):
                y = plan(s, "fwd")(
                    {"x": a.reshape(-1, s.c), "b": j[node.param]}
                )["y"].reshape(a.shape)
            elif isinstance(s, ReluSpec):
                whole = ReluSpec((B,) + tuple(s.shape)) if batched else s
                y = plan(whole, "fwd")({"x": a})["y"]
            elif isinstance(s, MaxPool2dSpec):
                y = plan(bspec(s), "fwd")({"x": a})["y"]
            elif isinstance(s, FlattenSpec):
                y = a.reshape((B, s.size) if batched else (s.size,))
            elif isinstance(s, AttentionSpec):
                xb = a.reshape(-1, s.seq, 3 * s.d)
                y = plan(BatchedSpec(s, xb.shape[0]), "fwd")({"x": xb})["y"]
                y = y.reshape(-1, s.d)
            elif isinstance(s, LayerNormSpec):
                y = plan(s, "fwd")({"x": a, "w": j[node.param]})["y"]
            elif isinstance(s, ResidualAddSpec):
                y = plan(s, "fwd")(
                    {"x": a, "x2": env[node.aux_edges[0]]}
                )["y"]
            elif isinstance(s, EmbeddingSpec):
                y = plan(s, "fwd")({"x": a, "w": j[node.param]})["y"]
            elif isinstance(s, PosEmbedSpec):
                # -1, not s.batch: mesh shards walk with a local batch
                xb = a.reshape(-1, s.seq, s.d)
                y = plan(s, "fwd")({"x": xb, "w": j[node.param]})["y"]
                y = y.reshape(-1, s.d)
            else:
                raise TypeError(f"no graph route for {type(s).__name__}")
            env[node.out_edge] = y
        elif pass_ == "dw":
            g = env[f"d_{node.out_edge}"]
            if isinstance(s, Conv2dSpec):
                dwv = plan(bspec(s), "dw")(
                    {"x": env[node.in_edge], "dy": g}
                )["dw"]
                dw = dwv.sum(axis=0) if batched else dwv
            elif isinstance(s, MatmulSpec):
                dw = plan(s, "dw")({"a": env[node.in_edge], "dy": g})["dw"]
            elif isinstance(s, BiasSpec):
                dw = plan(s, "dw")({"dy": g.reshape(-1, s.c)})["db"]
            elif isinstance(s, (LayerNormSpec, EmbeddingSpec)):
                dw = plan(s, "dw")({"x": env[node.in_edge], "dy": g})["dw"]
            elif isinstance(s, PosEmbedSpec):
                dw = plan(s, "dw")(
                    {"dy": g.reshape(-1, s.seq, s.d)}
                )["dw"]
            else:
                raise TypeError(f"no dW route for {type(s).__name__}")
            dw = reduce(dw)
            env[f"d_{node.param}"] = dw
            if keep_grads:
                outs[f"d_{node.param}"] = dw
        elif pass_ == "upd":
            p = node.param
            dw = env[f"d_{p}"]
            u_spec = SgdUpdateSpec(
                n=dw.size, lr=graph.lr, momentum=graph.momentum
            )
            u_in = {"w": j[p].reshape(-1), "dw": dw.reshape(-1)}
            if graph.momentum:
                u_in["v"] = j[f"v_{p}"].reshape(-1)
            u = plan(u_spec, "upd")(u_in)
            outs[f"{p}_new"] = u["w_new"].reshape(j[p].shape)
            if graph.momentum:
                outs[f"v_{p}_new"] = u["v_new"].reshape(j[p].shape)
        else:  # dx
            g = env[f"d_{node.out_edge}"]
            if isinstance(s, Conv2dSpec):
                gx = plan(bspec(s), "dx")({"dy": g, "w": j[node.param]})["dx"]
            elif isinstance(s, MatmulSpec):
                gx = plan(s, "dx")({"dy": g, "b": j[node.param]})["dx"]
            elif isinstance(s, ReluSpec):
                whole = ReluSpec((B,) + tuple(s.shape)) if batched else s
                gx = plan(whole, "dx")(
                    {"x": env[node.in_edge], "dy": g}
                )["dx"]
            elif isinstance(s, MaxPool2dSpec):
                gx = plan(bspec(s), "dx")(
                    {"x": env[node.in_edge], "dy": g}
                )["dx"]
            elif isinstance(s, AttentionSpec):
                a_in = env[node.in_edge]
                xb = a_in.reshape(-1, s.seq, 3 * s.d)
                gx = plan(BatchedSpec(s, xb.shape[0]), "dx")(
                    {"x": xb, "dy": g.reshape(-1, s.seq, s.d)}
                )["dx"].reshape(a_in.shape)
            elif isinstance(s, LayerNormSpec):
                gx = plan(s, "dx")(
                    {"x": env[node.in_edge], "w": j[node.param], "dy": g}
                )["dx"]
            elif isinstance(s, ResidualAddSpec):
                gx = plan(s, "dx")({"dy": g})["dx"]
                add_grad(node.aux_edges[0], gx)
            elif isinstance(s, PosEmbedSpec):
                gx = plan(s, "dx")(
                    {"dy": g.reshape(-1, s.seq, s.d)}
                )["dx"].reshape(-1, s.d)
            elif isinstance(s, FlattenSpec):
                shape = tuple(s.in_shape)
                gx = g.reshape((B,) + shape if batched else shape)
            else:  # BiasSpec dx: shape-preserving passthrough
                gx = g.reshape(env[node.in_edge].shape)
            add_grad(node.in_edge, gx)

    for seg in fusion.segments:
        if seg.region is None:
            exec_step(seg.step)
            continue
        region = seg.region
        if region.batch != B:
            region = dataclasses.replace(region, batch=B)
        ins = {}
        for name, is_b in region.inputs:
            v = env[name] if name in env else j[name]
            ins[name] = v[None] if (is_b and not batched) else v
        ro = plan(region, "region")(ins)
        for name, kind in region.outputs:
            v = ro[name]
            if kind == "batched":
                env[name] = v if batched else v[0]
            elif name.startswith("d_"):
                dw = reduce(v)
                env[name] = dw
                if keep_grads:
                    outs[name] = dw
            else:  # <param>_new / v_<param>_new epilogue results
                outs[name] = v
    outs[graph.logits_edge] = env[graph.logits_edge]
    return outs


def _run_pallas_graph_mesh(program, inputs, interpret: bool, cache,
                           fuse=True):
    """Data-parallel execution of a mesh-sharded train-step program.

    The batch shards over a ``(pod, data)`` jax device mesh shaped like the
    HMC mesh (the same DP-axis convention as :mod:`repro.parallel.sharding`)
    via ``shard_map``; each shard walks the graph on its slice through the
    shared :class:`PlanCache`, and the gradient allreduce epilogue is a
    cross-shard ``psum`` — a batch *mean* because the loss plan already
    scales by 1 / B_global. Updated weights come back replicated, exactly
    like the allgather of the command-level epilogue. With fewer jax
    devices than HMCs the walk runs unsharded on the full batch — the same
    numerics, minus the parallelism (the command-level program is
    unaffected; only this executor degrades).

    Elastically re-sharded programs (``mesh_meta["alive"]`` set by
    :func:`repro.lower.mesh.reshard_training_step`) re-enter ``shard_map``
    over a SHRUNKEN ``(1, n_alive)`` jax mesh — the survivors' batch
    shards, with the psum spanning only the shrunken mesh. When the batch
    no longer divides the survivor count (uneven re-chunking) or too few
    jax devices remain, the same single-device walk takes over.

    2D-sharded programs (``mesh_meta["shard"] == "2d"``) run over a real
    2D jax mesh with ``("pipe", "data")`` axes shaped like the physical
    (pipeline rows x tensor/data columns) grid. The jax mesh computes the
    data-parallel *numerics* — batch sharded over both axes, gradient
    psum across the full mesh — which is exactly the arithmetic the
    2D command stream replays (identity-copy communication, disjoint
    output splits), so gradients match ``jax.grad`` bit-for-tolerance
    like the 1D path; the pipeline fill/drain and tensor-shard structure
    live in the command stream and the timing model
    (:func:`repro.runtime.mesh.time_mesh_step_2d`), not in XLA's
    schedule. ``fuse_updates=False`` already holds on this path: the
    cross-mesh psum must run between dW and the SGD update whether the
    columns are data- or tensor-sharded, so the fuser interaction is
    identical for both layouts.
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro import compat

    graph = program.meta["graph"]
    mesh_meta = program.meta["mesh"]
    rows, cols = mesh_meta["shape"]
    n = mesh_meta["n_hmcs"]
    alive = mesh_meta.get("alive")
    n_alive = len(alive) if alive is not None else n
    B = graph.batch
    keep_grads = program.meta.get("keep_grads", True)
    j = _as_jax_f32(inputs)
    plan = _dispatch_plan(cache, program.design.name, interpret)

    if jax.device_count() < n_alive or B % n_alive:
        fusion = _fusion_for(program, fuse_updates=True) if fuse else None
        _record_fusion(obs.get_active(), fusion)
        if fusion is not None and obs_trace.get_active_trace() is None:
            step = _step_plan(cache, graph, fusion, program.design.name,
                              interpret, keep_grads=keep_grads)
            return step(j)
        return _graph_step_local(graph, j, plan, B, keep_grads=keep_grads,
                                 fusion=fusion)
    # inside shard_map the gradient psum must run between dW and the SGD
    # update, so regions keep the updates as per-node fallback dispatches
    fusion = _fusion_for(program, fuse_updates=False) if fuse else None
    _record_fusion(obs.get_active(), fusion)

    # 2D programs name the axes after their meaning (pipeline rows x
    # tensor/data columns); 1D keeps the (pod, data) convention of
    # repro.parallel.sharding. Either way both axes carry batch shards.
    dp_axes = (
        ("pipe", "data") if mesh_meta.get("shard") == "2d" else ("pod", "data")
    )
    # a degraded mesh no longer matches the physical (rows, cols) grid:
    # lay the survivors out along one axis of a shrunken jax mesh
    jax_shape = (rows, cols) if n_alive == n else (1, n_alive)
    mesh = compat.make_mesh(jax_shape, dp_axes)
    sharded_edges = {graph.input_edge, graph.label_edge}

    def batch_spec(name):
        return P(dp_axes) if name in sharded_edges else P()

    in_specs = ({k: batch_spec(k) for k in j},)
    out_specs = {graph.logits_edge: P(dp_axes)}
    for p in graph.param_shapes():
        out_specs[f"{p}_new"] = P()
        if keep_grads:
            out_specs[f"d_{p}"] = P()
        if graph.momentum:
            out_specs[f"v_{p}_new"] = P()

    def per_shard(shard_j):
        return _graph_step_local(
            graph, shard_j, plan, B // n_alive, keep_grads=keep_grads,
            grad_reduce=lambda g: jax.lax.psum(g, dp_axes), batched=True,
            fusion=fusion,
        )

    return compat.shard_map(
        per_shard, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        axis_names=set(dp_axes), check_vma=False,
    )(j)

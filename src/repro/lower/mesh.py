"""Mesh-of-HMCs data parallelism: shard a train-step program across cubes.

The paper's §4.9 scales training past one HMC by replicating the cube and
splitting the batch: every cube runs the same step on its shard of the
images, then the weight update is exchanged over the serial links (eqs.
14-21). :func:`shard_training_step` realizes that at the command level, on
top of the PR-4 graph compiler: it takes ONE whole-train-step
:class:`~repro.lower.ir.NtxProgram` and splits it into per-HMC shard
programs plus an explicit gradient-allreduce epilogue, emitted as ordinary
DMA/MAC :class:`~repro.lower.ir.CommandBlock`s.

Bit-identity is the design invariant, and it holds *by construction* rather
than by tolerance:

  * **Batch-parallel blocks** (forward, dX, the per-image conv-dW replicas,
    the loss-gradient stream) are split along the batch: either the
    outermost driver replication level the graph compiler appended
    (:func:`split_block_reps`) or the outermost template loop
    (:func:`~repro.runtime.scheduler.partition_command`). Concatenating the
    shard pieces in shard order reproduces the original command stream
    exactly — same commands, same order, same accumulator roundings.
  * **Cross-batch gradient reductions** (the conv batch-reduce MAC, the
    matmul dW, the bias db) become the *reduce-scatter* phase: each is
    split along its **output** dims into one chunk per HMC, so every chunk
    keeps its full f64 accumulation over all B contributions in the
    unsharded image order — one rounding per output element, exactly like
    the unsharded command. Chunk c is owned by HMC c and reads the other
    shards' per-image contributions across the mesh links.
  * **The SGD update** splits the same way: HMC c updates the parameter
    chunk it just reduced (the ZeRO-style sharded update of the paper's
    systolic weight exchange), and an **allgather** epilogue of identity
    ``copy`` blocks broadcasts every updated chunk back to the replicas —
    semantically a no-op in the flat reference memory (read AGU == write
    AGU), but carrying the link traffic the timing model charges.

One deliberate deviation from the textbook gradient ring: the matmul-dW
chunks read the batch-sharded *activations* across links (an activation
gather) instead of pre-reduced gradient partials, because a per-shard
partial sum would insert an extra fp32 rounding and break bit-identity.
The timing model charges the §4.9 weight-update traffic (eqs. 14-15)
either way; ``docs/architecture.md`` discusses the trade.

Past pure data parallelism, ``shard_training_step(..., shard="2d")``
lays the same step out over a 2D logical mesh: rows are **pipeline
stages** (contiguous layer runs balanced by busy cycles, GPipe-style
microbatch fill/drain) and columns are a **tensor/data hybrid** within
each stage — conv/matmul/bias blocks split their output-channel
replication level across the row (the rules in
:mod:`repro.parallel.sharding` decide which layers tensor-shard), stage
parameters live only on their row, and the stage-boundary activations/
gradients cross the vertical links as explicit ``send:``/``recv:``
identity-copy chunks. The same bit-identity invariant holds: every
communication block is an identity copy and every compute split is a
disjoint partition of pure output dims, so the combined stream replays
the unsharded arithmetic exactly. See :func:`_split_program_2d`.

The combined program (:attr:`ShardedTrainStep.program`) is consumed
unchanged by ``run_reference``/``run_timing``; ``run_pallas`` routes it
through a ``shard_map`` over a jax device mesh (see
:mod:`repro.lower.executors`), and :mod:`repro.runtime.mesh` times the
per-HMC shard programs plus the inter-HMC link schedule
(:func:`repro.runtime.mesh.time_mesh_step` /
:func:`~repro.runtime.mesh.time_mesh_step_2d`).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, replace

from repro.core.ntx import Agu, NtxCommand
from repro.lower.graph import NetworkGraph, lower_training_step
from repro.lower.ir import (
    ELEM_BYTES,
    CommandBlock,
    DesignPoint,
    NTX_DESIGN,
    NtxProgram,
    TensorRegion,
)

#: Blocks whose template body is at most this many iterations are treated as
#: driver-side staging (constant memsets, the 1.0 scalar) and replicated to
#: every HMC instead of being assigned to one.
_TINY_ITERS = 64

#: hmc assignment sentinel: the block runs on every HMC (reference executes
#: it once; the timing model charges it to each cube).
ALL_HMCS = -1


def parse_mesh(mesh: str | tuple[int, int]) -> tuple[int, int]:
    """``"2x4"`` or ``(2, 4)`` -> (rows, cols)."""
    if isinstance(mesh, str):
        try:
            r, c = (int(p) for p in mesh.lower().split("x"))
        except ValueError as e:
            raise ValueError(f"mesh spec {mesh!r} is not 'RxC'") from e
        return r, c
    r, c = mesh
    return int(r), int(c)


def _chunk_sizes(n: int, parts: int) -> list[int]:
    """The contiguous chunk sizes :func:`partition_command` uses (remainder
    spread over the first chunks) — shared so reduce/update/allgather agree
    on ownership boundaries."""
    parts = min(parts, n)
    base, rem = divmod(n, parts)
    return [base + (1 if p < rem else 0) for p in range(parts)]


def _rebased(agu: Agu | None, delta: int) -> Agu | None:
    if agu is None or delta == 0:
        return agu
    return Agu(agu.base + delta, agu.strides)


def split_block_reps(
    block: CommandBlock, parts: int, level: int = -1
) -> list[CommandBlock]:
    """Split one of a block's driver replication levels into ``parts``
    contiguous runs.

    ``level`` indexes :attr:`CommandBlock.reps` (innermost first; the
    default ``-1`` is the outermost level — the batch loop the graph
    compiler appended, used by the 1D batch split). The 2D tensor split
    passes ``len(reps) - 2``: for every conv lowering (NTX and NS alike)
    that is the output-channel replication level, so the pieces partition
    the layer's output channels.

    Pieces keep the full odometer shape except at ``level``, where piece
    ``p`` covers a contiguous run of replica indices with the template
    rebased by ``start * step`` per AGU — the same arithmetic
    :meth:`CommandBlock.commands` performs. Splitting any rep level
    yields disjoint writes (driver reps are pure output dims — the
    lowering keeps reduction dims inside the template), so concatenating
    the pieces reproduces the original final memory bit for bit even
    though the *outer* iteration order changes when ``level`` is not the
    outermost.
    """
    if level < 0:
        level += len(block.reps)
    n_out = block.reps[level]
    sizes = _chunk_sizes(n_out, parts)
    out = []
    start = 0
    t = block.template
    for sz in sizes:
        d0 = start * block.rd0_step[level]
        d1 = start * block.rd1_step[level]
        dw = start * block.wr_step[level]
        out.append(
            replace(
                block,
                template=NtxCommand(
                    loops=t.loops,
                    opcode=t.opcode,
                    agu_rd0=_rebased(t.agu_rd0, d0),
                    agu_rd1=_rebased(t.agu_rd1, d1),
                    agu_wr=_rebased(t.agu_wr, dw),
                    init_level=t.init_level,
                    store_level=t.store_level,
                    init_value=t.init_value,
                ),
                reps=block.reps[:level] + (sz,) + block.reps[level + 1 :],
            )
        )
        start += sz
    return out


def split_block_template(block: CommandBlock, parts: int) -> list[CommandBlock]:
    """Split a block along its template's outermost splittable loop —
    :func:`~repro.runtime.scheduler.partition_command` with the block's
    driver loops and block-level DMA totals carried over (traffic
    preserved, like ``partition_program``). Blocks whose template refuses
    to split (unit loops, accumulator spans) come back whole.

    Shared by the batch sharding here and the coarse-block §3.1 refinement
    of :mod:`repro.runtime.mesh` — one implementation of the
    piece/DMA-division semantics.
    """
    from repro.runtime.scheduler import partition_command

    try:
        pieces = partition_command(block.template, parts)
    except ValueError:
        pieces = [block.template]
    if len(pieces) == 1:
        return [block]
    return [
        replace(
            block,
            template=p,
            dma_bytes_in=block.dma_bytes_in / len(pieces),
            dma_bytes_out=block.dma_bytes_out / len(pieces),
        )
        for p in pieces
    ]


def _bcast_block(
    region: TensorRegion, start: int, size: int, owner: int, n_hmcs: int,
    *, tag_prefix: str = "allgather",
) -> CommandBlock:
    """One allgather step: HMC ``owner`` broadcasts its updated chunk.

    An identity copy (read AGU == write AGU) over the chunk — semantically
    a no-op in the flat reference memory, but it occupies the engine for
    one cycle per word and carries ``(n_hmcs - 1)`` chunk transfers of link
    traffic, which :mod:`repro.runtime.mesh` schedules over the serial
    links.
    """
    agu = Agu(region.base + start, (1, 0, 0, 0, 0))
    return CommandBlock(
        template=NtxCommand(
            loops=(size, 1, 1, 1, 1),
            opcode="copy",
            agu_rd0=agu,
            agu_wr=agu,
            init_level=0,
            store_level=0,
        ),
        tag=f"{tag_prefix}:{region.name}[{owner}]",
        reads=(region.name,),
        writes=(region.name,),
        dma_bytes_out=float(size * ELEM_BYTES * max(n_hmcs - 1, 0)),
    )


def _xfer_block(
    region: TensorRegion, start: int, size: int, kind: str, idx: int
) -> CommandBlock:
    """One pipeline-boundary transfer chunk: ``send:`` or ``recv:``.

    Like :func:`_bcast_block` an identity copy over a contiguous chunk of
    the boundary tensor — a no-op in the flat reference memory, but the
    block carries the chunk's bytes as outbound (``send``, charged to the
    producing stage's cube) or inbound (``recv``, charged to the consuming
    stage's cube) DMA, and :func:`repro.runtime.mesh.time_mesh_step_2d`
    schedules the matching vertical-link events per microbatch.
    """
    agu = Agu(region.base + start, (1, 0, 0, 0, 0))
    nbytes = float(size * ELEM_BYTES)
    return CommandBlock(
        template=NtxCommand(
            loops=(size, 1, 1, 1, 1),
            opcode="copy",
            agu_rd0=agu,
            agu_wr=agu,
            init_level=0,
            store_level=0,
        ),
        tag=f"{kind}:{region.name}[{idx}]",
        reads=(region.name,),
        writes=(region.name,),
        dma_bytes_out=nbytes if kind == "send" else 0.0,
        dma_bytes_in=nbytes if kind == "recv" else 0.0,
    )


@dataclass
class ShardedTrainStep:
    """One train step split across a mesh of HMCs.

    ``program`` is the combined command stream (bit-identical to the
    unsharded step under ``run_reference``); ``hmc_of_block[i]`` says which
    cube issues ``program.blocks[i]`` (:data:`ALL_HMCS` = every cube).
    ``alive`` is the ordered tuple of surviving cube ids after an elastic
    re-shard (:func:`reshard_training_step`); ``None`` means every cube in
    the physical mesh is healthy.
    """

    graph: NetworkGraph
    mesh_shape: tuple[int, int]
    program: NtxProgram
    base_program: NtxProgram
    hmc_of_block: list[int]
    alive: tuple[int, ...] | None = None

    @property
    def n_hmcs(self) -> int:
        """Cubes in the *physical* mesh (dead ones included)."""
        return self.mesh_shape[0] * self.mesh_shape[1]

    @property
    def alive_hmcs(self) -> tuple[int, ...]:
        return self.alive if self.alive is not None else tuple(range(self.n_hmcs))

    @property
    def n_alive(self) -> int:
        return len(self.alive_hmcs)

    @property
    def failed_hmcs(self) -> tuple[int, ...]:
        return tuple(sorted(set(range(self.n_hmcs)) - set(self.alive_hmcs)))

    @property
    def shard_batch(self) -> int:
        """Images per surviving cube (the largest shard when uneven)."""
        return -(-self.graph.batch // self.n_alive)

    @property
    def shard(self) -> str:
        """``"1d"`` (batch split) or ``"2d"`` (pipeline rows x tensor/data
        columns)."""
        return self.program.meta.get("mesh", {}).get("shard", "1d")

    @property
    def row_owners(self) -> list[tuple[int, ...]] | None:
        """2D programs: surviving cube ids per pipeline row (else None)."""
        ro = self.program.meta.get("mesh", {}).get("row_owners")
        return [tuple(r) for r in ro] if ro is not None else None

    @property
    def allreduce_bytes(self) -> float:
        """Bytes of parameters exchanged per update pass (eq. 14's W)."""
        return float(sum(
            math.prod(shape) * ELEM_BYTES
            for shape in self.graph.param_shapes().values()
        ))

    def shard_program(self, hmc: int) -> NtxProgram:
        """The command stream cube ``hmc`` issues (plus replicated staging).

        All shards are structurally symmetric — timing one of them times
        them all.
        """
        if not 0 <= hmc < self.n_hmcs:
            raise ValueError(f"hmc {hmc} outside mesh {self.mesh_shape}")
        if hmc not in self.alive_hmcs:
            raise ValueError(
                f"hmc {hmc} has failed; survivors are {self.alive_hmcs}"
            )
        blocks = [
            b for b, h in zip(self.program.blocks, self.hmc_of_block)
            if h == hmc or h == ALL_HMCS
        ]
        return NtxProgram(
            name=f"{self.program.name}:hmc{hmc}",
            blocks=blocks,
            regions=self.program.regions,
            design=self.program.design,
            meta={**self.program.meta, "hmc": hmc},
        )

    def epilogue_blocks(self) -> list[tuple[int, CommandBlock]]:
        """(hmc, block) pairs of the communication blocks, in program order.

        1D programs: the reduce-scatter/update/allgather epilogue. 2D
        programs additionally carry the in-row tensor gathers and the
        pipeline-boundary ``send:``/``recv:`` chunks.
        """
        out = []
        comm = ("allreduce:", "allgather:", "tpgather:", "send:", "recv:")
        for b, h in zip(self.program.blocks, self.hmc_of_block):
            if b.tag.startswith(comm):
                out.append((h, b))
        return out


def _n_microbatches(batch: int, rows: int) -> int:
    """GPipe microbatch count for the fill/drain schedule: aim for ~16
    in-flight microbatches (bubble fraction ``(R-1)/(M+R-1)`` under 20%
    for R <= 4), clipped to what divides the batch."""
    if rows <= 1:
        return 1
    return max(1, math.gcd(batch, 16 * (rows - 1)))


def shard_training_step(
    graph: NetworkGraph,
    *,
    design: DesignPoint = NTX_DESIGN,
    mesh_shape: str | tuple[int, int] = (2, 2),
    n_clusters: int = 16,
    keep_grads: bool = True,
    program: NtxProgram | None = None,
    shard: str = "1d",
) -> ShardedTrainStep:
    """Compile ``graph`` and split its train-step program across a mesh.

    ``program`` optionally supplies the already-compiled unsharded step
    (must come from ``lower_training_step(graph, ...)`` with the same
    design). The batch must divide evenly over the mesh.

    ``shard="1d"`` (default) is pure data parallelism — every cube runs
    the whole step on its batch shard. Block classification:

      * blocks writing a ``d_<param>`` region are the gradient reductions —
        split by output chunk (**reduce-scatter**, chunk c -> HMC c) and
        re-tagged ``allreduce:reduce:...``;
      * blocks writing ``<param>_new`` / ``v_<param>_new`` are the update —
        split by the same chunks (owner updates what it reduced), with the
        weight allgather appended after the parameter's last update piece;
      * everything else splits along the batch (outermost rep level, else
        the outermost template loop); unsplittable staging (constant
        memsets) is replicated to every HMC.

    ``shard="2d"`` maps mesh *rows* to pipeline stages (contiguous layer
    runs balanced by busy cycles, GPipe fill/drain over
    ``meta["mesh"]["pipeline"]["n_micro"]`` microbatches) and mesh
    *columns* to a tensor/data hybrid within each stage — see
    :func:`_split_program_2d`. Stage parameters live only on their row
    (the per-shard weight regions: each row holds ~1/R of the model), so
    a model too big for one HMC fits a tall-enough mesh. Both layouts
    produce a combined stream that is bit-identical to the unsharded step
    under ``run_reference``.
    """
    rows, cols = parse_mesh(mesh_shape)
    n = rows * cols
    if n < 1:
        raise ValueError(f"degenerate mesh {rows}x{cols}")
    if graph.batch % n:
        raise ValueError(
            f"batch {graph.batch} does not divide over a {rows}x{cols} mesh"
        )
    if shard not in ("1d", "2d"):
        raise ValueError(f"shard must be '1d' or '2d', got {shard!r}")
    if program is None:
        program = lower_training_step(
            graph, design=design, n_clusters=n_clusters, keep_grads=keep_grads
        )

    if shard == "2d":
        row_owners = [tuple(range(r * cols, (r + 1) * cols)) for r in range(rows)]
        blocks, hmc_of, pmeta = _split_program_2d(program, graph, row_owners)
        pmeta["n_micro"] = _n_microbatches(graph.batch, rows)
        mesh_meta = {
            "shape": (rows, cols),
            "n_hmcs": n,
            "shard_batch": graph.batch // n,
            "shard": "2d",
            "row_owners": [list(ro) for ro in row_owners],
            "pipeline": pmeta,
        }
    else:
        blocks, hmc_of = _split_program_onto(program, graph, tuple(range(n)))
        mesh_meta = {
            "shape": (rows, cols),
            "n_hmcs": n,
            "shard_batch": graph.batch // n,
        }

    combined = NtxProgram(
        name=f"{program.name}:mesh{rows}x{cols}"
        + (":2d" if shard == "2d" else ""),
        blocks=blocks,
        regions=program.regions,
        design=program.design,
        meta={**program.meta, "mesh": mesh_meta},
    )
    sharded = ShardedTrainStep(
        graph=graph,
        mesh_shape=(rows, cols),
        program=combined,
        base_program=program,
        hmc_of_block=hmc_of,
    )
    from repro.obs import counters as obs

    reg = obs.get_active()
    if reg is not None:
        with reg.scope("shard"):
            reg.inc("programs", 1)
            reg.inc("hmcs", n)
            reg.inc("epilogue_blocks", len(sharded.epilogue_blocks()))
            reg.inc("allreduce_bytes", sharded.allreduce_bytes)
            if shard == "2d":
                reg.inc("pipeline_stages", rows)
    return sharded


def _split_program_onto(
    program: NtxProgram, graph: NetworkGraph, owners: tuple[int, ...]
) -> tuple[list[CommandBlock], list[int]]:
    """Partition the unsharded step program over the cubes in ``owners``.

    The shared core of :func:`shard_training_step` (owners = the whole
    mesh) and :func:`reshard_training_step` (owners = the survivors).
    ``len(owners)`` sets the number of batch shards / reduce-scatter chunks;
    the owner *values* are the physical cube ids the pieces land on, so a
    degraded mesh re-partitions the exact same command stream onto fewer
    cubes — concatenation order (and therefore ``run_reference`` output) is
    unchanged by construction.
    """
    parts = len(owners)
    params = set(graph.param_shapes())
    grad_regions = {f"d_{p}" for p in params}
    new_regions = {f"{p}_new" for p in params} | {f"v_{p}_new" for p in params}
    param_of_new = {f"{p}_new": p for p in params}

    blocks: list[CommandBlock] = []
    hmc_of: list[int] = []

    def emit(piece: CommandBlock, hmc: int) -> None:
        blocks.append(piece)
        hmc_of.append(hmc)

    def emit_split(pieces: list[CommandBlock], retag: str | None = None) -> None:
        if len(pieces) == 1:
            b = pieces[0]
            tiny = b.template.total_iterations <= _TINY_ITERS and b.n_commands == 1
            emit(b, ALL_HMCS if tiny else owners[0])
            return
        for i, b in enumerate(pieces):
            if retag:
                b = replace(b, tag=f"{retag}:{b.tag}[{i}]")
            # pieces < parts only when the split dim had fewer iterations
            # than cubes; owners then cover a prefix of the survivors.
            emit(b, owners[i % parts])

    def output_split(b: CommandBlock) -> list[CommandBlock]:
        # Reduction/update blocks keep every reduction dim inside the
        # template (the lowering enforces usable >= n_red), so any driver
        # rep level is a pure output dim: rep-split and template-split are
        # both contiguous output-chunk (reduce-scatter) splits.
        return (
            split_block_reps(b, parts) if b.reps else split_block_template(b, parts)
        )

    for block in program.blocks:
        spillage = block.tag.startswith(("spill:", "fill:"))
        is_reduce = not spillage and any(w in grad_regions for w in block.writes)
        is_update = not spillage and any(w in new_regions for w in block.writes)
        if is_reduce:
            # cross-batch gradient reduction: output-chunk split ==
            # reduce-scatter. (Batched conv per-image dW replica writes
            # target the ``<node>.dwb`` staging region, not ``d_<param>``,
            # and take the batch split below — they are shard-local.)
            emit_split(output_split(block), retag="allreduce:reduce")
            continue
        if is_update:
            emit_split(output_split(block), retag="allreduce:update")
            # after the *parameter* update (not the momentum block), each
            # owner broadcasts its updated chunk to the other replicas
            wn = next((w for w in block.writes if w in param_of_new), None)
            if wn is not None:
                r = program.regions[wn]
                start = 0
                for c, sz in enumerate(_chunk_sizes(r.size, parts)):
                    if parts > 1:
                        emit(_bcast_block(r, start, sz, owners[c], parts), owners[c])
                    start += sz
            continue
        if block.reps:
            emit_split(split_block_reps(block, parts))
        else:
            emit_split(split_block_template(block, parts))

    return blocks, hmc_of


def _balanced_cuts(weights: list[int], k: int) -> list[tuple[int, int]]:
    """Contiguous min-max partition of ``weights`` into ``k`` non-empty
    runs (classic linear-partition DP). Returns ``[(start, stop), ...]``."""
    n = len(weights)
    prefix = [0]
    for w in weights:
        prefix.append(prefix[-1] + w)
    inf = float("inf")
    best = [[inf] * (k + 1) for _ in range(n + 1)]
    arg = [[0] * (k + 1) for _ in range(n + 1)]
    best[0][0] = 0.0
    for i in range(1, n + 1):
        for j in range(1, min(i, k) + 1):
            for m in range(j - 1, i):
                cost = max(best[m][j - 1], prefix[i] - prefix[m])
                if cost < best[i][j]:
                    best[i][j] = cost
                    arg[i][j] = m
    cuts: list[tuple[int, int]] = []
    i, j = n, k
    while j:
        m = arg[i][j]
        cuts.append((m, i))
        i, j = m, j - 1
    return cuts[::-1]


def _pipeline_stages(
    graph: NetworkGraph, program: NtxProgram, n_stages: int
) -> tuple[list[list[str]], list[int]]:
    """Assign the graph's layers to ``n_stages`` contiguous pipeline stages.

    Stage weight is the layer's busy cycles in the unsharded step program
    (fwd + dW + dX + update, read off the block tags), so the min-max cut
    balances the *training* work per mesh row, not the parameter count.
    The loss gradient runs where the logits live (folded into the last
    layer); spill/fill traffic rides with whichever stage is active.
    Zero-cycle layers trailing a stage (flatten aliases) are pushed into
    the next stage so every stage boundary edge is a tensor some block
    actually writes.
    """
    names = [nd.name for nd in graph.nodes]
    cyc = dict.fromkeys(names, 0)
    extra_last = 0
    for b in program.blocks:
        head = b.tag.split(":")[0]
        if head in cyc:
            cyc[head] += b.busy_cycles
        elif head == "loss":
            extra_last += b.busy_cycles
    weights = [cyc[nm] for nm in names]
    weights[-1] += extra_last
    busy = sum(1 for w in weights if w > 0)
    if n_stages > busy:
        raise ValueError(
            f"mesh has {n_stages} pipeline rows but {graph.name!r} has only "
            f"{busy} layers with compute to place on them"
        )
    stages = [list(names[a:b]) for a, b in _balanced_cuts(weights, n_stages)]
    for r in range(len(stages) - 1):
        while len(stages[r]) > 1 and cyc[stages[r][-1]] == 0:
            stages[r + 1].insert(0, stages[r].pop())
    stage_cycles = [
        sum(cyc[nm] for nm in st) + (extra_last if r == len(stages) - 1 else 0)
        for r, st in enumerate(stages)
    ]
    return stages, stage_cycles


def _split_program_2d(
    program: NtxProgram,
    graph: NetworkGraph,
    row_owners: list[tuple[int, ...]],
) -> tuple[list[CommandBlock], list[int], dict]:
    """Partition the unsharded step over a 2D (pipeline x tensor/data) mesh.

    Row ``r`` of ``row_owners`` lists the surviving cube ids of pipeline
    stage ``r`` (elastic re-sharding passes shrunken rows). Within a row
    the split is Megatron-style tensor/data hybrid:

      * layers with a tensor rule (:func:`repro.parallel.sharding
        .cnn_param_spec` — conv/matmul/bias) split their *output-channel*
        replication level (``reps[-2]``, present in every conv lowering)
        across the row's columns, followed by an in-row ``tpgather:``
        identity-copy round that re-replicates the produced tensor (the
        Megatron allgather; its bytes ride on the blocks);
      * layers without a rule (pool/relu/loss) and template-only blocks
        split along the batch / outermost template loop as in 1D —
        their outputs are gathered the same way so "replicated within the
        row after the producing step" is an invariant every consumer can
        rely on;
      * gradient reductions and the ZeRO update split by output chunk
        across the row (reduce-scatter; chunk c -> column c), with the
        weight allgather scoped to the row — stage ``r``'s parameters
        never leave their row. Reduce-scatter *inputs* (the per-image
        ``.dwb`` partials, the dW activation operands) skip the gather:
        that traffic is priced by the per-row weight-update exchange
        (eqs. 14-15), exactly like the 1D splitter's deviation note.

    Stage boundary tensors (the last layer's activation going down, its
    gradient coming back up) get explicit ``send:``/``recv:`` chunk pairs
    emitted the moment their producing step ends, so the vertical-link
    traffic is visible to :class:`repro.runtime.mesh.MeshInterconnect`.
    All communication blocks are identity copies: ``run_reference`` of the
    combined stream stays bit-identical to the unsharded step.
    """
    from repro.parallel.sharding import cnn_param_spec

    rows = len(row_owners)
    stages, stage_cycles = _pipeline_stages(graph, program, rows)
    stage_of = {nm: r for r, st in enumerate(stages) for nm in st}
    stage_of["loss"] = rows - 1
    node_of = {nd.name: nd for nd in graph.nodes}
    tensor_nodes = {
        nd.name
        for nd in graph.nodes
        if nd.param is not None
        and (spec := cnn_param_spec(nd.spec)) is not None
        and any(ax is not None for ax in spec)
    }
    params = set(graph.param_shapes())
    grad_regions = {f"d_{p}" for p in params}
    new_regions = {f"{p}_new" for p in params} | {f"v_{p}_new" for p in params}
    param_of_new = {f"{p}_new": p for p in params}
    param_rows = {
        nd.param: stage_of[nd.name] for nd in graph.nodes if nd.param is not None
    }
    stage_param_bytes = [0] * rows
    for p, shape in graph.param_shapes().items():
        stage_param_bytes[param_rows[p]] += math.prod(shape) * ELEM_BYTES

    written: set[str] = set()
    reduce_inputs: set[str] = set()
    for b in program.blocks:
        written.update(b.writes)
        if any(w in grad_regions for w in b.writes):
            reduce_inputs.update(b.reads)

    def _resolve(name: str) -> str | None:
        """Region actually written under ``name``'s storage (alias chase:
        flatten/bias edges share the producer's base)."""
        if name not in program.regions:
            return None
        if name in written:
            return name
        reg = program.regions[name]
        for n2, r2 in program.regions.items():
            if n2 != name and r2.base == reg.base and r2.size == reg.size and n2 in written:
                return n2
        return None

    # boundary tensors: stage r's last activation flows down to r+1, its
    # gradient flows back up. watch[written_name] = (src_row, dst_row, edge)
    watch: dict[str, tuple[int, int, str]] = {}
    boundaries: list[str] = []
    for r in range(rows - 1):
        edge = node_of[stages[r][-1]].out_edge
        boundaries.append(edge)
        fwd = _resolve(edge)
        if fwd is not None:
            watch[fwd] = (r, r + 1, edge)
        bwd = _resolve(f"d_{edge}")
        if bwd is not None:
            watch[bwd] = (r + 1, r, f"d_{edge}")

    blocks: list[CommandBlock] = []
    hmc_of: list[int] = []
    xfers: list[dict] = []

    def emit(piece: CommandBlock, hmc: int) -> None:
        blocks.append(piece)
        hmc_of.append(hmc)

    def emit_split(
        pieces: list[CommandBlock],
        owners: tuple[int, ...],
        retag: str | None = None,
    ) -> bool:
        """Returns True when the block actually fanned out over the row."""
        if len(pieces) == 1:
            b = pieces[0]
            tiny = b.template.total_iterations <= _TINY_ITERS and b.n_commands == 1
            emit(b, ALL_HMCS if tiny else owners[0])
            return False
        for i, b in enumerate(pieces):
            if retag:
                b = replace(b, tag=f"{retag}:{b.tag}[{i}]")
            emit(b, owners[i % len(owners)])
        return True

    def gather_row(region_name: str, owners: tuple[int, ...]) -> None:
        reg = program.regions[region_name]
        parts = len(owners)
        start = 0
        for c, sz in enumerate(_chunk_sizes(reg.size, parts)):
            emit(
                _bcast_block(reg, start, sz, owners[c], parts, tag_prefix="tpgather"),
                owners[c],
            )
            start += sz

    def flush(name: str) -> None:
        src, dst, edge = watch.pop(name)
        reg = program.regions[name]
        for side, kind in ((src, "send"), (dst, "recv")):
            start = 0
            for c, sz in enumerate(_chunk_sizes(reg.size, len(row_owners[side]))):
                emit(_xfer_block(reg, start, sz, kind, c), row_owners[side][c])
                start += sz
        xfers.append({
            "edge": edge,
            "region": name,
            "bytes": reg.size * ELEM_BYTES,
            "src": src,
            "dst": dst,
        })

    cur_stage = 0
    cur_key: tuple[str, ...] | None = None
    pending: list[str] = []

    for block in program.blocks:
        parts_tag = block.tag.split(":")
        head = parts_tag[0]
        key = tuple(parts_tag[:2])
        if key != cur_key:
            cur_key = key
            for name in pending:
                flush(name)
            pending = []
        if head in stage_of:
            cur_stage = stage_of[head]
        owners = row_owners[cur_stage]
        parts = len(owners)

        spillage = head in ("spill", "fill")
        is_reduce = not spillage and any(w in grad_regions for w in block.writes)
        is_update = not spillage and any(w in new_regions for w in block.writes)
        if is_reduce:
            pieces = (
                split_block_reps(block, parts)
                if block.reps
                else split_block_template(block, parts)
            )
            emit_split(pieces, owners, retag="allreduce:reduce")
        elif is_update:
            pieces = (
                split_block_reps(block, parts)
                if block.reps
                else split_block_template(block, parts)
            )
            emit_split(pieces, owners, retag="allreduce:update")
            wn = next((w for w in block.writes if w in param_of_new), None)
            if wn is not None and parts > 1:
                reg = program.regions[wn]
                start = 0
                for c, sz in enumerate(_chunk_sizes(reg.size, parts)):
                    emit(_bcast_block(reg, start, sz, owners[c], parts), owners[c])
                    start += sz
        else:
            if (
                head in tensor_nodes
                and len(block.reps) >= 2
                and not block.is_staging
            ):
                # output-channel split: reps[-2] is the channel replication
                # level in every conv lowering (batch is always outermost)
                pieces = split_block_reps(block, parts, level=len(block.reps) - 2)
            elif block.reps:
                pieces = split_block_reps(block, parts)
            else:
                pieces = split_block_template(block, parts)
            fanned = emit_split(pieces, owners)
            if (
                fanned
                and not block.is_staging
                and block.writes
                and block.writes[0] in program.regions
                and block.writes[0] not in reduce_inputs
            ):
                gather_row(block.writes[0], owners)

        for w in block.writes:
            if w in watch and w not in pending:
                pending.append(w)

    for name in list(pending):
        flush(name)

    pmeta = {
        "n_stages": rows,
        "stages": [list(st) for st in stages],
        "stage_cycles": [int(c) for c in stage_cycles],
        "stage_param_bytes": [int(b) for b in stage_param_bytes],
        "param_rows": param_rows,
        "boundaries": boundaries,
        "xfers": xfers,
    }
    return blocks, hmc_of, pmeta


def reshard_training_step(
    sharded: ShardedTrainStep, failed: int | tuple[int, ...] | list[int]
) -> ShardedTrainStep:
    """Elastic re-shard after cube loss: same step, surviving cubes only.

    Re-partitions the *unsharded* base program onto the cubes that are
    still alive — batch shards, reduce-scatter chunks, ZeRO update chunks
    and the allgather epilogue are all re-chunked for ``n_alive`` owners —
    so ``run_reference(resharded.program)`` stays bit-identical to the
    unsharded step (the command stream is re-grouped, never re-ordered or
    re-rounded). An uneven batch is allowed on the degraded mesh: the
    remainder spreads over the first survivors (:func:`_chunk_sizes`),
    matching how ``run_pallas`` falls back to the single-device walk when
    the shrunken jax mesh can't take an uneven split.

    ``failed`` names physical cube ids; cubes already dead in ``sharded``
    stay dead (failures accumulate across successive re-shards).

    2D programs re-shard *within rows*: losing a cube inside a tensor
    group re-chunks that pipeline stage's tensor/data split (and its
    row-scoped reduce-scatter/update/allgather) over the row's survivors,
    leaving the other stages untouched. A row that loses every cube takes
    its pipeline stage with it — that raises, because no re-chunking can
    recover a stage with zero compute left (the supervisor falls back to
    checkpoint restore instead).
    """
    if isinstance(failed, int):
        failed = (failed,)
    dead = set(sharded.failed_hmcs) | {int(h) for h in failed}
    bad = dead - set(range(sharded.n_hmcs))
    if bad:
        raise ValueError(f"failed cubes {sorted(bad)} outside mesh {sharded.mesh_shape}")
    alive = tuple(h for h in range(sharded.n_hmcs) if h not in dead)
    if not alive:
        raise ValueError(f"no surviving HMCs in mesh {sharded.mesh_shape}")

    program = sharded.base_program
    rows, cols = sharded.mesh_shape
    if sharded.shard == "2d":
        row_owners = [
            tuple(h for h in range(r * cols, (r + 1) * cols) if h in set(alive))
            for r in range(rows)
        ]
        dead_rows = [r for r, ro in enumerate(row_owners) if not ro]
        if dead_rows:
            raise ValueError(
                f"pipeline stage row(s) {dead_rows} lost every cube in mesh "
                f"{rows}x{cols}; a 2d program needs at least one survivor "
                "per row (restore from checkpoint instead)"
            )
        blocks, hmc_of, pmeta = _split_program_2d(program, sharded.graph, row_owners)
        pmeta["n_micro"] = _n_microbatches(sharded.graph.batch, rows)
        mesh_meta = {
            "shape": (rows, cols),
            "n_hmcs": rows * cols,
            "alive": list(alive),
            "failed": sorted(dead),
            "shard_batch": -(-sharded.graph.batch // len(alive)),
            "shard": "2d",
            "row_owners": [list(ro) for ro in row_owners],
            "pipeline": pmeta,
        }
    else:
        blocks, hmc_of = _split_program_onto(program, sharded.graph, alive)
        mesh_meta = {
            "shape": (rows, cols),
            "n_hmcs": rows * cols,
            "alive": list(alive),
            "failed": sorted(dead),
            "shard_batch": -(-sharded.graph.batch // len(alive)),
        }
    combined = NtxProgram(
        name=f"{program.name}:mesh{rows}x{cols}:alive{len(alive)}",
        blocks=blocks,
        regions=program.regions,
        design=program.design,
        meta={**program.meta, "mesh": mesh_meta},
    )
    out = ShardedTrainStep(
        graph=sharded.graph,
        mesh_shape=(rows, cols),
        program=combined,
        base_program=program,
        hmc_of_block=hmc_of,
        alive=alive,
    )
    from repro.obs import counters as obs

    reg = obs.get_active()
    if reg is not None:
        with reg.scope("reshard"):
            reg.inc("programs", 1)
            reg.inc("failed_hmcs", len(dead))
            reg.inc("alive_hmcs", len(alive))
            reg.inc("epilogue_blocks", len(out.epilogue_blocks()))
    return out

"""Mesh-of-HMCs data parallelism: shard a train-step program across cubes.

The paper's §4.9 scales training past one HMC by replicating the cube and
splitting the batch: every cube runs the same step on its shard of the
images, then the weight update is exchanged over the serial links (eqs.
14-21). :func:`shard_training_step` realizes that at the command level, on
top of the PR-4 graph compiler: it takes ONE whole-train-step
:class:`~repro.lower.ir.NtxProgram` and splits it into per-HMC shard
programs plus an explicit gradient-allreduce epilogue, emitted as ordinary
DMA/MAC :class:`~repro.lower.ir.CommandBlock`s.

Bit-identity is the design invariant, and it holds *by construction* rather
than by tolerance:

  * **Batch-parallel blocks** (forward, dX, the per-image conv-dW replicas,
    the loss-gradient stream) are split along the batch: either the
    outermost driver replication level the graph compiler appended
    (:func:`split_block_reps`) or the outermost template loop
    (:func:`~repro.runtime.scheduler.partition_command`). Concatenating the
    shard pieces in shard order reproduces the original command stream
    exactly — same commands, same order, same accumulator roundings.
  * **Cross-batch gradient reductions** (the conv batch-reduce MAC, the
    matmul dW, the bias db) become the *reduce-scatter* phase: each is
    split along its **output** dims into one chunk per HMC, so every chunk
    keeps its full f64 accumulation over all B contributions in the
    unsharded image order — one rounding per output element, exactly like
    the unsharded command. Chunk c is owned by HMC c and reads the other
    shards' per-image contributions across the mesh links.
  * **The SGD update** splits the same way: HMC c updates the parameter
    chunk it just reduced (the ZeRO-style sharded update of the paper's
    systolic weight exchange), and an **allgather** epilogue of identity
    ``copy`` blocks broadcasts every updated chunk back to the replicas —
    semantically a no-op in the flat reference memory (read AGU == write
    AGU), but carrying the link traffic the timing model charges.

One deliberate deviation from the textbook gradient ring: the matmul-dW
chunks read the batch-sharded *activations* across links (an activation
gather) instead of pre-reduced gradient partials, because a per-shard
partial sum would insert an extra fp32 rounding and break bit-identity.
The timing model charges the §4.9 weight-update traffic (eqs. 14-15)
either way; ``docs/architecture.md`` discusses the trade.

The combined program (:attr:`ShardedTrainStep.program`) is consumed
unchanged by ``run_reference``/``run_timing``; ``run_pallas`` routes it
through a ``shard_map`` over a jax device mesh (see
:mod:`repro.lower.executors`), and :mod:`repro.runtime.mesh` times the
per-HMC shard programs plus the inter-HMC link schedule.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, replace

from repro.core.ntx import Agu, NtxCommand
from repro.lower.graph import NetworkGraph, lower_training_step
from repro.lower.ir import (
    ELEM_BYTES,
    CommandBlock,
    DesignPoint,
    NTX_DESIGN,
    NtxProgram,
    TensorRegion,
)

#: Blocks whose template body is at most this many iterations are treated as
#: driver-side staging (constant memsets, the 1.0 scalar) and replicated to
#: every HMC instead of being assigned to one.
_TINY_ITERS = 64

#: hmc assignment sentinel: the block runs on every HMC (reference executes
#: it once; the timing model charges it to each cube).
ALL_HMCS = -1


def parse_mesh(mesh: str | tuple[int, int]) -> tuple[int, int]:
    """``"2x4"`` or ``(2, 4)`` -> (rows, cols)."""
    if isinstance(mesh, str):
        try:
            r, c = (int(p) for p in mesh.lower().split("x"))
        except ValueError as e:
            raise ValueError(f"mesh spec {mesh!r} is not 'RxC'") from e
        return r, c
    r, c = mesh
    return int(r), int(c)


def _chunk_sizes(n: int, parts: int) -> list[int]:
    """The contiguous chunk sizes :func:`partition_command` uses (remainder
    spread over the first chunks) — shared so reduce/update/allgather agree
    on ownership boundaries."""
    parts = min(parts, n)
    base, rem = divmod(n, parts)
    return [base + (1 if p < rem else 0) for p in range(parts)]


def _rebased(agu: Agu | None, delta: int) -> Agu | None:
    if agu is None or delta == 0:
        return agu
    return Agu(agu.base + delta, agu.strides)


def split_block_reps(block: CommandBlock, parts: int) -> list[CommandBlock]:
    """Split a block's outermost driver replication level into ``parts``
    contiguous runs (the batch loop the graph compiler appended).

    Executing the pieces in order issues exactly the original command
    stream: the outermost rep is the slowest odometer digit, so piece ``p``
    covers a contiguous run of replica indices with the template rebased by
    ``start * step`` per AGU — the same arithmetic
    :meth:`CommandBlock.commands` performs.
    """
    n_out = block.reps[-1]
    sizes = _chunk_sizes(n_out, parts)
    out = []
    start = 0
    t = block.template
    for sz in sizes:
        d0 = start * block.rd0_step[-1]
        d1 = start * block.rd1_step[-1]
        dw = start * block.wr_step[-1]
        out.append(
            replace(
                block,
                template=NtxCommand(
                    loops=t.loops,
                    opcode=t.opcode,
                    agu_rd0=_rebased(t.agu_rd0, d0),
                    agu_rd1=_rebased(t.agu_rd1, d1),
                    agu_wr=_rebased(t.agu_wr, dw),
                    init_level=t.init_level,
                    store_level=t.store_level,
                    init_value=t.init_value,
                ),
                reps=block.reps[:-1] + (sz,),
            )
        )
        start += sz
    return out


def split_block_template(block: CommandBlock, parts: int) -> list[CommandBlock]:
    """Split a block along its template's outermost splittable loop —
    :func:`~repro.runtime.scheduler.partition_command` with the block's
    driver loops and block-level DMA totals carried over (traffic
    preserved, like ``partition_program``). Blocks whose template refuses
    to split (unit loops, accumulator spans) come back whole.

    Shared by the batch sharding here and the coarse-block §3.1 refinement
    of :mod:`repro.runtime.mesh` — one implementation of the
    piece/DMA-division semantics.
    """
    from repro.runtime.scheduler import partition_command

    try:
        pieces = partition_command(block.template, parts)
    except ValueError:
        pieces = [block.template]
    if len(pieces) == 1:
        return [block]
    return [
        replace(
            block,
            template=p,
            dma_bytes_in=block.dma_bytes_in / len(pieces),
            dma_bytes_out=block.dma_bytes_out / len(pieces),
        )
        for p in pieces
    ]


def _bcast_block(
    region: TensorRegion, start: int, size: int, owner: int, n_hmcs: int,
    *, tag_prefix: str = "allgather",
) -> CommandBlock:
    """One allgather step: HMC ``owner`` broadcasts its updated chunk.

    An identity copy (read AGU == write AGU) over the chunk — semantically
    a no-op in the flat reference memory, but it occupies the engine for
    one cycle per word and carries ``(n_hmcs - 1)`` chunk transfers of link
    traffic, which :mod:`repro.runtime.mesh` schedules over the serial
    links.
    """
    agu = Agu(region.base + start, (1, 0, 0, 0, 0))
    return CommandBlock(
        template=NtxCommand(
            loops=(size, 1, 1, 1, 1),
            opcode="copy",
            agu_rd0=agu,
            agu_wr=agu,
            init_level=0,
            store_level=0,
        ),
        tag=f"{tag_prefix}:{region.name}[{owner}]",
        reads=(region.name,),
        writes=(region.name,),
        dma_bytes_out=float(size * ELEM_BYTES * max(n_hmcs - 1, 0)),
    )


@dataclass
class ShardedTrainStep:
    """One train step split across a mesh of HMCs.

    ``program`` is the combined command stream (bit-identical to the
    unsharded step under ``run_reference``); ``hmc_of_block[i]`` says which
    cube issues ``program.blocks[i]`` (:data:`ALL_HMCS` = every cube).
    ``alive`` is the ordered tuple of surviving cube ids after an elastic
    re-shard (:func:`reshard_training_step`); ``None`` means every cube in
    the physical mesh is healthy.
    """

    graph: NetworkGraph
    mesh_shape: tuple[int, int]
    program: NtxProgram
    base_program: NtxProgram
    hmc_of_block: list[int]
    alive: tuple[int, ...] | None = None

    @property
    def n_hmcs(self) -> int:
        """Cubes in the *physical* mesh (dead ones included)."""
        return self.mesh_shape[0] * self.mesh_shape[1]

    @property
    def alive_hmcs(self) -> tuple[int, ...]:
        return self.alive if self.alive is not None else tuple(range(self.n_hmcs))

    @property
    def n_alive(self) -> int:
        return len(self.alive_hmcs)

    @property
    def failed_hmcs(self) -> tuple[int, ...]:
        return tuple(sorted(set(range(self.n_hmcs)) - set(self.alive_hmcs)))

    @property
    def shard_batch(self) -> int:
        """Images per surviving cube (the largest shard when uneven)."""
        return -(-self.graph.batch // self.n_alive)

    @property
    def allreduce_bytes(self) -> float:
        """Bytes of parameters exchanged per update pass (eq. 14's W)."""
        return float(sum(
            math.prod(shape) * ELEM_BYTES
            for shape in self.graph.param_shapes().values()
        ))

    def shard_program(self, hmc: int) -> NtxProgram:
        """The command stream cube ``hmc`` issues (plus replicated staging).

        All shards are structurally symmetric — timing one of them times
        them all.
        """
        if not 0 <= hmc < self.n_hmcs:
            raise ValueError(f"hmc {hmc} outside mesh {self.mesh_shape}")
        if hmc not in self.alive_hmcs:
            raise ValueError(
                f"hmc {hmc} has failed; survivors are {self.alive_hmcs}"
            )
        blocks = [
            b for b, h in zip(self.program.blocks, self.hmc_of_block)
            if h == hmc or h == ALL_HMCS
        ]
        return NtxProgram(
            name=f"{self.program.name}:hmc{hmc}",
            blocks=blocks,
            regions=self.program.regions,
            design=self.program.design,
            meta={**self.program.meta, "hmc": hmc},
        )

    def epilogue_blocks(self) -> list[tuple[int, CommandBlock]]:
        """(hmc, block) pairs of the allreduce epilogue, in program order."""
        out = []
        for b, h in zip(self.program.blocks, self.hmc_of_block):
            if b.tag.startswith(("allreduce:", "allgather:")):
                out.append((h, b))
        return out


def shard_training_step(
    graph: NetworkGraph,
    *,
    design: DesignPoint = NTX_DESIGN,
    mesh_shape: str | tuple[int, int] = (2, 2),
    n_clusters: int = 16,
    keep_grads: bool = True,
    program: NtxProgram | None = None,
) -> ShardedTrainStep:
    """Compile ``graph`` and split its train-step program across a mesh.

    ``program`` optionally supplies the already-compiled unsharded step
    (must come from ``lower_training_step(graph, ...)`` with the same
    design). The batch must divide evenly over the mesh.

    Block classification:

      * blocks writing a ``d_<param>`` region are the gradient reductions —
        split by output chunk (**reduce-scatter**, chunk c -> HMC c) and
        re-tagged ``allreduce:reduce:...``;
      * blocks writing ``<param>_new`` / ``v_<param>_new`` are the update —
        split by the same chunks (owner updates what it reduced), with the
        weight allgather appended after the parameter's last update piece;
      * everything else splits along the batch (outermost rep level, else
        the outermost template loop); unsplittable staging (constant
        memsets) is replicated to every HMC.
    """
    rows, cols = parse_mesh(mesh_shape)
    n = rows * cols
    if n < 1:
        raise ValueError(f"degenerate mesh {rows}x{cols}")
    if graph.batch % n:
        raise ValueError(
            f"batch {graph.batch} does not divide over a {rows}x{cols} mesh"
        )
    if program is None:
        program = lower_training_step(
            graph, design=design, n_clusters=n_clusters, keep_grads=keep_grads
        )

    blocks, hmc_of = _split_program_onto(program, graph, tuple(range(n)))

    combined = NtxProgram(
        name=f"{program.name}:mesh{rows}x{cols}",
        blocks=blocks,
        regions=program.regions,
        design=program.design,
        meta={
            **program.meta,
            "mesh": {
                "shape": (rows, cols),
                "n_hmcs": n,
                "shard_batch": graph.batch // n,
            },
        },
    )
    sharded = ShardedTrainStep(
        graph=graph,
        mesh_shape=(rows, cols),
        program=combined,
        base_program=program,
        hmc_of_block=hmc_of,
    )
    from repro.obs import counters as obs

    reg = obs.get_active()
    if reg is not None:
        with reg.scope("shard"):
            reg.inc("programs", 1)
            reg.inc("hmcs", n)
            reg.inc("epilogue_blocks", len(sharded.epilogue_blocks()))
            reg.inc("allreduce_bytes", sharded.allreduce_bytes)
    return sharded


def _split_program_onto(
    program: NtxProgram, graph: NetworkGraph, owners: tuple[int, ...]
) -> tuple[list[CommandBlock], list[int]]:
    """Partition the unsharded step program over the cubes in ``owners``.

    The shared core of :func:`shard_training_step` (owners = the whole
    mesh) and :func:`reshard_training_step` (owners = the survivors).
    ``len(owners)`` sets the number of batch shards / reduce-scatter chunks;
    the owner *values* are the physical cube ids the pieces land on, so a
    degraded mesh re-partitions the exact same command stream onto fewer
    cubes — concatenation order (and therefore ``run_reference`` output) is
    unchanged by construction.
    """
    parts = len(owners)
    params = set(graph.param_shapes())
    grad_regions = {f"d_{p}" for p in params}
    new_regions = {f"{p}_new" for p in params} | {f"v_{p}_new" for p in params}
    param_of_new = {f"{p}_new": p for p in params}

    blocks: list[CommandBlock] = []
    hmc_of: list[int] = []

    def emit(piece: CommandBlock, hmc: int) -> None:
        blocks.append(piece)
        hmc_of.append(hmc)

    def emit_split(pieces: list[CommandBlock], retag: str | None = None) -> None:
        if len(pieces) == 1:
            b = pieces[0]
            tiny = b.template.total_iterations <= _TINY_ITERS and b.n_commands == 1
            emit(b, ALL_HMCS if tiny else owners[0])
            return
        for i, b in enumerate(pieces):
            if retag:
                b = replace(b, tag=f"{retag}:{b.tag}[{i}]")
            # pieces < parts only when the split dim had fewer iterations
            # than cubes; owners then cover a prefix of the survivors.
            emit(b, owners[i % parts])

    def output_split(b: CommandBlock) -> list[CommandBlock]:
        # Reduction/update blocks keep every reduction dim inside the
        # template (the lowering enforces usable >= n_red), so any driver
        # rep level is a pure output dim: rep-split and template-split are
        # both contiguous output-chunk (reduce-scatter) splits.
        return (
            split_block_reps(b, parts) if b.reps else split_block_template(b, parts)
        )

    for block in program.blocks:
        spillage = block.tag.startswith(("spill:", "fill:"))
        is_reduce = not spillage and any(w in grad_regions for w in block.writes)
        is_update = not spillage and any(w in new_regions for w in block.writes)
        if is_reduce:
            # cross-batch gradient reduction: output-chunk split ==
            # reduce-scatter. (Batched conv per-image dW replica writes
            # target the ``<node>.dwb`` staging region, not ``d_<param>``,
            # and take the batch split below — they are shard-local.)
            emit_split(output_split(block), retag="allreduce:reduce")
            continue
        if is_update:
            emit_split(output_split(block), retag="allreduce:update")
            # after the *parameter* update (not the momentum block), each
            # owner broadcasts its updated chunk to the other replicas
            wn = next((w for w in block.writes if w in param_of_new), None)
            if wn is not None:
                r = program.regions[wn]
                start = 0
                for c, sz in enumerate(_chunk_sizes(r.size, parts)):
                    if parts > 1:
                        emit(_bcast_block(r, start, sz, owners[c], parts), owners[c])
                    start += sz
            continue
        if block.reps:
            emit_split(split_block_reps(block, parts))
        else:
            emit_split(split_block_template(block, parts))

    return blocks, hmc_of


def reshard_training_step(
    sharded: ShardedTrainStep, failed: int | tuple[int, ...] | list[int]
) -> ShardedTrainStep:
    """Elastic re-shard after cube loss: same step, surviving cubes only.

    Re-partitions the *unsharded* base program onto the cubes that are
    still alive — batch shards, reduce-scatter chunks, ZeRO update chunks
    and the allgather epilogue are all re-chunked for ``n_alive`` owners —
    so ``run_reference(resharded.program)`` stays bit-identical to the
    unsharded step (the command stream is re-grouped, never re-ordered or
    re-rounded). An uneven batch is allowed on the degraded mesh: the
    remainder spreads over the first survivors (:func:`_chunk_sizes`),
    matching how ``run_pallas`` falls back to the single-device walk when
    the shrunken jax mesh can't take an uneven split.

    ``failed`` names physical cube ids; cubes already dead in ``sharded``
    stay dead (failures accumulate across successive re-shards).
    """
    if isinstance(failed, int):
        failed = (failed,)
    dead = set(sharded.failed_hmcs) | {int(h) for h in failed}
    bad = dead - set(range(sharded.n_hmcs))
    if bad:
        raise ValueError(f"failed cubes {sorted(bad)} outside mesh {sharded.mesh_shape}")
    alive = tuple(h for h in range(sharded.n_hmcs) if h not in dead)
    if not alive:
        raise ValueError(f"no surviving HMCs in mesh {sharded.mesh_shape}")

    program = sharded.base_program
    rows, cols = sharded.mesh_shape
    blocks, hmc_of = _split_program_onto(program, sharded.graph, alive)
    combined = NtxProgram(
        name=f"{program.name}:mesh{rows}x{cols}:alive{len(alive)}",
        blocks=blocks,
        regions=program.regions,
        design=program.design,
        meta={
            **program.meta,
            "mesh": {
                "shape": (rows, cols),
                "n_hmcs": rows * cols,
                "alive": list(alive),
                "failed": sorted(dead),
                "shard_batch": -(-sharded.graph.batch // len(alive)),
            },
        },
    )
    out = ShardedTrainStep(
        graph=sharded.graph,
        mesh_shape=(rows, cols),
        program=combined,
        base_program=program,
        hmc_of_block=hmc_of,
        alive=alive,
    )
    from repro.obs import counters as obs

    reg = obs.get_active()
    if reg is not None:
        with reg.scope("reshard"):
            reg.inc("programs", 1)
            reg.inc("failed_hmcs", len(dead))
            reg.inc("alive_hmcs", len(alive))
            reg.inc("epilogue_blocks", len(out.epilogue_blocks()))
    return out

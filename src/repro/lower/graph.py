"""Network-graph compiler: one :class:`NtxProgram` per training step.

The paper's headline claim is *training* at scale — a whole step (forward,
gradient propagation, and the SGD weight update) offloaded as one command
stream per HMC, with the update being exactly the streaming MAC workload NTX
is built for. This module is the graph level above :mod:`repro.lower.rules`:

  * :class:`NetworkGraph` — a sequential layer-node IR with explicit tensor
    edges (conv / matmul / relu / maxpool / flatten / bias nodes, a
    softmax-cross-entropy loss node, and an SGD(+momentum) update policy).
  * :func:`lower_training_step` — produce **one** :class:`NtxProgram` for
    fwd → loss grad → interleaved dX/dW → weight update, consumed unchanged
    by all three executors (``run_reference`` / ``run_timing`` /
    ``run_pallas``).
  * TCDM is managed by the graph-level liveness allocator
    (:class:`repro.lower.ir.LivenessAllocator`): activations are freed right
    after the backward pass that consumes them, the program's
    ``peak_tcdm_bytes`` is reported in ``meta`` and guaranteed to fit the
    design point's 64 KiB × clusters budget — regions that do not fit are
    spilled to the DRAM segment with in-band spill/fill DMA blocks.

Per-layer lowering rules are reused by *relocation*: each (node, pass) is
lowered with :func:`repro.lower.lower` at private bases, then every block's
AGUs are rebased into the graph-allocated regions, and per-image passes gain
one extra driver replication level stepping whole image planes — the batch
loop of the paper's Algorithm 1 made explicit. Cross-region constructs that
cannot be relocated (the SGD update's coefficient-pair MAC, the batch
reduction of per-image weight gradients) are emitted directly at final
addresses.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Callable, Iterable

import numpy as np

from repro.core.ntx import Agu, NtxCommand
from repro.lower import rules
from repro.obs import counters as obs_counters
from repro.obs import trace as obs_trace
from repro.lower.ir import (
    ELEM_BYTES,
    LIVE_END,
    CommandBlock,
    DesignPoint,
    LivenessAllocator,
    NTX_DESIGN,
    NtxProgram,
    TensorRegion,
)
from repro.lower.rules import (
    AttentionSpec,
    BiasSpec,
    Conv2dSpec,
    EmbeddingSpec,
    FlattenSpec,
    LayerNormSpec,
    MatmulSpec,
    MaxPool2dSpec,
    PosEmbedSpec,
    ReluSpec,
    ResidualAddSpec,
    SgdUpdateSpec,
    SoftmaxXentSpec,
    lower,
)

# ---------------------------------------------------------------------------
# The graph IR
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class GraphNode:
    """One layer node: a spec plus its explicit tensor edges.

    ``aux_edges`` are extra input edges beyond ``in_edge`` — a residual-add
    node reads its skip connection through one. Any edge consumed by more
    than one node (fan-out in the DAG) gets its gradient accumulated from
    per-consumer partials by the compiler.
    """

    name: str
    spec: Any
    in_edge: str
    out_edge: str
    param: str | None = None  # parameter edge name (conv/matmul: w, bias: b)
    in_shape: tuple[int, ...] = ()  # per-image
    out_shape: tuple[int, ...] = ()
    aux_edges: tuple[str, ...] = ()


def _shape_after(spec, cur: tuple[int, ...]) -> tuple[int, ...]:
    """Per-image output shape of ``spec`` applied to per-image ``cur``."""
    if isinstance(spec, Conv2dSpec):
        if cur != (spec.in_h, spec.in_w, spec.cin):
            raise ValueError(f"conv expects {(spec.in_h, spec.in_w, spec.cin)}, got {cur}")
        return (spec.out_h, spec.out_w, spec.cout)
    if isinstance(spec, MaxPool2dSpec):
        if cur != (spec.in_h, spec.in_w, spec.c):
            raise ValueError(f"maxpool expects {(spec.in_h, spec.in_w, spec.c)}, got {cur}")
        return (spec.out_h, spec.out_w, spec.c)
    if isinstance(spec, ReluSpec):
        if tuple(spec.shape) != cur:
            raise ValueError(f"relu expects {spec.shape}, got {cur}")
        return cur
    if isinstance(spec, FlattenSpec):
        if tuple(spec.in_shape) != cur:
            raise ValueError(f"flatten expects {spec.in_shape}, got {cur}")
        return (spec.size,)
    if isinstance(spec, MatmulSpec):
        # 1-D per-image (CNN head, m == batch) or 2-D per-image token rows
        # (LM projections, m == batch * rows)
        if cur == (spec.k,):
            return (spec.n,)
        if len(cur) == 2 and cur[-1] == spec.k:
            return (cur[0], spec.n)
        raise ValueError(f"matmul expects (.., {spec.k}), got {cur}")
    if isinstance(spec, BiasSpec):
        if cur[-1] != spec.c:
            raise ValueError(f"bias expects {spec.c} channels, got {cur}")
        return cur
    if isinstance(spec, AttentionSpec):
        if cur != (spec.seq, 3 * spec.d):
            raise ValueError(
                f"attention expects {(spec.seq, 3 * spec.d)}, got {cur}"
            )
        return (spec.seq, spec.d)
    if isinstance(spec, LayerNormSpec):
        if not cur or cur[-1] != spec.d:
            raise ValueError(f"layernorm expects last dim {spec.d}, got {cur}")
        return cur
    if isinstance(spec, ResidualAddSpec):
        if math.prod(spec.shape) % math.prod(cur) != 0:
            raise ValueError(f"residual shape {spec.shape} mismatches {cur}")
        return cur
    if isinstance(spec, EmbeddingSpec):
        if not cur or cur[-1] != spec.vocab:
            raise ValueError(
                f"embedding expects one-hot last dim {spec.vocab}, got {cur}"
            )
        return cur[:-1] + (spec.d,)
    if isinstance(spec, PosEmbedSpec):
        if cur != (spec.seq, spec.d):
            raise ValueError(f"posembed expects {(spec.seq, spec.d)}, got {cur}")
        return cur
    raise TypeError(f"no graph rule for {type(spec).__name__}")


def _param_shape(spec) -> tuple[int, ...] | None:
    if isinstance(spec, Conv2dSpec):
        return (spec.kh, spec.kw, spec.cin, spec.cout)
    if isinstance(spec, MatmulSpec):
        return (spec.k, spec.n)
    if isinstance(spec, BiasSpec):
        return (spec.c,)
    if isinstance(spec, LayerNormSpec):
        return (2, spec.d)  # row 0 = gamma, row 1 = beta
    if isinstance(spec, EmbeddingSpec):
        return (spec.vocab, spec.d)
    if isinstance(spec, PosEmbedSpec):
        return (spec.seq, spec.d)
    return None


@dataclass
class NetworkGraph:
    """A sequential training graph: layer nodes + loss + update policy."""

    name: str
    batch: int
    input_shape: tuple[int, ...]  # per-image
    nodes: list[GraphNode]
    loss: SoftmaxXentSpec
    lr: float = 0.05
    momentum: float = 0.0

    input_edge: str = "x"
    label_edge: str = "onehot"

    @classmethod
    def sequential(
        cls,
        name: str,
        batch: int,
        input_shape: tuple[int, ...],
        layers: Iterable[tuple[str, Any]],
        *,
        lr: float = 0.05,
        momentum: float = 0.0,
    ) -> "NetworkGraph":
        """Deprecated alias of :meth:`chain` (the sequential-only builder).

        The graph IR is a DAG now; use :meth:`chain` for linear stacks and
        :meth:`from_model_config` for transformer LMs.
        """
        import warnings

        warnings.warn(
            "NetworkGraph.sequential is deprecated; use NetworkGraph.chain "
            "(linear stacks) or NetworkGraph.from_model_config (LMs)",
            DeprecationWarning,
            stacklevel=2,
        )
        return cls.chain(
            name, batch, input_shape, layers, lr=lr, momentum=momentum
        )

    @classmethod
    def chain(
        cls,
        name: str,
        batch: int,
        input_shape: tuple[int, ...],
        layers: Iterable[tuple[str, Any]],
        *,
        lr: float = 0.05,
        momentum: float = 0.0,
    ) -> "NetworkGraph":
        """Chain ``layers`` ([(node_name, spec)]) over per-image
        ``input_shape``. Spec sugar: the strings ``"relu"``, ``"flatten"``
        and ``"bias"`` expand to specs matching the current shape; matmul
        specs must use ``m == batch``.
        """
        cur = tuple(input_shape)
        nodes: list[GraphNode] = []
        edge = cls.input_edge
        for lname, spec in layers:
            if spec == "relu":
                spec = ReluSpec(cur)
            elif spec == "flatten":
                spec = FlattenSpec(cur)
            elif spec == "bias":
                spec = BiasSpec(rows=batch * math.prod(cur[:-1]), c=cur[-1])
            if isinstance(spec, MatmulSpec) and spec.m != batch:
                raise ValueError(f"matmul node {lname!r}: m={spec.m} != batch={batch}")
            if isinstance(spec, BiasSpec) and spec.rows != batch * math.prod(cur[:-1]):
                raise ValueError(
                    f"bias node {lname!r}: rows={spec.rows} != "
                    f"{batch * math.prod(cur[:-1])}"
                )
            nxt = _shape_after(spec, cur)
            param = None
            if _param_shape(spec) is not None:
                prefix = "b" if isinstance(spec, BiasSpec) else "w"
                param = f"{prefix}_{lname}"
            nodes.append(
                GraphNode(
                    name=lname, spec=spec, in_edge=edge, out_edge=f"a_{lname}",
                    param=param, in_shape=cur, out_shape=nxt,
                )
            )
            edge = f"a_{lname}"
            cur = nxt
        if len(cur) != 1:
            raise ValueError(f"loss expects 1-D logits per image, got {cur}")
        return cls(
            name=name, batch=batch, input_shape=tuple(input_shape),
            nodes=nodes, loss=SoftmaxXentSpec(batch=batch, classes=cur[0]),
            lr=lr, momentum=momentum,
        )

    @classmethod
    def from_model_config(
        cls,
        cfg,
        *,
        batch: int = 2,
        seq: int = 8,
        lr: float = 0.05,
        momentum: float = 0.0,
    ) -> "NetworkGraph":
        """Build a decoder-only transformer training DAG from a
        :class:`repro.models.config.ModelConfig`.

        Per token position the input is a one-hot row over the vocabulary
        (the near-memory controller streams token indices as one-hot MAC
        operands), so the input edge is ``(seq, vocab)`` per sequence and
        the label edge is the next-token one-hot at ``(batch*seq, vocab)``.

        The lowered family is the dense pre-LN block NTX speaks: embedding
        + learned positions, then per layer LN → qkv matmul → causal MHA →
        out-proj → residual, LN → FFN (relu) → residual, with a final LN
        and vocab head. Config fields outside that family (RMS-vs-layer
        norm, swiglu, GQA ``n_kv_heads``, MoE/SSM mixers) map onto it —
        use :func:`repro.configs.reduce_config` plus ``cfg.with_(...)``
        overrides for test-sized graphs.
        """
        V, d, F = cfg.vocab_size, cfg.d_model, cfg.d_ff
        H = cfg.n_heads
        Dh = cfg.head_dim or d // H
        B, S = batch, seq
        rows = B * S
        eps = cfg.norm_eps
        nodes: list[GraphNode] = []
        edge, cur = cls.input_edge, (S, V)

        def add(name, spec, *, aux: tuple[str, ...] = ()):
            nonlocal edge, cur
            nxt = _shape_after(spec, cur)
            param = None
            if _param_shape(spec) is not None:
                param = f"w_{name}"
            nodes.append(
                GraphNode(
                    name=name, spec=spec, in_edge=edge, out_edge=f"a_{name}",
                    param=param, in_shape=cur, out_shape=nxt, aux_edges=aux,
                )
            )
            edge, cur = f"a_{name}", nxt

        add("emb", EmbeddingSpec(rows=rows, vocab=V, d=d))
        add("pos", PosEmbedSpec(batch=B, seq=S, d=d))
        for i in range(cfg.n_layers):
            skip = edge
            add(f"ln1_{i}", LayerNormSpec(rows, d, eps))
            add(f"qkv_{i}", MatmulSpec(rows, 3 * H * Dh, d))
            add(f"attn_{i}", AttentionSpec(S, H, Dh))
            add(f"proj_{i}", MatmulSpec(rows, d, H * Dh))
            add(f"res1_{i}", ResidualAddSpec((rows, d)), aux=(skip,))
            skip = edge
            add(f"ln2_{i}", LayerNormSpec(rows, d, eps))
            add(f"fc1_{i}", MatmulSpec(rows, F, d))
            add(f"relu_{i}", ReluSpec((S, F)))
            add(f"fc2_{i}", MatmulSpec(rows, d, F))
            add(f"res2_{i}", ResidualAddSpec((rows, d)), aux=(skip,))
        add("lnf", LayerNormSpec(rows, d, eps))
        add("head", MatmulSpec(rows, V, d))
        return cls(
            name=f"lm_{cfg.name}", batch=B, input_shape=(S, V), nodes=nodes,
            loss=SoftmaxXentSpec(batch=rows, classes=V),
            lr=lr, momentum=momentum,
        )

    # -- conveniences -------------------------------------------------------

    @property
    def logits_edge(self) -> str:
        return self.nodes[-1].out_edge

    def param_nodes(self) -> list[GraphNode]:
        return [n for n in self.nodes if n.param is not None]

    def param_shapes(self) -> dict[str, tuple[int, ...]]:
        return {n.param: _param_shape(n.spec) for n in self.param_nodes()}

    def init_params(self, seed: int = 0) -> dict[str, np.ndarray]:
        """Parameter (and momentum-state) arrays keyed by region name."""
        rng = np.random.RandomState(seed)
        out: dict[str, np.ndarray] = {}
        for node in self.param_nodes():
            pname = node.param
            shape = _param_shape(node.spec)
            if isinstance(node.spec, LayerNormSpec):
                w = np.zeros(shape, np.float32)
                w[0] = 1.0  # gamma row; beta row stays zero
                out[pname] = w
            elif pname.startswith("b_"):
                out[pname] = np.zeros(shape, np.float32)
            else:
                out[pname] = (rng.randn(*shape) * 0.1).astype(np.float32)
            if self.momentum:
                out[f"v_{pname}"] = np.zeros(shape, np.float32)
        return out


# ---------------------------------------------------------------------------
# Relocation: per-layer programs rebased into graph regions (+ batch loop)
# ---------------------------------------------------------------------------


def _relocate_blocks(
    layer_prog: NtxProgram,
    rename: dict[str, str],
    regions: dict[str, TensorRegion],
    static_names: set[str],
    batch: int,
    tag_prefix: str,
    *,
    skip_staging_of: tuple[str, ...] = (),
) -> list[CommandBlock]:
    """Rebase every block of ``layer_prog`` into graph-allocated regions.

    ``rename`` maps the layer program's region names to graph region names;
    ``static_names`` are graph regions that do NOT step with the batch
    (parameters, staged constants). With ``batch > 1`` each block gains one
    outermost driver replication level whose per-AGU base step is the
    per-image footprint of the region that AGU streams.
    """
    old_regions = layer_prog.regions
    out: list[CommandBlock] = []
    for b in layer_prog.blocks:
        if b.is_staging and any(w in skip_staging_of for w in b.writes):
            continue

        def target(old_name: str | None):
            if old_name is None:
                return None, 0
            gname = rename[old_name]
            new_r = regions[gname]
            old_r = old_regions[old_name]
            step = 0 if gname in static_names else old_r.size
            return new_r.base - old_r.base, step

        rd0_name = b.reads[0] if b.reads else b.writes[0]
        # a single-region reads tuple with both read AGUs live means rd1
        # streams the same region as rd0 (x*x squares, q·k within one qkv)
        rd1_name = b.reads[1] if len(b.reads) > 1 else (
            rd0_name if b.template.agu_rd1 is not None else None
        )
        wr_name = b.writes[0] if b.writes else None
        d0, s0 = target(rd0_name)
        d1, s1 = target(rd1_name if b.template.agu_rd1 is not None else None)
        dw_, sw = target(wr_name if b.template.agu_wr is not None else None)

        def rebase(agu: Agu | None, delta: int) -> Agu | None:
            if agu is None:
                return None
            return Agu(agu.base + delta, agu.strides)

        t = b.template
        template = NtxCommand(
            loops=t.loops,
            opcode=t.opcode,
            agu_rd0=rebase(t.agu_rd0, d0),
            agu_rd1=rebase(t.agu_rd1, d1),
            agu_wr=rebase(t.agu_wr, dw_),
            init_level=t.init_level,
            store_level=t.store_level,
            init_value=t.init_value,
        )
        reps, r0, r1, rw = b.reps, b.rd0_step, b.rd1_step, b.wr_step
        if batch > 1:
            reps = reps + (batch,)
            r0 = r0 + (s0,)
            r1 = r1 + (s1,)
            rw = rw + (sw,)
        out.append(
            CommandBlock(
                template=template,
                reps=reps,
                rd0_step=r0,
                rd1_step=r1,
                wr_step=rw,
                tag=f"{tag_prefix}:{b.tag}",
                reads=tuple(rename[n] for n in b.reads),
                writes=tuple(rename[n] for n in b.writes),
                dma_bytes_in=b.dma_bytes_in,
                dma_bytes_out=b.dma_bytes_out,
                tile=b.tile,
            )
        )
    return out


def _batch_reduce_block(
    src: TensorRegion,
    one: TensorRegion,
    dst: TensorRegion,
    batch: int,
    design: DesignPoint,
    tag: str,
) -> CommandBlock:
    """dst[i] = sum_b src[b, i] — reduce per-image weight-grad replicas."""
    n = dst.size
    return rules._nest_block(
        (batch, n), 1,
        (src.base, (n, 1)),
        (one.base, (0, 0)),
        (dst.base, (0, 1)),
        design, opcode="mac", tag=tag,
        reads=(src, one), writes=(dst,),
    )


def _spill_block(r: TensorRegion, direction: str) -> CommandBlock:
    """Model one spill/fill DMA transfer as an in-band identity copy.

    Semantically a no-op (read AGU == write AGU), but it occupies the
    engine for one cycle per word and carries the region's bytes as DMA
    traffic — what spilling an over-budget region to DRAM costs.
    """
    agu = Agu(r.base, (1, 0, 0, 0, 0))
    return CommandBlock(
        template=NtxCommand(
            loops=(r.size, 1, 1, 1, 1),
            opcode="copy",
            agu_rd0=agu,
            agu_wr=agu,
            init_level=0,
            store_level=0,
        ),
        tag=f"{direction}:{r.name}",
        reads=(r.name,),
        writes=(r.name,),
        dma_bytes_in=float(r.bytes) if direction == "fill" else 0.0,
        dma_bytes_out=float(r.bytes) if direction == "spill" else 0.0,
    )


# ---------------------------------------------------------------------------
# The compiler
# ---------------------------------------------------------------------------


@dataclass
class _Step:
    """One schedule position: its region touches + a block emitter."""

    key: str
    touched: dict[str, tuple[tuple[int, ...], str]] = field(default_factory=dict)
    aliases: list[tuple[str, str, tuple[int, ...], str]] = field(default_factory=list)
    emit: Callable[[dict[str, TensorRegion]], list[CommandBlock]] | None = None

    def touch(self, name: str, shape: tuple[int, ...] = (), kind: str = "scratch"):
        if name not in self.touched:
            self.touched[name] = (tuple(shape), kind)


def _grad(edge: str) -> str:
    return f"d_{edge}"


def edge_consumers(graph: "NetworkGraph") -> dict[str, list[GraphNode]]:
    """Forward-order consumer nodes per edge (``in_edge`` + ``aux_edges``).

    Edges with more than one consumer are the DAG fan-out points: each
    consumer's dX pass writes a private partial ``d_<edge>@<consumer>`` and
    the compiler emits one accumulate step summing the partials into
    ``d_<edge>`` after the last contribution (NTX blocks may not read and
    write the same span, so in-place accumulation is not expressible).
    """
    out: dict[str, list[GraphNode]] = {}
    for n in graph.nodes:
        for e in (n.in_edge, *n.aux_edges):
            out.setdefault(e, []).append(n)
    return out


def _plan_relocated(
    step: _Step,
    layer_prog: NtxProgram,
    rename: dict[str, str],
    kinds: dict[str, str],
    batched: bool,
    batch: int,
    static_names: set[str],
    tag_prefix: str,
    skip_staging_of: tuple[str, ...] = (),
) -> None:
    """Register a relocation emission on ``step``.

    ``rename`` maps layer-program region names to graph names; ``kinds``
    overrides the graph-level kind per graph name (default "scratch").
    """
    for old_name, old_r in layer_prog.regions.items():
        gname = rename[old_name]
        rep = batched and gname not in static_names
        shape = ((batch,) + old_r.shape) if (rep and batch > 1) else old_r.shape
        step.touch(gname, shape, kinds.get(gname, "scratch"))

    def emit(regions: dict[str, TensorRegion]) -> list[CommandBlock]:
        return _relocate_blocks(
            layer_prog, rename, regions,
            static_names if batched else set(rename.values()),
            batch if batched else 1,
            tag_prefix, skip_staging_of=skip_staging_of,
        )

    step.emit = emit


def lower_training_step(
    graph: NetworkGraph,
    *,
    design: DesignPoint = NTX_DESIGN,
    n_clusters: int = 16,
    keep_grads: bool = True,
) -> NtxProgram:
    """Compile ``graph`` into one whole-train-step :class:`NtxProgram`.

    Block order: forward node by node, the loss gradient, then per node in
    reverse — dW, the parameter's SGD update (freeing the gradient early),
    dX — exactly the fwd → loss grad → interleaved dX/dW → update schedule
    of the paper's training loop. TCDM comes from the liveness allocator
    with the design point's ``64 KiB x n_clusters`` budget;
    ``meta["peak_tcdm_bytes"]`` reports the high-water mark (guaranteed
    <= budget — anything else is spilled with in-band spill/fill blocks,
    listed in ``meta["spilled"]``).
    """
    B = graph.batch
    mom = graph.momentum
    steps: list[_Step] = []
    param_edges = set(graph.param_shapes())
    static: set[str] = set(param_edges)
    consumers = edge_consumers(graph)
    producers = {n.out_edge: n for n in graph.nodes}

    def grad_target(node: GraphNode, edge: str) -> str:
        """Where this node's dX contribution to ``edge`` lands."""
        if len(consumers.get(edge, ())) <= 1:
            return _grad(edge)
        return f"{_grad(edge)}@{node.name}"

    def edge_size(edge: str) -> int:
        if edge == graph.input_edge:
            return B * math.prod(graph.input_shape)
        return B * math.prod(producers[edge].out_shape)

    def scratch_rename(prog, rename: dict[str, str], prefix: str):
        for rn in prog.regions:
            if rn not in rename:
                rename[rn] = f"{prefix}.{rn}"
        return rename

    kinds_base: dict[str, str] = {
        graph.input_edge: "input",
        graph.label_edge: "input",
        graph.logits_edge: "output",
    }
    for p in param_edges:
        kinds_base[p] = "param"
        kinds_base[f"{p}_new"] = "output"
        kinds_base[_grad(p)] = "output" if keep_grads else "scratch"
        if mom:
            kinds_base[f"v_{p}"] = "param"
            kinds_base[f"v_{p}_new"] = "output"

    def kinds_for(names: Iterable[str]) -> dict[str, str]:
        return {n: kinds_base.get(n, "scratch") for n in names}

    def relocated_step(key, spec, pass_, rename, *, batched, skip=(), prog=None):
        if prog is None:
            prog = lower(spec, pass_, design=design)
        step = _Step(key=key)
        _plan_relocated(
            step, prog, rename, kinds_for(rename.values()), batched, B,
            static, key, skip_staging_of=skip,
        )
        steps.append(step)
        return step

    # -- forward ------------------------------------------------------------
    for node in graph.nodes:
        s = node.spec
        if isinstance(s, Conv2dSpec):
            relocated_step(
                f"{node.name}:fwd", s, "fwd",
                {"x": node.in_edge, "w": node.param, "y": node.out_edge,
                 "x_pad": f"{node.name}.x_pad"},
                batched=True,
            )
        elif isinstance(s, MatmulSpec):
            relocated_step(
                f"{node.name}:fwd", s, "fwd",
                {"a": node.in_edge, "b": node.param, "c": node.out_edge},
                batched=False,
            )
        elif isinstance(s, BiasSpec):
            relocated_step(
                f"{node.name}:fwd", s, "fwd",
                {"x": node.in_edge, "b": node.param, "y": node.out_edge},
                batched=False,
            )
        elif isinstance(s, ReluSpec):
            whole = ReluSpec((B,) + tuple(s.shape)) if B > 1 else s
            relocated_step(
                f"{node.name}:fwd", whole, "fwd",
                {"x": node.in_edge, "y": node.out_edge},
                batched=False,
            )
        elif isinstance(s, MaxPool2dSpec):
            relocated_step(
                f"{node.name}:fwd", s, "fwd",
                {"x": node.in_edge, "y": node.out_edge},
                batched=True,
            )
        elif isinstance(s, FlattenSpec):
            step = _Step(key=f"{node.name}:fwd")
            step.touch(node.in_edge)  # keeps the storage alive through here
            step.aliases.append(
                (node.out_edge, node.in_edge,
                 (B, s.size) if B > 1 else (s.size,),
                 kinds_base.get(node.out_edge, "scratch"))
            )
            steps.append(step)
        elif isinstance(s, AttentionSpec):
            prog = lower(s, "fwd", design=design)
            rename = scratch_rename(
                prog, {"x": node.in_edge, "y": node.out_edge},
                f"{node.name}.fwd",
            )
            static.add(f"{node.name}.fwd.mask")
            static.add(f"{node.name}.fwd.consts")
            relocated_step(f"{node.name}:fwd", s, "fwd", rename,
                           batched=True, prog=prog)
        elif isinstance(s, LayerNormSpec):
            prog = lower(s, "fwd", design=design)
            rename = scratch_rename(
                prog,
                {"x": node.in_edge, "w": node.param, "y": node.out_edge},
                f"{node.name}.fwd",
            )
            relocated_step(f"{node.name}:fwd", s, "fwd", rename,
                           batched=False, prog=prog)
        elif isinstance(s, ResidualAddSpec):
            relocated_step(
                f"{node.name}:fwd", s, "fwd",
                {"x": node.in_edge, "x2": node.aux_edges[0],
                 "y": node.out_edge},
                batched=False,
            )
        elif isinstance(s, (EmbeddingSpec, PosEmbedSpec)):
            relocated_step(
                f"{node.name}:fwd", s, "fwd",
                {"x": node.in_edge, "w": node.param, "y": node.out_edge},
                batched=False,
            )
        else:
            raise TypeError(f"no graph lowering for {type(s).__name__}")

    # -- loss gradient ------------------------------------------------------
    loss_rename = {"z": graph.logits_edge, "onehot": graph.label_edge,
                   "dz": _grad(graph.logits_edge)}
    for sname in rules.softmax_xent_scratch_shapes(graph.loss):
        loss_rename[sname] = f"loss.{sname}"
    static.add("loss.consts")
    relocated_step("loss:dx", graph.loss, "dx", loss_rename, batched=False)

    # -- backward: dW -> update -> dX, node by node in reverse ---------------
    for node in reversed(graph.nodes):
        s = node.spec
        g_out = _grad(node.out_edge)
        g_in = grad_target(node, node.in_edge)
        is_first = node.in_edge == graph.input_edge

        # dW + the update
        if node.param is not None:
            p = node.param
            dwb = f"{node.name}.dwb"  # per-image replicas (conv only, B > 1)
            if isinstance(s, Conv2dSpec):
                dw_target = dwb if B > 1 else _grad(p)
                step = relocated_step(
                    f"{node.name}:dw", s, "dw",
                    {"x": node.in_edge, "dy": g_out, "dw": dw_target,
                     "x_pad": f"{node.name}.x_pad"},
                    batched=True,
                    skip=("x_pad",) if s.padding else (),
                )
                if B > 1:
                    pshape = _param_shape(s)
                    one = f"{node.name}.one"
                    step.touch(one, (1,), "scratch")
                    step.touch(_grad(p), pshape, kinds_base[_grad(p)])
                    inner_emit = step.emit

                    def emit_dw(regions, _inner=inner_emit, _one=one,
                                _dwb=dwb, _dp=_grad(p), _node=node):
                        blocks = _inner(regions)
                        blocks.append(rules._memset_at(regions[_one], 0, 1.0))
                        blocks.append(
                            _batch_reduce_block(
                                regions[_dwb], regions[_one], regions[_dp],
                                B, design, tag=f"{_node.name}:dw:batch_reduce",
                            )
                        )
                        return blocks

                    step.emit = emit_dw
            elif isinstance(s, MatmulSpec):
                relocated_step(
                    f"{node.name}:dw", s, "dw",
                    {"a": node.in_edge, "dy": g_out, "dw": _grad(p)},
                    batched=False,
                )
            elif isinstance(s, BiasSpec):
                relocated_step(
                    f"{node.name}:dw", s, "dw",
                    {"dy": g_out, "one": f"{node.name}.one", "db": _grad(p)},
                    batched=False,
                )
            elif isinstance(s, LayerNormSpec):
                prog = lower(s, "dw", design=design)
                rename = scratch_rename(
                    prog,
                    {"x": node.in_edge, "dy": g_out, "dw": _grad(p)},
                    f"{node.name}.dw",
                )
                relocated_step(f"{node.name}:dw", s, "dw", rename,
                               batched=False, prog=prog)
            elif isinstance(s, EmbeddingSpec):
                relocated_step(
                    f"{node.name}:dw", s, "dw",
                    {"x": node.in_edge, "dy": g_out, "dw": _grad(p)},
                    batched=False,
                )
            elif isinstance(s, PosEmbedSpec):
                relocated_step(
                    f"{node.name}:dw", s, "dw",
                    {"dy": g_out, "one": f"{node.name}.dw.one",
                     "dw": _grad(p)},
                    batched=False,
                )

            # the SGD(+momentum) update, right after dW so the gradient's
            # liveness ends here unless the caller keeps it as an output
            pshape = _param_shape(s)
            upd = _Step(key=f"{node.name}:upd")
            upd.touch(p, pshape, "param")
            upd.touch(_grad(p), pshape, kinds_base[_grad(p)])
            upd.touch(f"{p}_new", pshape, "output")
            nconst = 4 if mom else 2
            upd.touch(f"{node.name}.upd.consts", (nconst,), "scratch")
            if mom:
                upd.touch(f"v_{p}", pshape, "param")
                upd.touch(f"v_{p}_new", pshape, "output")

            def emit_upd(regions, _node=node, _p=p, _pshape=pshape):
                spec_u = SgdUpdateSpec(
                    n=math.prod(_pshape), lr=graph.lr, momentum=mom
                )
                return rules.sgd_update_blocks(
                    spec_u,
                    regions[_p], regions[_grad(_p)], regions[f"{_p}_new"],
                    regions[f"{_node.name}.upd.consts"], design,
                    v=regions.get(f"v_{_p}"),
                    v_new=regions.get(f"v_{_p}_new"),
                    tag=f"{_node.name}:upd",
                )

            upd.emit = emit_upd
            steps.append(upd)

        # dX (skipped for the input-most node: nothing consumes it)
        if is_first:
            continue
        if isinstance(s, Conv2dSpec):
            rename = {"dy": g_out, "w": node.param, "dx": g_in}
            dx_prog = lower(s, "dx", design=design)
            scratch_rename(dx_prog, rename, f"{node.name}.dx")
            relocated_step(f"{node.name}:dx", s, "dx", rename, batched=True,
                           prog=dx_prog)
        elif isinstance(s, MatmulSpec):
            relocated_step(
                f"{node.name}:dx", s, "dx",
                {"dy": g_out, "b": node.param, "dx": g_in},
                batched=False,
            )
        elif isinstance(s, ReluSpec):
            whole = ReluSpec((B,) + tuple(s.shape)) if B > 1 else s
            relocated_step(
                f"{node.name}:dx", whole, "dx",
                {"x": node.in_edge, "dy": g_out,
                 "mask": f"{node.name}.mask", "dx": g_in},
                batched=False,
            )
        elif isinstance(s, MaxPool2dSpec):
            relocated_step(
                f"{node.name}:dx", s, "dx",
                {"x": node.in_edge, "y": node.out_edge, "dy": g_out,
                 "mask": f"{node.name}.mask", "dx": g_in},
                batched=True,
            )
        elif isinstance(s, AttentionSpec):
            dx_prog = lower(s, "dx", design=design)
            rename = {"x": node.in_edge, "dy": g_out, "dx": g_in}
            scratch_rename(dx_prog, rename, f"{node.name}.dx")
            static.add(f"{node.name}.dx.mask")
            static.add(f"{node.name}.dx.consts")
            relocated_step(f"{node.name}:dx", s, "dx", rename, batched=True,
                           prog=dx_prog)
        elif isinstance(s, LayerNormSpec):
            dx_prog = lower(s, "dx", design=design)
            rename = {"x": node.in_edge, "w": node.param, "dy": g_out,
                      "dx": g_in}
            scratch_rename(dx_prog, rename, f"{node.name}.dx")
            relocated_step(f"{node.name}:dx", s, "dx", rename, batched=False,
                           prog=dx_prog)
        elif isinstance(s, ResidualAddSpec):
            # one step, two identity-copy relocations: the upstream grad
            # flows unchanged into BOTH the main and the skip branch
            t_main = g_in
            t_aux = grad_target(node, node.aux_edges[0])
            dx_prog = lower(s, "dx", design=design)
            step = _Step(key=f"{node.name}:dx")
            step.touch(g_out, dx_prog.regions["dy"].shape,
                       kinds_base.get(g_out, "scratch"))
            for t in (t_main, t_aux):
                step.touch(t, dx_prog.regions["dx"].shape,
                           kinds_base.get(t, "scratch"))

            def emit_res_dx(regions, _prog=dx_prog, _g=g_out,
                            _targets=(t_main, t_aux),
                            _key=f"{node.name}:dx"):
                blocks: list[CommandBlock] = []
                for dst in _targets:
                    rename = {"dy": _g, "dx": dst}
                    blocks.extend(_relocate_blocks(
                        _prog, rename, regions, set(rename.values()), 1,
                        _key,
                    ))
                return blocks

            step.emit = emit_res_dx
            steps.append(step)
        elif isinstance(s, PosEmbedSpec):
            relocated_step(
                f"{node.name}:dx", s, "dx",
                {"dy": g_out, "dx": g_in},
                batched=False,
            )
        elif isinstance(s, (FlattenSpec, BiasSpec)):
            if len(consumers[node.in_edge]) > 1:
                # the alias trick can't feed a partial sum — identity-copy
                # the grad into this consumer's private partial instead
                relocated_step(
                    f"{node.name}:dx",
                    ResidualAddSpec((edge_size(node.in_edge),)), "dx",
                    {"dy": g_out, "dx": g_in},
                    batched=False,
                )
            else:
                # pure views backward: d_in aliases d_out, input's shape
                step = _Step(key=f"{node.name}:dx")
                step.touch(g_out)
                in_shape = ((B,) + node.in_shape) if B > 1 else node.in_shape
                if isinstance(s, BiasSpec):
                    in_shape = (s.rows, s.c)
                step.aliases.append(
                    (g_in, g_out, in_shape, kinds_base.get(g_in, "scratch"))
                )
                steps.append(step)
        else:
            raise TypeError(f"no dX graph lowering for {type(s).__name__}")

        # fan-out edges: once the forward-FIRST consumer (processed last
        # here) has contributed, sum the per-consumer partials into d_<e>
        for e in (node.in_edge, *node.aux_edges):
            cs = consumers[e]
            if len(cs) <= 1 or cs[0] is not node:
                continue
            size = edge_size(e)
            parts = [f"{_grad(e)}@{c.name}" for c in cs]
            acc = _Step(key=f"{e}:acc")
            for pn in parts:
                acc.touch(pn)
            chain: list[tuple[str, str, str]] = []
            cur = parts[0]
            for i, nxt in enumerate(parts[1:]):
                dst = (_grad(e) if i == len(parts) - 2
                       else f"{_grad(e)}.acc{i}")
                acc.touch(dst, (size,), kinds_base.get(dst, "scratch"))
                chain.append((cur, nxt, dst))
                cur = dst
            add_prog = lower(ResidualAddSpec((size,)), "fwd", design=design)

            def emit_acc(regions, _chain=tuple(chain), _prog=add_prog,
                         _key=f"{e}:acc"):
                blocks: list[CommandBlock] = []
                for a, b2, dst in _chain:
                    rename = {"x": a, "x2": b2, "y": dst}
                    blocks.extend(_relocate_blocks(
                        _prog, rename, regions, set(rename.values()), 1,
                        _key,
                    ))
                return blocks

            acc.emit = emit_acc
            steps.append(acc)

    return _assemble(graph, steps, design, n_clusters, keep_grads)


def _assemble(
    graph: NetworkGraph,
    steps: list[_Step],
    design: DesignPoint,
    n_clusters: int,
    keep_grads: bool,
) -> NtxProgram:
    """Liveness analysis -> interval allocation -> block emission."""
    # union storage groups through aliases (zero-copy views share addresses)
    parent: dict[str, str] = {}

    def find(n: str) -> str:
        while parent.get(n, n) != n:
            n = parent[n]
        return n

    first: dict[str, int] = {}
    last: dict[str, int] = {}
    shapes: dict[str, tuple[int, ...]] = {}
    kinds: dict[str, str] = {}
    alias_specs: dict[str, tuple[str, tuple[int, ...], str]] = {}
    order: list[str] = []
    for i, step in enumerate(steps):
        for name, (shape, kind) in step.touched.items():
            if name not in first:
                first[name] = i
                shapes[name] = shape
                kinds[name] = kind
                order.append(name)
            elif not shapes[name] and shape:
                shapes[name] = shape
                kinds[name] = kind
            last[name] = i
        for name, of, shape, kind in step.aliases:
            if name in first:
                raise ValueError(f"alias {name!r} already exists")
            first[name] = i
            last[name] = i
            shapes[name] = shape
            kinds[name] = kind
            alias_specs[name] = (of, shape, kind)
            parent[name] = of
            order.append(name)

    # graph inputs/params must be resident from program start (the executors
    # write them into memory before the first command)
    for name, kind in kinds.items():
        if kind in ("input", "param"):
            first[name] = -1

    # storage-group live interval = union over members
    group_first: dict[str, int] = {}
    group_last: dict[str, int] = {}
    for name in order:
        root = find(name)
        group_first[root] = min(group_first.get(root, first[name]), first[name])
        e = LIVE_END if kinds[name] == "output" else last[name]
        group_last[root] = max(group_last.get(root, e), e)

    budget_words = design.tcdm_budget_bytes(n_clusters) // ELEM_BYTES
    alloc = LivenessAllocator(budget_words=budget_words)
    # allocate primaries in birth order, then materialize aliases
    for name in sorted(order, key=lambda n: (group_first[find(n)], order.index(n))):
        root = find(name)
        if name == root:
            alloc.alloc(
                name, shapes[name] or (1,), kinds[name],
                start=group_first[root], end=group_last[root],
            )
    for name in order:
        if name in alias_specs:
            of, shape, kind = alias_specs[name]
            alloc.alias(name, of, shape, kind, end=group_last[find(name)])

    regions = alloc.regions
    spilled = set(alloc.spilled)

    # emit, inserting spill/fill DMA blocks around spilled regions' lives
    col = obs_trace.get_active_trace()
    blocks: list[CommandBlock] = []
    filled: set[str] = set()
    spilled_out: set[str] = set()
    for i, step in enumerate(steps):
        pre: list[CommandBlock] = []
        post: list[CommandBlock] = []
        for name in step.touched:
            root = find(name)
            if root not in spilled:
                continue
            if group_first[root] < i and root not in filled:
                pre.append(_spill_block(regions[root], "fill"))
                filled.add(root)
            if group_first[root] == i and root not in spilled_out:
                post.append(_spill_block(regions[root], "spill"))
                spilled_out.add(root)
        blocks.extend(pre)
        if step.emit is not None:
            if col is not None:
                with col.host_span(f"lower:{step.key}", tid="lowering",
                                   cat="lowering"):
                    blocks.extend(step.emit(regions))
            else:
                blocks.extend(step.emit(regions))
        blocks.extend(post)

    prog = NtxProgram(
        name=f"{graph.name}:train_step",
        blocks=blocks,
        regions=regions,
        design=design,
        meta={
            "graph": graph,
            "pass": "train_step",
            "batch": graph.batch,
            "n_clusters": n_clusters,
            "keep_grads": keep_grads,
            "peak_tcdm_bytes": alloc.peak_tcdm_bytes,
            "tcdm_budget_bytes": design.tcdm_budget_bytes(n_clusters),
            "spilled": sorted(spilled),
            "intervals": dict(alloc.intervals),
            "steps": [s.key for s in steps],
        },
    )
    assert prog.meta["peak_tcdm_bytes"] <= prog.meta["tcdm_budget_bytes"], (
        "liveness allocator exceeded the TCDM budget without spilling"
    )
    return prog


# ---------------------------------------------------------------------------
# The paper's CNN + a host-side training loop over the compiled step
# ---------------------------------------------------------------------------


def paper_cnn_graph(
    batch: int = 8,
    img: int = 32,
    n_classes: int = 10,
    *,
    lr: float = 0.05,
    momentum: float = 0.9,
) -> NetworkGraph:
    """The small GoogLeNet-style CNN of ``examples/train_cnn_paper.py`` as a
    training graph (GAP swapped for maxpool+flatten, which have lowerings)."""
    h1 = (img + 2 * 2 - 5) // 2 + 1  # conv1: 5x5 stride 2 pad 2
    h2 = (h1 + 2 * 1 - 3) // 2 + 1  # conv2: 3x3 stride 2 pad 1
    h3 = h2 // 2  # maxpool 2x2
    return NetworkGraph.chain(
        "paper_cnn", batch, (img, img, 3),
        [
            ("c1", Conv2dSpec(img, img, 3, 5, 5, 16, stride=2, padding=2)),
            ("r1", "relu"),
            ("c2", Conv2dSpec(h1, h1, 16, 3, 3, 32, stride=2, padding=1)),
            ("r2", "relu"),
            ("p1", MaxPool2dSpec(h2, h2, 32)),
            ("f1", "flatten"),
            ("fc", MatmulSpec(batch, n_classes, h3 * h3 * 32)),
            ("fcb", "bias"),
        ],
        lr=lr, momentum=momentum,
    )


def frequency_band_batches(
    rng: np.random.RandomState, batch: int, img: int, n_classes: int = 10
) -> Callable[[int], tuple[np.ndarray, np.ndarray]]:
    """The synthetic separable image task every CNN driver trains on:
    class = dominant frequency band, plus gaussian pixel noise. Returns a
    ``batch_fn(step) -> (images (B, img, img, 3), labels (B,))``."""

    def batch_fn(_step):
        y = rng.randint(0, n_classes, batch)
        base = np.linspace(0, 3.14 * 4, img)
        imgs = np.stack([
            np.sin(base[None, :] * (1 + c)) * np.cos(base[:, None] * (1 + c))
            for c in y
        ])[..., None].repeat(3, axis=-1)
        imgs += rng.randn(*imgs.shape) * 0.1
        return imgs.astype(np.float32), y

    return batch_fn


def lm_token_batches(
    rng: np.random.RandomState, batch: int, seq: int, vocab: int
) -> Callable[[int], tuple[np.ndarray, np.ndarray]]:
    """Synthetic next-token task for the LM train-step drivers: every
    position's target is a fixed affine remap of its input token, so the
    mapping is learnable by embedding + head alone and a few SGD steps
    visibly reduce the CE loss. Returns ``batch_fn(step) -> (one-hot
    tokens (B*S, V) float32, target ids (B*S,) int)`` — the token-row
    layout :meth:`NetworkGraph.from_model_config` graphs consume."""
    eye = np.eye(vocab, dtype=np.float32)

    def batch_fn(_step):
        tok = rng.randint(0, vocab, batch * seq)
        nxt = (tok * 3 + 1) % vocab
        return eye[tok], nxt

    return batch_fn


def softmax_xent_loss(logits: np.ndarray, labels: np.ndarray) -> float:
    """Host-side scalar loss over the program's logits output."""
    z = np.asarray(logits, np.float64)
    z = z - z.max(axis=1, keepdims=True)
    lse = np.log(np.exp(z).sum(axis=1))
    return float(np.mean(lse - z[np.arange(len(labels)), labels]))


def train_graph(
    graph: NetworkGraph,
    steps: int,
    batch_fn: Callable[[int], tuple[np.ndarray, np.ndarray]],
    *,
    backend: str = "pallas",
    design: DesignPoint = NTX_DESIGN,
    n_clusters: int = 16,
    interpret: bool | None = None,
    params: dict[str, np.ndarray] | None = None,
    cache=None,
    program: NtxProgram | None = None,
    registry=None,
    metrics_path=None,
    fuse: bool = True,
    chaos=None,
) -> dict[str, Any]:
    """Train ``graph`` for ``steps`` through one compiled NtxProgram.

    ``batch_fn(i)`` returns (images (B, H, W, C) float32, labels (B,) int).
    ``backend`` is ``"pallas"`` (graph-driven plan-cache execution) or
    ``"reference"`` (the numpy command interpreter). Every step runs the
    SAME program — parameters round-trip through the ``*_new`` outputs.
    The result carries per-step wall-clock seconds in ``"walls"``.

    ``registry`` (a :class:`repro.obs.CounterRegistry`) is installed for
    the loop; each step records under a ``step{i}`` scope, so per-step
    totals equal the program's closed-form counts. ``metrics_path`` streams
    one JSONL record per step (loss, wall seconds, the step's counter
    totals).

    ``chaos`` (a :class:`repro.runtime.faults.ChaosController`) injects
    faults: each executed step is intercepted BEFORE its outputs commit,
    so a cube kill discards the step, swaps in the elastically re-sharded
    program and replays it, and a preemption rewinds to the latest
    checkpoint — gradients match the healthy run because partial results
    never commit. Replayed steps re-enter ``batch_fn(i)`` at the same
    ``i`` (the (seed, step) data contract makes the stream bit-identical).
    """
    import time as _time
    from contextlib import nullcontext

    from repro.lower import executors
    from repro.obs import report as obs_report

    if program is None:
        program = lower_training_step(graph, design=design, n_clusters=n_clusters)
    if params is None:
        params = graph.init_params()
    params = dict(params)
    eye = np.eye(graph.loss.classes, dtype=np.float32)
    losses: list[float] = []
    walls: list[float] = []
    reg = registry if registry is not None else obs_counters.get_active()
    writer = obs_report.MetricsWriter(metrics_path) if metrics_path else None
    install = (
        obs_counters.use_registry(registry)
        if registry is not None
        else nullcontext()
    )
    try:
        with install:
            if chaos is not None:
                program = chaos.start(program, params)
            i = 0
            while i < steps:
                t0 = _time.perf_counter()
                x, labels = batch_fn(i)
                inputs = {graph.input_edge: np.asarray(x, np.float32),
                          graph.label_edge: eye[np.asarray(labels)], **params}
                step_scope = (
                    reg.scope(f"step{i}") if reg is not None else nullcontext()
                )
                with step_scope:
                    if backend == "reference":
                        outs = executors.run_reference(program, inputs)
                    elif backend == "pallas":
                        outs = executors.run_pallas(
                            program, inputs, interpret=interpret, cache=cache,
                            fuse=fuse,
                        )
                        import jax as _jax

                        # jax dispatch is async: wait for the step's device
                        # work so the recorded wall is the true step time
                        _jax.block_until_ready(outs)
                    else:
                        raise ValueError(f"unknown backend {backend!r}")
                if chaos is not None:
                    action = chaos.intercept(i, outs, params)
                    if action is not None:
                        # the step is discarded before commit: swap in the
                        # re-sharded program / rewound params and replay
                        if action.program is not None:
                            program = action.program
                        if action.params is not None:
                            params = dict(action.params)
                        del losses[action.resume_step:]
                        del walls[action.resume_step:]
                        i = action.resume_step
                        continue
                losses.append(
                    softmax_xent_loss(np.asarray(outs[graph.logits_edge]), labels)
                )
                # keep updated params as whatever the backend returned (jax
                # arrays stay on device between pallas steps — no per-step
                # host round trip); materialized to numpy once after the loop
                for p in graph.param_shapes():
                    params[p] = outs[f"{p}_new"]
                    if graph.momentum:
                        params[f"v_{p}"] = outs[f"v_{p}_new"]
                walls.append(_time.perf_counter() - t0)
                if writer is not None:
                    writer.write({
                        "step": i,
                        "loss": losses[-1],
                        "wall_s": walls[-1],
                        "counters": reg.totals(f"step{i}/") if reg is not None else {},
                    })
                if chaos is not None:
                    chaos.committed(i, params)
                i += 1
    finally:
        if writer is not None:
            writer.close()
    params = {k: np.asarray(v, np.float32) for k, v in params.items()}
    return {"program": program, "params": params, "losses": losses,
            "walls": walls, "registry": reg}

"""Region fuser: group contiguous train-step blocks into fused kernels.

The graph compiler (:mod:`repro.lower.graph`) emits one command block group
per node pass — ``c1:fwd``, ``r1:fwd``, …, ``loss:dx``, ``c2:dw``, … — and
the Pallas executor used to dispatch one cached ``pallas_call`` per group.
That per-op dispatch is exactly what the NTX datapath avoids: the hardware
streams whole loop nests through the FMAC pipeline (paper §3), so fusing the
software the same way is the hot-path fix.

:func:`plan_fusion` walks the step schedule of a lowered train-step
:class:`~repro.lower.ir.NtxProgram` and greedily groups contiguous
*fusable* steps into :class:`RegionSpec` regions:

  * fwd chains — conv → bias → relu → pool (window == stride) → flatten →
    matmul head, as far as the schedule stays fusable;
  * bwd chains — relu-dX → conv-dW → update → conv-dX runs, crossing layer
    boundaries;
  * SGD/momentum update blocks, fused as the epilogue of the dW that feeds
    them (single-device path only — under a cross-shard gradient reduce the
    psum must run between dW and the update, so updates stay per-node).

Steps with no fusion rule — the maxpool-dX winner scatter, steps touching
spilled regions, the DAG fan-out accumulate steps — become per-node
fallback :class:`Segment`s, so the fused walk stays numerically compatible
with ``run_reference`` on every graph. The softmax-CE loss gradient
``(softmax(z) - onehot) / B`` is row-independent and fuses like any other
stage, stitching the forward head chain to the backward dW chain. LM/DAG
graphs (attention, layernorm, residual fan-out) carry *token-row*
activations — ``B*S`` rows, not ``B`` — which the batch-tile grid of the
region kernel cannot stream correctly, so their activation passes all run
as fallbacks; only the SGD update epilogues fuse there.

Each region's intermediate edges stay resident in kernel scratch; only
edges read by steps outside the region (or program outputs) escape. The
:class:`RegionSpec` is a frozen dataclass — the region-level
:class:`~repro.lower.executors.PlanCache` key — so fused plans jit once and
retrace zero times, like every per-node plan.

One numerical identity makes bwd chains closed: the relu backward mask can
be taken from the relu *output* (``y > 0`` ⟺ ``x > 0`` for ``y = max(x,
0)``), so pre-activations never need to escape a forward region.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.lower.rules import (
    AttentionSpec,
    BiasSpec,
    Conv2dSpec,
    EmbeddingSpec,
    FlattenSpec,
    LayerNormSpec,
    MatmulSpec,
    MaxPool2dSpec,
    PosEmbedSpec,
    ReluSpec,
    ResidualAddSpec,
)


@dataclass(frozen=True)
class Stage:
    """One node pass inside a fused region (a former per-node dispatch)."""

    node: str
    pass_: str  # "fwd" | "dw" | "upd" | "dx"
    spec: object  # the layer spec (frozen dataclass)
    in_edge: str
    out_edge: str
    param: str | None = None


@dataclass(frozen=True)
class RegionSpec:
    """Plan-cache key for one fused region kernel.

    ``inputs`` are ``(edge, batched)`` pairs — batched edges stream through
    the kernel's double-buffered VMEM tiles, unbatched ones (params,
    momentum state) ride in as resident blocks. ``outputs`` are ``(edge,
    kind)`` with kind ``"batched"`` (written per batch tile) or
    ``"reduced"`` (accumulated across tiles, written on the last grid
    step: dW totals and updated params).
    """

    stages: tuple[Stage, ...]
    batch: int
    lr: float
    momentum: float
    inputs: tuple[tuple[str, bool], ...]
    outputs: tuple[tuple[str, str], ...]

    @property
    def label(self) -> str:
        first, last = self.stages[0], self.stages[-1]
        return (
            f"fused[{first.node}:{first.pass_}..{last.node}:{last.pass_}]"
            f"x{len(self.stages)}"
        )


@dataclass
class Segment:
    """One dispatch of the fused walk: a region or a per-node fallback."""

    region: RegionSpec | None = None
    step: str | None = None


@dataclass
class FusionPlan:
    """plan_fusion's output: the segment walk plus coverage accounting."""

    segments: list[Segment] = field(default_factory=list)
    fused_steps: set[str] = field(default_factory=set)
    fallback_steps: list[str] = field(default_factory=list)
    fused_commands: int = 0
    total_commands: int = 0

    @property
    def n_regions(self) -> int:
        return sum(1 for s in self.segments if s.region is not None)

    @property
    def coverage(self) -> float:
        """Fused commands / total program commands (the gated fraction)."""
        if not self.total_commands:
            return 0.0
        return self.fused_commands / self.total_commands

    def stats(self) -> dict:
        return {
            "regions": self.n_regions,
            "fallback_dispatches": len(self.fallback_steps),
            "fused_steps": len(self.fused_steps),
            "fused_commands": self.fused_commands,
            "total_commands": self.total_commands,
            "coverage": self.coverage,
        }


def step_schedule(graph, keep_grads: bool = True) -> list[str]:
    """The train-step step keys in schedule order (mirrors the lowering)."""
    from repro.lower.graph import edge_consumers

    consumers = edge_consumers(graph)
    keys = [f"{n.name}:fwd" for n in graph.nodes]
    keys.append("loss:dx")
    for node in reversed(graph.nodes):
        if node.param is not None:
            keys.append(f"{node.name}:dw")
            keys.append(f"{node.name}:upd")
        if node.in_edge == graph.input_edge:
            continue
        keys.append(f"{node.name}:dx")
        # fan-out accumulate fires once the forward-FIRST consumer (the
        # last one visited in reverse) has produced its partial
        for e in (node.in_edge, *node.aux_edges):
            cs = consumers.get(e, ())
            if len(cs) > 1 and cs[0] is node:
                keys.append(f"{e}:acc")
    return keys


def _fusable(node, pass_: str, *, fuse_updates: bool) -> bool:
    """Does this (node, pass) have an in-kernel fusion rule?"""
    s = node.spec
    if pass_ == "fwd":
        if isinstance(s, MaxPool2dSpec):
            # the reshape-max pool tile needs exact window tiling
            return (
                s.window == s.stride
                and s.in_h % s.window == 0
                and s.in_w % s.window == 0
            )
        return isinstance(
            s, (Conv2dSpec, MatmulSpec, BiasSpec, ReluSpec, FlattenSpec)
        )
    if pass_ == "dw":
        return isinstance(s, (Conv2dSpec, MatmulSpec, BiasSpec))
    if pass_ == "upd":
        return fuse_updates
    if pass_ == "dx":
        if isinstance(s, Conv2dSpec):
            # the in-kernel transposed conv dilates dy and pads by k-1-p
            return s.padding <= s.kh - 1 and s.padding <= s.kw - 1
        if isinstance(s, MaxPool2dSpec):
            # first-match winner mask needs the exact reshape tiling too
            return (
                s.window == s.stride
                and s.in_h % s.window == 0
                and s.in_w % s.window == 0
            )
        return isinstance(s, (MatmulSpec, ReluSpec, BiasSpec, FlattenSpec))
    return False


def _step_io(graph, node, pass_: str, *, fused: bool):
    """(reads, writes) edge names of one step, as the fused walk sees them.

    ``fused`` matters for relu-dX: inside a region the mask comes from the
    relu *output* (so pre-activations stay in scratch); the per-node
    fallback plan masks from the input, which must then escape.
    """
    if node is None:  # loss:dx
        return (
            [graph.logits_edge, graph.label_edge],
            [f"d_{graph.logits_edge}"],
        )
    s = node.spec
    if pass_ == "fwd":
        reads = [node.in_edge]
        if node.param is not None:
            reads.append(node.param)
        return reads, [node.out_edge]
    if pass_ == "dw":
        p = node.param
        if isinstance(s, BiasSpec):
            return [f"d_{node.out_edge}"], [f"d_{p}"]
        return [node.in_edge, f"d_{node.out_edge}"], [f"d_{p}"]
    if pass_ == "upd":
        p = node.param
        reads = [p, f"d_{p}"]
        writes = [f"{p}_new"]
        if graph.momentum:
            reads.append(f"v_{p}")
            writes.append(f"v_{p}_new")
        return reads, writes
    # dx
    g = f"d_{node.out_edge}"
    if isinstance(s, ReluSpec):
        mask_edge = node.out_edge if fused else node.in_edge
        return [mask_edge, g], [f"d_{node.in_edge}"]
    if isinstance(s, MaxPool2dSpec):
        return [node.in_edge, g], [f"d_{node.in_edge}"]
    if isinstance(s, (Conv2dSpec, MatmulSpec)):
        return [g, node.param], [f"d_{node.in_edge}"]
    return [g], [f"d_{node.in_edge}"]  # bias / flatten reshape


def _touches_spill(graph, node, pass_: str, spilled: set[str]) -> bool:
    """Conservative spill barrier: the step's edges or scratch are spilled."""
    if not spilled:
        return False
    reads, writes = _step_io(graph, node, pass_, fused=True)
    names = set(reads) | set(writes)
    if names & spilled:
        return True
    prefix = f"{node.name}." if node is not None else "loss."
    return any(name.startswith(prefix) for name in spilled)


def _heavy(stages: list[Stage]) -> bool:
    """Is this group worth a fused kernel (vs cheap per-node dispatches)?"""
    if len(stages) >= 2:
        return True
    return any(isinstance(st.spec, (Conv2dSpec, MatmulSpec)) for st in stages)


def plan_fusion(program, *, fuse_updates: bool = True) -> FusionPlan:
    """Plan the fused-region walk for one lowered train-step program.

    ``fuse_updates=False`` keeps every SGD update a per-node dispatch — the
    mesh shard walk needs the cross-shard psum between dW and the update,
    which cannot live inside a shared cached kernel.
    """
    graph = program.meta["graph"]
    keep_grads = program.meta.get("keep_grads", True)
    spilled = set(program.meta.get("spilled", ()))
    keys = program.meta.get("steps") or step_schedule(graph, keep_grads)
    nodes = {n.name: n for n in graph.nodes}
    unbatched = set()
    for p in graph.param_shapes():
        unbatched |= {p, f"v_{p}", f"d_{p}", f"{p}_new", f"v_{p}_new"}

    # LM/DAG graphs carry token-row activations (B*S rows): the region
    # kernel's batch-tile grid would stream only the first B rows, so
    # every activation pass falls back per-node there; SGD update
    # epilogues carry no streamed edges and stay fusable
    token_rows = any(
        isinstance(
            n.spec,
            (AttentionSpec, LayerNormSpec, EmbeddingSpec, PosEmbedSpec,
             ResidualAddSpec),
        )
        for n in graph.nodes
    )

    # 1. classify every step: fusable or per-node fallback
    fusable: dict[str, bool] = {}
    for key in keys:
        name, pass_ = key.split(":")
        node = nodes.get(name)
        if name == "loss":
            ok = not token_rows
            if ok and _touches_spill(graph, None, "dx", spilled):
                ok = False
            fusable[key] = ok
            continue
        if node is None:
            # fan-out accumulate steps ({edge}:acc) have no fusion rule
            fusable[key] = False
            continue
        ok = _fusable(node, pass_, fuse_updates=fuse_updates)
        if ok and pass_ != "upd" and token_rows:
            ok = False
        if ok and _touches_spill(graph, node, pass_, spilled):
            ok = False
        fusable[key] = ok

    # 2. greedy contiguous grouping; groups not worth a kernel demote to
    #    per-node fallbacks before the escape analysis sees them
    groups: list[tuple[bool, list[str]]] = []  # (is_region, step keys)
    for key in keys:
        if fusable[key] and groups and groups[-1][0]:
            groups[-1][1].append(key)
        else:
            groups.append((fusable[key], [key]))

    def _group_stages(ks: list[str]) -> list[Stage]:
        stages = []
        for key in ks:
            name, pass_ = key.split(":")
            if name == "loss":
                stages.append(
                    Stage(
                        node="loss",
                        pass_="dx",
                        spec=graph.loss,
                        in_edge=graph.logits_edge,
                        out_edge=graph.logits_edge,
                        param=graph.label_edge,
                    )
                )
                continue
            node = nodes[name]
            stages.append(
                Stage(
                    node=name,
                    pass_=pass_,
                    spec=node.spec,
                    in_edge=node.in_edge,
                    out_edge=node.out_edge,
                    param=node.param,
                )
            )
        return stages

    groups = [
        (ok and _heavy(_group_stages(ks)), ks) for ok, ks in groups
    ]

    # 3. per-step IO for escape analysis (fallback steps read their
    #    per-node operands, fused relu-dX masks from the relu output)
    key_fused = {key: ok for ok, ks in groups for key in ks}
    io: dict[str, tuple[list[str], list[str]]] = {}
    for key in keys:
        name, pass_ = key.split(":")
        if pass_ == "acc":
            # no-op in the jax walk: consumers' dX steps already
            # accumulated into d_<edge> as they landed
            io[key] = ([], [])
            continue
        node = nodes.get(name) if name != "loss" else None
        io[key] = _step_io(graph, node, pass_, fused=key_fused[key])

    program_outputs = {graph.logits_edge}
    for p in graph.param_shapes():
        program_outputs.add(f"{p}_new")
        if keep_grads:
            program_outputs.add(f"d_{p}")
        if graph.momentum:
            program_outputs.add(f"v_{p}_new")

    readers: dict[str, set[str]] = {}
    for key in keys:
        for edge in io[key][0]:
            readers.setdefault(edge, set()).add(key)

    plan = FusionPlan()
    for is_region, ks in groups:
        if is_region:
            stages = _group_stages(ks)
            in_region = set(ks)
            written: set[str] = set()
            inputs: list[tuple[str, bool]] = []
            outputs: list[tuple[str, str]] = []
            for key in ks:
                reads, writes = io[key]
                for edge in reads:
                    if edge not in written and edge not in {n for n, _ in inputs}:
                        inputs.append((edge, edge not in unbatched))
                for edge in writes:
                    written.add(edge)
            for key in ks:
                for edge in io[key][1]:
                    escapes = edge in program_outputs or any(
                        r not in in_region for r in readers.get(edge, ())
                    )
                    if escapes and edge not in {n for n, _ in outputs}:
                        kind = "reduced" if edge in unbatched else "batched"
                        outputs.append((edge, kind))
            region = RegionSpec(
                stages=tuple(stages),
                batch=graph.batch,
                lr=graph.lr,
                momentum=graph.momentum,
                inputs=tuple(inputs),
                outputs=tuple(outputs),
            )
            plan.segments.append(Segment(region=region))
            plan.fused_steps |= in_region
        else:
            for key in ks:
                plan.segments.append(Segment(step=key))
                plan.fallback_steps.append(key)

    # 4. command-level coverage accounting against the program's blocks
    for block in program.blocks:
        parts = block.tag.split(":")
        step = ":".join(parts[:2]) if len(parts) >= 2 else block.tag
        plan.total_commands += block.n_commands
        if step in plan.fused_steps:
            plan.fused_commands += block.n_commands
    return plan

"""The NtxProgram IR: what a whole layer pass looks like to the hardware.

The paper's Table 2 observation is that one training-layer pass is a *driver
loop around one command template*: the RISC-V core re-issues the same 5-deep
loop nest with rebased AGU base addresses. This module keeps that structure
first-class instead of materializing every command eagerly:

  * :class:`TensorRegion` — a named, shaped window of the flat TCDM address
    space (inputs, parameters, outputs, staging scratch).
  * :class:`CommandBlock` — one command *template* plus the driver-side
    replication loops (``reps``) and the per-level AGU base steps. A block
    with ``reps=(64,)`` is Table 2's "64 offloads" row; iterating
    :meth:`CommandBlock.commands` reproduces the exact command stream the
    driver would issue. Offload/cycle counts are O(1) properties — no
    materialization needed for the 802 816-command NS rows.
  * :class:`NtxProgram` — ordered blocks + regions + the layer spec they were
    lowered from. This is the single representation the reference
    interpreter, the event-driven timing model, and the Pallas backend all
    consume (see :mod:`repro.lower.executors`).

Staging (zero-padding, halo blits) is expressed *in-band* as ``memset`` /
``copy`` command blocks, so executing a program needs no out-of-band numpy
padding logic: the DMA/offload stream is the whole story.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Iterator

from repro.core.ntx import MAX_LOOPS, Agu, NtxCommand

ELEM_BYTES = 4  # the NTX datapath streams fp32 words

# The two design points the paper compares (Table 2). ``hw_loops`` is the
# depth of the hardware loop nest, ``n_agus`` the address generators, and
# ``autonomous_writeback`` whether a write AGU exists — without one (NS) at
# most the reduction dims can be offloaded: every output pixel is its own
# command (§2.5(iii)).


@dataclass(frozen=True)
class DesignPoint:
    name: str
    hw_loops: int
    n_agus: int
    autonomous_writeback: bool
    # One cluster's scratchpad (§2.1): the TCDM budget a whole-step program's
    # liveness allocator must fit into is this times the cluster count.
    tcdm_bytes_per_cluster: int = 64 * 1024

    def tcdm_budget_bytes(self, n_clusters: int) -> int:
        return self.tcdm_bytes_per_cluster * n_clusters


NS_DESIGN = DesignPoint("ns", hw_loops=3, n_agus=2, autonomous_writeback=False)
NTX_DESIGN = DesignPoint("ntx", hw_loops=5, n_agus=3, autonomous_writeback=True)


@dataclass(frozen=True)
class TensorRegion:
    """A named window of the flat TCDM address space (element units)."""

    name: str
    base: int
    shape: tuple[int, ...]
    kind: str  # "input" | "param" | "output" | "scratch"

    @property
    def size(self) -> int:
        return math.prod(self.shape)

    @property
    def end(self) -> int:
        return self.base + self.size

    @property
    def bytes(self) -> int:
        return self.size * ELEM_BYTES


def _rebased(agu: Agu | None, offset: int) -> Agu | None:
    if agu is None or offset == 0:
        return agu
    return Agu(agu.base + offset, agu.strides)


@dataclass(frozen=True)
class CommandBlock:
    """One command template + the driver loop that re-issues it.

    ``reps`` are the driver-side loop bounds (innermost first, may be empty);
    ``rd0_step``/``rd1_step``/``wr_step`` give, per rep level, how far each
    AGU's base moves between consecutive issues — exactly the software loop
    of Table 2 made explicit.
    """

    template: NtxCommand
    reps: tuple[int, ...] = ()
    rd0_step: tuple[int, ...] = ()
    rd1_step: tuple[int, ...] = ()
    wr_step: tuple[int, ...] = ()
    tag: str = ""
    reads: tuple[str, ...] = ()  # region names streamed in
    writes: tuple[str, ...] = ()  # region names streamed out
    dma_bytes_in: float = 0.0  # per command (block read traffic / n_commands)
    dma_bytes_out: float = 0.0
    tile: Any = None  # tiling-plan metadata (core/tiling.py), if any

    def __post_init__(self):
        for steps in (self.rd0_step, self.rd1_step, self.wr_step):
            if len(steps) != len(self.reps):
                raise ValueError(
                    f"AGU step list length {len(steps)} != reps {len(self.reps)}"
                )

    @property
    def n_commands(self) -> int:
        return math.prod(self.reps) if self.reps else 1

    @property
    def busy_cycles_per_command(self) -> int:
        return self.template.busy_cycles

    @property
    def busy_cycles(self) -> int:
        return self.n_commands * self.template.busy_cycles

    @property
    def is_staging(self) -> bool:
        return self.template.opcode in ("copy", "memset")

    def commands(self) -> Iterator[NtxCommand]:
        """The concrete command stream the driver issues, in program order."""
        t = self.template
        if not self.reps:
            yield t
            return
        idx = [0] * len(self.reps)
        n = self.n_commands
        for _ in range(n):
            d0 = sum(i * s for i, s in zip(idx, self.rd0_step))
            d1 = sum(i * s for i, s in zip(idx, self.rd1_step))
            dw = sum(i * s for i, s in zip(idx, self.wr_step))
            yield NtxCommand(
                loops=t.loops,
                opcode=t.opcode,
                agu_rd0=_rebased(t.agu_rd0, d0),
                agu_rd1=_rebased(t.agu_rd1, d1),
                agu_wr=_rebased(t.agu_wr, dw),
                init_level=t.init_level,
                store_level=t.store_level,
                init_value=t.init_value,
            )
            for lvl in range(len(self.reps)):  # odometer, innermost first
                idx[lvl] += 1
                if idx[lvl] < self.reps[lvl]:
                    break
                idx[lvl] = 0


@dataclass
class NtxProgram:
    """An ordered command stream + its memory map: one lowered layer pass."""

    name: str
    blocks: list[CommandBlock]
    regions: dict[str, TensorRegion]
    design: DesignPoint = NTX_DESIGN
    meta: dict[str, Any] = field(default_factory=dict)

    # -- memory map ---------------------------------------------------------

    @property
    def memory_words(self) -> int:
        return max((r.end for r in self.regions.values()), default=0)

    def region(self, name: str) -> TensorRegion:
        return self.regions[name]

    def regions_of_kind(self, kind: str) -> list[TensorRegion]:
        return [r for r in self.regions.values() if r.kind == kind]

    # -- offload accounting (the Table 2 view) ------------------------------

    @property
    def n_offloads(self) -> int:
        """Compute commands the driver issues (staging blits excluded)."""
        return sum(b.n_commands for b in self.blocks if not b.is_staging)

    @property
    def n_staging_offloads(self) -> int:
        return sum(b.n_commands for b in self.blocks if b.is_staging)

    @property
    def n_commands(self) -> int:
        return sum(b.n_commands for b in self.blocks)

    @property
    def busy_cycles(self) -> int:
        """Total datapath cycles (one loop iteration per cycle, §2.3)."""
        return sum(b.busy_cycles for b in self.blocks)

    @property
    def busy_cycles_per_offload(self) -> int:
        """Cycles of the dominant (first non-staging) command template."""
        for b in self.blocks:
            if not b.is_staging:
                return b.busy_cycles_per_command
        return 0

    @property
    def dma_bytes(self) -> float:
        return sum(
            (b.dma_bytes_in + b.dma_bytes_out) * b.n_commands for b in self.blocks
        )

    # -- command stream -----------------------------------------------------

    def commands(self) -> Iterator[NtxCommand]:
        for b in self.blocks:
            yield from b.commands()

    def command_dma_bytes(self) -> Iterator[float]:
        """Per-command input DMA bytes, aligned with :meth:`commands`."""
        for b in self.blocks:
            for _ in range(b.n_commands):
                yield b.dma_bytes_in

    def block_segments(self) -> Iterator[tuple[NtxCommand, int, float]]:
        """(template, n_commands, dma_bytes_in) per block, in program order.

        Every command a block replicates shares the template's loop bounds
        and AGU population (only bases are rebased) and the block's
        per-command DMA bytes, so this stream describes the whole program to
        the timing model without materializing commands — the contract the
        block-replicated fast path of
        :func:`repro.runtime.cmdqueue.simulate_offload_blocks` builds on.
        """
        for b in self.blocks:
            yield b.template, b.n_commands, b.dma_bytes_in

    def summary(self) -> dict[str, Any]:
        return {
            "name": self.name,
            "design": self.design.name,
            "n_offloads": self.n_offloads,
            "n_staging_offloads": self.n_staging_offloads,
            "busy_cycles": self.busy_cycles,
            "busy_cycles_per_offload": self.busy_cycles_per_offload,
            "dma_bytes": self.dma_bytes,
            "memory_words": self.memory_words,
        }


#: Sentinel "lives until the end of the program" step index.
LIVE_END = 1 << 62


class LivenessAllocator:
    """Liveness-based TCDM region allocator (interval coloring).

    Regions carry a live interval ``[start, end]`` in *step* units (the graph
    compiler's (node, pass) schedule positions). Allocation walks the steps
    in order: space whose region died strictly before the new region's birth
    is recycled first-fit; only when no gap fits does the watermark grow.
    ``peak_tcdm_bytes`` is therefore the true high-water mark of the laid-out
    program — two regions share addresses only when their live intervals are
    disjoint.

    When a ``budget_words`` is given (the design point's 64 KiB x clusters
    TCDM) and neither a gap nor the remaining headroom fits, the region is
    *spilled*: placed in the DRAM segment that starts at ``budget_words``,
    recorded in :attr:`spilled` so the graph compiler can stage the extra
    DMA traffic in-band. The flat-memory executors are oblivious — a spilled
    region is just an address window above the TCDM watermark — which keeps
    execution bit-identical while the timing model charges for the traffic.

    With ``budget_words=None`` and whole-program lifetimes this degenerates
    to the old back-to-back bump layout (see :class:`RegionAllocator`).
    """

    def __init__(self, budget_words: int | None = None):
        self.budget_words = budget_words
        self.regions: dict[str, TensorRegion] = {}
        self.intervals: dict[str, tuple[int, int]] = {}
        self.spilled: list[str] = []
        self._live: list[list] = []  # [base, size, end] of live TCDM regions
        self._gaps: list[list] = []  # [base, size], sorted by base
        self._top = 0  # TCDM watermark (words)
        self._peak = 0  # historical max watermark
        self._dram_top = budget_words  # spill segment grows from the budget

    # -- bookkeeping --------------------------------------------------------

    @property
    def peak_tcdm_words(self) -> int:
        return self._peak

    @property
    def peak_tcdm_bytes(self) -> int:
        return self._peak * ELEM_BYTES

    def _expire(self, now: int) -> None:
        keep = []
        for rec in self._live:
            if rec[2] < now:
                self._gaps.append([rec[0], rec[1]])
            else:
                keep.append(rec)
        self._live = keep
        # coalesce adjacent gaps so first-fit sees maximal holes
        self._gaps.sort()
        merged: list[list] = []
        for base, size in self._gaps:
            if merged and merged[-1][0] + merged[-1][1] == base:
                merged[-1][1] += size
            else:
                merged.append([base, size])
        # a gap touching the watermark is headroom, not a hole
        if merged and merged[-1][0] + merged[-1][1] == self._top:
            self._top = merged.pop()[0]
        self._gaps = merged

    def _place(self, size: int) -> tuple[int, bool]:
        """First-fit base address for ``size`` words; True when spilled."""
        for gap in self._gaps:
            if gap[1] >= size:
                base = gap[0]
                gap[0] += size
                gap[1] -= size
                if gap[1] == 0:
                    self._gaps.remove(gap)
                return base, False
        if self.budget_words is None or self._top + size <= self.budget_words:
            base = self._top
            self._top += size
            self._peak = max(self._peak, self._top)
            return base, False
        base = self._dram_top
        self._dram_top += size
        return base, True

    # -- the public surface -------------------------------------------------

    def alloc(
        self,
        name: str,
        shape: tuple[int, ...],
        kind: str,
        *,
        start: int = 0,
        end: int = LIVE_END,
    ) -> TensorRegion:
        if name in self.regions:
            raise ValueError(f"region {name!r} already allocated")
        size = math.prod(shape)
        self._expire(start)
        base, spilled = self._place(size)
        r = TensorRegion(name, base, tuple(shape), kind)
        self.regions[name] = r
        self.intervals[name] = (start, end)
        if spilled:
            self.spilled.append(name)
        else:
            self._live.append([base, size, end])
        return r

    def alias(
        self, name: str, of: str, shape: tuple[int, ...], kind: str, *, end: int = LIVE_END
    ) -> TensorRegion:
        """A zero-copy view of an existing region (flatten nodes): same base,
        new shape, and the underlying storage lives at least until ``end``."""
        if name in self.regions:
            raise ValueError(f"region {name!r} already allocated")
        src = self.regions[of]
        if math.prod(shape) != src.size:
            raise ValueError(
                f"alias {name!r} size {math.prod(shape)} != {of!r} size {src.size}"
            )
        r = TensorRegion(name, src.base, tuple(shape), kind)
        self.regions[name] = r
        s0, e0 = self.intervals[of]
        self.intervals[of] = (s0, max(e0, end))
        self.intervals[name] = (s0, end)
        for rec in self._live:
            if rec[0] == src.base and rec[1] == src.size:
                rec[2] = max(rec[2], end)
                break
        return r


class RegionAllocator:
    """Bump allocator laying regions out back to back in TCDM.

    Per-layer lowering keeps the historical behaviour — whole-program
    lifetimes over an unbounded budget make :class:`LivenessAllocator`
    degenerate to exactly the old bump layout.
    """

    def __init__(self):
        self._liv = LivenessAllocator(budget_words=None)

    @property
    def regions(self) -> dict[str, TensorRegion]:
        return self._liv.regions

    def alloc(self, name: str, shape: tuple[int, ...], kind: str) -> TensorRegion:
        return self._liv.alloc(name, shape, kind)

"""The NtxProgram IR: what a whole layer pass looks like to the hardware.

The paper's Table 2 observation is that one training-layer pass is a *driver
loop around one command template*: the RISC-V core re-issues the same 5-deep
loop nest with rebased AGU base addresses. This module keeps that structure
first-class instead of materializing every command eagerly:

  * :class:`TensorRegion` — a named, shaped window of the flat TCDM address
    space (inputs, parameters, outputs, staging scratch).
  * :class:`CommandBlock` — one command *template* plus the driver-side
    replication loops (``reps``) and the per-level AGU base steps. A block
    with ``reps=(64,)`` is Table 2's "64 offloads" row; iterating
    :meth:`CommandBlock.commands` reproduces the exact command stream the
    driver would issue. Offload/cycle counts are O(1) properties — no
    materialization needed for the 802 816-command NS rows.
  * :class:`NtxProgram` — ordered blocks + regions + the layer spec they were
    lowered from. This is the single representation the reference
    interpreter, the event-driven timing model, and the Pallas backend all
    consume (see :mod:`repro.lower.executors`).

Staging (zero-padding, halo blits) is expressed *in-band* as ``memset`` /
``copy`` command blocks, so executing a program needs no out-of-band numpy
padding logic: the DMA/offload stream is the whole story.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Iterator

from repro.core.ntx import MAX_LOOPS, Agu, NtxCommand

ELEM_BYTES = 4  # the NTX datapath streams fp32 words

# The two design points the paper compares (Table 2). ``hw_loops`` is the
# depth of the hardware loop nest, ``n_agus`` the address generators, and
# ``autonomous_writeback`` whether a write AGU exists — without one (NS) at
# most the reduction dims can be offloaded: every output pixel is its own
# command (§2.5(iii)).


@dataclass(frozen=True)
class DesignPoint:
    name: str
    hw_loops: int
    n_agus: int
    autonomous_writeback: bool


NS_DESIGN = DesignPoint("ns", hw_loops=3, n_agus=2, autonomous_writeback=False)
NTX_DESIGN = DesignPoint("ntx", hw_loops=5, n_agus=3, autonomous_writeback=True)


@dataclass(frozen=True)
class TensorRegion:
    """A named window of the flat TCDM address space (element units)."""

    name: str
    base: int
    shape: tuple[int, ...]
    kind: str  # "input" | "param" | "output" | "scratch"

    @property
    def size(self) -> int:
        return math.prod(self.shape)

    @property
    def end(self) -> int:
        return self.base + self.size

    @property
    def bytes(self) -> int:
        return self.size * ELEM_BYTES


def _rebased(agu: Agu | None, offset: int) -> Agu | None:
    if agu is None or offset == 0:
        return agu
    return Agu(agu.base + offset, agu.strides)


@dataclass(frozen=True)
class CommandBlock:
    """One command template + the driver loop that re-issues it.

    ``reps`` are the driver-side loop bounds (innermost first, may be empty);
    ``rd0_step``/``rd1_step``/``wr_step`` give, per rep level, how far each
    AGU's base moves between consecutive issues — exactly the software loop
    of Table 2 made explicit.
    """

    template: NtxCommand
    reps: tuple[int, ...] = ()
    rd0_step: tuple[int, ...] = ()
    rd1_step: tuple[int, ...] = ()
    wr_step: tuple[int, ...] = ()
    tag: str = ""
    reads: tuple[str, ...] = ()  # region names streamed in
    writes: tuple[str, ...] = ()  # region names streamed out
    dma_bytes_in: float = 0.0  # per command (block read traffic / n_commands)
    dma_bytes_out: float = 0.0
    tile: Any = None  # tiling-plan metadata (core/tiling.py), if any

    def __post_init__(self):
        for steps in (self.rd0_step, self.rd1_step, self.wr_step):
            if len(steps) != len(self.reps):
                raise ValueError(
                    f"AGU step list length {len(steps)} != reps {len(self.reps)}"
                )

    @property
    def n_commands(self) -> int:
        return math.prod(self.reps) if self.reps else 1

    @property
    def busy_cycles_per_command(self) -> int:
        return self.template.busy_cycles

    @property
    def busy_cycles(self) -> int:
        return self.n_commands * self.template.busy_cycles

    @property
    def is_staging(self) -> bool:
        return self.template.opcode in ("copy", "memset")

    def commands(self) -> Iterator[NtxCommand]:
        """The concrete command stream the driver issues, in program order."""
        t = self.template
        if not self.reps:
            yield t
            return
        idx = [0] * len(self.reps)
        n = self.n_commands
        for _ in range(n):
            d0 = sum(i * s for i, s in zip(idx, self.rd0_step))
            d1 = sum(i * s for i, s in zip(idx, self.rd1_step))
            dw = sum(i * s for i, s in zip(idx, self.wr_step))
            yield NtxCommand(
                loops=t.loops,
                opcode=t.opcode,
                agu_rd0=_rebased(t.agu_rd0, d0),
                agu_rd1=_rebased(t.agu_rd1, d1),
                agu_wr=_rebased(t.agu_wr, dw),
                init_level=t.init_level,
                store_level=t.store_level,
                init_value=t.init_value,
            )
            for lvl in range(len(self.reps)):  # odometer, innermost first
                idx[lvl] += 1
                if idx[lvl] < self.reps[lvl]:
                    break
                idx[lvl] = 0


@dataclass
class NtxProgram:
    """An ordered command stream + its memory map: one lowered layer pass."""

    name: str
    blocks: list[CommandBlock]
    regions: dict[str, TensorRegion]
    design: DesignPoint = NTX_DESIGN
    meta: dict[str, Any] = field(default_factory=dict)

    # -- memory map ---------------------------------------------------------

    @property
    def memory_words(self) -> int:
        return max((r.end for r in self.regions.values()), default=0)

    def region(self, name: str) -> TensorRegion:
        return self.regions[name]

    def regions_of_kind(self, kind: str) -> list[TensorRegion]:
        return [r for r in self.regions.values() if r.kind == kind]

    # -- offload accounting (the Table 2 view) ------------------------------

    @property
    def n_offloads(self) -> int:
        """Compute commands the driver issues (staging blits excluded)."""
        return sum(b.n_commands for b in self.blocks if not b.is_staging)

    @property
    def n_staging_offloads(self) -> int:
        return sum(b.n_commands for b in self.blocks if b.is_staging)

    @property
    def n_commands(self) -> int:
        return sum(b.n_commands for b in self.blocks)

    @property
    def busy_cycles(self) -> int:
        """Total datapath cycles (one loop iteration per cycle, §2.3)."""
        return sum(b.busy_cycles for b in self.blocks)

    @property
    def busy_cycles_per_offload(self) -> int:
        """Cycles of the dominant (first non-staging) command template."""
        for b in self.blocks:
            if not b.is_staging:
                return b.busy_cycles_per_command
        return 0

    @property
    def dma_bytes(self) -> float:
        return sum(
            (b.dma_bytes_in + b.dma_bytes_out) * b.n_commands for b in self.blocks
        )

    # -- command stream -----------------------------------------------------

    def commands(self) -> Iterator[NtxCommand]:
        for b in self.blocks:
            yield from b.commands()

    def command_dma_bytes(self) -> Iterator[float]:
        """Per-command input DMA bytes, aligned with :meth:`commands`."""
        for b in self.blocks:
            for _ in range(b.n_commands):
                yield b.dma_bytes_in

    def block_segments(self) -> Iterator[tuple[NtxCommand, int, float]]:
        """(template, n_commands, dma_bytes_in) per block, in program order.

        Every command a block replicates shares the template's loop bounds
        and AGU population (only bases are rebased) and the block's
        per-command DMA bytes, so this stream describes the whole program to
        the timing model without materializing commands — the contract the
        block-replicated fast path of
        :func:`repro.runtime.cmdqueue.simulate_offload_blocks` builds on.
        """
        for b in self.blocks:
            yield b.template, b.n_commands, b.dma_bytes_in

    def summary(self) -> dict[str, Any]:
        return {
            "name": self.name,
            "design": self.design.name,
            "n_offloads": self.n_offloads,
            "n_staging_offloads": self.n_staging_offloads,
            "busy_cycles": self.busy_cycles,
            "busy_cycles_per_offload": self.busy_cycles_per_offload,
            "dma_bytes": self.dma_bytes,
            "memory_words": self.memory_words,
        }


class RegionAllocator:
    """Bump allocator laying regions out back to back in TCDM."""

    def __init__(self):
        self.regions: dict[str, TensorRegion] = {}
        self._top = 0

    def alloc(self, name: str, shape: tuple[int, ...], kind: str) -> TensorRegion:
        if name in self.regions:
            raise ValueError(f"region {name!r} already allocated")
        r = TensorRegion(name, self._top, tuple(shape), kind)
        self.regions[name] = r
        self._top = r.end
        return r

"""Lowering rules: layer specs -> :class:`~repro.lower.ir.NtxProgram`.

One rule per (layer type, pass). Every rule goes through the same loop-nest
builder: order the iteration dims innermost-first as

    reduction dims  ++  output dims                       (paper §2.5)

give each AGU its per-dim element stride (eq. 1), and split the nest at the
design point's hardware-loop budget — the inner dims become the command
template, the outer dims become the driver's replication loops (Table 2's
offload counts fall out of this split; :func:`repro.core.ntx.offload_count`
is the closed form of the same arithmetic and the benchmarks assert the two
agree). A design without an autonomous write-back AGU (NS) can offload at
most the reduction dims: every output pixel is its own command.

The conv backward rules are the paper's §3.2 decomposition realized at the
command level: the weight gradient is one dense correlation block; the input
gradient is s*s phase blocks, each a dense correlation of a zero-padded
``dy`` with the (spatially flipped) filter-tap subset of that phase — the
flip and the subset selection are pure AGU striding (negative strides), and
the zero padding is staged in-band with ``memset``/``copy`` commands.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

from repro.core.ntx import MAX_LOOPS, Agu, NtxCommand
from repro.core.tiling import plan_matmul_tiles, plan_stencil_tiles
from repro.lower.ir import (
    ELEM_BYTES,
    CommandBlock,
    DesignPoint,
    NTX_DESIGN,
    NtxProgram,
    RegionAllocator,
    TensorRegion,
)

PASSES = ("fwd", "dw", "dx")


# ---------------------------------------------------------------------------
# Layer specs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MatmulSpec:
    """C[m,n] = A[m,k] @ B[k,n] (row major). dw = A^T dY, dx = dY B^T."""

    m: int
    n: int
    k: int


@dataclass(frozen=True)
class Conv2dSpec:
    """One conv layer per image: NHWC x HWIO -> NHWC with N=1 (Table 2)."""

    in_h: int
    in_w: int
    cin: int
    kh: int
    kw: int
    cout: int
    stride: int = 1
    padding: int = 0

    @property
    def out_h(self) -> int:
        return (self.in_h + 2 * self.padding - self.kh) // self.stride + 1

    @property
    def out_w(self) -> int:
        return (self.in_w + 2 * self.padding - self.kw) // self.stride + 1

    def conv_shape(self):
        """The paper's Table 2 view of this layer (offload_count input)."""
        from repro.core import ntx

        return ntx.ConvShape(
            kw=self.kw, kh=self.kh, cin=self.cin,
            out_w=self.out_w, out_h=self.out_h, cout=self.cout,
        )


@dataclass(frozen=True)
class MaxPool2dSpec:
    in_h: int
    in_w: int
    c: int
    window: int = 2
    stride: int = 2

    @property
    def out_h(self) -> int:
        return (self.in_h - self.window) // self.stride + 1

    @property
    def out_w(self) -> int:
        return (self.in_w - self.window) // self.stride + 1


@dataclass(frozen=True)
class ReluSpec:
    shape: tuple[int, ...]

    @property
    def size(self) -> int:
        return math.prod(self.shape)


# ---------------------------------------------------------------------------
# The shared loop-nest splitter
# ---------------------------------------------------------------------------


def _pad5(xs: tuple[int, ...], fill: int) -> tuple[int, ...]:
    return tuple(xs) + (fill,) * (MAX_LOOPS - len(xs))


def _nest_block(
    sizes: tuple[int, ...],
    n_red: int,
    rd0: tuple[int, tuple[int, ...]],
    rd1: tuple[int, tuple[int, ...]] | None,
    wr: tuple[int, tuple[int, ...]],
    design: DesignPoint,
    *,
    opcode: str = "mac",
    tag: str,
    reads: tuple[TensorRegion, ...],
    writes: tuple[TensorRegion, ...],
    init_value: float = 0.0,
    tile=None,
) -> CommandBlock:
    """Split an iteration nest at the design point's hardware-loop budget.

    ``sizes`` is the full nest innermost-first (reduction dims leading);
    ``rd0``/``rd1``/``wr`` are (base, per-dim element strides) over the same
    ordering. Dims beyond the budget become driver replication loops.
    """
    usable = min(design.hw_loops, len(sizes))
    if not design.autonomous_writeback:
        usable = min(usable, n_red)
    if usable < n_red:
        raise NotImplementedError(
            f"{tag}: {n_red} reduction dims exceed the {design.name} design's "
            f"{usable} offloadable loops — the driver would have to accumulate"
        )

    def split(agu):
        if agu is None:
            return None, ()
        base, strides = agu
        hw = Agu(base, _pad5(tuple(strides[:usable]), 0))
        return hw, tuple(strides[usable:])

    a0, s0 = split(rd0)
    a1, s1 = split(rd1)
    aw, sw = split(wr)
    template = NtxCommand(
        loops=_pad5(tuple(sizes[:usable]), 1),
        opcode=opcode,
        agu_rd0=a0,
        agu_rd1=a1,
        agu_wr=aw,
        init_level=n_red,
        store_level=n_red,
        init_value=init_value,
    )
    reps = tuple(sizes[usable:])
    n_cmds = math.prod(reps) if reps else 1
    bytes_in = sum(r.bytes for r in reads) / n_cmds
    bytes_out = sum(r.bytes for r in writes) / n_cmds
    return CommandBlock(
        template=template,
        reps=reps,
        rd0_step=s0,
        rd1_step=s1 if rd1 is not None else (0,) * len(reps),
        wr_step=sw,
        tag=tag,
        reads=tuple(r.name for r in reads),
        writes=tuple(r.name for r in writes),
        dma_bytes_in=bytes_in,
        dma_bytes_out=bytes_out,
        tile=tile,
    )


# ---------------------------------------------------------------------------
# In-band staging blits (zero padding as memset + copy commands)
# ---------------------------------------------------------------------------


def _memset_block(dst: TensorRegion, value: float = 0.0) -> CommandBlock:
    return CommandBlock(
        template=NtxCommand(
            loops=(dst.size, 1, 1, 1, 1),
            opcode="memset",
            agu_rd0=Agu(dst.base, (0,) * MAX_LOOPS),
            agu_wr=Agu(dst.base, _pad5((1,), 0)),
            init_level=0,
            store_level=0,
            init_value=value,
        ),
        tag=f"memset:{dst.name}",
        writes=(dst.name,),
        dma_bytes_out=float(dst.bytes),
    )


def _copy_block(
    src: TensorRegion,
    dst: TensorRegion,
    *,
    rows: int,
    row_elems: int,
    src_row_stride: int,
    dst_row_stride: int,
    src_off: int = 0,
    dst_off: int = 0,
    tag: str = "",
) -> CommandBlock:
    return CommandBlock(
        template=NtxCommand(
            loops=(row_elems, rows, 1, 1, 1),
            opcode="copy",
            agu_rd0=Agu(src.base + src_off, _pad5((1, src_row_stride), 0)),
            agu_wr=Agu(dst.base + dst_off, _pad5((1, dst_row_stride), 0)),
            init_level=0,
            store_level=0,
        ),
        tag=tag or f"copy:{src.name}->{dst.name}",
        reads=(src.name,),
        writes=(dst.name,),
        dma_bytes_in=float(rows * row_elems * ELEM_BYTES),
        dma_bytes_out=float(rows * row_elems * ELEM_BYTES),
    )


def _padded_plane(
    alloc: RegionAllocator,
    src: TensorRegion,
    *,
    h: int,
    w: int,
    c: int,
    pad: int,
    name: str,
) -> tuple[TensorRegion, list[CommandBlock]]:
    """Zero-padded copy of an (h, w, c) plane, staged with memset + copy."""
    if pad == 0:
        return src, []
    hp, wp = h + 2 * pad, w + 2 * pad
    dst = alloc.alloc(name, (hp, wp, c), "scratch")
    blocks = [
        _memset_block(dst),
        _copy_block(
            src,
            dst,
            rows=h,
            row_elems=w * c,
            src_row_stride=w * c,
            dst_row_stride=wp * c,
            dst_off=(pad * wp + pad) * c,
        ),
    ]
    return dst, blocks


# ---------------------------------------------------------------------------
# Matmul rules (fwd / dw / dx)
# ---------------------------------------------------------------------------


def matmul_nest(
    m: int, n: int, k: int, pass_: str, a_base: int, b_base: int, c_base: int
):
    """(sizes, n_red, rd0, rd1, wr) for one matmul pass at explicit bases.

    ``a``/``b``/``c`` are the *roles* of the three operands for the pass:
    fwd reads (A, B) writes C; dw reads (A, dY) writes dW; dx reads (dY, B)
    writes dX. Transposes are pure AGU striding — no data movement.
    """
    if pass_ == "fwd":
        # C[i2,i1] += A[i2,i0] * B[i0,i1];  dims (k, n, m)
        return (
            (k, n, m), 1,
            (a_base, (1, 0, k)),
            (b_base, (n, 1, 0)),
            (c_base, (0, 1, n)),
        )
    if pass_ == "dw":
        # dW[i2,i1] += A[i0,i2] * dY[i0,i1];  dims (m, n, k)
        return (
            (m, n, k), 1,
            (a_base, (k, 0, 1)),
            (b_base, (n, 1, 0)),
            (c_base, (0, 1, n)),
        )
    if pass_ == "dx":
        # dX[i2,i1] += dY[i2,i0] * B[i1,i0];  dims (n, k, m)
        return (
            (n, k, m), 1,
            (a_base, (1, 0, n)),
            (b_base, (1, n, 0)),
            (c_base, (0, 1, k)),
        )
    raise ValueError(f"unknown matmul pass {pass_!r}; expected one of {PASSES}")


def matmul_template(
    m: int, n: int, k: int, a_base: int, b_base: int, c_base: int
) -> NtxCommand:
    """The single-command NTX matmul at explicit TCDM bases (fwd pass).

    This is what :func:`repro.core.ntx.matmul_command` delegates to.
    """
    sizes, n_red, rd0, rd1, wr = matmul_nest(m, n, k, "fwd", a_base, b_base, c_base)
    return NtxCommand(
        loops=_pad5(sizes, 1),
        opcode="mac",
        agu_rd0=Agu(rd0[0], _pad5(rd0[1], 0)),
        agu_rd1=Agu(rd1[0], _pad5(rd1[1], 0)),
        agu_wr=Agu(wr[0], _pad5(wr[1], 0)),
        init_level=n_red,
        store_level=n_red,
    )


def _lower_matmul(spec: MatmulSpec, pass_: str, design: DesignPoint) -> NtxProgram:
    m, n, k = spec.m, spec.n, spec.k
    alloc = RegionAllocator()
    if pass_ == "fwd":
        ra = alloc.alloc("a", (m, k), "input")
        rb = alloc.alloc("b", (k, n), "param")
        rc = alloc.alloc("c", (m, n), "output")
    elif pass_ == "dw":
        ra = alloc.alloc("a", (m, k), "input")
        rb = alloc.alloc("dy", (m, n), "input")
        rc = alloc.alloc("dw", (k, n), "output")
    elif pass_ == "dx":
        ra = alloc.alloc("dy", (m, n), "input")
        rb = alloc.alloc("b", (k, n), "param")
        rc = alloc.alloc("dx", (m, k), "output")
    else:
        raise ValueError(f"unknown matmul pass {pass_!r}; expected one of {PASSES}")
    sizes, n_red, rd0, rd1, wr = matmul_nest(m, n, k, pass_, ra.base, rb.base, rc.base)
    plan = plan_matmul_tiles(m, n, k, in_dtype_bytes=ELEM_BYTES)
    block = _nest_block(
        sizes, n_red, rd0, rd1, wr, design,
        tag=f"matmul:{pass_}", reads=(ra, rb), writes=(rc,), tile=plan,
    )
    return NtxProgram(
        name=f"matmul{m}x{n}x{k}:{pass_}",
        blocks=[block],
        regions=alloc.regions,
        design=design,
        meta={"spec": spec, "pass": pass_, "plan": plan},
    )


# ---------------------------------------------------------------------------
# Conv2d rules (fwd / dw / dx)
# ---------------------------------------------------------------------------


def _conv_plan(spec: Conv2dSpec):
    return plan_stencil_tiles(
        spec.out_h, spec.out_w, spec.cin, spec.cout, spec.kh, spec.kw,
        dtype_bytes=ELEM_BYTES,
    )


def _lower_conv_fwd(spec: Conv2dSpec, design: DesignPoint) -> NtxProgram:
    s, p = spec.stride, spec.padding
    oh, ow = spec.out_h, spec.out_w
    alloc = RegionAllocator()
    rx = alloc.alloc("x", (spec.in_h, spec.in_w, spec.cin), "input")
    rw = alloc.alloc("w", (spec.kh, spec.kw, spec.cin, spec.cout), "param")
    ry = alloc.alloc("y", (oh, ow, spec.cout), "output")
    xp, staging = _padded_plane(
        alloc, rx, h=spec.in_h, w=spec.in_w, c=spec.cin, pad=p, name="x_pad"
    )
    iw = spec.in_w + 2 * p  # padded row pitch
    cin, kw, kh, cout = spec.cin, spec.kw, spec.kh, spec.cout
    block = _nest_block(
        (cin, kw, kh, ow, oh, cout), 3,
        (xp.base, (1, cin, iw * cin, s * cin, s * iw * cin, 0)),
        (rw.base, (cout, cin * cout, kw * cin * cout, 0, 0, 1)),
        (ry.base, (0, 0, 0, cout, ow * cout, 1)),
        design,
        tag="conv2d:fwd", reads=(xp, rw), writes=(ry,), tile=_conv_plan(spec),
    )
    return NtxProgram(
        name=f"conv{spec.kh}x{spec.kw}x{cin}->{oh}x{ow}x{cout}:fwd",
        blocks=staging + [block],
        regions=alloc.regions,
        design=design,
        meta={"spec": spec, "pass": "fwd", "plan": block.tile},
    )


def conv2d_fwd_template(
    in_h: int, in_w: int, cin: int, kh: int, kw: int, cout: int,
    x_base: int, w_base: int, y_base: int, stride: int = 1,
) -> NtxCommand:
    """The NTX conv-forward command template at explicit TCDM bases.

    With ``cout=1`` this is exactly the single-output-channel command of
    :func:`repro.core.ntx.conv2d_command` (HWI-contiguous weights, one output
    plane) — the thin wrapper there delegates here.
    """
    oh = (in_h - kh) // stride + 1
    ow = (in_w - kw) // stride + 1
    return NtxCommand(
        loops=(cin, kw, kh, ow, oh),
        opcode="mac",
        agu_rd0=Agu(x_base, (1, cin, in_w * cin, stride * cin, stride * in_w * cin)),
        agu_rd1=Agu(w_base, (cout, cin * cout, kw * cin * cout, 0, 0)),
        agu_wr=Agu(y_base, (0, 0, 0, cout, ow * cout)),
        init_level=3,
        store_level=3,
    )


def _lower_conv_dw(spec: Conv2dSpec, design: DesignPoint) -> NtxProgram:
    s, p = spec.stride, spec.padding
    oh, ow = spec.out_h, spec.out_w
    alloc = RegionAllocator()
    rx = alloc.alloc("x", (spec.in_h, spec.in_w, spec.cin), "input")
    rdy = alloc.alloc("dy", (oh, ow, spec.cout), "input")
    rdw = alloc.alloc("dw", (spec.kh, spec.kw, spec.cin, spec.cout), "output")
    xp, staging = _padded_plane(
        alloc, rx, h=spec.in_h, w=spec.in_w, c=spec.cin, pad=p, name="x_pad"
    )
    iw = spec.in_w + 2 * p
    cin, kw, kh, cout = spec.cin, spec.kw, spec.kh, spec.cout
    # dW[u,v,ci,co] += x_pad[s*ohi+u, s*owi+v, ci] * dy[ohi, owi, co]
    # dims innermost-first: (owi, ohi | ci, v, u, co)
    block = _nest_block(
        (ow, oh, cin, kw, kh, cout), 2,
        (xp.base, (s * cin, s * iw * cin, 1, cin, iw * cin, 0)),
        (rdy.base, (cout, ow * cout, 0, 0, 0, 1)),
        (rdw.base, (0, 0, cout, cin * cout, kw * cin * cout, 1)),
        design,
        tag="conv2d:dw", reads=(xp, rdy), writes=(rdw,), tile=_conv_plan(spec),
    )
    return NtxProgram(
        name=f"conv{kh}x{kw}x{cin}->{oh}x{ow}x{cout}:dw",
        blocks=staging + [block],
        regions=alloc.regions,
        design=design,
        meta={"spec": spec, "pass": "dw", "plan": block.tile},
    )


def _lower_conv_dx(spec: Conv2dSpec, design: DesignPoint) -> NtxProgram:
    """§3.2 / Fig. 6: s*s dense phase convolutions over zero-padded dy.

    Phase (a, b) collects the input pixels (i, j) with (i+p) % s == a etc.;
    only the filter taps congruent to the phase ever touch them, so each
    phase is a *dense* stride-1 correlation — constant MACs per pixel, one
    command block per phase (driver reps over cin). The tap subset and the
    spatial flip are encoded as negative AGU strides into the original
    weights; the zero padding of dy is staged in-band (memset + copy).
    """
    s, p = spec.stride, spec.padding
    oh, ow = spec.out_h, spec.out_w
    xh, xw = spec.in_h, spec.in_w
    cin, kw, kh, cout = spec.cin, spec.kw, spec.kh, spec.cout
    alloc = RegionAllocator()
    rdy = alloc.alloc("dy", (oh, ow, cout), "input")
    rw = alloc.alloc("w", (kh, kw, cin, cout), "param")
    rdx = alloc.alloc("dx", (xh, xw, cin), "output")

    blocks: list[CommandBlock] = []
    n_phases = 0
    for a in range(s):
        ta = len(range(a, kh, s))
        if ta == 0:
            continue
        for b in range(s):
            tb = len(range(b, kw, s))
            if tb == 0:
                continue
            i0 = (a - p) % s
            j0 = (b - p) % s
            na = len(range(i0, xh, s))
            nb = len(range(j0, xw, s))
            if na == 0 or nb == 0:
                continue
            ii0 = (i0 + p - a) // s
            jj0 = (j0 + p - b) // s
            # dy staged zero-padded: taps reach ta-1 rows above the first dy
            # row and the last phase pixel reaches ii0 + na - 1 + ta - 1.
            pt, pl = ta - 1, tb - 1
            hp = max(pt + oh, ii0 + na + ta - 1)
            wp = max(pl + ow, jj0 + nb + tb - 1)
            if (hp, wp) == (oh, ow):
                dyp, staging = rdy, []
            else:
                dyp = alloc.alloc(f"dy_pad{a}{b}", (hp, wp, cout), "scratch")
                staging = [
                    _memset_block(dyp),
                    _copy_block(
                        rdy, dyp,
                        rows=oh, row_elems=ow * cout,
                        src_row_stride=ow * cout, dst_row_stride=wp * cout,
                        dst_off=(pt * wp + pl) * cout,
                        tag=f"copy:dy->dy_pad{a}{b}",
                    ),
                ]
            blocks += staging
            # dx[i0+s*qi, j0+s*qj, ci] +=
            #   dy_pad[ii0+qi+ti, jj0+qj+tj, co] * w[a+s*(ta-1-ti), b+s*(tb-1-tj), ci, co]
            # dims innermost-first: (co, tj, ti | qj, qi, ci)
            u0 = a + s * (ta - 1)
            v0 = b + s * (tb - 1)
            blocks.append(
                _nest_block(
                    (cout, tb, ta, nb, na, cin), 3,
                    (
                        # dy_pad row r holds dy row r - pt; phase pixel qi
                        # reads rows (ii0 + qi) + ti of the padded plane.
                        dyp.base + (ii0 * wp + jj0) * cout,
                        (1, cout, wp * cout, cout, wp * cout, 0),
                    ),
                    (
                        rw.base + (u0 * kw + v0) * cin * cout,
                        (1, -s * cin * cout, -s * kw * cin * cout, 0, 0, cout),
                    ),
                    (
                        rdx.base + (i0 * xw + j0) * cin,
                        (0, 0, 0, s * cin, s * xw * cin, 1),
                    ),
                    design,
                    tag=f"conv2d:dx[{a},{b}]",
                    reads=(dyp, rw), writes=(rdx,), tile=_conv_plan(spec),
                )
            )
            n_phases += 1
    return NtxProgram(
        name=f"conv{kh}x{kw}x{cin}->{oh}x{ow}x{cout}:dx",
        blocks=blocks,
        regions=alloc.regions,
        design=design,
        meta={"spec": spec, "pass": "dx", "n_phases": n_phases,
              "plan": _conv_plan(spec)},
    )


# ---------------------------------------------------------------------------
# Pooling / ReLU rules
# ---------------------------------------------------------------------------


def _lower_maxpool(spec: MaxPool2dSpec, design: DesignPoint) -> NtxProgram:
    s, ww = spec.stride, spec.window
    oh, ow, c = spec.out_h, spec.out_w, spec.c
    iw = spec.in_w
    alloc = RegionAllocator()
    rx = alloc.alloc("x", (spec.in_h, spec.in_w, c), "input")
    ry = alloc.alloc("y", (oh, ow, c), "output")
    # y[i3,i2,i4] = max over (i1,i0) of x[s*i3+i1, s*i2+i0, i4]
    block = _nest_block(
        (ww, ww, ow, oh, c), 2,
        (rx.base, (c, iw * c, s * c, s * iw * c, 1)),
        None,
        (ry.base, (0, 0, c, ow * c, 1)),
        design,
        opcode="vmax",
        tag="maxpool:fwd", reads=(rx,), writes=(ry,),
    )
    return NtxProgram(
        name=f"maxpool{ww}x{ww}s{s}:{oh}x{ow}x{c}:fwd",
        blocks=[block],
        regions=alloc.regions,
        design=design,
        meta={"spec": spec, "pass": "fwd"},
    )


def _lower_relu(spec: ReluSpec, design: DesignPoint) -> NtxProgram:
    alloc = RegionAllocator()
    rx = alloc.alloc("x", spec.shape, "input")
    ry = alloc.alloc("y", spec.shape, "output")
    block = _nest_block(
        (spec.size,), 0,
        (rx.base, (1,)),
        None,
        (ry.base, (1,)),
        design,
        opcode="relu",
        tag="relu:fwd", reads=(rx,), writes=(ry,),
    )
    return NtxProgram(
        name=f"relu{spec.size}:fwd",
        blocks=[block],
        regions=alloc.regions,
        design=design,
        meta={"spec": spec, "pass": "fwd"},
    )


# ---------------------------------------------------------------------------
# The entry point
# ---------------------------------------------------------------------------


def lower(spec, pass_: str = "fwd", *, design: DesignPoint = NTX_DESIGN) -> NtxProgram:
    """Lower one layer spec + pass to an :class:`NtxProgram`."""
    if isinstance(spec, MatmulSpec):
        return _lower_matmul(spec, pass_, design)
    if isinstance(spec, Conv2dSpec):
        if pass_ == "fwd":
            return _lower_conv_fwd(spec, design)
        if pass_ == "dw":
            return _lower_conv_dw(spec, design)
        if pass_ == "dx":
            return _lower_conv_dx(spec, design)
        raise ValueError(f"unknown conv pass {pass_!r}; expected one of {PASSES}")
    if isinstance(spec, MaxPool2dSpec):
        if pass_ != "fwd":
            raise NotImplementedError("pooling backward is not lowered yet")
        return _lower_maxpool(spec, design)
    if isinstance(spec, ReluSpec):
        if pass_ != "fwd":
            raise NotImplementedError("relu backward is not lowered yet")
        return _lower_relu(spec, design)
    raise TypeError(f"no lowering rule for {type(spec).__name__}")


def lower_layer(spec, *, design: DesignPoint = NTX_DESIGN) -> dict[str, NtxProgram]:
    """All training passes of one layer: {'fwd': ..., 'dw': ..., 'dx': ...}.

    Pooling/ReLU only have a forward lowering so far.
    """
    if isinstance(spec, (MaxPool2dSpec, ReluSpec)):
        return {"fwd": lower(spec, "fwd", design=design)}
    return {p: lower(spec, p, design=design) for p in PASSES}

"""Lowering rules: layer specs -> :class:`~repro.lower.ir.NtxProgram`.

One rule per (layer type, pass). Every rule goes through the same loop-nest
builder: order the iteration dims innermost-first as

    reduction dims  ++  output dims                       (paper §2.5)

give each AGU its per-dim element stride (eq. 1), and split the nest at the
design point's hardware-loop budget — the inner dims become the command
template, the outer dims become the driver's replication loops (Table 2's
offload counts fall out of this split; :func:`repro.core.ntx.offload_count`
is the closed form of the same arithmetic and the benchmarks assert the two
agree). A design without an autonomous write-back AGU (NS) can offload at
most the reduction dims: every output pixel is its own command.

The conv backward rules are the paper's §3.2 decomposition realized at the
command level: the weight gradient is one dense correlation block; the input
gradient is s*s phase blocks, each a dense correlation of a zero-padded
``dy`` with the (spatially flipped) filter-tap subset of that phase — the
flip and the subset selection are pure AGU striding (negative strides), and
the zero padding is staged in-band with ``memset``/``copy`` commands.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

from repro.core.ntx import MAX_LOOPS, Agu, NtxCommand
from repro.core.tiling import plan_matmul_tiles, plan_stencil_tiles
from repro.lower.ir import (
    ELEM_BYTES,
    CommandBlock,
    DesignPoint,
    NTX_DESIGN,
    NtxProgram,
    RegionAllocator,
    TensorRegion,
)

PASSES = ("fwd", "dw", "dx")


# ---------------------------------------------------------------------------
# Layer specs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MatmulSpec:
    """C[m,n] = A[m,k] @ B[k,n] (row major). dw = A^T dY, dx = dY B^T."""

    m: int
    n: int
    k: int


@dataclass(frozen=True)
class Conv2dSpec:
    """One conv layer per image: NHWC x HWIO -> NHWC with N=1 (Table 2)."""

    in_h: int
    in_w: int
    cin: int
    kh: int
    kw: int
    cout: int
    stride: int = 1
    padding: int = 0

    @property
    def out_h(self) -> int:
        return (self.in_h + 2 * self.padding - self.kh) // self.stride + 1

    @property
    def out_w(self) -> int:
        return (self.in_w + 2 * self.padding - self.kw) // self.stride + 1

    def conv_shape(self):
        """The paper's Table 2 view of this layer (offload_count input)."""
        from repro.core import ntx

        return ntx.ConvShape(
            kw=self.kw, kh=self.kh, cin=self.cin,
            out_w=self.out_w, out_h=self.out_h, cout=self.cout,
        )


@dataclass(frozen=True)
class MaxPool2dSpec:
    in_h: int
    in_w: int
    c: int
    window: int = 2
    stride: int = 2

    @property
    def out_h(self) -> int:
        return (self.in_h - self.window) // self.stride + 1

    @property
    def out_w(self) -> int:
        return (self.in_w - self.window) // self.stride + 1


@dataclass(frozen=True)
class ReluSpec:
    shape: tuple[int, ...]

    @property
    def size(self) -> int:
        return math.prod(self.shape)


@dataclass(frozen=True)
class FlattenSpec:
    """A zero-copy reshape to 1-D per item: only the graph compiler consumes
    it (the output tensor aliases the input region — no commands)."""

    in_shape: tuple[int, ...]

    @property
    def size(self) -> int:
        return math.prod(self.in_shape)


@dataclass(frozen=True)
class BiasSpec:
    """y[r, c] = x[r, c] + b[c] over ``rows`` broadcast rows (rows folds
    batch and any spatial extent). db reduces dy over the rows."""

    rows: int
    c: int


@dataclass(frozen=True)
class SoftmaxXentSpec:
    """Softmax-cross-entropy over (batch, classes) logits.

    Only the gradient pass lowers (``dx``): dz = (softmax(z) - onehot) / B,
    staged entirely in-band (max/exp/sum/recip command blocks). The scalar
    loss value stays on the driver core — executors read it off the logits.
    """

    batch: int
    classes: int


@dataclass(frozen=True)
class SgdUpdateSpec:
    """SGD weight update over a flat parameter of ``n`` elements.

    Plain SGD is one MAC block: w_new[i] = w[i]*1 + dW[i]*(-lr), the
    two-term reduction streaming (w, dW) through rd0 and the (1, -lr)
    coefficient pair through rd1. With ``momentum`` a second MAC block runs
    first: v_new[i] = v[i]*mu + dW[i]*1, and the update reads v_new —
    matching :func:`repro.optim.optimizers.sgd`.
    """

    n: int
    lr: float
    momentum: float = 0.0


@dataclass(frozen=True)
class AttentionSpec:
    """Single-image causal multi-head self-attention core (param-free).

    The input is the fused qkv activation (seq, 3*n_heads*head_dim), laid
    out ``[q | k | v]`` per row; the output is the context (seq,
    n_heads*head_dim). Per head: ``scores = (q @ k^T) * head_dim**-0.5 +
    causal_mask``, ``p = softmax(scores)``, ``ctx = p @ v`` — the score and
    context matmuls fold the head index as a fourth loop dim, and the row
    softmax is the same in-band max/exp/sum/recip machinery the loss
    gradient uses. The dX pass rematerializes ``p`` from qkv (scores are
    cheaper to recompute than to keep live across the whole backward).
    """

    seq: int
    n_heads: int
    head_dim: int

    @property
    def d(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def scale(self) -> float:
        return 1.0 / math.sqrt(self.head_dim)


@dataclass(frozen=True)
class LayerNormSpec:
    """Row-wise layernorm over (rows, d) with a packed (2, d) parameter:
    row 0 is gamma (init 1), row 1 is beta (init 0). ``rows`` folds batch
    and sequence. Mean/variance are MAC reductions against a staged 1/d
    constant; rstd is a single ``vrsqrt`` stream; dX recomputes the stats
    (cheaper than keeping xhat/rstd live through the backward)."""

    rows: int
    d: int
    eps: float = 1e-5


@dataclass(frozen=True)
class ResidualAddSpec:
    """y = x0 + x1 elementwise over ``shape`` — the DAG join node.

    dX is an identity copy toward *each* branch; the graph compiler emits
    one copy per incoming edge and sums gradient contributions at joins.
    """

    shape: tuple[int, ...]

    @property
    def size(self) -> int:
        return math.prod(self.shape)


@dataclass(frozen=True)
class EmbeddingSpec:
    """Token embedding y[rows, d] = onehot[rows, vocab] @ W[vocab, d].

    The host stages tokens as one-hot rows (exactly like the loss labels),
    so fwd and dW are plain matmul nests over the embedding table. dX never
    lowers: the input is the token stream, which carries no gradient.
    """

    rows: int
    vocab: int
    d: int


@dataclass(frozen=True)
class PosEmbedSpec:
    """Learned positional embedding y[b, s, :] = x[b, s, :] + P[s, :].

    Whole-batch node (``batch`` is baked into the spec): fwd broadcasts P
    over the batch dim with a zero AGU stride, dW reduces dy over batch via
    a MAC against a staged 1.0, dX is an identity copy.
    """

    batch: int
    seq: int
    d: int


# ---------------------------------------------------------------------------
# The shared loop-nest splitter
# ---------------------------------------------------------------------------


def _pad5(xs: tuple[int, ...], fill: int) -> tuple[int, ...]:
    return tuple(xs) + (fill,) * (MAX_LOOPS - len(xs))


def _nest_block(
    sizes: tuple[int, ...],
    n_red: int,
    rd0: tuple[int, tuple[int, ...]],
    rd1: tuple[int, tuple[int, ...]] | None,
    wr: tuple[int, tuple[int, ...]],
    design: DesignPoint,
    *,
    opcode: str = "mac",
    tag: str,
    reads: tuple[TensorRegion, ...],
    writes: tuple[TensorRegion, ...],
    init_value: float = 0.0,
    tile=None,
) -> CommandBlock:
    """Split an iteration nest at the design point's hardware-loop budget.

    ``sizes`` is the full nest innermost-first (reduction dims leading);
    ``rd0``/``rd1``/``wr`` are (base, per-dim element strides) over the same
    ordering. Dims beyond the budget become driver replication loops.
    """
    usable = min(design.hw_loops, len(sizes))
    if not design.autonomous_writeback:
        usable = min(usable, n_red)
    if usable < n_red:
        raise NotImplementedError(
            f"{tag}: {n_red} reduction dims exceed the {design.name} design's "
            f"{usable} offloadable loops — the driver would have to accumulate"
        )

    def split(agu):
        if agu is None:
            return None, ()
        base, strides = agu
        hw = Agu(base, _pad5(tuple(strides[:usable]), 0))
        return hw, tuple(strides[usable:])

    a0, s0 = split(rd0)
    a1, s1 = split(rd1)
    aw, sw = split(wr)
    template = NtxCommand(
        loops=_pad5(tuple(sizes[:usable]), 1),
        opcode=opcode,
        agu_rd0=a0,
        agu_rd1=a1,
        agu_wr=aw,
        init_level=n_red,
        store_level=n_red,
        init_value=init_value,
    )
    reps = tuple(sizes[usable:])
    n_cmds = math.prod(reps) if reps else 1
    bytes_in = sum(r.bytes for r in reads) / n_cmds
    bytes_out = sum(r.bytes for r in writes) / n_cmds
    return CommandBlock(
        template=template,
        reps=reps,
        rd0_step=s0,
        rd1_step=s1 if rd1 is not None else (0,) * len(reps),
        wr_step=sw,
        tag=tag,
        reads=tuple(r.name for r in reads),
        writes=tuple(r.name for r in writes),
        dma_bytes_in=bytes_in,
        dma_bytes_out=bytes_out,
        tile=tile,
    )


# ---------------------------------------------------------------------------
# In-band staging blits (zero padding as memset + copy commands)
# ---------------------------------------------------------------------------


def _memset_block(dst: TensorRegion, value: float = 0.0) -> CommandBlock:
    return CommandBlock(
        template=NtxCommand(
            loops=(dst.size, 1, 1, 1, 1),
            opcode="memset",
            agu_rd0=Agu(dst.base, (0,) * MAX_LOOPS),
            agu_wr=Agu(dst.base, _pad5((1,), 0)),
            init_level=0,
            store_level=0,
            init_value=value,
        ),
        tag=f"memset:{dst.name}",
        writes=(dst.name,),
        dma_bytes_out=float(dst.bytes),
    )


def _memset_at(dst: TensorRegion, off: int, value: float) -> CommandBlock:
    """Stage one scalar constant in-band (a single-element memset)."""
    return CommandBlock(
        template=NtxCommand(
            loops=(1, 1, 1, 1, 1),
            opcode="memset",
            agu_rd0=Agu(dst.base + off, (0,) * MAX_LOOPS),
            agu_wr=Agu(dst.base + off, (0,) * MAX_LOOPS),
            init_level=0,
            store_level=0,
            init_value=value,
        ),
        tag=f"memset:{dst.name}[{off}]",
        writes=(dst.name,),
        dma_bytes_out=float(ELEM_BYTES),
    )


def _memset_range(
    dst: TensorRegion, off: int, count: int, value: float, *, tag: str = ""
) -> CommandBlock:
    """Stage ``count`` contiguous elements of a constant in-band."""
    return CommandBlock(
        template=NtxCommand(
            loops=(count, 1, 1, 1, 1),
            opcode="memset",
            agu_rd0=Agu(dst.base + off, (0,) * MAX_LOOPS),
            agu_wr=Agu(dst.base + off, _pad5((1,), 0)),
            init_level=0,
            store_level=0,
            init_value=value,
        ),
        tag=tag or f"memset:{dst.name}[{off}:{off + count}]",
        writes=(dst.name,),
        dma_bytes_out=float(count * ELEM_BYTES),
    )


def _copy_block(
    src: TensorRegion,
    dst: TensorRegion,
    *,
    rows: int,
    row_elems: int,
    src_row_stride: int,
    dst_row_stride: int,
    src_off: int = 0,
    dst_off: int = 0,
    tag: str = "",
) -> CommandBlock:
    return CommandBlock(
        template=NtxCommand(
            loops=(row_elems, rows, 1, 1, 1),
            opcode="copy",
            agu_rd0=Agu(src.base + src_off, _pad5((1, src_row_stride), 0)),
            agu_wr=Agu(dst.base + dst_off, _pad5((1, dst_row_stride), 0)),
            init_level=0,
            store_level=0,
        ),
        tag=tag or f"copy:{src.name}->{dst.name}",
        reads=(src.name,),
        writes=(dst.name,),
        dma_bytes_in=float(rows * row_elems * ELEM_BYTES),
        dma_bytes_out=float(rows * row_elems * ELEM_BYTES),
    )


def _padded_plane(
    alloc: RegionAllocator,
    src: TensorRegion,
    *,
    h: int,
    w: int,
    c: int,
    pad: int,
    name: str,
) -> tuple[TensorRegion, list[CommandBlock]]:
    """Zero-padded copy of an (h, w, c) plane, staged with memset + copy."""
    if pad == 0:
        return src, []
    hp, wp = h + 2 * pad, w + 2 * pad
    dst = alloc.alloc(name, (hp, wp, c), "scratch")
    blocks = [
        _memset_block(dst),
        _copy_block(
            src,
            dst,
            rows=h,
            row_elems=w * c,
            src_row_stride=w * c,
            dst_row_stride=wp * c,
            dst_off=(pad * wp + pad) * c,
        ),
    ]
    return dst, blocks


# ---------------------------------------------------------------------------
# Matmul rules (fwd / dw / dx)
# ---------------------------------------------------------------------------


def matmul_nest(
    m: int, n: int, k: int, pass_: str, a_base: int, b_base: int, c_base: int
):
    """(sizes, n_red, rd0, rd1, wr) for one matmul pass at explicit bases.

    ``a``/``b``/``c`` are the *roles* of the three operands for the pass:
    fwd reads (A, B) writes C; dw reads (A, dY) writes dW; dx reads (dY, B)
    writes dX. Transposes are pure AGU striding — no data movement.
    """
    if pass_ == "fwd":
        # C[i2,i1] += A[i2,i0] * B[i0,i1];  dims (k, n, m)
        return (
            (k, n, m), 1,
            (a_base, (1, 0, k)),
            (b_base, (n, 1, 0)),
            (c_base, (0, 1, n)),
        )
    if pass_ == "dw":
        # dW[i2,i1] += A[i0,i2] * dY[i0,i1];  dims (m, n, k)
        return (
            (m, n, k), 1,
            (a_base, (k, 0, 1)),
            (b_base, (n, 1, 0)),
            (c_base, (0, 1, n)),
        )
    if pass_ == "dx":
        # dX[i2,i1] += dY[i2,i0] * B[i1,i0];  dims (n, k, m)
        return (
            (n, k, m), 1,
            (a_base, (1, 0, n)),
            (b_base, (1, n, 0)),
            (c_base, (0, 1, k)),
        )
    raise ValueError(f"unknown matmul pass {pass_!r}; expected one of {PASSES}")


def matmul_template(
    m: int, n: int, k: int, a_base: int, b_base: int, c_base: int
) -> NtxCommand:
    """The single-command NTX matmul at explicit TCDM bases (fwd pass)."""
    sizes, n_red, rd0, rd1, wr = matmul_nest(m, n, k, "fwd", a_base, b_base, c_base)
    return NtxCommand(
        loops=_pad5(sizes, 1),
        opcode="mac",
        agu_rd0=Agu(rd0[0], _pad5(rd0[1], 0)),
        agu_rd1=Agu(rd1[0], _pad5(rd1[1], 0)),
        agu_wr=Agu(wr[0], _pad5(wr[1], 0)),
        init_level=n_red,
        store_level=n_red,
    )


def _lower_matmul(spec: MatmulSpec, pass_: str, design: DesignPoint) -> NtxProgram:
    m, n, k = spec.m, spec.n, spec.k
    alloc = RegionAllocator()
    if pass_ == "fwd":
        ra = alloc.alloc("a", (m, k), "input")
        rb = alloc.alloc("b", (k, n), "param")
        rc = alloc.alloc("c", (m, n), "output")
    elif pass_ == "dw":
        ra = alloc.alloc("a", (m, k), "input")
        rb = alloc.alloc("dy", (m, n), "input")
        rc = alloc.alloc("dw", (k, n), "output")
    elif pass_ == "dx":
        ra = alloc.alloc("dy", (m, n), "input")
        rb = alloc.alloc("b", (k, n), "param")
        rc = alloc.alloc("dx", (m, k), "output")
    else:
        raise ValueError(f"unknown matmul pass {pass_!r}; expected one of {PASSES}")
    sizes, n_red, rd0, rd1, wr = matmul_nest(m, n, k, pass_, ra.base, rb.base, rc.base)
    plan = plan_matmul_tiles(m, n, k, in_dtype_bytes=ELEM_BYTES)
    block = _nest_block(
        sizes, n_red, rd0, rd1, wr, design,
        tag=f"matmul:{pass_}", reads=(ra, rb), writes=(rc,), tile=plan,
    )
    return NtxProgram(
        name=f"matmul{m}x{n}x{k}:{pass_}",
        blocks=[block],
        regions=alloc.regions,
        design=design,
        meta={"spec": spec, "pass": pass_, "plan": plan},
    )


# ---------------------------------------------------------------------------
# Conv2d rules (fwd / dw / dx)
# ---------------------------------------------------------------------------


def _conv_plan(spec: Conv2dSpec):
    return plan_stencil_tiles(
        spec.out_h, spec.out_w, spec.cin, spec.cout, spec.kh, spec.kw,
        dtype_bytes=ELEM_BYTES,
    )


def _lower_conv_fwd(spec: Conv2dSpec, design: DesignPoint) -> NtxProgram:
    s, p = spec.stride, spec.padding
    oh, ow = spec.out_h, spec.out_w
    alloc = RegionAllocator()
    rx = alloc.alloc("x", (spec.in_h, spec.in_w, spec.cin), "input")
    rw = alloc.alloc("w", (spec.kh, spec.kw, spec.cin, spec.cout), "param")
    ry = alloc.alloc("y", (oh, ow, spec.cout), "output")
    xp, staging = _padded_plane(
        alloc, rx, h=spec.in_h, w=spec.in_w, c=spec.cin, pad=p, name="x_pad"
    )
    iw = spec.in_w + 2 * p  # padded row pitch
    cin, kw, kh, cout = spec.cin, spec.kw, spec.kh, spec.cout
    block = _nest_block(
        (cin, kw, kh, ow, oh, cout), 3,
        (xp.base, (1, cin, iw * cin, s * cin, s * iw * cin, 0)),
        (rw.base, (cout, cin * cout, kw * cin * cout, 0, 0, 1)),
        (ry.base, (0, 0, 0, cout, ow * cout, 1)),
        design,
        tag="conv2d:fwd", reads=(xp, rw), writes=(ry,), tile=_conv_plan(spec),
    )
    return NtxProgram(
        name=f"conv{spec.kh}x{spec.kw}x{cin}->{oh}x{ow}x{cout}:fwd",
        blocks=staging + [block],
        regions=alloc.regions,
        design=design,
        meta={"spec": spec, "pass": "fwd", "plan": block.tile},
    )


def conv2d_fwd_template(
    in_h: int, in_w: int, cin: int, kh: int, kw: int, cout: int,
    x_base: int, w_base: int, y_base: int, stride: int = 1,
) -> NtxCommand:
    """The NTX conv-forward command template at explicit TCDM bases.

    With ``cout=1`` this is the single-output-channel command (HWI-
    contiguous weights, one full output plane per offload).
    """
    oh = (in_h - kh) // stride + 1
    ow = (in_w - kw) // stride + 1
    return NtxCommand(
        loops=(cin, kw, kh, ow, oh),
        opcode="mac",
        agu_rd0=Agu(x_base, (1, cin, in_w * cin, stride * cin, stride * in_w * cin)),
        agu_rd1=Agu(w_base, (cout, cin * cout, kw * cin * cout, 0, 0)),
        agu_wr=Agu(y_base, (0, 0, 0, cout, ow * cout)),
        init_level=3,
        store_level=3,
    )


def _lower_conv_dw(spec: Conv2dSpec, design: DesignPoint) -> NtxProgram:
    s, p = spec.stride, spec.padding
    oh, ow = spec.out_h, spec.out_w
    alloc = RegionAllocator()
    rx = alloc.alloc("x", (spec.in_h, spec.in_w, spec.cin), "input")
    rdy = alloc.alloc("dy", (oh, ow, spec.cout), "input")
    rdw = alloc.alloc("dw", (spec.kh, spec.kw, spec.cin, spec.cout), "output")
    xp, staging = _padded_plane(
        alloc, rx, h=spec.in_h, w=spec.in_w, c=spec.cin, pad=p, name="x_pad"
    )
    iw = spec.in_w + 2 * p
    cin, kw, kh, cout = spec.cin, spec.kw, spec.kh, spec.cout
    # dW[u,v,ci,co] += x_pad[s*ohi+u, s*owi+v, ci] * dy[ohi, owi, co]
    # dims innermost-first: (owi, ohi | ci, v, u, co)
    block = _nest_block(
        (ow, oh, cin, kw, kh, cout), 2,
        (xp.base, (s * cin, s * iw * cin, 1, cin, iw * cin, 0)),
        (rdy.base, (cout, ow * cout, 0, 0, 0, 1)),
        (rdw.base, (0, 0, cout, cin * cout, kw * cin * cout, 1)),
        design,
        tag="conv2d:dw", reads=(xp, rdy), writes=(rdw,), tile=_conv_plan(spec),
    )
    return NtxProgram(
        name=f"conv{kh}x{kw}x{cin}->{oh}x{ow}x{cout}:dw",
        blocks=staging + [block],
        regions=alloc.regions,
        design=design,
        meta={"spec": spec, "pass": "dw", "plan": block.tile},
    )


def _lower_conv_dx(spec: Conv2dSpec, design: DesignPoint) -> NtxProgram:
    """§3.2 / Fig. 6: s*s dense phase convolutions over zero-padded dy.

    Phase (a, b) collects the input pixels (i, j) with (i+p) % s == a etc.;
    only the filter taps congruent to the phase ever touch them, so each
    phase is a *dense* stride-1 correlation — constant MACs per pixel, one
    command block per phase (driver reps over cin). The tap subset and the
    spatial flip are encoded as negative AGU strides into the original
    weights; the zero padding of dy is staged in-band (memset + copy).
    """
    s, p = spec.stride, spec.padding
    oh, ow = spec.out_h, spec.out_w
    xh, xw = spec.in_h, spec.in_w
    cin, kw, kh, cout = spec.cin, spec.kw, spec.kh, spec.cout
    alloc = RegionAllocator()
    rdy = alloc.alloc("dy", (oh, ow, cout), "input")
    rw = alloc.alloc("w", (kh, kw, cin, cout), "param")
    rdx = alloc.alloc("dx", (xh, xw, cin), "output")

    blocks: list[CommandBlock] = []
    n_phases = 0
    for a in range(s):
        ta = len(range(a, kh, s))
        if ta == 0:
            continue
        for b in range(s):
            tb = len(range(b, kw, s))
            if tb == 0:
                continue
            i0 = (a - p) % s
            j0 = (b - p) % s
            na = len(range(i0, xh, s))
            nb = len(range(j0, xw, s))
            if na == 0 or nb == 0:
                continue
            ii0 = (i0 + p - a) // s
            jj0 = (j0 + p - b) // s
            # dy staged zero-padded: taps reach ta-1 rows above the first dy
            # row and the last phase pixel reaches ii0 + na - 1 + ta - 1.
            pt, pl = ta - 1, tb - 1
            hp = max(pt + oh, ii0 + na + ta - 1)
            wp = max(pl + ow, jj0 + nb + tb - 1)
            if (hp, wp) == (oh, ow):
                dyp, staging = rdy, []
            else:
                dyp = alloc.alloc(f"dy_pad{a}{b}", (hp, wp, cout), "scratch")
                staging = [
                    _memset_block(dyp),
                    _copy_block(
                        rdy, dyp,
                        rows=oh, row_elems=ow * cout,
                        src_row_stride=ow * cout, dst_row_stride=wp * cout,
                        dst_off=(pt * wp + pl) * cout,
                        tag=f"copy:dy->dy_pad{a}{b}",
                    ),
                ]
            blocks += staging
            # dx[i0+s*qi, j0+s*qj, ci] +=
            #   dy_pad[ii0+qi+ti, jj0+qj+tj, co] * w[a+s*(ta-1-ti), b+s*(tb-1-tj), ci, co]
            # dims innermost-first: (co, tj, ti | qj, qi, ci)
            u0 = a + s * (ta - 1)
            v0 = b + s * (tb - 1)
            blocks.append(
                _nest_block(
                    (cout, tb, ta, nb, na, cin), 3,
                    (
                        # dy_pad row r holds dy row r - pt; phase pixel qi
                        # reads rows (ii0 + qi) + ti of the padded plane.
                        dyp.base + (ii0 * wp + jj0) * cout,
                        (1, cout, wp * cout, cout, wp * cout, 0),
                    ),
                    (
                        rw.base + (u0 * kw + v0) * cin * cout,
                        (1, -s * cin * cout, -s * kw * cin * cout, 0, 0, cout),
                    ),
                    (
                        rdx.base + (i0 * xw + j0) * cin,
                        (0, 0, 0, s * cin, s * xw * cin, 1),
                    ),
                    design,
                    tag=f"conv2d:dx[{a},{b}]",
                    reads=(dyp, rw), writes=(rdx,), tile=_conv_plan(spec),
                )
            )
            n_phases += 1
    return NtxProgram(
        name=f"conv{kh}x{kw}x{cin}->{oh}x{ow}x{cout}:dx",
        blocks=blocks,
        regions=alloc.regions,
        design=design,
        meta={"spec": spec, "pass": "dx", "n_phases": n_phases,
              "plan": _conv_plan(spec)},
    )


# ---------------------------------------------------------------------------
# Pooling / ReLU rules
# ---------------------------------------------------------------------------


def _lower_maxpool(spec: MaxPool2dSpec, design: DesignPoint) -> NtxProgram:
    s, ww = spec.stride, spec.window
    oh, ow, c = spec.out_h, spec.out_w, spec.c
    iw = spec.in_w
    alloc = RegionAllocator()
    rx = alloc.alloc("x", (spec.in_h, spec.in_w, c), "input")
    ry = alloc.alloc("y", (oh, ow, c), "output")
    # y[i3,i2,i4] = max over (i1,i0) of x[s*i3+i1, s*i2+i0, i4]
    block = _nest_block(
        (ww, ww, ow, oh, c), 2,
        (rx.base, (c, iw * c, s * c, s * iw * c, 1)),
        None,
        (ry.base, (0, 0, c, ow * c, 1)),
        design,
        opcode="vmax",
        tag="maxpool:fwd", reads=(rx,), writes=(ry,),
    )
    return NtxProgram(
        name=f"maxpool{ww}x{ww}s{s}:{oh}x{ow}x{c}:fwd",
        blocks=[block],
        regions=alloc.regions,
        design=design,
        meta={"spec": spec, "pass": "fwd"},
    )


def _lower_relu(spec: ReluSpec, design: DesignPoint) -> NtxProgram:
    alloc = RegionAllocator()
    rx = alloc.alloc("x", spec.shape, "input")
    ry = alloc.alloc("y", spec.shape, "output")
    block = _nest_block(
        (spec.size,), 0,
        (rx.base, (1,)),
        None,
        (ry.base, (1,)),
        design,
        opcode="relu",
        tag="relu:fwd", reads=(rx,), writes=(ry,),
    )
    return NtxProgram(
        name=f"relu{spec.size}:fwd",
        blocks=[block],
        regions=alloc.regions,
        design=design,
        meta={"spec": spec, "pass": "fwd"},
    )


def relu_dx_blocks(
    x: TensorRegion,
    dy: TensorRegion,
    mask: TensorRegion,
    dx: TensorRegion,
    design: DesignPoint,
    *,
    tag: str = "relu:dx",
) -> list[CommandBlock]:
    """dX = dY * (x > 0): the sign/select mask pattern at explicit regions.

    Two streaming blocks: a ``sign`` pass turns the forward input into a
    0/1 mask, a ``vmul`` pass gates the incoming gradient through it.
    """
    n = x.size
    return [
        _nest_block(
            (n,), 0,
            (x.base, (1,)), None, (mask.base, (1,)),
            design, opcode="sign", tag=f"{tag}:mask",
            reads=(x,), writes=(mask,),
        ),
        _nest_block(
            (n,), 0,
            (mask.base, (1,)), (dy.base, (1,)), (dx.base, (1,)),
            design, opcode="vmul", tag=tag,
            reads=(mask, dy), writes=(dx,),
        ),
    ]


def _lower_relu_dx(spec: ReluSpec, design: DesignPoint) -> NtxProgram:
    alloc = RegionAllocator()
    rx = alloc.alloc("x", spec.shape, "input")
    rdy = alloc.alloc("dy", spec.shape, "input")
    rm = alloc.alloc("mask", spec.shape, "scratch")
    rdx = alloc.alloc("dx", spec.shape, "output")
    return NtxProgram(
        name=f"relu{spec.size}:dx",
        blocks=relu_dx_blocks(rx, rdy, rm, rdx, design),
        regions=alloc.regions,
        design=design,
        meta={"spec": spec, "pass": "dx"},
    )


def maxpool_dx_blocks(
    spec: MaxPool2dSpec,
    x: TensorRegion,
    y: TensorRegion,
    dy: TensorRegion,
    mask: TensorRegion,
    dx: TensorRegion,
    design: DesignPoint,
    *,
    tag: str = "maxpool:dx",
) -> list[CommandBlock]:
    """Max-pool backward as the argmax-mask scatter, staged per window tap.

    For non-overlapping pooling every input pixel belongs to exactly one
    window, so the scatter is affine: per window tap (a, b), a ``cmpge``
    block recomputes the winner mask (x strided at the tap vs the pooled
    max), and a ``vmul`` block routes dY through it into the strided dX
    positions. The leading memset zeroes remainder pixels no window covers.
    Ties route the gradient to every winning tap (the jnp oracle picks one;
    with continuous inputs the two agree).
    """
    s, ww = spec.stride, spec.window
    if ww != s:
        raise NotImplementedError(
            "maxpool dX lowers only for non-overlapping pooling "
            f"(window == stride); got window={ww} stride={s}"
        )
    oh, ow, c = spec.out_h, spec.out_w, spec.c
    iw = spec.in_w
    blocks = [_memset_block(dx)]
    for a in range(ww):
        for b in range(ww):
            off = (a * iw + b) * c
            blocks.append(
                _nest_block(
                    (c, ow, oh), 0,
                    (x.base + off, (1, s * c, s * iw * c)),
                    (y.base, (1, c, ow * c)),
                    (mask.base, (1, c, ow * c)),
                    design, opcode="cmpge", tag=f"{tag}:mask[{a},{b}]",
                    reads=(x, y), writes=(mask,),
                )
            )
            blocks.append(
                _nest_block(
                    (c, ow, oh), 0,
                    (mask.base, (1, c, ow * c)),
                    (dy.base, (1, c, ow * c)),
                    (dx.base + off, (1, s * c, s * iw * c)),
                    design, opcode="vmul", tag=f"{tag}[{a},{b}]",
                    reads=(mask, dy), writes=(dx,),
                )
            )
    return blocks


def _lower_maxpool_dx(spec: MaxPool2dSpec, design: DesignPoint) -> NtxProgram:
    oh, ow, c = spec.out_h, spec.out_w, spec.c
    alloc = RegionAllocator()
    rx = alloc.alloc("x", (spec.in_h, spec.in_w, c), "input")
    ry = alloc.alloc("y", (oh, ow, c), "input")
    rdy = alloc.alloc("dy", (oh, ow, c), "input")
    rm = alloc.alloc("mask", (oh, ow, c), "scratch")
    rdx = alloc.alloc("dx", (spec.in_h, spec.in_w, c), "output")
    return NtxProgram(
        name=f"maxpool{spec.window}x{spec.window}s{spec.stride}:{oh}x{ow}x{c}:dx",
        blocks=maxpool_dx_blocks(spec, rx, ry, rdy, rm, rdx, design),
        regions=alloc.regions,
        design=design,
        meta={"spec": spec, "pass": "dx"},
    )


# ---------------------------------------------------------------------------
# Bias rules (fwd / dw / dx)
# ---------------------------------------------------------------------------


def _lower_bias(spec: BiasSpec, pass_: str, design: DesignPoint) -> NtxProgram:
    rows, c = spec.rows, spec.c
    alloc = RegionAllocator()
    if pass_ == "fwd":
        rx = alloc.alloc("x", (rows, c), "input")
        rb = alloc.alloc("b", (c,), "param")
        ry = alloc.alloc("y", (rows, c), "output")
        blocks = [
            _nest_block(
                (c, rows), 0,
                (rx.base, (1, c)), (rb.base, (1, 0)), (ry.base, (1, c)),
                design, opcode="vadd", tag="bias:fwd",
                reads=(rx, rb), writes=(ry,),
            )
        ]
    elif pass_ == "dw":
        rdy = alloc.alloc("dy", (rows, c), "input")
        rone = alloc.alloc("one", (1,), "scratch")
        rdb = alloc.alloc("db", (c,), "output")
        blocks = [
            _memset_at(rone, 0, 1.0),
            # db[ch] = sum_rows dy[row, ch] — a MAC against the staged 1.0
            _nest_block(
                (rows, c), 1,
                (rdy.base, (c, 1)), (rone.base, (0, 0)), (rdb.base, (0, 1)),
                design, opcode="mac", tag="bias:dw",
                reads=(rdy, rone), writes=(rdb,),
            ),
        ]
    elif pass_ == "dx":
        rdy = alloc.alloc("dy", (rows, c), "input")
        rdx = alloc.alloc("dx", (rows, c), "output")
        blocks = [
            _nest_block(
                (rows * c,), 0,
                (rdy.base, (1,)), None, (rdx.base, (1,)),
                design, opcode="copy", tag="bias:dx",
                reads=(rdy,), writes=(rdx,),
            )
        ]
    else:
        raise ValueError(f"unknown bias pass {pass_!r}; expected one of {PASSES}")
    return NtxProgram(
        name=f"bias{rows}x{c}:{pass_}",
        blocks=blocks,
        regions=alloc.regions,
        design=design,
        meta={"spec": spec, "pass": pass_},
    )


# ---------------------------------------------------------------------------
# Softmax-cross-entropy gradient (the loss node's backward rule)
# ---------------------------------------------------------------------------


def softmax_xent_grad_blocks(
    spec: SoftmaxXentSpec,
    z: TensorRegion,
    onehot: TensorRegion,
    dz: TensorRegion,
    scratch: dict[str, TensorRegion],
    design: DesignPoint,
    *,
    tag: str = "softmax_xent:dx",
) -> list[CommandBlock]:
    """dz = (softmax(z) - onehot) / B, staged entirely in-band.

    ``scratch`` must hold regions ``m``/``negm``/``s``/``r`` shaped (B,),
    ``zc``/``e``/``p``/``pb``/``ohb`` shaped (B, C), and a 4-element
    ``consts`` region. The max-subtraction keeps exp in range exactly like
    the numerically-stable jnp softmax.
    """
    B, C = spec.batch, spec.classes
    m, negm = scratch["m"], scratch["negm"]
    zc, e = scratch["zc"], scratch["e"]
    s, r, p = scratch["s"], scratch["r"], scratch["p"]
    pb, ohb = scratch["pb"], scratch["ohb"]
    consts = scratch["consts"]
    blocks = [
        _memset_at(consts, 0, -1.0),
        _memset_at(consts, 1, 1.0),
        _memset_at(consts, 2, 1.0 / B),
        _memset_at(consts, 3, -1.0 / B),
        # m[b] = max_c z[b, c]
        _nest_block(
            (C, B), 1,
            (z.base, (1, C)), None, (m.base, (0, 1)),
            design, opcode="vmax", tag=f"{tag}:rowmax",
            reads=(z,), writes=(m,),
        ),
        # negm = -m
        _nest_block(
            (B,), 0,
            (m.base, (1,)), (consts.base + 0, (0,)), (negm.base, (1,)),
            design, opcode="vmul", tag=f"{tag}:negmax",
            reads=(m, consts), writes=(negm,),
        ),
        # zc[b, c] = z - m[b]
        _nest_block(
            (C, B), 0,
            (z.base, (1, C)), (negm.base, (0, 1)), (zc.base, (1, C)),
            design, opcode="vadd", tag=f"{tag}:shift",
            reads=(z, negm), writes=(zc,),
        ),
        # e = exp(zc)
        _nest_block(
            (B * C,), 0,
            (zc.base, (1,)), None, (e.base, (1,)),
            design, opcode="vexp", tag=f"{tag}:exp",
            reads=(zc,), writes=(e,),
        ),
        # s[b] = sum_c e[b, c]
        _nest_block(
            (C, B), 1,
            (e.base, (1, C)), (consts.base + 1, (0, 0)), (s.base, (0, 1)),
            design, opcode="mac", tag=f"{tag}:rowsum",
            reads=(e, consts), writes=(s,),
        ),
        # r = 1 / s
        _nest_block(
            (B,), 0,
            (s.base, (1,)), None, (r.base, (1,)),
            design, opcode="vrecip", tag=f"{tag}:recip",
            reads=(s,), writes=(r,),
        ),
        # p[b, c] = e * r[b]
        _nest_block(
            (C, B), 0,
            (e.base, (1, C)), (r.base, (0, 1)), (p.base, (1, C)),
            design, opcode="vmul", tag=f"{tag}:softmax",
            reads=(e, r), writes=(p,),
        ),
        # dz = p/B - onehot/B
        _nest_block(
            (B * C,), 0,
            (p.base, (1,)), (consts.base + 2, (0,)), (pb.base, (1,)),
            design, opcode="vmul", tag=f"{tag}:scale_p",
            reads=(p, consts), writes=(pb,),
        ),
        _nest_block(
            (B * C,), 0,
            (onehot.base, (1,)), (consts.base + 3, (0,)), (ohb.base, (1,)),
            design, opcode="vmul", tag=f"{tag}:scale_onehot",
            reads=(onehot, consts), writes=(ohb,),
        ),
        _nest_block(
            (B * C,), 0,
            (pb.base, (1,)), (ohb.base, (1,)), (dz.base, (1,)),
            design, opcode="vadd", tag=tag,
            reads=(pb, ohb), writes=(dz,),
        ),
    ]
    return blocks


def softmax_xent_scratch_shapes(spec: SoftmaxXentSpec) -> dict[str, tuple[int, ...]]:
    """The scratch regions :func:`softmax_xent_grad_blocks` needs."""
    B, C = spec.batch, spec.classes
    return {
        "m": (B,), "negm": (B,), "s": (B,), "r": (B,),
        "zc": (B, C), "e": (B, C), "p": (B, C), "pb": (B, C), "ohb": (B, C),
        "consts": (4,),
    }


def _lower_softmax_xent_grad(spec: SoftmaxXentSpec, design: DesignPoint) -> NtxProgram:
    B, C = spec.batch, spec.classes
    alloc = RegionAllocator()
    rz = alloc.alloc("z", (B, C), "input")
    roh = alloc.alloc("onehot", (B, C), "input")
    rdz = alloc.alloc("dz", (B, C), "output")
    scratch = {
        name: alloc.alloc(name, shape, "scratch")
        for name, shape in softmax_xent_scratch_shapes(spec).items()
    }
    return NtxProgram(
        name=f"softmax_xent{B}x{C}:dx",
        blocks=softmax_xent_grad_blocks(spec, rz, roh, rdz, scratch, design),
        regions=alloc.regions,
        design=design,
        meta={"spec": spec, "pass": "dx"},
    )


# ---------------------------------------------------------------------------
# SGD update rule (w <- w - lr * dW, optional momentum)
# ---------------------------------------------------------------------------


def _pair_mac_block(
    src0: TensorRegion,
    src1: TensorRegion,
    coeffs: TensorRegion,
    coeff_off: int,
    dst: TensorRegion,
    design: DesignPoint,
    *,
    tag: str,
) -> CommandBlock:
    """dst[i] = src0[i]*coeffs[off] + src1[i]*coeffs[off+1] as one MAC nest.

    The two operands stream through rd0 via the cross-region base delta in
    the reduction dim; the coefficient pair streams through rd1 with the
    output-dim stride pinned to 0. NOT relocation-safe (the delta bakes the
    final bases in) — emit only at final region addresses.
    """
    delta = src1.base - src0.base
    return _nest_block(
        (2, src0.size), 1,
        (src0.base, (delta, 1)),
        (coeffs.base + coeff_off, (1, 0)),
        (dst.base, (0, 1)),
        design, opcode="mac", tag=tag,
        reads=(src0, src1, coeffs), writes=(dst,),
    )


def sgd_update_blocks(
    spec: SgdUpdateSpec,
    w: TensorRegion,
    dw: TensorRegion,
    w_new: TensorRegion,
    consts: TensorRegion,
    design: DesignPoint,
    *,
    v: TensorRegion | None = None,
    v_new: TensorRegion | None = None,
    tag: str = "sgd",
) -> list[CommandBlock]:
    """The weight-update MAC blocks (see :class:`SgdUpdateSpec`).

    ``consts`` is 2 elements for plain SGD ((1, -lr)), 4 with momentum
    ((mu, 1) then (1, -lr)).
    """
    lr, mu = spec.lr, spec.momentum
    if mu:
        if v is None or v_new is None:
            raise ValueError("momentum update needs v and v_new regions")
        return [
            _memset_at(consts, 0, mu),
            _memset_at(consts, 1, 1.0),
            _memset_at(consts, 2, 1.0),
            _memset_at(consts, 3, -lr),
            _pair_mac_block(v, dw, consts, 0, v_new, design, tag=f"{tag}:momentum"),
            _pair_mac_block(w, v_new, consts, 2, w_new, design, tag=f"{tag}:update"),
        ]
    return [
        _memset_at(consts, 0, 1.0),
        _memset_at(consts, 1, -lr),
        _pair_mac_block(w, dw, consts, 0, w_new, design, tag=f"{tag}:update"),
    ]


def _lower_sgd_update(spec: SgdUpdateSpec, design: DesignPoint) -> NtxProgram:
    n = spec.n
    alloc = RegionAllocator()
    rw = alloc.alloc("w", (n,), "param")
    rdw = alloc.alloc("dw", (n,), "input")
    rv = rvn = None
    if spec.momentum:
        rv = alloc.alloc("v", (n,), "param")
        rvn = alloc.alloc("v_new", (n,), "output")
    rc = alloc.alloc("consts", (4 if spec.momentum else 2,), "scratch")
    rwn = alloc.alloc("w_new", (n,), "output")
    return NtxProgram(
        name=f"sgd{n}:upd",
        blocks=sgd_update_blocks(spec, rw, rdw, rwn, rc, design, v=rv, v_new=rvn),
        regions=alloc.regions,
        design=design,
        meta={"spec": spec, "pass": "upd"},
    )


# ---------------------------------------------------------------------------
# Row softmax (shared by attention fwd/dx — same machinery as the loss grad)
# ---------------------------------------------------------------------------


def softmax_rows_blocks(
    src: TensorRegion,
    p: TensorRegion,
    scratch: dict[str, TensorRegion],
    consts: TensorRegion,
    design: DesignPoint,
    *,
    rows: int,
    cols: int,
    tag: str,
    neg1_off: int = 0,
    one_off: int = 1,
) -> list[CommandBlock]:
    """p = softmax(src) over ``rows`` independent rows of ``cols`` elements.

    The numerically-stable max/exp/sum/recip chain at explicit regions.
    ``scratch`` holds ``m``/``negm``/``s``/``r`` shaped (rows,) and
    ``zc``/``e`` shaped (rows, cols); ``consts`` must already stage -1.0 at
    ``neg1_off`` and 1.0 at ``one_off`` (the caller owns the staging so one
    consts region can serve several chains).
    """
    m, negm = scratch["m"], scratch["negm"]
    zc, e = scratch["zc"], scratch["e"]
    s, r = scratch["s"], scratch["r"]
    return [
        # m[row] = max_c src[row, c]
        _nest_block(
            (cols, rows), 1,
            (src.base, (1, cols)), None, (m.base, (0, 1)),
            design, opcode="vmax", tag=f"{tag}:rowmax",
            reads=(src,), writes=(m,),
        ),
        _nest_block(
            (rows,), 0,
            (m.base, (1,)), (consts.base + neg1_off, (0,)), (negm.base, (1,)),
            design, opcode="vmul", tag=f"{tag}:negmax",
            reads=(m, consts), writes=(negm,),
        ),
        _nest_block(
            (cols, rows), 0,
            (src.base, (1, cols)), (negm.base, (0, 1)), (zc.base, (1, cols)),
            design, opcode="vadd", tag=f"{tag}:shift",
            reads=(src, negm), writes=(zc,),
        ),
        _nest_block(
            (rows * cols,), 0,
            (zc.base, (1,)), None, (e.base, (1,)),
            design, opcode="vexp", tag=f"{tag}:exp",
            reads=(zc,), writes=(e,),
        ),
        # s[row] = sum_c e[row, c] — MAC against the staged 1.0
        _nest_block(
            (cols, rows), 1,
            (e.base, (1, cols)), (consts.base + one_off, (0, 0)), (s.base, (0, 1)),
            design, opcode="mac", tag=f"{tag}:rowsum",
            reads=(e, consts), writes=(s,),
        ),
        _nest_block(
            (rows,), 0,
            (s.base, (1,)), None, (r.base, (1,)),
            design, opcode="vrecip", tag=f"{tag}:recip",
            reads=(s,), writes=(r,),
        ),
        _nest_block(
            (cols, rows), 0,
            (e.base, (1, cols)), (r.base, (0, 1)), (p.base, (1, cols)),
            design, opcode="vmul", tag=f"{tag}:softmax",
            reads=(e, r), writes=(p,),
        ),
    ]


# ---------------------------------------------------------------------------
# Attention rules (fwd / dx)
# ---------------------------------------------------------------------------

#: additive mask for future positions; exp(x - rowmax) underflows to exactly
#: 0.0 in fp32 for masked entries, so masked softmax weights (and therefore
#: their backward contributions) are exact zeros — matching the jnp oracle.
_MASK_NEG = -1.0e9

_SOFTMAX_KEYS = ("m", "negm", "zc", "e", "s", "r")


def causal_mask_blocks(
    mask: TensorRegion, seq: int, *, tag: str = "attn:mask"
) -> list[CommandBlock]:
    """Stage the (seq, seq) additive causal mask in-band: zero the plane,
    then one ranged memset of ``_MASK_NEG`` per row's future positions."""
    blocks = [_memset_block(mask, 0.0)]
    for i in range(seq - 1):
        blocks.append(
            _memset_range(
                mask, i * seq + i + 1, seq - 1 - i, _MASK_NEG, tag=f"{tag}[{i}]"
            )
        )
    return blocks


def attention_scratch_shapes(
    spec: AttentionSpec, pass_: str = "fwd"
) -> dict[str, tuple[int, ...]]:
    """The scratch regions the attention blocks need (head-major (H, S, S)
    score planes; the softmax row scratch folds heads into rows)."""
    S, H = spec.seq, spec.n_heads
    hs, plane = (H * S,), (H, S, S)
    shapes: dict[str, tuple[int, ...]] = {
        "consts": (3,), "mask": (S, S),
        "scores": plane, "ss": plane, "sm": plane, "p": plane,
        "sm_m": hs, "sm_negm": hs, "sm_zc": plane, "sm_e": plane,
        "sm_s": hs, "sm_r": hs,
    }
    if pass_ == "dx":
        shapes.update({
            "dp": plane, "tp": plane, "rs": hs, "negr": hs,
            "dsh": plane, "dsp": plane, "ds": plane,
        })
    return shapes


def _attention_softmax_chain(
    spec: AttentionSpec,
    qkv: TensorRegion,
    scratch: dict[str, TensorRegion],
    design: DesignPoint,
    *,
    tag: str,
) -> list[CommandBlock]:
    """scores -> scaled -> masked -> row-softmax, producing scratch["p"].

    Shared verbatim by fwd and dx (the backward rematerializes p rather
    than keeping the (H, S, S) planes live across the whole step).
    """
    S, H, Dh = spec.seq, spec.n_heads, spec.head_dim
    D, W3 = spec.d, 3 * spec.d
    consts, mask = scratch["consts"], scratch["mask"]
    scores, ss, sm, p = scratch["scores"], scratch["ss"], scratch["sm"], scratch["p"]
    return [
        _memset_at(consts, 0, -1.0),
        _memset_at(consts, 1, 1.0),
        _memset_at(consts, 2, spec.scale),
        *causal_mask_blocks(mask, S, tag=f"{tag}:mask"),
        # scores[h,i,j] = sum_d q[i, h*Dh+d] * k[j, D + h*Dh+d]; the head
        # index rides as a fourth loop dim of the same command.
        _nest_block(
            (Dh, S, S, H), 1,
            (qkv.base, (1, 0, W3, Dh)),
            (qkv.base + D, (1, W3, 0, Dh)),
            (scores.base, (0, 1, S, S * S)),
            design, tag=f"{tag}:scores", reads=(qkv,), writes=(scores,),
        ),
        _nest_block(
            (H * S * S,), 0,
            (scores.base, (1,)), (consts.base + 2, (0,)), (ss.base, (1,)),
            design, opcode="vmul", tag=f"{tag}:scale",
            reads=(scores, consts), writes=(ss,),
        ),
        # the (S, S) mask broadcasts over heads with a zero stride
        _nest_block(
            (S, S, H), 0,
            (ss.base, (1, S, S * S)), (mask.base, (1, S, 0)),
            (sm.base, (1, S, S * S)),
            design, opcode="vadd", tag=f"{tag}:maskadd",
            reads=(ss, mask), writes=(sm,),
        ),
        *softmax_rows_blocks(
            sm, p, {k: scratch[f"sm_{k}"] for k in _SOFTMAX_KEYS}, consts,
            design, rows=H * S, cols=S, tag=f"{tag}:softmax",
        ),
    ]


def attention_fwd_blocks(
    spec: AttentionSpec,
    qkv: TensorRegion,
    ctx: TensorRegion,
    scratch: dict[str, TensorRegion],
    design: DesignPoint,
    *,
    tag: str = "attn:fwd",
) -> list[CommandBlock]:
    S, H, Dh = spec.seq, spec.n_heads, spec.head_dim
    D, W3 = spec.d, 3 * spec.d
    p = scratch["p"]
    return [
        *_attention_softmax_chain(spec, qkv, scratch, design, tag=tag),
        # ctx[i, h*Dh+dd] = sum_j p[h,i,j] * v[j, 2D + h*Dh+dd]
        _nest_block(
            (S, Dh, S, H), 1,
            (p.base, (1, 0, S, S * S)),
            (qkv.base + 2 * D, (W3, 1, 0, Dh)),
            (ctx.base, (0, 1, D, Dh)),
            design, tag=f"{tag}:ctx", reads=(p, qkv), writes=(ctx,),
        ),
    ]


def attention_dx_blocks(
    spec: AttentionSpec,
    qkv: TensorRegion,
    dctx: TensorRegion,
    dqkv: TensorRegion,
    scratch: dict[str, TensorRegion],
    design: DesignPoint,
    *,
    tag: str = "attn:dx",
) -> list[CommandBlock]:
    """d_qkv from d_ctx: dv = p^T dctx; softmax backward
    ds = scale * p * (dp - rowsum(dp * p)); dq = ds k; dk = ds^T q.

    Masked positions contribute exactly 0: p is an exact 0 there (see
    ``_MASK_NEG``) and every ds term carries a factor of p.
    """
    S, H, Dh = spec.seq, spec.n_heads, spec.head_dim
    D, W3 = spec.d, 3 * spec.d
    consts, p = scratch["consts"], scratch["p"]
    dp, tp, rs, negr = scratch["dp"], scratch["tp"], scratch["rs"], scratch["negr"]
    dsh, dsp, ds = scratch["dsh"], scratch["dsp"], scratch["ds"]
    return [
        *_attention_softmax_chain(spec, qkv, scratch, design, tag=tag),
        # dv[j,dd] = sum_i p[h,i,j] * dctx[i, h*Dh+dd]
        _nest_block(
            (S, Dh, S, H), 1,
            (p.base, (S, 0, 1, S * S)),
            (dctx.base, (D, 1, 0, Dh)),
            (dqkv.base + 2 * D, (0, 1, W3, Dh)),
            design, tag=f"{tag}:dv", reads=(p, dctx), writes=(dqkv,),
        ),
        # dp[h,i,j] = sum_dd dctx[i, h*Dh+dd] * v[j, 2D + h*Dh+dd]
        _nest_block(
            (Dh, S, S, H), 1,
            (dctx.base, (1, 0, D, Dh)),
            (qkv.base + 2 * D, (1, W3, 0, Dh)),
            (dp.base, (0, 1, S, S * S)),
            design, tag=f"{tag}:dp", reads=(dctx, qkv), writes=(dp,),
        ),
        _nest_block(
            (H * S * S,), 0,
            (dp.base, (1,)), (p.base, (1,)), (tp.base, (1,)),
            design, opcode="vmul", tag=f"{tag}:tp",
            reads=(dp, p), writes=(tp,),
        ),
        # rs[row] = sum_j (dp * p)[row, j]
        _nest_block(
            (S, H * S), 1,
            (tp.base, (1, S)), (consts.base + 1, (0, 0)), (rs.base, (0, 1)),
            design, opcode="mac", tag=f"{tag}:rowsum",
            reads=(tp, consts), writes=(rs,),
        ),
        _nest_block(
            (H * S,), 0,
            (rs.base, (1,)), (consts.base + 0, (0,)), (negr.base, (1,)),
            design, opcode="vmul", tag=f"{tag}:negrs",
            reads=(rs, consts), writes=(negr,),
        ),
        _nest_block(
            (S, H * S), 0,
            (dp.base, (1, S)), (negr.base, (0, 1)), (dsh.base, (1, S)),
            design, opcode="vadd", tag=f"{tag}:dshift",
            reads=(dp, negr), writes=(dsh,),
        ),
        _nest_block(
            (H * S * S,), 0,
            (dsh.base, (1,)), (p.base, (1,)), (dsp.base, (1,)),
            design, opcode="vmul", tag=f"{tag}:dsp",
            reads=(dsh, p), writes=(dsp,),
        ),
        _nest_block(
            (H * S * S,), 0,
            (dsp.base, (1,)), (consts.base + 2, (0,)), (ds.base, (1,)),
            design, opcode="vmul", tag=f"{tag}:dscale",
            reads=(dsp, consts), writes=(ds,),
        ),
        # dq[i,dd] = sum_j ds[h,i,j] * k[j, D + h*Dh+dd]
        _nest_block(
            (S, Dh, S, H), 1,
            (ds.base, (1, 0, S, S * S)),
            (qkv.base + D, (W3, 1, 0, Dh)),
            (dqkv.base, (0, 1, W3, Dh)),
            design, tag=f"{tag}:dq", reads=(ds, qkv), writes=(dqkv,),
        ),
        # dk[j,dd] = sum_i ds[h,i,j] * q[i, h*Dh+dd]
        _nest_block(
            (S, Dh, S, H), 1,
            (ds.base, (S, 0, 1, S * S)),
            (qkv.base, (W3, 1, 0, Dh)),
            (dqkv.base + D, (0, 1, W3, Dh)),
            design, tag=f"{tag}:dk", reads=(ds, qkv), writes=(dqkv,),
        ),
    ]


def _lower_attention(spec: AttentionSpec, pass_: str, design: DesignPoint) -> NtxProgram:
    S, W3, D = spec.seq, 3 * spec.d, spec.d
    alloc = RegionAllocator()
    rx = alloc.alloc("x", (S, W3), "input")
    if pass_ == "fwd":
        ry = alloc.alloc("y", (S, D), "output")
    else:
        rdy = alloc.alloc("dy", (S, D), "input")
        rdx = alloc.alloc("dx", (S, W3), "output")
    scratch = {
        name: alloc.alloc(name, shape, "scratch")
        for name, shape in attention_scratch_shapes(spec, pass_).items()
    }
    if pass_ == "fwd":
        blocks = attention_fwd_blocks(spec, rx, ry, scratch, design)
    else:
        blocks = attention_dx_blocks(spec, rx, rdy, rdx, scratch, design)
    return NtxProgram(
        name=f"attn{spec.n_heads}h{spec.head_dim}x{S}:{pass_}",
        blocks=blocks,
        regions=alloc.regions,
        design=design,
        meta={"spec": spec, "pass": pass_},
    )


# ---------------------------------------------------------------------------
# LayerNorm rules (fwd / dw / dx)
# ---------------------------------------------------------------------------


def layernorm_scratch_shapes(
    spec: LayerNormSpec, pass_: str = "fwd"
) -> dict[str, tuple[int, ...]]:
    rows, d = spec.rows, spec.d
    shapes: dict[str, tuple[int, ...]] = {
        "consts": (4,),
        "mean": (rows,), "negmean": (rows,), "xc": (rows, d),
        "sq": (rows, d), "var": (rows,), "vareps": (rows,),
        "rstd": (rows,), "xhat": (rows, d),
    }
    if pass_ == "fwd":
        shapes["yg"] = (rows, d)
    elif pass_ == "dw":
        shapes["dyx"] = (rows, d)
    else:
        shapes.update({
            "dyg": (rows, d), "m1": (rows,), "negm1": (rows,),
            "t2": (rows, d), "m2": (rows,), "negm2": (rows,),
            "a1": (rows, d), "b1": (rows, d), "c1": (rows, d),
        })
    return shapes


def layernorm_stat_blocks(
    spec: LayerNormSpec,
    x: TensorRegion,
    scratch: dict[str, TensorRegion],
    design: DesignPoint,
    *,
    tag: str,
) -> list[CommandBlock]:
    """mean/var/rstd/xhat over the rows — shared by every layernorm pass
    (dW and dX recompute the statistics instead of keeping them live)."""
    rows, d = spec.rows, spec.d
    c = scratch["consts"]
    mean, negmean, xc = scratch["mean"], scratch["negmean"], scratch["xc"]
    sq, var, vareps = scratch["sq"], scratch["var"], scratch["vareps"]
    rstd, xhat = scratch["rstd"], scratch["xhat"]
    return [
        _memset_at(c, 0, 1.0 / d),
        _memset_at(c, 1, -1.0),
        _memset_at(c, 2, spec.eps),
        # mean[r] = sum_col x[r, col] * (1/d) — MAC against the staged 1/d
        _nest_block(
            (d, rows), 1,
            (x.base, (1, d)), (c.base + 0, (0, 0)), (mean.base, (0, 1)),
            design, opcode="mac", tag=f"{tag}:mean",
            reads=(x, c), writes=(mean,),
        ),
        _nest_block(
            (rows,), 0,
            (mean.base, (1,)), (c.base + 1, (0,)), (negmean.base, (1,)),
            design, opcode="vmul", tag=f"{tag}:negmean",
            reads=(mean, c), writes=(negmean,),
        ),
        _nest_block(
            (d, rows), 0,
            (x.base, (1, d)), (negmean.base, (0, 1)), (xc.base, (1, d)),
            design, opcode="vadd", tag=f"{tag}:center",
            reads=(x, negmean), writes=(xc,),
        ),
        _nest_block(
            (rows * d,), 0,
            (xc.base, (1,)), (xc.base, (1,)), (sq.base, (1,)),
            design, opcode="vmul", tag=f"{tag}:square",
            reads=(xc,), writes=(sq,),
        ),
        _nest_block(
            (d, rows), 1,
            (sq.base, (1, d)), (c.base + 0, (0, 0)), (var.base, (0, 1)),
            design, opcode="mac", tag=f"{tag}:var",
            reads=(sq, c), writes=(var,),
        ),
        _nest_block(
            (rows,), 0,
            (var.base, (1,)), (c.base + 2, (0,)), (vareps.base, (1,)),
            design, opcode="vadd", tag=f"{tag}:vareps",
            reads=(var, c), writes=(vareps,),
        ),
        _nest_block(
            (rows,), 0,
            (vareps.base, (1,)), None, (rstd.base, (1,)),
            design, opcode="vrsqrt", tag=f"{tag}:rstd",
            reads=(vareps,), writes=(rstd,),
        ),
        _nest_block(
            (d, rows), 0,
            (xc.base, (1, d)), (rstd.base, (0, 1)), (xhat.base, (1, d)),
            design, opcode="vmul", tag=f"{tag}:xhat",
            reads=(xc, rstd), writes=(xhat,),
        ),
    ]


def _lower_layernorm(spec: LayerNormSpec, pass_: str, design: DesignPoint) -> NtxProgram:
    rows, d = spec.rows, spec.d
    alloc = RegionAllocator()
    rx = alloc.alloc("x", (rows, d), "input")
    if pass_ == "fwd":
        rw = alloc.alloc("w", (2, d), "param")
        rout = alloc.alloc("y", (rows, d), "output")
    elif pass_ == "dw":
        rdy = alloc.alloc("dy", (rows, d), "input")
        rout = alloc.alloc("dw", (2, d), "output")
    else:
        rw = alloc.alloc("w", (2, d), "param")
        rdy = alloc.alloc("dy", (rows, d), "input")
        rout = alloc.alloc("dx", (rows, d), "output")
    scratch = {
        name: alloc.alloc(name, shape, "scratch")
        for name, shape in layernorm_scratch_shapes(spec, pass_).items()
    }
    c = scratch["consts"]
    rstd, xhat = scratch["rstd"], scratch["xhat"]
    blocks = layernorm_stat_blocks(spec, rx, scratch, design, tag=f"layernorm:{pass_}")
    if pass_ == "fwd":
        yg = scratch["yg"]
        blocks += [
            # y = xhat * gamma + beta (gamma = w row 0, beta = w row 1)
            _nest_block(
                (d, rows), 0,
                (xhat.base, (1, d)), (rw.base, (1, 0)), (yg.base, (1, d)),
                design, opcode="vmul", tag="layernorm:fwd:gamma",
                reads=(xhat, rw), writes=(yg,),
            ),
            _nest_block(
                (d, rows), 0,
                (yg.base, (1, d)), (rw.base + d, (1, 0)), (rout.base, (1, d)),
                design, opcode="vadd", tag="layernorm:fwd",
                reads=(yg, rw), writes=(rout,),
            ),
        ]
    elif pass_ == "dw":
        dyx = scratch["dyx"]
        blocks += [
            _memset_at(c, 3, 1.0),
            _nest_block(
                (rows * d,), 0,
                (rdy.base, (1,)), (xhat.base, (1,)), (dyx.base, (1,)),
                design, opcode="vmul", tag="layernorm:dw:dyx",
                reads=(rdy, xhat), writes=(dyx,),
            ),
            # dgamma[col] = sum_r dy[r,col] * xhat[r,col]  (dw row 0)
            _nest_block(
                (rows, d), 1,
                (dyx.base, (d, 1)), (c.base + 3, (0, 0)), (rout.base, (0, 1)),
                design, opcode="mac", tag="layernorm:dw:gamma",
                reads=(dyx, c), writes=(rout,),
            ),
            # dbeta[col] = sum_r dy[r,col]  (dw row 1)
            _nest_block(
                (rows, d), 1,
                (rdy.base, (d, 1)), (c.base + 3, (0, 0)), (rout.base + d, (0, 1)),
                design, opcode="mac", tag="layernorm:dw:beta",
                reads=(rdy, c), writes=(rout,),
            ),
        ]
    else:
        # dx = rstd * (dyg - mean(dyg) - xhat * mean(dyg * xhat)),
        # dyg = dy * gamma, means over the feature dim
        dyg, t2 = scratch["dyg"], scratch["t2"]
        m1, negm1 = scratch["m1"], scratch["negm1"]
        m2, negm2 = scratch["m2"], scratch["negm2"]
        a1, b1, c1 = scratch["a1"], scratch["b1"], scratch["c1"]
        blocks += [
            _nest_block(
                (d, rows), 0,
                (rdy.base, (1, d)), (rw.base, (1, 0)), (dyg.base, (1, d)),
                design, opcode="vmul", tag="layernorm:dx:dyg",
                reads=(rdy, rw), writes=(dyg,),
            ),
            _nest_block(
                (d, rows), 1,
                (dyg.base, (1, d)), (c.base + 0, (0, 0)), (m1.base, (0, 1)),
                design, opcode="mac", tag="layernorm:dx:m1",
                reads=(dyg, c), writes=(m1,),
            ),
            _nest_block(
                (rows,), 0,
                (m1.base, (1,)), (c.base + 1, (0,)), (negm1.base, (1,)),
                design, opcode="vmul", tag="layernorm:dx:negm1",
                reads=(m1, c), writes=(negm1,),
            ),
            _nest_block(
                (rows * d,), 0,
                (dyg.base, (1,)), (xhat.base, (1,)), (t2.base, (1,)),
                design, opcode="vmul", tag="layernorm:dx:t2",
                reads=(dyg, xhat), writes=(t2,),
            ),
            _nest_block(
                (d, rows), 1,
                (t2.base, (1, d)), (c.base + 0, (0, 0)), (m2.base, (0, 1)),
                design, opcode="mac", tag="layernorm:dx:m2",
                reads=(t2, c), writes=(m2,),
            ),
            _nest_block(
                (rows,), 0,
                (m2.base, (1,)), (c.base + 1, (0,)), (negm2.base, (1,)),
                design, opcode="vmul", tag="layernorm:dx:negm2",
                reads=(m2, c), writes=(negm2,),
            ),
            _nest_block(
                (d, rows), 0,
                (dyg.base, (1, d)), (negm1.base, (0, 1)), (a1.base, (1, d)),
                design, opcode="vadd", tag="layernorm:dx:a",
                reads=(dyg, negm1), writes=(a1,),
            ),
            _nest_block(
                (d, rows), 0,
                (xhat.base, (1, d)), (negm2.base, (0, 1)), (b1.base, (1, d)),
                design, opcode="vmul", tag="layernorm:dx:b",
                reads=(xhat, negm2), writes=(b1,),
            ),
            _nest_block(
                (rows * d,), 0,
                (a1.base, (1,)), (b1.base, (1,)), (c1.base, (1,)),
                design, opcode="vadd", tag="layernorm:dx:ab",
                reads=(a1, b1), writes=(c1,),
            ),
            _nest_block(
                (d, rows), 0,
                (c1.base, (1, d)), (rstd.base, (0, 1)), (rout.base, (1, d)),
                design, opcode="vmul", tag="layernorm:dx",
                reads=(c1, rstd), writes=(rout,),
            ),
        ]
    return NtxProgram(
        name=f"layernorm{rows}x{d}:{pass_}",
        blocks=blocks,
        regions=alloc.regions,
        design=design,
        meta={"spec": spec, "pass": pass_},
    )


# ---------------------------------------------------------------------------
# Residual / embedding / positional-embedding rules
# ---------------------------------------------------------------------------


def _lower_residual(spec: ResidualAddSpec, pass_: str, design: DesignPoint) -> NtxProgram:
    n = spec.size
    alloc = RegionAllocator()
    if pass_ == "fwd":
        rx0 = alloc.alloc("x", spec.shape, "input")
        rx1 = alloc.alloc("x2", spec.shape, "input")
        ry = alloc.alloc("y", spec.shape, "output")
        blocks = [
            _nest_block(
                (n,), 0,
                (rx0.base, (1,)), (rx1.base, (1,)), (ry.base, (1,)),
                design, opcode="vadd", tag="residual:fwd",
                reads=(rx0, rx1), writes=(ry,),
            )
        ]
    else:
        # the gradient passes through unchanged to each branch; the graph
        # compiler emits one copy per incoming edge
        rdy = alloc.alloc("dy", spec.shape, "input")
        rdx = alloc.alloc("dx", spec.shape, "output")
        blocks = [
            _nest_block(
                (n,), 0,
                (rdy.base, (1,)), None, (rdx.base, (1,)),
                design, opcode="copy", tag="residual:dx",
                reads=(rdy,), writes=(rdx,),
            )
        ]
    return NtxProgram(
        name=f"residual{n}:{pass_}",
        blocks=blocks,
        regions=alloc.regions,
        design=design,
        meta={"spec": spec, "pass": pass_},
    )


def _lower_embedding(spec: EmbeddingSpec, pass_: str, design: DesignPoint) -> NtxProgram:
    rows, V, d = spec.rows, spec.vocab, spec.d
    alloc = RegionAllocator()
    rx = alloc.alloc("x", (rows, V), "input")  # one-hot token rows
    if pass_ == "fwd":
        rw = alloc.alloc("w", (V, d), "param")
        rout = alloc.alloc("y", (rows, d), "output")
        sizes, n_red, rd0, rd1, wr = matmul_nest(
            rows, d, V, "fwd", rx.base, rw.base, rout.base
        )
        reads = (rx, rw)
    else:
        rdy = alloc.alloc("dy", (rows, d), "input")
        rout = alloc.alloc("dw", (V, d), "output")
        sizes, n_red, rd0, rd1, wr = matmul_nest(
            rows, d, V, "dw", rx.base, rdy.base, rout.base
        )
        reads = (rx, rdy)
    block = _nest_block(
        sizes, n_red, rd0, rd1, wr, design,
        tag=f"embed:{pass_}", reads=reads, writes=(rout,),
    )
    return NtxProgram(
        name=f"embed{V}x{d}:{pass_}",
        blocks=[block],
        regions=alloc.regions,
        design=design,
        meta={"spec": spec, "pass": pass_},
    )


def _lower_posembed(spec: PosEmbedSpec, pass_: str, design: DesignPoint) -> NtxProgram:
    B, S, d = spec.batch, spec.seq, spec.d
    alloc = RegionAllocator()
    if pass_ == "fwd":
        rx = alloc.alloc("x", (B, S, d), "input")
        rw = alloc.alloc("w", (S, d), "param")
        ry = alloc.alloc("y", (B, S, d), "output")
        blocks = [
            # P broadcasts over the batch dim with a zero stride
            _nest_block(
                (d, S, B), 0,
                (rx.base, (1, d, S * d)), (rw.base, (1, d, 0)),
                (ry.base, (1, d, S * d)),
                design, opcode="vadd", tag="posembed:fwd",
                reads=(rx, rw), writes=(ry,),
            )
        ]
    elif pass_ == "dw":
        rdy = alloc.alloc("dy", (B, S, d), "input")
        rone = alloc.alloc("one", (1,), "scratch")
        rdw = alloc.alloc("dw", (S, d), "output")
        blocks = [
            _memset_at(rone, 0, 1.0),
            # dP[s, c] = sum_b dy[b, s, c] — MAC against the staged 1.0
            _nest_block(
                (B, d, S), 1,
                (rdy.base, (S * d, 1, d)), (rone.base, (0, 0, 0)),
                (rdw.base, (0, 1, d)),
                design, opcode="mac", tag="posembed:dw",
                reads=(rdy, rone), writes=(rdw,),
            ),
        ]
    else:
        rdy = alloc.alloc("dy", (B, S, d), "input")
        rdx = alloc.alloc("dx", (B, S, d), "output")
        blocks = [
            _nest_block(
                (B * S * d,), 0,
                (rdy.base, (1,)), None, (rdx.base, (1,)),
                design, opcode="copy", tag="posembed:dx",
                reads=(rdy,), writes=(rdx,),
            )
        ]
    return NtxProgram(
        name=f"posembed{B}x{S}x{d}:{pass_}",
        blocks=blocks,
        regions=alloc.regions,
        design=design,
        meta={"spec": spec, "pass": pass_},
    )


# ---------------------------------------------------------------------------
# The lowering registry + entry point
# ---------------------------------------------------------------------------

#: spec type -> {pass name -> rule fn(spec, pass_, design) -> NtxProgram}
_LOWERINGS: dict[type, dict[str, object]] = {}
#: spec type -> factory(pass_) -> Exception, raised for unregistered passes
_UNSUPPORTED: dict[type, object] = {}

ALL_PASSES = (*PASSES, "upd")  # canonical ordering for introspection


def register_lowering(spec_type: type, *passes: str):
    """Decorator: register ``fn(spec, pass_, design)`` as the lowering rule
    for ``spec_type`` under each named pass.

    New layer types plug into :func:`lower` this way instead of growing a
    dispatch ladder; :func:`supported_matrix` introspects the result.
    """
    if not passes:
        raise ValueError("register_lowering needs at least one pass name")

    def deco(fn):
        table = _LOWERINGS.setdefault(spec_type, {})
        for p in passes:
            if p in table:
                raise ValueError(
                    f"{spec_type.__name__} pass {p!r} already registered"
                )
            table[p] = fn
        return fn

    return deco


def register_unsupported(spec_type: type, make_error):
    """Declare what :func:`lower` raises for ``spec_type`` passes with no
    registered rule. ``make_error(pass_)`` returns the exception instance:
    ``NotImplementedError`` for meaningful-but-unsupported combinations,
    ``ValueError`` for nonsensical pass names (the precise split the support
    -matrix tests pin)."""
    _UNSUPPORTED[spec_type] = make_error
    return make_error


def _registry_entry(spec) -> tuple[type, dict] | None:
    for klass in type(spec).__mro__:
        if klass in _LOWERINGS or klass in _UNSUPPORTED:
            return klass, _LOWERINGS.get(klass, {})
    return None


def lower(spec, pass_: str = "fwd", *, design: DesignPoint = NTX_DESIGN) -> NtxProgram:
    """Lower one layer spec + pass to an :class:`NtxProgram`.

    Dispatches through the lowering registry (:func:`register_lowering`);
    :func:`supported_matrix` renders the live support matrix. Combinations
    outside it raise what their :func:`register_unsupported` entry declares:
    ``NotImplementedError`` when the pass is meaningful but genuinely
    unsupported (overlapping-pool dX, flatten standalone, embedding dX),
    ``ValueError`` when the pass name itself is nonsensical for the spec
    (e.g. relu ``dw`` — no parameters exist). Unknown spec types raise
    ``TypeError``.
    """
    entry = _registry_entry(spec)
    if entry is None:
        raise TypeError(f"no lowering rule for {type(spec).__name__}")
    klass, table = entry
    fn = table.get(pass_)
    if fn is not None:
        return fn(spec, pass_, design)
    make_error = _UNSUPPORTED.get(klass)
    if make_error is None:
        raise ValueError(
            f"{klass.__name__} has no {pass_!r} pass; "
            f"registered: {tuple(table)}"
        )
    raise make_error(pass_)


def supported_matrix() -> dict[str, tuple[str, ...]]:
    """Spec-type name -> lowerable passes, straight from the registry.

    The docs' support-matrix table is generated from this (see
    ``docs/architecture.md``) instead of being hand-maintained; spec types
    that never lower standalone (flatten) appear with an empty tuple.
    """
    known = set(_LOWERINGS) | set(_UNSUPPORTED)
    return {
        klass.__name__: tuple(
            p for p in ALL_PASSES if p in _LOWERINGS.get(klass, {})
        )
        for klass in sorted(known, key=lambda k: k.__name__)
    }


def lower_layer(spec, *, design: DesignPoint = NTX_DESIGN) -> dict[str, NtxProgram]:
    """All registered training passes of one layer, keyed by pass name.

    Parameterized layers (matmul/conv/bias/layernorm) get fwd+dw+dx; relu,
    (non-overlapping) pooling, attention and residual get fwd+dx; embedding
    gets fwd+dw — the pass set comes from the registry.
    """
    entry = _registry_entry(spec)
    if entry is None:
        raise TypeError(f"no lowering rule for {type(spec).__name__}")
    klass, table = entry
    if not table:
        raise _UNSUPPORTED[klass]("fwd")
    return {
        p: lower(spec, p, design=design) for p in ALL_PASSES if p in table
    }


# -- registrations for the existing rule set --------------------------------


@register_lowering(MatmulSpec, *PASSES)
def _matmul_rule(spec, pass_, design):
    return _lower_matmul(spec, pass_, design)


register_unsupported(
    MatmulSpec,
    lambda p: ValueError(f"unknown matmul pass {p!r}; expected one of {PASSES}"),
)


@register_lowering(Conv2dSpec, *PASSES)
def _conv_rule(spec, pass_, design):
    if pass_ == "fwd":
        return _lower_conv_fwd(spec, design)
    if pass_ == "dw":
        return _lower_conv_dw(spec, design)
    return _lower_conv_dx(spec, design)


register_unsupported(
    Conv2dSpec,
    lambda p: ValueError(f"unknown conv pass {p!r}; expected one of {PASSES}"),
)


@register_lowering(MaxPool2dSpec, "fwd", "dx")
def _maxpool_rule(spec, pass_, design):
    # dx lowers for window == stride only (maxpool_dx_blocks raises otherwise)
    return _lower_maxpool(spec, design) if pass_ == "fwd" else _lower_maxpool_dx(spec, design)


register_unsupported(
    MaxPool2dSpec,
    lambda p: ValueError(
        f"maxpool has no {p!r} pass (no parameters); supported: fwd, dx"
    ),
)


@register_lowering(ReluSpec, "fwd", "dx")
def _relu_rule(spec, pass_, design):
    return _lower_relu(spec, design) if pass_ == "fwd" else _lower_relu_dx(spec, design)


register_unsupported(
    ReluSpec,
    lambda p: ValueError(
        f"relu has no {p!r} pass (no parameters); supported: fwd, dx"
    ),
)


@register_lowering(BiasSpec, *PASSES)
def _bias_rule(spec, pass_, design):
    return _lower_bias(spec, pass_, design)


register_unsupported(
    BiasSpec,
    lambda p: ValueError(f"unknown bias pass {p!r}; expected one of {PASSES}"),
)


@register_lowering(SoftmaxXentSpec, "dx")
def _softmax_xent_rule(spec, pass_, design):
    return _lower_softmax_xent_grad(spec, design)


register_unsupported(
    SoftmaxXentSpec,
    lambda p: NotImplementedError(
        "softmax-cross-entropy lowers only its gradient (pass 'dx'); "
        "the scalar loss value is computed on the driver core"
    ),
)


@register_lowering(SgdUpdateSpec, "upd")
def _sgd_rule(spec, pass_, design):
    return _lower_sgd_update(spec, design)


register_unsupported(
    SgdUpdateSpec,
    lambda p: ValueError(f"sgd update only has the 'upd' pass, got {p!r}"),
)


register_unsupported(
    FlattenSpec,
    lambda p: NotImplementedError(
        "flatten is a zero-copy view; only the graph compiler "
        "(repro.lower.graph) consumes it, by aliasing regions"
    ),
)


# -- registrations for the transformer/LM rule set ---------------------------


@register_lowering(AttentionSpec, "fwd", "dx")
def _attention_rule(spec, pass_, design):
    return _lower_attention(spec, pass_, design)


register_unsupported(
    AttentionSpec,
    lambda p: ValueError(
        f"attention has no {p!r} pass (no parameters); supported: fwd, dx"
    ),
)


@register_lowering(LayerNormSpec, *PASSES)
def _layernorm_rule(spec, pass_, design):
    return _lower_layernorm(spec, pass_, design)


register_unsupported(
    LayerNormSpec,
    lambda p: ValueError(
        f"unknown layernorm pass {p!r}; expected one of {PASSES}"
    ),
)


@register_lowering(ResidualAddSpec, "fwd", "dx")
def _residual_rule(spec, pass_, design):
    return _lower_residual(spec, pass_, design)


register_unsupported(
    ResidualAddSpec,
    lambda p: ValueError(
        f"residual-add has no {p!r} pass (no parameters); supported: fwd, dx"
    ),
)


@register_lowering(EmbeddingSpec, "fwd", "dw")
def _embedding_rule(spec, pass_, design):
    return _lower_embedding(spec, pass_, design)


def _embedding_unsupported(p):
    if p == "dx":
        return NotImplementedError(
            "embedding has no dX lowering; its input is the one-hot token "
            "stream, which carries no gradient"
        )
    return ValueError(f"unknown embedding pass {p!r}; expected one of {PASSES}")


register_unsupported(EmbeddingSpec, _embedding_unsupported)


@register_lowering(PosEmbedSpec, *PASSES)
def _posembed_rule(spec, pass_, design):
    return _lower_posembed(spec, pass_, design)


register_unsupported(
    PosEmbedSpec,
    lambda p: ValueError(
        f"unknown posembed pass {p!r}; expected one of {PASSES}"
    ),
)

"""The vectorized ntx_execute fast path: bit-equivalence + speed.

The fast path detects affine-dense mac/copy/memset commands and evaluates
them with gathered numpy views while preserving the loop interpreter's exact
accumulation order and rounding points — so every test here asserts
*bit-identical* results, not allclose. Anything the fast path cannot prove
safe (aliasing, out-of-range, exotic init/store levels) must fall back to
the loops, which the randomized sweep exercises too.
"""

import time

import numpy as np
import pytest

from repro.core import ntx
from repro.core.ntx import MAX_LOOPS, Agu, NtxCommand
from repro.lower.rules import conv2d_fwd_template, matmul_template


def _both(cmd, mem, wide=True):
    slow = ntx.ntx_execute(cmd, mem, wide=wide, vectorize=False)
    fast = ntx.ntx_execute(cmd, mem, wide=wide, vectorize=True)
    return slow, fast


def test_matmul_bit_identical_both_widths():
    rng = np.random.RandomState(0)
    mem = rng.randn(3 * 32 * 32 + 8).astype(np.float32)
    cmd = matmul_template(32, 32, 32, 0, 32 * 32, 2 * 32 * 32)
    for wide in (True, False):
        slow, fast = _both(cmd, mem, wide=wide)
        np.testing.assert_array_equal(slow, fast)


def test_conv_command_bit_identical():
    rng = np.random.RandomState(1)
    ih, iw, ci, kh, kw = 9, 8, 4, 3, 3
    mem = np.zeros(2000, np.float32)
    mem[: ih * iw * ci] = rng.randn(ih * iw * ci)
    mem[600 : 600 + kh * kw * ci] = rng.randn(kh * kw * ci)
    cmd = conv2d_fwd_template(ih, iw, ci, kh, kw, 1, 0, 600, 1200)
    slow, fast = _both(cmd, mem)
    np.testing.assert_array_equal(slow, fast)


def test_copy_and_memset_bit_identical():
    rng = np.random.RandomState(2)
    mem = rng.randn(256).astype(np.float32)
    copy = NtxCommand(
        loops=(8, 6, 1, 1, 1), opcode="copy",
        agu_rd0=Agu(0, (1, 8, 0, 0, 0)),
        agu_wr=Agu(100, (6, 1, 0, 0, 0)),  # transpose via AGUs
        init_level=0, store_level=0,
    )
    np.testing.assert_array_equal(*_both(copy, mem))
    memset = NtxCommand(
        loops=(10, 4, 1, 1, 1), opcode="memset",
        agu_rd0=Agu(0, (0,) * MAX_LOOPS),
        agu_wr=Agu(50, (2, 20, 0, 0, 0)),
        init_level=0, store_level=0, init_value=-3.25,
    )
    np.testing.assert_array_equal(*_both(memset, mem))


def test_aliasing_read_write_falls_back_correctly():
    """Overlapping read/write spans must still match the sequential loops
    (the fast path has to refuse and fall back)."""
    rng = np.random.RandomState(3)
    mem = rng.randn(64).astype(np.float32)
    # in-place prefix shift: reads [0..16), writes [8..24)
    cmd = NtxCommand(
        loops=(16, 1, 1, 1, 1), opcode="copy",
        agu_rd0=Agu(0, (1, 0, 0, 0, 0)),
        agu_wr=Agu(8, (1, 0, 0, 0, 0)),
        init_level=0, store_level=0,
    )
    np.testing.assert_array_equal(*_both(cmd, mem))


@pytest.mark.parametrize("seed", range(4))
def test_randomized_commands_bit_identical(seed):
    """Randomized loops/strides/opcodes: fast path (or its fallback) must be
    bit-identical to the loop interpreter in every case."""
    rng = np.random.RandomState(100 + seed)
    for _ in range(60):
        loops = tuple(int(x) for x in rng.randint(1, 4, MAX_LOOPS))
        opcode = ("mac", "copy", "memset", "vadd", "relu", "vmax")[rng.randint(6)]

        def agu():
            return Agu(int(rng.randint(0, 60)),
                       tuple(int(s) for s in rng.randint(-3, 4, MAX_LOOPS)))

        lvl = int(rng.randint(0, MAX_LOOPS + 1))
        cmd = NtxCommand(
            loops=loops, opcode=opcode,
            agu_rd0=agu(),
            agu_rd1=agu() if opcode in ("mac", "vadd") else None,
            agu_wr=agu(),
            init_level=lvl,
            store_level=lvl if opcode == "mac" else int(rng.randint(0, 3)),
            init_value=float(rng.randn()),
        )
        mem = rng.randn(400).astype(np.float32)
        wide = bool(rng.randint(2))
        slow, fast = _both(cmd, mem, wide=wide)
        np.testing.assert_array_equal(slow, fast, err_msg=repr(cmd))


def test_fast_path_20x_on_64cube_matmul():
    """Acceptance floor: >= 20x over the loop interpreter on a 64x64x64
    matmul command, bit-identical results (measured ~100x)."""
    rng = np.random.RandomState(4)
    mem = rng.randn(3 * 64 * 64).astype(np.float32)
    cmd = matmul_template(64, 64, 64, 0, 64 * 64, 2 * 64 * 64)

    t0 = time.perf_counter()
    slow = ntx.ntx_execute(cmd, mem, vectorize=False)
    t_loop = time.perf_counter() - t0

    # min-of-3: the fast leg is sub-ms, so one unlucky scheduler window
    # under full-suite load can eat the whole 20x margin
    t_fast = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        fast = ntx.ntx_execute(cmd, mem, vectorize=True)
        t_fast = min(t_fast, time.perf_counter() - t0)

    np.testing.assert_array_equal(slow, fast)
    assert t_loop / t_fast >= 20.0, f"only {t_loop / t_fast:.1f}x"


def test_inplace_execution_mutates_and_matches():
    rng = np.random.RandomState(5)
    mem = rng.randn(200).astype(np.float32)
    cmd = matmul_template(4, 5, 6, 0, 60, 120)
    copied = ntx.ntx_execute(cmd, mem)
    inplace = mem.copy()
    ret = ntx.ntx_execute(cmd, inplace, inplace=True)
    assert ret is inplace
    np.testing.assert_array_equal(copied, inplace)

"""Model zoo behaviour: every family forward/backward + decode == forward."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import lm
from repro.models.config import ModelConfig, ParallelCtx

CTX = ParallelCtx(attn_backend="xla")


def tiny(name, **kw):
    base = dict(
        name=name, family="dense", n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=97, dtype=jnp.float32,
    )
    base.update(kw)
    return ModelConfig(**base)


FAMILIES = {
    "dense": tiny("dense"),
    "dense_bias_qknorm": tiny("dbq", qkv_bias=True, qk_norm=True),
    "swa": tiny("swa", pattern=(("swa", "mlp"),), window=8),
    "moe_top2": tiny("moe", family="moe", pattern=(("attn", "moe"),), n_experts=4,
                     top_k=2, moe_d_ff=64),
    "moe_top1_shared": tiny("moes", family="moe", pattern=(("attn", "moe"),),
                            n_experts=4, top_k=1, moe_d_ff=64, shared_expert_d_ff=64),
    "hybrid": tiny("hyb", family="hybrid", n_layers=5, window=8, lru_width=64,
                   pattern=(("rec", "mlp"), ("rec", "mlp"), ("swa", "mlp"))),
    "ssm": tiny("ssm", family="ssm", pattern=(("ssm", None),), n_heads=8,
                ssm_headdim=16, ssm_state=16, ssm_groups=2),
    "audio_codebooks": tiny("audio", family="audio", n_codebooks=2, vocab_size=32),
    "tied": tiny("tied", tie_embeddings=True, embed_scale=True),
    "layernorm_gelu": tiny("ln", norm_type="layer", mlp_act="gelu"),
}


def _batch(cfg, b=2, s=16, seed=0):
    rng = jax.random.PRNGKey(seed)
    if cfg.n_codebooks > 1:
        toks = jax.random.randint(rng, (b, s, cfg.n_codebooks), 0, cfg.vocab_size)
        labels = jax.random.randint(rng, (b, s, cfg.n_codebooks), 0, cfg.vocab_size)
    else:
        toks = jax.random.randint(rng, (b, s), 0, cfg.vocab_size)
        labels = jax.random.randint(rng, (b, s), 0, cfg.vocab_size)
    return {"inputs": toks, "labels": labels}


@pytest.mark.parametrize("famname", sorted(FAMILIES))
def test_loss_and_grads_finite(famname):
    cfg = FAMILIES[famname]
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    loss, metrics = lm.lm_loss(params, batch, cfg, CTX)
    assert np.isfinite(float(loss))
    assert float(loss) > 0
    grads = jax.grad(lambda p: lm.lm_loss(p, batch, cfg, CTX)[0])(params)
    for leaf in jax.tree.leaves(grads):
        assert bool(jnp.isfinite(leaf).all())


@pytest.mark.parametrize("famname", ["dense", "swa", "moe_top2", "hybrid", "ssm",
                                     "audio_codebooks", "tied"])
def test_decode_matches_forward(famname):
    cfg = FAMILIES[famname]
    params = lm.init_lm(jax.random.PRNGKey(1), cfg)
    b, s = 2, 16
    batch = _batch(cfg, b, s, seed=1)
    tokens = batch["inputs"]
    logits_full, _ = lm.forward(params, tokens, cfg, CTX)
    cache = lm.init_cache(cfg, b, s, dtype=jnp.float32)
    step = jax.jit(lambda p, c, t, pos: lm.serve_step(p, c, t, pos, cfg, CTX))
    errs = []
    for t in range(s):
        tok = tokens[:, t]
        lg, cache = step(params, cache, tok, jnp.int32(t))
        errs.append(float(jnp.abs(lg - logits_full[:, t]).max()))
    assert max(errs) < 1e-4, errs


def test_embeddings_input_mode():
    cfg = tiny("vlm", family="vlm", input_mode="embeddings")
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, cfg.d_model), jnp.float32)
    labels = jax.random.randint(jax.random.PRNGKey(3), (2, 16), 0, cfg.vocab_size)
    loss, _ = lm.lm_loss(params, {"inputs": x, "labels": labels}, cfg, CTX)
    assert np.isfinite(float(loss))


def test_sliding_window_locality():
    """A token beyond the window must not influence logits (swa semantics)."""
    cfg = tiny("swa2", pattern=(("swa", "mlp"),), window=4, n_layers=1)
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0, cfg.vocab_size)
    logits1, _ = lm.forward(params, toks, cfg, CTX)
    toks2 = toks.at[0, 0].set((toks[0, 0] + 1) % cfg.vocab_size)
    logits2, _ = lm.forward(params, toks2, cfg, CTX)
    # position 11 attends to >= 8 only (window 4): flipping token 0 is invisible
    np.testing.assert_allclose(
        np.asarray(logits1[0, -1]), np.asarray(logits2[0, -1]), atol=1e-5
    )
    # ...but position 1 must change
    assert float(jnp.abs(logits1[0, 1] - logits2[0, 1]).max()) > 1e-6


def test_causality():
    cfg = FAMILIES["dense"]
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 16), 0, cfg.vocab_size)
    logits1, _ = lm.forward(params, toks, cfg, CTX)
    toks2 = toks.at[0, -1].set((toks[0, -1] + 1) % cfg.vocab_size)
    logits2, _ = lm.forward(params, toks2, cfg, CTX)
    np.testing.assert_allclose(
        np.asarray(logits1[0, :-1]), np.asarray(logits2[0, :-1]), atol=1e-5
    )

"""Optimizers + gradient compression (error feedback)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.optim import compression
from repro.optim.optimizers import adamw, apply_updates, clip_by_global_norm, sgd


def _quadratic(opt, steps=60):
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    for _ in range(steps):
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params)
        params = apply_updates(params, upd)
    return float(loss(params))


def test_sgd_converges():
    assert _quadratic(sgd(lr=0.1, momentum=0.9), steps=200) < 1e-3


def test_adamw_converges():
    assert _quadratic(adamw(lr=0.1, weight_decay=0.0), steps=200) < 1e-3


def test_clip_by_global_norm():
    g = {"a": jnp.ones(4) * 10.0, "b": jnp.ones(3) * -10.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    total = jnp.sqrt(sum(jnp.sum(x**2) for x in jax.tree.leaves(clipped)))
    np.testing.assert_allclose(float(total), 1.0, rtol=1e-5)
    assert float(norm) > 1.0


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 200))
def test_quantize_roundtrip_bound(n):
    rng = np.random.RandomState(n)
    x = jnp.asarray(rng.randn(n) * 10 ** rng.uniform(-2, 2), jnp.float32)
    q, scale = compression.quantize_int8(x)
    back = compression.dequantize_int8(q, scale)
    assert float(jnp.abs(back - x).max()) <= float(scale) / 2 + 1e-7


def test_error_feedback_unbiased_over_time():
    """EF-SGD property: accumulated compressed updates track the true sum."""
    rng = np.random.RandomState(0)
    grads_seq = [jnp.asarray(rng.randn(64), jnp.float32) for _ in range(50)]
    err = {"g": jnp.zeros(64)}
    sum_true = jnp.zeros(64)
    sum_comp = jnp.zeros(64)
    for g in grads_seq:
        ghat, _payload, err = compression.compress_with_feedback({"g": g}, err)
        sum_true = sum_true + g
        sum_comp = sum_comp + ghat["g"]
    # residual is bounded by the last error state, not growing with T
    resid = float(jnp.abs(sum_true - sum_comp).max())
    assert resid <= float(jnp.abs(err["g"]).max()) + 1e-5


def test_compression_payload_is_int8():
    g = {"w": jnp.ones((8, 8))}
    err = compression.init_error_state(g)
    _, payload, _ = compression.compress_with_feedback(g, err)
    q, scale = payload["w"]
    assert q.dtype == jnp.int8
    assert scale.dtype == jnp.float32

"""The region fuser: fused Pallas kernels vs the per-node walk vs jax.grad.

The fusion pass must be invisible to numerics: ``run_pallas`` on a fused
whole-step program has to reproduce ``jax.grad`` + the SGD update to the
same tolerances as ``tests/test_graph.py``, match the per-node
``fuse=False`` walk near bit-for-bit, and jit once — region keys included
— across repeated steps. Tie-breaking subtleties (maxpool gradients route
to the FIRST maximal tap, like XLA's select-and-scatter) get their own
case because they only bite on plateaued inputs.
"""

import numpy as np
import pytest

from repro.lower import (
    PlanCache,
    RegionSpec,
    lower_training_step,
    paper_cnn_graph,
    plan_fusion,
    run_pallas,
    run_reference,
)

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from benchmarks import workloads  # noqa: E402
from repro.kernels import ref  # noqa: E402
from repro.lower.rules import (  # noqa: E402
    BiasSpec,
    Conv2dSpec,
    FlattenSpec,
    MatmulSpec,
    MaxPool2dSpec,
    ReluSpec,
)

WORKLOADS = [
    "paper_cnn",
    pytest.param("googlenet", marks=pytest.mark.slow),
]


def _graph_for(name):
    if name == "paper_cnn":
        return paper_cnn_graph(batch=4, img=16, lr=0.05, momentum=0.9)
    return workloads.network_graph(name, batch=2, lr=0.05, momentum=0.0)


def _batch_for(graph, seed=0):
    rng = np.random.RandomState(seed)
    h, w, c = graph.input_shape
    x = rng.randn(graph.batch, h, w, c).astype(np.float32)
    labels = rng.randint(0, graph.loss.classes, graph.batch)
    onehot = np.eye(graph.loss.classes, dtype=np.float32)[labels]
    return x, onehot


def _jax_forward_graph(graph, p, x):
    """Any sequential NetworkGraph in plain jax — the autodiff oracle."""
    h = jnp.asarray(x)
    for node in graph.nodes:
        s = node.spec
        if isinstance(s, Conv2dSpec):
            h = ref.conv2d_ref(
                h, p[node.param], stride=s.stride, padding=s.padding
            )
        elif isinstance(s, ReluSpec):
            h = jax.nn.relu(h)
        elif isinstance(s, MaxPool2dSpec):
            h = jax.lax.reduce_window(
                h, -jnp.inf, jax.lax.max,
                (1, s.window, s.window, 1), (1, s.stride, s.stride, 1),
                "VALID",
            )
        elif isinstance(s, FlattenSpec):
            h = h.reshape(h.shape[0], -1)
        elif isinstance(s, MatmulSpec):
            h = h @ p[node.param]
        elif isinstance(s, BiasSpec):
            h = h + p[node.param][None, :]
        else:  # pragma: no cover - new layer types need an oracle rule
            raise TypeError(type(s).__name__)
    return h


# ---------------------------------------------------------------------------
# Region formation on the paper CNN
# ---------------------------------------------------------------------------


def test_paper_cnn_fusion_plan_shape():
    graph = paper_cnn_graph(batch=4, img=16)
    program = lower_training_step(graph)
    fusion = plan_fusion(program)
    # the fused softmax-CE gradient stitches the forward chain to the
    # backward chain: the whole train step is ONE region, zero fallbacks
    assert fusion.n_regions == 1
    assert fusion.fallback_steps == []
    assert fusion.coverage >= 0.9
    region = next(s.region for s in fusion.segments if s.region is not None)
    assert region.label.startswith("fused[c1:fwd..")
    assert any(st.node == "loss" and st.pass_ == "dx" for st in region.stages)
    # intermediates stay in scratch: only program outputs escape
    out_names = {n for n, _ in region.outputs}
    assert "a_c1" not in out_names and "a_c2" not in out_names
    assert f"d_{graph.logits_edge}" not in out_names


def test_fusion_plan_disables_update_fusion_for_mesh_shards():
    graph = paper_cnn_graph(batch=4, img=16)
    program = lower_training_step(graph)
    fusion = plan_fusion(program, fuse_updates=False)
    # updates must stay per-node so the gradient psum can run before them
    assert all(
        st.pass_ != "upd"
        for seg in fusion.segments
        if seg.region is not None
        for st in seg.region.stages
    )
    assert any(s.endswith(":upd") for s in fusion.fallback_steps)


# ---------------------------------------------------------------------------
# Numerics: fused == unfused == reference == jax.grad
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", WORKLOADS)
def test_fused_step_matches_jax_grad(name):
    graph = _graph_for(name)
    program = lower_training_step(graph)
    params = graph.init_params(seed=1)
    x, onehot = _batch_for(graph)
    inputs = {graph.input_edge: x, graph.label_edge: onehot, **params}

    cache = PlanCache()
    outs = run_pallas(program, inputs, cache=cache, fuse=True)

    def loss_fn(p):
        z = _jax_forward_graph(graph, p, x)
        return -jnp.mean(jnp.sum(jax.nn.log_softmax(z) * onehot, axis=1))

    jp = {
        k: jnp.asarray(v) for k, v in params.items() if not k.startswith("v_")
    }
    grads = jax.grad(loss_fn)(jp)
    # the googlenet trunk contracts over 25k+ elements per conv tap, so
    # summation-order noise vs the oracle gets the run_reference band
    rtol, atol = (2e-3, 1e-4) if name == "googlenet" else (1e-3, 1e-5)
    z = _jax_forward_graph(graph, jp, x)
    np.testing.assert_allclose(
        np.asarray(outs[graph.logits_edge]), np.asarray(z),
        rtol=1e-4, atol=1e-5,
    )
    for p in graph.param_shapes():
        g = np.asarray(grads[p])
        np.testing.assert_allclose(
            np.asarray(outs[f"d_{p}"]), g, rtol=rtol, atol=atol, err_msg=p
        )
        if graph.momentum:
            v_new = graph.momentum * params[f"v_{p}"] + g
            np.testing.assert_allclose(
                np.asarray(outs[f"v_{p}_new"]), v_new,
                rtol=rtol, atol=atol, err_msg=p,
            )
        else:
            v_new = g
        np.testing.assert_allclose(
            np.asarray(outs[f"{p}_new"]), params[p] - graph.lr * v_new,
            rtol=rtol, atol=atol, err_msg=p,
        )


@pytest.mark.parametrize("name", WORKLOADS)
def test_fused_matches_unfused_and_reference(name):
    graph = _graph_for(name)
    program = lower_training_step(graph)
    params = graph.init_params(seed=2)
    x, onehot = _batch_for(graph, seed=3)
    inputs = {graph.input_edge: x, graph.label_edge: onehot, **params}

    cache = PlanCache()
    fused = run_pallas(program, inputs, cache=cache, fuse=True)
    unfused = run_pallas(program, inputs, cache=cache, fuse=False)
    assert set(fused) == set(unfused)
    for k in fused:
        np.testing.assert_allclose(
            np.asarray(fused[k]), np.asarray(unfused[k]),
            rtol=1e-5, atol=1e-6, err_msg=k,
        )
    ref_outs = run_reference(program, inputs)
    for k in ref_outs:
        np.testing.assert_allclose(
            np.asarray(fused[k]), ref_outs[k], rtol=2e-3, atol=1e-5,
            err_msg=k,
        )


def test_maxpool_grad_tie_breaking_matches_xla():
    """Plateaued windows: the gradient goes to the FIRST maximal tap."""
    from repro.kernels.fused import _pool_dx_tile
    from repro.lower.rules import MaxPool2dSpec as MP

    spec = MP(4, 4, 2)
    x = jnp.asarray(
        np.ones((2, 4, 4, 2), np.float32)  # every window is all-ties
    )
    g = jnp.asarray(np.random.RandomState(0).randn(2, 2, 2, 2).astype(np.float32))

    def pool(x):
        return jax.lax.reduce_window(
            x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
        )

    _, vjp = jax.vjp(pool, x)
    want = vjp(g)[0]
    got = _pool_dx_tile(x, g, spec)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=0)


# ---------------------------------------------------------------------------
# Plan cache: region keys + the step-level plan jit once, retrace never
# ---------------------------------------------------------------------------


def test_fused_plans_zero_retrace_and_region_keys():
    graph = paper_cnn_graph(batch=4, img=16)
    program = lower_training_step(graph)
    params = graph.init_params(seed=0)
    x, onehot = _batch_for(graph)
    inputs = {graph.input_edge: x, graph.label_edge: onehot, **params}

    cache = PlanCache()
    run_pallas(program, inputs, cache=cache, fuse=True)
    keys = list(cache._plans)
    assert any(isinstance(k[0], RegionSpec) for k in keys)
    assert any(k[0] == "train_step" for k in keys)
    traces = {k: p.traces for k, p in cache._plans.items()}
    assert all(t == 1 for t in traces.values())

    hits0 = cache.hits
    run_pallas(program, inputs, cache=cache, fuse=True)
    assert {k: p.traces for k, p in cache._plans.items()} == traces
    assert len(cache._plans) == len(keys)
    assert cache.hits > hits0

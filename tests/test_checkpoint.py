"""Checkpointing: atomic roundtrip, retention, async, torn-write immunity."""

import json
import shutil
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as ckpt


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 16)), "b": jnp.zeros(16, jnp.bfloat16)},
        "opt": {"mu": jnp.ones((8, 16))},
        "step": jnp.int32(5),
    }


def test_roundtrip_identity(tmp_path):
    s = _state()
    ckpt.save(tmp_path, 5, s, extras={"iterator": {"seed": 1, "step": 5, "batch_size": 2}})
    template = jax.tree.map(jnp.zeros_like, s)
    restored, extras = ckpt.restore(tmp_path, template)
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype
    assert extras["iterator"]["step"] == 5


def test_latest_and_retention(tmp_path):
    s = _state()
    for step in [1, 2, 3, 4, 5]:
        ckpt.save(tmp_path, step, s, keep=3)
    assert ckpt.latest_step(tmp_path) == 5
    kept = sorted(p.name for p in Path(tmp_path).iterdir())
    assert kept == ["step_00000003", "step_00000004", "step_00000005"]


def test_torn_write_ignored(tmp_path):
    s = _state()
    ckpt.save(tmp_path, 1, s)
    # simulate a crash mid-write: a tmp dir and a final dir missing manifest
    (Path(tmp_path) / ".tmp-step_00000002").mkdir()
    broken = Path(tmp_path) / "step_00000003"
    broken.mkdir()
    (broken / "leaf_0.npy").write_bytes(b"garbage")
    assert ckpt.latest_step(tmp_path) == 1
    template = jax.tree.map(jnp.zeros_like, s)
    restored, _ = ckpt.restore(tmp_path, template)
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["w"]), np.asarray(s["params"]["w"])
    )


def test_async_checkpointer(tmp_path):
    s = _state()
    ac = ckpt.AsyncCheckpointer(tmp_path)
    ac.save(7, s, extras={"step": 7, "iterator": {"seed": 0, "step": 7, "batch_size": 1}})
    ac.wait()
    assert ckpt.latest_step(tmp_path) == 7


def test_restore_rejects_shape_mismatch(tmp_path):
    s = _state()
    ckpt.save(tmp_path, 1, s)
    bad = dict(s, params={"w": jnp.zeros((4, 4)), "b": s["params"]["b"]})
    try:
        ckpt.restore(tmp_path, bad)
        raise AssertionError("expected shape mismatch")
    except AssertionError as e:
        assert "expected shape mismatch" not in str(e)

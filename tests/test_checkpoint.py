"""Checkpointing: atomic roundtrip, retention, async, torn-write immunity."""

import json
import shutil
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as ckpt


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 16)), "b": jnp.zeros(16, jnp.bfloat16)},
        "opt": {"mu": jnp.ones((8, 16))},
        "step": jnp.int32(5),
    }


def test_roundtrip_identity(tmp_path):
    s = _state()
    ckpt.save(tmp_path, 5, s, extras={"iterator": {"seed": 1, "step": 5, "batch_size": 2}})
    template = jax.tree.map(jnp.zeros_like, s)
    restored, extras = ckpt.restore(tmp_path, template)
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype
    assert extras["iterator"]["step"] == 5


def test_latest_and_retention(tmp_path):
    s = _state()
    for step in [1, 2, 3, 4, 5]:
        ckpt.save(tmp_path, step, s, keep=3)
    assert ckpt.latest_step(tmp_path) == 5
    kept = sorted(p.name for p in Path(tmp_path).iterdir())
    assert kept == ["step_00000003", "step_00000004", "step_00000005"]


def test_torn_write_ignored(tmp_path):
    s = _state()
    ckpt.save(tmp_path, 1, s)
    # simulate a crash mid-write: a tmp dir and a final dir missing manifest
    (Path(tmp_path) / ".tmp-step_00000002").mkdir()
    broken = Path(tmp_path) / "step_00000003"
    broken.mkdir()
    (broken / "leaf_0.npy").write_bytes(b"garbage")
    assert ckpt.latest_step(tmp_path) == 1
    template = jax.tree.map(jnp.zeros_like, s)
    restored, _ = ckpt.restore(tmp_path, template)
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["w"]), np.asarray(s["params"]["w"])
    )


def test_async_checkpointer(tmp_path):
    s = _state()
    ac = ckpt.AsyncCheckpointer(tmp_path)
    ac.save(7, s, extras={"step": 7, "iterator": {"seed": 0, "step": 7, "batch_size": 1}})
    ac.wait()
    assert ckpt.latest_step(tmp_path) == 7


def test_async_failure_propagates_as_checkpoint_error(tmp_path):
    """A failed background save is re-raised on the next save()/wait()."""
    s = _state()
    target = tmp_path / "ck"
    ac = ckpt.AsyncCheckpointer(target)
    ac.save(1, s)
    assert ac.wait()
    # make the directory un-writable-to: the next background save fails
    shutil.rmtree(target)
    target.write_text("now a file, not a directory")
    ac.save(2, s)
    try:
        ac.wait()
        raise AssertionError("expected CheckpointError")
    except ckpt.CheckpointError as e:
        assert "background checkpoint save failed" in str(e)
    # the failure is raised once, then cleared: the checkpointer recovers
    target.unlink()
    ac.save(3, s)
    assert ac.wait()
    assert ckpt.latest_step(target) == 3


def test_async_wait_timeout_bounds_shutdown(tmp_path):
    """wait(timeout) returns False while the writer hangs, True after."""
    import threading

    gate = threading.Event()
    orig_save = ckpt.save

    def slow_save(*args, **kwargs):
        gate.wait()
        return orig_save(*args, **kwargs)

    ac = ckpt.AsyncCheckpointer(tmp_path / "ck")
    try:
        ckpt.save = slow_save
        ac.save(1, _state())
        assert ac.wait(timeout=0.05) is False  # still hung: bounded, no raise
    finally:
        ckpt.save = orig_save
        gate.set()
    assert ac.wait() is True  # a later wait() collects the finished writer
    assert ckpt.latest_step(tmp_path / "ck") == 1


def test_restore_falls_back_over_corrupted_leaf(tmp_path):
    """Corruption past the header check: restore skips to the older step."""
    s = _state()
    ckpt.save(tmp_path, 1, s)
    ckpt.save(tmp_path, 2, s)
    # step 2 passes validate_step_dir (real .npy magic) but is truncated
    leaf = Path(tmp_path) / "step_00000002" / "leaf_0.npy"
    leaf.write_bytes(leaf.read_bytes()[:48])
    template = jax.tree.map(jnp.zeros_like, s)
    import warnings as _warnings

    with _warnings.catch_warnings():
        _warnings.simplefilter("ignore")
        restored, extras = ckpt.restore(tmp_path, template)
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["w"]), np.asarray(s["params"]["w"])
    )
    # the torn step still fails loudly when named explicitly
    try:
        ckpt.restore(tmp_path, template, step=2)
        raise AssertionError("expected a load failure for the torn step")
    except (ckpt.CheckpointError, ValueError):
        pass


def test_restore_rejects_shape_mismatch(tmp_path):
    s = _state()
    ckpt.save(tmp_path, 1, s)
    bad = dict(s, params={"w": jnp.zeros((4, 4)), "b": s["params"]["b"]})
    try:
        ckpt.restore(tmp_path, bad)
        raise AssertionError("expected shape mismatch")
    except AssertionError as e:
        assert "expected shape mismatch" not in str(e)

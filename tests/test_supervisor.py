"""Fault tolerance: crash/restore resume, stragglers, elastic re-mesh."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import DataIterator, InMemoryDataset
from repro.runtime.faults import RetryPolicy
from repro.runtime.supervisor import FailureInjector, StragglerPolicy, Supervisor


def _toy_setup(tmp_path):
    """A linear-regression 'model' so we can check exact-resume numerics."""
    ds = InMemoryDataset.synthetic(50_000, 31, 8, seed=0)
    it = DataIterator(ds, batch_size=4, seed=1)

    def init_state(mesh):
        return {"w": jnp.zeros((31,)), "count": jnp.int32(0)}

    def make_step(mesh):
        @jax.jit
        def step(state, batch):
            x = jax.nn.one_hot(batch["inputs"][:, 0], 31).mean(0)
            w = state["w"] + 0.1 * x
            return {"w": w, "count": state["count"] + 1}, {"loss": jnp.sum(w)}

        return step

    return init_state, make_step, it


def test_run_to_completion(tmp_path):
    init_state, make_step, it = _toy_setup(tmp_path)
    sup = Supervisor(make_step, init_state, it, tmp_path / "ck", ckpt_every=5)
    report = sup.run(12)
    assert report.steps_run == 12
    assert report.restarts == 0


def test_crash_restart_is_exact(tmp_path):
    """State after crash+restore must equal the uninterrupted run."""
    # uninterrupted reference
    init_state, make_step, it = _toy_setup(tmp_path)
    sup = Supervisor(make_step, init_state, it, tmp_path / "a", ckpt_every=4)
    sup.run(16)
    from repro.checkpoint import checkpoint as ckpt

    ref_state, _ = ckpt.restore(tmp_path / "a", init_state(None))

    # crashing run
    init_state, make_step, it2 = _toy_setup(tmp_path)
    inj = FailureInjector({7: "crash", 13: "crash"})
    sup2 = Supervisor(make_step, init_state, it2, tmp_path / "b", ckpt_every=4,
                      injector=inj, sleep_fn=lambda s: None)
    report = sup2.run(16)
    assert report.restarts == 2
    got_state, _ = ckpt.restore(tmp_path / "b", init_state(None))
    np.testing.assert_allclose(
        np.asarray(got_state["w"]), np.asarray(ref_state["w"]), atol=1e-6
    )
    assert int(got_state["count"]) == 16


def test_straggler_logged_and_continues(tmp_path):
    init_state, make_step, it = _toy_setup(tmp_path)
    inj = FailureInjector({3: "straggler"})
    sup = Supervisor(make_step, init_state, it, tmp_path / "c", ckpt_every=5, injector=inj)
    report = sup.run(10)
    assert report.steps_run == 10
    assert report.straggler_events >= 1
    assert any("straggler" in line for line in report.log)


def test_elastic_remesh_failover(tmp_path):
    """After a crash, the job continues on the fallback mesh entry."""
    init_state, make_step, it = _toy_setup(tmp_path)
    inj = FailureInjector({5: "crash"})
    sup = Supervisor(
        make_step, init_state, it, tmp_path / "d", ckpt_every=2,
        injector=inj, meshes=["mesh-large", "mesh-small"],
        sleep_fn=lambda s: None,
    )
    report = sup.run(9)
    assert report.remesh_events == 1
    assert any("re-mesh" in line for line in report.log)
    from repro.checkpoint import checkpoint as ckpt

    st, _ = ckpt.restore(tmp_path / "d", init_state(None))
    assert int(st["count"]) == 9


def test_crash_backoff_follows_retry_schedule(tmp_path):
    """Each restart sleeps the RetryPolicy's delay; progress resets it."""
    init_state, make_step, it = _toy_setup(tmp_path)
    inj = FailureInjector({3: "crash", 9: "crash"})
    slept = []
    sup = Supervisor(make_step, init_state, it, tmp_path / "bo", ckpt_every=2,
                     injector=inj, retry=RetryPolicy(base_delay=0.25),
                     sleep_fn=slept.append)
    report = sup.run(12)
    assert report.restarts == 2
    # steps committed between the crashes reset the attempt counter, so
    # BOTH retries back off at the first-attempt delay
    assert report.backoffs == [0.25, 0.25]
    assert slept == report.backoffs


def test_consecutive_crashes_escalate_then_give_up(tmp_path):
    """Back-to-back failures walk the exponential schedule, then re-raise."""
    from repro.runtime.supervisor import SimulatedFailure

    init_state, make_step, it = _toy_setup(tmp_path)

    class AlwaysCrash:
        def check(self, step):
            raise SimulatedFailure(f"injected crash at step {step}")

    sup = Supervisor(make_step, init_state, it, tmp_path / "gu", ckpt_every=2,
                     injector=AlwaysCrash(),
                     retry=RetryPolicy(max_retries=3, base_delay=0.5),
                     sleep_fn=lambda s: None)
    with pytest.raises(SimulatedFailure):
        sup.run(12)
    assert sup.report.restarts == 4  # 3 retries + the one that gave up
    assert sup.report.backoffs == [0.5, 1.0, 2.0]  # doubling, no progress
    assert any("giving up" in line for line in sup.report.log)


def test_straggler_redispatches_to_backup(tmp_path):
    init_state, make_step, it = _toy_setup(tmp_path)
    inj = FailureInjector({3: "straggler", 6: "straggler"})
    sup = Supervisor(make_step, init_state, it, tmp_path / "rd", ckpt_every=5,
                     injector=inj)
    report = sup.run(10)
    assert report.steps_run == 10
    assert report.redispatches == 2
    assert sum("backup worker" in line for line in report.log) == 2
    # the accounting is optional: redispatch=False records only the event
    init_state, make_step, it = _toy_setup(tmp_path)
    sup2 = Supervisor(make_step, init_state, it, tmp_path / "rd2",
                      ckpt_every=5, injector=FailureInjector({3: "straggler"}),
                      redispatch=False)
    report2 = sup2.run(10)
    assert report2.straggler_events >= 1 and report2.redispatches == 0


def test_checkpoint_error_triggers_restart(tmp_path):
    """A broken checkpoint cadence restarts the loop, not the process."""
    from repro.checkpoint import checkpoint as ckpt

    init_state, make_step, it = _toy_setup(tmp_path)
    fired = []

    class BadCkptOnce:
        def check(self, step):
            if step == 5 and not fired:
                fired.append(step)
                raise ckpt.CheckpointError("background checkpoint save failed")

    sup = Supervisor(make_step, init_state, it, tmp_path / "ce", ckpt_every=2,
                     injector=BadCkptOnce(), sleep_fn=lambda s: None)
    report = sup.run(10)
    assert report.restarts == 1
    assert int(ckpt.restore(tmp_path / "ce", init_state(None))[0]["count"]) == 10


def test_straggler_deadline_uses_paper_model():
    pol = StragglerPolicy(slack=2.0, weight_bytes=300e6, mesh_side=16)
    pol.observe(0.5)
    # paper: T_update = 4*(300MB/60GBps + 16*20us) = 4*(5ms + 0.32ms) ~ 21.3ms
    d = pol.deadline()
    assert 1.0 < d < 2.0  # 2*0.5 + 0.0213


def test_metrics_cb_with_counter_registry_end_to_end(tmp_path):
    """Counters + JSONL through the supervisor, no failures injected."""
    from repro import obs

    init_state, make_step, it = _toy_setup(tmp_path)
    reg = obs.CounterRegistry()
    path = tmp_path / "metrics.jsonl"
    seen = []
    sup = Supervisor(make_step, init_state, it, tmp_path / "m", ckpt_every=5,
                     registry=reg, metrics_path=str(path))
    report = sup.run(12, metrics_cb=lambda step, m: seen.append(step))
    assert report.steps_run == 12
    assert seen == list(range(1, 13))
    assert reg.get("supervisor/steps") == 12
    assert reg.get("supervisor/restarts", 0) == 0
    recs = obs.read_jsonl(path)
    assert [r["step"] for r in recs] == list(range(1, 13))
    for r in recs:
        assert r["schema_version"] == obs.SCHEMA_VERSION
        assert "loss" in r["metrics"]
        assert r["counters"]["steps"] == r["step"]


def test_counters_survive_crash_restore_cycle(tmp_path):
    """Counters roll back with the checkpoint: totals stay exact across a
    simulated failure (replayed steps are not double-counted), while
    lifecycle counters (restarts) survive the rollback."""
    from repro import obs

    init_state, make_step, it = _toy_setup(tmp_path)
    reg = obs.CounterRegistry()
    inj = FailureInjector({7: "crash"})
    path = tmp_path / "metrics.jsonl"
    sup = Supervisor(make_step, init_state, it, tmp_path / "cc", ckpt_every=2,
                     injector=inj, registry=reg, metrics_path=str(path),
                     sleep_fn=lambda s: None)
    report = sup.run(10)
    assert report.steps_run > 10  # steps 7..8 replayed after the crash
    assert report.restarts == 1
    # rollback-to-checkpoint keeps the counter total EXACT despite replay
    assert reg.get("supervisor/steps") == 10
    assert reg.get("supervisor/restarts") == 1
    # the JSONL stream shows the replay (re-run steps appear twice)
    recs = obs.read_jsonl(path)
    steps = [r["step"] for r in recs]
    assert len(steps) == report.steps_run > 10
    assert len(set(steps)) < len(steps)
    assert recs[-1]["step"] == 10
    assert recs[-1]["counters"]["restarts"] == 1

"""ntx_execute opcode edge cases (no hypothesis — always collected).

Covers the non-MAC opcodes (memset, copy, argmax, vmax/vmin, relu, vadd,
vmul) and the accumulator init/store-level corners that the command-queue
partitioner relies on.
"""

import numpy as np
import pytest

from repro.core import ntx
from repro.core.ntx import Agu, MAX_LOOPS, NtxCommand
from repro.lower.rules import matmul_template


def _agu(base, *strides):
    return Agu(base, tuple(strides) + (0,) * (MAX_LOOPS - len(strides)))


def test_memset_fills_strided_region():
    mem = np.arange(32, dtype=np.float32)
    cmd = NtxCommand(
        loops=(8, 1, 1, 1, 1), opcode="memset",
        agu_rd0=_agu(0, 0),  # reads are ignored but addressed
        agu_wr=_agu(4, 2),  # every other word from 4
        init_level=MAX_LOOPS, store_level=0, init_value=7.5,
    )
    out = ntx.ntx_execute(cmd, mem)
    np.testing.assert_array_equal(out[4:20:2], np.full(8, 7.5, np.float32))
    untouched = [i for i in range(32) if not (4 <= i < 20 and (i - 4) % 2 == 0)]
    np.testing.assert_array_equal(out[untouched], mem[untouched])


def test_copy_transposes_via_agus():
    rows, cols = 3, 4
    mem = np.zeros(50, np.float32)
    mem[: rows * cols] = np.arange(rows * cols)
    cmd = NtxCommand(
        loops=(cols, rows, 1, 1, 1), opcode="copy",
        agu_rd0=_agu(0, 1, cols),  # read row-major [i1, i0]
        agu_wr=_agu(20, rows, 1),  # write column-major -> transpose
        init_level=0, store_level=0,
    )
    out = ntx.ntx_execute(cmd, mem)
    want = mem[: rows * cols].reshape(rows, cols).T
    np.testing.assert_array_equal(out[20 : 20 + rows * cols].reshape(cols, rows), want)


def test_argmax_writes_index():
    vec = np.array([3.0, -1.0, 9.0, 9.0, 2.0], np.float32)  # first max wins
    mem = np.concatenate([vec, np.zeros(3, np.float32)])
    cmd = NtxCommand(
        loops=(5, 1, 1, 1, 1), opcode="argmax",
        agu_rd0=_agu(0, 1), agu_wr=_agu(6, 0),
        init_level=MAX_LOOPS, store_level=1,
    )
    out = ntx.ntx_execute(cmd, mem)
    assert out[6] == 2.0


def test_argmax_per_row_with_init_level():
    x = np.array([[1.0, 5.0, 2.0], [7.0, 0.0, 3.0]], np.float32)
    mem = np.concatenate([x.ravel(), np.zeros(4, np.float32)])
    cmd = NtxCommand(
        loops=(3, 2, 1, 1, 1), opcode="argmax",
        agu_rd0=_agu(0, 1, 3), agu_wr=_agu(8, 0, 1),
        init_level=1, store_level=1,  # fresh argmax per row, store per row
    )
    out = ntx.ntx_execute(cmd, mem)
    np.testing.assert_array_equal(out[8:10], [1.0, 0.0])


@pytest.mark.parametrize("op,fn", [("vmax", np.max), ("vmin", np.min)])
def test_vmax_vmin_ignore_init_value(op, fn):
    rng = np.random.RandomState(0)
    vec = rng.randn(16).astype(np.float32) - 5.0  # all negative-ish
    mem = np.concatenate([vec, np.zeros(2, np.float32)])
    cmd = NtxCommand(
        loops=(16, 1, 1, 1, 1), opcode=op,
        agu_rd0=_agu(0, 1), agu_wr=_agu(17, 0),
        init_level=1, store_level=1, init_value=0.0,
    )
    out = ntx.ntx_execute(cmd, mem)
    assert out[17] == np.float32(fn(vec))  # init_value must not leak into max


def test_relu_and_vadd_elementwise():
    a = np.array([-2.0, 3.0, -0.5, 4.0], np.float32)
    b = np.array([1.0, 1.0, 1.0, 1.0], np.float32)
    mem = np.concatenate([a, b, np.zeros(8, np.float32)])
    relu = NtxCommand(
        loops=(4, 1, 1, 1, 1), opcode="relu",
        agu_rd0=_agu(0, 1), agu_wr=_agu(8, 1),
        init_level=0, store_level=0,
    )
    out = ntx.ntx_execute(relu, mem)
    np.testing.assert_array_equal(out[8:12], np.maximum(a, 0.0))
    vadd = NtxCommand(
        loops=(4, 1, 1, 1, 1), opcode="vadd",
        agu_rd0=_agu(0, 1), agu_rd1=_agu(4, 1), agu_wr=_agu(8, 1),
        init_level=0, store_level=0,
    )
    out = ntx.ntx_execute(vadd, mem)
    np.testing.assert_array_equal(out[8:12], a + b)


def test_mac_init_level_max_is_one_running_sum():
    """init_level=MAX_LOOPS: the accumulator is never re-initialized -> the
    final store holds the grand total (plus init_value)."""
    x = np.ones(12, np.float32)
    mem = np.concatenate([x, x, np.zeros(2, np.float32)])
    cmd = NtxCommand(
        loops=(4, 3, 1, 1, 1), opcode="mac",
        agu_rd0=_agu(0, 1, 4), agu_rd1=_agu(12, 1, 4), agu_wr=_agu(25, 0, 0),
        init_level=MAX_LOOPS, store_level=2, init_value=100.0,
    )
    out = ntx.ntx_execute(cmd, mem)
    assert out[25] == 112.0  # 100 + 12 dot-products of 1*1


def test_mac_store_level_0_streams_partial_sums():
    x = np.array([1.0, 2.0, 3.0], np.float32)
    mem = np.concatenate([x, np.ones(3, np.float32), np.zeros(4, np.float32)])
    cmd = NtxCommand(
        loops=(3, 1, 1, 1, 1), opcode="mac",
        agu_rd0=_agu(0, 1), agu_rd1=_agu(3, 1), agu_wr=_agu(6, 1),
        init_level=MAX_LOOPS, store_level=0,
    )
    out = ntx.ntx_execute(cmd, mem)
    np.testing.assert_array_equal(out[6:9], np.cumsum(x))  # prefix sums


def test_wide_false_rounds_every_fma():
    rng = np.random.RandomState(4)
    k = 2048
    a = (rng.randn(k) * 10.0 ** rng.uniform(-3, 3, k)).astype(np.float32)
    b = rng.randn(k).astype(np.float32)
    mem = np.concatenate([a, b, np.zeros(1, np.float32)])
    cmd = matmul_template(1, 1, k, 0, k, 2 * k)
    ref = float(np.dot(a.astype(np.float64), b.astype(np.float64)))
    wide = float(ntx.ntx_execute(cmd, mem, wide=True)[2 * k])
    narrow = float(ntx.ntx_execute(cmd, mem, wide=False)[2 * k])
    assert abs(wide - ref) <= abs(narrow - ref)


def test_invalid_commands_rejected():
    with pytest.raises(ValueError):
        NtxCommand(loops=(1, 1, 1, 1), opcode="mac", agu_rd0=_agu(0, 1))
    with pytest.raises(ValueError):
        NtxCommand(loops=(1, 1, 1, 1, 1), opcode="nope", agu_rd0=_agu(0, 1))
    with pytest.raises(ValueError):
        NtxCommand(loops=(0, 1, 1, 1, 1), opcode="mac", agu_rd0=_agu(0, 1))
    with pytest.raises(ValueError):
        Agu(0, (1, 2))

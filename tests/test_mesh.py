"""Mesh-of-HMCs data parallelism: sharded programs + the link layer.

The contract under test is the §4.9 scaling story made executable:

  * ``shard_training_step`` splits a whole-train-step program across the
    mesh **bit-identically** — ``run_reference`` on the sharded program
    equals the unsharded step with ``assert_array_equal``, not a tolerance
    (batch splits and output-chunk reduce-scatter splits never move an
    accumulator rounding).
  * The allreduce epilogue is explicit: reduce-scatter chunks own every
    ``d_<param>``, update chunks follow, and the weight allgather carries
    ``(n-1)`` chunk transfers of link traffic.
  * The link layer reproduces eqs. (14)-(15) exactly on square meshes,
    serializes congested links, and pins its §4.9 constants to
    ``benchmarks/ntx_model.py``.
  * ``time_mesh_step`` + ``ntx_model.mesh`` agree on parallel efficiency
    within 1% with the paper's >= 95% bar cleared (full 4-size sweep in
    the slow lane; one size in tier-1).

The shard_map gradient oracle against ``jax.grad`` at 1/4/16 fake devices
lives in ``tests/distributed`` (fresh subprocesses own the device count).
"""

import numpy as np
import pytest

from repro.lower import (
    NS_DESIGN,
    lower_training_step,
    paper_cnn_graph,
    parse_mesh,
    reshard_training_step,
    run_reference,
    shard_training_step,
)
from repro.lower.mesh import ALL_HMCS
from repro.runtime.mesh import (
    HOP_LATENCY,
    LINK_BW,
    LinkTransfer,
    MeshInterconnect,
    expected_update_time,
    time_mesh_step,
)


def _inputs(graph, seed=0):
    rng = np.random.RandomState(seed)
    b, img = graph.batch, graph.input_shape[0]
    x = rng.randn(b, img, img, 3).astype(np.float32)
    labels = rng.randint(0, graph.loss.classes, b)
    onehot = np.eye(graph.loss.classes, dtype=np.float32)[labels]
    return {"x": x, "onehot": onehot, **graph.init_params(seed=seed + 1)}


# ---------------------------------------------------------------------------
# Bit-identity: the sharded program IS the unsharded step
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("design,momentum", [
    (None, 0.9),  # NTX + momentum
    (None, 0.0),  # NTX plain SGD
    (NS_DESIGN, 0.9),  # NS: every block carries driver reps
])
@pytest.mark.parametrize("mesh", [(2, 2), (4, 2), (1, 1)])
def test_sharded_bit_identical_to_unsharded(design, momentum, mesh):
    graph = paper_cnn_graph(batch=8, img=8, momentum=momentum)
    kw = {} if design is None else {"design": design}
    prog = lower_training_step(graph, **kw)
    sh = shard_training_step(graph, mesh_shape=mesh, program=prog, **kw)
    inputs = _inputs(graph)
    want = run_reference(prog, inputs)
    got = run_reference(sh.program, inputs)
    assert set(got) == set(want)
    for k in want:
        np.testing.assert_array_equal(got[k], want[k], err_msg=k)


def test_sharded_spilled_program_bit_identical():
    """Spill/fill blits split across shards without touching semantics."""
    graph = paper_cnn_graph(batch=8, img=16)
    prog = lower_training_step(graph, n_clusters=1)  # tiny budget -> spills
    assert prog.meta["spilled"]
    sh = shard_training_step(graph, mesh_shape=(2, 2), program=prog,
                             n_clusters=1)
    inputs = _inputs(graph, seed=3)
    want = run_reference(prog, inputs)
    got = run_reference(sh.program, inputs)
    for k in want:
        np.testing.assert_array_equal(got[k], want[k], err_msg=k)


# ---------------------------------------------------------------------------
# The allreduce epilogue and shard assignment
# ---------------------------------------------------------------------------


def test_allreduce_epilogue_structure():
    graph = paper_cnn_graph(batch=8, img=8, momentum=0.9)
    sh = shard_training_step(graph, mesh_shape=(2, 2))
    n = sh.n_hmcs
    epi = sh.epilogue_blocks()
    assert epi, "no allreduce epilogue emitted"
    reduced = {w for _, b in epi if b.tag.startswith("allreduce:reduce")
               for w in b.writes}
    assert reduced == {f"d_{p}" for p in graph.param_shapes()}
    updated = {w for _, b in epi if b.tag.startswith("allreduce:update")
               for w in b.writes}
    for p in graph.param_shapes():
        assert f"{p}_new" in updated and f"v_{p}_new" in updated
    gathers = [(h, b) for h, b in epi if b.tag.startswith("allgather:")]
    for p, shape in graph.param_shapes().items():
        size = int(np.prod(shape))
        mine = [(h, b) for h, b in gathers if b.reads == (f"{p}_new",)]
        # one chunk per HMC (parameters smaller than the mesh: one per elem)
        assert len(mine) == min(n, size)
        assert sorted(h for h, _ in mine) == list(range(len(mine)))
        # each broadcast carries its chunk to the n-1 other replicas
        total = sum(b.dma_bytes_out for _, b in mine)
        assert total == pytest.approx(size * 4 * (n - 1))


def test_shard_programs_partition_the_combined_stream():
    graph = paper_cnn_graph(batch=8, img=8)
    sh = shard_training_step(graph, mesh_shape=(2, 2))
    owned = [h for h in sh.hmc_of_block if h != ALL_HMCS]
    assert set(owned) == set(range(sh.n_hmcs))
    per_shard = [sh.shard_program(h) for h in range(sh.n_hmcs)]
    replicated = sum(1 for h in sh.hmc_of_block if h == ALL_HMCS)
    assert sum(len(p.blocks) for p in per_shard) == (
        len(sh.program.blocks) + replicated * (sh.n_hmcs - 1)
    )
    # compute commands are conserved: the combined stream carries exactly
    # the unsharded commands plus the allgather identity copies
    gather_cmds = sum(b.n_commands for _, b in sh.epilogue_blocks()
                      if b.tag.startswith("allgather:"))
    assert sh.program.busy_cycles == (
        sh.base_program.busy_cycles
        + sum(b.busy_cycles for _, b in sh.epilogue_blocks()
              if b.tag.startswith("allgather:"))
    )
    assert gather_cmds > 0


def test_mesh_validation_errors():
    graph = paper_cnn_graph(batch=6, img=8)
    with pytest.raises(ValueError, match="does not divide"):
        shard_training_step(graph, mesh_shape=(2, 2))
    with pytest.raises(ValueError, match="not 'RxC'"):
        parse_mesh("2by2")
    assert parse_mesh("2x4") == (2, 4) and parse_mesh((4, 4)) == (4, 4)


# ---------------------------------------------------------------------------
# run_pallas routes (single-device tier-1 coverage; multi-device in slow)
# ---------------------------------------------------------------------------


def test_run_pallas_mesh_routes_match_reference():
    from repro.lower import PlanCache, run_pallas

    graph = paper_cnn_graph(batch=4, img=8, momentum=0.9)
    prog = lower_training_step(graph)
    inputs = _inputs(graph, seed=5)
    want = run_reference(prog, inputs)
    # 1x1: the shard_map path over a single-device mesh
    sh1 = shard_training_step(graph, mesh_shape=(1, 1), program=prog)
    got1 = run_pallas(sh1.program, inputs, cache=PlanCache())
    # 2x2 on one device: the graceful single-device fallback walk
    sh4 = shard_training_step(graph, mesh_shape=(2, 2), program=prog)
    got4 = run_pallas(sh4.program, inputs, cache=PlanCache())
    for got in (got1, got4):
        assert set(got) == set(want)
        for k in want:
            np.testing.assert_allclose(
                np.asarray(got[k]), want[k], rtol=2e-3, atol=1e-5, err_msg=k
            )


# ---------------------------------------------------------------------------
# The link layer (repro.runtime.mesh)
# ---------------------------------------------------------------------------


def test_link_constants_pinned_to_analytical_model():
    M = pytest.importorskip("benchmarks.ntx_model")
    assert LINK_BW == M.LINK_BW
    assert HOP_LATENCY == M.HOP_LATENCY


@pytest.mark.parametrize("side", [2, 4, 8, 16])
def test_systolic_update_matches_eq15(side):
    net = MeshInterconnect(side, side)
    for w in (1e6, 300e6):
        want = 4.0 * (w / LINK_BW + side * HOP_LATENCY)
        assert net.update_time(w) == pytest.approx(want, rel=1e-12)
        assert expected_update_time(w, side, side) == pytest.approx(want)
    # congestion-free on the line embedding
    assert net.systolic_update(300e6).congestion_time == 0.0


def test_single_cube_has_no_update():
    assert MeshInterconnect(1, 1).update_time(300e6) == 0.0


def test_rectangular_mesh_update_matches_closed_form():
    # two passes per non-degenerate axis, each paying its own hop count
    for rows, cols in ((4, 2), (2, 4), (1, 4), (4, 1)):
        net = MeshInterconnect(rows, cols)
        want = sum(2.0 * (300e6 / LINK_BW + ax * HOP_LATENCY)
                   for ax in (rows, cols) if ax > 1)
        assert net.update_time(300e6) == pytest.approx(want, rel=1e-12)
        assert expected_update_time(300e6, rows, cols) == pytest.approx(want)


def test_link_congestion_serializes():
    net = MeshInterconnect(2, 2)
    link = ((0, 0), (0, 1))
    s = net.schedule([LinkTransfer(link, LINK_BW), LinkTransfer(link, LINK_BW)])
    # two 1-second transfers on one link: the second queues a full second
    assert s.transfers[1].queued == pytest.approx(1.0 + HOP_LATENCY)
    assert s.makespan == pytest.approx(2.0 + 2 * HOP_LATENCY)
    # distinct links run concurrently
    s2 = net.schedule([LinkTransfer(((0, 0), (0, 1)), LINK_BW),
                       LinkTransfer(((1, 0), (1, 1)), LINK_BW)])
    assert s2.makespan == pytest.approx(1.0 + HOP_LATENCY)
    assert s2.congestion_time == 0.0


def test_ring_allreduce_wrap_latency():
    # a 1x4 snake ring's wrap edge is a 3-hop store-and-forward path: the
    # ring must run past the congestion-free single-hop floor by the
    # wrap's extra hops, and every step must still serialize cleanly
    net = MeshInterconnect(1, 4)
    n = net.n_hmcs
    step_t = 4e6 / n / LINK_BW + HOP_LATENCY
    floor = 2 * (n - 1) * step_t
    sched = net.ring_allreduce(4e6)
    assert sched.makespan == pytest.approx(floor + 2 * step_t)
    # a square mesh's snake ring closes on a real link: exactly the floor
    sq = MeshInterconnect(2, 2).ring_allreduce(4e6)
    assert sq.makespan == pytest.approx(2 * 3 * step_t)
    assert sq.congestion_time == 0.0
    # two rings sharing the mesh congest: re-run the same transfers twice
    doubled = net.schedule(
        [t for s in (sched, sched) for t in
         (x.transfer for x in s.transfers)]
    )
    assert doubled.congestion_time > 0.0


def test_schedule_rejects_bogus_links():
    net = MeshInterconnect(2, 2)
    with pytest.raises(ValueError, match="nearest-neighbour"):
        net.schedule([LinkTransfer(((0, 0), (1, 1)), 1.0)])
    with pytest.raises(ValueError, match="outside"):
        net.schedule([LinkTransfer(((0, 0), (0, 2)), 1.0)])


# ---------------------------------------------------------------------------
# Executed + timed mesh steps vs the analytical model
# ---------------------------------------------------------------------------


def test_time_mesh_step_composition():
    graph = paper_cnn_graph(batch=8, img=8)
    sh = shard_training_step(graph, mesh_shape=(2, 2))
    tm = time_mesh_step(sh)
    assert tm.t_update == pytest.approx(
        expected_update_time(sh.allreduce_bytes, 2, 2)
    )
    assert tm.t_step == pytest.approx(tm.t_shard + tm.t_update)
    assert tm.speedup == pytest.approx(tm.t_single / tm.t_step)
    assert tm.parallel_eff == pytest.approx(tm.speedup / 4)
    assert tm.shard_cycles > 0 and tm.single_cycles > tm.shard_cycles


def test_mesh_efficiency_executed_one_size():
    """Tier-1 slice of the acceptance gate: one executed mesh size must
    clear 95% parallel efficiency within 1% of ``ntx_model.mesh``."""
    M = pytest.importorskip("benchmarks.ntx_model")
    workloads = pytest.importorskip("benchmarks.workloads")

    graph = workloads.network_graph("googlenet", batch=256)
    sh = shard_training_step(graph, mesh_shape=(2, 2))
    tm = time_mesh_step(sh)
    mod = M.mesh(2, 256, t_image=tm.t_image, weight_bytes=sh.allreduce_bytes)
    assert tm.parallel_eff >= 0.95
    assert abs(tm.parallel_eff - mod.parallel_eff) / mod.parallel_eff < 0.01


@pytest.mark.slow
def test_mesh_efficiency_executed_full_sweep():
    """The full >= 4-size acceptance sweep (same code path as
    ``benchmarks/mesh_bench.py`` and the CI BENCH_mesh.json gate)."""
    mesh_bench = pytest.importorskip("benchmarks.mesh_bench")

    rows, summary = mesh_bench.mesh_executed_sweep()
    assert summary["four_or_more_sizes"]
    assert summary["parallel_eff_above_95pct"], summary
    assert summary["within_1pct_of_model"], summary


# ---------------------------------------------------------------------------
# 2D sharding: pipeline rows x tensor/data columns
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("design,momentum", [
    (None, 0.9),  # NTX + momentum
    (None, 0.0),  # NTX plain SGD
    (NS_DESIGN, 0.9),  # NS: every block carries driver reps
])
@pytest.mark.parametrize("mesh", [(2, 2), (2, 4), (4, 2)])
def test_2d_bit_identical_to_unsharded(design, momentum, mesh):
    """The signature guarantee extends to 2D: tensor-channel splits,
    pipeline send/recv copies and row-scoped reduce/update/gather never
    move a flop or an accumulator rounding."""
    graph = paper_cnn_graph(batch=8, img=8, momentum=momentum)
    kw = {} if design is None else {"design": design}
    prog = lower_training_step(graph, **kw)
    sh = shard_training_step(graph, mesh_shape=mesh, program=prog,
                             shard="2d", **kw)
    assert sh.shard == "2d"
    inputs = _inputs(graph)
    want = run_reference(prog, inputs)
    got = run_reference(sh.program, inputs)
    assert set(got) == set(want)
    for k in want:
        np.testing.assert_array_equal(got[k], want[k], err_msg=k)


def test_2d_spilled_program_bit_identical():
    graph = paper_cnn_graph(batch=8, img=16)
    prog = lower_training_step(graph, n_clusters=1)  # tiny budget -> spills
    assert prog.meta["spilled"]
    sh = shard_training_step(graph, mesh_shape=(2, 2), program=prog,
                             n_clusters=1, shard="2d")
    inputs = _inputs(graph, seed=3)
    want = run_reference(prog, inputs)
    got = run_reference(sh.program, inputs)
    for k in want:
        np.testing.assert_array_equal(got[k], want[k], err_msg=k)


def test_2d_pipeline_structure():
    """Stages partition the layer sequence in order, every stage boundary
    gets a send/recv pair (activation down, gradient up), and the weight
    epilogue is row-scoped (stage params live only on their row)."""
    graph = paper_cnn_graph(batch=8, img=8, momentum=0.9)
    sh = shard_training_step(graph, mesh_shape=(2, 2), shard="2d")
    meta = sh.program.meta["mesh"]
    pmeta = meta["pipeline"]
    rows, cols = sh.mesh_shape
    assert pmeta["n_stages"] == rows
    # stages are a contiguous, order-preserving partition of the layers
    flat = [nd for stage in pmeta["stages"] for nd in stage]
    assert flat == [nd.name for nd in graph.nodes]
    assert meta["row_owners"] == [[0, 1], [2, 3]]
    # each of the rows-1 boundaries ships the activation down and its
    # gradient back up as explicit identity-copy blocks
    xfers = pmeta["xfers"]
    assert len(xfers) == 2 * (rows - 1)
    dirs = {(x["src"], x["dst"]) for x in xfers}
    assert dirs == {(0, 1), (1, 0)}
    tags = [b.tag for b in sh.program.blocks]
    for x in xfers:
        sends = [t for t in tags if t.startswith(f"send:{x['region']}[")]
        recvs = [t for t in tags if t.startswith(f"recv:{x['region']}[")]
        assert sends and len(sends) == len(recvs), x
    # row-scoped epilogue: every reduce/update/gather block is owned by a
    # cube on its parameter's home row
    row_of = {h: r for r, ro in enumerate(meta["row_owners"]) for h in ro}
    stage_of = {nd: r for r, stage in enumerate(pmeta["stages"]) for nd in stage}
    param_rows = pmeta["param_rows"]
    for h, b in sh.epilogue_blocks():
        if b.tag.startswith(("allreduce:", "allgather:")):
            assert h != ALL_HMCS
            name = b.writes[0] if b.writes else b.reads[0]
            base = name.removeprefix("d_").removeprefix("v_")
            base = base.removesuffix("_new")
            assert row_of[h] == param_rows[base], (b.tag, h)
    # tensor-sharded layers (conv/matmul/bias) really fan across columns
    assert any(t.startswith("tpgather:") for t in tags)
    assert all(r in set(param_rows.values()) for r in range(rows))
    assert stage_of  # partition non-empty


def test_2d_traffic_conservation():
    """Compute commands are conserved: the combined 2D stream is exactly
    the unsharded step plus the identity-copy communication blocks
    (tpgather/allgather/send/recv)."""
    graph = paper_cnn_graph(batch=8, img=8, momentum=0.9)
    sh = shard_training_step(graph, mesh_shape=(2, 2), shard="2d")
    comm = sum(
        b.busy_cycles for b in sh.program.blocks
        if b.tag.startswith(("tpgather:", "allgather:", "send:", "recv:"))
    )
    assert comm > 0
    assert sh.program.busy_cycles == sh.base_program.busy_cycles + comm


def test_2d_reshard_tensor_group_bit_identical():
    """Survivability x 2D: killing one cube of a tensor group re-chunks
    that pipeline stage over the row's survivors, bit-identically."""
    graph = paper_cnn_graph(batch=8, img=8, momentum=0.9)
    prog = lower_training_step(graph)
    sh = shard_training_step(graph, mesh_shape=(2, 2), program=prog,
                             shard="2d")
    degraded = reshard_training_step(sh, 1)  # row 0 keeps only cube 0
    assert degraded.shard == "2d"
    assert degraded.program.meta["mesh"]["row_owners"] == [[0], [2, 3]]
    inputs = _inputs(graph, seed=7)
    want = run_reference(prog, inputs)
    got = run_reference(degraded.program, inputs)
    for k in want:
        np.testing.assert_array_equal(got[k], want[k], err_msg=k)
    # a second loss in the other row still re-shards
    twice = reshard_training_step(degraded, 3)
    got2 = run_reference(twice.program, inputs)
    for k in want:
        np.testing.assert_array_equal(got2[k], want[k], err_msg=k)
    # losing a whole pipeline row is unrecoverable by re-chunking
    with pytest.raises(ValueError, match="lost every cube"):
        reshard_training_step(twice, 0)


def test_2d_validation_errors():
    graph = paper_cnn_graph(batch=8, img=8)
    with pytest.raises(ValueError, match="shard must be"):
        shard_training_step(graph, mesh_shape=(2, 2), shard="3d")
    # more pipeline rows than layers with compute cannot balance
    with pytest.raises(ValueError, match="pipeline"):
        shard_training_step(graph, mesh_shape=(8, 1), shard="2d")


def test_run_pallas_2d_routes_match_reference():
    from repro.lower import PlanCache, run_pallas

    graph = paper_cnn_graph(batch=4, img=8, momentum=0.9)
    prog = lower_training_step(graph)
    inputs = _inputs(graph, seed=5)
    want = run_reference(prog, inputs)
    # 2x2 on one device: the graceful single-device fallback walk
    sh = shard_training_step(graph, mesh_shape=(2, 2), program=prog,
                             shard="2d")
    got = run_pallas(sh.program, inputs, cache=PlanCache())
    assert set(got) == set(want)
    for k in want:
        np.testing.assert_allclose(
            np.asarray(got[k]), want[k], rtol=2e-3, atol=1e-5, err_msg=k
        )


def test_time_mesh_step_2d_composition():
    graph = paper_cnn_graph(batch=8, img=8)
    sh = shard_training_step(graph, mesh_shape=(2, 2), shard="2d")
    tm = time_mesh_step(sh)  # dispatches to the 2D model
    assert tm.mesh_shape == (2, 2)
    assert len(tm.row_times) == 2 and all(t > 0 for t in tm.row_times)
    assert tm.n_micro == sh.program.meta["mesh"]["pipeline"]["n_micro"]
    assert tm.t_step == pytest.approx(
        max(tm.t_compute, tm.t_boundary) + tm.t_update
    )
    assert 0.0 <= tm.bubble_frac < 1.0
    assert tm.speedup == pytest.approx(tm.t_single / tm.t_step)
    assert tm.parallel_eff == pytest.approx(tm.speedup / 4)
    s = tm.summary()
    for key in ("mesh", "n_micro", "bubble_frac", "parallel_eff",
                "row_times_ms", "t_boundary_ms"):
        assert key in s


def test_2d_efficiency_executed_one_size():
    """Tier-1 slice of the 2D acceptance gate: GoogLeNet (too big for one
    HMC at bench scale) on a 2x2 must clear the 80% efficiency floor."""
    workloads = pytest.importorskip("benchmarks.workloads")

    graph = workloads.network_graph("googlenet", batch=256)
    sh = shard_training_step(graph, mesh_shape=(2, 2), shard="2d")
    tm = time_mesh_step(sh)
    assert tm.parallel_eff >= 0.80
    assert tm.bubble_frac <= 0.25

"""Run multi-device semantics tests in subprocesses (8 fake CPU devices).

The main pytest process keeps a single device (per task spec); each case gets
a fresh interpreter with XLA_FLAGS set before jax import.
"""

import subprocess
import sys
from pathlib import Path

import jax
import pytest

pytestmark = pytest.mark.slow  # each case is a fresh 8-fake-device subprocess

ROOT = Path(__file__).resolve().parents[2]

# jax 0.4.x's shard_map cannot partially-auto over a subset of mesh axes the
# way these two cases need (fixed in the 0.5+ sharding-in-types rework, and
# verified green on the CI matrix's 0.5.3 lane); they have failed since the
# seed on 0.4.x. Gate on the parsed (major, minor) tuple rather than a
# string prefix so e.g. "0.40.0" or a dev suffix can't dodge (or wrongly
# trip) the guard — 0.5+ runs both cases for real.
_JAX_MAJOR_MINOR = tuple(
    int(p) for p in jax.__version__.split(".")[:2] if p.isdigit()
)
_PARTIAL_AUTO_XFAIL = pytest.mark.xfail(
    condition=_JAX_MAJOR_MINOR < (0, 5),
    reason=f"jax {jax.__version__}: partial-auto shard_map over a mesh-axis "
    "subset is unsupported before 0.5 (green on >=0.5.3)",
    strict=False,
)

CASES = [
    "systolic_equals_psum",
    "systolic_tree",
    pytest.param("train_systolic_equals_auto", marks=_PARTIAL_AUTO_XFAIL),
    "moe_ep_multidevice_matches_dense",
    "elastic_checkpoint_reshard",
    pytest.param("compressed_train_step_runs", marks=_PARTIAL_AUTO_XFAIL),
    "sp_model_same_loss",
    # mesh-of-HMCs data parallelism: run_pallas on a sharded train-step
    # program vs jax.grad at 1, 4, and 16 simulated devices (each case
    # pins its own --xla_force_host_platform_device_count in run_cases)
    "mesh_dp_grads_1",
    "mesh_dp_grads_4",
    "mesh_dp_grads_16",
    # 2D (pipeline x tensor) sharding through the same jax.grad oracle
    "mesh_2d_grads_4",
]


@pytest.mark.parametrize("case", CASES)
def test_case(case):
    env = {"PYTHONPATH": f"{ROOT / 'src'}:{ROOT}"}
    import os

    env.update({k: v for k, v in os.environ.items() if k not in ("XLA_FLAGS",)})
    env["PYTHONPATH"] = f"{ROOT / 'src'}:{ROOT}"
    proc = subprocess.run(
        [sys.executable, "-m", "tests.distributed.run_cases", case],
        capture_output=True,
        text=True,
        cwd=ROOT,
        env=env,
        timeout=600,
    )
    assert proc.returncode == 0, f"{case} failed:\n{proc.stdout}\n{proc.stderr}"
    assert f"PASS {case}" in proc.stdout

"""Multi-device test cases run in a subprocess with 8 fake devices.

Invoked as:  python -m tests.distributed.run_cases <case_name>
Prints "PASS <case>" on success; any exception exits non-zero.
"""

import os
import sys

# The mesh data-parallel cases pin their own fake-device count (1x1 / 2x2 /
# 4x4 HMC meshes -> 1 / 4 / 16 devices); every other case keeps the
# historical 8. Must be decided before jax imports.
_DEVICE_COUNTS = {"mesh_dp_grads_1": 1, "mesh_dp_grads_4": 4,
                  "mesh_dp_grads_16": 16, "mesh_2d_grads_4": 4}
_N_DEV = _DEVICE_COUNTS.get(sys.argv[1] if len(sys.argv) > 1 else "", 8)
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={_N_DEV}"
)

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro import compat  # noqa: E402


def mesh3():
    return compat.make_mesh((2, 2, 2), ("pod", "data", "model"))


def case_systolic_equals_psum():
    from repro.core import systolic

    mesh = mesh3()
    x = jnp.arange(4 * 37, dtype=jnp.float32).reshape(4, 37)

    def inner(xs):
        local = xs[0]
        m = systolic.systolic_mean(local, ("data", "pod"), (2, 2))
        p = systolic.psum_mean_tree(local, ("data", "pod"))
        return (m - p)[None]

    f = jax.jit(
        compat.shard_map(inner, mesh=mesh, in_specs=P(("pod", "data")),
                      out_specs=P(("pod", "data")), check_vma=False)
    )
    diff = f(x)
    assert float(jnp.abs(diff).max()) < 1e-5


def case_systolic_tree():
    from repro.core import systolic

    mesh = mesh3()
    tree = {
        "a": jnp.arange(4 * 10, dtype=jnp.float32).reshape(4, 10),
        "b": jnp.ones((4, 3, 5)) * jnp.arange(4)[:, None, None],
    }

    def inner(t):
        t = jax.tree.map(lambda l: l[0], t)
        m = systolic.systolic_mean_tree(t, ("data", "pod"), (2, 2))
        return jax.tree.map(lambda l: l[None], m)

    f = jax.jit(
        compat.shard_map(inner, mesh=mesh, in_specs=P(("pod", "data")),
                      out_specs=P(("pod", "data")), check_vma=False)
    )
    out = f(tree)
    np.testing.assert_allclose(
        np.asarray(out["a"][0]), np.asarray(tree["a"].mean(0)), atol=1e-5
    )
    np.testing.assert_allclose(np.asarray(out["b"]), 1.5, atol=1e-5)


def case_train_systolic_equals_auto():
    """One systolic train step == one pjit-auto train step (same update)."""
    from repro.configs import get_config, reduce_config
    from repro.launch.train import init_train_state, make_train_step
    from repro.models.config import ParallelCtx
    from repro.optim.optimizers import sgd

    mesh = mesh3()
    cfg = reduce_config(get_config("qwen3_8b"))
    opt = sgd(lr=0.05)
    rng = jax.random.PRNGKey(0)
    batch = {
        "inputs": jax.random.randint(rng, (8, 16), 0, cfg.vocab_size),
        "labels": jax.random.randint(rng, (8, 16), 0, cfg.vocab_size),
    }
    results = {}
    for gs in ("auto", "systolic"):
        ctx = ParallelCtx(mesh=mesh, dp_axes=("pod", "data"), tp_axis="model",
                          attn_backend="xla", grad_sync=gs)
        state = init_train_state(jax.random.PRNGKey(1), cfg, opt, gs, mesh,
                                 ("pod", "data"))
        step = jax.jit(make_train_step(cfg, ctx, opt, grad_sync=gs))
        new_state, metrics = step(state, batch)
        results[gs] = (jax.device_get(new_state["params"]), float(metrics["loss"]))
    la, lb = results["auto"][1], results["systolic"][1]
    assert abs(la - lb) < 1e-4, (la, lb)
    for a, b in zip(jax.tree.leaves(results["auto"][0]),
                    jax.tree.leaves(results["systolic"][0])):
        np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                                   atol=5e-3)


def case_moe_ep_multidevice_matches_dense():
    from repro.models import moe
    from repro.models.config import ModelConfig

    mesh = mesh3()
    cfg = ModelConfig(
        name="m", family="moe", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
        head_dim=16, d_ff=64, vocab_size=64, n_experts=8, top_k=2, moe_d_ff=32,
        dtype=jnp.float32, capacity_factor=8.0,
    )
    params = moe.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 4, cfg.d_model), jnp.float32)
    y_dense, _ = moe.moe_dense(x, params, cfg)

    from repro.parallel import sharding as shd

    p_sh = jax.tree_util.tree_map_with_path(
        lambda path, leaf: jax.device_put(
            leaf, NamedSharding(mesh, shd.spec_for_path(path, leaf.shape))
        ),
        {"moe": params},
    )["moe"]
    xs = jax.device_put(x, NamedSharding(mesh, P(("pod", "data"), None, None)))

    @jax.jit
    def f(params, x):
        y, _aux = moe.moe_ep(x, params, cfg, mesh, dp_axes=("pod", "data"))
        return y

    y_ep = f(p_sh, xs)
    np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_dense), atol=2e-4)


def case_elastic_checkpoint_reshard():
    """Save from an 8-device mesh, restore onto a 4-device mesh."""
    import tempfile

    from repro.checkpoint import checkpoint as ckpt

    mesh_a = compat.make_mesh((4, 2), ("data", "model"))
    devices = np.array(jax.devices()[:4]).reshape(2, 2)
    from jax.sharding import Mesh

    mesh_b = Mesh(devices, ("data", "model"))
    w = jnp.arange(16.0 * 8).reshape(16, 8)
    state = {"w": jax.device_put(w, NamedSharding(mesh_a, P("data", "model")))}
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 1, state)
        template = {"w": jnp.zeros((16, 8))}
        sh = {"w": NamedSharding(mesh_b, P("data", "model"))}
        restored, _ = ckpt.restore(d, template, shardings=sh)
        np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(w))
        assert restored["w"].sharding.mesh.shape["data"] == 2


def case_compressed_train_step_runs():
    from repro.configs import get_config, reduce_config
    from repro.launch.train import init_train_state, make_train_step
    from repro.models.config import ParallelCtx
    from repro.optim.optimizers import sgd

    mesh = mesh3()
    cfg = reduce_config(get_config("llama3_2_3b"))
    opt = sgd(lr=0.05)
    ctx = ParallelCtx(mesh=mesh, dp_axes=("pod", "data"), tp_axis="model",
                      attn_backend="xla", grad_sync="compressed")
    state = init_train_state(jax.random.PRNGKey(1), cfg, opt, "compressed", mesh,
                             ("pod", "data"))
    step = jax.jit(make_train_step(cfg, ctx, opt, grad_sync="compressed"))
    rng = jax.random.PRNGKey(0)
    batch = {
        "inputs": jax.random.randint(rng, (8, 16), 0, cfg.vocab_size),
        "labels": jax.random.randint(rng, (8, 16), 0, cfg.vocab_size),
    }
    s1, m1 = step(state, batch)
    s2, m2 = step(s1, batch)
    assert np.isfinite(float(m1["loss"])) and np.isfinite(float(m2["loss"]))
    assert float(m2["ce"]) < float(m1["ce"])  # it actually learns
    # error state is being used (nonzero after a step)
    err_mag = sum(float(jnp.abs(l).sum()) for l in jax.tree.leaves(s2["err"]))
    assert err_mag > 0


def case_sp_model_same_loss():
    """The §Perf sp_model/bf16 knobs must not change the computed loss."""
    from repro.configs import get_config, reduce_config
    from repro.launch.train import init_train_state, make_train_step
    from repro.models.config import ParallelCtx
    from repro.optim.optimizers import sgd

    mesh = mesh3()
    cfg = reduce_config(get_config("qwen3_8b"))
    opt = sgd(lr=0.05)
    rng = jax.random.PRNGKey(0)
    batch = {
        "inputs": jax.random.randint(rng, (8, 16), 0, cfg.vocab_size),
        "labels": jax.random.randint(rng, (8, 16), 0, cfg.vocab_size),
    }
    losses = {}
    for name, kw in {
        "base": {},
        "sp": dict(sp_model=True),
        "sp_windowed": dict(sp_model=True, windowed_attn=True),
    }.items():
        ctx = ParallelCtx(mesh=mesh, dp_axes=("pod", "data"), tp_axis="model",
                          attn_backend="xla", **kw)
        state = init_train_state(jax.random.PRNGKey(1), cfg, opt)
        step = jax.jit(make_train_step(cfg, ctx, opt))
        _, metrics = step(state, batch)
        losses[name] = float(metrics["loss"])
    base = losses["base"]
    for name, l in losses.items():
        assert abs(l - base) < 1e-4, losses


def _mesh_dp_grads(rows: int, cols: int, shard: str = "1d"):
    """run_pallas on a mesh-sharded train step == jax.grad, data-parallel.

    The whole-train-step program shards over a (rows x cols) device mesh
    via shard_map; logits, per-parameter gradients, momentum, and updated
    weights must match jax autodiff + SGD on the same model to fp32
    tolerance. One jax device per HMC — the real allreduce (psum) runs.
    ``shard="2d"`` runs the pipeline x tensor splitter's program through
    the same oracle (the shard_map axes become ("pipe", "data")).
    """
    from repro.kernels import ref
    from repro.lower import (
        PlanCache,
        lower_training_step,
        paper_cnn_graph,
        run_pallas,
        shard_training_step,
    )

    n = rows * cols
    assert jax.device_count() == n, (jax.device_count(), n)
    graph = paper_cnn_graph(batch=16, img=8, lr=0.05, momentum=0.9)
    prog = lower_training_step(graph)
    sharded = shard_training_step(graph, mesh_shape=(rows, cols),
                                  program=prog, shard=shard)

    rng = np.random.RandomState(0)
    x = rng.randn(16, 8, 8, 3).astype(np.float32)
    onehot = np.eye(10, dtype=np.float32)[rng.randint(0, 10, 16)]
    params = graph.init_params(seed=1)
    outs = run_pallas(sharded.program, {"x": x, "onehot": onehot, **params},
                      cache=PlanCache())

    def forward(p, xb):
        h = ref.conv2d_ref(xb, p["w_c1"], stride=2, padding=2)
        h = jax.nn.relu(h)
        h = ref.conv2d_ref(h, p["w_c2"], stride=2, padding=1)
        h = jax.nn.relu(h)
        h = jax.lax.reduce_window(
            h, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
        )
        return h.reshape(xb.shape[0], -1) @ p["w_fc"] + p["b_fcb"][None, :]

    def loss_fn(p):
        z = forward(p, jnp.asarray(x))
        return -jnp.mean(jnp.sum(jax.nn.log_softmax(z) * onehot, axis=1))

    jp = {k: jnp.asarray(v) for k, v in params.items()
          if not k.startswith("v_")}
    grads = jax.grad(loss_fn)(jp)
    np.testing.assert_allclose(
        np.asarray(outs[graph.logits_edge]), np.asarray(forward(jp, x)),
        rtol=1e-4, atol=1e-5,
    )
    for p in graph.param_shapes():
        g = np.asarray(grads[p])
        np.testing.assert_allclose(np.asarray(outs[f"d_{p}"]), g,
                                   rtol=1e-3, atol=1e-5, err_msg=p)
        v_new = graph.momentum * params[f"v_{p}"] + g
        np.testing.assert_allclose(np.asarray(outs[f"v_{p}_new"]), v_new,
                                   rtol=1e-3, atol=1e-5, err_msg=p)
        np.testing.assert_allclose(
            np.asarray(outs[f"{p}_new"]), params[p] - graph.lr * v_new,
            rtol=1e-3, atol=1e-5, err_msg=p,
        )


def case_mesh_dp_grads_1():
    _mesh_dp_grads(1, 1)


def case_mesh_dp_grads_4():
    _mesh_dp_grads(2, 2)


def case_mesh_dp_grads_16():
    _mesh_dp_grads(4, 4)


def case_mesh_2d_grads_4():
    _mesh_dp_grads(2, 2, shard="2d")


CASES = {k[5:]: v for k, v in list(globals().items()) if k.startswith("case_")}

if __name__ == "__main__":
    name = sys.argv[1]
    CASES[name]()
    print(f"PASS {name}")

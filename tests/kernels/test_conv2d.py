"""Kernel sweep: conv2d_ntx (interpret mode) vs the lax oracle."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.conv2d import conv2d_ntx
from repro.lower.rules import conv2d_fwd_template

CASES = [
    # (n, h, w, cin, kh, kw, cout, stride)
    (1, 12, 12, 3, 3, 3, 8, 1),
    (2, 16, 10, 4, 3, 3, 8, 2),
    (1, 9, 9, 3, 1, 1, 16, 1),
    (1, 14, 14, 3, 5, 5, 4, 2),
    (2, 11, 13, 2, 3, 2, 4, 3),
    (1, 8, 8, 8, 7, 7, 4, 1),
]


@pytest.mark.parametrize("n,h,w,cin,kh,kw,cout,stride", CASES)
def test_conv_vs_ref(n, h, w, cin, kh, kw, cout, stride):
    rng = np.random.RandomState(h * 10 + kh + stride)
    x = jnp.asarray(rng.randn(n, h, w, cin), jnp.float32)
    wt = jnp.asarray(rng.randn(kh, kw, cin, cout) * 0.2, jnp.float32)
    got = conv2d_ntx(x, wt, stride=stride, tile_h=4, interpret=True)
    want = ref.conv2d_ref(x, wt, stride=stride, padding=0)
    assert got.shape == want.shape, (got.shape, want.shape)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-4)


def test_conv_matches_ntx_interpreter():
    """Kernel == the cycle-level NtxCommand interpreter (C2 semantics)."""
    from repro.core import ntx

    rng = np.random.RandomState(0)
    ih, iw, ci, kh, kw = 6, 6, 3, 3, 3
    x = rng.randn(ih, iw, ci).astype(np.float32)
    w = rng.randn(kh, kw, ci).astype(np.float32)
    mem = np.zeros(4000, np.float32)
    mem[: x.size] = x.ravel()
    mem[200 : 200 + w.size] = w.ravel()
    cmd = conv2d_fwd_template(ih, iw, ci, kh, kw, 1, 0, 200, 300)
    out = ntx.ntx_execute(cmd, mem)
    oh, ow = ih - kh + 1, iw - kw + 1
    want = out[300 : 300 + oh * ow].reshape(oh, ow)

    got = conv2d_ntx(
        jnp.asarray(x)[None], jnp.asarray(w)[..., None], stride=1, tile_h=2,
        interpret=True,
    )[0, :, :, 0]
    np.testing.assert_allclose(np.asarray(got), want, atol=1e-4, rtol=1e-4)

"""Kernel sweep: Mamba-2 SSD chunked scan vs the sequential recurrence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import ops, ref

CASES = [
    # (B, H, G, S, P, N, chunk)
    (2, 4, 2, 256, 32, 32, 64),
    (1, 2, 1, 128, 64, 128, 128),
    (1, 4, 4, 192, 16, 32, 64),
    (1, 1, 1, 64, 8, 16, 32),
]


def _mk(bs, h, g, s, p, n, seed=0):
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(bs, h, s, p) * 0.5, jnp.float32)
    la = -jnp.abs(jnp.asarray(rng.rand(bs, h, s), jnp.float32)) * 0.5
    b = jnp.asarray(rng.randn(bs, g, s, n) * 0.3, jnp.float32)
    c = jnp.asarray(rng.randn(bs, g, s, n) * 0.3, jnp.float32)
    return x, la, b, c


@pytest.mark.parametrize("bs,h,g,s,p,n,chunk", CASES)
@pytest.mark.parametrize("backend", ["interpret", "xla"])
def test_ssd_vs_sequential(bs, h, g, s, p, n, chunk, backend):
    x, la, b, c = _mk(bs, h, g, s, p, n, seed=s + p)
    want = ref.ssd_ref(x, la, b, c)
    got = ops.ssd(x, la, b, c, chunk=chunk, backend=backend)
    scale = float(jnp.abs(want).max()) + 1e-6
    np.testing.assert_allclose(
        np.asarray(got) / scale, np.asarray(want) / scale, atol=3e-5
    )


@settings(max_examples=10, deadline=None)
@given(st.sampled_from([32, 64, 128]), st.sampled_from([32, 64, 128]))
def test_chunk_invariance(c1, c2):
    """The chunked dual form must be independent of chunk size."""
    x, la, b, c = _mk(1, 2, 1, 384, 16, 32, seed=c1 * 1000 + c2)
    y1 = ops.ssd(x, la, b, c, chunk=c1, backend="xla")
    y2 = ops.ssd(x, la, b, c, chunk=c2, backend="xla")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-5)


def test_final_state_matches_recurrence():
    x, la, b, c = _mk(1, 2, 1, 128, 16, 32, seed=7)
    y, h = ops.ssd(x, la, b, c, chunk=32, backend="xla", return_state=True)
    # step the sequential recurrence to the end
    grp = 2 // 1
    bfull = jnp.repeat(b, grp, axis=1)
    href = jnp.zeros((1, 2, 16, 32))
    for t in range(128):
        a = jnp.exp(la[:, :, t])[..., None, None]
        href = a * href + x[:, :, t][..., :, None] * bfull[:, :, t][..., None, :]
    np.testing.assert_allclose(np.asarray(h), np.asarray(href), atol=1e-4, rtol=1e-3)


def test_gradients_flow():
    x, la, b, c = _mk(1, 2, 1, 128, 16, 32, seed=9)

    def f(x):
        return (ops.ssd(x, la, b, c, chunk=64, backend="xla") ** 2).sum()

    g = jax.grad(f)(x)
    assert bool(jnp.isfinite(g).all())

"""Kernel sweep: ntx_matmul (interpret mode) vs the jnp oracle."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SHAPES = [
    (128, 128, 128),
    (128, 128, 512),
    (256, 128, 384),
    (64, 64, 64),
    (100, 70, 333),  # ragged -> exercises padding
    (8, 200, 40),
]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("m,n,k", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_matmul_interpret_vs_ref(m, n, k, dtype):
    rng = np.random.RandomState(m + n + k)
    a = jnp.asarray(rng.randn(m, k), dtype)
    b = jnp.asarray(rng.randn(k, n), dtype)
    got = ops.matmul(a, b, backend="interpret")
    want = ref.matmul_ref(a, b)
    tol = 2e-5 * np.sqrt(k) if dtype == jnp.float32 else 2e-2 * np.sqrt(k)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=tol, rtol=1e-2)


@pytest.mark.parametrize("m,n,k", [(128, 128, 2048)])
def test_compensated_not_worse_vs_fp64(m, n, k):
    rng = np.random.RandomState(0)
    a = jnp.asarray(rng.randn(m, k) * 10.0 ** rng.uniform(-2, 2, (m, k)), jnp.float32)
    b = jnp.asarray(rng.randn(k, n), jnp.float32)
    want = ref.matmul_ref64(np.asarray(a), np.asarray(b))
    plain = np.asarray(ops.matmul(a, b, backend="interpret"), np.float64)
    comp = np.asarray(ops.matmul(a, b, backend="interpret", compensated=True), np.float64)
    rms = lambda x: float(np.sqrt(np.mean(np.square(x - want))))
    assert rms(comp) <= rms(plain) * 1.001


def test_out_dtype():
    a = jnp.ones((128, 128), jnp.bfloat16)
    b = jnp.ones((128, 128), jnp.bfloat16)
    out = ops.matmul(a, b, backend="interpret", out_dtype=jnp.bfloat16)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out, np.float32), 128.0)

"""Streaming (manual double-buffered DMA) kernels vs the jnp/NTX oracles."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ntx
from repro.kernels import ref, streaming
from repro.lower.rules import matmul_template

SHAPES = [
    (128, 128, 128),
    (128, 128, 512),
    (64, 64, 256),
    (100, 70, 333),  # ragged -> exercises padding
    (8, 200, 40),
]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("m,n,k", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_streaming_matmul_vs_ref(m, n, k, dtype):
    rng = np.random.RandomState(m + n + k)
    a = jnp.asarray(rng.randn(m, k), dtype)
    b = jnp.asarray(rng.randn(k, n), dtype)
    got = streaming.streaming_matmul(a, b, interpret=True)
    want = ref.matmul_ref(a, b)
    tol = 2e-5 * np.sqrt(k) if dtype == jnp.float32 else 2e-2 * np.sqrt(k)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=tol, rtol=1e-2)


def test_streaming_matmul_out_dtype():
    a = jnp.ones((128, 128), jnp.bfloat16)
    b = jnp.ones((128, 128), jnp.bfloat16)
    out = streaming.streaming_matmul(a, b, out_dtype=jnp.bfloat16, interpret=True)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out, np.float32), 128.0)


def test_streaming_matmul_matches_ntx_interpreter():
    """Closed loop: manual-DMA kernel == the NtxCommand reference interpreter."""
    rng = np.random.RandomState(7)
    m, n, k = 8, 6, 12
    a = rng.randn(m, k).astype(np.float32)
    b = rng.randn(k, n).astype(np.float32)
    mem = np.zeros(1000, np.float32)
    mem[: m * k] = a.ravel()
    mem[200 : 200 + k * n] = b.ravel()
    cmd = matmul_template(m, n, k, 0, 200, 500)
    want = ntx.ntx_execute(cmd, mem)[500 : 500 + m * n].reshape(m, n)
    got = streaming.streaming_matmul(jnp.asarray(a), jnp.asarray(b), interpret=True)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("stride,padding", [(1, 0), (1, 1), (2, 1)])
def test_streaming_conv_vs_ref(stride, padding):
    rng = np.random.RandomState(3 + stride + padding)
    x = jnp.asarray(rng.randn(2, 12, 12, 3), jnp.float32)
    w = jnp.asarray(rng.randn(3, 3, 3, 8), jnp.float32)
    got = streaming.streaming_conv2d(x, w, stride=stride, padding=padding,
                                     interpret=True)
    want = ref.conv2d_ref(x, w, stride=stride, padding=padding)
    assert got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-3)


def test_streaming_tiles_describe_the_schedule():
    """The cost descriptor enumerates exactly grid x k_tiles transfers and
    its modeled pipeline overlaps (feeds the runtime DMA model)."""
    from repro.runtime.dma import DmaConfig, DmaEngine, Transfer

    m, n, k = 256, 128, 512
    tiles = streaming.streaming_tiles(m, n, k, block_m=128, block_n=128,
                                      block_k=128)
    assert len(tiles) == (256 // 128) * (128 // 128) * (512 // 128)
    assert sum(t[1] for t in tiles) == float(m * n * k)  # all MACs covered
    stats = DmaEngine(DmaConfig()).pipeline(
        [(Transfer(b), macs / 8) for b, macs in tiles]
    )
    assert stats.overlap_efficiency > 0.9  # double buffering hides the DMA

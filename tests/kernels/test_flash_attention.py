"""Kernel sweep: flash attention (interpret + blockwise xla) vs dense oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

CASES = [
    # (B, Hq, Hkv, Sq, Skv, D, causal, window)
    (2, 4, 2, 128, 128, 64, True, None),
    (1, 8, 4, 256, 256, 64, True, None),
    (1, 4, 4, 128, 384, 64, True, 128),
    (2, 2, 1, 128, 128, 128, False, None),
    (1, 2, 2, 64, 192, 32, True, 64),
]


@pytest.mark.parametrize("b,hq,hkv,sq,skv,d,causal,window", CASES)
@pytest.mark.parametrize("backend", ["interpret", "xla"])
def test_attention_vs_ref(b, hq, hkv, sq, skv, d, causal, window, backend):
    rng = np.random.RandomState(abs(hash((b, hq, sq, skv, d, causal, window))) % 2**31)
    q = jnp.asarray(rng.randn(b, hq, sq, d) * 0.3, jnp.float32)
    k = jnp.asarray(rng.randn(b, hkv, skv, d) * 0.3, jnp.float32)
    v = jnp.asarray(rng.randn(b, hkv, skv, d) * 0.3, jnp.float32)
    want = ref.attention_ref(q, k, v, causal=causal, window=window)
    got = ops.attention(
        q, k, v, causal=causal, window=window, backend=backend, block_q=64, block_kv=64
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=1e-3)


def test_attention_bf16():
    rng = np.random.RandomState(0)
    q = jnp.asarray(rng.randn(1, 4, 128, 64) * 0.3, jnp.bfloat16)
    k = jnp.asarray(rng.randn(1, 2, 128, 64) * 0.3, jnp.bfloat16)
    v = jnp.asarray(rng.randn(1, 2, 128, 64) * 0.3, jnp.bfloat16)
    want = ref.attention_ref(q, k, v, causal=True)
    got = ops.attention(q, k, v, causal=True, backend="interpret")
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=2e-2
    )


def test_decode_path_traced_offsets():
    rng = np.random.RandomState(1)
    b, hq, hkv, d, s = 2, 4, 2, 64, 128
    q1 = jnp.asarray(rng.randn(b, hq, 1, d) * 0.3, jnp.float32)
    kc = jnp.asarray(rng.randn(b, hkv, s, d) * 0.3, jnp.float32)
    vc = jnp.asarray(rng.randn(b, hkv, s, d) * 0.3, jnp.float32)
    for pos in [0, 5, 77, 127]:
        want = ref.attention_ref(q1, kc, vc, causal=True, q_offset=pos, kv_valid_len=pos + 1)
        got = ops.attention(
            q1, kc, vc, causal=True,
            q_offset=jnp.int32(pos), kv_valid_len=jnp.int32(pos + 1),
            backend="xla", block_kv=32,
        )
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_gradients_match_dense():
    rng = np.random.RandomState(2)
    q = jnp.asarray(rng.randn(1, 2, 32, 16) * 0.3, jnp.float32)
    k = jnp.asarray(rng.randn(1, 1, 32, 16) * 0.3, jnp.float32)
    v = jnp.asarray(rng.randn(1, 1, 32, 16) * 0.3, jnp.float32)

    def f_block(q):
        return (ops.attention(q, k, v, backend="xla", block_kv=8) ** 2).sum()

    def f_ref(q):
        return (ref.attention_ref(q, k, v) ** 2).sum()

    g1 = jax.grad(f_block)(q)
    g2 = jax.grad(f_ref)(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-4, rtol=1e-3)


def test_windowed_equals_masked_blockwise():
    """H5 (window-limited KV scan) must equal the masked blockwise path."""
    rng = np.random.RandomState(5)
    for (b, hq, hkv, s, d, win) in [(1, 4, 2, 512, 32, 128), (2, 2, 1, 256, 16, 64)]:
        q = jnp.asarray(rng.randn(b, hq, s, d) * 0.3, jnp.float32)
        k = jnp.asarray(rng.randn(b, hkv, s, d) * 0.3, jnp.float32)
        v = jnp.asarray(rng.randn(b, hkv, s, d) * 0.3, jnp.float32)
        base = ops.attention(q, k, v, causal=True, window=win, backend="xla",
                             block_kv=64)
        fast = ops.attention(q, k, v, causal=True, window=win, backend="xla",
                             block_kv=64, windowed=True)
        np.testing.assert_allclose(np.asarray(fast), np.asarray(base),
                                   atol=2e-5, rtol=1e-3)


def test_windowed_gradients():
    rng = np.random.RandomState(6)
    q = jnp.asarray(rng.randn(1, 2, 512, 16) * 0.3, jnp.float32)
    k = jnp.asarray(rng.randn(1, 1, 512, 16) * 0.3, jnp.float32)
    v = jnp.asarray(rng.randn(1, 1, 512, 16) * 0.3, jnp.float32)

    def f(q, windowed):
        return (ops.attention(q, k, v, causal=True, window=128, backend="xla",
                              block_kv=64, windowed=windowed) ** 2).sum()

    g1 = jax.grad(lambda q: f(q, True))(q)
    g2 = jax.grad(lambda q: f(q, False))(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-4, rtol=1e-3)

"""C2: the NTX offload model — interpreter, AGU math, Table 2 counts."""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import ntx
from repro.lower.rules import conv2d_fwd_template, matmul_template


@settings(max_examples=50, deadline=None)
@given(
    st.lists(st.integers(1, 9), min_size=5, max_size=5),
    st.lists(st.integers(-50, 50), min_size=5, max_size=5),
)
def test_strides_steps_roundtrip(loops, strides):
    steps = ntx.strides_to_steps(strides, loops)
    back = ntx.steps_to_strides(steps, loops)
    assert back == list(strides)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 5), st.integers(1, 5), st.integers(1, 6))
def test_interpreter_matmul(m, n, k):
    rng = np.random.RandomState(m * 100 + n * 10 + k)
    a = rng.randn(m, k).astype(np.float32)
    b = rng.randn(k, n).astype(np.float32)
    mem = np.zeros(500, np.float32)
    mem[: m * k] = a.ravel()
    mem[100 : 100 + k * n] = b.ravel()
    cmd = matmul_template(m, n, k, 0, 100, 300)
    out = ntx.ntx_execute(cmd, mem)
    np.testing.assert_allclose(out[300 : 300 + m * n].reshape(m, n), a @ b, rtol=1e-5)


def test_interpreter_wide_beats_fpu():
    """wide=True (PCS model) must beat wide=False (fp32 FPU) vs fp64."""
    rng = np.random.RandomState(0)
    k = 4096
    a = (rng.randn(1, k) * 10.0 ** rng.uniform(-3, 3, (1, k))).astype(np.float32)
    b = rng.randn(k, 1).astype(np.float32)
    mem = np.zeros(3 * k + 10, np.float32)
    mem[:k] = a.ravel()
    mem[k : 2 * k] = b.ravel()
    cmd = matmul_template(1, 1, k, 0, k, 3 * k)
    ref = np.dot(a.astype(np.float64), b.astype(np.float64))[0, 0]
    wide = ntx.ntx_execute(cmd, mem, wide=True)[3 * k]
    fpu = ntx.ntx_execute(cmd, mem, wide=False)[3 * k]
    assert abs(wide - ref) <= abs(fpu - ref)


def test_table2_offload_counts():
    """Exact reproduction of paper Table 2."""
    rows = [
        (ntx.ConvShape(7, 7, 3, 112, 112, 64), 802_816, 64, 147, 1_843_968),
        (ntx.ConvShape(3, 3, 64, 56, 56, 192), 602_112, 192, 576, 1_806_336),
        (ntx.ConvShape(1, 1, 256, 28, 28, 64), 50_176, 64, 256, 200_704),
        (ntx.ConvShape(1, 1, 512, 14, 14, 192), 37_632, 192, 512, 100_352),
    ]
    for conv, ns_off, ntx_off, ns_cyc, ntx_cyc in rows:
        assert ntx.offload_count(conv, **ntx.NS_LOOPS) == ns_off
        assert ntx.offload_count(conv, **ntx.NTX_LOOPS) == ntx_off
        assert ntx.busy_cycles_per_offload(conv, **ntx.NS_LOOPS) == ns_cyc
        assert ntx.busy_cycles_per_offload(conv, **ntx.NTX_LOOPS) == ntx_cyc


def test_conv_command_matches_numpy():
    rng = np.random.RandomState(3)
    ih, iw, ci, kh, kw = 7, 8, 3, 3, 2
    x = rng.randn(ih, iw, ci).astype(np.float32)
    w = rng.randn(kh, kw, ci).astype(np.float32)
    mem = np.zeros(2000, np.float32)
    mem[: x.size] = x.ravel()
    mem[500 : 500 + w.size] = w.ravel()
    cmd = conv2d_fwd_template(ih, iw, ci, kh, kw, 1, 0, 500, 1000)
    out = ntx.ntx_execute(cmd, mem)
    oh, ow = ih - kh + 1, iw - kw + 1
    got = out[1000 : 1000 + oh * ow].reshape(oh, ow)
    want = np.zeros((oh, ow), np.float32)
    for i in range(oh):
        for j in range(ow):
            want[i, j] = float((x[i : i + kh, j : j + kw] * w).sum())
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_command_semantics_match_pallas_matmul():
    """C2 closed loop: the NtxCommand interpreter and the Pallas ntx_matmul
    kernel compute the same contraction (offload model == TPU kernel)."""
    import jax.numpy as jnp

    from repro.kernels import ops

    rng = np.random.RandomState(11)
    m, n, k = 8, 6, 12
    a = rng.randn(m, k).astype(np.float32)
    b = rng.randn(k, n).astype(np.float32)
    mem = np.zeros(1000, np.float32)
    mem[: m * k] = a.ravel()
    mem[200 : 200 + k * n] = b.ravel()
    cmd = matmul_template(m, n, k, 0, 200, 500)
    want = ntx.ntx_execute(cmd, mem)[500 : 500 + m * n].reshape(m, n)
    got = ops.matmul(jnp.asarray(a), jnp.asarray(b), backend="interpret")
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-5)

"""End-to-end behaviour: the full training system on CPU at smoke scale.

Covers the integration of data pipeline -> model -> optimizer -> checkpoint ->
supervisor, i.e. the paper's "entire DNN training batches performed completely
in memory, without intervention from a host" (§3) at miniature scale.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.data.pipeline import DataIterator, InMemoryDataset
from repro.launch.train import init_train_state, make_train_step
from repro.models.config import ParallelCtx
from repro.optim.optimizers import adamw, sgd
from repro.runtime.supervisor import FailureInjector, Supervisor

pytestmark = pytest.mark.slow  # minutes of end-to-end training on CPU

CTX = ParallelCtx(attn_backend="xla")


def test_lm_learns_synthetic_corpus():
    """CE on a learnable synthetic stream must drop substantially."""
    cfg = reduce_config(get_config("qwen1_5_0_5b")).with_(vocab_size=64)
    ds = InMemoryDataset.synthetic(200_000, cfg.vocab_size, 32, seed=0)
    it = DataIterator(ds, batch_size=8, seed=0)
    opt = adamw(lr=3e-3, weight_decay=0.0)
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    step = jax.jit(make_train_step(cfg, CTX, opt))
    losses = []
    for _ in range(60):
        batch = next(it)
        state, metrics = step(state, batch)
        losses.append(float(metrics["ce"]))
    first, last = np.mean(losses[:5]), np.mean(losses[-5:])
    assert last < first - 0.5, (first, last)


def test_full_stack_with_supervisor_and_crash(tmp_path):
    """Data -> train_step -> checkpoints -> injected crash -> exact resume."""
    cfg = reduce_config(get_config("llama3_2_3b")).with_(vocab_size=64)
    ds = InMemoryDataset.synthetic(100_000, cfg.vocab_size, 16, seed=1)
    opt = sgd(lr=0.05)

    def make_iter():
        return DataIterator(ds, batch_size=4, seed=2)

    def init_state(mesh):
        return init_train_state(jax.random.PRNGKey(0), cfg, opt)

    def make_step(mesh):
        return jax.jit(make_train_step(cfg, CTX, opt))

    # reference: no crash
    sup_a = Supervisor(make_step, init_state, make_iter(), tmp_path / "a", ckpt_every=5)
    sup_a.run(15)
    # crashing run
    inj = FailureInjector({8: "crash"})
    sup_b = Supervisor(make_step, init_state, make_iter(), tmp_path / "b",
                       ckpt_every=5, injector=inj)
    rep = sup_b.run(15)
    assert rep.restarts == 1

    from repro.checkpoint import checkpoint as ckpt

    sa, _ = ckpt.restore(tmp_path / "a", init_state(None))
    sb, _ = ckpt.restore(tmp_path / "b", init_state(None))
    for a, b in zip(jax.tree.leaves(sa["params"]), jax.tree.leaves(sb["params"])):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=1e-5
        )


def test_microbatched_equals_full_batch():
    """Gradient accumulation must not change the update (up to fp error)."""
    cfg = reduce_config(get_config("qwen3_8b")).with_(vocab_size=64)
    opt = sgd(lr=0.1, momentum=0.0)
    rng = jax.random.PRNGKey(0)
    batch = {
        "inputs": jax.random.randint(rng, (8, 16), 0, cfg.vocab_size),
        "labels": jax.random.randint(rng, (8, 16), 0, cfg.vocab_size),
    }
    outs = {}
    for nmb in (1, 4):
        state = init_train_state(jax.random.PRNGKey(1), cfg, opt)
        step = jax.jit(make_train_step(cfg, CTX, opt, num_microbatches=nmb,
                                       clip_norm=None))
        new_state, _ = step(state, batch)
        outs[nmb] = jax.device_get(new_state["params"])
    for a, b in zip(jax.tree.leaves(outs[1]), jax.tree.leaves(outs[4])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_serve_greedy_decode_runs():
    from repro.launch.serve import greedy_decode
    from repro.models import lm

    cfg = reduce_config(get_config("qwen1_5_0_5b")).with_(vocab_size=64)
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 4), 0, cfg.vocab_size)
    out = greedy_decode(params, cfg, CTX, prompt, max_new=6)
    assert out.shape == (2, 6)
    assert bool((out >= 0).all()) and bool((out < cfg.vocab_size).all())

"""C1: wide-accumulation numerics (paper §2.3, Table 1)."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.precision import two_prod, two_sum, wide_dot, wide_sum

f32 = st.floats(
    min_value=-1e6, max_value=1e6, allow_nan=False, allow_infinity=False, width=32
)


@settings(max_examples=50, deadline=None)
@given(f32, f32)
def test_two_sum_error_free(a, b):
    """a + b == s + e exactly (verified in fp64)."""
    s, e = two_sum(jnp.float32(a), jnp.float32(b))
    lhs = np.float64(a) + np.float64(b)
    rhs = np.float64(np.float32(s)) + np.float64(np.float32(e))
    # The EFT identity holds exactly when s doesn't overflow.
    assert lhs == rhs or abs(lhs - rhs) <= 1e-16 * abs(lhs)


@settings(max_examples=50, deadline=None)
@given(
    st.floats(min_value=-1e3, max_value=1e3, allow_nan=False, width=32),
    st.floats(min_value=-1e3, max_value=1e3, allow_nan=False, width=32),
)
def test_two_prod_error_free(a, b):
    # Dekker's EFT is exact only when the product error does not underflow
    # (|a*b| well above the subnormal range) — the classical precondition.
    if 0.0 < abs(np.float64(a) * np.float64(b)) < 1e-20:
        return
    p, e = two_prod(jnp.float32(a), jnp.float32(b))
    exact = np.float64(np.float32(a)) * np.float64(np.float32(b))
    assert np.float64(np.float32(p)) + np.float64(np.float32(e)) == exact


def test_wide_sum_beats_naive():
    rng = np.random.RandomState(0)
    x = (rng.randn(200_000) * 10.0 ** rng.uniform(-4, 4, 200_000)).astype(np.float32)
    ref = np.sum(x.astype(np.float64))
    naive = float(np.add.reduce(x))  # sequential fp32
    wide = float(wide_sum(jnp.asarray(x)))
    assert abs(wide - ref) < abs(naive - ref) / 2, (wide - ref, naive - ref)


def test_wide_dot_beats_naive():
    rng = np.random.RandomState(1)
    a = (rng.randn(100_000) * 10.0 ** rng.uniform(-3, 3, 100_000)).astype(np.float32)
    b = rng.randn(100_000).astype(np.float32)
    ref = np.dot(a.astype(np.float64), b.astype(np.float64))
    naive = 0.0
    naive = float(np.add.reduce(a * b))
    wide = float(wide_dot(jnp.asarray(a), jnp.asarray(b)))
    assert abs(wide - ref) <= abs(naive - ref), (wide - ref, naive - ref)


def test_table1_property_reduction_rmse():
    """The Table 1 claim, reproduced in miniature: wide accumulation has lower
    RMSE than a conventional fp32 reduction on a conv-like inner product."""
    rng = np.random.RandomState(2)
    k = 3 * 3 * 192  # a GoogLeNet 3x3 reduction
    trials = 64
    errs_naive, errs_wide = [], []
    for _ in range(trials):
        x = rng.randn(k).astype(np.float32)
        w = rng.randn(k).astype(np.float32)
        ref = np.dot(x.astype(np.float64), w.astype(np.float64))
        errs_naive.append(float(np.add.reduce(x * w)) - ref)
        errs_wide.append(float(wide_dot(jnp.asarray(x), jnp.asarray(w))) - ref)
    rmse_naive = np.sqrt(np.mean(np.square(errs_naive)))
    rmse_wide = np.sqrt(np.mean(np.square(errs_wide)))
    # Paper: 1.7x lower for NTX; two-float is far stronger.
    assert rmse_wide < rmse_naive / 1.7

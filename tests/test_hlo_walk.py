"""Trip-count-aware HLO accounting (launch/hlo_walk) — validated on known
flop/collective counts, including nested scans."""

import jax
import jax.numpy as jnp

from repro.launch import hlo_walk


def _walk(f, *args):
    return hlo_walk.walk(jax.jit(f).lower(*args).compile().as_text())


def test_scan_matmul_flops():
    x = jnp.ones((64, 64))
    w = jnp.ones((64, 64))

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None

        out, _ = jax.lax.scan(body, x, None, length=7)
        return out.sum()

    s = _walk(f, x, w)
    assert s.flops == 7 * 2 * 64**3


def test_nested_scan_flops():
    x = jnp.ones((64, 64))
    w = jnp.ones((64, 64))

    def f(x, w):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None

            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None

        out, _ = jax.lax.scan(outer, x, None, length=5)
        return out.sum()

    s = _walk(f, x, w)
    assert s.flops == 5 * 3 * 2 * 64**3


def test_unrolled_matches_scan():
    x = jnp.ones((32, 32))
    w = jnp.ones((32, 32))

    def f_scan(x, w):
        def body(c, _):
            return c @ w, None

        out, _ = jax.lax.scan(body, x, None, length=4)
        return out.sum()

    def f_unrolled(x, w):
        c = x
        for _ in range(4):
            c = c @ w
        return c.sum()

    assert _walk(f_scan, x, w).flops == _walk(f_unrolled, x, w).flops


def test_bytes_proxy_positive_and_scales():
    x = jnp.ones((128, 128))

    def f(x):
        return jnp.tanh(x) * 2.0 + 1.0

    def g(x):
        def body(c, _):
            return jnp.tanh(c) * 2.0 + 1.0, None

        out, _ = jax.lax.scan(body, x, None, length=10)
        return out

    s1 = _walk(f, x)
    s2 = _walk(g, x)
    assert s1.bytes_proxy > 0
    assert s2.bytes_proxy > 5 * s1.bytes_proxy  # ~10x, allow fusion slack

"""C4: strided-conv backward decomposition == autodiff (paper §3.2)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import conv_decomp as cd


@settings(max_examples=25, deadline=None)
@given(
    st.integers(5, 14),  # xh
    st.integers(5, 14),  # xw
    st.integers(1, 5),  # k
    st.integers(1, 3),  # stride
    st.integers(0, 3),  # padding
)
def test_input_grad_decomposition(xh, xw, k, s, pad):
    if xh + 2 * pad < k or xw + 2 * pad < k:
        return
    rng = np.random.RandomState(xh * 1000 + xw * 100 + k * 10 + s + pad)
    x = jnp.asarray(rng.randn(2, xh, xw, 3), jnp.float32)
    w = jnp.asarray(rng.randn(k, k, 3, 4), jnp.float32)

    def loss(x):
        return 0.5 * (cd.conv2d(x, w, s, pad) ** 2).sum()

    dx_ref = jax.grad(loss)(x)
    dy = cd.conv2d(x, w, s, pad)
    dx = cd.conv2d_input_grad_decomposed(dy, w, s, (xh, xw), pad)
    np.testing.assert_allclose(np.asarray(dx), np.asarray(dx_ref), rtol=2e-4, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(st.integers(6, 12), st.integers(1, 4), st.integers(1, 3), st.integers(0, 2))
def test_weight_grad(xh, k, s, pad):
    if xh + 2 * pad < k:
        return
    rng = np.random.RandomState(xh * 100 + k * 10 + s + pad)
    x = jnp.asarray(rng.randn(2, xh, xh, 3), jnp.float32)
    w = jnp.asarray(rng.randn(k, k, 3, 4), jnp.float32)

    def loss(w):
        return 0.5 * (cd.conv2d(x, w, s, pad) ** 2).sum()

    dw_ref = jax.grad(loss)(w)
    dy = cd.conv2d(x, w, s, pad)
    dw = cd.conv2d_weight_grad(x, dy, s, (k, k), pad)
    np.testing.assert_allclose(np.asarray(dw), np.asarray(dw_ref), rtol=2e-4, atol=2e-4)


def test_custom_vjp_conv_trains():
    """The decomposed-VJP conv actually trains a toy layer."""
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(4, 10, 10, 3), jnp.float32)
    target = jnp.asarray(rng.randn(4, 4, 4, 8), jnp.float32)
    w = jnp.asarray(rng.randn(3, 3, 3, 8) * 0.1, jnp.float32)

    def loss(w):
        y = cd.conv2d_with_decomposed_vjp(x, w, stride=2, padding=0)
        return ((y - target) ** 2).mean()

    l0 = loss(w)
    g = jax.jit(jax.grad(loss))
    for _ in range(60):
        w = w - 0.05 * g(w)
    assert loss(w) < l0 * 0.9

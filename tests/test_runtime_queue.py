"""Offload runtime: queue back-pressure, sync-vs-queued, scheduler, model agreement."""

import sys
from pathlib import Path

import numpy as np
import pytest

from repro.core import ntx
from repro.runtime import cmdqueue, scheduler
from repro.runtime.cmdqueue import CommandQueue, QueueFull, QueueRecord
from repro.runtime.dma import DmaConfig, DmaEngine, Transfer, bank_conflict_factor
from repro.lower.rules import matmul_template

ROOT = str(Path(__file__).resolve().parents[1])
if ROOT not in sys.path:  # for `import benchmarks` under bare `pytest`
    sys.path.insert(0, ROOT)


def _cmds(n, m=4, k=16):
    return [matmul_template(m, m, k, 0, 100, 300) for _ in range(n)]


# ---------------------------------------------------------------------------
# CommandQueue semantics
# ---------------------------------------------------------------------------


def _rec(engine, issue, retire):
    cmd = _cmds(1)[0]
    return QueueRecord(cmd, engine, issue, issue, issue, issue, issue, retire)


def test_queue_backpressure_raises_when_full():
    q = CommandQueue(depth=2)
    q.push(_rec(0, 0, 100))
    q.push(_rec(0, 10, 200))
    with pytest.raises(QueueFull):
        q.push(_rec(0, 20, 300))  # both slots still in flight at t=20
    q.push(_rec(0, 100, 400))  # first retired at t=100 -> slot free


def test_queue_free_at_is_oldest_inflight_retire():
    q = CommandQueue(depth=2)
    q.push(_rec(0, 0, 100))
    q.push(_rec(0, 10, 200))
    assert q.free_at(50) == 100  # next slot frees when the older one retires
    assert q.free_at(150) == 150  # one in flight -> immediate
    assert q.occupancy(50) == 2
    assert q.occupancy(150) == 1


def test_depth_must_be_positive():
    with pytest.raises(ValueError):
        CommandQueue(0)


# ---------------------------------------------------------------------------
# simulate_offload: timestamps, depth, back-pressure accounting
# ---------------------------------------------------------------------------


def test_timestamps_monotonic_and_fifo_per_engine():
    tr = cmdqueue.simulate_offload(_cmds(40), n_engines=4, queue_depth=2)
    per_engine = {}
    for r in tr.records:
        assert r.program_start <= r.issue_t <= r.exec_start < r.retire_t
        prev = per_engine.get(r.engine)
        if prev is not None:
            assert r.issue_t >= prev.issue_t  # FIFO issue order
            assert r.exec_start >= prev.retire_t  # one command at a time
        per_engine[r.engine] = r


def test_queue_depth_never_exceeded():
    tr = cmdqueue.simulate_offload(_cmds(64), n_engines=2, queue_depth=3)
    for q in tr.queues:
        for r in q.records:
            assert q.occupancy(r.issue_t) <= q.depth


def test_backpressure_stalls_driver():
    # 1 engine, long commands: the driver must block on the full queue
    cmds = _cmds(16, m=8, k=64)
    tr = cmdqueue.simulate_offload(cmds, n_engines=1, queue_depth=2)
    assert tr.stats.queue_stall_cycles > 0
    # deeper queue, same makespan (engine was already saturated)
    deep = cmdqueue.simulate_offload(cmds, n_engines=1, queue_depth=16)
    assert deep.stats.total_cycles == tr.stats.total_cycles
    assert deep.stats.queue_stall_cycles == 0


def test_sync_mode_serializes():
    cmds = _cmds(24)
    s = cmdqueue.simulate_offload(cmds, n_engines=8, sync=True)
    # engines never overlap in sync mode: makespan >= sum of exec
    assert s.stats.total_cycles >= s.stats.exec_cycles
    q = cmdqueue.simulate_offload(cmds, n_engines=8, queue_depth=4)
    assert q.stats.total_cycles < s.stats.total_cycles


def test_one_driver_keeps_eight_engines_busy():
    """The paper's §2.2 design point: queue depth 4, 8 engines, >85% busy."""
    cmds = _cmds(256, m=8, k=32)
    tr = cmdqueue.simulate_offload(cmds, n_engines=8, queue_depth=4)
    assert tr.stats.utilization > 0.85


def test_offload_overhead_reduction_at_least_5x():
    """Acceptance: queued offload cuts modeled overhead >=5x vs synchronous."""
    _, _, red = cmdqueue.overhead_reduction(_cmds(128), n_engines=1,
                                            queue_depth=4)
    assert red >= 5.0, red


def test_dma_overlap_hides_transfers():
    cmds = _cmds(32, m=8, k=32)
    dma = [100] * len(cmds)
    ov = cmdqueue.simulate_offload(cmds, n_engines=2, dma_cycles=dma,
                                   dma_overlap=True)
    ser = cmdqueue.simulate_offload(cmds, n_engines=2, dma_cycles=dma,
                                    dma_overlap=False)
    assert ov.stats.total_cycles < ser.stats.total_cycles
    assert ov.stats.dma_stall_cycles < ser.stats.dma_stall_cycles


# ---------------------------------------------------------------------------
# DMA engine
# ---------------------------------------------------------------------------


def test_bank_conflicts():
    assert bank_conflict_factor(1) == 1
    assert bank_conflict_factor(2) == 2
    assert bank_conflict_factor(32) == 32
    assert bank_conflict_factor(0) == 32  # broadcast pins one bank
    assert bank_conflict_factor(33) == 1  # coprime stride spreads over banks
    cfg = DmaConfig(bytes_per_cycle=4.0, eta=1.0)
    assert cfg.transfer_cycles(Transfer(1024, word_stride=2)) == 2 * (
        cfg.transfer_cycles(Transfer(1024, word_stride=1))
    )


def test_double_buffering_overlaps():
    cfg = DmaConfig(bytes_per_cycle=4.0, eta=1.0)
    tiles = [(Transfer(400), 100)] * 16  # 100 dma cycles vs 100 compute
    ov = DmaEngine(cfg).pipeline(tiles, overlap=True)
    ser = DmaEngine(cfg).pipeline(tiles, overlap=False)
    assert ser.total_cycles == 16 * 200
    assert ov.total_cycles == 100 + 16 * 100  # fill + fully overlapped
    assert ov.overlap_efficiency > 0.9


def test_runtime_constants_match_analytic_model():
    from benchmarks import ntx_model as M

    from repro.runtime import dma as dma_mod

    assert dma_mod.R_D_BYTES_PER_CYCLE == M.R_D_BYTES
    assert dma_mod.ETA_DMA == M.ETA_D
    assert dma_mod.HMC_INTERNAL_BW == M.HMC_INTERNAL_BW
    assert scheduler.ETA_COMPUTE == M.ETA_C
    assert scheduler.ETA_NET == M.ETA_NET


# ---------------------------------------------------------------------------
# Scheduler: partitioning, timeline, analytic-model agreement
# ---------------------------------------------------------------------------


def test_partition_command_matches_whole_execution():
    rng = np.random.RandomState(1)
    m, n, k = 7, 5, 6
    a = rng.randn(m, k).astype(np.float32)
    b = rng.randn(k, n).astype(np.float32)
    mem = np.zeros(500, np.float32)
    mem[: m * k] = a.ravel()
    mem[100 : 100 + k * n] = b.ravel()
    cmd = matmul_template(m, n, k, 0, 100, 300)
    want = ntx.ntx_execute(cmd, mem)
    for parts in (2, 3, 7, 12):
        got = mem
        pieces = scheduler.partition_command(cmd, parts)
        assert len(pieces) == min(parts, m)
        assert sum(p.loops[2] for p in pieces) == m
        for p in pieces:
            got = ntx.ntx_execute(p, got)
        np.testing.assert_array_equal(got, want)


def test_partition_refuses_split_accumulations():
    # a pure reduction: store only at the very end -> cannot split loop 0
    cmd = ntx.NtxCommand(
        loops=(64, 1, 1, 1, 1), opcode="mac",
        agu_rd0=ntx.Agu(0, (1, 0, 0, 0, 0)),
        agu_rd1=ntx.Agu(64, (1, 0, 0, 0, 0)),
        agu_wr=ntx.Agu(200, (0, 0, 0, 0, 0)),
        init_level=ntx.MAX_LOOPS, store_level=5,
    )
    with pytest.raises(ValueError):
        scheduler.partition_command(cmd, 4)


def test_multicluster_schedule_and_trace(tmp_path):
    cmd = matmul_template(64, 32, 32, 0, 10_000, 20_000)
    sched = scheduler.MultiClusterScheduler(n_clusters=4)
    buckets = sched.distribute(cmd)
    assert len(buckets) == 4 and all(len(b) == 1 for b in buckets)
    res = sched.schedule(buckets, bytes_per_command=[1024.0] * 4)
    assert res.total_cycles > 0
    assert res.summary()["n_commands"] == 4

    trace = res.timeline.to_chrome_trace()
    assert trace["traceEvents"], "timeline must not be empty"
    for ev in trace["traceEvents"]:
        assert ev["ph"] == "X" and ev["dur"] >= 0
        assert ev["cat"] in ("program", "dma", "exec")
    path = tmp_path / "trace.json"
    res.timeline.save(path)
    assert path.stat().st_size > 0


def test_scheduler_flat_round_robin():
    cmds = _cmds(12)
    res = scheduler.MultiClusterScheduler(n_clusters=3).schedule(cmds)
    assert [t.stats.n_commands for t in res.cluster_traces] == [4, 4, 4]


def test_workload_cycles_match_analytic_model_within_10pct():
    """Acceptance: event-driven runtime vs benchmarks/ntx_model.py, 3+ loads."""
    from benchmarks import ntx_model as M
    from benchmarks.workloads import WORKLOADS

    checked = 0
    for name in ("googlenet", "resnet50", "inception_v3", "alexnet"):
        w = WORKLOADS[name]
        k = M.Kernel(macs=w.train_gflop * 1e9 / 2,
                     bytes_total=w.dma_bytes(True))
        m = M.cube(k, 16, 1.5e9, "28nm")
        assert not m.bw_capped  # the two models cap differently; compare uncapped
        est = scheduler.simulate_workload(k.macs, k.bytes_total,
                                          n_clusters=16, f_ntx=1.5e9)
        assert abs(est.time - m.time) / m.time < 0.10, name
        checked += 1
    assert checked >= 3

"""Shared pytest plumbing: the ``slow`` marker gate.

Long-running system/distributed tests are marked ``@pytest.mark.slow`` and
skipped by default so the tier-1 run stays fast; ``--runslow`` enables them
(CI runs both lanes).
"""

import pytest


def pytest_addoption(parser):
    parser.addoption("--runslow", action="store_true", default=False,
                     help="also run tests marked @pytest.mark.slow")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--runslow"):
        return
    skip_slow = pytest.mark.skip(reason="slow test: pass --runslow to include")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip_slow)

"""The network-graph compiler: whole train-step NtxPrograms.

Oracle is jax autodiff on the *same* model: the compiled step's logits,
per-parameter gradients, and updated weights must match ``jax.grad`` +
the SGD(+momentum) update to fp32 tolerance. The liveness allocator is
checked for actual reuse (peak < bump layout) and for the no-aliasing
invariant (regions overlapping in time never overlap in address), and all
three executors must see the same command stream.
"""

import numpy as np
import pytest

from repro.lower import (
    AttentionSpec,
    EmbeddingSpec,
    LayerNormSpec,
    LivenessAllocator,
    NS_DESIGN,
    NetworkGraph,
    PosEmbedSpec,
    ResidualAddSpec,
    edge_consumers,
    lower,
    lower_training_step,
    paper_cnn_graph,
    run_reference,
    run_timing,
    softmax_xent_loss,
    train_graph,
)

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.kernels import ref  # noqa: E402


def _batch(rng, b, img, n_classes=10):
    x = rng.randn(b, img, img, 3).astype(np.float32)
    labels = rng.randint(0, n_classes, b)
    return x, labels, np.eye(n_classes, dtype=np.float32)[labels]


def _jax_forward(graph, p, x):
    """The paper CNN of ``paper_cnn_graph`` in plain jax (the oracle)."""
    h = ref.conv2d_ref(x, p["w_c1"], stride=2, padding=2)
    h = jax.nn.relu(h)
    h = ref.conv2d_ref(h, p["w_c2"], stride=2, padding=1)
    h = jax.nn.relu(h)
    h = jax.lax.reduce_window(
        h, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )
    h = h.reshape(x.shape[0], -1)
    return h @ p["w_fc"] + p["b_fcb"][None, :]


# ---------------------------------------------------------------------------
# Whole-step oracle: gradients + updated weights vs jax.grad
# ---------------------------------------------------------------------------


def test_train_step_gradients_match_jax_grad():
    graph = paper_cnn_graph(batch=2, img=8, lr=0.05, momentum=0.9)
    prog = lower_training_step(graph)
    rng = np.random.RandomState(0)
    params = graph.init_params(seed=1)
    x, labels, onehot = _batch(rng, 2, 8)
    outs = run_reference(prog, {"x": x, "onehot": onehot, **params})

    def loss_fn(p):
        z = _jax_forward(graph, p, jnp.asarray(x))
        return -jnp.mean(jnp.sum(jax.nn.log_softmax(z) * onehot, axis=1))

    jp = {k: jnp.asarray(v) for k, v in params.items() if not k.startswith("v_")}
    loss, grads = jax.value_and_grad(loss_fn)(jp)

    # logits + host-side loss
    z = _jax_forward(graph, jp, jnp.asarray(x))
    np.testing.assert_allclose(
        outs[graph.logits_edge], np.asarray(z), rtol=1e-4, atol=1e-5
    )
    assert softmax_xent_loss(outs[graph.logits_edge], labels) == pytest.approx(
        float(loss), rel=1e-5
    )

    # per-parameter gradients, momentum, and the updated weights
    for p in graph.param_shapes():
        g = np.asarray(grads[p])
        np.testing.assert_allclose(
            outs[f"d_{p}"], g, rtol=1e-3, atol=1e-5, err_msg=p
        )
        v_new = graph.momentum * params[f"v_{p}"] + g
        np.testing.assert_allclose(
            outs[f"v_{p}_new"], v_new, rtol=1e-3, atol=1e-5, err_msg=p
        )
        np.testing.assert_allclose(
            outs[f"{p}_new"], params[p] - graph.lr * v_new,
            rtol=1e-3, atol=1e-5, err_msg=p,
        )


def test_train_step_plain_sgd_and_ns_design():
    """No-momentum update + the NS design point produce the same numerics."""
    graph = paper_cnn_graph(batch=2, img=8, lr=0.1, momentum=0.0)
    rng = np.random.RandomState(1)
    params = graph.init_params(seed=2)
    x, _labels, onehot = _batch(rng, 2, 8)
    inputs = {"x": x, "onehot": onehot, **params}
    outs = run_reference(lower_training_step(graph), inputs)
    ns_outs = run_reference(
        lower_training_step(graph, design=NS_DESIGN), inputs
    )
    assert "v_w_c1_new" not in outs
    for k in outs:
        np.testing.assert_allclose(
            ns_outs[k], outs[k], rtol=1e-5, atol=1e-6, err_msg=k
        )
    for p in graph.param_shapes():
        np.testing.assert_allclose(
            outs[f"{p}_new"], params[p] - 0.1 * outs[f"d_{p}"],
            rtol=1e-5, atol=1e-6,
        )


# ---------------------------------------------------------------------------
# One program, three executors, identical command streams
# ---------------------------------------------------------------------------


def test_all_executors_consume_one_program():
    from repro.lower import PlanCache, run_pallas

    graph = paper_cnn_graph(batch=2, img=8)
    prog = lower_training_step(graph)
    rng = np.random.RandomState(2)
    params = graph.init_params(seed=3)
    x, _labels, onehot = _batch(rng, 2, 8)
    inputs = {"x": x, "onehot": onehot, **params}

    want = run_reference(prog, inputs)
    ev = run_timing(prog, n_clusters=2, engine="event").summary()
    bl = run_timing(prog, n_clusters=2, engine="block").summary()
    assert ev["n_commands"] == prog.n_commands == bl["n_commands"]
    assert all(ev[k] == bl[k] for k in ev if k != "elided_commands")

    got = run_pallas(prog, inputs, cache=PlanCache())
    assert set(got) == set(want)
    for k in want:
        np.testing.assert_allclose(
            np.asarray(got[k]), want[k], rtol=2e-3, atol=1e-5, err_msg=k
        )


def test_training_decreases_loss_reference_backend():
    graph = paper_cnn_graph(batch=4, img=8, lr=0.1, momentum=0.9)
    rng = np.random.RandomState(3)
    y = rng.randint(0, 10, 4)
    base = np.linspace(0, 3.14 * 4, 8)
    imgs = np.stack([
        np.sin(base[None, :] * (1 + c)) * np.cos(base[:, None] * (1 + c))
        for c in y
    ])[..., None].repeat(3, axis=-1).astype(np.float32)

    res = train_graph(graph, 4, lambda _i: (imgs, y), backend="reference")
    assert res["losses"][-1] < res["losses"][0], res["losses"]


# ---------------------------------------------------------------------------
# The liveness allocator
# ---------------------------------------------------------------------------


def test_liveness_reuse_beats_bump_allocation():
    graph = paper_cnn_graph(batch=2, img=16)
    prog = lower_training_step(graph)
    peak = prog.meta["peak_tcdm_bytes"]
    # bump layout = every distinct storage location laid out back to back
    seen_bases = set()
    bump = 0
    for r in prog.regions.values():
        if r.base not in seen_bases:
            seen_bases.add(r.base)
            bump += r.bytes
    assert peak < bump, (peak, bump)
    assert peak <= prog.meta["tcdm_budget_bytes"]


def test_no_region_aliasing_across_live_intervals():
    graph = paper_cnn_graph(batch=2, img=8)
    prog = lower_training_step(graph)
    intervals = prog.meta["intervals"]
    regions = prog.regions
    names = list(intervals)
    for i, a in enumerate(names):
        ra, (sa, ea) = regions[a], intervals[a]
        for b in names[i + 1:]:
            rb, (sb, eb) = regions[b], intervals[b]
            overlap_time = not (ea < sb or eb < sa)
            overlap_addr = not (ra.end <= rb.base or rb.end <= ra.base)
            if overlap_time and overlap_addr:
                # the only legal address sharing is an explicit alias view,
                # which shares the full storage window exactly
                assert ra.base == rb.base and ra.size == rb.size, (
                    f"{a}{intervals[a]}@[{ra.base},{ra.end}) aliases "
                    f"{b}{intervals[b]}@[{rb.base},{rb.end})"
                )


def test_allocator_spills_over_budget_and_execution_is_identical():
    graph = paper_cnn_graph(batch=2, img=16)
    full = lower_training_step(graph, n_clusters=16)
    tiny = lower_training_step(graph, n_clusters=1)
    assert not full.meta["spilled"]
    assert tiny.meta["spilled"]
    assert tiny.meta["peak_tcdm_bytes"] <= tiny.meta["tcdm_budget_bytes"]
    spills = [b for b in tiny.blocks if b.tag.startswith(("spill:", "fill:"))]
    assert spills and all(
        b.dma_bytes_in + b.dma_bytes_out > 0 for b in spills
    )
    rng = np.random.RandomState(4)
    params = graph.init_params(seed=4)
    x, _labels, onehot = _batch(rng, 2, 16)
    inputs = {"x": x, "onehot": onehot, **params}
    a = run_reference(full, inputs)
    b = run_reference(tiny, inputs)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k], err_msg=k)


# ---------------------------------------------------------------------------
# Transformer node lowerings: per-node oracle round-trips
# ---------------------------------------------------------------------------


def test_attention_matches_jax_vjp():
    S, H, Dh = 6, 2, 4
    D = H * Dh
    spec = AttentionSpec(S, H, Dh)
    rng = np.random.RandomState(5)
    qkv = rng.randn(S, 3 * D).astype(np.float32)

    def oracle(qkv):
        def heads(m):
            return m.reshape(S, H, Dh).transpose(1, 0, 2)

        q, k, v = (heads(qkv[:, i * D:(i + 1) * D]) for i in range(3))
        sc = jnp.einsum("hid,hjd->hij", q, k) * (Dh ** -0.5)
        mask = jnp.where(jnp.tril(jnp.ones((S, S))) > 0, 0.0, -1e9)
        pr = jax.nn.softmax(sc + mask[None], axis=-1)
        return jnp.einsum("hij,hjd->hid", pr, v).transpose(1, 0, 2).reshape(S, D)

    outs = run_reference(lower(spec, "fwd"), {"x": qkv})
    want_y, vjp = jax.vjp(oracle, jnp.asarray(qkv))
    np.testing.assert_allclose(
        outs["y"], np.asarray(want_y), rtol=1e-4, atol=1e-5
    )
    dctx = rng.randn(S, D).astype(np.float32)
    outs = run_reference(lower(spec, "dx"), {"x": qkv, "dy": dctx})
    np.testing.assert_allclose(
        outs["dx"], np.asarray(vjp(jnp.asarray(dctx))[0]),
        rtol=1e-4, atol=1e-5,
    )


def test_layernorm_matches_jax_vjp():
    rows, d, eps = 10, 8, 1e-5
    spec = LayerNormSpec(rows, d, eps)
    rng = np.random.RandomState(6)
    x = rng.randn(rows, d).astype(np.float32)
    w = rng.randn(2, d).astype(np.float32)  # row0=gamma, row1=beta

    def oracle(x, w):
        mu = jnp.mean(x, axis=1, keepdims=True)
        var = jnp.mean((x - mu) ** 2, axis=1, keepdims=True)
        return (x - mu) * jax.lax.rsqrt(var + eps) * w[0] + w[1]

    want_y, vjp = jax.vjp(oracle, jnp.asarray(x), jnp.asarray(w))
    outs = run_reference(lower(spec, "fwd"), {"x": x, "w": w})
    np.testing.assert_allclose(
        outs["y"], np.asarray(want_y), rtol=1e-4, atol=1e-5
    )
    dy = rng.randn(rows, d).astype(np.float32)
    want_dx, want_dw = vjp(jnp.asarray(dy))
    outs = run_reference(lower(spec, "dw"), {"x": x, "dy": dy})
    np.testing.assert_allclose(
        outs["dw"], np.asarray(want_dw), rtol=1e-4, atol=1e-5
    )
    outs = run_reference(lower(spec, "dx"), {"x": x, "w": w, "dy": dy})
    np.testing.assert_allclose(
        outs["dx"], np.asarray(want_dx), rtol=1e-4, atol=1e-5
    )


def test_residual_embedding_posembed_match_oracles():
    rng = np.random.RandomState(7)
    a = rng.randn(5, 7).astype(np.float32)
    b = rng.randn(5, 7).astype(np.float32)
    rs = ResidualAddSpec((5, 7))
    np.testing.assert_allclose(
        run_reference(lower(rs, "fwd"), {"x": a, "x2": b})["y"], a + b,
        rtol=1e-6,
    )
    # d(x + x2)/dx is the identity on both inputs
    np.testing.assert_allclose(
        run_reference(lower(rs, "dx"), {"dy": a})["dx"], a, rtol=1e-6
    )

    emb = EmbeddingSpec(rows=6, vocab=11, d=5)
    oh = np.eye(11, dtype=np.float32)[rng.randint(0, 11, 6)]
    W = rng.randn(11, 5).astype(np.float32)
    np.testing.assert_allclose(
        run_reference(lower(emb, "fwd"), {"x": oh, "w": W})["y"], oh @ W,
        rtol=1e-4, atol=1e-5,
    )
    dy = rng.randn(6, 5).astype(np.float32)
    np.testing.assert_allclose(
        run_reference(lower(emb, "dw"), {"x": oh, "dy": dy})["dw"],
        oh.T @ dy, rtol=1e-4, atol=1e-5,
    )

    pe = PosEmbedSpec(batch=3, seq=4, d=5)
    x3 = rng.randn(3, 4, 5).astype(np.float32)
    P = rng.randn(4, 5).astype(np.float32)
    np.testing.assert_allclose(
        run_reference(lower(pe, "fwd"), {"x": x3, "w": P})["y"],
        x3 + P[None], rtol=1e-5,
    )
    dy3 = rng.randn(3, 4, 5).astype(np.float32)
    np.testing.assert_allclose(
        run_reference(lower(pe, "dw"), {"dy": dy3})["dw"], dy3.sum(0),
        rtol=1e-4, atol=1e-5,
    )
    np.testing.assert_allclose(
        run_reference(lower(pe, "dx"), {"dy": dy3})["dx"], dy3, rtol=1e-6
    )


# ---------------------------------------------------------------------------
# The DAG compiler: tiny transformer vs jax.grad, branching liveness
# ---------------------------------------------------------------------------


def _tiny_lm(batch=2, seq=6, n_layers=2):
    from repro.models.config import ModelConfig

    cfg = ModelConfig(
        name="tiny", family="dense", n_layers=n_layers, d_model=16,
        n_heads=2, n_kv_heads=2, head_dim=8, d_ff=32, vocab_size=13,
    )
    return NetworkGraph.from_model_config(cfg, batch=batch, seq=seq, lr=0.05)


def test_lm_train_step_gradients_match_jax_grad():
    from repro.launch.train import _dag_oracle_loss

    graph = _tiny_lm()
    prog = lower_training_step(graph)
    params = graph.init_params(seed=1)
    rng = np.random.RandomState(8)
    V = graph.loss.classes
    eye = np.eye(V, dtype=np.float32)
    x = eye[rng.randint(0, V, graph.loss.batch)]
    onehot = eye[rng.randint(0, V, graph.loss.batch)]
    outs = run_reference(
        prog, {graph.input_edge: x, graph.label_edge: onehot, **params}
    )

    jp = {k: jnp.asarray(v) for k, v in params.items()}
    grads = jax.grad(
        lambda p: _dag_oracle_loss(graph, p, jnp.asarray(x),
                                   jnp.asarray(onehot))
    )(jp)
    for p in graph.param_shapes():
        g = np.asarray(grads[p])
        np.testing.assert_allclose(
            outs[f"d_{p}"], g, rtol=1e-4, atol=1e-5, err_msg=p
        )
        np.testing.assert_allclose(
            outs[f"{p}_new"], params[p] - graph.lr * g,
            rtol=1e-4, atol=1e-5, err_msg=p,
        )


def test_lm_dag_liveness_and_gradient_accumulation():
    graph = _tiny_lm(n_layers=1)
    prog = lower_training_step(graph)

    # residual fan-out: the skip edges feed both a layernorm and an add
    multi = {e: [n.name for n in ns]
             for e, ns in edge_consumers(graph).items() if len(ns) > 1}
    assert multi, "expected residual fan-out edges"
    for e, names in multi.items():
        assert len(names) == 2, (e, names)
    # ... and each fan-out edge gets an explicit partial-accumulation step
    acc_tags = {b.tag for b in prog.blocks if ":acc:" in b.tag}
    assert {t.split(":")[0] for t in acc_tags} == set(multi)

    # the liveness allocator invariants must survive branching lifetimes
    assert prog.meta["peak_tcdm_bytes"] <= prog.meta["tcdm_budget_bytes"]
    seen_bases, bump = set(), 0
    for r in prog.regions.values():
        if r.base not in seen_bases:
            seen_bases.add(r.base)
            bump += r.bytes
    assert prog.meta["peak_tcdm_bytes"] < bump
    intervals, regions = prog.meta["intervals"], prog.regions
    names = list(intervals)
    for i, a in enumerate(names):
        ra, (sa, ea) = regions[a], intervals[a]
        for b in names[i + 1:]:
            rb, (sb, eb) = regions[b], intervals[b]
            if not (ea < sb or eb < sa):  # live at the same time
                assert (ra.end <= rb.base or rb.end <= ra.base
                        or (ra.base == rb.base and ra.size == rb.size)), (
                    f"{a} aliases {b}"
                )


def test_sequential_is_deprecated_alias_of_chain():
    from repro.lower.rules import FlattenSpec, MatmulSpec

    layers = [("flat", "flatten"), ("fc", MatmulSpec(2, 10, 12))]
    with pytest.warns(DeprecationWarning, match="from_model_config"):
        old = NetworkGraph.sequential("t", 2, (3, 4), layers)
    new = NetworkGraph.chain("t", 2, (3, 4), layers)
    assert old == new


def test_liveness_allocator_unit():
    l = LivenessAllocator(budget_words=100)
    x = l.alloc("x", (40,), "input", start=0, end=2)
    y = l.alloc("y", (30,), "scratch", start=1, end=3)
    z = l.alloc("z", (35,), "scratch", start=3, end=5)
    assert z.base == x.base  # x died at 2 -> its hole is recycled
    assert l.peak_tcdm_words == 70
    s = l.alloc("s", (50,), "scratch", start=3, end=4)  # nothing fits
    assert "s" in l.spilled and s.base >= 100
    assert y.base == 40  # live regions were never moved
    f = l.alias("f", "z", (5, 7), "scratch", end=9)
    assert f.base == z.base and f.size == z.size

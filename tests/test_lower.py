"""The unified lowering pipeline: spec -> NtxProgram -> {reference, timing,
Pallas} round trips.

Ground truth is always an independent derivation: the jnp oracles in
``kernels/ref.py`` for the forward passes, ``core/conv_decomp.py`` (itself
validated against jax.vjp in test_conv_decomp.py) for the training passes,
and the closed-form Table 2 arithmetic in ``core/ntx.py`` for offload
counts.
"""

import numpy as np
import pytest

from repro.core import ntx
from repro.lower import (
    Conv2dSpec,
    MatmulSpec,
    MaxPool2dSpec,
    NS_DESIGN,
    NTX_DESIGN,
    ReluSpec,
    lower,
    lower_layer,
    run_reference,
    run_timing,
)

jnp = pytest.importorskip("jax.numpy")

CONV_CASES = [  # (spec, label) — strides and paddings the paper exercises
    (Conv2dSpec(8, 9, 3, 3, 2, 4), "s1p0"),
    (Conv2dSpec(8, 9, 3, 3, 3, 4, padding=1), "s1p1"),
    (Conv2dSpec(9, 8, 2, 3, 3, 3, stride=2), "s2p0"),
    (Conv2dSpec(8, 8, 3, 3, 3, 4, stride=2, padding=1), "s2p1"),
    (Conv2dSpec(11, 10, 2, 5, 4, 3, stride=3, padding=2), "s3p2"),
]


def _rand(rng, *shape):
    return rng.randn(*shape).astype(np.float32)


# ---------------------------------------------------------------------------
# Reference executor vs jnp oracles
# ---------------------------------------------------------------------------


def test_matmul_all_passes_match_numpy():
    rng = np.random.RandomState(0)
    m, n, k = 6, 5, 7
    a, b, dy = _rand(rng, m, k), _rand(rng, k, n), _rand(rng, m, n)
    out = run_reference(lower(MatmulSpec(m, n, k), "fwd"), {"a": a, "b": b})
    np.testing.assert_allclose(out["c"], a @ b, rtol=1e-5, atol=1e-6)
    out = run_reference(lower(MatmulSpec(m, n, k), "dw"), {"a": a, "dy": dy})
    np.testing.assert_allclose(out["dw"], a.T @ dy, rtol=1e-5, atol=1e-6)
    out = run_reference(lower(MatmulSpec(m, n, k), "dx"), {"dy": dy, "b": b})
    np.testing.assert_allclose(out["dx"], dy @ b.T, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("spec,label", CONV_CASES, ids=[c[1] for c in CONV_CASES])
def test_conv_fwd_matches_jnp_oracle(spec, label):
    from repro.kernels import ref

    rng = np.random.RandomState(1)
    x = _rand(rng, spec.in_h, spec.in_w, spec.cin)
    w = _rand(rng, spec.kh, spec.kw, spec.cin, spec.cout)
    got = run_reference(lower(spec, "fwd"), {"x": x, "w": w})["y"]
    want = np.asarray(
        ref.conv2d_ref(jnp.asarray(x)[None], jnp.asarray(w),
                       stride=spec.stride, padding=spec.padding)
    )[0]
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("spec,label", CONV_CASES, ids=[c[1] for c in CONV_CASES])
def test_conv_dw_matches_decomp_oracle(spec, label):
    from repro.core import conv_decomp

    rng = np.random.RandomState(2)
    x = _rand(rng, spec.in_h, spec.in_w, spec.cin)
    dy = _rand(rng, spec.out_h, spec.out_w, spec.cout)
    got = run_reference(lower(spec, "dw"), {"x": x, "dy": dy})["dw"]
    want = np.asarray(
        conv_decomp.conv2d_weight_grad(
            jnp.asarray(x)[None], jnp.asarray(dy)[None],
            spec.stride, (spec.kh, spec.kw), spec.padding,
        )
    )
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("spec,label", CONV_CASES, ids=[c[1] for c in CONV_CASES])
def test_conv_dx_matches_decomp_oracle(spec, label):
    from repro.core import conv_decomp

    rng = np.random.RandomState(3)
    w = _rand(rng, spec.kh, spec.kw, spec.cin, spec.cout)
    dy = _rand(rng, spec.out_h, spec.out_w, spec.cout)
    got = run_reference(lower(spec, "dx"), {"dy": dy, "w": w})["dx"]
    want = np.asarray(
        conv_decomp.conv2d_input_grad_decomposed(
            jnp.asarray(dy)[None], jnp.asarray(w),
            spec.stride, (spec.in_h, spec.in_w), spec.padding,
        )
    )[0]
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_pool_and_relu_match_numpy():
    rng = np.random.RandomState(4)
    spec = MaxPool2dSpec(6, 8, 3)
    x = _rand(rng, 6, 8, 3)
    got = run_reference(lower(spec), {"x": x})["y"]
    want = x.reshape(3, 2, 4, 2, 3).max(axis=(1, 3))
    np.testing.assert_array_equal(got, want)
    r = ReluSpec((4, 5))
    x = _rand(rng, 4, 5)
    got = run_reference(lower(r), {"x": x})["y"]
    np.testing.assert_array_equal(got, np.maximum(x, 0.0))


# ---------------------------------------------------------------------------
# Offload counts vs the closed form (Table 2) — both design points
# ---------------------------------------------------------------------------


def test_table2_counts_from_programs():
    rows = [
        (Conv2dSpec(224, 224, 3, 7, 7, 64, stride=2, padding=3), 802_816, 64,
         147, 1_843_968),
        (Conv2dSpec(56, 56, 64, 3, 3, 192, padding=1), 602_112, 192,
         576, 1_806_336),
        (Conv2dSpec(28, 28, 256, 1, 1, 64), 50_176, 64, 256, 200_704),
        (Conv2dSpec(14, 14, 512, 1, 1, 192), 37_632, 192, 512, 100_352),
    ]
    for spec, ns_off, ntx_off, ns_cyc, ntx_cyc in rows:
        ns = lower(spec, "fwd", design=NS_DESIGN)
        nt = lower(spec, "fwd", design=NTX_DESIGN)
        assert ns.n_offloads == ns_off
        assert nt.n_offloads == ntx_off
        assert ns.busy_cycles_per_offload == ns_cyc
        assert nt.busy_cycles_per_offload == ntx_cyc
        shape = spec.conv_shape()
        assert ns.n_offloads == ntx.offload_count(shape, **ntx.NS_LOOPS)
        assert nt.n_offloads == ntx.offload_count(shape, **ntx.NTX_LOOPS)


def test_every_workload_layer_lowers_all_passes():
    """Acceptance: lower() produces fwd/dW/dX for every conv workload in
    benchmarks/workloads.py, counts agreeing with the closed form."""
    from benchmarks.workloads import CONV_LAYERS

    for name, specs in CONV_LAYERS.items():
        for spec in specs:
            progs = lower_layer(spec)
            assert set(progs) == {"fwd", "dw", "dx"}
            shape = spec.conv_shape()
            assert progs["fwd"].n_offloads == ntx.offload_count(
                shape, **ntx.NTX_LOOPS
            ), f"{name}: {spec}"
            # training-pass MAC work ~= 2x forward (exactly for these shapes
            # the dW correlation matches fwd MACs; dX pays only tap coverage)
            fwd = progs["fwd"].busy_cycles
            bwd = progs["dw"].busy_cycles + progs["dx"].busy_cycles
            assert 1.5 * fwd <= bwd <= 2.6 * fwd, (name, spec, bwd / fwd)


def test_ns_design_rejects_matmul_output_loops():
    """NS (no write-back AGU) must put every output pixel in its own
    command: one offload per (m, n) for matmul."""
    p = lower(MatmulSpec(6, 5, 9), "fwd", design=NS_DESIGN)
    assert p.n_offloads == 6 * 5
    assert p.blocks[0].template.loops == (9, 1, 1, 1, 1)
    out = run_reference(p, {"a": np.eye(6, 9, dtype=np.float32),
                            "b": np.ones((9, 5), np.float32)})
    np.testing.assert_allclose(out["c"], np.eye(6, 9) @ np.ones((9, 5)))


# ---------------------------------------------------------------------------
# Partitioner integration: lowered commands stay bit-identical when split
# ---------------------------------------------------------------------------


def test_partition_command_over_lowered_program_bit_identical():
    from repro.runtime import scheduler as rs

    rng = np.random.RandomState(5)
    spec = Conv2dSpec(7, 8, 2, 3, 2, 3, stride=2, padding=1)
    for pass_ in ("fwd", "dw", "dx"):
        prog = lower(spec, pass_)
        mem = np.zeros(prog.memory_words, np.float32)
        for r in prog.regions.values():
            if r.kind in ("input", "param"):
                mem[r.base : r.end] = rng.randn(r.size)
        whole = mem.copy()
        parts_mem = mem.copy()
        for cmd in prog.commands():
            ntx.ntx_execute(cmd, whole, inplace=True)
            for part in rs.partition_command(cmd, 3):
                ntx.ntx_execute(part, parts_mem, inplace=True)
        np.testing.assert_array_equal(whole, parts_mem, err_msg=pass_)


# ---------------------------------------------------------------------------
# Timing executor
# ---------------------------------------------------------------------------


def test_timing_executor_consumes_program():
    spec = Conv2dSpec(8, 8, 3, 3, 3, 4, padding=1)
    prog = lower(spec, "fwd")
    res = run_timing(prog, n_clusters=2)
    assert res.summary()["n_commands"] == prog.n_commands
    # engine-seconds must cover the program's datapath work, and the
    # makespan can't beat perfect parallelism over 2 clusters x 8 engines
    # nor the longest single command
    assert res.exec_cycles >= prog.busy_cycles
    longest = max(c.busy_cycles for c in prog.commands())
    assert res.total_cycles >= max(longest, prog.busy_cycles / 16)


def test_timing_executor_handles_huge_programs():
    """The old MAX_TIMED_COMMANDS guard is gone: NS-design programs with
    hundreds of thousands of commands route through the block-replicated
    steady-state engine (exactness vs the event engine is asserted in
    test_timing_fast.py)."""
    spec = Conv2dSpec(224, 224, 3, 7, 7, 64, stride=2, padding=3)
    prog = lower(spec, "fwd", design=NS_DESIGN)  # 802816 commands + staging
    res = run_timing(prog, n_clusters=4)  # auto -> block engine
    s = res.summary()
    assert s["n_commands"] == prog.n_commands
    assert s["elided_commands"] > 0  # records were not materialized
    assert res.exec_cycles >= prog.busy_cycles


# ---------------------------------------------------------------------------
# Pallas executor (interpret mode on CPU)
# ---------------------------------------------------------------------------


def test_pallas_executor_matmul_and_conv_fwd():
    from repro.lower import run_pallas

    rng = np.random.RandomState(6)
    m, n, k = 8, 6, 12
    a, b = _rand(rng, m, k), _rand(rng, k, n)
    out = run_pallas(lower(MatmulSpec(m, n, k), "fwd"), {"a": a, "b": b})
    np.testing.assert_allclose(out["c"], a @ b, rtol=1e-4, atol=1e-4)

    spec = Conv2dSpec(8, 8, 3, 3, 3, 4, stride=2, padding=1)
    x = _rand(rng, spec.in_h, spec.in_w, spec.cin)
    w = _rand(rng, spec.kh, spec.kw, spec.cin, spec.cout)
    ref_y = run_reference(lower(spec, "fwd"), {"x": x, "w": w})["y"]
    pal_y = run_pallas(lower(spec, "fwd"), {"x": x, "w": w})["y"]
    np.testing.assert_allclose(ref_y, pal_y, rtol=1e-4, atol=1e-4)


def test_pallas_executor_conv_training_passes():
    from repro.lower import run_pallas

    rng = np.random.RandomState(7)
    spec = Conv2dSpec(8, 8, 3, 3, 3, 4, stride=2, padding=1)
    x = _rand(rng, spec.in_h, spec.in_w, spec.cin)
    w = _rand(rng, spec.kh, spec.kw, spec.cin, spec.cout)
    dy = _rand(rng, spec.out_h, spec.out_w, spec.cout)
    ref_dw = run_reference(lower(spec, "dw"), {"x": x, "dy": dy})["dw"]
    pal_dw = run_pallas(lower(spec, "dw"), {"x": x, "dy": dy})["dw"]
    np.testing.assert_allclose(ref_dw, pal_dw, rtol=1e-4, atol=1e-4)
    ref_dx = run_reference(lower(spec, "dx"), {"dy": dy, "w": w})["dx"]
    pal_dx = run_pallas(lower(spec, "dx"), {"dy": dy, "w": w})["dx"]
    np.testing.assert_allclose(ref_dx, pal_dx, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Backward rules for parameter-free layers + the support matrix
# ---------------------------------------------------------------------------


def test_relu_dx_matches_mask():
    rng = np.random.RandomState(8)
    spec = ReluSpec((5, 6))
    x, dy = _rand(rng, 5, 6), _rand(rng, 5, 6)
    got = run_reference(lower(spec, "dx"), {"x": x, "dy": dy})["dx"]
    np.testing.assert_array_equal(got, dy * (x > 0))


def test_maxpool_dx_matches_jax_vjp():
    import jax

    rng = np.random.RandomState(9)
    spec = MaxPool2dSpec(6, 8, 3)
    x = _rand(rng, 6, 8, 3)
    dy = _rand(rng, spec.out_h, spec.out_w, 3)

    def pool(xx):
        return jax.lax.reduce_window(
            xx, -jnp.inf, jax.lax.max, (2, 2, 1), (2, 2, 1), "VALID"
        )

    y, vjp = jax.vjp(pool, jnp.asarray(x))
    want = np.asarray(vjp(jnp.asarray(dy))[0])
    got = run_reference(
        lower(spec, "dx"), {"x": x, "y": np.asarray(y), "dy": dy}
    )["dx"]
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-7)


def test_lower_support_matrix_errors_are_precise():
    # meaningful-but-unsupported combos -> NotImplementedError
    with pytest.raises(NotImplementedError, match="window == stride"):
        lower(MaxPool2dSpec(9, 9, 2, window=3, stride=2), "dx")
    from repro.lower import FlattenSpec, SoftmaxXentSpec

    with pytest.raises(NotImplementedError, match="zero-copy view"):
        lower(FlattenSpec((4, 4, 2)))
    with pytest.raises(NotImplementedError, match="driver core"):
        lower(SoftmaxXentSpec(4, 10), "fwd")
    # nonsensical pass names -> ValueError
    with pytest.raises(ValueError, match="no parameters"):
        lower(ReluSpec((4,)), "dw")
    with pytest.raises(ValueError, match="no parameters"):
        lower(MaxPool2dSpec(8, 8, 2), "dw")


def test_program_dma_descriptors_cover_regions():
    spec = Conv2dSpec(14, 14, 512, 1, 1, 192)
    prog = lower(spec, "fwd")
    x, w = prog.region("x"), prog.region("w")
    per_cmd = prog.blocks[-1].dma_bytes_in
    assert per_cmd * prog.n_offloads == pytest.approx(x.bytes + w.bytes)
    assert prog.dma_bytes > 0
    assert prog.memory_words >= sum(r.size for r in prog.regions.values())

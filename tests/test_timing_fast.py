"""Block-replicated timing fast path: exact agreement with the event-driven
engine, block-boundary stitching, and the wall-clock floor.

The contract under test (see ``simulate_offload_blocks``): simulating a
homogeneous command block event-by-event until one full engine round advances
every live timestamp by the same delta, then replicating analytically, must
produce *bit-identical* cycle stats to simulating every command — the update
rules are max-plus, so a uniformly shifted state reproduces a uniformly
shifted round. These tests drive randomized programs through both engines
and require exact equality, then check the speed claims that justify
removing the old ``MAX_TIMED_COMMANDS`` guard.
"""

import time

import numpy as np
import pytest

from repro.core.ntx import Agu, NtxCommand
from repro.lower import (
    Conv2dSpec,
    MatmulSpec,
    NS_DESIGN,
    NTX_DESIGN,
    lower,
    run_timing,
)
from repro.runtime import cmdqueue, scheduler
from repro.runtime.cmdqueue import BlockSegment


def _summaries_equal(a, b):
    sa, sb = a.summary(), b.summary()
    keys = set(sa) - {"elided_commands"}
    return all(sa[k] == sb[k] for k in keys), {k: (sa[k], sb[k]) for k in keys}


def _rand_template(rng):
    loops = tuple(int(rng.randint(1, 6)) for _ in range(5))
    return NtxCommand(
        loops=loops,
        opcode="mac",
        agu_rd0=Agu(0, (1, 0, 0, 0, 0)),
        agu_rd1=Agu(100, (1, 0, 0, 0, 0)) if rng.rand() < 0.7 else None,
        agu_wr=Agu(200, (0, 1, 0, 0, 0)) if rng.rand() < 0.8 else None,
    )


# ---------------------------------------------------------------------------
# Exactness: randomized segment streams, every config axis
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(8))
def test_randomized_segments_match_event_engine_exactly(seed):
    rng = np.random.RandomState(seed)
    for _ in range(6):
        segs = [
            BlockSegment(
                _rand_template(rng),
                int(rng.randint(1, 400)),
                int(rng.choice([0, 3, 17, 80])),
            )
            for _ in range(rng.randint(1, 6))
        ]
        cmds = [s.template for s in segs for _ in range(s.count)]
        dcs = [s.dma_cycles for s in segs for _ in range(s.count)]
        kw = dict(
            n_engines=int(rng.choice([1, 3, 8])),
            queue_depth=int(rng.choice([1, 2, 4])),
            sync=bool(rng.rand() < 0.2),
            dma_overlap=bool(rng.rand() < 0.8),
            dma_buffers=int(rng.choice([1, 2, 3])),
        )
        ev = cmdqueue.simulate_offload(cmds, dma_cycles=dcs, **kw)
        bl = cmdqueue.simulate_offload_blocks(segs, **kw)
        assert ev.stats == bl.stats, (kw, ev.stats, bl.stats)
        assert bl.elided_commands + len(bl.records) == len(cmds)


def test_block_boundaries_stitch_exactly():
    """Segments whose counts are not multiples of the engine count shift the
    round-robin phase at every boundary; the carried state must stitch."""
    rng = np.random.RandomState(99)
    segs = [
        BlockSegment(_rand_template(rng), c, d)
        for c, d in [(37, 11), (101, 0), (64, 25), (5, 7), (200, 3)]
    ]
    cmds = [s.template for s in segs for _ in range(s.count)]
    dcs = [s.dma_cycles for s in segs for _ in range(s.count)]
    for n_eng in (3, 8):
        ev = cmdqueue.simulate_offload(
            cmds, n_engines=n_eng, queue_depth=4, dma_cycles=dcs
        )
        bl = cmdqueue.simulate_offload_blocks(
            segs, n_engines=n_eng, queue_depth=4
        )
        assert ev.stats == bl.stats


# ---------------------------------------------------------------------------
# Exactness at the program level (run_timing engine="block" vs "event")
# ---------------------------------------------------------------------------


PROGRAM_CASES = [
    (Conv2dSpec(8, 8, 3, 3, 3, 4, padding=1), "fwd", NTX_DESIGN),
    (Conv2dSpec(8, 8, 3, 3, 3, 4, stride=2, padding=1), "dx", NTX_DESIGN),
    (Conv2dSpec(14, 14, 8, 3, 3, 6, padding=1), "fwd", NS_DESIGN),
    (Conv2dSpec(9, 11, 2, 5, 4, 3, stride=3, padding=2), "dw", NS_DESIGN),
    (MatmulSpec(30, 20, 10), "fwd", NS_DESIGN),
    (MatmulSpec(16, 16, 16), "dw", NTX_DESIGN),
]


@pytest.mark.parametrize(
    "spec,pass_,design",
    PROGRAM_CASES,
    ids=[f"{type(s).__name__}-{p}-{d.name}" for s, p, d in PROGRAM_CASES],
)
def test_program_block_engine_matches_event(spec, pass_, design):
    prog = lower(spec, pass_, design=design)
    for ncl in (1, 2, 4):
        ev = run_timing(prog, n_clusters=ncl, engine="event")
        bl = run_timing(prog, n_clusters=ncl, engine="block")
        ok, diff = _summaries_equal(ev, bl)
        assert ok, (spec, pass_, design.name, ncl, diff)


def test_partitioned_program_block_engine_matches_event():
    """mesh_sweep refines programs with partition_program first — the fast
    path must stay exact over the refined block structure too."""
    prog = lower(Conv2dSpec(12, 12, 4, 3, 3, 8, padding=1), "fwd")
    part = scheduler.partition_program(prog, 16)
    assert part.n_commands > prog.n_commands
    ev = run_timing(part, n_clusters=2, engine="event")
    bl = run_timing(part, n_clusters=2, engine="block")
    ok, diff = _summaries_equal(ev, bl)
    assert ok, diff


def test_sync_cluster_config_matches_event():
    prog = lower(Conv2dSpec(10, 10, 3, 3, 3, 4), "fwd", design=NS_DESIGN)
    cl = scheduler.ClusterConfig(sync=True)
    ev = run_timing(prog, n_clusters=2, cluster=cl, engine="event")
    bl = run_timing(prog, n_clusters=2, cluster=cl, engine="block")
    ok, diff = _summaries_equal(ev, bl)
    assert ok, diff


# ---------------------------------------------------------------------------
# The size guard is gone; big programs are cheap
# ---------------------------------------------------------------------------


def test_max_timed_commands_guard_removed():
    from repro.lower import executors

    assert not hasattr(executors, "MAX_TIMED_COMMANDS")


def test_million_command_ns_program_under_10s():
    """Acceptance: a >= 1e6-command NS-design conv program times in < 10s."""
    spec = Conv2dSpec(224, 224, 3, 7, 7, 64, stride=2, padding=3)
    prog = lower(spec, "fwd", design=NS_DESIGN)
    dw = lower(spec, "dw", design=NS_DESIGN)
    assert prog.n_commands + dw.n_commands >= 800_000
    t0 = time.perf_counter()
    res = run_timing(prog, n_clusters=16)  # auto -> block
    res2 = run_timing(dw, n_clusters=16)
    wall = time.perf_counter() - t0
    assert wall < 10.0, wall
    assert res.summary()["n_commands"] == prog.n_commands
    assert res2.summary()["n_commands"] == dw.n_commands
    # the makespan cannot beat perfect parallelism over 16 clusters x 8
    # engines nor the longest command
    assert res.total_cycles >= prog.busy_cycles / (16 * 8)


def test_wallclock_floor_20x_on_500k_commands():
    """Acceptance: >= 20x over the event engine on a >= 500k-command stream,
    with bit-identical stats."""
    template = NtxCommand(
        loops=(32, 4, 1, 1, 1),
        opcode="mac",
        agu_rd0=Agu(0, (1, 0, 0, 0, 0)),
        agu_rd1=Agu(200, (1, 0, 0, 0, 0)),
        agu_wr=Agu(400, (0, 1, 0, 0, 0)),
    )
    n = 500_000
    seg = BlockSegment(template, n, dma_cycles=20)
    t0 = time.perf_counter()
    ev = cmdqueue.simulate_offload(
        [template] * n, n_engines=8, queue_depth=4, dma_cycles=[20] * n
    )
    t_event = time.perf_counter() - t0
    t0 = time.perf_counter()
    bl = cmdqueue.simulate_offload_blocks([seg], n_engines=8, queue_depth=4)
    t_block = time.perf_counter() - t0
    assert ev.stats == bl.stats
    assert t_event / t_block >= 20.0, (t_event, t_block)

"""bf16 dtype consistency via eval_shape (no execution; XLA:CPU can't run
bf16 dots, but abstract evaluation catches scan-carry dtype leaks — the class
of bug that once broke the full-scale mamba2 dry-run)."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config, reduce_config
from repro.launch.train import init_train_state, make_train_step
from repro.models import lm
from repro.models.config import ParallelCtx
from repro.optim.optimizers import sgd

CTX = ParallelCtx(attn_backend="xla")
OPT = sgd(1e-2)


def _batch_structs(cfg, b=2, s=16):
    if cfg.input_mode == "embeddings":
        inp = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)
    elif cfg.n_codebooks > 1:
        inp = jax.ShapeDtypeStruct((b, s, cfg.n_codebooks), jnp.int32)
    else:
        inp = jax.ShapeDtypeStruct((b, s), jnp.int32)
    lab_shape = (b, s) if cfg.n_codebooks == 1 else (b, s, cfg.n_codebooks)
    return {"inputs": inp, "labels": jax.ShapeDtypeStruct(lab_shape, jnp.int32)}


@pytest.mark.parametrize("arch", ARCHS)
def test_bf16_train_step_abstractly(arch):
    cfg = reduce_config(get_config(arch)).with_(dtype=jnp.bfloat16)
    state = jax.eval_shape(lambda: init_train_state(jax.random.PRNGKey(0), cfg, OPT))
    step = make_train_step(cfg, CTX, OPT)
    new_state, metrics = jax.eval_shape(step, state, _batch_structs(cfg))
    # params keep their dtypes through the update
    for a, b in zip(jax.tree.leaves(state["params"]), jax.tree.leaves(new_state["params"])):
        assert a.dtype == b.dtype


@pytest.mark.parametrize("arch", ARCHS)
def test_bf16_serve_step_abstractly(arch):
    cfg = reduce_config(get_config(arch)).with_(dtype=jnp.bfloat16)
    params = jax.eval_shape(lambda: lm.init_lm(jax.random.PRNGKey(0), cfg))
    cache = jax.eval_shape(lambda: lm.init_cache(cfg, 2, 32))
    if cfg.input_mode == "embeddings":
        tok = jax.ShapeDtypeStruct((2, cfg.d_model), jnp.bfloat16)
    elif cfg.n_codebooks > 1:
        tok = jax.ShapeDtypeStruct((2, cfg.n_codebooks), jnp.int32)
    else:
        tok = jax.ShapeDtypeStruct((2,), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    logits, new_cache = jax.eval_shape(
        lambda p, c, t, q: lm.serve_step(p, c, t, q, cfg, CTX), params, cache, tok, pos
    )
    assert logits.dtype == jnp.float32
    for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(new_cache)):
        assert a.dtype == b.dtype and a.shape == b.shape

"""Sharding rules: every arch's param/opt/cache trees get valid specs for the
production mesh shape (divisibility-sanitized), without touching devices."""

import jax
import jax.numpy as jnp
import pytest

try:
    from jax.sharding import AbstractMesh, AxisType
except ImportError:
    pytest.skip("jax.sharding.AxisType not in this jax release",
                allow_module_level=True)

from repro.configs import ARCHS, get_config
from repro.launch import shapes as shp
from repro.models import lm
from repro.parallel import sharding as shd

MESH = AbstractMesh((16, 16), ("data", "model"), axis_types=(AxisType.Auto,) * 2)


def _check_tree(tree, shardings):
    leaves = jax.tree_util.tree_leaves(tree)
    shs = jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda x: isinstance(x, jax.sharding.NamedSharding)
    )
    assert len(leaves) == len(shs)
    for leaf, sh in zip(leaves, shs):
        spec = sh.spec
        entries = list(spec) + [None] * (len(leaf.shape) - len(spec))
        for e, d in zip(entries, leaf.shape):
            if e is None:
                continue
            axes = e if isinstance(e, tuple) else (e,)
            n = 1
            for a in axes:
                n *= MESH.shape[a]
            assert d % n == 0, (leaf.shape, spec)


@pytest.mark.parametrize("arch", ARCHS)
def test_param_and_opt_shardings_divisible(arch):
    cfg = get_config(arch)
    params = jax.eval_shape(lambda: lm.init_lm(jax.random.PRNGKey(0), cfg))
    p_sh = shd.param_shardings(params, MESH)
    _check_tree(params, p_sh)
    o_sh = shd.opt_state_shardings(params, MESH, ("data",))
    _check_tree(params, o_sh)


@pytest.mark.parametrize("arch", ARCHS)
def test_cache_shardings_divisible(arch):
    cfg = get_config(arch)
    cell = shp.SHAPES["decode_32k"]
    cache = jax.eval_shape(lambda: lm.init_cache(cfg, cell.batch, cell.seq))
    c_sh = shd.cache_specs(cache, MESH, ("data",), cell.batch)
    _check_tree(cache, c_sh)


def test_tp_weights_actually_sharded():
    """The big matrices must not silently fall back to replication."""
    cfg = get_config("qwen3_8b")
    params = jax.eval_shape(lambda: lm.init_lm(jax.random.PRNGKey(0), cfg))
    p_sh = shd.param_shardings(params, MESH)
    flat = dict(
        jax.tree_util.tree_flatten_with_path(p_sh)[0].__iter__()
        if False
        else [
            ("/".join(str(k) for k in path), v)
            for path, v in jax.tree_util.tree_flatten_with_path(
                p_sh, is_leaf=lambda x: isinstance(x, jax.sharding.NamedSharding)
            )[0]
        ]
    )
    sharded = [k for k, v in flat.items() if any(e is not None for e in v.spec)]
    # embeddings, attention projections, mlp mats must all be sharded
    assert any("embed" in k for k in sharded)
    assert any("wq" in k for k in sharded)
    assert any("w_down" in k for k in sharded)
    frac = len(sharded) / len(flat)
    assert frac > 0.5, f"only {frac:.0%} of leaves sharded"

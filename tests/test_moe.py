"""MoE invariants: routing, capacity, EP == dense oracle."""

import jax

from repro import compat
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.models import moe
from repro.models.config import ModelConfig


def _cfg(**kw):
    base = dict(
        name="m", family="moe", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
        head_dim=16, d_ff=64, vocab_size=64, n_experts=8, top_k=2, moe_d_ff=32,
        dtype=jnp.float32, capacity_factor=8.0,  # ample capacity => no drops
    )
    base.update(kw)
    return ModelConfig(**base)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 64), st.integers(1, 4))
def test_router_invariants(t, k):
    cfg = _cfg(top_k=k)
    rng = np.random.RandomState(t * 10 + k)
    x = jnp.asarray(rng.randn(t, cfg.d_model), jnp.float32)
    router = jnp.asarray(rng.randn(cfg.d_model, cfg.n_experts), jnp.float32)
    w, ids, aux = moe.route(x, router, k)
    assert w.shape == (t, k) and ids.shape == (t, k)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, atol=1e-5)  # renormalized
    assert bool((w >= 0).all())
    assert bool((ids >= 0).all()) and bool((ids < cfg.n_experts).all())
    # top-k ids are distinct per token
    for row in np.asarray(ids):
        assert len(set(row.tolist())) == k
    assert float(aux["load_balance"]) >= 1.0 - 1e-5  # >= 1 by Cauchy-Schwarz


def test_dense_vs_ep_single_rank():
    """EP on a 1-rank model axis with ample capacity == dense oracle."""
    cfg = _cfg()
    rng = jax.random.PRNGKey(0)
    params = moe.init_moe(rng, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model), jnp.float32)
    y_dense, aux_d = moe.moe_dense(x, params, cfg)
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    y_ep, aux_e = moe.moe_ep(x, params, cfg, mesh, dp_axes=())
    np.testing.assert_allclose(np.asarray(y_dense), np.asarray(y_ep), atol=1e-4)
    np.testing.assert_allclose(
        float(aux_d["load_balance"]), float(aux_e["load_balance"]), atol=1e-5
    )


def test_capacity_drops_reduce_output():
    """With capacity 0-ish, routed contributions vanish (drop semantics)."""
    cfg = _cfg(capacity_factor=1e-9)
    params = moe.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, cfg.d_model), jnp.float32)
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    y_ep, _ = moe.moe_ep(x, params, cfg, mesh, dp_axes=())
    y_dense, _ = moe.moe_dense(x, params, cfg)
    # capacity floor is 8 slots/expert, so *some* tokens survive, but overall
    # magnitude must shrink vs the uncapped oracle.
    assert float(jnp.abs(y_ep).mean()) < float(jnp.abs(y_dense).mean())


def test_shared_expert_always_active():
    cfg = _cfg(shared_expert_d_ff=32, capacity_factor=1e-9, top_k=1)
    params = moe.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 4, cfg.d_model), jnp.float32)
    y, _ = moe.moe_dense(x, params, cfg)
    # zero out routed experts: shared path must still produce signal
    p2 = dict(params)
    p2["w_down"] = jnp.zeros_like(params["w_down"])
    y2, _ = moe.moe_dense(x, p2, cfg)
    assert float(jnp.abs(y2).mean()) > 0

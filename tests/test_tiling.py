"""C3: tile planner invariants (VMEM budget, alignment, burst length)."""

import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.tiling import (
    DEFAULT_VMEM_BUDGET,
    LANE,
    MIN_BURST_ELEMS,
    plan_matmul_tiles,
    plan_stencil_tiles,
)

dim = st.integers(1, 16384)


@settings(max_examples=50, deadline=None)
@given(dim, dim, dim, st.sampled_from([1, 2, 4]))
def test_matmul_plan_fits_and_aligned(m, n, k, bytes_):
    plan = plan_matmul_tiles(m, n, k, in_dtype_bytes=bytes_)
    assert plan.vmem_bytes <= DEFAULT_VMEM_BUDGET
    assert plan.bm % LANE == 0 and plan.bn % LANE == 0 and plan.bk % LANE == 0
    # grid covers the problem
    assert plan.grid[0] * plan.bm >= m
    assert plan.grid[1] * plan.bn >= n
    assert plan.grid[2] * plan.bk >= k


@settings(max_examples=30, deadline=None)
@given(
    st.integers(4, 256),
    st.integers(4, 256),
    st.integers(1, 512),
    st.integers(1, 512),
    st.integers(1, 7),
)
def test_stencil_plan_fits(h, w, cin, cout, k):
    plan = plan_stencil_tiles(h, w, cin, cout, k, k)
    # weights alone may exceed the budget for pathological channel counts; the
    # planner must never *under-report*.
    inp = (plan.th + plan.halo) * (plan.tw + plan.halo) * cin
    out = plan.th * plan.tw * cout
    wgt = k * k * cin * cout
    assert plan.vmem_bytes == (2 * inp + 2 * out + wgt) * 4
    assert plan.burst_elems >= MIN_BURST_ELEMS
    assert plan.halo == k - 1


def test_reuse_grows_with_tiles():
    small = plan_matmul_tiles(128, 128, 4096)
    big = plan_matmul_tiles(4096, 4096, 4096)
    assert big.arithmetic_intensity >= small.arithmetic_intensity

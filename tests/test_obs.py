"""The unified telemetry layer: counters, merged traces, reports.

The load-bearing property is the tentpole's acceptance criterion: counter
totals recorded by the executors must equal the program's *closed-form*
counts (``NtxProgram.n_offloads`` / ``n_commands`` / ``dma_bytes``)
exactly — the counters are the program's own arithmetic, not a parallel
estimate. On top of that: registry mechanics (scoping, snapshot/restore,
merge, zero-overhead-off), the per-step JSONL schema, the plan-cache and
mesh-link instrumentation, the merged Perfetto trace's lanes and flow
events, and the shared BENCH ``schema_version`` envelope.
"""

import json

import numpy as np
import pytest

from repro import obs
from repro.lower import (
    lower_training_step,
    paper_cnn_graph,
    run_reference,
    run_timing,
    shard_training_step,
    train_graph,
)
from repro.obs.counters import block_scope, program_totals

jax = pytest.importorskip("jax")

from repro.lower import executors  # noqa: E402
from repro.lower.executors import PlanCache, run_pallas  # noqa: E402


def _graph_and_inputs(batch=2, img=8, seed=0):
    graph = paper_cnn_graph(batch=batch, img=img, lr=0.05, momentum=0.9)
    prog = lower_training_step(graph, n_clusters=4)
    rng = np.random.RandomState(seed)
    x = rng.randn(batch, img, img, 3).astype(np.float32)
    labels = rng.randint(0, graph.loss.classes, batch)
    onehot = np.eye(graph.loss.classes, dtype=np.float32)[labels]
    inputs = {graph.input_edge: x, graph.label_edge: onehot,
              **graph.init_params(seed=1)}
    return graph, prog, inputs


# ---------------------------------------------------------------------------
# Registry mechanics
# ---------------------------------------------------------------------------


def test_registry_scoping_and_totals():
    reg = obs.CounterRegistry()
    with reg.scope("step0"):
        with reg.scope("c1", "fwd"):
            reg.inc("offloads", 3)
            reg.inc("dma_bytes", 100)
        with reg.scope("c2", "fwd"):
            reg.inc("offloads", 2)
    reg.inc("offloads")  # root scope
    assert reg.get("step0/c1/fwd/offloads") == 3
    assert reg.get("step0/c2/fwd/offloads") == 2
    assert reg.total("offloads") == 6
    assert reg.total("offloads", prefix="step0/") == 5
    assert reg.totals("step0/") == {"offloads": 5, "dma_bytes": 100}
    assert reg.tree()["step0"]["c1"]["fwd"]["offloads"] == 3


def test_registry_prefixes_do_not_collide():
    # step1 must not swallow step10 (the trailing-separator contract).
    reg = obs.CounterRegistry()
    with reg.scope("step1"):
        reg.inc("offloads", 1)
    with reg.scope("step10"):
        reg.inc("offloads", 100)
    assert reg.total("offloads", prefix="step1/") == 1


def test_registry_disabled_records_nothing():
    reg = obs.CounterRegistry(enabled=False)
    with reg.scope("a"):
        reg.inc("x", 5)
    assert len(reg) == 0
    obs.record_program(reg, object())  # must not even touch the program


def test_registry_empty_is_still_truthy():
    # `if reg:` at an instrument site must mean "telemetry on", never
    # "has already counted something".
    assert bool(obs.CounterRegistry())
    assert len(obs.CounterRegistry()) == 0


def test_use_registry_installs_and_restores():
    assert obs.get_active() is None
    reg = obs.CounterRegistry()
    with obs.use_registry(reg):
        assert obs.get_active() is reg
    assert obs.get_active() is None


def test_snapshot_restore_merge_roundtrip():
    reg = obs.CounterRegistry()
    reg.inc("a/x", 2)
    reg.inc("y", 1.5)
    snap = reg.snapshot()
    assert json.loads(json.dumps(snap)) == snap  # checkpoint-extras safe
    reg.inc("a/x", 10)
    reg.restore(snap)
    assert reg.get("a/x") == 2
    other = obs.CounterRegistry()
    other.inc("a/x", 3)
    reg.merge(other)
    assert reg.get("a/x") == 5
    reg.merge(snap)
    assert reg.get("y") == 3.0


def test_block_scope_mapping():
    assert block_scope("c1:fwd:conv") == ("c1", "fwd")
    assert block_scope("fc:dw:matmul") == ("fc", "dw")
    assert block_scope("spill:act1") == ("tcdm", "spill")
    assert block_scope("fill:act1") == ("tcdm", "fill")
    assert block_scope("allreduce:update:fc:upd[0]") == ("mesh", "allreduce")
    assert block_scope("allgather:w_c1[1]") == ("mesh", "allgather")
    assert block_scope("") == ("untagged",)


# ---------------------------------------------------------------------------
# Executor counters == closed-form program counts (the acceptance check)
# ---------------------------------------------------------------------------


def test_run_reference_counters_match_closed_form():
    graph, prog, inputs = _graph_and_inputs()
    reg = obs.CounterRegistry()
    with obs.use_registry(reg):
        run_reference(prog, inputs)
    want = program_totals(prog)
    got = reg.totals()
    for leaf, v in want.items():
        assert got.get(leaf, 0) == v, leaf
    assert got["macs"] > 0
    assert want["offloads"] == prog.n_offloads
    assert want["dma_bytes"] == prog.dma_bytes


def test_run_timing_records_program_and_schedule():
    _, prog, _ = _graph_and_inputs()
    reg = obs.CounterRegistry()
    with obs.use_registry(reg):
        result = run_timing(prog, n_clusters=4)
    assert reg.total("commands") == prog.n_commands
    assert reg.get("timing/scheduled_programs") == 1
    assert reg.get("timing/total_cycles") == result.total_cycles
    assert reg.get("timing/exec_cycles") == result.exec_cycles
    assert reg.get("timing/exec_cycles") > 0


def test_run_pallas_counters_and_plan_cache():
    graph, prog, inputs = _graph_and_inputs()
    cache = PlanCache()
    reg = obs.CounterRegistry()
    with obs.use_registry(reg):
        with reg.scope("cold"):
            run_pallas(prog, inputs, cache=cache)
        with reg.scope("warm"):
            run_pallas(prog, inputs, cache=cache)
    for pfx in ("cold/", "warm/"):
        assert reg.total("commands", prefix=pfx) == prog.n_commands
        assert reg.total("offloads", prefix=pfx) == prog.n_offloads
    assert reg.get("cold/plan_cache/misses") > 0
    assert reg.get("warm/plan_cache/misses", 0) == 0
    assert reg.get("warm/plan_cache/hits") > 0
    assert reg.get("warm/plan_cache/retraces", 0) == 0


def test_zero_overhead_when_disabled_records_nothing_globally():
    graph, prog, inputs = _graph_and_inputs()
    assert obs.get_active() is None
    run_reference(prog, inputs)  # no registry installed: must not blow up


def test_train_graph_jsonl_matches_closed_form(tmp_path):
    graph, prog, _ = _graph_and_inputs(batch=2, img=8)
    rng = np.random.RandomState(0)
    eyec = np.eye(graph.loss.classes, dtype=np.float32)
    x = rng.randn(2, 8, 8, 3).astype(np.float32)
    labels = rng.randint(0, graph.loss.classes, 2)
    path = tmp_path / "metrics.jsonl"
    reg = obs.CounterRegistry()
    res = train_graph(graph, 2, lambda _i: (x, labels), program=prog,
                      backend="reference", registry=reg,
                      metrics_path=str(path))
    assert res["registry"] is reg
    recs = obs.read_jsonl(path)
    assert [r["step"] for r in recs] == [0, 1]
    want = program_totals(prog)
    for r in recs:
        assert r["schema_version"] == obs.SCHEMA_VERSION
        assert r["counters"]["offloads"] == want["offloads"]
        assert r["counters"]["commands"] == want["commands"]
        assert r["counters"]["dma_bytes"] == want["dma_bytes"]
        assert r["wall_s"] > 0 and "loss" in r
    # per-step scopes sum to steps x closed form
    assert reg.total("commands") == 2 * prog.n_commands


# ---------------------------------------------------------------------------
# Mesh-link counters
# ---------------------------------------------------------------------------


def test_time_mesh_step_link_counters_match_schedule():
    from repro.runtime.mesh import MeshInterconnect, time_mesh_step

    graph = paper_cnn_graph(batch=4, img=8)
    reg = obs.CounterRegistry()
    with obs.use_registry(reg):
        sharded = shard_training_step(graph, mesh_shape=(2, 2), n_clusters=4)
        time_mesh_step(sharded, n_clusters=4)
    upd = MeshInterconnect(2, 2).systolic_update(sharded.allreduce_bytes)
    assert reg.total("link_hops") == len(upd.transfers)
    assert reg.total("link_bytes") == sum(
        st.transfer.num_bytes for st in upd.transfers
    )
    assert reg.get("shard/programs") == 1
    assert reg.get("shard/hmcs") == 4
    assert reg.get("shard/allreduce_bytes") == sharded.allreduce_bytes


# ---------------------------------------------------------------------------
# Merged Perfetto trace
# ---------------------------------------------------------------------------


def test_merged_trace_has_all_lanes_and_flows(tmp_path):
    graph = paper_cnn_graph(batch=4, img=8)
    col = obs.TraceCollector()
    with obs.use_collector(col):
        sharded = shard_training_step(graph, mesh_shape=(2, 2), n_clusters=4)
        result, upd = col.add_mesh_step(sharded, n_clusters=4)
    cats = {e.get("cat") for e in col.events}
    assert {"exec", "dma", "link", "lowering", "flow"} <= cats
    phs = {e["ph"] for e in col.events}
    assert {"X", "s", "f"} <= phs  # flow starts + finishes present
    pids = {e["pid"] for e in col.events}
    assert {"hmc0", "mesh", "host"} <= pids
    # exec spans cover every non-elided block exactly once per cluster share
    path = tmp_path / "trace.json"
    col.save(path)
    doc = json.loads(path.read_text())
    assert doc["traceEvents"] and doc["displayTimeUnit"] == "ns"
    # every flow id has exactly one start and one finish
    starts = [e["id"] for e in col.events if e["ph"] == "s"]
    fins = [e["id"] for e in col.events if e["ph"] == "f"]
    assert sorted(starts) == sorted(fins)


def test_dispatch_spans_recorded_by_pallas_executor():
    graph, prog, inputs = _graph_and_inputs()
    # fused default: the whole step (loss gradient included) is one region,
    # so the walk records region spans instead of per-node dispatch spans
    col = obs.TraceCollector()
    with obs.use_collector(col):
        run_pallas(prog, inputs, cache=PlanCache())
    assert "fused" in {e.get("cat") for e in col.events}
    # the per-node escape hatch still emits one dispatch span per step
    col = obs.TraceCollector()
    with obs.use_collector(col):
        run_pallas(prog, inputs, cache=PlanCache(), fuse=False)
    assert "dispatch" in {e.get("cat") for e in col.events}


def test_block_spans_cover_commands():
    from repro.obs.trace import block_spans

    _, prog, _ = _graph_and_inputs()
    result = run_timing(prog, n_clusters=4, engine="event")
    spans = list(block_spans(prog, result, 4))
    assert sum(n for *_x, n in spans) == prog.n_commands
    for _c, _tag, e0, e1, _d0, _d1, _n in spans:
        assert e1 >= e0 >= 0


# ---------------------------------------------------------------------------
# Reports
# ---------------------------------------------------------------------------


def test_hotspot_table_renders_sections():
    reg = obs.CounterRegistry()
    with reg.scope("c1", "fwd"):
        reg.inc("busy_cycles", 5_000_000)
        reg.inc("dma_bytes", 123)
    txt = obs.format_hotspots(reg, k=3)
    assert "by cycles" in txt and "c1/fwd" in txt and "5.00M" in txt
    assert "by DMA bytes" in txt
    assert "by link bytes" not in txt  # no link traffic recorded


def test_bench_json_writer_stamps_schema_version(tmp_path):
    p = tmp_path / "BENCH_x.json"
    obs.write_bench_json({"summary": {"a": 1}, "schema_version": 999}, p)
    doc = json.loads(p.read_text())
    assert doc["schema_version"] == obs.SCHEMA_VERSION
    assert doc["summary"] == {"a": 1}


def test_offload_bench_envelope_single_writer(tmp_path):
    p = tmp_path / "BENCH_offload.json"
    results = {"a": {"wall_s": 1.5, "summary": {}},
               "b": {"wall_s": 0.5, "summary": {}}}
    obs.write_offload_bench(results, p)
    doc = json.loads(p.read_text())
    assert doc["total_wall_s"] == 2.0
    assert doc["schema_version"] == obs.SCHEMA_VERSION
    assert set(doc["benchmarks"]) == {"a", "b"}


def test_metrics_writer_coerces_arrays(tmp_path):
    path = tmp_path / "m.jsonl"
    with obs.MetricsWriter(path) as w:
        w.write({"step": 0, "metrics": {"ce": np.float32(1.25)}})
    recs = obs.read_jsonl(path)
    assert recs[0]["metrics"]["ce"] == 1.25

"""Deliverable (f): per-assigned-architecture smoke tests.

Each arch instantiates its REDUCED config (same family/block pattern, tiny
dims) and runs one forward + one train step + one serve step on CPU, asserting
output shapes and the absence of NaNs. The FULL configs are exercised by the
dry-run only.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, reduce_config
from repro.launch.train import init_train_state, make_train_step
from repro.models import lm
from repro.models.config import ParallelCtx
from repro.optim.optimizers import sgd

CTX = ParallelCtx(attn_backend="xla")


def _batch(cfg, b, s, seed=0):
    rng = jax.random.PRNGKey(seed)
    if cfg.input_mode == "embeddings":
        inputs = jax.random.normal(rng, (b, s, cfg.d_model), jnp.float32)
    elif cfg.n_codebooks > 1:
        inputs = jax.random.randint(rng, (b, s, cfg.n_codebooks), 0, cfg.vocab_size)
    else:
        inputs = jax.random.randint(rng, (b, s), 0, cfg.vocab_size)
    if cfg.n_codebooks > 1:
        labels = jax.random.randint(rng, (b, s, cfg.n_codebooks), 0, cfg.vocab_size)
    else:
        labels = jax.random.randint(rng, (b, s), 0, cfg.vocab_size)
    return {"inputs": inputs, "labels": labels}


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_forward_and_train_step(arch):
    cfg = reduce_config(get_config(arch))
    b, s = 2, 16
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, b, s)

    logits, aux = lm.forward(params, batch["inputs"], cfg, CTX)
    want = (
        (b, s, cfg.vocab_size)
        if cfg.n_codebooks == 1
        else (b, s, cfg.n_codebooks, cfg.vocab_size)
    )
    assert logits.shape == want, (arch, logits.shape)
    assert bool(jnp.isfinite(logits).all()), arch

    opt = sgd(lr=1e-2)
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    step = jax.jit(make_train_step(cfg, CTX, opt))
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"])), arch
    assert int(state["step"]) == 1
    for leaf in jax.tree.leaves(state["params"]):
        assert bool(jnp.isfinite(leaf).all()), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_serve_step(arch):
    cfg = reduce_config(get_config(arch))
    b, max_len = 2, 16
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    cache = lm.init_cache(cfg, b, max_len, dtype=jnp.float32)
    if cfg.input_mode == "embeddings":
        tok = jax.random.normal(jax.random.PRNGKey(1), (b, cfg.d_model), jnp.float32)
    elif cfg.n_codebooks > 1:
        tok = jax.random.randint(jax.random.PRNGKey(1), (b, cfg.n_codebooks), 0, cfg.vocab_size)
    else:
        tok = jax.random.randint(jax.random.PRNGKey(1), (b,), 0, cfg.vocab_size)
    logits, cache2 = lm.serve_step(params, cache, tok, jnp.int32(0), cfg, CTX)
    want = (b, cfg.vocab_size) if cfg.n_codebooks == 1 else (b, cfg.n_codebooks, cfg.vocab_size)
    assert logits.shape == want, (arch, logits.shape)
    assert bool(jnp.isfinite(logits).all()), arch
    # cache structure is preserved
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_loss_decreases(arch):
    """A few SGD steps on a fixed batch must reduce the loss (trainability)."""
    cfg = reduce_config(get_config(arch))
    batch = _batch(cfg, 4, 16, seed=3)
    opt = sgd(lr=0.1)
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    step = jax.jit(make_train_step(cfg, CTX, opt))
    _, m0 = step(state, batch)
    for _ in range(8):
        state, metrics = step(state, batch)
    assert float(metrics["ce"]) < float(m0["ce"]), (arch, float(m0["ce"]), float(metrics["ce"]))

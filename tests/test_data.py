"""Data pipeline: determinism, resumability, sharding, prefetch."""

import numpy as np

from repro.data.pipeline import DataIterator, InMemoryDataset, Prefetcher


def test_synthetic_deterministic():
    d1 = InMemoryDataset.synthetic(10_000, 97, 32, seed=7)
    d2 = InMemoryDataset.synthetic(10_000, 97, 32, seed=7)
    np.testing.assert_array_equal(d1.tokens, d2.tokens)


def test_batch_at_pure():
    ds = InMemoryDataset.synthetic(10_000, 97, 32, seed=0)
    b1 = ds.batch_at(5, 4, seed=3)
    b2 = ds.batch_at(5, 4, seed=3)
    np.testing.assert_array_equal(b1["inputs"], b2["inputs"])
    b3 = ds.batch_at(6, 4, seed=3)
    assert not np.array_equal(b1["inputs"], b3["inputs"])


def test_labels_shifted():
    ds = InMemoryDataset.synthetic(10_000, 97, 32, seed=0)
    b = ds.batch_at(0, 2, seed=0)
    assert b["inputs"].shape == (2, 32)
    # labels are inputs shifted by one within the sampled window
    np.testing.assert_array_equal(b["inputs"][:, 1:], b["labels"][:, :-1])


def test_iterator_resume_bit_identical():
    ds = InMemoryDataset.synthetic(20_000, 97, 16, seed=1)
    it = DataIterator(ds, batch_size=4, seed=9)
    batches = [next(it) for _ in range(5)]
    snap = it.state_dict()
    after = [next(it) for _ in range(3)]

    it2 = DataIterator(ds, batch_size=4, seed=0)
    it2.load_state_dict(snap)
    after2 = [next(it2) for _ in range(3)]
    for a, b in zip(after, after2):
        np.testing.assert_array_equal(a["inputs"], b["inputs"])
        np.testing.assert_array_equal(a["labels"], b["labels"])


def test_shards_disjoint():
    ds = InMemoryDataset.synthetic(64_000, 97, 32, seed=2)
    s0 = ds.shard(0, 4)
    s1 = ds.shard(1, 4)
    assert s0.n_sequences == s1.n_sequences
    # shards come from disjoint token ranges
    assert not np.array_equal(s0.tokens[:100], s1.tokens[:100])


def test_prefetcher_yields_and_stops():
    ds = InMemoryDataset.synthetic(10_000, 97, 16, seed=3)
    it = DataIterator(ds, batch_size=2, seed=0)
    pf = Prefetcher(it, depth=2)
    try:
        b1 = next(pf)
        b2 = next(pf)
        assert b1["inputs"].shape == (2, 16)
        assert not np.array_equal(np.asarray(b1["inputs"]), np.asarray(b2["inputs"]))
    finally:
        pf.stop()

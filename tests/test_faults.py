"""Survivable mesh: fault injection, elastic re-sharding, recovery.

The contract under test is PR 8's survivability story:

  * :class:`~repro.runtime.faults.ChaosSchedule` is strictly
    deterministic — scripted events fire exactly once and seeded
    schedules replay the same fault history for the same seed.
  * :func:`~repro.lower.reshard_training_step` re-partitions the whole
    train-step program onto the survivors **bit-identically** — the
    reference executor on the resharded program equals the unsharded
    step with ``assert_array_equal``, including uneven batches and
    cumulative kills.
  * :class:`~repro.runtime.faults.ChaosController` discards killed
    steps BEFORE they commit, so a chaos run's losses and final
    parameters match the healthy run exactly (reference backend), and
    bounded retry gives up after ``RetryPolicy.max_retries``.
  * The degraded :class:`~repro.runtime.mesh.MeshInterconnect` rejects
    dead links, falls back to the survivor-ring allreduce, and raises
    when failures partition the mesh.
"""

import numpy as np
import pytest

from repro.lower import (
    lower_training_step,
    paper_cnn_graph,
    reshard_training_step,
    run_reference,
    shard_training_step,
)
from repro.runtime.faults import (
    ChaosController,
    ChaosSchedule,
    RetryPolicy,
    time_recovery,
)
from repro.runtime.mesh import MeshInterconnect, time_mesh_step


def _inputs(graph, seed=0):
    rng = np.random.RandomState(seed)
    b, img = graph.batch, graph.input_shape[0]
    x = rng.randn(b, img, img, 3).astype(np.float32)
    labels = rng.randint(0, graph.loss.classes, b)
    onehot = np.eye(graph.loss.classes, dtype=np.float32)[labels]
    return {"x": x, "onehot": onehot, **graph.init_params(seed=seed + 1)}


def _batch_fn(graph):
    """Step-keyed batches: batch_fn(i) depends only on i (replayable)."""
    b, img = graph.batch, graph.input_shape[0]

    def fn(i):
        rng = np.random.RandomState(100 + i)
        x = rng.randn(b, img, img, 3).astype(np.float32)
        labels = rng.randint(0, graph.loss.classes, b)
        return x, labels

    return fn


# ---------------------------------------------------------------------------
# ChaosSchedule: grammar + determinism
# ---------------------------------------------------------------------------


def test_parse_scripted_grammar():
    s = ChaosSchedule.parse(
        "straggle:hmc=0,slow=2.5@step=3;kill:hmc=1@step=2;preempt@step=5"
    )
    assert [e.step for e in s.events] == [2, 3, 5]  # sorted by step
    kill, strag, pre = s.events
    assert (kill.kind, kill.hmc) == ("kill", 1)
    assert (strag.kind, strag.hmc, strag.slow) == ("straggle", 0, 2.5)
    assert (pre.kind, pre.hmc) == ("preempt", None)
    assert bool(s)


def test_parse_none_is_empty():
    for spec in ("none", "", "  NONE  "):
        s = ChaosSchedule.parse(spec)
        assert not s and s.events == ()


@pytest.mark.parametrize("bad", [
    "kill@step=2",               # kill needs hmc=
    "straggle@step=1",           # straggle needs hmc=
    "explode:hmc=1@step=2",      # unknown kind
    "kill:hmc=1",                # missing @step=
    "kill:hmc=1,wat=3@step=2",   # unknown param
    "random:p_kill=0.5",         # seeded spec needs seed=
])
def test_parse_rejects_bad_specs(bad):
    with pytest.raises(ValueError):
        ChaosSchedule.parse(bad)


def test_scripted_event_fires_once():
    s = ChaosSchedule.parse("kill:hmc=1@step=2")
    assert [e.describe() for e in s.events_at(2, 4)] == ["kill:hmc1@step2"]
    assert s.events_at(2, 4) == []  # replaying the step: already fired


def test_seeded_schedule_is_deterministic():
    spec = "random:seed=7,p_kill=0.02,p_straggle=0.05,slow=3,max_kills=2"

    def history(spec):
        s = ChaosSchedule.parse(spec)
        return [
            e.describe() for step in range(60) for e in s.events_at(step, 16)
        ]

    a, b = history(spec), history(spec)
    assert a == b and a, "same seed must replay the same fault history"
    kills = [e for e in a if e.startswith("kill")]
    assert len(kills) <= 2, "max_kills must cap cube deaths"
    assert history("random:seed=8,p_kill=0.02,p_straggle=0.05") != a


def test_retry_policy_backoff_bounds():
    p = RetryPolicy(max_retries=6, base_delay=0.5, factor=2.0, max_delay=4.0)
    ds = p.delays()
    assert ds == [0.5, 1.0, 2.0, 4.0, 4.0, 4.0]  # doubles, then capped
    assert all(a <= b for a, b in zip(ds, ds[1:]))  # monotone
    assert max(ds) <= p.max_delay
    with pytest.raises(ValueError):
        p.delay(-1)


# ---------------------------------------------------------------------------
# Elastic re-sharding: bit-identical on the survivors
# ---------------------------------------------------------------------------


def test_reshard_reference_bit_identical():
    graph = paper_cnn_graph(batch=8, img=8, momentum=0.9)
    prog = lower_training_step(graph)
    sh = shard_training_step(graph, mesh_shape=(2, 2), program=prog)
    degraded = reshard_training_step(sh, 1)
    assert degraded.alive_hmcs == (0, 2, 3)
    assert degraded.failed_hmcs == (1,)
    inputs = _inputs(graph)
    want = run_reference(prog, inputs)
    got = run_reference(degraded.program, inputs)
    assert set(got) == set(want)
    for k in want:
        np.testing.assert_array_equal(got[k], want[k], err_msg=k)


def test_reshard_cumulative_kills_bit_identical():
    """Failures accumulate: a second kill re-splits onto the remaining 2."""
    graph = paper_cnn_graph(batch=8, img=8)
    sh = shard_training_step(graph, mesh_shape=(2, 2))
    once = reshard_training_step(sh, 3)
    twice = reshard_training_step(once, 0)
    assert twice.alive_hmcs == (1, 2)
    assert twice.failed_hmcs == (0, 3)
    inputs = _inputs(graph, seed=2)
    want = run_reference(sh.base_program, inputs)
    got = run_reference(twice.program, inputs)
    for k in want:
        np.testing.assert_array_equal(got[k], want[k], err_msg=k)


def test_reshard_rejects_dead_and_out_of_mesh():
    graph = paper_cnn_graph(batch=8, img=8)
    sh = shard_training_step(graph, mesh_shape=(2, 2))
    degraded = reshard_training_step(sh, 1)
    with pytest.raises(ValueError):
        degraded.shard_program(1)  # dead cube has no shard
    with pytest.raises(ValueError):
        reshard_training_step(sh, 9)  # outside the mesh


def test_degraded_mesh_step_timing():
    graph = paper_cnn_graph(batch=8, img=8)
    sh = shard_training_step(graph, mesh_shape=(2, 2))
    degraded = reshard_training_step(sh, 2)
    tm = time_mesh_step(degraded, n_clusters=4)
    assert tm.n_alive == 3 and tm.n_hmcs == 4
    assert tm.t_step > 0
    # efficiency is measured against the SURVIVORS, not the full mesh
    assert tm.parallel_eff == pytest.approx(tm.speedup / 3)
    rec = time_recovery(sh, degraded, n_clusters=4)
    assert rec.t_detect > 0 and rec.t_restore > 0 and rec.t_replay > 0
    assert rec.cycles() == int(round(rec.t_total * 1.5e9))
    assert rec.overhead_steps == pytest.approx(rec.t_total / rec.healthy_step)
    for key in ("t_total_ms", "recovery_cycles", "overhead_steps"):
        assert key in rec.summary()


# ---------------------------------------------------------------------------
# ChaosController through the train loop (reference backend: exact numerics)
# ---------------------------------------------------------------------------


def _healthy_run(graph, sh, steps=4):
    from repro.lower.graph import train_graph

    return train_graph(graph, steps, _batch_fn(graph), backend="reference",
                       program=sh.program, params=graph.init_params(seed=0))


def test_chaos_kill_run_matches_healthy_exactly():
    from repro.lower.graph import train_graph

    graph = paper_cnn_graph(batch=8, img=8)
    sh = shard_training_step(graph, mesh_shape=(2, 2))
    want = _healthy_run(graph, sh)

    sh2 = shard_training_step(graph, mesh_shape=(2, 2))
    ctl = ChaosController("kill:hmc=1@step=2", sharded=sh2)
    got = train_graph(graph, 4, _batch_fn(graph), backend="reference",
                      program=sh2.program, params=graph.init_params(seed=0),
                      chaos=ctl)
    assert ctl.sharded.alive_hmcs == (0, 2, 3)
    assert ctl.report()["remesh_events"] == 1
    assert ctl.report()["recovery_cycles"] > 0
    np.testing.assert_array_equal(want["losses"], got["losses"])
    for k in want["params"]:
        np.testing.assert_array_equal(want["params"][k], got["params"][k],
                                      err_msg=k)


def test_chaos_preempt_rewinds_and_matches_healthy(tmp_path):
    from repro.lower.graph import train_graph

    graph = paper_cnn_graph(batch=8, img=8)
    sh = shard_training_step(graph, mesh_shape=(2, 2))
    want = _healthy_run(graph, sh)

    sh2 = shard_training_step(graph, mesh_shape=(2, 2))
    ctl = ChaosController("preempt@step=3", sharded=sh2,
                          ckpt_dir=tmp_path / "ck", ckpt_every=1)
    got = train_graph(graph, 4, _batch_fn(graph), backend="reference",
                      program=sh2.program, params=graph.init_params(seed=0),
                      chaos=ctl)
    assert ctl.report()["preemptions"] == 1
    assert any(e.startswith("preempt") for e in ctl.report()["events"])
    np.testing.assert_array_equal(want["losses"], got["losses"])
    for k in want["params"]:
        np.testing.assert_array_equal(want["params"][k], got["params"][k],
                                      err_msg=k)


def test_chaos_gives_up_after_max_retries():
    from repro.lower.graph import train_graph

    graph = paper_cnn_graph(batch=8, img=8)
    sh = shard_training_step(graph, mesh_shape=(2, 2))
    ctl = ChaosController("kill:hmc=1@step=1;kill:hmc=2@step=1",
                          sharded=sh, retry=RetryPolicy(max_retries=1))
    with pytest.raises(RuntimeError, match="gave up after 1"):
        train_graph(graph, 4, _batch_fn(graph), backend="reference",
                    program=sh.program, params=graph.init_params(seed=0),
                    chaos=ctl)
    assert ctl.backoffs == [0.5]  # the schedule it slept before dying


def test_chaos_straggler_records_without_changing_numerics():
    from repro.lower.graph import train_graph

    graph = paper_cnn_graph(batch=8, img=8)
    sh = shard_training_step(graph, mesh_shape=(2, 2))
    want = _healthy_run(graph, sh)
    sh2 = shard_training_step(graph, mesh_shape=(2, 2))
    ctl = ChaosController("straggle:hmc=0,slow=4@step=1", sharded=sh2)
    got = train_graph(graph, 4, _batch_fn(graph), backend="reference",
                      program=sh2.program, params=graph.init_params(seed=0),
                      chaos=ctl)
    assert ctl.report()["straggler_events"] == 1
    assert ctl.sharded.n_alive == 4  # nobody died
    np.testing.assert_array_equal(want["losses"], got["losses"])


# ---------------------------------------------------------------------------
# Degraded interconnect
# ---------------------------------------------------------------------------


def test_failed_cube_kills_its_links():
    net = MeshInterconnect(2, 2, failed=(1,))
    assert (0, 1) not in net.alive_nodes
    with pytest.raises(ValueError, match="failed cube"):
        net._check_link(((0, 0), (0, 1)))
    with pytest.raises(ValueError, match="degraded"):
        net.systolic_update(1e6)


def test_degraded_update_falls_back_to_survivor_ring():
    healthy = MeshInterconnect(4, 4)
    degraded = MeshInterconnect(4, 4, failed=(5,))
    assert len(degraded.alive_nodes) == 15
    assert healthy.update_time(1e6) == healthy.systolic_update(1e6).makespan
    assert degraded.update_time(1e6) == (
        degraded.ring_allreduce(1e6).makespan
    )
    # the survivor snake skips the hole but keeps every living cube
    snake = degraded._snake_nodes()
    assert len(snake) == 15 and (1, 1) not in snake


def test_partitioned_mesh_raises():
    # killing the diagonal of a 2x2 disconnects the two survivors
    net = MeshInterconnect(2, 2, failed=(0, 3))
    with pytest.raises(ValueError, match="partition"):
        net.ring_allreduce(1e6)

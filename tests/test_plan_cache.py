"""Pallas plan cache: jitted whole-pass executables, zero retraces after
warmup, device-array passthrough, and graph-driven whole-step execution.

Trace counts are asserted through ``CompiledPlan.traces`` — a counter
incremented inside the traced function, so it ticks exactly when jax
(re-)traces. All runs use interpret mode on CPU; numerics are checked
against the ``run_reference`` interpreter (itself oracle-checked in
test_lower.py / test_graph.py).
"""

import numpy as np
import pytest

from repro.lower import (
    Conv2dSpec,
    MatmulSpec,
    NetworkGraph,
    PlanCache,
    lower,
    lower_training_step,
    run_pallas,
    run_reference,
)

jnp = pytest.importorskip("jax.numpy")


def _rand(rng, *shape):
    return rng.randn(*shape).astype(np.float32)


def test_repeated_calls_hit_cache_zero_retraces():
    rng = np.random.RandomState(0)
    m, n, k = 8, 6, 12
    spec = MatmulSpec(m, n, k)
    prog = lower(spec, "fwd")
    a, b = _rand(rng, m, k), _rand(rng, k, n)
    cache = PlanCache()
    for _ in range(4):
        out = run_pallas(prog, {"a": a, "b": b}, cache=cache)
    np.testing.assert_allclose(np.asarray(out["c"]), a @ b, rtol=1e-4, atol=1e-4)
    assert len(cache) == 1
    assert cache.misses == 1 and cache.hits == 3
    (plan,) = cache._plans.values()
    assert plan.traces == 1, "retraced after warmup"
    assert plan.calls == 4


def test_equal_specs_share_one_plan():
    """The key is the spec value, not the program object: two independently
    lowered programs from equal specs reuse one executable."""
    rng = np.random.RandomState(1)
    spec = Conv2dSpec(8, 8, 3, 3, 3, 4, padding=1)
    x, w = _rand(rng, 8, 8, 3), _rand(rng, 3, 3, 3, 4)
    cache = PlanCache()
    run_pallas(lower(spec, "fwd"), {"x": x, "w": w}, cache=cache)
    run_pallas(lower(spec, "fwd"), {"x": x, "w": w}, cache=cache)
    assert len(cache) == 1 and cache.hits == 1


def test_jax_arrays_pass_through_and_return():
    rng = np.random.RandomState(2)
    spec = MatmulSpec(8, 8, 8)
    prog = lower(spec, "fwd")
    a = jnp.asarray(_rand(rng, 8, 8))
    b = jnp.asarray(_rand(rng, 8, 8))
    cache = PlanCache()
    out = run_pallas(prog, {"a": a, "b": b}, cache=cache)
    assert isinstance(out["c"], jnp.ndarray)  # jax.Array, no forced np copy
    np.testing.assert_allclose(
        np.asarray(out["c"]), np.asarray(a) @ np.asarray(b),
        rtol=1e-4, atol=1e-4,
    )


def test_all_passes_cached_and_match_reference():
    rng = np.random.RandomState(3)
    spec = Conv2dSpec(8, 8, 3, 3, 3, 4, stride=2, padding=1)
    x = _rand(rng, spec.in_h, spec.in_w, spec.cin)
    w = _rand(rng, spec.kh, spec.kw, spec.cin, spec.cout)
    dy = _rand(rng, spec.out_h, spec.out_w, spec.cout)
    cache = PlanCache()
    cases = [
        ("fwd", {"x": x, "w": w}, "y"),
        ("dw", {"x": x, "dy": dy}, "dw"),
        ("dx", {"dy": dy, "w": w}, "dx"),
    ]
    for pass_, ins, out_name in cases:
        prog = lower(spec, pass_)
        want = run_reference(prog, ins)[out_name]
        got = run_pallas(prog, ins, cache=cache)[out_name]
        got2 = run_pallas(prog, ins, cache=cache)[out_name]
        np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(got2))
    assert len(cache) == 3
    assert all(p.traces == 1 for p in cache._plans.values())


def test_graph_program_no_retrace_and_matches_reference():
    """A whole train-step program through the graph-driven Pallas executor:
    every output matches the reference interpreter, and a second invocation
    triggers zero new traces anywhere in the cache."""
    from benchmarks.workloads import pallas_graph

    rng = np.random.RandomState(4)
    graph = pallas_graph(batch=2)
    prog = lower_training_step(graph)
    params = graph.init_params(seed=1)
    inputs = {
        "x": _rand(rng, 2, 16, 16, 3),
        "onehot": np.eye(10, dtype=np.float32)[rng.randint(0, 10, 2)],
        **params,
    }
    want = run_reference(prog, inputs)
    cache = PlanCache()
    got = run_pallas(prog, inputs, cache=cache)
    assert set(got) == set(want)
    for k in want:
        np.testing.assert_allclose(
            np.asarray(got[k]), want[k], rtol=2e-3, atol=1e-5, err_msg=k
        )
    traces = sum(p.traces for p in cache._plans.values())
    got2 = run_pallas(prog, inputs, cache=cache)
    assert sum(p.traces for p in cache._plans.values()) == traces
    np.testing.assert_array_equal(
        np.asarray(got[graph.logits_edge]), np.asarray(got2[graph.logits_edge])
    )


def test_matmul_graph_through_plan_cache():
    rng = np.random.RandomState(5)
    graph = NetworkGraph.chain(
        "mlp", 6, (8,),
        [("l1", MatmulSpec(6, 10, 8)), ("r1", "relu"),
         ("l2", MatmulSpec(6, 4, 10))],
        lr=0.1,
    )
    prog = lower_training_step(graph)
    params = graph.init_params(seed=2)
    inputs = {
        "x": _rand(rng, 6, 8),
        "onehot": np.eye(4, dtype=np.float32)[rng.randint(0, 4, 6)],
        **params,
    }
    want = run_reference(prog, inputs)
    cache = PlanCache()
    got = run_pallas(prog, inputs, cache=cache)
    for k in want:
        np.testing.assert_allclose(
            np.asarray(got[k]), want[k], rtol=2e-3, atol=1e-5, err_msg=k
        )

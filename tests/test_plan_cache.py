"""Pallas plan cache: jitted whole-pass executables, zero retraces after
warmup, device-array passthrough, and the whole-chain network executor.

Trace counts are asserted through ``CompiledPlan.traces`` — a counter
incremented inside the traced function, so it ticks exactly when jax
(re-)traces. All runs use interpret mode on CPU; numerics are checked
against the ``run_reference`` interpreter (itself oracle-checked in
test_lower.py).
"""

import numpy as np
import pytest

from repro.lower import (
    Conv2dSpec,
    MatmulSpec,
    PlanCache,
    ReluSpec,
    lower,
    run_pallas,
    run_pallas_network,
    run_reference,
)

jnp = pytest.importorskip("jax.numpy")


def _rand(rng, *shape):
    return rng.randn(*shape).astype(np.float32)


def test_repeated_calls_hit_cache_zero_retraces():
    rng = np.random.RandomState(0)
    m, n, k = 8, 6, 12
    spec = MatmulSpec(m, n, k)
    prog = lower(spec, "fwd")
    a, b = _rand(rng, m, k), _rand(rng, k, n)
    cache = PlanCache()
    for _ in range(4):
        out = run_pallas(prog, {"a": a, "b": b}, cache=cache)
    np.testing.assert_allclose(np.asarray(out["c"]), a @ b, rtol=1e-4, atol=1e-4)
    assert len(cache) == 1
    assert cache.misses == 1 and cache.hits == 3
    (plan,) = cache._plans.values()
    assert plan.traces == 1, "retraced after warmup"
    assert plan.calls == 4


def test_equal_specs_share_one_plan():
    """The key is the spec value, not the program object: two independently
    lowered programs from equal specs reuse one executable."""
    rng = np.random.RandomState(1)
    spec = Conv2dSpec(8, 8, 3, 3, 3, 4, padding=1)
    x, w = _rand(rng, 8, 8, 3), _rand(rng, 3, 3, 3, 4)
    cache = PlanCache()
    run_pallas(lower(spec, "fwd"), {"x": x, "w": w}, cache=cache)
    run_pallas(lower(spec, "fwd"), {"x": x, "w": w}, cache=cache)
    assert len(cache) == 1 and cache.hits == 1


def test_jax_arrays_pass_through_and_return():
    rng = np.random.RandomState(2)
    spec = MatmulSpec(8, 8, 8)
    prog = lower(spec, "fwd")
    a = jnp.asarray(_rand(rng, 8, 8))
    b = jnp.asarray(_rand(rng, 8, 8))
    cache = PlanCache()
    out = run_pallas(prog, {"a": a, "b": b}, cache=cache)
    assert isinstance(out["c"], jnp.ndarray)  # jax.Array, no forced np copy
    np.testing.assert_allclose(
        np.asarray(out["c"]), np.asarray(a) @ np.asarray(b),
        rtol=1e-4, atol=1e-4,
    )


def test_all_passes_cached_and_match_reference():
    rng = np.random.RandomState(3)
    spec = Conv2dSpec(8, 8, 3, 3, 3, 4, stride=2, padding=1)
    x = _rand(rng, spec.in_h, spec.in_w, spec.cin)
    w = _rand(rng, spec.kh, spec.kw, spec.cin, spec.cout)
    dy = _rand(rng, spec.out_h, spec.out_w, spec.cout)
    cache = PlanCache()
    cases = [
        ("fwd", {"x": x, "w": w}, "y"),
        ("dw", {"x": x, "dy": dy}, "dw"),
        ("dx", {"dy": dy, "w": w}, "dx"),
    ]
    for pass_, ins, out_name in cases:
        prog = lower(spec, pass_)
        want = run_reference(prog, ins)[out_name]
        got = run_pallas(prog, ins, cache=cache)[out_name]
        got2 = run_pallas(prog, ins, cache=cache)[out_name]
        np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(got2))
    assert len(cache) == 3
    assert all(p.traces == 1 for p in cache._plans.values())


def test_network_chain_fwd_dw_dx_no_per_layer_retrace():
    """A conv-relu-conv training chain through cached plans: outputs match
    the chained reference executors, and a second invocation triggers zero
    new traces anywhere in the cache."""
    rng = np.random.RandomState(4)
    c1 = Conv2dSpec(10, 10, 3, 3, 3, 4, padding=1)
    r1 = ReluSpec((10, 10, 4))
    c2 = Conv2dSpec(10, 10, 4, 3, 3, 4, stride=2, padding=1)
    x = _rand(rng, 10, 10, 3)
    w1 = _rand(rng, 3, 3, 3, 4)
    w2 = _rand(rng, 3, 3, 4, 4)
    cache = PlanCache()
    net = run_pallas_network([c1, r1, c2], x, [w1, None, w2], cache=cache)

    # oracle: the reference interpreter, layer by layer
    y1 = run_reference(lower(c1, "fwd"), {"x": x, "w": w1})["y"]
    a1 = np.maximum(y1, 0)
    y2 = run_reference(lower(c2, "fwd"), {"x": a1, "w": w2})["y"]
    np.testing.assert_allclose(np.asarray(net["y"]), y2, rtol=1e-4, atol=1e-4)
    dy = np.ones_like(y2)
    dw2 = run_reference(lower(c2, "dw"), {"x": a1, "dy": dy})["dw"]
    dx2 = run_reference(lower(c2, "dx"), {"dy": dy, "w": w2})["dx"]
    g1 = dx2 * (y1 > 0)
    dw1 = run_reference(lower(c1, "dw"), {"x": x, "dy": g1})["dw"]
    dx1 = run_reference(lower(c1, "dx"), {"dy": g1, "w": w1})["dx"]
    np.testing.assert_allclose(np.asarray(net["dw"][2]), dw2, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(net["dw"][0]), dw1, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(net["dx"]), dx1, rtol=1e-3, atol=1e-4)
    assert net["dw"][1] is None  # relu carries no params

    traces = sum(p.traces for p in cache._plans.values())
    net2 = run_pallas_network([c1, r1, c2], x, [w1, None, w2], cache=cache)
    assert sum(p.traces for p in cache._plans.values()) == traces
    np.testing.assert_array_equal(np.asarray(net["y"]), np.asarray(net2["y"]))


def test_network_rejects_mismatched_params():
    with pytest.raises(ValueError):
        run_pallas_network([MatmulSpec(4, 4, 4)], np.zeros((4, 4)), [])


def test_matmul_chain_through_network():
    rng = np.random.RandomState(5)
    s1, s2 = MatmulSpec(6, 10, 8), MatmulSpec(6, 4, 10)
    x = _rand(rng, 6, 8)
    w1, w2 = _rand(rng, 8, 10), _rand(rng, 10, 4)
    cache = PlanCache()
    net = run_pallas_network([s1, s2], x, [w1, w2], cache=cache)
    y = (x @ w1) @ w2
    np.testing.assert_allclose(np.asarray(net["y"]), y, rtol=1e-4, atol=1e-4)
    dy = np.ones_like(y)
    np.testing.assert_allclose(
        np.asarray(net["dw"][1]), (x @ w1).T @ dy, rtol=1e-4, atol=1e-4
    )
    np.testing.assert_allclose(
        np.asarray(net["dx"]), (dy @ w2.T) @ w1.T, rtol=1e-4, atol=1e-4
    )

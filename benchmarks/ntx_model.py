"""The paper's analytical performance/energy model (§4.1, eqs. 4-21).

Implemented verbatim so the benchmark harness can reproduce Tables 4/5 and
Figures 8/9/14/15/16. Calibration constants come straight from the paper:

  * cluster energy 165 pJ/cycle at the 0.75 GHz cluster clock (§4.1.2),
  * eta_c = 0.84 NTX utilization, eta_d = 0.87 TCDM/DMA efficiency,
  * r_c = 8 MACs/NTX-cycle/cluster (8 co-processors), NTX clock 2x cluster,
  * P_dram(B) = 7.9 W + 21.5 mW/(GB/s) (§4.1.1), DRAM tech factor 0.87,
  * 28nm -> 14nm: 1.4x speed, 0.4x area, 0.7x dynamic power (§4.1.6),
  * HMC internal bandwidth cap 320 GB/s, serial links 60 GB/s  (§4.9),
  * mesh update: eqs. (14)-(21).
"""

from __future__ import annotations

import math
from dataclasses import dataclass

# --- technology ------------------------------------------------------------

TECH = {
    "28nm": dict(speed=1.0, power=1.0, area=1.0, dram_power=1.0, f_nom=1.5e9,
                 f_min=0.1e9, f_max=2.5e9),
    "14nm": dict(speed=1.4, power=0.7, area=0.4, dram_power=0.87, f_nom=2.1e9,
                 f_min=0.14e9, f_max=3.5e9),
}

E_CYCLE_28 = 165e-12  # J per NTX-clock cycle per cluster at nominal V (§4.1.2)
ETA_C = 0.84
ETA_D = 0.87
# Full-network utilization on top of the per-kernel eta_c: calibrated once so
# the model's GoogLeNet times land on Table 4 (tile boundaries, special
# functions, inter-layer stalls not visible in the single-kernel trace).
ETA_NET = 0.855
R_C_MACS = 8  # MACs per NTX cycle per cluster
R_D_BYTES = 4.8  # DMA bytes per NTX cycle per cluster (Table 4: 57.6 GB/s / 16 / 0.75 GHz / 2)
HMC_INTERNAL_BW = 320e9  # B/s
P_DRAM_STATIC = 7.9  # W
P_DRAM_PER_BW = 21.5e-3 / 1e9  # W per B/s
LINK_BW = 60e9  # B/s per serial link (§4.9)
P_LINKS = 8.0  # W, all four serial links
HOP_LATENCY = 20e-6  # s per cube (conservative, §4.9)
CUBE_POWER_MESH = 21.0  # W assumed during mesh compute (§4.9)


def voltage(f: float, tech: str) -> float:
    """V in [0.6, 1.2] linear in f across the tech's frequency range (§4.3)."""
    t = TECH[tech]
    frac = (f - t["f_min"]) / (t["f_max"] - t["f_min"])
    return 0.6 + 0.6 * min(max(frac, 0.0), 1.0)


def cluster_power(f: float, tech: str) -> float:
    """P_cl = 165 pJ * f, scaled quadratically with voltage and by tech node."""
    t = TECH[tech]
    v_nom = voltage(t["f_nom"], tech)
    return E_CYCLE_28 * t["power"] * f * (voltage(f, tech) / v_nom) ** 2


def p_dram(bandwidth: float, tech: str) -> float:
    return TECH[tech]["dram_power"] * (P_DRAM_STATIC + bandwidth * P_DRAM_PER_BW)


@dataclass(frozen=True)
class Kernel:
    """One offloaded workload: total MACs and DMA bytes (head/par/tail)."""

    macs: float
    bytes_total: float
    bytes_seq_frac: float = 0.02  # head+tail fraction (first fetch, last store)


def cluster_time(k: Kernel, f: float) -> tuple[float, float]:
    """Eqs. (4)-(7): (T_cl, B_cl) for one cluster at NTX frequency f."""
    t_c = k.macs / (ETA_C * ETA_NET * R_C_MACS * f)  # (4)
    d_seq = k.bytes_total * k.bytes_seq_frac
    t_dpar = (k.bytes_total - d_seq) / (ETA_D * R_D_BYTES * f)  # (5)
    t_dseq = d_seq / (ETA_D * R_D_BYTES * f)  # (6)
    t_cl = max(t_c, t_dpar) + t_dseq  # (7)
    return t_cl, k.bytes_total / t_cl


@dataclass(frozen=True)
class CubeMetrics:
    time: float  # s (eq. 11)
    bandwidth: float  # B/s (eq. 10)
    power: float  # W (eq. 12)
    efficiency: float  # flop/s/W (eq. 13)
    bw_capped: bool


def cube(k: Kernel, clusters: int, f: float, tech: str) -> CubeMetrics:
    """Eqs. (8)-(13): a kernel tiled across ``clusters`` clusters of one HMC."""
    per = Kernel(k.macs / clusters, k.bytes_total / clusters, k.bytes_seq_frac)
    t_cl, b_cl = cluster_time(per, f)
    bw = clusters * b_cl  # (10)
    capped = bw > HMC_INTERNAL_BW
    if capped:
        # internal bandwidth bound: stretch time to fit the cap (Fig. 8 dent)
        scale = bw / HMC_INTERNAL_BW
        t_cl *= scale
        bw = HMC_INTERNAL_BW
    t = t_cl  # (11): already per-cluster-share of the work
    p = p_dram(bw, tech) + clusters * cluster_power(f, tech)  # (12)
    eff = (2.0 * k.macs) / (p * t)  # (13)
    return CubeMetrics(time=t, bandwidth=bw, power=p, efficiency=eff, bw_capped=capped)


def best_operating_point(k: Kernel, clusters: int, tech: str, steps: int = 60):
    """Fig. 8: sweep frequency, return (f*, CubeMetrics) at max efficiency."""
    t = TECH[tech]
    best = None
    f = t["f_min"]
    step = (t["f_max"] - t["f_min"]) / steps
    while f <= t["f_max"] + 1e-6:
        m = cube(k, clusters, f, tech)
        if best is None or m.efficiency > best[1].efficiency:
            best = (f, m)
        f += step
    return best


# --- mesh of HMCs (eqs. 14-21) ----------------------------------------------


@dataclass(frozen=True)
class MeshMetrics:
    t_update: float
    t_step: float
    t_total: float
    speedup: float
    parallel_eff: float
    energy_eff: float


def mesh(
    n_side: int,
    batch: float,
    t_image: float = 8.69e-3,  # NTX64 GoogLeNet training (Table 4)
    weight_bytes: float = 300e6,
) -> MeshMetrics:
    n2 = n_side * n_side
    t_tx = weight_bytes / LINK_BW
    t_pass = t_tx + n_side * HOP_LATENCY  # (14)
    t_update = 4.0 * t_pass  # (15)
    t_step = t_image * batch / n2  # (16)
    t_total = t_update + t_step
    t_single = t_image * batch
    speedup = t_single / t_total
    e_pass = t_pass * (CUBE_POWER_MESH + P_LINKS)  # (17)
    e_pwrud = 2 * P_LINKS * 50e-3  # (18)
    e_update = 4 * e_pass + e_pwrud  # (19)
    e_step = t_step * CUBE_POWER_MESH * n2  # (20)  [total over mesh]
    e_total = (e_update + e_step / n2) * n2  # (21) per-cube update + its step share
    e_single = t_single * CUBE_POWER_MESH
    return MeshMetrics(
        t_update=t_update,
        t_step=t_step,
        t_total=t_total,
        speedup=speedup,
        parallel_eff=speedup / n2,
        energy_eff=e_single / e_total,
    )


# --- data-center comparisons (Figs. 15/16) ----------------------------------

P100_PEAK = 10.6e12  # flop/s
DGX_GPU_POWER = 2.4e3  # W (8x P100)
DGX_GPU_COMPUTE = 84.8e12  # flop/s
DGX_SERVER_POWER = 3.2e3  # W (whole DGX-1)
DGX_DRAM_POWER = 128.0  # W: 512 GB DDR4 at 6 W / 16 GB under load (§4.10)

# Table 5 operating points (14nm): clusters -> NTX frequency [GHz]
TABLE5_FREQ_14NM = {16: 3.08, 32: 2.24, 64: 1.68, 128: 0.98, 256: 0.56, 512: 0.28}


def ntx_config_peak(clusters: int, tech: str):
    """(peak flop/s, power) at the paper's Table 5 operating point."""
    f = TABLE5_FREQ_14NM.get(clusters, 1.0) * 1e9 if tech == "14nm" else 1.5e9
    k = Kernel(macs=5e9, bytes_total=400e6)  # 3x3-conv-like workload
    m = cube(k, clusters, f, tech)
    peak = 2.0 * R_C_MACS * clusters * f
    return peak, m.power, f


def same_compute(clusters: int = 128, tech: str = "14nm"):
    """Fig. 15: HMC count to match the DGX-1's 84.8 Tflop/s; server-level
    power reduction (GPUs and system DRAM both replaced by NTX-HMCs)."""
    peak, power, f = ntx_config_peak(clusters, tech)
    n = math.ceil(DGX_GPU_COMPUTE / peak)
    total_power = n * power
    server_old = DGX_SERVER_POWER + DGX_DRAM_POWER
    server_new = DGX_SERVER_POWER - DGX_GPU_POWER - DGX_DRAM_POWER + total_power
    return dict(n_hmcs=n, power=total_power, reduction=server_old / server_new, f=f)


def same_tdp(clusters: int = 128, tech: str = "14nm"):
    """Fig. 16: HMCs deployable in the 2.4 kW GPU budget; compute gained."""
    peak, power, f = ntx_config_peak(clusters, tech)
    n = int(DGX_GPU_POWER // power)
    total = n * peak
    return dict(n_hmcs=n, compute=total, improvement=total / DGX_GPU_COMPUTE, f=f)

"""Per-metric benchmark regression gate over the ``BENCH_*.json`` artifacts.

Replaces the old single-number "2x smoke wall budget": every benchmark
artifact is diffed against ``benchmarks/bench_baseline.json`` metric by
metric, a summary table goes to the job log, and any violation fails the
run. Three metric kinds:

  * ``wall``  — wall-clock seconds: one-sided, fails above
    ``WALL_BUDGET x`` baseline (machine-speed tolerant; catches simulator
    perf regressions, not CI-runner jitter).
  * ``model`` — deterministic modeled floats (cycle-derived times,
    efficiencies, ratios): two-sided ``MODEL_RTOL`` relative band — any
    real drift between the analytical model, the timing engine, and the
    lowering pipeline trips it.
  * ``exact`` — integers (command counts, cycle totals, TCDM peaks): must
    match the baseline bit for bit.
  * ``bound`` — absolute one-sided limit carried by the spec itself (no
    baseline entry): fails above ``limit``. Used for the instrumentation
    overhead gate (counters-on vs counters-off wall delta <= 5%).
  * ``floor`` — absolute one-sided minimum carried by the spec itself (no
    baseline entry): fails *below* ``limit``. Used for the PR-7 fusion
    gates (``fusion_coverage`` >= 0.8 and ``fused_speedup`` >= 5x — the
    speedup is an in-run ratio of warm fused vs per-node step walls, so it
    is machine-speed independent unlike the ``wall`` kind).

Every artifact must also carry the shared ``schema_version`` stamp
(:data:`repro.obs.report.SCHEMA_VERSION` — every writer routes through
``repro.obs.report``); a missing or mismatched stamp is a failure.

Usage::

    PYTHONPATH=src python -m benchmarks.check_regression FILE [FILE ...]
    PYTHONPATH=src python -m benchmarks.check_regression --update FILE ...

``--update`` re-records the baseline entries for the given files (run it
after an intentional perf/model change and commit the result). Named files
must exist — a missing artifact is a failure, not a silent pass.
"""

from __future__ import annotations

import argparse
import json
import os
from dataclasses import dataclass

BASELINE_PATH = os.path.join(os.path.dirname(__file__), "bench_baseline.json")

WALL_BUDGET = 2.5  # x baseline; CI runners are slower than dev boxes
MODEL_RTOL = 1e-3  # deterministic floats: drift band (ulp-noise tolerant)


@dataclass(frozen=True)
class MetricSpec:
    file: str  # artifact basename this metric comes from
    path: str  # dot path inside the json ("summary.n_commands")
    kind: str  # "wall" | "model" | "exact" | "bound" | "floor"
    limit: float | None = None  # "bound"/"floor": absolute one-sided limit


#: Every metric the gate tracks. Keys into the baseline are
#: ``"<file>:<path>"``.
SPECS = [
    # -- offload smoke suite (benchmarks.offload_bench --smoke) ------------
    MetricSpec("BENCH_offload.json", "total_wall_s", "wall"),
    MetricSpec("BENCH_offload.json",
               "benchmarks.offload_overhead.summary.min_overhead_reduction",
               "model"),
    MetricSpec("BENCH_offload.json",
               "benchmarks.model_crosscheck.summary.max_rel_err_uncapped",
               "model"),
    MetricSpec("BENCH_offload.json",
               "benchmarks.lowering_crosscheck.summary."
               "mean_train_to_infer_cycle_ratio", "model"),
    MetricSpec("BENCH_offload.json",
               "benchmarks.mesh_sweep.summary.t_image_sim_ms_ntx", "model"),
    MetricSpec("BENCH_offload.json",
               "benchmarks.mesh_sweep.summary.ntx_min_parallel_eff", "model"),
    MetricSpec("BENCH_offload.json",
               "benchmarks.mesh_sweep.summary.ns_program_commands", "exact"),
    # -- executed mesh sweep (benchmarks.mesh_bench) -----------------------
    MetricSpec("BENCH_mesh.json", "wall_s", "wall"),
    MetricSpec("BENCH_mesh.json", "summary.min_parallel_eff", "model"),
    MetricSpec("BENCH_mesh.json", "summary.max_model_rel_err", "model"),
    MetricSpec("BENCH_mesh.json", "summary.shard_cycles_total", "exact"),
    MetricSpec("BENCH_mesh.json", "summary.link_hops_total", "exact"),
    MetricSpec("BENCH_mesh.json", "summary.link_bytes_total", "model"),
    # survivability: lose 1 of N cubes (N in {4, 16, 64}) — recovery must
    # cost at most 2 healthy steps and the survivors must keep >= 90%
    # parallel efficiency (benchmarks.mesh_bench.recovery_sweep)
    MetricSpec("BENCH_mesh.json", "summary.recovery_cycles_total", "exact"),
    MetricSpec("BENCH_mesh.json", "summary.recovery_max_overhead_steps",
               "bound", limit=2.0),
    MetricSpec("BENCH_mesh.json", "summary.recovery_min_survivor_eff",
               "floor", limit=0.9),
    # 2D sharding (pipeline rows x tensor/data columns): the acceptance
    # gate — >= 80% parallel efficiency up to 64 cubes (including the
    # >= 16-cube meshes), GPipe bubble fraction bounded, and the link
    # traffic of the send/recv + tpgather + row-scoped update schedules
    # pinned (benchmarks.mesh_bench.mesh_2d_sweep)
    MetricSpec("BENCH_mesh.json", "summary.mesh2d_min_parallel_eff",
               "floor", limit=0.8),
    MetricSpec("BENCH_mesh.json", "summary.mesh2d_min_parallel_eff_16plus",
               "floor", limit=0.8),
    MetricSpec("BENCH_mesh.json", "summary.mesh2d_max_bubble_frac",
               "bound", limit=0.25),
    MetricSpec("BENCH_mesh.json", "summary.mesh2d_shard_cycles_total",
               "exact"),
    MetricSpec("BENCH_mesh.json", "summary.mesh2d_link_hops_total", "exact"),
    MetricSpec("BENCH_mesh.json", "summary.mesh2d_link_bytes_total", "model"),
    # -- whole-train-step bench (benchmarks.trainstep_bench) ---------------
    MetricSpec("BENCH_trainstep.json", "wall_s", "wall"),
    MetricSpec("BENCH_trainstep.json", "summary.n_commands", "exact"),
    MetricSpec("BENCH_trainstep.json", "summary.peak_tcdm_bytes", "exact"),
    MetricSpec("BENCH_trainstep.json", "summary.step_cycles_ntx", "exact"),
    MetricSpec("BENCH_trainstep.json", "summary.step_cycles_ns", "exact"),
    MetricSpec("BENCH_trainstep.json", "summary.counter_commands_total",
               "exact"),
    MetricSpec("BENCH_trainstep.json", "summary.counter_offloads_total",
               "exact"),
    MetricSpec("BENCH_trainstep.json", "summary.counter_dma_bytes_total",
               "exact"),
    MetricSpec("BENCH_trainstep.json",
               "summary.instrumentation_overhead_frac", "bound", limit=0.05),
    MetricSpec("BENCH_trainstep.json", "summary.fusion_coverage",
               "floor", limit=0.8),
    MetricSpec("BENCH_trainstep.json", "summary.fused_speedup",
               "floor", limit=5.0),
    # the tiny-transformer step (workloads.lm_graph through the DAG
    # compiler): program accounting is deterministic, so exact; the
    # loss-decrease and TCDM-budget gates live in trainstep_bench.GATES
    MetricSpec("BENCH_trainstep.json", "summary.lm_n_commands", "exact"),
    MetricSpec("BENCH_trainstep.json", "summary.lm_n_offloads", "exact"),
    MetricSpec("BENCH_trainstep.json", "summary.lm_step_cycles_ntx", "exact"),
    MetricSpec("BENCH_trainstep.json", "summary.lm_peak_tcdm_bytes", "exact"),
]


def _lookup(doc, path: str):
    cur = doc
    for part in path.split("."):
        if not isinstance(cur, dict) or part not in cur:
            raise KeyError(path)
        cur = cur[part]
    return cur


def _key(spec: MetricSpec) -> str:
    return f"{spec.file}:{spec.path}"


def load_baseline() -> dict:
    if not os.path.exists(BASELINE_PATH):
        return {"metrics": {}}
    with open(BASELINE_PATH) as f:
        return json.load(f)


def check_file(path: str, baseline: dict, *, update: bool) -> list[str]:
    """Check (or re-record) every tracked metric of one artifact.

    Returns human-readable failure lines; prints the per-metric summary.
    """
    name = os.path.basename(path)
    specs = [s for s in SPECS if s.file == name]
    if not specs:
        print(f"{name}: no tracked metrics (nothing to gate)")
        return []
    with open(path) as f:
        doc = json.load(f)
    metrics = baseline.setdefault("metrics", {})
    failures: list[str] = []
    print(f"== {name} ==")
    from repro.obs import SCHEMA_VERSION

    ver = doc.get("schema_version")
    if ver != SCHEMA_VERSION:
        failures.append(
            f"{name}: schema_version {ver!r} != {SCHEMA_VERSION} "
            "(every BENCH writer must route through repro.obs.report)"
        )
        print(f"  FAIL    schema_version: {ver!r} != {SCHEMA_VERSION}")
    for spec in specs:
        key = _key(spec)
        try:
            cur = float(_lookup(doc, spec.path))
        except KeyError:
            failures.append(f"{key}: metric missing from artifact")
            print(f"  MISSING  {spec.path}")
            continue
        if spec.kind in ("bound", "floor"):
            # Baseline-free: the one-sided limit rides in the spec itself.
            if spec.kind == "bound":
                ok = cur <= spec.limit
                detail = f"{cur:.4g} vs limit {spec.limit:.4g}"
            else:
                ok = cur >= spec.limit
                detail = f"{cur:.4g} vs floor {spec.limit:.4g}"
            print(f"  {'ok' if ok else 'FAIL':8s}{spec.path}: {detail}")
            if not ok:
                failures.append(f"{key}: {detail}")
            continue
        if update:
            metrics[key] = cur
            print(f"  RECORD   {spec.path} = {cur:.6g}")
            continue
        base = metrics.get(key)
        if base is None:
            failures.append(f"{key}: no baseline recorded "
                            f"(run check_regression --update)")
            print(f"  NOBASE   {spec.path} = {cur:.6g}")
            continue
        base = float(base)
        if spec.kind == "wall":
            ok = cur <= WALL_BUDGET * base
            detail = f"{cur:.3f}s vs {base:.3f}s (budget {WALL_BUDGET}x)"
        elif spec.kind == "exact":
            ok = cur == base
            detail = f"{cur:.0f} vs {base:.0f}"
        else:  # model
            denom = max(abs(base), 1e-12)
            rel = abs(cur - base) / denom
            ok = rel <= MODEL_RTOL
            detail = f"{cur:.6g} vs {base:.6g} (drift {rel:.2e})"
        print(f"  {'ok' if ok else 'FAIL':8s}{spec.path}: {detail}")
        if not ok:
            failures.append(f"{key}: {detail}")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="+",
                    help="BENCH_*.json artifacts to gate (must exist)")
    ap.add_argument("--update", action="store_true",
                    help="re-record the baseline entries for these files")
    args = ap.parse_args()

    baseline = load_baseline()
    failures: list[str] = []
    for path in args.files:
        if not os.path.exists(path):
            failures.append(f"{path}: artifact missing")
            print(f"{path}: MISSING (the producing benchmark did not run?)")
            continue
        failures += check_file(path, baseline, update=args.update)
    if args.update:
        with open(BASELINE_PATH, "w") as f:
            json.dump(baseline, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"baseline updated: {BASELINE_PATH}")
    if failures:
        raise SystemExit(
            "benchmark regression gate failed:\n  " + "\n  ".join(failures)
        )
    if not args.update:
        print("regression gate: all tracked metrics within budget")


if __name__ == "__main__":
    main()

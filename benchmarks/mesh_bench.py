"""Executed mesh-of-HMCs training sweep: sharded programs, timed links.

Where ``benchmarks/offload_bench.py::mesh_sweep`` feeds the paper's mesh
*equations* with a simulated per-image time, this benchmark **executes** the
mesh: :func:`repro.lower.shard_training_step` splits one whole-train-step
GoogLeNet program into per-HMC shards plus the gradient-allreduce epilogue,
the block-replicated timing engine times HMC 0's shard, and the weight
exchange runs through the event-level link scheduler of
:mod:`repro.runtime.mesh` (which lands on eqs. 14-15 exactly on the
congestion-free embedding). Parallel efficiency comes out of those two
timed components — and is cross-checked against ``ntx_model.mesh`` fed the
same per-image time, which must agree within 1%.

The sweep weak-scales the batch with the mesh exactly like Fig. 14 (more
cubes -> more images per step), covering >= 4 mesh sizes that must all
clear the paper's 95% parallel-efficiency bar.

Standalone::

    PYTHONPATH=src python -m benchmarks.mesh_bench

Writes ``artifacts/BENCH_mesh.json`` (uploaded by the CI bench-smoke lane
and diffed by ``benchmarks/check_regression.py``).
"""

from __future__ import annotations

import argparse
import time

from benchmarks import ntx_model as M

#: (mesh side, global batch) — Fig. 14-style weak scaling; every batch
#: divides evenly over its side**2 HMCs.
CASES = ((2, 512), (4, 1024), (8, 4096), (16, 8192))

EFF_FLOOR = 0.95  # the paper's §4.9 bar
MODEL_TOL = 0.01  # executed vs ntx_model.mesh parallel efficiency

#: Survivability cases: lose 1 of N cubes for N in {4, 16, 64}.
RECOVERY_CASES = ((2, 512), (4, 1024), (8, 4096))
RECOVERY_OVERHEAD_CAP = 2.0  # recovery costs <= this many healthy steps
SURVIVOR_EFF_FLOOR = 0.90  # parallel eff of the N-1 survivors

#: 2D (pipeline rows x tensor/data columns) weak scaling: 4 -> 64 cubes.
#: Rows stay at 2 because GoogLeNet's trunk is conv1/conv2-heavy — two
#: balanced stages exist, four don't (documented in docs/architecture.md);
#: columns weak-scale the batch like Fig. 14. The biggest cases' step
#: footprint exceeds one HMC's 4 GiB DRAM — the model-parallel wall the
#: 2D layout exists to cross.
CASES_2D = ((2, 2, 512), (2, 4, 1024), (2, 8, 2048), (2, 16, 4096),
            (2, 32, 8192))
EFF_FLOOR_2D = 0.80  # acceptance floor for pipeline+tensor efficiency
BUBBLE_CAP_2D = 0.25  # GPipe fill/drain bubble fraction bound


def mesh_executed_sweep(cases=CASES, network="googlenet", n_clusters=16,
                        f_ntx=1.5e9):
    """One row per mesh size: executed vs modeled parallel efficiency."""
    from repro.lower import shard_training_step
    from repro.obs import CounterRegistry, use_registry
    from repro.runtime.mesh import (
        MeshInterconnect,
        expected_update_time,
        time_mesh_step,
    )

    from benchmarks.workloads import network_graph

    rows = []
    effs = []
    errs = []
    cmds = {}
    shard_cycles_total = 0
    reg = CounterRegistry()
    for side, batch in cases:
        graph = network_graph(network, batch=batch)
        with use_registry(reg), reg.scope(f"{side}x{side}"):
            sharded = shard_training_step(
                graph, mesh_shape=(side, side), n_clusters=n_clusters
            )
            tm = time_mesh_step(sharded, n_clusters=n_clusters, f_ntx=f_ntx)
        mod = M.mesh(side, batch, t_image=tm.t_image,
                     weight_bytes=sharded.allreduce_bytes)
        err = abs(tm.parallel_eff - mod.parallel_eff) / mod.parallel_eff
        net = MeshInterconnect(side, side)
        ring_ms = net.ring_allreduce_time(sharded.allreduce_bytes) * 1e3
        upd_eq15 = expected_update_time(sharded.allreduce_bytes, side, side)
        effs.append(tm.parallel_eff)
        errs.append(err)
        cmds[f"{side}x{side}"] = sharded.program.n_commands
        shard_cycles_total += tm.shard_cycles
        rows.append((
            f"{side}x{side}/b{batch}", sharded.program.n_commands,
            tm.t_shard * 1e3, tm.t_update * 1e3, ring_ms,
            tm.parallel_eff, mod.parallel_eff, err,
        ))
        assert abs(tm.t_update - upd_eq15) < 1e-9, (
            f"{side}x{side}: link schedule {tm.t_update} != eq. 15 {upd_eq15}"
        )
    return rows, {
        "n_mesh_sizes": len(rows),
        "min_parallel_eff": min(effs),
        "max_model_rel_err": max(errs),
        "shard_cycles_total": shard_cycles_total,
        "link_bytes_total": reg.total("link_bytes"),
        "link_hops_total": reg.total("link_hops"),
        "allreduce_bytes_total": reg.total("allreduce_bytes"),
        "parallel_eff_above_95pct": min(effs) >= EFF_FLOOR,
        "within_1pct_of_model": max(errs) < MODEL_TOL,
        "four_or_more_sizes": len(rows) >= 4,
    }


def mesh_2d_sweep(cases=CASES_2D, network="googlenet", n_clusters=16,
                  f_ntx=1.5e9):
    """Executed 2D sweep: pipeline rows + tensor/data columns, 4-64 cubes.

    Every case shards the whole-step program with ``shard="2d"`` (rows =
    GPipe stages with explicit send/recv link traffic, columns = the
    tensor/data hybrid), times each row's representative shard plus the
    boundary/update link schedules, and reports microbatch count, bubble
    fraction and parallel efficiency vs the timed unsharded step. The
    step's tensor footprint is checked against ``HMC_DRAM_BYTES`` — the
    acceptance workload must NOT fit one cube.
    """
    from repro.lower import shard_training_step
    from repro.obs import CounterRegistry, use_registry
    from repro.runtime.mesh import HMC_DRAM_BYTES, time_mesh_step

    from benchmarks.workloads import network_graph

    rows = []
    effs = []
    bubbles = []
    footprints = []
    n_cubes = []
    shard_cycles_total = 0
    reg = CounterRegistry()
    for r, c, batch in cases:
        graph = network_graph(network, batch=batch)
        with use_registry(reg), reg.scope(f"{r}x{c}"):
            sharded = shard_training_step(
                graph, mesh_shape=(r, c), n_clusters=n_clusters, shard="2d"
            )
            tm = time_mesh_step(sharded, n_clusters=n_clusters, f_ntx=f_ntx)
        footprint = sum(
            reg2.bytes for reg2 in sharded.base_program.regions.values()
        )
        effs.append(tm.parallel_eff)
        bubbles.append(tm.bubble_frac)
        footprints.append(footprint)
        n_cubes.append(r * c)
        shard_cycles_total += tm.shard_cycles
        rows.append((
            f"{r}x{c}/b{batch}", sharded.program.n_commands, tm.n_micro,
            tm.t_compute * 1e3, tm.t_boundary * 1e3, tm.t_update * 1e3,
            tm.bubble_frac, tm.parallel_eff, footprint / 2**30,
        ))
    big_eff = min(e for e, n in zip(effs, n_cubes) if n >= 16)
    return rows, {
        "mesh2d_n_cases": len(rows),
        "mesh2d_min_parallel_eff": min(effs),
        "mesh2d_min_parallel_eff_16plus": big_eff,
        "mesh2d_max_bubble_frac": max(bubbles),
        "mesh2d_shard_cycles_total": shard_cycles_total,
        "mesh2d_link_bytes_total": reg.total("link_bytes"),
        "mesh2d_link_hops_total": reg.total("link_hops"),
        "mesh2d_eff_above_80pct": min(effs) >= EFF_FLOOR_2D,
        "mesh2d_bubble_bounded": max(bubbles) <= BUBBLE_CAP_2D,
        "mesh2d_covers_4_to_64_cubes": (min(n_cubes) <= 4
                                        and max(n_cubes) >= 64
                                        and any(n >= 16 for n in n_cubes)),
        "mesh2d_big_case_exceeds_one_hmc": max(footprints) > HMC_DRAM_BYTES,
    }


def recovery_sweep(cases=RECOVERY_CASES, network="googlenet", n_clusters=16,
                   f_ntx=1.5e9):
    """Losing 1 of N cubes: modeled recovery cost + survivor efficiency.

    For each mesh the last cube is killed via
    :func:`repro.lower.reshard_training_step`, the whole-step program is
    re-partitioned onto the survivors, and :func:`repro.runtime.faults.
    time_recovery` prices the recovery (detect + restore + replay) in the
    same event-level link-scheduler currency as the healthy sweep. Gates:
    recovery costs at most ``RECOVERY_OVERHEAD_CAP`` healthy steps, and
    the N-1 survivors keep parallel efficiency above
    ``SURVIVOR_EFF_FLOOR``.
    """
    from types import SimpleNamespace

    from repro.lower import reshard_training_step, shard_training_step
    from repro.runtime.faults import time_recovery
    from repro.runtime.mesh import time_mesh_step

    from benchmarks.workloads import network_graph

    rows = []
    effs = []
    overheads = []
    cycles_total = 0
    for side, batch in cases:
        graph = network_graph(network, batch=batch)
        healthy = shard_training_step(
            graph, mesh_shape=(side, side), n_clusters=n_clusters
        )
        degraded = reshard_training_step(healthy, side * side - 1)
        tm_h = time_mesh_step(healthy, n_clusters=n_clusters, f_ntx=f_ntx)
        # the unsharded reference is the same program for both meshes —
        # time it once and share the ScheduleResult cycles
        single = SimpleNamespace(total_cycles=tm_h.single_cycles)
        tm_d = time_mesh_step(degraded, n_clusters=n_clusters, f_ntx=f_ntx,
                              single_result=single)
        rec = time_recovery(healthy, degraded, n_clusters=n_clusters,
                            f_ntx=f_ntx, single_result=single)
        effs.append(tm_d.parallel_eff)
        overheads.append(rec.overhead_steps)
        cycles_total += rec.cycles(f_ntx)
        rows.append((
            f"{side}x{side}-1/b{batch}", degraded.n_alive,
            rec.t_detect * 1e3, rec.t_restore * 1e3, rec.t_replay * 1e3,
            rec.overhead_steps, tm_d.parallel_eff,
        ))
    return rows, {
        "recovery_n_cases": len(rows),
        "recovery_cycles_total": cycles_total,
        "recovery_max_overhead_steps": max(overheads),
        "recovery_min_survivor_eff": min(effs),
        "recovery_overhead_bounded": max(overheads) <= RECOVERY_OVERHEAD_CAP,
        "survivor_eff_above_floor": min(effs) >= SURVIVOR_EFF_FLOOR,
        "recovery_covers_three_sizes": len(rows) >= 3,
    }


def write_mesh_trace(path, *, network="googlenet", side=2, batch=8,
                     n_clusters=16) -> str:
    """Merged Perfetto trace for one small mesh step (the CI artifact).

    Lowers the network at a trace-friendly batch (full per-command records
    under the event engine), shards it over a ``side x side`` mesh, and
    emits HMC 0's cluster exec/DMA lanes, the systolic update's link lanes,
    the host-side lowering spans and the flow arrows tying them together.
    """
    from repro.lower import shard_training_step
    from repro.obs import TraceCollector, use_collector

    from benchmarks.workloads import network_graph

    col = TraceCollector()
    with use_collector(col):
        graph = network_graph(network, batch=batch)
        sharded = shard_training_step(
            graph, mesh_shape=(side, side), n_clusters=n_clusters
        )
        col.add_mesh_step(sharded, n_clusters=n_clusters)
    return col.save(path)


GATES = ("parallel_eff_above_95pct", "within_1pct_of_model",
         "four_or_more_sizes", "recovery_overhead_bounded",
         "survivor_eff_above_floor", "recovery_covers_three_sizes",
         "mesh2d_eff_above_80pct", "mesh2d_bubble_bounded",
         "mesh2d_covers_4_to_64_cubes", "mesh2d_big_case_exceeds_one_hmc")


def write_json(rows, summary, wall_s, recovery_rows=(), rows_2d=(),
               path: str = "artifacts/BENCH_mesh.json") -> str:
    from repro.obs import write_bench_json

    return write_bench_json({
        "wall_s": wall_s,
        "summary": summary,
        "rows": [list(r) for r in rows],
        "columns": ["mesh/batch", "n_commands", "t_shard_ms",
                    "t_update_ms", "t_ring_ms", "parallel_eff",
                    "model_parallel_eff", "rel_err"],
        "recovery_rows": [list(r) for r in recovery_rows],
        "recovery_columns": ["mesh-1/batch", "n_alive", "t_detect_ms",
                             "t_restore_ms", "t_replay_ms",
                             "overhead_steps", "survivor_parallel_eff"],
        "rows_2d": [list(r) for r in rows_2d],
        "columns_2d": ["mesh/batch", "n_commands", "n_micro",
                       "t_compute_ms", "t_boundary_ms", "t_update_ms",
                       "bubble_frac", "parallel_eff", "footprint_gib"],
    }, path)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--network", default="googlenet")
    ap.add_argument("--json", default="artifacts/BENCH_mesh.json")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="also write the merged Perfetto trace for one "
                         "small 2x2 mesh step (CI uploads this artifact)")
    args = ap.parse_args()

    t0 = time.perf_counter()
    rows, summary = mesh_executed_sweep(network=args.network)
    rec_rows, rec_summary = recovery_sweep(network=args.network)
    summary.update(rec_summary)
    rows_2d, summary_2d = mesh_2d_sweep(network=args.network)
    summary.update(summary_2d)
    wall = time.perf_counter() - t0
    for r in rows:
        print("  ", *(f"{x:.4g}" if isinstance(x, float) else x for x in r))
    print("  -- recovery (lose 1 of N) --")
    for r in rec_rows:
        print("  ", *(f"{x:.4g}" if isinstance(x, float) else x for x in r))
    print("  -- 2d: pipeline rows x tensor/data columns --")
    for r in rows_2d:
        print("  ", *(f"{x:.4g}" if isinstance(x, float) else x for x in r))
    for k, v in summary.items():
        print(f"   -> {k}: {v}")
    print("json:", write_json(rows, summary, wall, rec_rows, rows_2d,
                              args.json))
    if args.trace:
        print("trace:", write_mesh_trace(args.trace, network=args.network))
    failed = [g for g in GATES if not summary.get(g)]
    if failed:
        raise SystemExit(f"mesh gates failed: {', '.join(failed)}")


if __name__ == "__main__":
    main()
